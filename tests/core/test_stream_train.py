"""Streaming-epoch engine gates (ISSUE 3 acceptance):

  * a streamed one-pass run is allclose to the in-memory ``train_epoch`` on
    the same realized shuffled order — binary and multi-class, including the
    ragged-chunk carry path;
  * a run killed mid-epoch resumes from its checkpoint and finishes BITWISE
    identical to the uninterrupted run.
"""
import os

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.core import (BSGDConfig, MulticlassSVMConfig, fit_multiclass_stream,
                        fit_stream, init_multiclass_state, init_state,
                        train_epoch, train_epoch_multiclass,
                        train_epoch_stream)
from repro.data import (ArrayChunks, FileChunks, epoch_permutation, make_blobs,
                        make_blobs_multiclass, write_npz_chunks)

# one shared config -> the jitted chunk/step programs compile once per module
CFG = BSGDConfig(budget=16, lambda_=1e-4, gamma=0.5, batch_size=4)
MCFG = MulticlassSVMConfig(n_classes=3, binary=CFG)
DIM = 6


def _binary(n=200, seed=0):
    x, y = make_blobs(jax.random.PRNGKey(seed), n, DIM)
    return np.asarray(x), np.asarray(y)


def _leaves_equal(a, b, *, exact, atol=1e-6):
    for name, la, lb in zip(a._fields, a, b):
        if la is None:
            assert lb is None
            continue
        la, lb = np.asarray(la), np.asarray(lb)
        if exact:
            assert np.array_equal(la, lb), name
        else:
            np.testing.assert_allclose(la, lb, atol=atol, err_msg=name)


def test_stream_matches_inmemory_binary():
    x, y = _binary()
    src = ArrayChunks(x, y, 40)                   # 5 even chunks
    seed = 7
    st_stream = fit_stream(CFG, src, epochs=1, seed=seed)
    perm = epoch_permutation(src, jax.random.fold_in(jax.random.PRNGKey(seed), 0))
    st_mem = train_epoch(CFG, CFG.table(), init_state(CFG, DIM), x, y, perm)
    _leaves_equal(st_mem, st_stream, exact=False)


def test_stream_matches_inmemory_ragged_carry():
    """Chunk lens not divisible by batch_size: remainder rows carry into the
    next chunk, so the realized batch sequence equals the in-memory one."""
    x, y = _binary(n=197)
    src = ArrayChunks(x, y, 37)
    assert any(c % CFG.batch_size for c in src.chunk_lens)
    st_stream = fit_stream(CFG, src, epochs=1, seed=3)
    perm = epoch_permutation(src, jax.random.fold_in(jax.random.PRNGKey(3), 0))
    st_mem = train_epoch(CFG, CFG.table(), init_state(CFG, DIM), x, y, perm)
    _leaves_equal(st_mem, st_stream, exact=False)


def test_stream_matches_inmemory_multiclass():
    x, y = make_blobs_multiclass(jax.random.PRNGKey(1), 180, DIM, 3)
    x, y = np.asarray(x), np.asarray(y)
    src = ArrayChunks(x, y, 36)
    st_stream = fit_multiclass_stream(MCFG, src, epochs=1, seed=5)
    perm = epoch_permutation(src, jax.random.fold_in(jax.random.PRNGKey(5), 0))
    st_mem = train_epoch_multiclass(MCFG, MCFG.table(),
                                    init_multiclass_state(MCFG, DIM), x, y,
                                    jax.numpy.asarray(perm))
    _leaves_equal(st_mem, st_stream, exact=False)


def test_kill_and_resume_bitwise(tmp_path):
    """Killed after N chunks (no final checkpoint written — a hard kill),
    resumed from the every-2-chunks checkpoint: bitwise-identical end state,
    across an epoch boundary and with ragged chunks."""
    x, y = _binary(n=230)
    src = ArrayChunks(x, y, 37)                   # 7 ragged chunks
    ref = fit_stream(CFG, src, epochs=2, seed=5)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src, epochs=2, seed=5, ckpt_dir=ck, ckpt_every=2,
               max_chunks=9)                      # dies mid-epoch-2
    steps = ckpt.all_steps(ck)
    assert steps and max(steps) <= 9
    meta = ckpt.load_metadata(ck, max(steps))
    assert meta["kind"] == "stream-epoch" and meta["epoch"] == 1
    resumed = fit_stream(CFG, src, epochs=2, seed=5, ckpt_dir=ck,
                         ckpt_every=2)
    _leaves_equal(ref, resumed, exact=True)


def test_kill_between_checkpoints_replays_chunks(tmp_path):
    """A kill BETWEEN checkpoints replays the since-last-checkpoint chunks on
    resume — still bitwise (the replayed programs are deterministic)."""
    x, y = _binary(n=200)
    src = ArrayChunks(x, y, 40)
    ref = fit_stream(CFG, src, epochs=1, seed=11)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src, epochs=1, seed=11, ckpt_dir=ck, ckpt_every=2,
               max_chunks=3)                      # ckpt at 2, killed at 3
    assert ckpt.latest_step(ck) == 2
    resumed = fit_stream(CFG, src, epochs=1, seed=11, ckpt_dir=ck,
                         ckpt_every=2)
    _leaves_equal(ref, resumed, exact=True)


def test_resume_multiclass_bitwise(tmp_path):
    x, y = make_blobs_multiclass(jax.random.PRNGKey(2), 180, DIM, 3)
    x, y = np.asarray(x), np.asarray(y)
    src = ArrayChunks(x, y, 36)
    ref = fit_multiclass_stream(MCFG, src, epochs=1, seed=4)
    ck = os.path.join(tmp_path, "ck")
    fit_multiclass_stream(MCFG, src, epochs=1, seed=4, ckpt_dir=ck,
                          ckpt_every=1, max_chunks=2)
    resumed = fit_multiclass_stream(MCFG, src, epochs=1, seed=4, ckpt_dir=ck,
                                    ckpt_every=1)
    _leaves_equal(ref, resumed, exact=True)


def test_resume_refuses_mismatched_seed_or_chunking(tmp_path):
    """The checkpoint cursor is only meaningful against the same shuffle and
    chunking; resuming with a different seed or a re-chunked source must
    raise, not silently train a corrupted epoch."""
    import pytest

    x, y = _binary(n=200)
    src = ArrayChunks(x, y, 40)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src, epochs=1, seed=5, ckpt_dir=ck, ckpt_every=2,
               max_chunks=2)
    with pytest.raises(ValueError, match="seed"):
        fit_stream(CFG, src, epochs=1, seed=6, ckpt_dir=ck)
    with pytest.raises(ValueError, match="chunks"):
        fit_stream(CFG, ArrayChunks(x, y, 50), epochs=1, seed=5, ckpt_dir=ck)


def test_file_chunks_end_to_end(tmp_path):
    """On-disk shards through fit_stream == in-memory arrays through
    fit_stream (the source kind must not matter)."""
    x, y = _binary(n=160)
    paths = write_npz_chunks(str(tmp_path), x, y, 40)
    st_disk = fit_stream(CFG, FileChunks(paths), epochs=1, seed=2)
    st_mem = fit_stream(CFG, ArrayChunks(x, y, 40), epochs=1, seed=2)
    _leaves_equal(st_mem, st_disk, exact=True)


def test_fit_stream_does_not_consume_caller_state():
    """fit_stream donates state into the chunk programs but must copy a
    caller-provided state first — same non-destructive contract as fit."""
    x, y = _binary(n=160)
    src = ArrayChunks(x, y, 40)
    st0 = fit_stream(CFG, src, epochs=1, seed=0)
    st1 = fit_stream(CFG, src, epochs=1, seed=1, state=st0)
    st2 = fit_stream(CFG, src, epochs=1, seed=1, state=st0)  # st0 still alive
    assert int(st0.count) >= 0                               # not deleted
    _leaves_equal(st1, st2, exact=True)


def test_train_epoch_stream_cursor_contract():
    """train_epoch_stream returns (state, next_chunk, carry); max_chunks cuts
    the epoch short at the right cursor and a manual continuation finishes it
    identically to the one-shot epoch."""
    x, y = _binary(n=200)
    src = ArrayChunks(x, y, 40)
    table = CFG.table()
    key = jax.random.PRNGKey(13)
    full, nc, _ = train_epoch_stream(CFG, table, init_state(CFG, DIM), src,
                                     key=key)
    assert nc == src.n_chunks
    st, nc, carry = train_epoch_stream(CFG, table, init_state(CFG, DIM), src,
                                       key=key, max_chunks=2)
    assert nc == 2
    st, nc, _ = train_epoch_stream(CFG, table, st, src, key=key,
                                   start_chunk=nc, carry=carry)
    assert nc == src.n_chunks
    _leaves_equal(full, st, exact=True)
