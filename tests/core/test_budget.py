"""Budget maintenance: pair choice vs exhaustive oracle, compaction, methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import METHODS, default_table, maintenance_step, merge_math
from repro.kernels import ref


def _random_sv_set(key, n_active, slots, dim, *, same_sign=False):
    k1, k2 = jax.random.split(key)
    sv_x = jax.random.normal(k1, (slots, dim))
    alpha = 0.1 * jax.random.normal(k2, (slots,))
    if same_sign:
        alpha = jnp.abs(alpha) + 0.01
    alpha = alpha.at[n_active:].set(0.0)
    return sv_x, alpha


@pytest.mark.parametrize("method", METHODS)
def test_count_decrements_and_compacts(method):
    key = jax.random.PRNGKey(0)
    slots, count, dim, gamma = 16, 12, 5, 0.5
    sv_x, alpha = _random_sv_set(key, count, slots, dim, same_sign=True)
    table = default_table() if method.startswith("lookup") else None
    new_x, new_a, new_count, info = maintenance_step(
        sv_x, alpha, jnp.int32(count), gamma, method=method, table=table)
    assert int(new_count) == count - 1
    # compaction: slots >= new_count have zero alpha, active all non-zero
    assert np.all(np.asarray(new_a[int(new_count):]) == 0.0)
    assert np.all(np.asarray(jnp.abs(new_a[: int(new_count)])) > 0.0)
    assert bool(info.merged)


def test_min_alpha_partner_is_fixed():
    key = jax.random.PRNGKey(1)
    sv_x, alpha = _random_sv_set(key, 10, 12, 4, same_sign=True)
    alpha = alpha.at[7].set(1e-4)  # force the min slot
    _, _, _, info = maintenance_step(sv_x, alpha, jnp.int32(10), 1.0,
                                     method="gss-precise")
    assert int(info.i_min) == 7


def test_partner_choice_matches_exhaustive_oracle():
    """The chosen partner minimizes true WD among same-sign candidates."""
    key = jax.random.PRNGKey(2)
    count, slots, dim, gamma = 14, 16, 3, 0.7
    sv_x, alpha = _random_sv_set(key, count, slots, dim, same_sign=True)
    _, _, _, info = maintenance_step(sv_x, alpha, jnp.int32(count), gamma,
                                     method="gss-precise")
    i = int(info.i_min)
    kappa = np.asarray(ref.rbf_row(sv_x, sv_x[i], gamma))
    a = np.asarray(alpha)
    best_wd, best_j = np.inf, -1
    for j in range(count):
        if j == i:
            continue
        h = merge_math.gss_numpy(a[i] / (a[i] + a[j]), kappa[j])
        az = a[i] * kappa[j] ** ((1 - h) ** 2) + a[j] * kappa[j] ** (h**2)
        wd = a[i]**2 + a[j]**2 + 2 * a[i] * a[j] * kappa[j] - az**2
        if wd < best_wd:
            best_wd, best_j = wd, j
    assert int(info.j_star) == best_j
    assert np.isclose(float(info.wd_star), best_wd, rtol=1e-3, atol=1e-6)


def test_opposite_sign_fallback_removal():
    """All-different-sign candidates -> removal of the min-|alpha| SV."""
    key = jax.random.PRNGKey(3)
    sv_x, _ = _random_sv_set(key, 6, 8, 3)
    alpha = jnp.asarray([0.01, -0.5, -0.3, -0.7, -0.2, -0.9, 0.0, 0.0])
    new_x, new_a, new_count, info = maintenance_step(
        sv_x, alpha, jnp.int32(6), 1.0, method="gss")
    assert not bool(info.merged)
    assert int(new_count) == 5
    assert np.all(np.asarray(new_a[:5]) < 0)  # the lone positive SV was removed


@pytest.mark.parametrize("method", ["lookup-h", "lookup-wd"])
def test_lookup_agrees_with_gss_decisions(method):
    """Paper Table 3: lookup picks the same partner as GSS nearly always."""
    table = default_table()
    agree = 0
    trials = 40
    for t in range(trials):
        key = jax.random.PRNGKey(100 + t)
        sv_x, alpha = _random_sv_set(key, 20, 24, 4, same_sign=True)
        _, _, _, info_g = maintenance_step(sv_x, alpha, jnp.int32(20), 0.5,
                                           method="gss")
        _, _, _, info_l = maintenance_step(sv_x, alpha, jnp.int32(20), 0.5,
                                           method=method, table=table)
        agree += int(info_g.j_star) == int(info_l.j_star)
    assert agree / trials >= 0.85
