"""BDCA solver gates: dual-math properties, learning, streaming, donation.

The dual coordinate-ascent math (``core.bdca``) is pinned by properties that
hold by construction of the exact 1-D maximization:

  * the dual objective is monotone non-decreasing over ascent sweeps on a
    fixed working set;
  * the box ``0 <= |alpha_i| <= C`` is never violated;
  * the KKT residual (projected dual gradient) is driven down by sweeps.

Property tests run under real hypothesis in CI and under the deterministic
seeded fallback elsewhere (``helpers.hypothesis_compat``).  The
solver-agnostic invariants (cache == rebuild, integer-state consistency,
maintenance bitwise, serve round-trip) live in ``test_solver_invariants.py``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.hypothesis_compat import given, settings, st
from helpers.invariants import assert_state_parity

from repro.core import BSGDConfig, MulticlassSVMConfig, bdca, fit, fit_stream
from repro.core.bsgd import accuracy, init_state, train_chunk
from repro.data import ArrayChunks, make_blobs, make_two_moons

COMMON = dict(deadline=None, max_examples=25)
SLOTS, DIM = 24, 4


def _cfg(**kw):
    kw.setdefault("budget", 16)
    kw.setdefault("gamma", 2.0)
    kw.setdefault("batch_size", 4)
    kw.setdefault("use_kernel_cache", True)
    return BSGDConfig(solver="bdca", **kw)


def _working_set(seed, count, C):
    """A valid random working set: unit-diagonal exact Gram (fp32), signed
    coefficients inside the box, zeros past the watermark."""
    rng = np.random.default_rng(seed)
    sv = rng.normal(0.0, 1.0, (SLOTS, DIM)).astype(np.float32)
    gamma = 0.8
    d2 = ((sv[:, None] - sv[None, :]) ** 2).sum(-1)
    kmat = np.exp(-gamma * d2).astype(np.float32)
    np.fill_diagonal(kmat, 1.0)
    a = rng.uniform(0.0, C, SLOTS) * rng.choice([-1.0, 1.0], SLOTS)
    a[count:] = 0.0
    return (jnp.asarray(a.astype(np.float32)), jnp.asarray(kmat),
            jnp.asarray(count, jnp.int32))


# --------------------------------------------------------------------------
# dual-math properties
# --------------------------------------------------------------------------
@given(seed=st.integers(0, 2**30), count=st.integers(2, SLOTS),
       C=st.floats(0.2, 4.0))
@settings(**COMMON)
def test_dual_objective_monotone_over_rounds(seed, count, C):
    alpha, kmat, cnt = _working_set(seed, count, C)
    prev = float(bdca.dual_objective(alpha, kmat, cnt))
    for _ in range(4):
        alpha = bdca.ascent_rounds(alpha, kmat, cnt, C, 1)
        cur = float(bdca.dual_objective(alpha, kmat, cnt))
        assert cur >= prev - 1e-4 * max(1.0, abs(prev)), (cur, prev)
        prev = cur


@given(seed=st.integers(0, 2**30), count=st.integers(2, SLOTS),
       C=st.floats(0.2, 4.0), rounds=st.integers(1, 5))
@settings(**COMMON)
def test_box_constraints_never_violated(seed, count, C, rounds):
    alpha, kmat, cnt = _working_set(seed, count, C)
    out = np.asarray(bdca.ascent_rounds(alpha, kmat, cnt, C, rounds))
    assert np.all(np.abs(out) <= C * (1 + 1e-6)), np.abs(out).max()
    np.testing.assert_array_equal(out[count:], 0.0)   # watermark preserved


@given(seed=st.integers(0, 2**30), count=st.integers(2, SLOTS),
       C=st.floats(0.2, 4.0))
@settings(**COMMON)
def test_kkt_residual_decreases(seed, count, C):
    """Enough exact coordinate sweeps drive the projected gradient toward
    stationarity: after 8 sweeps the residual is no worse than at the start
    (plus fp noise), and strictly reduced whenever it started non-trivial."""
    alpha, kmat, cnt = _working_set(seed, count, C)
    r0 = float(bdca.kkt_residual(alpha, kmat, cnt, C))
    out = bdca.ascent_rounds(alpha, kmat, cnt, C, 8)
    r1 = float(bdca.kkt_residual(out, kmat, cnt, C))
    assert r1 <= r0 + 1e-4, (r0, r1)
    if r0 > 0.5:
        assert r1 < r0, (r0, r1)


def test_frozen_coordinates_stay_frozen():
    """A coefficient driven to 0 has lost its label sign: sweeps must leave
    it untouched (the documented freeze), and it never re-enters f."""
    alpha, kmat, cnt = _working_set(3, 10, 1.0)
    alpha = alpha.at[4].set(0.0)
    out = np.asarray(bdca.ascent_rounds(alpha, kmat, cnt, 1.0, 3))
    assert out[4] == 0.0


# --------------------------------------------------------------------------
# config validation
# --------------------------------------------------------------------------
def test_bdca_config_validation():
    with pytest.raises(ValueError, match="use_kernel_cache"):
        BSGDConfig(solver="bdca", use_kernel_cache=False)
    with pytest.raises(ValueError, match="step_engine"):
        BSGDConfig(solver="bdca", use_kernel_cache=True,
                   step_engine="pallas")
    with pytest.raises(ValueError, match="bdca_rounds"):
        BSGDConfig(solver="bdca", use_kernel_cache=True, bdca_rounds=0)
    with pytest.raises(ValueError, match="bdca_C"):
        BSGDConfig(solver="bdca", use_kernel_cache=True, bdca_C=0.0)
    with pytest.raises(ValueError, match="solver"):
        BSGDConfig(solver="smo")
    # maintenance_engine="pallas" composes with bdca
    BSGDConfig(solver="bdca", use_kernel_cache=True,
               maintenance_engine="pallas")


# --------------------------------------------------------------------------
# learning + more sweeps help
# --------------------------------------------------------------------------
def test_bdca_learns_two_moons():
    x, y = make_two_moons(jax.random.PRNGKey(0), 400, noise=0.15)
    st_d = fit(_cfg(budget=24), x, y, epochs=2, seed=0)
    assert int(st_d.count) <= 24
    assert float(accuracy(st_d, x, y, 2.0)) > 0.93


def test_more_rounds_do_not_hurt():
    """4-sweep training lands at least as tight a dual fit as 1-sweep on the
    same stream of batches (coarse sanity that the sweeps do real work)."""
    x, y = make_two_moons(jax.random.PRNGKey(2), 300, noise=0.1)
    acc = {}
    for rounds in (1, 4):
        st_d = fit(_cfg(budget=24, bdca_rounds=rounds), x, y, epochs=2)
        acc[rounds] = float(accuracy(st_d, x, y, 2.0))
    assert acc[4] >= acc[1] - 0.02, acc


# --------------------------------------------------------------------------
# streaming: bitwise kill-and-resume + bank publishing
# --------------------------------------------------------------------------
def test_bdca_stream_kill_and_resume_bitwise(tmp_path):
    cfg = _cfg(budget=12, gamma=0.5)
    x, y = make_blobs(jax.random.PRNGKey(1), 230, DIM)
    src = ArrayChunks(np.asarray(x), np.asarray(y), 37)    # ragged chunks
    ref = fit_stream(cfg, src, epochs=2, seed=5)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(cfg, src, epochs=2, seed=5, ckpt_dir=ck, ckpt_every=2,
               max_chunks=9)                               # hard kill
    resumed = fit_stream(cfg, src, epochs=2, seed=5, ckpt_dir=ck,
                         ckpt_every=2)
    assert_state_parity(ref, resumed, bitwise=True)


def test_bdca_stream_publishes_bank():
    from repro.core import ModelBank, predict_labels

    cfg = _cfg(budget=12, gamma=0.5)
    x, y = make_blobs(jax.random.PRNGKey(1), 160, DIM)
    src = ArrayChunks(np.asarray(x), np.asarray(y), 40)
    bank = ModelBank()
    st_d = fit_stream(cfg, src, epochs=1, seed=0, bank=bank, publish_every=2)
    assert bank.version >= 1
    _, model = bank.current()
    from repro.core.bsgd import predict
    np.testing.assert_array_equal(np.asarray(predict_labels(model, x)),
                                  np.asarray(predict(st_d, x, cfg.gamma)))


# --------------------------------------------------------------------------
# donation regression gates (the PR 3/4 double-donation class)
# --------------------------------------------------------------------------
def test_bdca_init_state_counter_buffers_distinct():
    st_d = init_state(_cfg(budget=8, gamma=0.5), DIM)
    ptrs = {a.unsafe_buffer_pointer()
            for a in (st_d.count, st_d.n_inserts, st_d.n_merges)}
    assert len(ptrs) == 3


def test_bdca_train_chunk_double_donation_safe():
    """The donated bdca chunk scan on a fresh ``init_state`` — twice, to
    cover the donate-the-result path too."""
    cfg = _cfg(budget=8, gamma=0.5)
    x, y = make_blobs(jax.random.PRNGKey(2), 32, DIM)
    xc = jnp.asarray(x).reshape(8, 4, DIM)
    yc = jnp.asarray(y).reshape(8, 4)
    st_d = init_state(cfg, DIM)
    st_d = train_chunk(cfg, cfg.table(), st_d, xc, yc)
    st_d = train_chunk(cfg, cfg.table(), st_d, xc, yc)
    assert int(st_d.count) > 0


def test_box_from_lambda_clamped_mapping():
    """The lambda -> C correspondence (ISSUE 9 bugfix): textbook 1/(n*lambda)
    wherever it is moderate, clamped at the cap in the small-lambda regime
    the paper's tables live in (1e-5 at n in the thousands would otherwise
    blow the dual box up to ~1e2)."""
    # textbook regime: mapping passes through untouched
    assert bdca.box_from_lambda(100, 1e-2) == pytest.approx(1.0)
    assert bdca.box_from_lambda(1000, 1e-3) == pytest.approx(1.0)
    assert bdca.box_from_lambda(500, 1e-2, cap=4.0) == pytest.approx(0.2)
    # paper-table regime: clamped to the cap, not ~1e2
    assert bdca.box_from_lambda(3000, 1e-5) == 4.0
    assert bdca.box_from_lambda(1000, 1e-5, cap=2.0) == 2.0
    # validation
    with pytest.raises(ValueError, match="n="):
        bdca.box_from_lambda(0, 1e-3)
    with pytest.raises(ValueError, match="lambda_"):
        bdca.box_from_lambda(100, 0.0)
