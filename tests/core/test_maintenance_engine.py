"""Strategy layer: multi-merge, removal, fallback paths, bf16 training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSGDConfig, STRATEGIES, accuracy, default_table, fit,
                        init_state, run_maintenance, train_step)
from repro.data import make_blobs, make_two_moons, train_test_split


def _random_sv_set(key, n_active, slots, dim, *, same_sign=False):
    k1, k2 = jax.random.split(key)
    sv_x = jax.random.normal(k1, (slots, dim))
    alpha = 0.1 * jax.random.normal(k2, (slots,))
    if same_sign:
        alpha = jnp.abs(alpha) + 0.01
    alpha = alpha.at[n_active:].set(0.0)
    return sv_x, alpha


# --------------------------------------------------------------------------
# multi-merge
# --------------------------------------------------------------------------
def test_multi_merge_p1_matches_single_merge_model():
    """P=1 multi-merge makes the same decision as the classic single merge
    (layouts differ by a slot permutation; the model function must agree)."""
    key = jax.random.PRNGKey(0)
    slots, count, dim, gamma = 16, 12, 5, 0.5
    sv_x, alpha = _random_sv_set(key, count, slots, dim, same_sign=True)
    table = default_table()
    xq = jax.random.normal(jax.random.PRNGKey(9), (32, dim))

    def model(sv, a, c):
        from repro.kernels import ref
        k = ref.rbf_matrix(xq, sv, gamma)
        return k @ jnp.where(jnp.arange(slots) < c, a, 0.0)

    s1, a1, _, c1, _ = run_maintenance(
        sv_x, alpha, None, jnp.int32(count), jnp.int32(0), gamma, table,
        budget=count - 1, strategy="merge", method="lookup-wd")
    s2, a2, _, c2, _ = run_maintenance(
        sv_x, alpha, None, jnp.int32(count), jnp.int32(0), gamma, table,
        budget=count - 1, strategy="multi-merge", merge_batch=1,
        method="lookup-wd", impl="ref")
    assert int(c1) == int(c2) == count - 1
    np.testing.assert_allclose(np.asarray(model(s1, a1, c1)),
                               np.asarray(model(s2, a2, c2)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("excess,p", [(1, 4), (3, 4), (7, 3)])
def test_multi_merge_count_and_compaction(excess, p):
    """count lands exactly on budget; survivors are compacted to the front."""
    key = jax.random.PRNGKey(1)
    slots, dim, gamma = 32, 4, 0.5
    budget = 20
    count = budget + excess
    sv_x, alpha = _random_sv_set(key, count, slots, dim, same_sign=True)
    _, a2, _, c2, n2 = run_maintenance(
        sv_x, alpha, None, jnp.int32(count), jnp.int32(0), gamma,
        default_table(), budget=budget, strategy="multi-merge", merge_batch=p,
        method="lookup-wd", impl="ref")
    assert int(c2) == budget
    # each fused event executes between 1 and P pairs (a pair is skipped when
    # its fixed slot was consumed as an earlier pair's partner)
    assert -(-excess // p) <= int(n2) <= excess
    a2 = np.asarray(a2)
    assert np.all(a2[budget:] == 0.0)
    assert np.all(np.abs(a2[:budget]) > 0.0)


def test_multi_merge_pairs_can_merge_each_other():
    """The two smallest-|alpha| SVs must be allowed to merge with each other
    (not silently fall back to removal because both are fixed partners)."""
    slots, dim, gamma = 16, 4, 0.5
    sv_x = jax.random.normal(jax.random.PRNGKey(5), (slots, dim))
    # slots 0/1: tiny same-sign pair, near-identical points (clear best merge);
    # everything else opposite-sign so they are each other's ONLY partners
    sv_x = sv_x.at[1].set(sv_x[0] + 1e-3)
    alpha = jnp.full((slots,), -0.5).at[0].set(0.01).at[1].set(0.02)
    count = 12
    alpha = alpha.at[count:].set(0.0)
    mass = float(jnp.sum(alpha[:count]))
    _, a2, _, c2, n2 = run_maintenance(
        sv_x, alpha, None, jnp.int32(count), jnp.int32(0), gamma,
        default_table(), budget=count - 1, strategy="multi-merge",
        merge_batch=2, method="lookup-wd", impl="ref")
    assert int(c2) == count - 1
    # merged, not removed: the ~0.03 of positive mass is preserved
    a2 = np.asarray(a2)[: count - 1]
    assert a2.max() > 0.025, a2.max()
    assert np.isclose(a2.sum(), mass, atol=5e-3)


@pytest.mark.parametrize("method", ["lookup-wd", "gss"])
def test_multi_merge_learns_two_moons(method):
    key = jax.random.PRNGKey(42)
    x, y = make_two_moons(key, 1200, noise=0.15)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=40, lambda_=1e-4, gamma=2.0, method=method,
                     maintenance="multi-merge", merge_batch=4,
                     use_kernel_cache=True)
    st = fit(cfg, xtr, ytr, epochs=2, seed=0)
    acc = float(accuracy(st, xte, yte, cfg.gamma))
    assert acc > 0.95, (method, acc)
    assert int(st.count) <= cfg.budget
    assert int(st.n_merges) > 0


def test_multi_merge_batched_insert_over_budget():
    """A minibatch can overshoot the budget by several SVs at once; one or two
    fused events must absorb all of them."""
    key = jax.random.PRNGKey(2)
    x, y = make_blobs(key, 200, 6, sep=1.0)
    cfg = BSGDConfig(budget=16, lambda_=1e-3, gamma=0.5, method="lookup-wd",
                     batch_size=8, maintenance="multi-merge", merge_batch=4,
                     use_kernel_cache=True)
    table = cfg.table()
    state = init_state(cfg, 6)
    for i in range(0, 160, 8):
        state = train_step(cfg, table, state, x[i:i + 8], y[i:i + 8])
        assert int(state.count) <= cfg.budget


# --------------------------------------------------------------------------
# removal strategy
# --------------------------------------------------------------------------
def test_removal_strategy_drops_smallest():
    key = jax.random.PRNGKey(3)
    slots, count, budget = 16, 12, 9
    sv_x, alpha = _random_sv_set(key, count, slots, 4, same_sign=True)
    _, a2, _, c2, n2 = run_maintenance(
        sv_x, alpha, None, jnp.int32(count), jnp.int32(0), 0.5, None,
        budget=budget, strategy="removal")
    assert int(c2) == budget and int(n2) == 1
    kept = np.sort(np.abs(np.asarray(a2[:budget])))
    want = np.sort(np.abs(np.asarray(alpha[:count])))[count - budget:]
    np.testing.assert_allclose(kept, want, rtol=1e-6)


def test_removal_strategy_trains():
    key = jax.random.PRNGKey(4)
    x, y = make_blobs(key, 800, 4, sep=2.0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=25, lambda_=1e-4, gamma=0.5, maintenance="removal")
    st = fit(cfg, xtr, ytr, epochs=2, seed=0)
    assert int(st.count) <= cfg.budget
    assert float(accuracy(st, xte, yte, cfg.gamma)) > 0.9


# --------------------------------------------------------------------------
# do_remove fallback through the full training step
# --------------------------------------------------------------------------
@pytest.mark.parametrize("use_cache", [False, True])
def test_train_step_removal_fallback(use_cache):
    """When the min-|alpha| SV has no same-sign partner, a real training step
    must fall back to removal (previously only unit-covered)."""
    from repro.core import kernel_cache

    cfg = BSGDConfig(budget=4, lambda_=1e-2, gamma=1.0, method="lookup-wd",
                     use_kernel_cache=use_cache)
    table = cfg.table()
    # budget full of strong negatives; a far-away positive margin violator
    # then inserts with |alpha| = 1/(lambda t) = 1, the strict minimum, and
    # has no same-sign merge partner -> do_remove must fire.
    sv = jnp.asarray([[0.0, 0.0], [10.0, 0.0], [0.0, 10.0], [10.0, 10.0],
                      [0.0, 0.0]])
    alpha = jnp.asarray([-5.0, -5.0, -5.0, -5.0, 0.0])
    state = init_state(cfg, 2)._replace(
        sv_x=sv, alpha=alpha, count=jnp.int32(4), step=jnp.int32(100),
        kmat=kernel_cache.exact_cache(sv, cfg.gamma) if use_cache else None)
    state = train_step(cfg, table, state, jnp.asarray([[30.0, 30.0]]),
                       jnp.asarray([1.0]))
    assert int(state.count) == cfg.budget
    assert int(state.n_merges) == 1
    # the fallback removed the lone positive outright; survivors all negative
    assert np.all(np.asarray(state.alpha[:int(state.count)]) < 0)
    if use_cache:
        _c = int(state.count)
        got = np.asarray(state.kmat)[:_c, :_c]
        want = np.asarray(jnp.asarray(
            kernel_cache.exact_cache(state.sv_x, cfg.gamma)))[:_c, :_c]
        np.testing.assert_allclose(got, want, atol=5e-5)


# --------------------------------------------------------------------------
# bf16 SV storage
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["merge", "multi-merge"])
def test_bfloat16_sv_training(strategy):
    """sv_dtype="bfloat16" trains end to end (with the fp32 kernel cache)."""
    key = jax.random.PRNGKey(5)
    x, y = make_blobs(key, 1000, 8, sep=2.5)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=30, lambda_=1e-4, gamma=0.3, method="lookup-wd",
                     sv_dtype="bfloat16", use_kernel_cache=True,
                     maintenance=strategy, merge_batch=4)
    st = fit(cfg, xtr, ytr, epochs=2, seed=0)
    assert st.sv_x.dtype == jnp.bfloat16
    assert st.kmat.dtype == jnp.float32
    assert int(st.count) <= cfg.budget
    acc = float(accuracy(st, xte, yte, cfg.gamma))
    assert acc > 0.9, acc


def test_config_validation():
    with pytest.raises(ValueError):
        BSGDConfig(maintenance="bogus")
    with pytest.raises(ValueError):
        BSGDConfig(budget=4, maintenance="multi-merge", merge_batch=8)
    assert set(STRATEGIES) == {"merge", "multi-merge", "removal",
                               "removal-project", "quantized"}
