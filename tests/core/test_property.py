"""Hypothesis property tests on the merge-problem invariants.

Runs under real hypothesis when installed (CI), and under the deterministic
seeded-draw fallback otherwise (``helpers.hypothesis_compat``) — never
skipped either way.
"""
import jax.numpy as jnp
import numpy as np

from helpers.hypothesis_compat import given, settings, st

from repro.core import default_table, merge_math as mm

UNIT = st.floats(0.001, 0.999)
POS = st.floats(0.01, 5.0)
H = st.floats(0.0, 1.0)

COMMON = dict(deadline=None, max_examples=60)


@given(m=UNIT, k=UNIT)
@settings(**COMMON)
def test_wd_nonnegative_at_optimum(m, k):
    h = float(mm.gss_numpy(m, k))
    wd = float(mm.wd_norm_at(h, m, k))
    assert wd >= -1e-5


@given(m=UNIT, k=UNIT)
@settings(**COMMON)
def test_optimum_beats_endpoints(m, k):
    """Merging at h* is never worse than removing either point (h=0/1)."""
    h = float(mm.gss_numpy(m, k))
    wd_star = float(mm.wd_norm_at(h, m, k))
    assert wd_star <= float(mm.wd_norm_at(0.0, m, k)) + 1e-5
    assert wd_star <= float(mm.wd_norm_at(1.0, m, k)) + 1e-5


@given(m=UNIT, k=UNIT, h=H)
@settings(**COMMON)
def test_optimum_beats_random_h(m, k, h):
    h_star = float(mm.gss_numpy(m, k))
    assert float(mm.wd_norm_at(h_star, m, k)) <= float(mm.wd_norm_at(h, m, k)) + 1e-5


@given(m=UNIT, k=UNIT)
@settings(**COMMON)
def test_wd_symmetry_in_m(m, k):
    h1 = float(mm.gss_numpy(m, k))
    h2 = float(mm.gss_numpy(1 - m, k))
    assert abs(float(mm.wd_norm_at(h1, m, k))
               - float(mm.wd_norm_at(h2, 1 - m, k))) < 1e-5


@given(a=POS, b=POS, k=UNIT, h=H)
@settings(**COMMON)
def test_alpha_z_scale_equivariance(a, b, k, h):
    """alpha_z(c*a, c*b) = c * alpha_z(a, b) — justifies the (m, kappa)
    normalization that makes the 2-D lookup possible."""
    c = 3.7
    z1 = float(mm.merge_alpha_z(a, b, k, h))
    z2 = float(mm.merge_alpha_z(c * a, c * b, k, h))
    assert np.isclose(z2, c * z1, rtol=1e-4)


@given(a=POS, b=POS, k=UNIT)
@settings(**COMMON)
def test_wd_scale_quadratic(a, b, k):
    """WD scales as (a+b)^2 * WD_norm(m, kappa) — the Lookup-WD identity."""
    m = a / (a + b)
    h = float(mm.gss_numpy(m, k))
    az = mm.merge_alpha_z(jnp.float32(a), jnp.float32(b), jnp.float32(k),
                          jnp.float32(h))
    wd = float(mm.weight_degradation(jnp.float32(a), jnp.float32(b),
                                     jnp.float32(k), az))
    wd_norm = float(mm.wd_norm_at(h, m, k))
    assert np.isclose(wd, (a + b) ** 2 * wd_norm, rtol=5e-3, atol=1e-5)


@given(m=st.floats(0.05, 0.95), k=st.floats(float(np.exp(-2)) + 0.02, 0.995))
@settings(**COMMON)
def test_lookup_wd_close_to_precise(m, k):
    tbl = default_table()
    wd_tbl = float(tbl.lookup_wd_norm(jnp.float32(m), jnp.float32(k)))
    h = float(mm.gss_numpy(m, k))
    wd_ref = float(mm.wd_norm_at(h, m, k))
    assert abs(wd_tbl - wd_ref) < 5e-5
