"""Hypothesis property tests for the serving engine.

Decision-identity of the fused serve cell against per-class scoring loops
across random states / budgets / class counts, queue bitwise parity across
arbitrary arrival patterns, and bf16-bank decision stability on
margin-separated rows.  Ties are excluded the principled way: label equality
is asserted only where the reference top-2 score gap exceeds float noise
(the fused fold may differ from the loop by ULPs, and a ULP can legally
flip an exact tie).
"""
import jax
import jax.numpy as jnp
import numpy as np

from helpers.hypothesis_compat import given, settings, st

from repro.core import (SVMState, decision_function, export_model,
                        predict_labels, serve_requests, serve_scores)

COMMON = dict(deadline=None, max_examples=25)
GAMMA = 0.7


def random_stacked_state(seed: int, c: int, slots: int, dim: int) -> SVMState:
    """A synthetic trained-looking stacked state: random bank/coefficients,
    per-class active counts anywhere in [0, slots]."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    z = jnp.zeros((c,), jnp.int32)
    return SVMState(
        sv_x=jax.random.normal(ks[0], (c, slots, dim)),
        alpha=jax.random.normal(ks[1], (c, slots)) * 0.5,
        count=jax.random.randint(ks[2], (c,), 0, slots + 1),
        step=jnp.ones((c,), jnp.int32), n_inserts=z, n_merges=z)


@given(seed=st.integers(0, 2**30), c=st.integers(2, 6),
       slots=st.integers(2, 24), dim=st.integers(1, 8))
@settings(**COMMON)
def test_fused_cell_decision_identical_to_class_loop(seed, c, slots, dim):
    state = random_stacked_state(seed, c, slots, dim)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (17, dim))
    model = export_model(state, GAMMA)

    # reference: C sequential binary decision functions (the class loop)
    loop_scores = np.stack([
        np.asarray(decision_function(
            SVMState(sv_x=state.sv_x[q], alpha=state.alpha[q],
                     count=state.count[q], step=state.step[q],
                     n_inserts=state.n_inserts[q], n_merges=state.n_merges[q]),
            x, GAMMA)) for q in range(c)])
    fused_scores = np.asarray(serve_scores(model, x))
    np.testing.assert_allclose(fused_scores, loop_scores, rtol=1e-5, atol=1e-5)

    top2 = np.sort(loop_scores, axis=0)[-2:]
    clear = (top2[1] - top2[0]) > 1e-4            # exclude near-ties
    got = np.asarray(predict_labels(model, x))
    np.testing.assert_array_equal(got[clear], loop_scores.argmax(0)[clear])


@given(seed=st.integers(0, 2**30),
       sizes=st.lists(st.integers(0, 40), min_size=1, max_size=12),
       max_batch=st.integers(1, 48), min_bucket=st.integers(1, 8))
@settings(**COMMON)
def test_queue_bitwise_parity_any_arrival_pattern(seed, sizes, max_batch,
                                                  min_bucket):
    state = random_stacked_state(seed, 3, 8, 4)
    model = export_model(state, GAMMA)
    n = sum(sizes)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 2), (n + 1, 4)))
    reqs, off = [], 0
    for s in sizes:
        reqs.append(x[off:off + s])
        off += s
    labels = serve_requests(model, reqs, max_batch=max_batch,
                            min_bucket=min_bucket)
    assert [l.shape[0] for l in labels] == sizes
    if n:
        direct = np.asarray(predict_labels(model, x[:n]))
        np.testing.assert_array_equal(np.concatenate(labels), direct)


@given(seed=st.integers(0, 2**30))
@settings(**COMMON)
def test_bf16_bank_matches_fp32_decisions_off_the_margin(seed):
    state = random_stacked_state(seed, 4, 16, 6)
    x = jax.random.normal(jax.random.PRNGKey(seed + 3), (64, 6))
    fp32 = export_model(state, GAMMA)
    bf16 = export_model(state, GAMMA, bank_dtype="bfloat16")
    scores = np.asarray(serve_scores(fp32, x))
    top2 = np.sort(scores, axis=0)[-2:]
    clear = (top2[1] - top2[0]) > 0.05            # margin-separated rows
    l32 = np.asarray(predict_labels(fp32, x))
    l16 = np.asarray(predict_labels(bf16, x))
    np.testing.assert_array_equal(l16[clear], l32[clear])
