"""Merge-problem math: GSS optimality, closed forms, paper Lemma 1."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge_math as mm

GRID_POINTS = [(m, k) for m in (0.01, 0.2, 0.45, 0.5, 0.55, 0.8, 0.99)
               for k in (0.01, 0.1, float(np.exp(-2)), 0.2, 0.5, 0.9, 0.999)]


def s_np(h, m, k):
    k = max(k, 1e-30)
    return m * k ** ((1.0 - h) ** 2) + (1.0 - m) * k ** (h**2)


@pytest.mark.parametrize("m,k", GRID_POINTS)
def test_gss_reaches_brute_force_max(m, k):
    """Objective VALUE at the GSS solution matches the dense-grid max.

    (argmax may differ on the bimodal set Z where two maxima tie — Lemma 1.)
    """
    h_bf = mm.brute_force_h(m, k, n_grid=100_001)
    best = s_np(h_bf, m, k)
    h64 = float(mm.gss_numpy(m, k))
    assert s_np(h64, m, k) >= best - 1e-9
    h32 = float(mm.golden_section_search(m, k, eps=1e-10))
    assert s_np(h32, m, k) >= best - 1e-5


def test_gss_iteration_counts_match_paper():
    assert mm.gss_num_iters(1e-2) == 10     # paper's runtime precision
    assert mm.gss_num_iters(1e-10) == 48    # paper's table-build precision


def test_closed_forms_consistent():
    """alpha_z / WD closed forms vs direct RKHS computation on explicit
    2-point geometry: phi(x).phi(x') = kappa."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        a_a, a_b = rng.uniform(0.1, 2.0, 2)
        kap = rng.uniform(0.01, 0.999)
        h = rng.uniform(0, 1)
        a_z = float(mm.merge_alpha_z(a_a, a_b, kap, h))
        # Gram matrix of [phi(x_a), phi(x_b), phi(z)]
        kaz = kap ** ((1 - h) ** 2)
        kbz = kap ** (h**2)
        # || a_a phi_a + a_b phi_b - a_z phi_z ||^2 expanded via the Gram matrix
        wd_direct = (a_a**2 + a_b**2 + a_z**2 + 2 * a_a * a_b * kap
                     - 2 * a_a * a_z * kaz - 2 * a_b * a_z * kbz)
        wd_formula = float(mm.weight_degradation(a_a, a_b, kap, a_z))
        assert np.isclose(wd_direct, wd_formula, rtol=1e-5, atol=1e-6)


def test_optimal_alpha_z_minimizes_wd():
    """alpha_z = a_a k(x_a,z) + a_b k(x_b,z) is the exact minimizer over
    alpha for fixed z (projection), so perturbing it can only increase WD."""
    for (a_a, a_b, kap, h) in [(1.0, 0.5, 0.7, 0.4), (0.2, 0.9, 0.3, 0.8)]:
        a_z = float(mm.merge_alpha_z(a_a, a_b, kap, h))
        kaz = kap ** ((1 - h) ** 2)
        kbz = kap ** (h**2)
        def wd_at(az):
            return (a_a**2 + a_b**2 + az**2 + 2 * a_a * a_b * kap
                    - 2 * a_a * az * kaz - 2 * a_b * az * kbz)
        assert wd_at(a_z) <= wd_at(a_z + 0.01) + 1e-9
        assert wd_at(a_z) <= wd_at(a_z - 0.01) + 1e-9


def test_lemma1_mode_structure():
    """s''_{1/2,kappa}(1/2) > 0  <=>  kappa < e^{-2} (two modes)."""
    for k in (0.05, 0.10, 0.13):
        assert float(mm.s_second_derivative_at_half(k)) > 0, k
    for k in (0.14, 0.3, 0.9):
        assert float(mm.s_second_derivative_at_half(k)) < 0, k


def test_lemma1_h_discontinuity_wd_continuity():
    """Crossing m = 1/2 at kappa < e^-2: h jumps, WD stays continuous."""
    k = 0.05
    h_lo = float(mm.gss_numpy(0.499, k))
    h_hi = float(mm.gss_numpy(0.501, k))
    assert abs(h_hi - h_lo) > 0.5          # the jump across Z
    wd_lo = float(mm.wd_norm_at(h_lo, 0.499, k))
    wd_hi = float(mm.wd_norm_at(h_hi, 0.501, k))
    assert abs(wd_hi - wd_lo) < 1e-3       # WD continuous (Lemma 1)


def test_h_symmetry():
    """h(m, kappa) = 1 - h(1-m, kappa) by the merge symmetry."""
    for m in (0.1, 0.3, 0.45):
        for k in (0.2, 0.5, 0.9):
            h1 = float(mm.gss_numpy(m, k))
            h2 = float(mm.gss_numpy(1.0 - m, k))
            assert abs((1.0 - h2) - h1) < 1e-4
