"""Budgeted KV cache (beyond-paper transfer): mechanics + merge-beats-evict."""
import jax
import jax.numpy as jnp

from repro.core.budgeted_kv import init_kv_state, kv_append, kv_attend
from repro.core.lookup import default_table


def _drift_stream(key, t, batch, heads, dim):
    k1, k2 = jax.random.split(key)
    center = jnp.sin(jnp.arange(dim) * 0.1 + t * 0.02)
    k_new = center + 0.3 * jax.random.normal(k1, (batch, 1, heads, dim))
    v_new = jax.random.normal(k2, (batch, 1, heads, dim))
    return k_new, v_new


def test_budget_is_enforced_and_exact_below_budget():
    table = default_table()
    B, H, D, W = 2, 2, 16, 8
    st = init_kv_state(B, W, H, D, jnp.float32)
    key = jax.random.PRNGKey(0)
    kept_k, kept_v = [], []
    for t in range(6):  # below budget: appends are exact
        key, sub = jax.random.split(key)
        k_new, v_new = _drift_stream(sub, t, B, H, D)
        st = kv_append(st, k_new, v_new, 0.05, table)
        kept_k.append(k_new)
        kept_v.append(v_new)
    assert int(st.count) == 6
    q = jax.random.normal(key, (B, 1, H, D))
    out_b = kv_attend(st, q, 0.25)
    fk = jnp.concatenate(kept_k, 1)
    fv = jnp.concatenate(kept_v, 1)
    s = jax.nn.softmax(jnp.einsum("bqhd,bwhd->bhqw", q, fk) * 0.25, -1)
    out_f = jnp.einsum("bhqw,bwhd->bqhd", s, fv)
    assert float(jnp.max(jnp.abs(out_b - out_f))) < 1e-4
    for t in range(6, 20):  # past budget: count pinned at W
        key, sub = jax.random.split(key)
        st = kv_append(st, *_drift_stream(sub, t, B, H, D), 0.05, table)
        assert int(st.count) <= W


def test_merge_no_worse_than_evict():
    """The paper's merge-beats-removal claim, transferred to KV caches."""
    table = default_table()
    B, H, D, W, T = 2, 2, 32, 32, 96
    gamma = 1.0 / (2.0 * D**0.5)
    scale = 1.0 / D**0.5
    states = {p: init_kv_state(B, W, H, D, jnp.float32)
              for p in ("merge", "evict")}
    key = jax.random.PRNGKey(1)
    fk, fv = [], []
    for t in range(T):
        key, sub = jax.random.split(key)
        k_new, v_new = _drift_stream(sub, t, B, H, D)
        for p in states:
            states[p] = kv_append(states[p], k_new, v_new, gamma, table,
                                  policy=p)
        fk.append(k_new)
        fv.append(v_new)
    q = jax.random.normal(key, (B, 1, H, D))
    K = jnp.concatenate(fk, 1)
    V = jnp.concatenate(fv, 1)
    s = jax.nn.softmax(jnp.einsum("bqhd,bwhd->bhqw", q, K) * scale, -1)
    out_f = jnp.einsum("bhqw,bwhd->bqhd", s, V)
    errs = {p: float(jnp.linalg.norm(kv_attend(states[p], q, scale) - out_f))
            for p in states}
    assert errs["merge"] <= errs["evict"] * 1.05, errs
