"""Checkpoint failure modes surface as clear ``ValueError``s, and the
donated-state streaming path stays safe.

The streaming trainers and the serving loader both resume from on-disk
state written by someone else (possibly a dead process, possibly a human
moving directories around); every way that state can be wrong must produce
an actionable ``ValueError`` naming the file and the mismatch — never a
raw ``FileNotFoundError`` / ``JSONDecodeError`` / ``BadZipFile`` traceback
from three layers down.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import BSGDConfig, fit_stream, init_state, train_chunk
from repro.data import ArrayChunks, make_blobs


def _saved(tmp_path, step=3):
    d = str(tmp_path / "ck")
    ckpt.save(d, step, {"w": jnp.arange(6.0).reshape(2, 3)},
              metadata={"kind": "test", "cursor": 7})
    return d


def test_load_metadata_roundtrip(tmp_path):
    d = _saved(tmp_path)
    assert ckpt.load_metadata(d, 3) == {"kind": "test", "cursor": 7}


def test_load_metadata_missing_manifest(tmp_path):
    d = _saved(tmp_path)
    os.remove(os.path.join(d, "step_00000003", "manifest.json"))
    with pytest.raises(ValueError, match="no manifest"):
        ckpt.load_metadata(d, 3)


def test_load_metadata_missing_step(tmp_path):
    d = _saved(tmp_path)
    with pytest.raises(ValueError, match="no manifest"):
        ckpt.load_metadata(d, 99)


def test_load_metadata_corrupt_manifest(tmp_path):
    d = _saved(tmp_path)
    path = os.path.join(d, "step_00000003", "manifest.json")
    with open(path, "w") as f:
        f.write('{"metadata": {"trunc')       # mid-write truncation
    with pytest.raises(ValueError, match="corrupt"):
        ckpt.load_metadata(d, 3)


def test_load_truncated_arrays(tmp_path):
    d = _saved(tmp_path)
    path = os.path.join(d, "step_00000003", "arrays.npz")
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])       # torn zip
    with pytest.raises(ValueError, match="truncated or corrupt"):
        ckpt.load(d, 3, {"w": jnp.zeros((2, 3))})


def test_load_missing_arrays(tmp_path):
    d = _saved(tmp_path)
    os.remove(os.path.join(d, "step_00000003", "arrays.npz"))
    with pytest.raises(ValueError, match="no arrays.npz"):
        ckpt.load(d, 3, {"w": jnp.zeros((2, 3))})


def test_load_missing_leaves_is_valueerror(tmp_path):
    d = _saved(tmp_path)
    with pytest.raises(ValueError, match="missing leaves"):
        ckpt.load(d, 3, {"w": jnp.zeros((2, 3)), "extra": jnp.zeros(())})


def _stream_fixture(tmp_path, *, seed=0, chunk_rows=64):
    cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=0.5, batch_size=4)
    x, y = make_blobs(jax.random.PRNGKey(0), 256, 5, sep=1.5)
    source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=chunk_rows)
    d = str(tmp_path / "stream_ck")
    fit_stream(cfg, source, epochs=1, seed=seed, ckpt_dir=d, ckpt_every=2,
               max_chunks=2)
    return cfg, d


def test_stream_resume_cursor_seed_mismatch(tmp_path):
    """The cursor is only meaningful against the same shuffle: resuming with
    another seed must refuse, not silently re-train / skip rows."""
    cfg, d = _stream_fixture(tmp_path)
    x, y = make_blobs(jax.random.PRNGKey(0), 256, 5, sep=1.5)
    source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=64)
    with pytest.raises(ValueError, match="seed"):
        fit_stream(cfg, source, epochs=1, seed=1, ckpt_dir=d)


def test_stream_resume_rechunked_source_mismatch(tmp_path):
    cfg, d = _stream_fixture(tmp_path)
    x, y = make_blobs(jax.random.PRNGKey(0), 256, 5, sep=1.5)
    rechunked = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=32)
    with pytest.raises(ValueError, match="re-chunked"):
        fit_stream(cfg, rechunked, epochs=1, seed=0, ckpt_dir=d)


def test_stream_resume_foreign_checkpoint_kind(tmp_path):
    """A non-streaming checkpoint in the directory must refuse cleanly."""
    cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=0.5, batch_size=4)
    x, y = make_blobs(jax.random.PRNGKey(0), 128, 5, sep=1.5)
    source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=64)
    d = str(tmp_path / "foreign")
    ckpt.save(d, 5, {"params": jnp.zeros((2,))})   # no stream metadata
    with pytest.raises(ValueError, match="not a .*streaming checkpoint"):
        fit_stream(cfg, source, epochs=1, seed=0, ckpt_dir=d)


def test_init_state_counter_buffers_are_distinct():
    """Regression (PR 3): the streaming path donates the whole state and XLA
    rejects one buffer donated twice — the zero-initialized counters must
    not share storage."""
    cfg = BSGDConfig(budget=8, lambda_=1e-3, gamma=0.5, batch_size=4)
    st = init_state(cfg, 5)
    ptrs = {a.unsafe_buffer_pointer()
            for a in (st.count, st.n_inserts, st.n_merges)}
    assert len(ptrs) == 3


def test_train_chunk_double_donation_safe():
    """The donated chunk program runs on a fresh ``init_state`` (this is the
    exact call that crashed when counters aliased) — twice, to cover the
    donate-the-result path too."""
    cfg = BSGDConfig(budget=8, lambda_=1e-3, gamma=0.5, batch_size=4)
    x, y = make_blobs(jax.random.PRNGKey(2), 32, 5, sep=1.5)
    xc = jnp.asarray(x).reshape(8, 4, 5)
    yc = jnp.asarray(y).reshape(8, 4)
    st = init_state(cfg, 5)
    st = train_chunk(cfg, cfg.table(), st, xc, yc)
    st = train_chunk(cfg, cfg.table(), st, xc, yc)
    assert int(st.count) > 0


# ---- integrity: per-leaf crc32, torn-write walk-back (DESIGN.md §16) ----


def test_crc_detects_silently_modified_leaf(tmp_path):
    """A bit flip inside arrays.npz that keeps shape/dtype intact fails the
    per-leaf checksum on load — silent corruption never restores."""
    d = _saved(tmp_path)
    step_dir = os.path.join(d, "step_00000003")
    with np.load(os.path.join(step_dir, "arrays.npz")) as z:
        arrs = {k: z[k].copy() for k in z.files}
    (key,) = arrs.keys()
    arrs[key].flat[0] += 1.0                      # same shape, same dtype
    np.savez(os.path.join(step_dir, "arrays.npz"), **arrs)
    with pytest.raises(ValueError, match="checksum"):
        ckpt.load(d, 3, {"w": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="checksum"):
        ckpt.verify_step(d, 3)


def test_verify_step_passes_clean_and_names_torn_files(tmp_path):
    d = _saved(tmp_path)
    ckpt.verify_step(d, 3)                        # clean: no raise
    step_dir = os.path.join(d, "step_00000003")
    os.remove(os.path.join(step_dir, "arrays.npz"))
    with pytest.raises(ValueError, match="torn write"):
        ckpt.verify_step(d, 3)
    os.remove(os.path.join(step_dir, "manifest.json"))
    with pytest.raises(ValueError, match="torn write"):
        ckpt.verify_step(d, 3)


def test_restore_latest_walks_back_past_torn_step(tmp_path):
    """The newest step is torn (crash mid-save): restore_latest silently
    falls back to the newest step that verifies."""
    d = str(tmp_path / "ck")
    for step in (1, 2, 3):
        ckpt.save(d, step, {"w": jnp.full((2, 3), float(step))})
    os.remove(os.path.join(d, "step_00000003", "arrays.npz"))     # torn
    assert ckpt.latest_step(d) == 3
    assert ckpt.latest_verifiable_step(d) == 2
    step, tree = ckpt.restore_latest(d, {"w": jnp.zeros((2, 3))})
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.full((2, 3), 2.0))


def test_restore_latest_refuses_when_nothing_verifies(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w": jnp.zeros((2, 3))})
    os.remove(os.path.join(d, "step_00000001", "manifest.json"))
    with pytest.raises(ValueError, match="none verify"):
        ckpt.restore_latest(d, {"w": jnp.zeros((2, 3))})
    assert ckpt.restore_latest(str(tmp_path / "empty"),
                               {"w": jnp.zeros((2, 3))}) == (None, None)


def test_stream_resume_skips_torn_newest_checkpoint(tmp_path):
    """fit_stream resume walks back past a torn newest step and still
    finishes bitwise identical to the uninterrupted run (the since-then
    chunks replay deterministically)."""
    cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=0.5, batch_size=4)
    x, y = make_blobs(jax.random.PRNGKey(0), 256, 5, sep=1.5)
    source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=64)
    ref = fit_stream(cfg, source, epochs=1, seed=0)
    d = str(tmp_path / "ck")
    fit_stream(cfg, source, epochs=1, seed=0, ckpt_dir=d, ckpt_every=1,
               max_chunks=3)                      # steps 1..3, hard kill
    newest = os.path.join(d, f"step_{ckpt.latest_step(d):08d}")
    with open(os.path.join(newest, "arrays.npz"), "r+b") as f:
        f.truncate(17)                            # torn mid-write
    resumed = fit_stream(cfg, source, epochs=1, seed=0, ckpt_dir=d,
                         ckpt_every=1)
    for name, a, b in zip(ref._fields, ref, resumed):
        if a is not None:
            assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_save_is_atomic_under_simulated_crash(tmp_path, monkeypatch):
    """Kill the writer at every fsync point: the step directory either does
    not exist (crash before os.replace) or verifies completely — no torn
    state is ever left under the final name."""
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}

    class _Crash(RuntimeError):
        pass

    from repro.checkpoint import checkpointer as cp

    real_fsync = os.fsync
    for crash_at in (1, 2, 3):
        calls = {"n": 0}

        def fsync(fd, _crash_at=crash_at, _calls=calls):
            _calls["n"] += 1
            if _calls["n"] == _crash_at:
                raise _Crash(f"crash at fsync #{_crash_at}")
            return real_fsync(fd)

        monkeypatch.setattr(cp.os, "fsync", fsync)
        with pytest.raises(_Crash):
            cp.save(d, 7, tree)
        monkeypatch.setattr(cp.os, "fsync", real_fsync)
        assert ckpt.all_steps(d) == []            # nothing under final name
        assert not os.path.exists(os.path.join(d, "step_00000007"))
    cp.save(d, 7, tree)                           # and the real save works
    ckpt.verify_step(d, 7)
