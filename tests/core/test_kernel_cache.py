"""Kernel cache: incremental invariants and trajectory equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.invariants import check_cache_invariants, exact_gram

from repro.core import BSGDConfig, fit, kernel_cache
from repro.data import make_blobs, make_two_moons, train_test_split
from repro.kernels import ref

# shared with the cross-solver harness (tests/helpers/invariants.py)
_exact = exact_gram
_check_cache = check_cache_invariants


def test_insert_rows_matches_direct():
    key = jax.random.PRNGKey(0)
    gamma, slots, count, batch, dim = 0.7, 12, 6, 3, 5
    sv = jax.random.normal(key, (slots, dim))
    xb = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))
    kmat = kernel_cache.exact_cache(sv, gamma)
    # insert 2 of the 3 batch points (middle one dropped, like a non-violator)
    idx = jnp.asarray([count, slots, count + 1])
    sv2 = sv.at[idx].set(xb, mode="drop")
    k_bs = ref.rbf_matrix(xb, sv, gamma)
    k_bb = ref.rbf_matrix(xb, xb, gamma)
    kmat2 = kernel_cache.insert_rows(kmat, idx, k_bs, k_bb)
    want = _exact(sv2, count + 2, gamma)
    np.testing.assert_allclose(np.asarray(kmat2)[:count + 2, :count + 2], want,
                               atol=1e-5)


def test_merge_z_row_closed_form():
    """k(z, .) from cached rows only == direct rbf against z."""
    key = jax.random.PRNGKey(2)
    gamma, slots, dim = 0.5, 10, 4
    sv = jax.random.normal(key, (slots, dim))
    kmat = kernel_cache.exact_cache(sv, gamma)
    for h in (0.0, 0.31, 0.5, 1.0):
        z = h * sv[2] + (1 - h) * sv[7]
        got = kernel_cache.merge_z_row(kmat, jnp.int32(2), jnp.int32(7),
                                       jnp.float32(h))
        want = ref.rbf_row(sv, z, gamma)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("strategy,merge_batch", [("merge", 1),
                                                  ("multi-merge", 4),
                                                  ("removal", 1)])
def test_cache_consistent_through_training(strategy, merge_batch):
    """Invariant I1 after real training: cache == recomputed kernel matrix."""
    key = jax.random.PRNGKey(3)
    x, y = make_blobs(key, 400, 6, sep=2.0)
    cfg = BSGDConfig(budget=20, lambda_=1e-4, gamma=0.5, method="lookup-wd",
                     batch_size=2, use_kernel_cache=True, maintenance=strategy,
                     merge_batch=merge_batch)
    st = fit(cfg, x, y, epochs=1, seed=0)
    assert int(st.count) <= cfg.budget
    assert int(st.n_merges) > 0
    _check_cache(st, cfg.gamma)


def test_cached_trajectory_matches_recompute():
    """Acceptance: cached-kappa single-merge training follows the recompute
    path's trajectory (same inserts, same merge decisions)."""
    cases = [
        (make_blobs(jax.random.PRNGKey(0), 600, 6, sep=2.0),
         dict(budget=25, lambda_=1e-4, gamma=0.5, method="lookup-wd")),
        (make_two_moons(jax.random.PRNGKey(42), 1000, noise=0.15),
         dict(budget=40, lambda_=1e-4, gamma=2.0, method="lookup-wd")),
    ]
    for (x, y), base in cases:
        (xtr, ytr), _ = train_test_split(x, y)
        st0 = fit(BSGDConfig(**base), xtr, ytr, epochs=1, seed=0)
        st1 = fit(BSGDConfig(**base, use_kernel_cache=True), xtr, ytr,
                  epochs=1, seed=0)
        assert int(st0.count) == int(st1.count)
        assert int(st0.n_merges) == int(st1.n_merges)
        np.testing.assert_allclose(np.asarray(st0.alpha), np.asarray(st1.alpha),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(st0.sv_x), np.asarray(st1.sv_x),
                                   rtol=1e-4, atol=1e-5)


def test_cache_survives_removal_fallback():
    """do_remove (no same-sign partner) keeps the cache consistent."""
    from repro.core import SVMState, run_maintenance, default_table

    gamma, slots, count = 1.0, 8, 6
    sv = jax.random.normal(jax.random.PRNGKey(5), (slots, 3))
    # lone tiny positive alpha among negatives: fallback must fire first
    alpha = jnp.asarray([0.01, -0.5, -0.3, -0.7, -0.2, -0.9, 0.0, 0.0])
    kmat = kernel_cache.exact_cache(sv, gamma)
    sv2, a2, kmat2, c2, n2 = run_maintenance(
        sv, alpha, kmat, jnp.int32(count), jnp.int32(0), gamma,
        default_table(), budget=count - 2, strategy="merge",
        method="lookup-wd")
    assert int(c2) == count - 2 and int(n2) == 2
    assert np.all(np.asarray(a2[:int(c2)]) < 0)   # the positive SV is gone
    state = SVMState(sv_x=sv2, alpha=a2, count=c2, step=jnp.int32(1),
                     n_inserts=jnp.int32(0), n_merges=n2, kmat=kmat2)
    _check_cache(state, gamma)


def test_apply_merge_reference_matches_exact():
    """apply_merge/apply_removal are the reference forms of the fused update
    in core.budget; they must track a from-scratch rebuild exactly."""
    gamma, slots = 0.8, 10
    sv = jax.random.normal(jax.random.PRNGKey(7), (slots, 3))
    kmat = kernel_cache.exact_cache(sv, gamma)
    i, j, last, h = 2, 7, 9, 0.4
    got = kernel_cache.apply_merge(kmat, jnp.int32(i), jnp.int32(j),
                                   jnp.int32(last), jnp.float32(h))
    z = h * sv[i] + (1 - h) * sv[j]
    sv2 = sv.at[i].set(z).at[j].set(sv[last])
    want = kernel_cache.exact_cache(sv2, gamma)
    np.testing.assert_allclose(np.asarray(got)[:last, :last],
                               np.asarray(want)[:last, :last],
                               rtol=1e-5, atol=1e-6)

    got_r = kernel_cache.apply_removal(kmat, jnp.int32(3), jnp.int32(last))
    sv3 = sv.at[3].set(sv[last])
    want_r = kernel_cache.exact_cache(sv3, gamma)
    np.testing.assert_allclose(np.asarray(got_r)[:last, :last],
                               np.asarray(want_r)[:last, :last],
                               rtol=1e-6, atol=1e-7)


def test_permute_matches_double_gather():
    kmat = jax.random.uniform(jax.random.PRNGKey(8), (6, 6))
    perm = jnp.asarray([3, 1, 5, 0, 2, 4])
    got = np.asarray(kernel_cache.permute(kmat, perm))
    want = np.asarray(kmat)[np.asarray(perm)][:, np.asarray(perm)]
    np.testing.assert_array_equal(got, want)
