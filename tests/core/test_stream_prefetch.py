"""Prefetched streaming gates (ISSUE 7): ``prefetch > 0`` changes wall-clock
only — the realized batch sequence, checkpoint cursors, kill-and-resume and
final states are BITWISE the sync path's, and a chunk source failing on the
stager thread surfaces on the main thread instead of hanging the trainer."""
import os

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import (BSGDConfig, MulticlassSVMConfig, fit_multiclass_stream,
                        fit_stream)
from repro.data import ArrayChunks, make_blobs, make_blobs_multiclass

CFG = BSGDConfig(budget=16, lambda_=1e-4, gamma=0.5, batch_size=4)
MCFG = MulticlassSVMConfig(n_classes=3, binary=CFG)
DIM = 6


def _binary(n=200, seed=0):
    x, y = make_blobs(jax.random.PRNGKey(seed), n, DIM)
    return np.asarray(x), np.asarray(y)


def _leaves_equal(a, b):
    for name, la, lb in zip(a._fields, a, b):
        if la is None:
            assert lb is None
            continue
        assert np.array_equal(np.asarray(la), np.asarray(lb)), name


def test_prefetch_bitwise_binary(watchdog):
    """Ragged chunks (carry path) + 2 epochs: prefetch=1..3 all bitwise."""
    watchdog(300)
    x, y = _binary(n=197)
    src = ArrayChunks(x, y, 37)
    ref = fit_stream(CFG, src, epochs=2, seed=3)
    for depth in (1, 2, 3):
        got = fit_stream(CFG, src, epochs=2, seed=3, prefetch=depth)
        _leaves_equal(ref, got)


def test_prefetch_bitwise_multiclass(watchdog):
    watchdog(300)
    x, y = make_blobs_multiclass(jax.random.PRNGKey(1), 180, DIM, 3)
    x, y = np.asarray(x), np.asarray(y)
    src = ArrayChunks(x, y, 36)
    ref = fit_multiclass_stream(MCFG, src, epochs=1, seed=5)
    got = fit_multiclass_stream(MCFG, src, epochs=1, seed=5, prefetch=2)
    _leaves_equal(ref, got)


def test_prefetch_kill_and_resume_bitwise(tmp_path, watchdog):
    """Killed mid-epoch-2 under prefetch, resumed under prefetch: bitwise the
    uninterrupted SYNC run — cursor semantics are prefetch-invariant."""
    watchdog(300)
    x, y = _binary(n=230)
    src = ArrayChunks(x, y, 37)
    ref = fit_stream(CFG, src, epochs=2, seed=5)          # sync reference
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src, epochs=2, seed=5, ckpt_dir=ck, ckpt_every=2,
               max_chunks=9, prefetch=2)                  # dies mid-epoch-2
    steps = ckpt.all_steps(ck)
    assert steps and max(steps) <= 9
    assert ckpt.load_metadata(ck, max(steps))["epoch"] == 1
    resumed = fit_stream(CFG, src, epochs=2, seed=5, ckpt_dir=ck,
                         ckpt_every=2, prefetch=2)
    _leaves_equal(ref, resumed)


def test_prefetch_epoch_boundary_resume(tmp_path, watchdog):
    """Killed exactly at an epoch boundary (checkpoint cursor = next epoch,
    chunk 0) and resumed with prefetch: bitwise."""
    watchdog(300)
    x, y = _binary(n=200)
    src = ArrayChunks(x, y, 40)                           # 5 even chunks
    ref = fit_stream(CFG, src, epochs=2, seed=9)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src, epochs=2, seed=9, ckpt_dir=ck, ckpt_every=5,
               max_chunks=5, prefetch=2)                  # dies after epoch 1
    meta = ckpt.load_metadata(ck, max(ckpt.all_steps(ck)))
    # boundary cursor convention: end of epoch 0, not (epoch 1, chunk 0)
    assert (meta["epoch"], meta["next_chunk"]) == (0, 5)
    resumed = fit_stream(CFG, src, epochs=2, seed=9, ckpt_dir=ck,
                         ckpt_every=5, prefetch=2)
    _leaves_equal(ref, resumed)


def test_stager_error_surfaces_on_main_thread(watchdog):
    """A source whose load() raises mid-epoch fails the fit_stream CALL (not
    a daemon thread) and leaves no live stager behind."""
    import threading

    watchdog(120)

    class Boom(ArrayChunks):
        def load(self, i):
            if len(getattr(self, "_loads", [])) >= 2:
                raise OSError("shard unreadable")
            self._loads = getattr(self, "_loads", []) + [i]
            return super().load(i)

    x, y = _binary(n=200)
    with pytest.raises(OSError, match="shard unreadable"):
        fit_stream(CFG, Boom(x, y, 40), epochs=1, seed=0, prefetch=2)
    # the stager wound down with the failure — nothing left running
    for _ in range(50):
        alive = [t.name for t in threading.enumerate()
                 if t.name.startswith(("chunk-stager", "prefetch"))]
        if not alive:
            break
        threading.Event().wait(0.1)
    assert not alive, f"hung worker threads: {alive}"


def test_prefetch_publishes_to_bank(watchdog):
    """fit_stream(bank=, publish_every=K) publishes monotone versions, the
    final model always lands, and snapshots survive the donated-state scan
    (copied out — later chunks must not corrupt an earlier snapshot)."""
    watchdog(300)
    from repro.core import ModelBank, predict_labels

    x, y = _binary(n=200)
    src = ArrayChunks(x, y, 40)                           # 5 chunks/epoch
    seen = []                                 # (version, model, alpha-copy)

    class Spy(ModelBank):
        def publish(self, model):
            v = super().publish(model)
            seen.append((v, model, np.asarray(model.alpha).copy()))
            return v

    bank = Spy()
    state = fit_stream(CFG, src, epochs=1, seed=2, bank=bank,
                       publish_every=2)
    assert bank.version >= 2                  # mid-run + final snapshots
    assert [v for v, _, _ in seen] == list(range(1, bank.version + 1))
    # every snapshot kept its publish-time bytes: the donated-state scan of
    # LATER chunks must not have invalidated an earlier snapshot's buffers
    for v, model, alpha_then in seen:
        np.testing.assert_array_equal(np.asarray(model.alpha), alpha_then)
    # the final published model is the final state's export
    from repro.core import export_model
    _, final = bank.current()
    direct = np.asarray(predict_labels(export_model(state, CFG.gamma), x))
    np.testing.assert_array_equal(np.asarray(predict_labels(final, x)),
                                  direct)
