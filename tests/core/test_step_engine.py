"""Train-step engine: fused megakernel step vs the composed three-phase step.

The contracts pinned here (DESIGN.md §12):
  * ``step_engine="pallas"`` makes bitwise-identical train-step DECISIONS
    (all integer state: counts, step, insert/event totals) through real
    multi-step training, with float state inside fp32 round-off — across
    maintenance strategies, class counts, and the bf16 bank;
  * the kernel cache stays exact (== rebuild from the bank) after fused
    training;
  * fused-vs-composed parity holds at every cell measured by
    ``benchmarks/bench_train_step.py`` (the committed BENCH_train_step.json
    numbers compare like for like);
  * the BOGD-style ``maintenance="removal-project"`` strategy matches its
    closed form and stays loop-exact under the vmapped multi-class step;
  * ``kernels.ops._pad_to_lane`` round-trips (pad then slice == identity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSGDConfig, MulticlassSVMConfig, accuracy, fit,
                        fit_multiclass, fit_multiclass_loop, kernel_cache)
from repro.core.budget import _removal_all, _removal_project_all
from repro.data import make_blobs_multiclass, make_two_moons, train_test_split
from repro.kernels.ops import _pad_to_lane

GAMMA = 0.5


def _binary_cfg(maintenance="merge", **kw):
    return BSGDConfig(budget=12, lambda_=1e-3, gamma=GAMMA, batch_size=8,
                      method="lookup-wd", use_kernel_cache=True,
                      maintenance=maintenance, **kw)


def _fit_mc(cfg_b, n_classes, seed=0):
    cfg = MulticlassSVMConfig(n_classes=n_classes, binary=cfg_b)
    key = jax.random.PRNGKey(seed)
    x, y = make_blobs_multiclass(key, 160, 5, n_classes=n_classes)
    return fit_multiclass(cfg, x, y, epochs=2, seed=seed, impl="ref")


# ints BITWISE, floats inside fp32 round-off — shared with the cross-solver
# harness (tests/helpers/invariants.py)
from helpers.invariants import assert_state_parity as _assert_state_parity


# --------------------------------------------------------------------------
# fused step == composed step through real training
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n_classes", [2, 16])
@pytest.mark.parametrize("strategy", ["merge", "multi-merge"])
def test_fused_step_matches_composed_multiclass(strategy, n_classes):
    st_c = _fit_mc(_binary_cfg(strategy, step_engine="composed"), n_classes)
    st_f = _fit_mc(_binary_cfg(strategy, step_engine="pallas"), n_classes)
    assert int(jnp.sum(st_c.n_merges)) > 0         # the budget actually bit
    _assert_state_parity(st_c, st_f)


@pytest.mark.parametrize("strategy", ["merge", "multi-merge"])
def test_fused_step_matches_composed_binary(strategy):
    """C=1: the binary ``bsgd.train_step`` fused branch (no class axis)."""
    x, y = make_two_moons(jax.random.PRNGKey(0), 200)
    st_c = fit(_binary_cfg(strategy, step_engine="composed"), x, y,
               epochs=2, impl="ref")
    st_f = fit(_binary_cfg(strategy, step_engine="pallas"), x, y,
               epochs=2, impl="ref")
    assert int(st_c.n_merges) > 0
    _assert_state_parity(st_c, st_f)
    acc = float(accuracy(st_f, x, y, GAMMA))
    assert acc > 0.8, acc


def test_fused_step_bf16_bank():
    cfg_c = _binary_cfg(sv_dtype="bfloat16", step_engine="composed")
    cfg_f = _binary_cfg(sv_dtype="bfloat16", step_engine="pallas")
    st_c = _fit_mc(cfg_c, 4)
    st_f = _fit_mc(cfg_f, 4)
    assert st_f.sv_x.dtype == jnp.bfloat16
    assert st_f.kmat.dtype == jnp.float32
    _assert_state_parity(st_c, st_f)


def test_cache_matches_rebuild_after_fused_training():
    st = _fit_mc(_binary_cfg("multi-merge", step_engine="pallas"), 3)
    rebuilt = jax.vmap(
        lambda s: kernel_cache.exact_cache(s.astype(jnp.float32), GAMMA))(
            st.sv_x)
    slots = st.alpha.shape[1]
    live = jnp.arange(slots)[None, :] < st.count[:, None]
    mask = (live[:, :, None] & live[:, None, :])
    np.testing.assert_allclose(
        np.where(np.asarray(mask), np.asarray(st.kmat), 0.0),
        np.where(np.asarray(mask), np.asarray(rebuilt), 0.0), atol=5e-4)


# --------------------------------------------------------------------------
# parity at every cell the benchmark measures
# --------------------------------------------------------------------------
BENCH_CELLS = [(dim, budget, c) for dim in (64, 512)
               for budget in (256, 1024) for c in (1, 16)]


@pytest.mark.parametrize("dim,budget,n_classes", BENCH_CELLS)
def test_fused_step_parity_at_bench_cells(dim, budget, n_classes):
    """One steady-state step (count == budget, events fire) per measured
    cell of BENCH_train_step.json — the benchmark compares like for like."""
    kw = dict(budget=budget, lambda_=1e-3, gamma=2.0**-7, batch_size=8,
              method="lookup-wd", use_kernel_cache=True, maintenance="merge")
    if n_classes == 1:
        from repro.core.bsgd import init_state, train_step
        cfg_c = BSGDConfig(step_engine="composed", **kw)
        cfg_f = BSGDConfig(step_engine="pallas", **kw)
        make_step = lambda cfg: lambda tbl, st, xb, yb: train_step(
            cfg, tbl, st, xb, yb, impl="ref")
        state = init_state(cfg_c, dim)
        lead = ()
    else:
        from repro.core.multiclass import (init_multiclass_state,
                                           train_step_multiclass)
        cfg_c = MulticlassSVMConfig(
            n_classes=n_classes, binary=BSGDConfig(step_engine="composed",
                                                   **kw))
        cfg_f = MulticlassSVMConfig(
            n_classes=n_classes, binary=BSGDConfig(step_engine="pallas",
                                                   **kw))
        make_step = lambda cfg: lambda tbl, st, xb, yb: train_step_multiclass(
            cfg, tbl, st, xb, yb, impl="ref")
        state = init_multiclass_state(cfg_c, dim)
        lead = (n_classes,)

    # steady state: bank full at exactly budget, same-sign alphas, exact
    # cache — every violator insert forces a maintenance event this step
    rng = np.random.default_rng(dim * 7 + budget + n_classes)
    slots = state.alpha.shape[-1]
    sv = jnp.asarray(rng.normal(size=lead + (slots, dim)), jnp.float32)
    al = jnp.asarray(0.1 * np.abs(rng.normal(size=lead + (slots,))) + 0.01,
                     jnp.float32)
    cnt = jnp.full(lead, budget, jnp.int32)
    al = jnp.where(jnp.arange(slots) < budget, al, 0.0)
    cache = kernel_cache.exact_cache if n_classes == 1 else jax.vmap(
        lambda s: kernel_cache.exact_cache(s, kw["gamma"]))
    km = cache(sv, kw["gamma"]) if n_classes == 1 else cache(sv)
    state = state._replace(sv_x=sv, alpha=al, kmat=km, count=cnt,
                           step=jnp.full(lead, 3, jnp.int32))
    xb = jnp.asarray(rng.normal(size=(8, dim)), jnp.float32)
    if n_classes == 1:
        yb = jnp.asarray(np.where(rng.random(8) < 0.5, -1.0, 1.0),
                         jnp.float32)
    else:
        yb = jnp.asarray(rng.integers(0, n_classes, size=8), jnp.int32)

    table = cfg_c.table()
    st_c = make_step(cfg_c)(table, state, xb, yb)
    st_f = make_step(cfg_f)(table, state, xb, yb)
    assert int(jnp.sum(st_c.n_merges)) > 0
    _assert_state_parity(st_c, st_f)


# --------------------------------------------------------------------------
# removal-project (BOGD-style removal + projection, arXiv 1206.4633)
# --------------------------------------------------------------------------
def test_removal_project_matches_closed_form():
    """One event == plain removal + the documented projection formula."""
    rng = np.random.default_rng(3)
    slots, dim, budget, count = 20, 5, 14, 18
    sv = jnp.asarray(rng.normal(size=(slots, dim)), jnp.float32)
    al = jnp.asarray(rng.normal(size=(slots,)) * 0.1, jnp.float32)
    al = jnp.where(jnp.arange(slots) < count, al, 0.0)
    km = kernel_cache.exact_cache(sv, GAMMA)
    cnt = jnp.int32(count)

    sv_r, al_r, km_r, cnt_r = _removal_all(sv, al, km, cnt, budget)
    sv_p, al_p, km_p, cnt_p = _removal_project_all(sv, al, km, cnt, budget)
    assert int(cnt_p) == int(cnt_r) == budget
    # same survivors in the same order, same permuted cache
    np.testing.assert_array_equal(np.asarray(sv_p), np.asarray(sv_r))
    np.testing.assert_allclose(np.asarray(km_p), np.asarray(km_r), atol=1e-6)

    # numpy closed form: holes = smallest-|alpha| active rows
    a = np.asarray(al)
    k = np.asarray(km)
    active = np.arange(slots) < count
    order = np.argsort(np.where(active, np.abs(a), np.inf), kind="stable")
    holes = np.zeros(slots, bool)
    holes[order[:count - budget]] = True
    surv = active & ~holes
    k_hs = np.where(holes[:, None] & surv[None, :], k, 0.0)
    denom = np.maximum(k_hs.sum(axis=1), 1e-12)
    gain = (np.where(holes, a, 0.0) / denom) @ k_hs
    expect = np.where(surv, a + gain, a)
    # compaction keeps survivor order: positions [0, budget) are exactly the
    # surviving slots in slot order
    np.testing.assert_allclose(np.asarray(al_p)[:budget], expect[surv],
                               rtol=1e-6, atol=1e-7)
    assert not np.allclose(np.asarray(al_p), np.asarray(al_r))


def test_removal_project_vmap_loop_parity():
    cfg = MulticlassSVMConfig(n_classes=3, binary=BSGDConfig(
        budget=14, lambda_=1e-3, gamma=GAMMA, batch_size=8,
        method="lookup-wd", use_kernel_cache=True,
        maintenance="removal-project"))
    key = jax.random.PRNGKey(1)
    x, y = make_blobs_multiclass(key, 160, 5, n_classes=3)
    s1 = fit_multiclass(cfg, x, y, epochs=1)
    s2 = fit_multiclass_loop(cfg, x, y, epochs=1)
    np.testing.assert_array_equal(np.asarray(s1.count), np.asarray(s2.count))
    np.testing.assert_allclose(np.asarray(s1.alpha), np.asarray(s2.alpha),
                               rtol=1e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(s1.kmat), np.asarray(s2.kmat),
                               rtol=1e-5, atol=5e-5)


def test_removal_project_learns():
    from repro.data import make_blobs
    x, y = make_blobs(jax.random.PRNGKey(5), 1000, 8, sep=2.5)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=30, lambda_=1e-4, gamma=0.3, method="lookup-wd",
                     use_kernel_cache=True, maintenance="removal-project")
    st = fit(cfg, xtr, ytr, epochs=2, seed=0)
    assert int(st.count) <= cfg.budget
    acc = float(accuracy(st, xte, yte, cfg.gamma))
    assert acc > 0.9, acc


# --------------------------------------------------------------------------
# config validation + _pad_to_lane
# --------------------------------------------------------------------------
def test_step_engine_config_validation():
    with pytest.raises(ValueError, match="step_engine"):
        BSGDConfig(step_engine="bogus")
    with pytest.raises(ValueError, match="kernel cache|use_kernel_cache"):
        BSGDConfig(step_engine="pallas")                 # needs the cache
    with pytest.raises(ValueError, match="step_engine"):
        BSGDConfig(step_engine="pallas", use_kernel_cache=True,
                   method="lookup-h")                    # needs lookup-wd
    with pytest.raises(ValueError, match="step_engine"):
        BSGDConfig(step_engine="pallas", use_kernel_cache=True,
                   maintenance="removal")                # needs merge rounds
    with pytest.raises(ValueError, match="use_kernel_cache"):
        BSGDConfig(maintenance="removal-project")        # needs the cache


@pytest.mark.parametrize("shape,axes,multiple", [
    ((5,), 0, 128),
    ((5, 7), (0, 1), 128),
    ((3, 5, 7), (1, 2), (8, 128)),
    ((256, 128), (0, 1), 128),                           # already aligned
])
def test_pad_to_lane_roundtrip(shape, axes, multiple):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    p = _pad_to_lane(x, axes, multiple)
    mults = (multiple,) * len(np.atleast_1d(axes)) \
        if isinstance(multiple, int) else multiple
    for ax, m in zip(np.atleast_1d(axes), mults):
        assert p.shape[ax] % m == 0
        assert p.shape[ax] >= x.shape[ax]
    sl = tuple(slice(0, n) for n in shape)
    np.testing.assert_array_equal(np.asarray(p[sl]), np.asarray(x))
    # padding is appended zeros — the original block is untouched
    assert float(jnp.sum(jnp.abs(p))) == pytest.approx(
        float(jnp.sum(jnp.abs(x))), rel=1e-6)


def test_pad_to_lane_value():
    x = jnp.ones((3, 5))
    p = _pad_to_lane(x, 1, 8, value=1.0)
    assert p.shape == (3, 8)
    np.testing.assert_array_equal(np.asarray(p), 1.0)
