"""Cross-solver invariant harness: the §14 solver contract, enforced.

One fixture yields a ``(solver, maintenance, engine, C)`` cell — every valid
combination of {bsgd, bdca} x {merge, multi-merge, removal, removal-project,
quantized} x {xla, pallas} x two box/regularization strengths — trains a
real model through it, and every invariant test runs against every cell:

  * kernel-cache I1-I4 hold after training (the carried cache equals a
    from-scratch rebuild on the final SV set, exactly symmetric, unit
    diagonal) — ``helpers.invariants.check_cache_invariants``;
  * active-count / watermark integer state is consistent (count <= budget,
    alpha zero past the watermark, monotone counters, finite cache);
  * maintenance decisions are bitwise identical whether the over-budget
    state was reached via the bsgd or the bdca insert path, and whichever
    solver's config drives the drain — maintenance must never read the
    solver;
  * serve export round-trips (``export_model`` -> the untouched
    ``core/predict`` path scores exactly like the training-side decision
    functions).

Cells that would be invalid configs (pallas engine x non-merge strategy,
removal-project without the cache) are not generated — the harness runs
every valid cell and SKIPS none.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.invariants import (assert_state_parity, check_cache_invariants,
                                check_integer_state)
from helpers import invariants as inv

from repro.core import BSGDConfig, MulticlassSVMConfig, bdca, fit
from repro.core import fit_multiclass
from repro.core.bsgd import drain_budget, insert_from_rows
from repro.data import make_blobs, make_blobs_multiclass
from repro.kernels import ops as kops

BUDGET, BATCH, DIM, GAMMA = 10, 4, 4, 0.7

# every valid (maintenance, engine) pair: the pallas event engine is the
# fused lookup-wd merge engine, so only merge composes with it
MAINT_ENGINE = [("merge", "xla"), ("merge", "pallas"),
                ("multi-merge", "xla"), ("removal", "xla"),
                ("removal-project", "xla"), ("quantized", "xla")]
CELLS = [(solver, maint, engine, C)
         for solver in ("bsgd", "bdca")
         for maint, engine in MAINT_ENGINE
         for C in (0.5, 4.0)]


def _cell_cfg(solver, maint, engine, C, n):
    # the same C-parameterization for both solvers: lambda = 1/(nC) drives
    # the Pegasos step, bdca_C bounds the dual box
    return BSGDConfig(solver=solver, lambda_=1.0 / (n * C), bdca_C=C,
                      budget=BUDGET, gamma=GAMMA, batch_size=BATCH,
                      method="lookup-wd", use_kernel_cache=True,
                      maintenance=maint, maintenance_engine=engine,
                      unroll_maintenance=True)


@pytest.fixture(scope="module", params=CELLS,
                ids=[f"{s}-{m}-{e}-C{c}" for s, m, e, c in CELLS])
def cell(request):
    """One trained (solver, maintenance, engine, C) cell: config + final
    state + the training rows, shared by every invariant test."""
    solver, maint, engine, C = request.param
    n = 160
    cfg = _cell_cfg(solver, maint, engine, C, n)
    x, y = make_blobs(jax.random.PRNGKey(3), n, DIM, sep=1.2)
    state = fit(cfg, x, y, epochs=1, seed=0)
    assert int(state.n_merges) > 0, "cell never exercised maintenance"
    return cfg, state, np.asarray(x), np.asarray(y)


def test_cache_matches_rebuild(cell):
    cfg, state, _, _ = cell
    check_cache_invariants(state, cfg.gamma)


def test_integer_state_consistent(cell):
    cfg, state, _, _ = cell
    check_integer_state(state, cfg.budget)


def test_serve_export_roundtrip(cell):
    cfg, state, x, _ = cell
    inv.assert_serve_roundtrip(state, cfg.gamma, jnp.asarray(x[:32]))


def _over_budget(cfg, state, rng_seed=9):
    """Push the cell's trained state over budget through its own solver's
    insert path: a far-away batch violates every margin, so count lands at
    budget + batch — the exact pre-maintenance state a train step produces."""
    rng = np.random.default_rng(rng_seed)
    xb = jnp.asarray(rng.normal(8.0, 0.1, (cfg.batch_size, DIM)),
                     state.sv_x.dtype)        # kernel ~ 0 vs the bank
    yb = jnp.ones((cfg.batch_size,), state.alpha.dtype)
    k_b = kops.rbf_matrix(xb, state.sv_x, cfg.gamma)
    k_bb = kops.rbf_matrix(xb, xb, cfg.gamma)
    insert = (bdca.insert_from_rows if cfg.solver == "bdca"
              else insert_from_rows)
    over = insert(cfg, state, xb, yb, k_b, k_bb)
    assert int(over.count) > cfg.budget
    return over


def test_maintenance_decisions_solver_agnostic(cell):
    """From the same over-budget state, the drain under the bsgd config and
    under the bdca config is BITWISE identical — maintenance never reads the
    solver, for states reached by either solver's own insert path."""
    cfg, state, _, _ = cell
    over = _over_budget(cfg, state)
    other = dataclasses.replace(
        cfg, solver=("bsgd" if cfg.solver == "bdca" else "bdca"))
    table = cfg.table()
    drained = drain_budget(cfg, table, over)
    drained_other = drain_budget(other, table, over)
    assert int(drained.count) <= cfg.budget
    assert_state_parity(drained, drained_other, bitwise=True)


def test_maintenance_engines_agree_from_either_solver(cell):
    """For merge cells, the xla and fused-pallas engines drain the SAME
    over-budget state to bitwise-identical decisions (integer state) with
    floats inside fp32 round-off — also when that state came from bdca."""
    cfg, state, _, _ = cell
    if cfg.maintenance != "merge":
        return                    # the fused engine is merge-only
    over = _over_budget(cfg, state)
    table = cfg.table()
    st_x = drain_budget(dataclasses.replace(cfg, maintenance_engine="xla"),
                        table, over)
    st_p = drain_budget(dataclasses.replace(cfg, maintenance_engine="pallas"),
                        table, over)
    assert_state_parity(st_x, st_p)


# --------------------------------------------------------------------------
# the same contract through the OVR multiclass engine
# --------------------------------------------------------------------------
MC_CELLS = [(solver, maint, engine)
            for solver in ("bsgd", "bdca")
            for maint, engine in (("merge", "xla"), ("merge", "pallas"),
                                  ("removal", "xla"), ("quantized", "xla"))]


@pytest.fixture(scope="module", params=MC_CELLS,
                ids=[f"{s}-{m}-{e}" for s, m, e in MC_CELLS])
def mc_cell(request):
    solver, maint, engine = request.param
    n = 240
    cfg = MulticlassSVMConfig(
        n_classes=3, binary=_cell_cfg(solver, maint, engine, 1.0, n))
    x, y = make_blobs_multiclass(jax.random.PRNGKey(5), n, DIM, 3, sep=1.2)
    state = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    assert int(jnp.sum(state.n_merges)) > 0
    return cfg, state, np.asarray(x), np.asarray(y)


def test_mc_cache_matches_rebuild(mc_cell):
    cfg, state, _, _ = mc_cell
    check_cache_invariants(state, cfg.binary.gamma)


def test_mc_integer_state_consistent(mc_cell):
    cfg, state, _, _ = mc_cell
    check_integer_state(state, cfg.binary.budget)


def test_mc_serve_export_roundtrip(mc_cell):
    cfg, state, x, _ = mc_cell
    inv.assert_serve_roundtrip(state, cfg.binary.gamma, jnp.asarray(x[:32]))


# --------------------------------------------------------------------------
# quantized-specific contract (ISSUE 9 tentpole)
# --------------------------------------------------------------------------

def test_quantized_codebook_slots_fixed_after_drain(cell):
    """Quantized maintenance absorbs fresh violators into the codebook: the
    first ``budget`` sv rows and cache block are bitwise UNTOUCHED by a
    drain, only alphas move, and count lands exactly at budget."""
    cfg, state, _, _ = cell
    if cfg.maintenance != "quantized":
        return
    over = _over_budget(cfg, state)
    drained = drain_budget(cfg, cfg.table(), over)
    assert int(drained.count) == cfg.budget
    np.testing.assert_array_equal(np.asarray(drained.sv_x[:cfg.budget]),
                                  np.asarray(over.sv_x[:cfg.budget]))
    np.testing.assert_array_equal(
        np.asarray(drained.kmat[:cfg.budget, :cfg.budget]),
        np.asarray(over.kmat[:cfg.budget, :cfg.budget]))
    assert int(drained.n_merges) == int(over.n_merges) + 1


def test_quantized_rejections_are_validated():
    """Quantized x pallas engines and quantized without the cache are
    structurally invalid configs — rejected at construction with an error
    naming the constraint, never a silent skip or a runtime surprise."""
    kw = dict(budget=BUDGET, gamma=GAMMA, batch_size=BATCH,
              method="lookup-wd", maintenance="quantized")
    with pytest.raises(ValueError, match="use_kernel_cache"):
        BSGDConfig(use_kernel_cache=False, **kw)
    with pytest.raises(ValueError, match="maintenance_engine"):
        BSGDConfig(use_kernel_cache=True, maintenance_engine="pallas", **kw)
    with pytest.raises(ValueError, match="step_engine"):
        BSGDConfig(use_kernel_cache=True, step_engine="pallas", **kw)


def test_quantized_kmeans_codebook_seed():
    """kmeans_codebook + seed_codebook produce a warm-started state that
    already satisfies the cache invariants, and training from it keeps the
    seeded centroids frozen."""
    from repro.core import init_state, kmeans_codebook, seed_codebook

    n = 160
    cfg = _cell_cfg("bsgd", "quantized", "xla", 1.0, n)
    x, y = make_blobs(jax.random.PRNGKey(21), n, DIM, sep=1.2)
    cents = kmeans_codebook(jax.random.PRNGKey(22), x, BUDGET)
    assert cents.shape == (BUDGET, DIM)
    st = seed_codebook(init_state(cfg, DIM), cents, cfg.gamma)
    assert int(st.count) == BUDGET
    check_cache_invariants(st, cfg.gamma)
    # training from the warm start keeps the seeded centroids frozen
    # (snapshot first: prequential_stream's donated step consumes st)
    codebook = np.array(st.sv_x[:BUDGET])
    from repro.core import prequential_stream
    from repro.data import ArrayChunks

    src = ArrayChunks(np.asarray(x, np.float32), np.asarray(y, np.float32),
                      40)
    r = prequential_stream(cfg, src, state=st)
    np.testing.assert_array_equal(np.asarray(r["state"].sv_x[:BUDGET]),
                                  codebook)
    assert int(r["state"].n_merges) > 0


def test_solvers_land_comparable_accuracy():
    """Both solvers learn the same separable problems to within 1% of each
    other — binary and multiclass (the acceptance-level parity that the
    benchmark measures at real sizes).  Budget 24 so the dual working set is
    expressive; the harness's budget-10 cells stress the contract, not
    accuracy."""
    from repro.core import accuracy, accuracy_multiclass
    from repro.data import make_two_moons

    n = 400
    x, y = make_two_moons(jax.random.PRNGKey(11), n, noise=0.12)
    accs = {}
    for solver in ("bsgd", "bdca"):
        cfg = dataclasses.replace(
            _cell_cfg(solver, "merge", "xla", 1.0, n), budget=24, gamma=2.0)
        st = fit(cfg, x, y, epochs=2)
        accs[solver] = float(accuracy(st, x, y, 2.0))
    assert abs(accs["bsgd"] - accs["bdca"]) <= 0.01, accs

    xm, ym = make_blobs_multiclass(jax.random.PRNGKey(12), n, DIM, 3, sep=2.0)
    maccs = {}
    for solver in ("bsgd", "bdca"):
        binary = dataclasses.replace(
            _cell_cfg(solver, "merge", "xla", 1.0, n), budget=24)
        cfg = MulticlassSVMConfig(n_classes=3, binary=binary)
        st = fit_multiclass(cfg, xm, ym, epochs=2)
        maccs[solver] = float(accuracy_multiclass(st, xm, ym, GAMMA))
    assert abs(maccs["bsgd"] - maccs["bdca"]) <= 0.01, maccs
