"""BSGD training: budget enforcement, learning, all four methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSGDConfig, METHODS, accuracy, fit, init_state, train_step
from repro.data import make_blobs, make_two_moons, train_test_split


def test_budget_never_exceeded():
    key = jax.random.PRNGKey(0)
    x, y = make_blobs(key, 400, 6, sep=1.0)
    cfg = BSGDConfig(budget=20, lambda_=1e-3, gamma=0.5, method="lookup-wd",
                     batch_size=4)
    table = cfg.table()
    state = init_state(cfg, 6)
    for i in range(0, 200, 4):
        state = train_step(cfg, table, state, x[i:i+4], y[i:i+4])
        assert int(state.count) <= cfg.budget


def test_insert_only_on_margin_violation():
    cfg = BSGDConfig(budget=50, lambda_=1e-3, gamma=1.0, method="gss")
    state = init_state(cfg, 2)
    x = jnp.asarray([[1.0, 0.0]])
    y = jnp.asarray([1.0])
    # empty model: margin = 0 < 1 -> insert
    state = train_step(cfg, None, state, x, y)
    assert int(state.count) == 1 and int(state.n_inserts) == 1


@pytest.mark.parametrize("method", METHODS)
def test_learns_two_moons(method):
    key = jax.random.PRNGKey(42)
    x, y = make_two_moons(key, 1200, noise=0.15)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=40, lambda_=1e-4, gamma=2.0, method=method)
    st = fit(cfg, xtr, ytr, epochs=2, seed=0)
    acc = float(accuracy(st, xte, yte, cfg.gamma))
    assert acc > 0.95, (method, acc)
    assert int(st.count) <= cfg.budget
    assert int(st.n_merges) > 0  # the budget actually bit


def test_methods_reach_equivalent_accuracy():
    """Paper Table 2: lookup variants match GSS accuracy."""
    key = jax.random.PRNGKey(7)
    x, y = make_blobs(key, 1500, 10, sep=2.5)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    accs = {}
    for method in METHODS:
        cfg = BSGDConfig(budget=30, lambda_=1e-4, gamma=0.3, method=method,
                         batch_size=2)
        st = fit(cfg, xtr, ytr, epochs=2, seed=1)
        accs[method] = float(accuracy(st, xte, yte, cfg.gamma))
    spread = max(accs.values()) - min(accs.values())
    assert spread < 0.05, accs
    assert min(accs.values()) > 0.9, accs


def test_minibatch_matches_single_roughly():
    key = jax.random.PRNGKey(3)
    x, y = make_blobs(key, 800, 4, sep=2.0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    acc = {}
    for bs in (1, 8):
        cfg = BSGDConfig(budget=25, lambda_=1e-4, gamma=0.5, method="lookup-wd",
                         batch_size=bs)
        st = fit(cfg, xtr, ytr, epochs=2, seed=0)
        acc[bs] = float(accuracy(st, xte, yte, cfg.gamma))
    assert abs(acc[1] - acc[8]) < 0.08, acc
