"""Serving engine: fused predict cell, bank export, queue parity, resume.

The contracts pinned here (DESIGN.md §10):
  * the fused serve cell is decision-identical to the training-side
    predictors (binary sign and multiclass argmax);
  * export folds the active-count mask into alpha and quantizes only the
    bank — bf16 predictions match fp32 decisions on margin-separated rows;
  * the ``BatchQueue`` returns BITWISE the labels of one direct fused call
    on the same rows, for any arrival pattern (ragged tails, requests
    spanning microbatches, empty requests) — and its compiled-shape set is
    exactly its bucket list;
  * a mid-epoch ``fit_stream`` checkpoint serves identically to the
    in-memory model it snapshotted.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSGDConfig, BatchQueue, MulticlassSVMConfig,
                        decision_function_multiclass, export_model, fit,
                        fit_multiclass, fit_multiclass_stream, fit_stream,
                        load_serve_model, predict, predict_labels,
                        predict_multiclass, predict_proba, serve_requests,
                        top_k_labels)
from repro.data import ArrayChunks, make_blobs, make_blobs_multiclass

GAMMA = 0.5


@pytest.fixture(scope="module")
def mc_model():
    cfg = MulticlassSVMConfig.create(5, budget=24, lambda_=1e-3, gamma=GAMMA,
                                     batch_size=8)
    x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 640, 8, n_classes=5,
                                 sep=2.0)
    state = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    return cfg, state, np.asarray(x), np.asarray(y)


@pytest.fixture(scope="module")
def bin_model():
    cfg = BSGDConfig(budget=16, lambda_=1e-3, gamma=GAMMA, batch_size=8)
    x, y = make_blobs(jax.random.PRNGKey(1), 320, 6, sep=2.0)
    state = fit(cfg, x, y, epochs=1, seed=0)
    return cfg, state, np.asarray(x), np.asarray(y)


def test_export_folds_count_mask_and_quantizes_bank_only(mc_model):
    cfg, state, _, _ = mc_model
    model = export_model(state, GAMMA, bank_dtype="bfloat16")
    assert model.sv_x.dtype == jnp.bfloat16
    assert model.alpha.dtype == jnp.float32          # fp32 accumulation
    assert not model.binary and model.n_classes == 5
    counts = np.asarray(model.count)
    alpha = np.asarray(model.alpha)
    for c in range(5):
        assert (alpha[c, counts[c]:] == 0).all()     # mask folded in
        np.testing.assert_array_equal(
            alpha[c, :counts[c]], np.asarray(state.alpha)[c, :counts[c]])


def test_binary_export_is_c1_bank(bin_model):
    cfg, state, x, _ = bin_model
    model = export_model(state, GAMMA)
    assert model.binary and model.sv_x.shape[0] == 1
    labels = np.asarray(predict_labels(model, x))
    assert labels.dtype == np.float32
    np.testing.assert_array_equal(labels, np.asarray(predict(state, x, GAMMA)))


def test_fused_serve_cell_matches_train_side_predict(mc_model):
    cfg, state, x, y = mc_model
    model = export_model(state, GAMMA)
    got = np.asarray(predict_labels(model, x))
    want = np.asarray(predict_multiclass(state, x, GAMMA))
    np.testing.assert_array_equal(got, want)
    assert (got == y.astype(np.int32)).mean() > 0.9  # the model is real


@pytest.mark.parametrize("k", [1, 2, 5])
def test_top_k_rank1_is_argmax_and_scores_sorted(mc_model, k):
    """top_k_labels: rank 1 bitwise == predict_labels; scores descend; every
    row's id set is k distinct valid classes; ids/scores agree with the
    training-side per-class decision functions."""
    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    ids, vals = top_k_labels(model, x[:100], k=k)
    ids, vals = np.asarray(ids), np.asarray(vals)
    assert ids.shape == vals.shape == (100, k) and ids.dtype == np.int32
    np.testing.assert_array_equal(ids[:, 0],
                                  np.asarray(predict_labels(model, x[:100])))
    assert (np.diff(vals, axis=1) <= 0).all()            # best first
    assert ((ids >= 0) & (ids < 5)).all()
    assert all(len(set(r)) == k for r in ids)            # distinct classes
    scores = np.asarray(decision_function_multiclass(state, x[:100], GAMMA)).T
    np.testing.assert_allclose(np.take_along_axis(scores, ids, axis=1), vals,
                               rtol=1e-5, atol=1e-5)


def test_predict_proba_calibrated_softmax(mc_model):
    """Rows sum to 1, argmax == predict_labels, temperature reorders nothing
    but flattens confidence monotonically."""
    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    probs = np.asarray(predict_proba(model, x[:100]))
    assert probs.shape == (100, 5)
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-5)
    np.testing.assert_array_equal(
        probs.argmax(axis=1).astype(np.int32),
        np.asarray(predict_labels(model, x[:100])))
    hot = np.asarray(predict_proba(model, x[:100], temperature=10.0))
    np.testing.assert_array_equal(probs.argmax(axis=1), hot.argmax(axis=1))
    assert (hot.max(axis=1) <= probs.max(axis=1) + 1e-6).all()


def test_top_k_and_proba_reject_binary_and_bad_k(bin_model, mc_model):
    cfg, state, x, _ = bin_model
    bmodel = export_model(state, GAMMA)
    with pytest.raises(ValueError):
        top_k_labels(bmodel, x[:4])
    with pytest.raises(ValueError):
        predict_proba(bmodel, x[:4])
    _, mstate, mx, _ = mc_model
    mmodel = export_model(mstate, GAMMA)
    with pytest.raises(ValueError):
        top_k_labels(mmodel, mx[:4], k=6)                # > n_classes
    with pytest.raises(ValueError):
        top_k_labels(mmodel, mx[:4], k=0)
    with pytest.raises(ValueError):
        predict_proba(mmodel, mx[:4], temperature=0.0)   # NaN factory
    with pytest.raises(ValueError):
        predict_proba(mmodel, mx[:4], temperature=-1.0)  # reversed ranking


ARRIVALS = [
    [640],                                # one big request, spans microbatches
    [1] * 37,                             # tiny requests packed together
    [3, 50, 1, 0, 17, 120, 5, 200, 31],   # ragged mix with an empty request
    [63, 64, 65],                         # straddling the microbatch size
]


@pytest.mark.parametrize("sizes", ARRIVALS)
def test_queue_bitwise_parity_multiclass(mc_model, sizes):
    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    direct = np.asarray(predict_labels(model, x))
    reqs, off = [], 0
    for s in sizes:
        reqs.append(x[off:off + s])
        off += s
    labels = serve_requests(model, reqs, max_batch=64)
    assert [l.shape[0] for l in labels] == sizes
    np.testing.assert_array_equal(np.concatenate(labels), direct[:off])


@pytest.mark.parametrize("sizes", ARRIVALS)
def test_queue_bitwise_parity_binary(bin_model, sizes):
    cfg, state, x, _ = bin_model
    sizes = [min(s, 40) for s in sizes]   # binary fixture has 320 rows
    model = export_model(state, GAMMA)
    direct = np.asarray(predict_labels(model, x))
    reqs, off = [], 0
    for s in sizes:
        reqs.append(x[off:off + s])
        off += s
    labels = serve_requests(model, reqs, max_batch=32, min_bucket=4)
    np.testing.assert_array_equal(np.concatenate(labels), direct[:off])


def test_queue_pads_to_buckets_only(mc_model):
    """Compiled-shape discipline: every microbatch is a bucket size, full
    microbatches run eagerly at submit, and pad rows are accounted."""
    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    q = BatchQueue(model, max_batch=32, min_bucket=8)
    assert q.buckets == (8, 16, 32)
    t1 = q.submit(x[:70])                 # 2 full microbatches run now
    assert q.stats["microbatches"] == 2 and q._pending_rows == 6
    t2 = q.submit(x[70:75])               # still below a microbatch
    q.drain()                             # ragged 11 -> bucket 16
    assert q.stats["bucket_counts"] == {32: 2, 16: 1}
    assert q.stats["padded_rows"] == 5
    direct = np.asarray(predict_labels(model, x[:75]))
    np.testing.assert_array_equal(
        np.concatenate([q.take(t1), q.take(t2)]), direct)


def test_queue_take_before_drain_raises(mc_model):
    cfg, state, x, _ = mc_model
    q = BatchQueue(export_model(state, GAMMA), max_batch=64)
    t = q.submit(x[:3])
    with pytest.raises(KeyError, match="drain"):
        q.take(t)
    q.drain()
    assert q.take(t).shape == (3,)


def test_bf16_bank_matches_fp32_on_margin_separated_rows(mc_model):
    cfg, state, x, _ = mc_model
    fp32 = export_model(state, GAMMA)
    bf16 = export_model(state, GAMMA, bank_dtype="bfloat16")
    from repro.core import serve_scores

    scores = np.asarray(serve_scores(fp32, x))            # (C, n)
    top2 = np.sort(scores, axis=0)[-2:]
    margin = top2[1] - top2[0]
    sep = margin > 0.05                   # rows where fp32 decides clearly
    assert sep.mean() > 0.8               # the blobs are actually separated
    l32 = np.asarray(predict_labels(fp32, x))
    l16 = np.asarray(predict_labels(bf16, x))
    np.testing.assert_array_equal(l16[sep], l32[sep])


@pytest.mark.parametrize("multiclass", [False, True])
def test_serving_midepoch_checkpoint_equals_inmemory(tmp_path, multiclass):
    """A killed streamed run's checkpoint serves bitwise like the in-memory
    model the kill returned (the train -> checkpoint -> export seam)."""
    ck = str(tmp_path / "ck")
    if multiclass:
        cfg = MulticlassSVMConfig.create(3, budget=12, lambda_=1e-3,
                                         gamma=GAMMA, batch_size=4)
        x, y = make_blobs_multiclass(jax.random.PRNGKey(2), 256, 5,
                                     n_classes=3, sep=2.0)
        source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=64)
        state = fit_multiclass_stream(cfg, source, epochs=1, seed=0,
                                      ckpt_dir=ck, ckpt_every=2, max_chunks=2)
    else:
        cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=GAMMA, batch_size=4)
        x, y = make_blobs(jax.random.PRNGKey(3), 256, 5, sep=2.0)
        source = ArrayChunks(np.asarray(x), np.asarray(y), chunk_rows=64)
        state = fit_stream(cfg, source, epochs=1, seed=0,
                           ckpt_dir=ck, ckpt_every=2, max_chunks=2)
    assert os.path.isdir(ck)              # the mid-epoch checkpoint exists
    from_ckpt = load_serve_model(ck, GAMMA)
    in_mem = export_model(state, GAMMA)
    np.testing.assert_array_equal(np.asarray(from_ckpt.sv_x),
                                  np.asarray(in_mem.sv_x))
    np.testing.assert_array_equal(np.asarray(from_ckpt.alpha),
                                  np.asarray(in_mem.alpha))
    xe = np.asarray(x)[:96]
    np.testing.assert_array_equal(np.asarray(predict_labels(from_ckpt, xe)),
                                  np.asarray(predict_labels(in_mem, xe)))


def test_load_serve_model_rejects_non_svm_checkpoint(tmp_path):
    from repro import checkpoint as ckpt

    d = str(tmp_path / "lm")
    ckpt.save(d, 1, {"params": {"w": jnp.ones((2, 2))}})
    with pytest.raises(ValueError, match="not an SVM training checkpoint"):
        load_serve_model(d, GAMMA)
    with pytest.raises(ValueError, match="no complete checkpoint"):
        load_serve_model(str(tmp_path / "empty"), GAMMA)


def test_queue_rejects_bad_geometry(mc_model):
    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    with pytest.raises(ValueError, match="max_batch"):
        BatchQueue(model, max_batch=0)
    with pytest.raises(ValueError, match="min_bucket"):
        BatchQueue(model, max_batch=8, min_bucket=0)


def test_drive_trace_max_batch_one(mc_model):
    """The degenerate single-row-microbatch service still runs (regression:
    the trace generator crashed on max_batch=1)."""
    from repro.core import drive_trace, ragged_trace_sizes

    cfg, state, x, _ = mc_model
    model = export_model(state, GAMMA)
    rng = np.random.default_rng(0)
    sizes = ragged_trace_sizes(8, 1, rng)
    assert sizes == [1] * 8
    stats = drive_trace(model, x[:8], sizes, max_batch=1, min_bucket=1)
    assert stats["rows"] == 8 and stats["microbatches"] == 8


def test_load_serve_model_corrupt_manifest(tmp_path):
    from repro import checkpoint as ckpt

    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"state": jnp.zeros((2,))})
    with open(os.path.join(d, "step_00000001", "manifest.json"), "w") as f:
        f.write('{"leaves": {"trunc')
    with pytest.raises(ValueError, match="corrupt"):
        load_serve_model(d, GAMMA)
