"""Prequential streaming driver (ISSUE 9): test-then-train semantics,
seeded single-pass determinism under drift, and the regret readout.

The contract DESIGN.md §15 pins:

  * chunks are visited in natural order by default — the drift schedule
    plays out where it was placed;
  * the whole pass is deterministic given (source, seed): two runs agree on
    every mistake count and bitwise on the final model;
  * each chunk is scored BEFORE it is trained on — a model that has seen a
    chunk cannot use it for that chunk's mistakes.
"""
import jax
import numpy as np
import pytest

from repro.core import BSGDConfig, MulticlassSVMConfig, prequential_stream
from repro.data import (ArrayChunks, DriftChunks, label_flip_schedule,
                        make_blobs, make_blobs_multiclass)

DIM = 6


def _binary_source(n=640, chunk=64, seed=0):
    x, y = make_blobs(jax.random.PRNGKey(seed), n, DIM, sep=2.0)
    return ArrayChunks(np.asarray(x, np.float32),
                       np.asarray(y, np.float32), chunk)


def _cfg(maint="merge", batch=8):
    return BSGDConfig(budget=16, lambda_=1e-3, gamma=0.5, method="lookup-wd",
                      batch_size=batch, use_kernel_cache=True,
                      maintenance=maint)


def test_prequential_learns_and_counts_every_row():
    src = _binary_source()
    r = prequential_stream(_cfg(), src)
    assert r["n_rows"] == src.n_rows
    assert sum(r["chunk_mistakes"]) == r["mistakes"]
    assert len(r["chunk_acc"]) == src.n_chunks
    # cold model scores sign(0)=0 on chunk 0: all mistakes by convention
    assert r["chunk_acc"][0] == 0.0
    # ...but it learns: late chunks beat early post-cold chunks comfortably
    assert np.mean(r["chunk_acc"][-3:]) > 0.8
    assert r["mistake_rate"] == round(r["mistakes"] / src.n_rows, 4)


@pytest.mark.parametrize("maint", ["merge", "quantized"])
def test_seeded_single_pass_regret_deterministic(maint, watchdog):
    """The ISSUE 9 gate: same drifted source + same seed => identical
    mistake sequence and bitwise-identical final model, including through
    the quantized fixed-codebook path."""
    watchdog(300)
    src = _binary_source()
    flip = label_flip_schedule(src.n_chunks, start=0.5, prob=1.0)

    def run():
        drift = DriftChunks(src, flip=flip, seed=7)
        return prequential_stream(_cfg(maint), drift)

    a, b = run(), run()
    assert a["chunk_mistakes"] == b["chunk_mistakes"]
    assert a["mistakes"] == b["mistakes"]
    np.testing.assert_array_equal(np.asarray(a["state"].alpha),
                                  np.asarray(b["state"].alpha))
    np.testing.assert_array_equal(np.asarray(a["state"].sv_x),
                                  np.asarray(b["state"].sv_x))
    # the drift actually bit: the flip chunk is much worse than its
    # immediate pre-drift neighbour
    mid = src.n_chunks // 2
    assert a["chunk_acc"][mid] < a["chunk_acc"][mid - 1] - 0.3


def test_drift_regret_orders_pre_vs_post():
    """Cumulative mistakes on a clean stream < on the same stream with a
    mid-pass label flip — the regret readout responds to drift."""
    src = _binary_source()
    clean = prequential_stream(_cfg(), src)
    flip = label_flip_schedule(src.n_chunks, start=0.5, prob=1.0)
    drifted = prequential_stream(_cfg(), DriftChunks(src, flip=flip, seed=0))
    assert drifted["mistakes"] > clean["mistakes"]


def test_prequential_multiclass_and_remainder_rows():
    """OVR path works, and remainder rows (chunk not divisible by the batch)
    are scored but not trained — n_rows still counts them."""
    n, chunk = 330, 55                       # 55 = 6*8 + 7 remainder rows
    x, y = make_blobs_multiclass(jax.random.PRNGKey(2), n, DIM, 3, sep=2.5)
    src = ArrayChunks(np.asarray(x, np.float32), np.asarray(y), chunk)
    cfg = MulticlassSVMConfig.create(3, budget=16, lambda_=1e-3, gamma=0.5,
                                     batch_size=8, use_kernel_cache=True)
    r = prequential_stream(cfg, src)
    assert r["n_rows"] == n
    assert np.mean(r["chunk_acc"][-2:]) > 0.7
    # trained rows are the batch-aligned prefixes only (each trained row can
    # insert into every one of the 3 OVR binary problems, never more)
    assert int(r["state"].n_inserts.sum()) <= (chunk // 8) * 8 * src.n_chunks * 3


def test_prequential_rejects_non_config():
    with pytest.raises(TypeError, match="BSGDConfig"):
        prequential_stream(object(), _binary_source())
