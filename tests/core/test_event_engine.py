"""Maintenance-event engine: fused rounds vs the vmapped per-class engines.

The contracts pinned here (DESIGN.md §11):
  * one fused event round == ``_merge_once`` per over-budget class, bitwise
    on the ref path (the production CPU impl);
  * the three engines — xla while-loop, xla unrolled, pallas (fused events
    on the sorted-excess schedule) — make bitwise-identical maintenance
    DECISIONS through real training (integer state: counts, inserts, event
    totals) with float state inside fp32 round-off;
  * the sorted-excess schedule early-exits to a bitwise no-op when no class
    is over budget (while AND unrolled forms);
  * the removal strategy stays loop-exact under the vmapped multi-class
    step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.invariants import assert_state_parity

from repro.core import (BSGDConfig, MulticlassSVMConfig, default_table, fit,
                        fit_multiclass, fit_multiclass_loop, kernel_cache,
                        run_maintenance_classes)
from repro.core.budget import _merge_once
from repro.data import make_blobs_multiclass, make_two_moons, train_test_split
from repro.kernels import ops as kops

GAMMA = 0.5


def _stacked_over_budget(key, c, slots, dim, counts):
    """Random stacked state with exact caches; count[q] = counts[q]."""
    k1, k2 = jax.random.split(key)
    sv = jax.random.normal(k1, (c, slots, dim))
    alpha = 0.1 * jax.random.normal(k2, (c, slots))
    counts = jnp.asarray(counts, jnp.int32)
    alpha = jnp.where(jnp.arange(slots)[None, :] < counts[:, None], alpha, 0.0)
    kmat = jax.vmap(lambda s: kernel_cache.exact_cache(s, GAMMA))(sv)
    return sv, alpha, kmat, counts


# --------------------------------------------------------------------------
# one fused round == _merge_once per class
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("seed", [0, 1])
def test_merge_event_round_matches_merge_once(impl, seed):
    c, slots, dim, budget = 4, 24, 6, 14
    counts = [20, 14, 24, 17]                      # classes 1: at budget
    sv, alpha, kmat, count = _stacked_over_budget(
        jax.random.PRNGKey(seed), c, slots, dim, counts)
    table = default_table()
    over = count > budget
    sv2, al2, km2 = kops.merge_event(sv, alpha, kmat, count, over, table,
                                     impl=impl)
    for q in range(c):
        if not bool(over[q]):
            # no-op classes come back BITWISE untouched
            np.testing.assert_array_equal(np.asarray(al2[q]),
                                          np.asarray(alpha[q]))
            np.testing.assert_array_equal(np.asarray(sv2[q]),
                                          np.asarray(sv[q]))
            np.testing.assert_array_equal(np.asarray(km2[q]),
                                          np.asarray(kmat[q]))
            continue
        s1, a1, k1, _, _ = _merge_once(sv[q], alpha[q], kmat[q], count[q],
                                       GAMMA, "lookup-wd", table)
        # same decisions and formulas; the class-batched ops leave XLA a
        # width-dependent FMA-contraction choice in the z-row combine, so
        # floats match to ~1 ulp, not bitwise (same envelope as the cached
        # vmap engine in test_multiclass)
        tol = 1e-7 if impl == "ref" else 1e-5
        np.testing.assert_allclose(np.asarray(al2[q]), np.asarray(a1),
                                   atol=tol)
        np.testing.assert_allclose(np.asarray(sv2[q]), np.asarray(s1),
                                   atol=tol)
        np.testing.assert_allclose(np.asarray(km2[q]), np.asarray(k1),
                                   atol=max(tol, 1e-6))


def test_merge_event_removal_fallback_round():
    """A class whose min-|alpha| SV has no same-sign partner must fall back
    to removal inside the fused round (mixed with a merging class)."""
    slots, dim = 12, 3
    sv = jax.random.normal(jax.random.PRNGKey(5), (2, slots, dim))
    # class 0: lone positive among strong negatives -> removal fallback;
    # class 1: all same sign -> genuine merge
    a0 = jnp.full((slots,), -2.0).at[3].set(0.01)
    a1 = 0.1 * jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (slots,))) + 0.01
    alpha = jnp.stack([a0, a1])
    count = jnp.asarray([10, 10], jnp.int32)
    alpha = jnp.where(jnp.arange(slots)[None, :] < count[:, None], alpha, 0.0)
    kmat = jax.vmap(lambda s: kernel_cache.exact_cache(s, GAMMA))(sv)
    table = default_table()
    for impl in ("ref", "pallas_interpret"):
        sv2, al2, km2 = kops.merge_event(sv, alpha, kmat, count,
                                         jnp.asarray([True, True]), table,
                                         impl=impl)
        # class 0 removed its positive: survivors all negative, mass intact
        surv = np.asarray(al2[0][:9])
        assert (surv < 0).all(), impl
        # class 1 merged: same-sign mass preserved to fp tolerance
        assert np.isclose(np.asarray(al2[1][:9]).sum(),
                          float(alpha[1].sum()), atol=5e-3), impl
        merged = []
        for q in range(2):
            s1, a1_, k1, _, info = _merge_once(sv[q], alpha[q], kmat[q],
                                               count[q], GAMMA, "lookup-wd",
                                               table)
            np.testing.assert_allclose(np.asarray(al2[q]), np.asarray(a1_),
                                       atol=1e-6, err_msg=f"{impl} c={q}")
            merged.append(bool(info.merged))
        assert merged == [False, True]     # fallback fired, merge fired


# --------------------------------------------------------------------------
# sorted-excess schedule
# --------------------------------------------------------------------------
@pytest.mark.parametrize("unroll", [0, 4])
def test_sorted_excess_early_exit_is_bitwise_noop(unroll):
    """No class over budget -> the engine returns the state BITWISE
    unchanged (while form: zero rounds; unrolled form: masked no-op rounds)."""
    c, slots, dim, budget = 3, 16, 4, 12
    sv, alpha, kmat, count = _stacked_over_budget(
        jax.random.PRNGKey(2), c, slots, dim, [12, 7, 10])
    n0 = jnp.asarray([5, 0, 2], jnp.int32)         # pre-existing event counts
    out = run_maintenance_classes(sv, alpha, kmat, count, n0,
                                  default_table(), budget=budget, impl="ref",
                                  unroll=unroll)
    for got, want in zip(out, (sv, alpha, kmat, count, n0)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("unroll", [0, 8])
def test_sorted_excess_drains_every_class_to_budget(unroll):
    """Mixed excesses: the schedule runs to the worst class's excess; every
    class lands exactly on budget and logs exactly its own excess events."""
    c, slots, dim, budget = 4, 32, 5, 20
    counts = [28, 20, 22, 26]                      # excess 8, 0, 2, 6
    key = jax.random.PRNGKey(3)
    sv, alpha, kmat, count = _stacked_over_budget(key, c, slots, dim, counts)
    # same-sign alphas so every event is a merge (event count == excess)
    alpha = jnp.abs(alpha) + jnp.where(
        jnp.arange(slots)[None, :] < count[:, None], 0.01, 0.0)
    alpha = jnp.where(jnp.arange(slots)[None, :] < count[:, None], alpha, 0.0)
    n0 = jnp.zeros((c,), jnp.int32)
    _, al2, _, c2, n2 = run_maintenance_classes(
        sv, alpha, kmat, count, n0, default_table(), budget=budget,
        impl="ref", unroll=unroll)
    np.testing.assert_array_equal(np.asarray(c2), budget)
    np.testing.assert_array_equal(np.asarray(n2),
                                  np.maximum(np.asarray(counts) - budget, 0))
    al2 = np.asarray(al2)
    assert (al2[:, budget:] == 0).all()
    assert (np.abs(al2[:, :budget]) > 0).all()


def test_engine_requires_cache_and_table():
    c, slots, dim, budget = 2, 8, 3, 4
    sv, alpha, kmat, count = _stacked_over_budget(
        jax.random.PRNGKey(0), c, slots, dim, [6, 6])
    with pytest.raises(ValueError):
        run_maintenance_classes(sv, alpha, None, count, count * 0,
                                default_table(), budget=budget)
    with pytest.raises(ValueError):
        run_maintenance_classes(sv, alpha, kmat, count, count * 0, None,
                                budget=budget)


def test_engine_config_validation():
    with pytest.raises(ValueError):
        BSGDConfig(maintenance_engine="bogus")
    # pallas needs cache + merge + lookup-wd
    with pytest.raises(ValueError):
        BSGDConfig(maintenance_engine="pallas")
    with pytest.raises(ValueError):
        BSGDConfig(maintenance_engine="pallas", use_kernel_cache=True,
                   maintenance="removal")
    with pytest.raises(ValueError):
        BSGDConfig(maintenance_engine="pallas", use_kernel_cache=True,
                   method="gss")
    BSGDConfig(maintenance_engine="pallas", use_kernel_cache=True)  # valid


# --------------------------------------------------------------------------
# decision-bitwise property across the three engines, through real training
# --------------------------------------------------------------------------
def _fit_engines(cfg_kw, x, y, n_classes=4):
    states = {}
    for name, extra in (("xla-loop", {}),
                        ("xla-unroll", {"unroll_maintenance": True}),
                        ("pallas", {"maintenance_engine": "pallas",
                                    "unroll_maintenance": True})):
        cfg = MulticlassSVMConfig.create(n_classes, **cfg_kw, **extra)
        states[name] = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    return states


def test_three_engines_decision_bitwise_float_allclose():
    """xla while-loop vs xla unrolled vs the fused event engine, through a
    real multi-class fit: all integer state (counts, step, inserts, event
    totals — i.e. every merge-partner/removal decision) BITWISE identical,
    float state within fp32 round-off (the same envelope the cached vmap
    engine is pinned to in test_multiclass)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(7), 480, 6, 4, sep=1.2)
    states = _fit_engines(dict(budget=16, lambda_=1e-3, gamma=0.3,
                               method="lookup-wd", batch_size=4,
                               use_kernel_cache=True), x, y)
    ref_st = states["xla-unroll"]
    assert int(jnp.sum(ref_st.n_merges)) > 0       # the budget actually bit
    for name, st in states.items():
        assert_state_parity(ref_st, st, context=name)


def test_binary_engine_bitwise_vs_unroll():
    """C = 1 lifts through the engine: the binary pallas path is BITWISE the
    unrolled xla path (same trace by construction — pinned so it stays so)."""
    x, y = make_two_moons(jax.random.PRNGKey(42), 600, noise=0.15)
    base = dict(budget=24, lambda_=1e-3, gamma=2.0, method="lookup-wd",
                batch_size=4, use_kernel_cache=True, unroll_maintenance=True)
    st_x = fit(BSGDConfig(**base), x, y, epochs=1, seed=0)
    st_p = fit(BSGDConfig(maintenance_engine="pallas", **base), x, y,
               epochs=1, seed=0)
    assert int(st_p.n_merges) > 0
    assert_state_parity(st_x, st_p, bitwise=True)


def test_engine_trains_bf16_bank_multiclass():
    """The fused engine end to end on a bfloat16 SV bank (fp32 cache)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(1), 1200, 8, 4, sep=2.0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = MulticlassSVMConfig.create(
        4, budget=20, lambda_=1e-3, gamma=0.3, method="lookup-wd",
        batch_size=4, use_kernel_cache=True, sv_dtype="bfloat16",
        maintenance_engine="pallas")
    st = fit_multiclass(cfg, xtr, ytr, epochs=1, seed=0)
    assert st.sv_x.dtype == jnp.bfloat16 and st.kmat.dtype == jnp.float32
    assert np.all(np.asarray(st.count) <= 20)
    assert int(jnp.sum(st.n_merges)) > 0
    from repro.core import accuracy_multiclass
    assert float(accuracy_multiclass(st, xte, yte, 0.3)) > 0.9


def test_engine_cache_stays_consistent_through_training():
    """After a real fit through the fused engine, the carried cache equals a
    from-scratch rebuild on the final SV set (invariant I1)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(9), 400, 5, 3, sep=1.5)
    cfg = MulticlassSVMConfig.create(
        3, budget=14, lambda_=1e-3, gamma=0.4, method="lookup-wd",
        batch_size=4, use_kernel_cache=True, maintenance_engine="pallas")
    st = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    assert int(jnp.sum(st.n_merges)) > 0
    for q in range(3):
        n = int(st.count[q])
        got = np.asarray(st.kmat[q])[:n, :n]
        want = np.asarray(kernel_cache.exact_cache(st.sv_x[q], 0.4))[:n, :n]
        np.testing.assert_allclose(got, want, atol=5e-4)


# --------------------------------------------------------------------------
# removal strategy under the vmapped multi-class step
# --------------------------------------------------------------------------
def test_removal_strategy_vmapped_multiclass_matches_loop():
    """maintenance="removal" through the lockstep (vmapped) multi-class step
    == the per-class loop baseline, bitwise — and the budget holds."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(4), 400, 6, 4, sep=1.2)
    cfg = MulticlassSVMConfig.create(4, budget=16, lambda_=1e-3, gamma=0.2,
                                     method="lookup-wd", batch_size=4,
                                     maintenance="removal")
    st_b = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    st_l = fit_multiclass_loop(cfg, x, y, epochs=1, seed=0)
    assert int(jnp.sum(st_b.n_merges)) > 0         # removal events fired
    assert np.all(np.asarray(st_b.count) <= 16)
    assert_state_parity(st_b, st_l, bitwise=True)
