"""End-to-end resilience gates for the training layer (ISSUE 10 tentpole):

  * the non-finite guard snapshots per chunk and ROLLS BACK a chunk program
    that poisons the state — a rolled-back chunk is bitwise an identity
    chunk, and the poison never persists;
  * the zero-fault path is bitwise the pre-resilience program: wrapping the
    source in a no-op ``FaultyChunks`` and turning on retry + guard changes
    nothing (ints bitwise, floats exact);
  * the ServeModel-finiteness property: with NaN/Inf rows injected at any
    chunk, every snapshot a guarded streaming trainer publishes is finite —
    across solver x maintenance cells;
  * quarantine composes with kill-and-resume: a faulty run killed mid-epoch
    resumes bitwise the uninterrupted faulty run;
  * ``debug_invariants`` runs the I1-I3 cache validator on every accepted
    state, and the validator actually catches a corrupted cache.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers.invariants import assert_state_parity

from repro import checkpoint as ckpt
from repro.core import (BSGDConfig, ModelBank, MulticlassSVMConfig,
                        fit_multiclass_stream, fit_stream, train_chunk)
from repro.core.kernel_cache import CacheInvariantError, check_invariants
from repro.data import (ArrayChunks, FaultSchedule, FaultyChunks,
                        ResilienceReport, RetryPolicy, make_blobs,
                        make_blobs_multiclass)

CFG = BSGDConfig(budget=16, lambda_=1e-4, gamma=0.5, batch_size=4)
MCFG = MulticlassSVMConfig(n_classes=3, binary=CFG)
DIM = 6
_POLICY = RetryPolicy(max_attempts=3, base_delay_s=0.0, max_delay_s=0.0)


def _binary(n=200, seed=0):
    x, y = make_blobs(jax.random.PRNGKey(seed), n, DIM)
    return np.asarray(x), np.asarray(y)


def _multi(n=180, seed=1, classes=3):
    x, y = make_blobs_multiclass(jax.random.PRNGKey(seed), n, DIM, classes)
    return np.asarray(x), np.asarray(y)


def _poison(st):
    return jax.tree.map(
        lambda l: l * jnp.nan if jnp.issubdtype(l.dtype, jnp.inexact) else l,
        st)


def test_guard_rolls_back_poisoned_chunk():
    """A chunk program that poisons the state is rolled back wholesale: the
    guarded run equals a run where that chunk program was the identity, and
    the rollback is tallied at the chunk's stream position."""
    x, y = _binary()
    table = CFG.table()

    def make_fn(poison_at):
        calls = {"n": 0}

        def fn(st, xc, yc):
            calls["n"] += 1
            new = train_chunk(CFG, table, st, xc, yc)
            if calls["n"] == poison_at:
                new = _poison(new)
            return new
        return fn

    def make_skip_fn(skip_at):
        calls = {"n": 0}

        def fn(st, xc, yc):
            calls["n"] += 1
            if calls["n"] == skip_at:
                return st                     # identity: chunk skipped
            return train_chunk(CFG, table, st, xc, yc)
        return fn

    src = ArrayChunks(x, y, 40)
    rep = ResilienceReport()
    guarded = fit_stream(CFG, src, epochs=1, seed=7, chunk_fn=make_fn(3),
                         guard_finite=True, report=rep)
    want = fit_stream(CFG, src, epochs=1, seed=7, chunk_fn=make_skip_fn(3))
    assert len(rep.rollbacks) == 1            # exactly the poisoned chunk
    assert_state_parity(want, guarded, bitwise=True, context="rollback")
    finite = [bool(np.isfinite(np.asarray(l)).all()) for l in guarded
              if l is not None and np.issubdtype(np.asarray(l).dtype,
                                                 np.floating)]
    assert all(finite)


def test_unguarded_poison_persists():
    """The counterfactual: without the guard the same poisoned program DOES
    leave NaN in the state — the guard is doing the work."""
    x, y = _binary()
    table = CFG.table()
    calls = {"n": 0}

    def fn(st, xc, yc):
        calls["n"] += 1
        new = train_chunk(CFG, table, st, xc, yc)
        return _poison(new) if calls["n"] == 3 else new

    st = fit_stream(CFG, ArrayChunks(x, y, 40), epochs=1, seed=7, chunk_fn=fn)
    assert not np.isfinite(np.asarray(st.alpha)).all()


def test_zero_fault_path_is_bitwise_pre_resilience():
    """The full resilience stack on a clean source (empty schedule, retry,
    guard, report) is bitwise the plain run, and the report stays empty —
    the zero-fault acceptance gate of ISSUE 10."""
    x, y = _binary(n=230)
    plain = fit_stream(CFG, ArrayChunks(x, y, 37), epochs=2, seed=5)
    rep = ResilienceReport()
    armed = fit_stream(
        CFG, FaultyChunks(ArrayChunks(x, y, 37), FaultSchedule()),
        epochs=2, seed=5, retry=_POLICY, guard_finite=True, report=rep)
    assert_state_parity(plain, armed, bitwise=True, context="zero-fault")
    assert rep.as_dict() == {"retries": 0, "recovered": [], "quarantined": [],
                             "rollbacks": [], "restarts": 0}


def test_faulty_run_recovers_and_quarantines_bitwise_vs_skip():
    """Transient faults recover bitwise; a fatal chunk quarantines and the
    run equals the clean run over the surviving chunks (skip_chunks)."""
    x, y = _binary(n=230)
    faulty = FaultyChunks(
        ArrayChunks(x, y, 37),
        FaultSchedule(io_chunks=(1,), io_attempts=2, fatal_chunks=(4,)))
    rep = ResilienceReport()
    got = fit_stream(CFG, faulty, epochs=1, seed=9, retry=_POLICY, report=rep)
    want = fit_stream(CFG, ArrayChunks(x, y, 37), epochs=1, seed=9,
                      skip_chunks=(4,))
    assert rep.quarantined_chunks() == [4]
    assert rep.recovered == [(1, 2)]
    assert_state_parity(want, got, bitwise=True, context="quarantine")


def test_quarantine_composes_with_kill_and_resume(tmp_path):
    """A faulty run killed mid-epoch and resumed from its checkpoint is
    bitwise the uninterrupted faulty run — faults replay deterministically
    because the schedule is pure in (seed, chunk_id)."""
    x, y = _binary(n=230)

    def src():
        # fresh wrapper per run: attempt counters are in-process state
        return FaultyChunks(
            ArrayChunks(x, y, 37),
            FaultSchedule(io_chunks=(0, 3), io_attempts=1, fatal_chunks=(5,)))

    ref = fit_stream(CFG, src(), epochs=2, seed=5, retry=_POLICY)
    ck = os.path.join(tmp_path, "ck")
    fit_stream(CFG, src(), epochs=2, seed=5, retry=_POLICY, ckpt_dir=ck,
               ckpt_every=2, max_chunks=9)       # hard kill mid-epoch-2
    resumed = fit_stream(CFG, src(), epochs=2, seed=5, retry=_POLICY,
                         ckpt_dir=ck, ckpt_every=2)
    assert_state_parity(ref, resumed, bitwise=True, context="kill-resume")


class _RecordingBank(ModelBank):
    """Keep every published snapshot, not just the newest."""

    def __init__(self):
        super().__init__()
        self.history = []

    def publish(self, model):
        self.history.append(model)
        return super().publish(model)


_CELLS = [
    pytest.param(dict(solver="bsgd", maintenance="merge"), id="bsgd-merge"),
    pytest.param(dict(solver="bsgd", maintenance="removal",
                      use_kernel_cache=True), id="bsgd-removal-cache"),
    pytest.param(dict(solver="bdca", maintenance="merge",
                      use_kernel_cache=True), id="bdca-merge"),
]


@pytest.mark.parametrize("kw", _CELLS)
def test_nan_rows_never_reach_servemodel(kw):
    """The §16 serving property: NaN/Inf rows injected into ANY chunk — and
    a chunk program forced through them — never surface in a published
    ServeModel: every snapshot's exported leaves are finite, across
    solver x maintenance cells."""
    x, y = _multi()
    cfg = MulticlassSVMConfig.create(3, budget=16, lambda_=1e-4, gamma=0.5,
                                     batch_size=4, **kw)
    for nan_chunk in (0, 2, 4):
        bank = _RecordingBank()
        rep = ResilienceReport()
        faulty = FaultyChunks(
            ArrayChunks(x, y, 36),
            FaultSchedule(nan_chunks=(nan_chunk,), nan_rows=6))
        st = fit_multiclass_stream(cfg, faulty, epochs=1, seed=3,
                                   retry=_POLICY, guard_finite=True,
                                   bank=bank, publish_every=1, report=rep)
        assert len(bank.history) >= 5             # every chunk + final
        for m in bank.history:
            for name in ("sv_x", "alpha"):
                leaf = np.asarray(getattr(m, name), np.float32)
                assert np.isfinite(leaf).all(), \
                    f"{name} non-finite with nan_chunk={nan_chunk}"
        for leaf in (st.sv_x, st.alpha):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_debug_invariants_validates_every_accepted_state():
    """debug_invariants runs the I1-I3 checker per chunk (smoke: a clean run
    passes), and the checker itself catches a corrupted cache."""
    x, y = _binary()
    cfg = BSGDConfig(budget=16, lambda_=1e-4, gamma=0.5, batch_size=4,
                     use_kernel_cache=True)
    st = fit_stream(cfg, ArrayChunks(x, y, 40), epochs=1, seed=2,
                    guard_finite=True, debug_invariants=True)
    check_invariants(st.kmat, st.sv_x, st.count, cfg.gamma)
    bad = np.asarray(st.kmat).copy()
    bad[0, 1] += 0.25                            # break I1 and I2
    with pytest.raises(CacheInvariantError):
        check_invariants(jnp.asarray(bad), st.sv_x, st.count, cfg.gamma)
