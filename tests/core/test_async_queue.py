"""Continuous-batching serve queue + versioned model bank (ISSUE 7 gates):

  * ``AsyncBatchQueue`` labels are bitwise one direct ``predict_labels``
    call for ANY arrival pattern (randomized sizes, interleaved takes);
  * ``ModelBank`` versions are monotone, reads are atomic pairs, and the
    queue hot-swaps a newly published model without draining;
  * a warmed queue never recompiles on its first real submit (the PR 4
    jit-cache-key footgun, now a regression gate for both queues);
  * a dispatcher failure re-raises on the caller's thread — never a hang.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core import (AsyncBatchQueue, BatchQueue, BSGDConfig, ModelBank,
                        MulticlassSVMConfig, default_buckets, export_model,
                        fit_multiclass, pad_bucket, predict_labels)
from repro.data import make_blobs_multiclass

N_CLASSES, DIM = 4, 8
X, Y = make_blobs_multiclass(jax.random.PRNGKey(0), 640, DIM,
                             n_classes=N_CLASSES, sep=2.5)
X = np.asarray(X, np.float32)
CFG = MulticlassSVMConfig.create(N_CLASSES, budget=16, lambda_=1e-3,
                                 gamma=0.5, batch_size=8)
MODEL = export_model(fit_multiclass(CFG, X, np.asarray(Y), epochs=1, seed=0),
                     0.5)


def test_pad_bucket_is_the_shared_rule():
    buckets = (8, 16, 32, 64)
    assert [pad_bucket(n, buckets) for n in (1, 8, 9, 16, 33, 64, 99)] == \
        [8, 8, 16, 16, 64, 64, 64]
    assert default_buckets(64, 8) == buckets
    # both queues derive their pad targets from it
    assert BatchQueue(MODEL, max_batch=64)._bucket_for(9) == 16
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        assert q.buckets == buckets


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_async_queue_bitwise_any_arrivals(seed, watchdog):
    """Randomized ragged arrivals (incl. empty and > max_batch requests),
    interleaved takes: labels bitwise one direct call."""
    watchdog(300)
    rng = np.random.default_rng(seed)
    sizes = [int(s) for s in rng.integers(0, 97, size=24)]
    with AsyncBatchQueue(MODEL, max_batch=64, min_bucket=8) as q:
        q.warmup()
        tickets, off = [], 0
        got = {}
        for i, s in enumerate(sizes):
            tickets.append(q.submit(X[off % 512:off % 512 + s]))
            off += s
            if i % 5 == 4:                    # interleave takes mid-stream
                tk = tickets[len(got)]        # earliest not-yet-taken ticket
                got[tk] = q.take(tk, timeout=60.0)
        q.drain(timeout=60.0)
        for t in tickets:
            if t not in got:
                got[t] = q.take(t, timeout=60.0)
        versions = dict(q.stats["versions"])
    ref_rows = np.concatenate(
        [X[o % 512:o % 512 + s] for o, s in
         zip(np.cumsum([0] + sizes[:-1]), sizes)]) if sum(sizes) else \
        np.zeros((0, DIM), np.float32)
    direct = np.asarray(predict_labels(MODEL, ref_rows))
    labels = np.concatenate([got[t] for t in tickets])
    assert (labels == direct).all()
    assert not versions                       # fixed model: no bank versions


@pytest.mark.parametrize("seed", [0, 1])
def test_async_never_more_dispatches_than_sync(seed, watchdog):
    """The ISSUE 9 regression gate: for the same submit-all-then-drain
    trace, waiter-gated dispatch must coalesce at least as well as the sync
    queue — MORE microbatches would mean the async path re-introduced the
    per-row trickle that made BENCH_pipeline's async bar dip below 1x."""
    watchdog(300)
    from repro.core.predict import drive_trace, ragged_trace_sizes
    rng = np.random.default_rng(seed)
    sizes = ragged_trace_sizes(512, 64, rng)
    sync = drive_trace(MODEL, X[:512], sizes, max_batch=64, queue="sync")
    asyn = drive_trace(MODEL, X[:512], sizes, max_batch=64, queue="async")
    assert asyn["microbatches"] <= sync["microbatches"], \
        (asyn["microbatches"], sync["microbatches"])


def test_take_ungates_partial_batch(watchdog):
    """A live caller blocked in take() must not wait for a full max_batch:
    the waiter un-gates dispatch of whatever is pending."""
    watchdog(120)
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        q.warmup()
        t1 = q.submit(X[:5])                  # far below max_batch
        labels = q.take(t1, timeout=30.0)     # must dispatch, not hang
    assert (labels == np.asarray(predict_labels(MODEL, X[:5]))).all()


def test_async_queue_warmup_never_recompiles():
    """The warmed AOT-executable cache covers every bucket; real traffic
    adds no new compilations (the PR 4 static-arg cache-key footgun)."""
    with AsyncBatchQueue(MODEL, max_batch=64, min_bucket=8) as q:
        q.warmup()
        n_compiled = len(q._compiled)
        assert n_compiled == len(q.buckets)
        for s in (3, 9, 17, 64, 130):         # every bucket + wrap-around
            q.submit(X[:s])
        q.drain(timeout=60.0)
        assert len(q._compiled) == n_compiled


def test_sync_queue_warmup_never_recompiles():
    """Same gate for BatchQueue via the jit cache itself:
    ``predict_labels._cache_size()`` must not grow on first real submit."""
    q = BatchQueue(MODEL, max_batch=64, min_bucket=8)
    q.warmup()
    before = predict_labels._cache_size()
    t1 = q.submit(X[:37])
    q.drain()
    q.take(t1)
    assert predict_labels._cache_size() == before, \
        "warmed BatchQueue recompiled on its first real submit"


def test_model_bank_versioning_and_atomicity():
    bank = ModelBank()
    with pytest.raises(LookupError):
        bank.current()
    assert bank.version == 0
    with pytest.raises(TimeoutError):
        bank.wait(1, timeout=0.05)
    assert bank.publish(MODEL) == 1
    v, m = bank.current()
    assert v == 1 and m is MODEL
    # concurrent publishes: versions stay strictly monotone, reads always
    # see a consistent (version, model) pair — checked on the MAIN thread
    # over the reader's recorded (version, model) stream
    models = {v: export_model(
        fit_multiclass(CFG, X, np.asarray(Y), epochs=1, seed=v), 0.5)
        for v in range(2, 6)}
    seen, stop = [], threading.Event()

    def reader():
        while not stop.is_set():
            seen.append(bank.current())

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    for m in models.values():
        bank.publish(m)
    stop.set()
    t.join(5.0)
    assert bank.version == 5
    by_version = {1: MODEL, **models}
    last = 0
    for v, m in seen:
        assert v >= last, "version went backwards"
        assert m is by_version[v], f"torn read at version {v}"
        last = v
    # wait() returns once the version lands
    assert bank.wait(5, timeout=1.0)[0] == 5


def test_hot_swap_mid_stream_without_drain(watchdog):
    """A version published while the queue is live is picked up at the next
    microbatch — no drain, per-microbatch version consistency."""
    watchdog(300)
    model_b = export_model(
        fit_multiclass(CFG, X, np.asarray(Y), epochs=1, seed=99), 0.5)
    assert not np.array_equal(np.asarray(MODEL.alpha),
                              np.asarray(model_b.alpha))
    bank = ModelBank(MODEL)
    with AsyncBatchQueue(bank, max_batch=64) as q:
        q.warmup()
        t1 = q.submit(X[:100])
        q.drain(timeout=60.0)                 # phase 1 fully scored by v1
        bank.publish(model_b)                 # hot-swap, queue stays open
        t2 = q.submit(X[100:200])
        q.drain(timeout=60.0)
        l1, l2 = q.take(t1), q.take(t2)
        versions = dict(q.stats["versions"])
    assert (l1 == np.asarray(predict_labels(MODEL, X[:100]))).all()
    assert (l2 == np.asarray(predict_labels(model_b, X[100:200]))).all()
    assert set(versions) == {1, 2}, versions


def test_bank_queue_rejects_predict_fn():
    with pytest.raises(ValueError, match="ModelBank"):
        AsyncBatchQueue(ModelBank(MODEL), predict_fn=lambda xb: xb)


def test_dispatcher_error_surfaces_no_hang(watchdog):
    """A predict_fn that raises on the dispatcher thread fails take/drain
    and subsequent submits on the CALLER's thread — never a hang."""
    watchdog(120)

    def boom(xb):
        raise RuntimeError("device lost")

    q = AsyncBatchQueue(MODEL, max_batch=64, predict_fn=boom)
    t1 = q.submit(X[:10])
    with pytest.raises(RuntimeError, match="dispatcher failed"):
        q.drain(timeout=60.0)
    with pytest.raises(RuntimeError, match="dispatcher failed"):
        q.take(t1, timeout=60.0)
    with pytest.raises(RuntimeError, match="dispatcher failed"):
        q.submit(X[:5])
    q.close()


def test_async_queue_edge_requests(watchdog):
    watchdog(120)
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        t_empty = q.submit(X[:0])
        assert q.take(t_empty, timeout=10.0).shape == (0,)
        with pytest.raises(ValueError, match=r"\(n, dim\)"):
            q.submit(X[0])                    # 1-D row, not (n, dim)
        with pytest.raises(TimeoutError):
            q.take(999, timeout=0.05)         # unknown ticket times out
    with pytest.raises(RuntimeError, match="closed"):
        q.submit(X[:1])                       # after close()
    with pytest.raises(ValueError):
        AsyncBatchQueue(MODEL, max_batch=0)


# ---- overload protection: typed shedding, never hangs (DESIGN.md §16) ----


def test_submit_validates_rows(watchdog):
    """Malformed requests fail AT SUBMIT with a clear ValueError — never a
    shape blowup (or a silently poisoned score) inside a fused microbatch."""
    watchdog(120)
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        with pytest.raises(ValueError, match=r"\(n, dim\)"):
            q.submit(X[0])                          # 1-D
        with pytest.raises(ValueError, match="numeric"):
            q.submit(np.zeros((3, DIM), np.bool_))
        with pytest.raises(ValueError, match="numeric"):
            q.submit(np.array([["a"] * DIM]))
        with pytest.raises(ValueError, match="request dim"):
            q.submit(np.zeros((3, DIM + 1), np.float32))
        bad = X[:3].copy()
        bad[1, 2] = np.nan
        with pytest.raises(ValueError, match="non-finite"):
            q.submit(bad)
        bad[1, 2] = np.inf
        with pytest.raises(ValueError, match="non-finite"):
            q.submit(bad)
        t = q.submit(X[:3])                         # queue still healthy
        assert (q.take(t, timeout=30.0) ==
                np.asarray(predict_labels(MODEL, X[:3]))).all()
    from repro.core import BatchQueue
    bq = BatchQueue(MODEL, max_batch=64)
    with pytest.raises(ValueError, match="non-finite"):
        bq.submit(np.full((2, DIM), np.nan, np.float32))


def test_serve_timeout_is_typed_and_names_the_ticket(watchdog):
    watchdog(120)
    from repro.core import ServeTimeout
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        with pytest.raises(ServeTimeout, match="ticket 999") as ei:
            q.take(999, timeout=0.05)
        assert isinstance(ei.value, TimeoutError)   # old handlers still work
        assert "in flight" in str(ei.value)
        t = q.submit(X[:4])
        q.take(t, timeout=30.0)

    def slow(xb):
        import time as _t
        _t.sleep(0.5)
        return np.asarray(predict_labels(MODEL, xb))

    with AsyncBatchQueue(MODEL, max_batch=64, predict_fn=slow) as q:
        q.submit(X[:4])
        with pytest.raises(ServeTimeout, match="unresolved"):
            q.drain(timeout=0.05)
        q.drain(timeout=30.0)                       # still completes after


def test_queue_full_sheds_at_submit(watchdog):
    """max_pending bounds the buffer: the overflowing submit raises
    QueueFull IMMEDIATELY and leaves earlier tickets untouched."""
    watchdog(120)
    from repro.core import QueueFull
    with AsyncBatchQueue(MODEL, max_batch=64, max_pending=64) as q:
        t1 = q.submit(X[:40])                       # gate closed: stays pending
        with pytest.raises(QueueFull, match="max_pending=64"):
            q.submit(X[40:75])                      # 40 + 35 > 64
        t2 = q.submit(X[40:60])                     # 40 + 20 fits
        got1, got2 = q.take(t1, timeout=30.0), q.take(t2, timeout=30.0)
        direct = np.asarray(predict_labels(MODEL, X[:60]))
        assert (np.concatenate([got1, got2]) == direct).all()
        t3 = q.submit(X[:30])                       # buffer drained: open again
        q.take(t3, timeout=30.0)
    with pytest.raises(ValueError, match="max_pending"):
        AsyncBatchQueue(MODEL, max_batch=64, max_pending=8)


def test_deadline_sheds_undispatched_request(watchdog):
    """A request whose deadline expires before dispatch is shed: take raises
    ServeDeadline (typed, names the ticket), drain still completes, and
    surviving tickets resolve bitwise."""
    watchdog(120)
    import time as _t

    from repro.core import ServeDeadline
    with AsyncBatchQueue(MODEL, max_batch=64) as q:
        q.warmup()
        t_live = q.submit(X[:8])                    # no deadline
        t_dead = q.submit(X[8:16], deadline_s=0.01)
        _t.sleep(0.05)                              # expires while gated
        with pytest.raises(ServeDeadline, match=f"ticket {t_dead}") as ei:
            q.take(t_dead, timeout=30.0)
        assert isinstance(ei.value, TimeoutError)
        got = q.take(t_live, timeout=30.0)
        assert (got == np.asarray(predict_labels(MODEL, X[:8]))).all()
        q.drain(timeout=30.0)                       # shed rows never wedge it
        # a generous deadline is a no-op: the request resolves normally
        t_ok = q.submit(X[:16], deadline_s=60.0)
        assert (q.take(t_ok, timeout=30.0) ==
                np.asarray(predict_labels(MODEL, X[:16]))).all()
