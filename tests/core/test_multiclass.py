"""Multi-class OVR engine: vmap loop-parity, learning, fused margin path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BSGDConfig, MulticlassSVMConfig, STRATEGIES,
                        accuracy_multiclass, decision_function,
                        decision_function_multiclass, fit_multiclass,
                        fit_multiclass_loop, init_multiclass_state, init_state,
                        ovr_targets, predict_multiclass, train_step,
                        train_step_multiclass)
from repro.data import make_blobs_multiclass, train_test_split


def _stacked_binary_problems(key, c, n, dim):
    """C independent binary problems (distinct x AND y per stack entry)."""
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (c, n, dim))
    y = jnp.where(jax.random.bernoulli(ky, 0.5, (c, n)), 1.0, -1.0)
    return x, y


def _run_vmap_vs_loop(cfg, c=3, n=60, dim=5):
    table = cfg.table()
    x, y = _stacked_binary_problems(jax.random.PRNGKey(0), c, n, dim)
    st_v = jax.vmap(lambda _: init_state(cfg, dim))(jnp.arange(c))
    st_l = [jax.tree.map(lambda a: a[q], st_v) for q in range(c)]
    step = lambda st, xb, yb: train_step(cfg, table, st, xb, yb, impl="ref")
    vstep = jax.vmap(step)
    bs = cfg.batch_size
    for i in range(0, n, bs):
        st_v = vstep(st_v, x[:, i:i + bs], y[:, i:i + bs])
        for q in range(c):
            st_l[q] = step(st_l[q], x[q, i:i + bs], y[q, i:i + bs])
    return st_v, st_l


@pytest.mark.parametrize("use_cache", [False, True])
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_vmap_train_step_matches_per_class_loop(strategy, use_cache):
    """vmap(train_step) over stacked binary problems == looping train_step
    per class — every strategy x both cache modes.

    Uses ``unroll_maintenance=True``: XLA compiles a ``lax.while_loop`` body
    with batch-width-dependent FMA contraction, so the while-mode vmap
    drifts ~1 ULP per maintenance event; the statically inlined events are
    the vmap-exact path (core.budget.run_maintenance).  Without the kernel
    cache the match is BITWISE for every strategy.  The cache path's
    score -> z_row chain still leaves XLA one width-dependent contraction
    choice (measured <= 4e-7 absolute on CPU), so there the maintenance
    *decisions* (all integer state: counts, inserts, events) must be bitwise
    and the float state within fp32 round-off — tight enough that any real
    divergence (a different merge partner, a dropped event) fails loudly.
    """
    if strategy in ("removal-project", "quantized") and not use_cache:
        # not a valid cell: projection/absorption reads cached kernel rows —
        # pin the config validation instead of skipping
        with pytest.raises(ValueError, match=strategy):
            BSGDConfig(budget=12, maintenance=strategy,
                       use_kernel_cache=False)
        return
    cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=0.5, method="lookup-wd",
                     batch_size=4, use_kernel_cache=use_cache,
                     maintenance=strategy, unroll_maintenance=True)
    st_v, st_l = _run_vmap_vs_loop(cfg)
    assert int(jnp.sum(st_v.n_merges)) > 0      # the budget actually bit
    for q, st_q in enumerate(st_l):
        for name, a, b in zip(st_v._fields, st_v, st_q):
            if a is None:
                continue
            a, b = np.asarray(a[q]), np.asarray(b)
            if not use_cache or not np.issubdtype(a.dtype, np.floating):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{name} differs for stacked problem {q}")
            else:
                np.testing.assert_allclose(
                    a, b, rtol=1e-5, atol=2e-6,
                    err_msg=f"{name} drifts beyond fp32 round-off for "
                            f"stacked problem {q}")


@pytest.mark.parametrize("use_cache", [False, True])
def test_vmap_while_loop_mode_matches_to_fp_noise(use_cache):
    """The default while_loop maintenance under vmap makes identical merge
    DECISIONS (counts/merge totals bitwise) and drifts only by XLA's
    while-body FMA-contraction noise in the float state."""
    cfg = BSGDConfig(budget=12, lambda_=1e-3, gamma=0.5, method="lookup-wd",
                     batch_size=4, use_kernel_cache=use_cache)
    st_v, st_l = _run_vmap_vs_loop(cfg)
    for q, st_q in enumerate(st_l):
        for name in ("count", "step", "n_inserts", "n_merges"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_v, name)[q]),
                np.asarray(getattr(st_q, name)), err_msg=name)
        np.testing.assert_allclose(np.asarray(st_v.alpha[q]),
                                   np.asarray(st_q.alpha), atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_v.sv_x[q]),
                                   np.asarray(st_q.sv_x), atol=1e-5)


def test_ovr_targets():
    y = jnp.asarray([0, 2, 1, 2])
    t = ovr_targets(y, 3)
    want = np.asarray([[1, -1, -1, -1], [-1, -1, 1, -1], [-1, 1, -1, 1]],
                      np.float32)
    np.testing.assert_array_equal(np.asarray(t), want)
    assert t.dtype == jnp.float32


def test_fit_multiclass_matches_loop_baseline_bitwise():
    """The lockstep engine trains the SAME model as C sequential binary fits
    (same seed => same permutations; unrolled maintenance => bitwise)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(3), 400, 6, 4, sep=1.2)
    cfg = MulticlassSVMConfig.create(4, budget=20, lambda_=1e-3, gamma=0.2,
                                     method="lookup-wd", batch_size=4,
                                     unroll_maintenance=True)
    st_b = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    st_l = fit_multiclass_loop(cfg, x, y, epochs=1, seed=0)
    assert int(jnp.sum(st_b.n_merges)) > 0
    for name, a, b in zip(st_b._fields, st_b, st_l):
        if a is None:
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_multiclass_learns_blobs_one_pass():
    """>= 4 classes to >= 90% test accuracy in ONE pass with the budget
    biting (the examples/svm_multiclass.py acceptance, in miniature)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 2500, 12, 5, sep=1.2)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = MulticlassSVMConfig.create(5, budget=24, lambda_=1e-4, gamma=0.05,
                                     method="lookup-wd", batch_size=4)
    st = fit_multiclass(cfg, xtr, ytr, epochs=1, seed=0)
    acc = float(accuracy_multiclass(st, xte, yte, cfg.binary.gamma))
    assert acc >= 0.9, acc
    assert np.all(np.asarray(st.count) <= cfg.binary.budget)
    assert int(jnp.sum(st.n_merges)) > 0
    pred = predict_multiclass(st, xte, cfg.binary.gamma)
    assert pred.dtype == jnp.int32
    assert set(np.unique(np.asarray(pred))) <= set(range(5))


def test_fused_decision_function_matches_per_class():
    """decision_function_multiclass (one fused rbf call) == C separate
    binary decision_function calls on the per-class slices."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(1), 600, 8, 4, sep=1.5)
    cfg = MulticlassSVMConfig.create(4, budget=24, lambda_=1e-4, gamma=0.1,
                                     method="lookup-wd", batch_size=4)
    st = fit_multiclass(cfg, x, y, epochs=1, seed=0)
    scores = decision_function_multiclass(st, x[:50], cfg.binary.gamma)
    for c in range(4):
        st_c = jax.tree.map(lambda a: a[c], st)
        f_c = decision_function(st_c, x[:50], cfg.binary.gamma)
        np.testing.assert_allclose(np.asarray(scores[c]), np.asarray(f_c),
                                   rtol=1e-5, atol=1e-5)


def test_multiclass_config_validation():
    with pytest.raises(ValueError):
        MulticlassSVMConfig.create(1, budget=10)


def test_multiclass_state_shapes():
    cfg = MulticlassSVMConfig.create(6, budget=10, batch_size=3)
    st = init_multiclass_state(cfg, 7)
    assert st.sv_x.shape == (6, 13, 7)
    assert st.alpha.shape == (6, 13)
    assert st.count.shape == (6,)
    out = train_step_multiclass(cfg, cfg.table(), st,
                                jnp.ones((3, 7)), jnp.asarray([0, 5, 2]))
    assert out.sv_x.shape == st.sv_x.shape
    assert int(jnp.sum(out.n_inserts)) > 0
