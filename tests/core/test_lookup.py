"""Lookup tables: precompute accuracy, interpolation, persistence."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MergeLookupTable, merge_math
from repro.core.lookup import bilinear_lookup, build_lookup_table


def test_bilinear_exact_at_grid_nodes():
    tbl = jnp.arange(25.0).reshape(5, 5)
    g = jnp.linspace(0, 1, 5)
    for i in range(5):
        for j in range(5):
            assert float(bilinear_lookup(tbl, g[i], g[j])) == float(tbl[i, j])


def test_bilinear_linear_function_is_exact():
    f = lambda u, v: 2.0 * u - 3.0 * v + 0.5
    tbl = build_lookup_table(f, grid_size=11)
    rng = np.random.default_rng(0)
    u, v = rng.uniform(0, 1, (2, 100)).astype(np.float32)
    got = bilinear_lookup(tbl, jnp.asarray(u), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), f(u, v), rtol=2e-5, atol=2e-5)


def test_table_matches_precise_gss_off_grid():
    """Paper §4: lookup at 400x400 is *more* precise than eps=.01 GSS."""
    tbl = MergeLookupTable.create()
    rng = np.random.default_rng(1)
    m = rng.uniform(0.05, 0.95, 500)
    k = rng.uniform(np.exp(-2) + 0.02, 0.995, 500)
    h_ref = merge_math.gss_numpy(m, k)
    wd_ref = np.asarray(merge_math.wd_norm_at(
        jnp.asarray(h_ref, jnp.float32), jnp.asarray(m, jnp.float32),
        jnp.asarray(k, jnp.float32)))
    wd_tbl = np.asarray(tbl.lookup_wd_norm(jnp.asarray(m, jnp.float32),
                                           jnp.asarray(k, jnp.float32)))
    assert np.max(np.abs(wd_tbl - wd_ref)) < 2e-5

    # and indeed tighter than the eps=.01 runtime GSS the paper replaces:
    h_std = np.asarray(merge_math.golden_section_search(
        jnp.asarray(m, jnp.float32), jnp.asarray(k, jnp.float32), eps=1e-2))
    wd_std = np.asarray(merge_math.wd_norm_at(
        jnp.asarray(h_std), jnp.asarray(m, jnp.float32), jnp.asarray(k, jnp.float32)))
    assert np.mean(np.abs(wd_tbl - wd_ref)) <= np.mean(np.abs(wd_std - wd_ref))


def test_boundary_columns_analytic():
    tbl = MergeLookupTable.create(grid_size=101)
    g = np.linspace(0, 1, 101)
    np.testing.assert_allclose(np.asarray(tbl.h_table[:, -1]), g, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tbl.wd_table[:, -1]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tbl.wd_table[:, 0]),
                               np.minimum(g, 1 - g) ** 2, atol=1e-6)


def test_save_load_roundtrip(tmp_path):
    tbl = MergeLookupTable.create(grid_size=64)
    path = os.path.join(tmp_path, "tables.npz")
    tbl.save(path)
    tbl2 = MergeLookupTable.load(path)
    np.testing.assert_array_equal(np.asarray(tbl.h_table), np.asarray(tbl2.h_table))
    np.testing.assert_array_equal(np.asarray(tbl.wd_table), np.asarray(tbl2.wd_table))


def test_default_table_cache_keyed_by_build_params(tmp_path):
    """The process cache must key on every build parameter, not just
    grid_size — a later call with different eps/dtype used to get a stale
    table built with someone else's settings."""
    from repro.core.lookup import default_table

    a = default_table(64)
    assert default_table(64) is a                         # hit: same params
    b16 = default_table(64, dtype=jnp.bfloat16)
    assert b16 is not a
    assert b16.h_table.dtype == jnp.bfloat16
    assert default_table(64).h_table.dtype == jnp.float32  # fp32 not clobbered
    loose = default_table(64, eps=1e-3)
    assert loose is not a
    assert default_table(64, eps=1e-3) is loose           # its own cache line

    # a cached table survives a save/load round trip unchanged
    path = os.path.join(tmp_path, "default.npz")
    a.save(path)
    back = MergeLookupTable.load(path)
    np.testing.assert_array_equal(np.asarray(a.h_table), np.asarray(back.h_table))
    np.testing.assert_array_equal(np.asarray(a.wd_table), np.asarray(back.wd_table))


def test_table_threads_through_jit():
    tbl = MergeLookupTable.create(grid_size=64)

    @jax.jit
    def f(t: MergeLookupTable, m, k):
        return t.lookup_wd_norm(m, k)

    out = f(tbl, jnp.float32(0.4), jnp.float32(0.8))
    assert jnp.isfinite(out)
