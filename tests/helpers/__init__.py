"""Shared test helpers (importable because ``tests/conftest.py`` puts the
tests directory on ``sys.path``): the cross-solver invariant checkers
(``helpers.invariants``) and the hypothesis compatibility layer
(``helpers.hypothesis_compat``)."""
