"""Hypothesis when installed, a deterministic fallback exerciser otherwise.

The property modules used to open with ``pytest.importorskip("hypothesis")``
— correct in CI (the ``test`` extra installs hypothesis) but a standing SKIP
in minimal environments, which meant the properties were silently untested
exactly where developers run tier-1 most.  This shim keeps one import line::

    from helpers.hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

With hypothesis importable, the real ``given``/``settings``/``strategies``
are re-exported unchanged (CI asserts tier-1 reports 0 hypothesis-skips and
runs with the real engine).  Without it, a minimal deterministic stand-in
runs the test body over seeded random draws covering the strategy subset the
suite uses (``floats``/``integers``/``lists``/``sampled_from``/
``booleans``).  No shrinking, no database, no adaptive search — just "the
property holds on N seeded draws", which is strictly more coverage than a
skip.  The draw seed is derived from the test function's name, so failures
reproduce exactly.
"""
from __future__ import annotations

import zlib

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX = 10      # examples per test without the adaptive engine

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors ``hypothesis.strategies as st``
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(**kw):
        def deco(fn):
            fn._compat_settings = kw
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            cfg = getattr(fn, "_compat_settings", {})
            n = min(int(cfg.get("max_examples", _FALLBACK_MAX)),
                    _FALLBACK_MAX)

            def runner():
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**draws)

            # copy identity WITHOUT functools.wraps: wraps sets
            # ``__wrapped__`` and pytest would then see the original
            # signature and demand the drawn parameters as fixtures
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
