"""Cross-solver invariant checkers shared across the core test suite.

One home for the assertions that used to be duplicated inline in
``test_kernel_cache.py`` / ``test_event_engine.py`` / ``test_step_engine.py``
and that the cross-solver harness (``test_solver_invariants.py``) now runs
for every (solver × maintenance × engine × C) cell.  Every checker takes a
trained ``SVMState`` — binary (2-D ``sv_x``) or stacked multiclass (leading
class axis) — and must hold regardless of which solver produced it; that IS
the §14 solver contract, enforced.
"""
import jax.numpy as jnp
import numpy as np


def exact_gram(sv_x, count, gamma):
    """Ground-truth Gram block: k(sv, sv) rebuilt from scratch (fp32)."""
    from repro.kernels import ref

    x = np.asarray(sv_x, np.float32)[:count]
    return np.asarray(ref.rbf_matrix(jnp.asarray(x), jnp.asarray(x), gamma))


def check_cache_invariants(state, gamma, tol=5e-5):
    """Kernel-cache I1-I3 on a trained state: the carried cache equals a
    from-scratch rebuild on the final SV set (I1, within carried-fp ``tol``),
    is exactly symmetric (I2) and has an exactly-unit diagonal (I3).  Stacked
    states are checked per class."""
    if state.sv_x.ndim == 3:                     # stacked multiclass state
        for q in range(state.sv_x.shape[0]):
            check_cache_invariants(
                state._replace(sv_x=state.sv_x[q], alpha=state.alpha[q],
                               count=state.count[q], step=state.step[q],
                               n_inserts=state.n_inserts[q],
                               n_merges=state.n_merges[q],
                               kmat=state.kmat[q]), gamma, tol)
        return
    c = int(state.count)
    got = np.asarray(state.kmat)[:c, :c]
    want = exact_gram(state.sv_x, c, gamma)
    np.testing.assert_allclose(got, want, atol=tol)
    # I2/I3: exact symmetry, unit diagonal
    np.testing.assert_array_equal(got, got.T)
    np.testing.assert_array_equal(np.diag(got), np.ones(c, np.float32))


def assert_state_parity(st_a, st_b, *, atol_cache=5e-5, atol_float=2e-6,
                        rtol=1e-5, bitwise=False, context=""):
    """Two states agree field by field: ints BITWISE (every insert and
    merge-partner/removal decision identical), floats inside fp32 round-off
    (``bitwise=True`` demands exact float equality too).  bfloat16 leaves
    compare as fp32."""
    tag = f"{context}: " if context else ""
    for name, a, b in zip(st_a._fields, st_a, st_b):
        if a is None:
            assert b is None, f"{tag}{name}"
            continue
        a = np.asarray(a, np.float32) if a.dtype == jnp.bfloat16 \
            else np.asarray(a)
        b = np.asarray(b, np.float32) if b.dtype == jnp.bfloat16 \
            else np.asarray(b)
        if bitwise or np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(
                a, b, err_msg=f"{tag}{name} decision drift")
        else:
            atol = atol_cache if name == "kmat" else atol_float
            np.testing.assert_allclose(
                a, b, rtol=rtol, atol=atol,
                err_msg=f"{tag}{name} beyond fp round-off")


def check_integer_state(state, budget):
    """Watermark/counter consistency on a trained state (binary or stacked):
    ``0 <= count <= budget``, alpha exactly zero past the watermark (the
    invariant ``init_state`` establishes and every step must preserve),
    non-negative monotone event counters, and a NaN/Inf-free cache."""
    count = np.atleast_1d(np.asarray(state.count))
    alpha = np.asarray(state.alpha)
    if alpha.ndim == 1:
        alpha = alpha[None]
    assert np.all(count >= 0) and np.all(count <= budget), count
    mask = np.arange(alpha.shape[-1])[None, :] >= count[:, None]
    np.testing.assert_array_equal(alpha[mask], 0.0,
                                  err_msg="alpha past watermark not zero")
    for name in ("step", "n_inserts", "n_merges"):
        v = np.asarray(getattr(state, name))
        assert v.dtype == np.int32 and np.all(v >= 0), name
    assert np.all(np.asarray(state.step) >= 1), "step starts at 1"
    if state.kmat is not None:
        assert np.all(np.isfinite(np.asarray(state.kmat))), "cache not finite"


def assert_serve_roundtrip(state, gamma, x, tol=1e-6):
    """``export_model`` round-trips: the served labels/scores equal the
    training-side decision functions on the same points, for binary and
    stacked states alike (the serving path never asks which solver trained
    the state)."""
    from repro.core import export_model, predict_labels, serve_scores
    from repro.core.bsgd import decision_function, predict
    from repro.core.multiclass import (decision_function_multiclass,
                                       predict_multiclass)

    model = export_model(state, gamma)
    got = np.asarray(predict_labels(model, x))
    scores = np.asarray(serve_scores(model, x))
    if state.sv_x.ndim == 2:
        np.testing.assert_array_equal(got, np.asarray(predict(state, x, gamma)))
        np.testing.assert_allclose(
            scores[0], np.asarray(decision_function(state, x, gamma)),
            atol=tol, rtol=tol)
    else:
        np.testing.assert_array_equal(
            got, np.asarray(predict_multiclass(state, x, gamma)))
        np.testing.assert_allclose(
            scores, np.asarray(decision_function_multiclass(state, x, gamma)),
            atol=tol, rtol=tol)
