"""Optimizer, schedules, gradient compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.train.grad_compress import compress_tree, decompress_tree
from repro.train.optimizer import AdamW, SGD, cosine_schedule, global_norm


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(120):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_sgd_momentum_minimizes():
    opt = SGD(lr=0.02)  # heavy-ball on x^2 oscillates at high lr
    params = {"w": jnp.asarray([2.0])}
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params)
    assert abs(float(params["w"][0])) < 5e-2


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _ = opt.update(huge, state, params)
    # clipped grad norm <= 1 -> first adam step magnitude <= lr
    assert float(jnp.max(jnp.abs(new_params["w"]))) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) <= 0.1 + 1e-6
    assert float(lr(55)) < float(lr(20))


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


def test_int8_compression_roundtrip_error_and_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    payload, resid = compress_tree(g, None)
    decoded = decompress_tree(payload)
    scale = float(payload["scale"]["w"])
    # quantization error bounded by half a bucket
    assert float(jnp.max(jnp.abs(decoded["w"] - g["w"]))) <= 0.5 * scale + 1e-7
    # error feedback: residual holds exactly the rounding error
    np.testing.assert_allclose(np.asarray(resid["w"]),
                               np.asarray(g["w"] - decoded["w"]), atol=1e-7)
    # feeding the residual back makes the two-step mean nearly exact
    payload2, _ = compress_tree(g, resid)
    decoded2 = decompress_tree(payload2)
    two_step = (decoded["w"] + decoded2["w"]) / 2.0
    assert float(jnp.max(jnp.abs(two_step - g["w"]))) <= 0.3 * scale + 1e-7


# ------------------------------------------------------------ checkpointing
def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"m": jnp.ones((2, 3)), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 10, t)
    assert ckpt.latest_step(d) == 10
    loaded = ckpt.load(d, 10, jax.tree.map(jnp.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 t, loaded)


def test_checkpoint_keep_last(tmp_path):
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, _tree(), keep_last=2)
    assert ckpt.all_steps(d) == [4, 5]


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, _tree())
    # simulate a crashed mid-write attempt
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt.latest_step(d) == 3


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    bad = {"params": {"w": jnp.zeros((3, 3))},
           "opt": {"m": jnp.ones((2, 3)), "step": jnp.int32(0)}}
    try:
        ckpt.load(d, 1, bad)
        assert False, "should raise"
    except ValueError:
        pass


def test_restore_latest_none(tmp_path):
    step, tree = ckpt.restore_latest(str(tmp_path / "nope"), _tree())
    assert step is None and tree is None


def test_save_async(tmp_path):
    d = str(tmp_path / "ck")
    t = ckpt.save_async(d, 2, _tree())
    t.join(timeout=30)
    assert ckpt.latest_step(d) == 2
