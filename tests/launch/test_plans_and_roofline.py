"""Launch-layer structural tests: cell plans lower+compile on a small mesh
(subprocess with 8 host devices — the cheap rehearsal of the 512-dev dryrun),
and the roofline HLO parsers on synthetic text."""

from repro.launch import roofline as rl



def test_every_family_lowers_and_compiles_every_step_kind(run_py):
    """One arch per family x {train, prefill, decode} on a 2x4 mesh with
    reduced configs — catches sharding-plan bugs without 512-dev compiles."""
    out = run_py(r"""
import dataclasses
import jax
from repro.configs import get_smoke, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch.steps import plan_cell
from repro.launch import inputs as inp

mesh = make_mesh((2, 4), ("data", "model"))
ARCHS = ["smollm_360m", "mamba2_130m", "jamba_v01_52b", "deepseek_v2_236b",
         "hubert_xlarge"]
# shrink the assignment shapes so compiles are fast
small_shapes = {
    "train_4k": dict(seq_len=32, global_batch=8, step="train"),
    "prefill_32k": dict(seq_len=64, global_batch=8, step="prefill"),
    "decode_32k": dict(seq_len=64, global_batch=8, step="decode"),
}
import repro.configs.registry as reg
import repro.launch.inputs as inputs_mod
reg.SHAPES.update(small_shapes)

from repro.launch.steps import lower_cell
for arch in ARCHS:
    cfg = get_smoke(arch)
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        if cfg.is_encoder and shape == "decode_32k":
            continue
        lowered, plan = lower_cell(cfg, shape, mesh, strategy="tp")
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # jax < 0.5 wrapped the dict in a list
            cost = cost[0]
        assert cost.get("flops", 0) > 0 or shape == "decode_32k"
        print("OK", arch, shape, int(cost.get("flops", 0)))
print("ALL OK")
""")
    assert "ALL OK" in out


SAMPLE_HLO = """
HloModule test
ENTRY %main (p0: bf16[16,128]) -> bf16[16,128] {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[16,2048]{1,0} all-gather(%p0), replica_groups={}
  %ar = bf16[16,128]{1,0} all-reduce(%p0), to_apply=%add
  %ars = bf16[16,128]{1,0} all-reduce-start(%p0), to_apply=%add
  %ard = bf16[16,128]{1,0} all-reduce-done(%ars)
  %rs = bf16[2,128]{1,0} reduce-scatter(%p0), dimensions={0}
  %a2a = bf16[16,128]{1,0} all-to-all(%p0), dimensions={0}
  %cp = bf16[16,128]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %dot.1 = f32[16,16]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  ROOT %r = bf16[16,128]{1,0} copy(%p0)
}
"""


def test_collective_bytes_parser():
    out = rl.collective_bytes(SAMPLE_HLO)
    assert out["all-gather"] == 16 * 2048 * 2
    # plain all-reduce + the -start of the async pair; -done NOT double counted
    assert out["all-reduce"] == 2 * 16 * 128 * 2
    assert out["reduce-scatter"] == 2 * 128 * 2
    assert out["all-to-all"] == 16 * 128 * 2
    assert out["collective-permute"] == 16 * 128 * 2


def test_fused_bytes_counts_dot_traffic():
    got = rl.fused_bytes(SAMPLE_HLO, arg_bytes=100.0, out_bytes=50.0)
    # dot: result f32[16,16] + two reads of bf16[16,128]
    assert got == 100.0 + 50.0 + 16 * 16 * 4 + 2 * 16 * 128 * 2


def test_roofline_terms_and_dominance():
    rec = rl.Roofline(
        arch="a", shape="s", mesh="16x16", strategy="tp", n_devices=256,
        flops_per_dev=1.97e12, bytes_per_dev=819e9 / 2,
        bytes_per_dev_raw=1e12, coll_bytes_per_dev=50e9 * 2,
        coll_breakdown={}, peak_mem_per_dev=0.0, arg_bytes_per_dev=1e9,
        act_bytes_est=1e9, model_flops_global=1.97e12 * 256 / 2).finalize()
    assert abs(rec.compute_s - 0.01) < 1e-9
    assert abs(rec.memory_s - 0.5) < 1e-9
    assert abs(rec.collective_s - 2.0) < 1e-9
    assert rec.dominant == "collective"
    assert abs(rec.useful_ratio - 0.5) < 1e-9
    assert rec.fits_hbm
    assert abs(rec.roofline_frac - 0.005 / 2.0) < 1e-9


def test_model_flops_bookkeeping():
    from repro.configs import SHAPES, get
    cfg = get("smollm_360m")
    f_train = rl.model_flops(cfg, "train_4k", SHAPES)
    assert abs(f_train - 6 * cfg.active_param_count() * 256 * 4096) < 1e6
    f_dec = rl.model_flops(cfg, "decode_32k", SHAPES)
    assert abs(f_dec - 2 * cfg.active_param_count() * 128) < 1e6
    # MoE: active < total
    v3 = get("deepseek_v3_671b")
    assert v3.active_param_count() < 0.1 * v3.param_count()
