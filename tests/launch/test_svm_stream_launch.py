"""Streaming SVM through the launch layer: the pjit'd chunk program lowers
on a multi-device mesh and ``svm_stream_loop`` reproduces the single-device
streamed trainer (subprocess with forced host devices, cf. test_svm_class_layout)."""


def test_chunk_cell_lowers_replicated_and_class(run_py):
    """make_distributed_chunk_step lowers + compiles for both layouts
    (reduced sizes; the production sizing is dryrun-only)."""
    out = run_py(r"""
import jax
from repro.core.distributed import lower_svm_cell, make_distributed_chunk_step
from repro.core import BSGDConfig, MulticlassSVMConfig
from repro.launch.inputs import svm_chunk_specs
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
for layout in ("replicated", "class"):
    lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                                  layout=layout, n_classes=8, stream_steps=4)
    mem = lowered.compile().memory_analysis()
    assert mem.argument_size_in_bytes > 0
    # inputs.svm_chunk_specs must agree with the chunk program's abstract args
    b = cfg.binary if layout == "class" else cfg
    _, cargs, _, _ = make_distributed_chunk_step(cfg, mesh, 32, 4,
                                                 cfg.table(), layout=layout)
    spec = svm_chunk_specs(32, 4, b.batch_size,
                           n_classes=cfg.n_classes if layout == "class" else None,
                           x_dtype=b.sv_dtype or b.dtype, y_dtype=b.dtype)
    for got, want in ((cargs[2], spec["xc"]), (cargs[3], spec["yc"])):
        assert got.shape == want.shape and got.dtype == want.dtype, (got, want)
    print("OK", layout)
""")
    assert "OK replicated" in out and "OK class" in out


def test_svm_stream_loop_matches_single_device(run_py):
    """svm_stream_loop on an 8-device mesh == single-device fit_stream on the
    same source/seed (binary), and the class layout trains per-class models."""
    out = run_py(r"""
import numpy as np, jax, tempfile, os
from repro.data import make_blobs, make_blobs_multiclass, write_npz_chunks
from repro.data.stream import FileChunks
from repro.launch.train import svm_stream_loop
from repro.core import BSGDConfig, fit_stream

x, y = map(np.asarray, make_blobs(jax.random.PRNGKey(0), 256, 8))
with tempfile.TemporaryDirectory() as d:
    src = FileChunks(write_npz_chunks(d, x, y, 64))
    st, cfg = svm_stream_loop(src, budget=16, batch_size=8, gamma=0.5,
                              epochs=1, seed=2, verbose=False)
    ref = fit_stream(BSGDConfig(budget=16, batch_size=8, gamma=0.5), src,
                     epochs=1, seed=2)
    for name, a, b in zip(ref._fields, ref, st):
        if a is not None:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, err_msg=name)
print("OK binary")

xm, ym = map(np.asarray, make_blobs_multiclass(jax.random.PRNGKey(1), 192, 8, 4))
with tempfile.TemporaryDirectory() as d:
    src = FileChunks(write_npz_chunks(d, xm, ym, 48))
    st, cfg = svm_stream_loop(src, layout="class", n_classes=4, budget=12,
                              batch_size=8, gamma=0.3, epochs=1, verbose=False)
    assert np.asarray(st.count).shape == (4,)
    assert (np.asarray(st.count) > 0).all()
print("OK class")
""")
    assert "OK binary" in out and "OK class" in out
