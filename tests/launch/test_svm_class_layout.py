"""Multi-class SVM cell on the production mesh: ``layout="class"`` lowers,
compiles, and reproduces the single-device lockstep step (8 host devices)."""


def test_lower_svm_cell_class_layout(run_py):
    """lower_svm_cell lowers + compiles the multi-class cell with classes
    sharded over `model` (reduced sizes; the 512-dev sizing is dryrun-only)."""
    out = run_py(r"""
from repro.core.distributed import lower_svm_cell
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                              layout="class", n_classes=8)
assert cfg.n_classes == 8
compiled = lowered.compile()
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
print("OK class cell", mem.argument_size_in_bytes)
""")
    assert "OK class cell" in out


def test_lower_svm_cell_class_layout_event_engine(run_py):
    """The fused maintenance-event engine (sorted-excess schedule over the
    class-sharded state + kernel cache) lowers and compiles on the mesh."""
    out = run_py(r"""
from repro.core.distributed import lower_svm_cell
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                              layout="class", n_classes=8,
                              maintenance_engine="pallas")
assert cfg.binary.maintenance_engine == "pallas"
assert cfg.binary.use_kernel_cache
compiled = lowered.compile()
print("OK engine cell", compiled.memory_analysis().argument_size_in_bytes)
""")
    assert "OK engine cell" in out


def test_lower_svm_cell_class_layout_fused_step(run_py):
    """The fused train-step megakernel cell (``step_engine="pallas"``,
    DESIGN.md §12) lowers and compiles with classes sharded over `model`."""
    out = run_py(r"""
from repro.core.distributed import lower_svm_cell
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                              layout="class", n_classes=8,
                              step_engine="pallas")
assert cfg.binary.step_engine == "pallas"
assert cfg.binary.use_kernel_cache
compiled = lowered.compile()
print("OK fused-step cell", compiled.memory_analysis().argument_size_in_bytes)
""")
    assert "OK fused-step cell" in out


def test_distributed_class_step_fused_engine_matches_single_device(run_py):
    """The pjit'd class-layout step with the fused train-step engine == the
    single-device composed step, with maintenance actually firing."""
    out = run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (BSGDConfig, MulticlassSVMConfig, init_multiclass_state,
                        train_step_multiclass)
from repro.core.distributed import make_distributed_step
from repro.launch.mesh import make_mesh
from repro.data import make_blobs_multiclass

cfg_c = MulticlassSVMConfig(4, BSGDConfig(budget=8, lambda_=1e-3, gamma=0.5,
                                          method="lookup-wd", batch_size=16,
                                          use_kernel_cache=True))
cfg_f = MulticlassSVMConfig(4, BSGDConfig(budget=8, lambda_=1e-3, gamma=0.5,
                                          method="lookup-wd", batch_size=16,
                                          use_kernel_cache=True,
                                          step_engine="pallas"))
table = cfg_c.table()
x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 64, 8, 4, sep=1.0)
state = init_multiclass_state(cfg_c, 8)
for i in range(0, 32, 16):   # warm the model so maintenance fires
    state = train_step_multiclass(cfg_c, table, state, x[i:i+16], y[i:i+16],
                                  impl="ref")
ref = train_step_multiclass(cfg_c, table, state, x[32:48], y[32:48],
                            impl="ref")
assert int(jnp.sum(ref.n_merges)) > 0, "budget never bit"

mesh = make_mesh((2, 4), ("data", "model"))
step, args, in_sh, out_sh = make_distributed_step(cfg_f, mesh, 8, table,
                                                  layout="class")
with mesh:
    out = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)(
        state, table, x[32:48], y[32:48])
for name in ("count", "step", "n_inserts", "n_merges"):
    assert np.array_equal(np.asarray(getattr(out, name)),
                          np.asarray(getattr(ref, name))), name
err = float(jnp.max(jnp.abs(out.alpha - ref.alpha)))
assert err < 1e-4, err
print("OK fused-step parity", err, int(jnp.sum(out.n_merges)))
""")
    assert "OK fused-step parity" in out


def test_distributed_class_step_event_engine_matches_single_device(run_py):
    """The pjit'd class-layout step with the fused event engine == the
    single-device lockstep step, with maintenance actually firing."""
    out = run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (BSGDConfig, MulticlassSVMConfig, init_multiclass_state,
                        train_step_multiclass)
from repro.core.distributed import make_distributed_step
from repro.launch.mesh import make_mesh
from repro.data import make_blobs_multiclass

cfg = MulticlassSVMConfig(4, BSGDConfig(budget=8, lambda_=1e-3, gamma=0.5,
                                        method="lookup-wd", batch_size=16,
                                        use_kernel_cache=True,
                                        maintenance_engine="pallas"))
table = cfg.table()
x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 64, 8, 4, sep=1.0)
state = init_multiclass_state(cfg, 8)
for i in range(0, 32, 16):   # warm the model so maintenance fires
    state = train_step_multiclass(cfg, table, state, x[i:i+16], y[i:i+16],
                                  impl="ref")
ref = train_step_multiclass(cfg, table, state, x[32:48], y[32:48], impl="ref")
assert int(jnp.sum(ref.n_merges)) > 0, "budget never bit"

mesh = make_mesh((2, 4), ("data", "model"))
step, args, in_sh, out_sh = make_distributed_step(cfg, mesh, 8, table,
                                                  layout="class")
with mesh:
    out = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)(
        state, table, x[32:48], y[32:48])
for name in ("count", "n_inserts", "n_merges"):
    assert np.array_equal(np.asarray(getattr(out, name)),
                          np.asarray(getattr(ref, name))), name
err = float(jnp.max(jnp.abs(out.alpha - ref.alpha)))
assert err < 1e-4, err
print("OK engine parity", err, int(jnp.sum(out.n_merges)))
""")
    assert "OK engine parity" in out


def test_distributed_class_step_matches_single_device(run_py):
    """The pjit'd class-layout step == the single-device lockstep step."""
    out = run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import (BSGDConfig, MulticlassSVMConfig, init_multiclass_state,
                        train_step_multiclass)
from repro.core.distributed import make_distributed_step
from repro.launch.mesh import make_mesh
from repro.data import make_blobs_multiclass

cfg = MulticlassSVMConfig(4, BSGDConfig(budget=32, lambda_=1e-4, gamma=0.5,
                                        method="lookup-wd", batch_size=16))
table = cfg.table()
x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 64, 8, 4, sep=1.0)
state = init_multiclass_state(cfg, 8)
for i in range(0, 32, 16):   # warm the model so maintenance fires
    state = train_step_multiclass(cfg, table, state, x[i:i+16], y[i:i+16],
                                  impl="ref")
ref = train_step_multiclass(cfg, table, state, x[32:48], y[32:48], impl="ref")

mesh = make_mesh((2, 4), ("data", "model"))
step, args, in_sh, out_sh = make_distributed_step(cfg, mesh, 8, table,
                                                  layout="class")
with mesh:
    out = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)(
        state, table, x[32:48], y[32:48])
assert np.array_equal(np.asarray(out.count), np.asarray(ref.count))
err = float(jnp.max(jnp.abs(out.alpha - ref.alpha)))
assert err < 1e-4, err
print("OK class parity", err)
""")
    assert "OK class parity" in out


def test_lower_svm_cell_class_layout_bdca_solver(run_py):
    """The dual coordinate-ascent solver (``solver="bdca"``, DESIGN.md §14)
    lowers and compiles with classes sharded over `model` — the cache is
    forced on and the same mesh layouts apply unchanged."""
    out = run_py(r"""
from repro.core.distributed import lower_svm_cell
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                              layout="class", n_classes=8, solver="bdca")
assert cfg.binary.solver == "bdca"
assert cfg.binary.use_kernel_cache
compiled = lowered.compile()
print("OK bdca cell", compiled.memory_analysis().argument_size_in_bytes)
""")
    assert "OK bdca cell" in out
