"""Serving on the production mesh: the ``layout="serve"`` predict cell
lowers + compiles, the sharded queue path is bitwise the single-device one
(8 host devices via the shared ``run_py`` fixture), and the CLI arm runs."""
import subprocess
import sys


def test_serve_cell_lowers_binary_and_class(run_py):
    """lower_svm_cell(step="predict") compiles for the C=1 and multiclass
    banks; the abstract serving inputs match ``inputs.svm_serve_specs``."""
    out = run_py(r"""
from repro.core.distributed import lower_svm_cell, make_distributed_predict
from repro.launch.inputs import svm_serve_specs
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
for layout in ("replicated", "class"):
    lowered, cfg = lower_svm_cell(mesh, budget=64, dim=32, batch=16,
                                  layout=layout, n_classes=8, step="predict")
    mem = lowered.compile().memory_analysis()
    assert mem.argument_size_in_bytes > 0
    b = cfg.binary if layout == "class" else cfg
    n_classes = 8 if layout == "class" else None
    _, args, _, _ = make_distributed_predict(
        mesh, dim=32, batch=16, slots=b.slots, n_classes=n_classes)
    spec = svm_serve_specs(32, 16, b.slots, n_classes=n_classes)
    model_abs, x_abs = args
    for name in ("sv_x", "alpha", "count", "gamma"):
        got = getattr(model_abs, name)
        assert (got.shape, got.dtype) == (spec[name].shape, spec[name].dtype), name
    assert (x_abs.shape, x_abs.dtype) == (spec["x"].shape, spec["x"].dtype)
    print("OK serve cell", layout, mem.argument_size_in_bytes)
""")
    assert "OK serve cell replicated" in out
    assert "OK serve cell class" in out


def test_sharded_queue_bitwise_matches_direct(run_py):
    """The acceptance gate on 8 devices: a BatchQueue driving the pjit'd
    serve cell (bank replicated, batch sharded over every axis) returns
    bitwise the labels of the single-device direct predict."""
    run_py(r"""
import jax, numpy as np
from repro.core import (MulticlassSVMConfig, BatchQueue, export_model,
                        fit_multiclass, predict_labels)
from repro.core.distributed import make_distributed_predict
from repro.data import make_blobs_multiclass
from repro.launch.mesh import make_mesh

x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 512, 8, n_classes=4,
                             sep=2.0)
cfg = MulticlassSVMConfig.create(4, budget=16, lambda_=1e-3, gamma=0.5,
                                 batch_size=8)
state = fit_multiclass(cfg, x, y)
model = export_model(state, 0.5, bank_dtype="bfloat16")
direct = np.asarray(predict_labels(model, x))          # single-device path

mesh = make_mesh((2, 4), ("data", "model"))
fn, args, in_sh, out_sh = make_distributed_predict(
    mesh, dim=8, batch=64, slots=cfg.slots, n_classes=4)
with mesh:
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    q = BatchQueue(model, max_batch=64, min_bucket=8,
                   predict_fn=lambda xb: jfn(model, xb))
    sizes = [10, 100, 3, 0, 143, 64, 65, 127]
    tickets, off = [], 0
    xs = np.asarray(x)
    for s in sizes:
        tickets.append(q.submit(xs[off:off + s])); off += s
    q.drain()
    got = np.concatenate([q.take(t) for t in tickets])
assert (got == direct[:off]).all()
assert set(q.stats["bucket_counts"]) <= set(q.buckets)
print("OK sharded queue bitwise", q.stats)
""")


def test_serve_cli_svm_smoke(subprocess_env):
    """``serve --arch svm_bsgd --smoke`` runs end-to-end (its internal
    queue/direct parity assert is part of the run)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "svm_bsgd",
         "--smoke"],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(n_devices=1))
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    assert "queue == direct predict (bitwise)" in proc.stdout


def test_sharded_async_queue_bitwise_matches_direct(run_py):
    """AsyncBatchQueue parity on 8 devices: the dispatcher thread driving
    the pjit'd serve cell via predict_fn returns bitwise the single-device
    direct labels under ragged randomized arrivals."""
    run_py(r"""
import jax, numpy as np
from repro.core import (MulticlassSVMConfig, AsyncBatchQueue, export_model,
                        fit_multiclass, predict_labels)
from repro.core.distributed import make_distributed_predict
from repro.data import make_blobs_multiclass
from repro.launch.mesh import make_mesh

x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 512, 8, n_classes=4,
                             sep=2.0)
cfg = MulticlassSVMConfig.create(4, budget=16, lambda_=1e-3, gamma=0.5,
                                 batch_size=8)
state = fit_multiclass(cfg, x, y)
model = export_model(state, 0.5, bank_dtype="bfloat16")
direct = np.asarray(predict_labels(model, x))          # single-device path

mesh = make_mesh((2, 4), ("data", "model"))
fn, args, in_sh, out_sh = make_distributed_predict(
    mesh, dim=8, batch=64, slots=cfg.slots, n_classes=4)
with mesh:
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    with AsyncBatchQueue(model, max_batch=64, min_bucket=8,
                         predict_fn=lambda xb: jfn(model, xb)) as q:
        q.warmup()
        rng = np.random.default_rng(1)
        sizes = [int(s) for s in rng.integers(0, 97, size=12)]
        xs = np.asarray(x)
        tickets, off = [], 0
        for s in sizes:
            tickets.append(q.submit(xs[off:off + s])); off += min(s, 512 - off)
        q.drain(timeout=300.0)
        got = np.concatenate([q.take(t, timeout=60.0) for t in tickets])
n = got.shape[0]
assert (got == direct[:n]).all()
assert set(q.stats["bucket_counts"]) <= set(q.buckets)
print("OK sharded async queue bitwise", q.stats["microbatches"])
""")


def test_serve_cli_live_smoke(subprocess_env):
    """``serve --arch svm_bsgd --smoke --live``: the train-while-serve arm
    runs end-to-end — background trainer publishing into the ModelBank,
    AsyncBatchQueue serving over it, versions reported."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "svm_bsgd",
         "--smoke", "--live"],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(n_devices=1))
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    assert "versions served" in proc.stdout
    assert "rows/s" in proc.stdout


def test_serve_cli_from_stream_checkpoint(subprocess_env, tmp_path):
    """Train via the streaming CLI path, then serve the written checkpoint:
    the full train -> checkpoint -> export -> queue pipeline as processes."""
    import glob
    import os

    import numpy as np

    from repro.data import make_blobs_multiclass, write_npz_chunks
    import jax

    x, y = make_blobs_multiclass(jax.random.PRNGKey(0), 512, 6, n_classes=4,
                                 sep=2.0)
    shards = str(tmp_path / "shards")
    write_npz_chunks(shards, np.asarray(x), np.asarray(y), 128)
    ck = str(tmp_path / "ck")
    env = subprocess_env(n_devices=1)
    train = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "svm_bsgd",
         "--stream", shards, "--svm-layout", "class", "--svm-classes", "4",
         "--svm-budget", "16", "--batch-size", "8", "--ckpt-dir", ck,
         "--ckpt-every", "2"],
        capture_output=True, text=True, timeout=900, env=env)
    assert train.returncode == 0, f"{train.stdout}\n{train.stderr}"
    assert glob.glob(os.path.join(ck, "step_*")), "no checkpoint written"
    serve = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "svm_bsgd",
         "--model", ck, "--gamma", "0.5", "--rows", "512",
         "--max-batch", "64"],
        capture_output=True, text=True, timeout=900, env=env)
    assert serve.returncode == 0, f"{serve.stdout}\n{serve.stderr}"
    assert "queue == direct predict (bitwise)" in serve.stdout
    assert "loaded" in serve.stdout and "C=4" in serve.stdout


def test_live_supervisor_restarts_crashed_trainer(watchdog):
    """The §16 supervisor drill, in process: a crash-once chunk kills the
    trainer mid-run — serving stays up on the last published bank version,
    the supervisor restarts the trainer from the latest verifiable
    checkpoint, a fatal shard quarantines, and the run still finishes with
    a finite final snapshot and the usual serve stats."""
    watchdog(600)
    from repro.data import FaultSchedule
    from repro.launch.serve import serve_svm_live

    faults = FaultSchedule(seed=0, io_chunks=(1,), io_attempts=1,
                           crash_chunks=(5,), fatal_chunks=(6,))
    result = serve_svm_live(train_rows=1024, chunk_rows=128, epochs=2,
                            publish_every=2, budget=16, rows=512,
                            max_batch=64, verbose=False, faults=faults,
                            max_restarts=2)
    assert result["restarts"] >= 1                # the crash was supervised
    assert 6 in result["quarantined"]             # the fatal shard skipped
    assert result["retries"] >= 1                 # the io fault retried
    assert result["final_version"] >= 2           # mid-run publishes happened
    assert result["rows"] == 512                  # every request served


def test_serve_cli_live_chaos_smoke(subprocess_env):
    """``serve --arch svm_bsgd --smoke --live --faults 0``: the chaos drill
    through the CLI — the run must survive injected faults and report the
    resilience tally with a finite final snapshot."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "svm_bsgd",
         "--smoke", "--live", "--faults", "0"],
        capture_output=True, text=True, timeout=900,
        env=subprocess_env(n_devices=1))
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    assert "resilience:" in proc.stdout
    assert "final snapshot finite" in proc.stdout
