import os
import subprocess
import sys

import pytest

# Tests import the package from src/ (works with or without PYTHONPATH=src).
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

# Shared checkers/shims live in tests/helpers (``from helpers.invariants
# import ...``); the tests dir itself is importable so test modules in any
# subdirectory reach them without a package install.
sys.path.insert(0, os.path.dirname(__file__))

# Tests must see the single real CPU device (the 512-device env is exclusive
# to repro.launch.dryrun subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def subprocess_env(n_devices: int = 8, env_extra: dict | None = None) -> dict:
    """Env for multi-device ``python -c`` children, shared by every
    launch/distributed/serve subprocess test.

    Forces ``JAX_PLATFORMS=cpu``: with it unset, a jax[tpu] install probes
    the cloud TPU metadata service and stalls for ~8 minutes per child on
    machines without one — the forced host-device count is a CPU-platform
    feature anyway.  Centralized here so a new subprocess test cannot
    reintroduce the hang by forgetting the variable.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    if env_extra:
        env.update(env_extra)
    return env


@pytest.fixture(name="subprocess_env")
def subprocess_env_fixture():
    """The env-builder itself, for tests that spawn CLI children directly
    (``python -m repro.launch.serve ...``) rather than ``python -c`` code."""
    return subprocess_env


@pytest.fixture
def watchdog():
    """Deadlock tripwire for thread-based tests (prefetch stagers, async
    serve queues): arm it with a deadline and a hung worker dumps every
    thread's stack and kills the process instead of hanging tier-1 until
    the CI job timeout.

        def test_x(watchdog):
            watchdog(60)          # seconds; re-arm allowed
            ...

    Uses ``faulthandler.dump_traceback_later(exit=True)`` — the dump shows
    WHERE each thread is stuck, which a plain pytest timeout would not —
    and always disarms on teardown so a passing test leaves nothing armed.
    """
    import faulthandler

    armed = []

    def arm(seconds: float = 120.0) -> None:
        faulthandler.dump_traceback_later(seconds, exit=True)
        armed.append(seconds)

    yield arm
    if armed:
        faulthandler.cancel_dump_traceback_later()


@pytest.fixture
def run_py():
    """Run a code string in an isolated multi-device child; returns stdout.

    The one sanctioned way to run multi-device scenarios from the suite
    (smoke tests must keep seeing 1 device, so every such scenario is an
    isolated ``python -c`` child with its own forced host-device count and
    the TPU probe disabled — see ``subprocess_env``).
    """

    def _run(code: str, n_devices: int = 8, timeout: int = 900,
             env_extra: dict | None = None) -> str:
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=timeout, env=subprocess_env(n_devices, env_extra))
        assert proc.returncode == 0, \
            f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        return proc.stdout

    return _run
