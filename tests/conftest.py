import os
import sys

# Tests import the package from src/ (works with or without PYTHONPATH=src).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the single real CPU device (the 512-device env is exclusive
# to repro.launch.dryrun subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
