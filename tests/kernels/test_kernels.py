"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import default_table
from repro.kernels import ops, ref

RBF_SHAPES = [(8, 8, 4), (100, 73, 37), (128, 128, 128), (130, 257, 512),
              (1, 300, 3), (513, 5, 700)]


@pytest.mark.parametrize("n,m,d", RBF_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rbf_matrix_matches_ref(n, m, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 31 + m))
    x = jax.random.normal(k1, (n, d), dtype)
    y = jax.random.normal(k2, (m, d), dtype)
    got = ops.rbf_matrix(x, y, 0.3, impl="pallas_interpret")
    want = ref.rbf_matrix(x.astype(jnp.float32), y.astype(jnp.float32), 0.3)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("gamma", [0.01, 1.0, 30.0])
def test_rbf_gamma_sweep(gamma):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 16))
    got = ops.rbf_matrix(x, x, gamma, impl="pallas_interpret")
    want = ref.rbf_matrix(x, x, gamma)
    # exp amplifies fp error by ~gamma * |eps(d^2)| — scale tolerance with it
    tol = max(1e-5, 3e-5 * gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    # self-distance cancels to ~eps; diagonal ~= 1 up to exp(-gamma*eps)
    np.testing.assert_allclose(np.asarray(jnp.diag(got)), 1.0, atol=tol)


def test_rbf_row():
    key = jax.random.PRNGKey(1)
    sv = jax.random.normal(key, (57, 9))
    x = jax.random.normal(jax.random.PRNGKey(2), (9,))
    got = ops.rbf_row(sv, x, 0.7, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.rbf_row(sv, x, 0.7)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("s", [16, 100, 512, 1000])
def test_merge_scores_matches_ref(s):
    tbl = default_table()
    key = jax.random.PRNGKey(s)
    alpha = jnp.abs(jax.random.normal(key, (s,))) * 0.2 + 0.01
    kappa = jax.random.uniform(jax.random.PRNGKey(s + 1), (s,))
    valid = jax.random.bernoulli(jax.random.PRNGKey(s + 2), 0.8, (s,))
    a_min = 0.05
    wd_p, int_p = ops.merge_scores(alpha, kappa, valid, a_min, tbl.wd_table,
                                   impl="pallas_interpret")
    wd_r, int_r = ops.merge_scores(alpha, kappa, valid, a_min, tbl.wd_table,
                                   impl="ref")
    mask = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(wd_p)[mask], np.asarray(wd_r)[mask],
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(int_p), np.asarray(int_r),
                               rtol=1e-4, atol=1e-6)
    # invalid slots must lose every argmin
    if (~mask).any() and mask.any():
        assert np.asarray(wd_p)[~mask].min() > np.asarray(wd_p)[mask].max()


def test_merge_scores_argmin_equals_oracle():
    """End-to-end: the kernel's argmin picks the oracle's best partner."""
    tbl = default_table()
    for seed in range(5):
        key = jax.random.PRNGKey(seed)
        alpha = jnp.abs(jax.random.normal(key, (64,))) * 0.3 + 0.02
        kappa = jax.random.uniform(jax.random.PRNGKey(seed + 9), (64,),
                                   minval=0.2, maxval=0.99)
        valid = jnp.ones((64,), bool).at[10].set(False)
        wd_p, _ = ops.merge_scores(alpha, kappa, valid, 0.04, tbl.wd_table,
                                   impl="pallas_interpret")
        wd_r, _ = ops.merge_scores(alpha, kappa, valid, 0.04, tbl.wd_table,
                                   impl="ref")
        assert int(jnp.argmin(wd_p)) == int(jnp.argmin(wd_r))


@pytest.mark.parametrize("shape", [(1, 16), (3, 100), (8, 512)])
@pytest.mark.parametrize("n_iters", [10, 48])
def test_gss_kernel_matches_ref(shape, n_iters):
    k1, k2 = jax.random.split(jax.random.PRNGKey(shape[1]))
    m = jax.random.uniform(k1, shape, minval=0.01, maxval=0.99)
    kappa = jax.random.uniform(k2, shape, minval=0.15, maxval=0.999)
    got = ops.gss_solve(m, kappa, n_iters=n_iters, impl="pallas_interpret")
    want = ref.gss(m, kappa, n_iters)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("p", [1, 3, 8])
@pytest.mark.parametrize("s", [16, 100, 512])
def test_multi_merge_scores_matches_ref(p, s):
    tbl = default_table()
    key = jax.random.PRNGKey(p * 131 + s)
    alpha = jnp.abs(jax.random.normal(key, (s,))) * 0.2 + 0.01
    kappa = jax.random.uniform(jax.random.PRNGKey(s + 1), (p, s))
    valid = jax.random.bernoulli(jax.random.PRNGKey(s + 2), 0.8, (p, s))
    a_min = jnp.abs(jax.random.normal(jax.random.PRNGKey(s + 3), (p,))) * 0.05
    wd_p, h_p = ops.multi_merge_scores(alpha, kappa, valid, a_min, tbl,
                                       impl="pallas_interpret")
    wd_r, h_r = ops.multi_merge_scores(alpha, kappa, valid, a_min, tbl,
                                       impl="ref")
    mask = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(wd_p)[mask], np.asarray(wd_r)[mask],
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               rtol=1e-4, atol=1e-5)
    # invalid slots must lose every per-row argmin
    for q in range(p):
        if mask[q].any() and (~mask[q]).any():
            assert np.asarray(wd_p)[q][~mask[q]].min() > \
                np.asarray(wd_p)[q][mask[q]].max()


@pytest.mark.parametrize("c,p,s", [(2, 1, 16), (3, 4, 100), (5, 8, 256)])
def test_multi_merge_scores_class_batched(c, p, s):
    """(C, P, s) layout == per-class (P, s) calls, pallas vs ref."""
    tbl = default_table()
    key = jax.random.PRNGKey(c * 7 + s)
    alpha = jnp.abs(jax.random.normal(key, (c, s))) * 0.2 + 0.01
    kappa = jax.random.uniform(jax.random.PRNGKey(s + 1), (c, p, s))
    valid = jax.random.bernoulli(jax.random.PRNGKey(s + 2), 0.8, (c, p, s))
    a_min = jnp.abs(jax.random.normal(jax.random.PRNGKey(s + 3), (c, p))) * 0.05
    wd_p, h_p = ops.multi_merge_scores(alpha, kappa, valid, a_min, tbl,
                                       impl="pallas_interpret")
    wd_r, h_r = ops.multi_merge_scores(alpha, kappa, valid, a_min, tbl,
                                       impl="ref")
    assert wd_p.shape == (c, p, s)
    mask = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(wd_p)[mask], np.asarray(wd_r)[mask],
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r),
                               rtol=1e-4, atol=1e-5)
    for q in range(c):   # each class row == the unbatched call on its slice
        wd_q, h_q = ops.multi_merge_scores(alpha[q], kappa[q], valid[q],
                                           a_min[q], tbl, impl="ref")
        np.testing.assert_allclose(np.asarray(wd_r[q]), np.asarray(wd_q),
                                   rtol=1e-6, atol=0)
        np.testing.assert_allclose(np.asarray(h_r[q]), np.asarray(h_q),
                                   rtol=1e-6, atol=0)


def test_merge_scores_class_batched_matches_per_class():
    """(C, s) merge_scores == C single calls (one fixed partner per class)."""
    tbl = default_table()
    c, s = 4, 100
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (c, s))) * 0.3 + 0.02
    kappa = jax.random.uniform(jax.random.PRNGKey(1), (c, s))
    valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.9, (c, s))
    a_min = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (c,))) * 0.05
    for impl in ("ref", "pallas_interpret"):
        wd_b, int_b = ops.merge_scores(alpha, kappa, valid, a_min,
                                       tbl.wd_table, impl=impl)
        assert wd_b.shape == (c, s)
        for q in range(c):
            wd_q, int_q = ops.merge_scores(alpha[q], kappa[q], valid[q],
                                           a_min[q], tbl.wd_table, impl=impl)
            mask = np.asarray(valid[q])
            np.testing.assert_allclose(np.asarray(wd_b[q])[mask],
                                       np.asarray(wd_q)[mask],
                                       rtol=1e-5, atol=1e-7)
            np.testing.assert_allclose(np.asarray(int_b[q]), np.asarray(int_q),
                                       rtol=1e-4, atol=1e-5)


def test_multi_merge_scores_rows_match_single_kernel():
    """Each row of the multi kernel == the single-partner kernel's output."""
    tbl = default_table()
    s, p = 100, 4
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (s,))) * 0.3 + 0.02
    kappa = jax.random.uniform(jax.random.PRNGKey(1), (p, s))
    valid = jnp.ones((p, s), bool)
    a_min = jnp.asarray([0.01, 0.04, 0.1, 0.5])
    wd_m, h_m = ops.multi_merge_scores(alpha, kappa, valid, a_min, tbl,
                                       impl="pallas_interpret")
    for q in range(p):
        wd_s, _ = ops.merge_scores(alpha, kappa[q], valid[q], a_min[q],
                                   tbl.wd_table, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(wd_m[q]), np.asarray(wd_s),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(h_m[q]),
            np.asarray(ref.bilinear_lookup(tbl.h_table, *ref.merge_coords(
                a_min[q], alpha, kappa[q]))), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("c,s,d", [(1, 16, 4), (3, 40, 9), (4, 130, 33)])
def test_merge_event_kernel_matches_ref(c, s, d):
    """The fused maintenance-event kernel (interpret) vs its oracle: random
    stacked over-budget states, mixed over/at-budget classes."""
    from repro.core import kernel_cache

    key = jax.random.PRNGKey(c * 31 + s)
    k1, k2, k3 = jax.random.split(key, 3)
    sv = jax.random.normal(k1, (c, s, d))
    counts = jax.random.randint(k2, (c,), s // 2, s + 1).astype(jnp.int32)
    alpha = 0.1 * jax.random.normal(k3, (c, s))
    alpha = jnp.where(jnp.arange(s)[None, :] < counts[:, None], alpha, 0.0)
    kmat = jax.vmap(lambda v: kernel_cache.exact_cache(v, 0.5))(sv)
    over = jnp.arange(c) % 2 == 0                    # every other class runs
    tbl = default_table()
    got = ops.merge_event(sv, alpha, kmat, counts, over, tbl,
                          impl="pallas_interpret")
    want = ops.merge_event(sv, alpha, kmat, counts, over, tbl, impl="ref")
    for g, w, name in zip(got, want, ("sv_x", "alpha", "kmat")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
    # classes with over clear are bitwise untouched on BOTH impls
    for arrs, orig in ((got, (sv, alpha, kmat)), (want, (sv, alpha, kmat))):
        for g, o in zip(arrs, orig):
            np.testing.assert_array_equal(np.asarray(g)[1::2],
                                          np.asarray(o)[1::2])


def test_merge_event_kernel_bf16_bank():
    """bf16 SV banks round-trip the kernel: untouched rows stay bitwise, the
    merged row matches the oracle's bf16 cast."""
    from repro.core import kernel_cache

    c, s, d = 2, 24, 8
    sv = jax.random.normal(jax.random.PRNGKey(0), (c, s, d)).astype(jnp.bfloat16)
    alpha = 0.1 * jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (c, s))) + 0.01
    counts = jnp.asarray([20, 18], jnp.int32)
    alpha = jnp.where(jnp.arange(s)[None, :] < counts[:, None], alpha, 0.0)
    kmat = jax.vmap(lambda v: kernel_cache.exact_cache(
        v.astype(jnp.float32), 0.5))(sv)
    tbl = default_table()
    got = ops.merge_event(sv, alpha, kmat, counts, counts > 0, tbl,
                          impl="pallas_interpret")
    want = ops.merge_event(sv, alpha, kmat, counts, counts > 0, tbl,
                           impl="ref")
    assert got[0].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got[0]).astype(np.float32),
                               np.asarray(want[0]).astype(np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("c,slots,n,d", [(1, 16, 8, 4), (5, 33, 70, 11),
                                         (8, 128, 130, 32)])
def test_class_scores_fused_matches_per_class_oracle(c, slots, n, d):
    """The serving contraction: one fused (n, C*slots) launch == C
    sequential kernel calls, for fp32 and quantized bf16 banks."""
    keys = jax.random.split(jax.random.PRNGKey(c * 7 + n), 3)
    sv = jax.random.normal(keys[0], (c, slots, d))
    alpha = jax.random.normal(keys[1], (c, slots))
    x = jax.random.normal(keys[2], (n, d))
    for bank_dtype, tol in ((jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)):
        bank = sv.astype(bank_dtype)
        for impl in ("ref", "pallas_interpret"):
            got = ops.class_scores(x, bank, alpha, 0.4, impl=impl)
            assert got.shape == (c, n) and got.dtype == alpha.dtype
            want = ref.class_scores(x, bank.astype(jnp.float32), alpha, 0.4)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=tol, atol=tol)
