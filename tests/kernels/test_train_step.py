"""Fused train-step megakernel (interpret mode) vs the ``ref`` oracle.

``train_step.train_step_pallas`` folds classes onto the grid axis and runs
margin + insert + maintenance event rounds in one launch chain.  These
sweeps pin it (via ``ops.train_step`` with ``impl="pallas_interpret"``, so
the padding path is exercised too) against ``ref.train_step_fused``:
integer decisions BITWISE, float state inside fp32 round-off.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSGDConfig, kernel_cache
from repro.kernels import ops

GAMMA = 0.5
LAMBDA = 1e-3


def _steady_state(c, slots, dim, count, seed=0):
    """Random stacked near-budget state with exact caches."""
    rng = np.random.default_rng(seed)
    sv = jnp.asarray(rng.normal(size=(c, slots, dim)), jnp.float32)
    al = jnp.asarray(rng.normal(size=(c, slots)) * 0.05, jnp.float32)
    km = jax.vmap(lambda x: kernel_cache.exact_cache(x, GAMMA))(sv)
    cnt = jnp.full((c,), count, jnp.int32)
    al = jnp.where(jnp.arange(slots)[None, :] < cnt[:, None], al, 0.0)
    return sv, al, km, cnt


def _step_args(c, slots, dim, count, batch, seed=0):
    sv, al, km, cnt = _steady_state(c, slots, dim, count, seed)
    rng = np.random.default_rng(seed + 99)
    xb = jnp.asarray(rng.normal(size=(batch, dim)), jnp.float32)
    yb = jnp.asarray(np.where(rng.random((c, batch)) < 0.5, -1.0, 1.0),
                     jnp.float32)
    k_bb = ops.rbf_matrix(xb, xb, GAMMA, impl="ref")
    step = jnp.full((c,), 5, jnp.int32)
    z = jnp.zeros((c,), jnp.int32)
    return (sv, al, km, cnt, step, z, z, xb, yb, k_bb)


def _assert_step_parity(ref_out, pl_out, *, tag, atol=5e-5):
    names = ("sv_x", "alpha", "kmat", "count", "step", "n_inserts",
             "n_merges")
    for name, r, p in zip(names, ref_out, pl_out):
        assert r.dtype == p.dtype, f"{tag}:{name} dtype"
        r = np.asarray(r, np.float32) if r.dtype == jnp.bfloat16 \
            else np.asarray(r)
        p = np.asarray(p, np.float32) if p.dtype == jnp.bfloat16 \
            else np.asarray(p)
        if np.issubdtype(r.dtype, np.integer):
            np.testing.assert_array_equal(r, p, err_msg=f"{tag}:{name}")
        else:
            np.testing.assert_allclose(r, p, rtol=1e-5, atol=atol,
                                       err_msg=f"{tag}:{name}")


@pytest.mark.parametrize("maintenance", ["merge", "multi-merge"])
@pytest.mark.parametrize("c,budget,dim,batch", [
    (2, 120, 128, 8),                 # slots = 128: lane-aligned fast path
    (3, 40, 6, 8),                    # slots = 48: pad path, tiny dim
    (1, 60, 17, 4),                   # single class, odd dim
])
def test_fused_step_kernel_matches_ref(maintenance, c, budget, dim, batch):
    cfg = BSGDConfig(budget=budget, lambda_=LAMBDA, gamma=GAMMA,
                     batch_size=batch, method="lookup-wd",
                     use_kernel_cache=True)
    args = _step_args(c, cfg.slots, dim, budget - 2, batch,
                      seed=c * 13 + budget)
    kw = dict(budget=budget, lambda_=LAMBDA, gamma=GAMMA, batch_size=batch,
              maintenance=maintenance, merge_batch=4)
    ref_out = ops.train_step(*args, cfg.table(), impl="ref", **kw)
    pl_out = ops.train_step(*args, cfg.table(), impl="pallas_interpret",
                            **kw)
    # the steady state actually forces maintenance events this step
    assert int(jnp.sum(ref_out[6])) > 0
    _assert_step_parity(ref_out, pl_out, tag=maintenance)


def test_fused_step_kernel_under_budget_noop_rounds():
    """A state far below budget inserts but never merges — the masked event
    rounds must be bitwise no-ops."""
    cfg = BSGDConfig(budget=100, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
                     method="lookup-wd", use_kernel_cache=True)
    args = _step_args(2, cfg.slots, 10, 20, 8, seed=1)
    kw = dict(budget=100, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
              maintenance="merge", merge_batch=4)
    ref_out = ops.train_step(*args, cfg.table(), impl="ref", **kw)
    pl_out = ops.train_step(*args, cfg.table(), impl="pallas_interpret",
                            **kw)
    assert int(jnp.sum(ref_out[6])) == 0
    np.testing.assert_array_equal(np.asarray(ref_out[3]),
                                  np.asarray(pl_out[3]))
    _assert_step_parity(ref_out, pl_out, tag="noop")


def test_fused_step_kernel_bf16_bank():
    cfg = BSGDConfig(budget=40, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
                     method="lookup-wd", use_kernel_cache=True,
                     sv_dtype="bfloat16")
    sv, al, km, cnt, step, z, z2, xb, yb, k_bb = _step_args(
        2, cfg.slots, 9, 38, 8, seed=3)
    sv = sv.astype(jnp.bfloat16)
    km = jax.vmap(lambda x: kernel_cache.exact_cache(x, GAMMA))(sv)
    kw = dict(budget=40, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
              maintenance="multi-merge", merge_batch=4)
    args = (sv, al, km, cnt, step, z, z2, xb, yb, k_bb)
    ref_out = ops.train_step(*args, cfg.table(), impl="ref", **kw)
    pl_out = ops.train_step(*args, cfg.table(), impl="pallas_interpret",
                            **kw)
    assert pl_out[0].dtype == jnp.bfloat16
    assert pl_out[2].dtype == jnp.float32
    _assert_step_parity(ref_out, pl_out, tag="bf16", atol=1e-2)


def test_fused_step_kernel_multi_step_chain():
    """Three fused steps back to back stay on the oracle trajectory (state
    feeds state — any drift would compound and break the integer parity)."""
    cfg = BSGDConfig(budget=24, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
                     method="lookup-wd", use_kernel_cache=True)
    args = _step_args(2, cfg.slots, 7, 22, 8, seed=4)
    kw = dict(budget=24, lambda_=LAMBDA, gamma=GAMMA, batch_size=8,
              maintenance="merge", merge_batch=4)
    table = cfg.table()
    st_r, st_p = args, args
    rng = np.random.default_rng(11)
    for i in range(3):
        xb = jnp.asarray(rng.normal(size=(8, 7)), jnp.float32)
        yb = jnp.asarray(np.where(rng.random((2, 8)) < 0.5, -1.0, 1.0),
                         jnp.float32)
        k_bb = ops.rbf_matrix(xb, xb, GAMMA, impl="ref")
        st_r = ops.train_step(*st_r[:7], xb, yb, k_bb, table, impl="ref",
                              **kw)
        st_p = ops.train_step(*st_p[:7], xb, yb, k_bb, table,
                              impl="pallas_interpret", **kw)
        _assert_step_parity(st_r, st_p, tag=f"chain-step{i}")
    assert int(jnp.sum(st_r[6])) > 0
