"""Component-level oracles: SSD vs naive recurrence, chunked vs full attention,
MLA absorbed vs expanded, MoE dispatch vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ArchConfig, MoECfg, SSMCfg
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.common import split_params_axes


# --------------------------------------------------------------------- SSD
def _naive_ssm(xh, b, c, dt, a_h):
    """Literal per-step recurrence: S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T."""
    bsz, s, h, p = xh.shape
    n = b.shape[-1]
    state = np.zeros((bsz, h, n, p))
    ys = []
    for t in range(s):
        da = np.exp(dt[:, t] * a_h[None, :])                      # (B,H)
        outer = np.einsum("bh,bhn,bhp->bhnp", dt[:, t], b[:, t], xh[:, t])
        state = state * da[:, :, None, None] + outer
        ys.append(np.einsum("bhn,bhnp->bhp", c[:, t], state))
    return np.stack(ys, axis=1)                                   # (B,S,H,P)


def test_ssd_chunked_equals_naive_recurrence():
    rng = np.random.default_rng(0)
    bsz, s, h, p, n, chunk = 2, 32, 3, 4, 5, 8
    xh = rng.normal(size=(bsz, s, h, p)).astype(np.float32)
    b = rng.normal(size=(bsz, s, h, n)).astype(np.float32)
    c = rng.normal(size=(bsz, s, h, n)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(bsz, s, h)).astype(np.float32)
    a_h = -rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32)
    y, final_state = m2._ssd_chunked(jnp.asarray(xh), jnp.asarray(b),
                                     jnp.asarray(c), jnp.asarray(dt),
                                     jnp.asarray(a_h), chunk)
    y_ref = _naive_ssm(xh, b, c, dt, a_h)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    # final state matches the naive run's last state
    state = np.zeros((bsz, h, n, p))
    for t in range(s):
        da = np.exp(dt[:, t] * a_h[None, :])
        state = state * da[:, :, None, None] + np.einsum(
            "bh,bhn,bhp->bhnp", dt[:, t], b[:, t], xh[:, t])
    np.testing.assert_allclose(np.asarray(final_state), state, rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_invariance():
    """Different chunk sizes give identical results (up to fp)."""
    rng = np.random.default_rng(1)
    bsz, s, h, p, n = 1, 64, 2, 8, 4
    args = (rng.normal(size=(bsz, s, h, p)).astype(np.float32),
            rng.normal(size=(bsz, s, h, n)).astype(np.float32),
            rng.normal(size=(bsz, s, h, n)).astype(np.float32),
            rng.uniform(0.01, 0.3, size=(bsz, s, h)).astype(np.float32))
    a_h = -np.ones((h,), np.float32)
    y8, _ = m2._ssd_chunked(*map(jnp.asarray, args), jnp.asarray(a_h), 8)
    y32, _ = m2._ssd_chunked(*map(jnp.asarray, args), jnp.asarray(a_h), 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=2e-4,
                               atol=2e-4)


# --------------------------------------------------- chunked attention
def _mk_attn_cfg(**kw):
    base = dict(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=64, head_dim=8, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_chunked_attention_equals_full():
    cfg = _mk_attn_cfg(attn_chunk=16)
    key = jax.random.PRNGKey(0)
    p, _ = split_params_axes(attn_mod.init_attention(key, cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.arange(64, dtype=jnp.int32)
    y_chunked, _ = attn_mod.attention(cfg, p, x, pos, mode="full")
    cfg_full = dataclasses.replace(cfg, attn_chunk=4096)
    y_full, _ = attn_mod.attention(cfg_full, p, x, pos, mode="full")
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_distant_keys():
    cfg = _mk_attn_cfg(sliding_window=8, attn_chunk=4096)
    key = jax.random.PRNGKey(0)
    p, _ = split_params_axes(attn_mod.init_attention(key, cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    pos = jnp.arange(32, dtype=jnp.int32)
    y, _ = attn_mod.attention(cfg, p, x, pos, mode="full")
    # perturbing a token > window away must not change the output at t=31
    x2 = x.at[:, 5].add(10.0)       # 31 - 5 = 26 > 8
    y2, _ = attn_mod.attention(cfg, p, x2, pos, mode="full")
    np.testing.assert_allclose(np.asarray(y[:, -1]), np.asarray(y2[:, -1]),
                               rtol=1e-4, atol=1e-4)
    # ...but a token inside the window does
    x3 = x.at[:, 30].add(10.0)
    y3, _ = attn_mod.attention(cfg, p, x3, pos, mode="full")
    assert float(jnp.max(jnp.abs(y3[:, -1] - y[:, -1]))) > 1e-3


def test_swa_ring_decode_matches_full():
    """Decode through a ring cache == full forward on the suffix window."""
    cfg = _mk_attn_cfg(sliding_window=8, attn_chunk=4096)
    key = jax.random.PRNGKey(0)
    p, _ = split_params_axes(attn_mod.init_attention(key, cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
    pos = jnp.arange(24, dtype=jnp.int32)
    y_full, _ = attn_mod.attention(cfg, p, x, pos, mode="full")
    cache = attn_mod.init_attn_cache(cfg, 1, 8, jnp.float32)  # ring of size 8
    outs = []
    for t in range(24):
        y, cache = attn_mod.attention(cfg, p, x[:, t:t+1], None, mode="decode",
                                      cache=cache, cache_pos=jnp.int32(t))
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- MoE
def test_moe_matches_dense_oracle_when_no_drops():
    cfg = get_smoke("deepseek_v2_236b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, min_capacity=128))
    key = jax.random.PRNGKey(0)
    p, _ = split_params_axes(moe_mod.init_moe(key, cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got = moe_mod.moe_ffn(cfg, p, x)

    # dense oracle: run every expert on every token, combine with gates
    m = cfg.moe
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    scores = jax.nn.softmax(logits, -1)
    gate, sel = jax.lax.top_k(scores, m.top_k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    outs = []
    for e in range(m.num_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    per_expert = jnp.stack(outs, axis=1)             # (T, E, D)
    weights = jnp.zeros((xf.shape[0], m.num_experts)).at[
        jnp.arange(xf.shape[0])[:, None], sel].add(gate)
    want = jnp.einsum("te,ted->td", weights, per_expert)
    if m.n_shared:
        sp = p["shared"]
        want = want + (jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])) @ sp["w_down"]
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    cfg = get_smoke("deepseek_v3_671b")
    key = jax.random.PRNGKey(0)
    p, _ = split_params_axes(moe_mod.init_moe(key, cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    out = moe_mod.moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
