"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get, get_smoke
from repro.launch.steps import make_train_step
from repro.models import decode_step, forward, init_cache, init_lm, loss_fn
from repro.train.optimizer import AdamW

B, S = 2, 32


def _batch(cfg, key):
    if cfg.input_kind == "frames":
        k1, k2, k3 = jax.random.split(key, 3)
        return {"frames": jax.random.normal(k1, (B, S, cfg.frame_dim),
                                            jnp.dtype(cfg.dtype)),
                "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
                "mask": jax.random.bernoulli(k3, 0.4, (B, S))}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
            "mask": jnp.ones((B, S), jnp.float32)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params, axes = init_lm(key, cfg)
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params, _ = init_lm(key, cfg)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    new_params, new_opt, loss = step(params, opt_state, _batch(cfg, key))
    assert bool(jnp.isfinite(loss))
    assert int(new_opt.step) == 1
    # params actually moved and stayed finite
    moved = jax.tree.map(lambda a, b: bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))
                         and a.shape == b.shape, params, new_params)
    assert all(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if a != "hubert_xlarge"])
def test_one_decode_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(2)
    params, _ = init_lm(key, cfg)
    cache = init_cache(cfg, B, 16)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(0)))(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["deepseek_v2_236b", "jamba_v01_52b",
                                  "mamba2_130m", "yi_9b"])
def test_decode_matches_full_forward(arch):
    """Step-by-step decode reproduces the full forward logits (MoE archs use
    a no-drop capacity so dispatch truncation cannot differ between paths)."""
    cfg = get_smoke(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0, min_capacity=64))
    key = jax.random.PRNGKey(3)
    params, _ = init_lm(key, cfg)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)
    full, _ = forward(cfg, params, {"tokens": toks}, mode="full")
    cache = init_cache(cfg, B, 20)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    outs = []
    for t in range(16):
        lg, cache = step(params, cache, toks[:, t:t+1], jnp.int32(t))
        outs.append(lg)
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 2e-2, err


def test_full_configs_have_exact_assigned_dims():
    spec = {
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "mamba2_130m": (24, 768, 24, 24, 0, 50280),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "h2o_danube3_4b": (24, 3840, 32, 8, 10240, 32000),
        "yi_9b": (48, 4096, 32, 4, 11008, 64000),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "deepseek_v2_236b": (60, 5120, 128, 128, 1536, 102400),
        "deepseek_v3_671b": (61, 7168, 128, 128, 2048, 129280),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch


def test_moe_structure():
    v2, v3, jb = get("deepseek_v2_236b"), get("deepseek_v3_671b"), get("jamba_v01_52b")
    assert (v2.moe.num_experts, v2.moe.top_k, v2.moe.n_shared) == (160, 6, 2)
    assert (v3.moe.num_experts, v3.moe.top_k, v3.moe.n_shared) == (256, 8, 1)
    assert (jb.moe.num_experts, jb.moe.top_k) == (16, 2)
    # jamba interleave: 4 attention layers at period 8, offset 4
    kinds = [jb.mixer_kind(i) for i in range(32)]
    assert kinds.count("attn") == 4
    assert all(kinds[i] == "attn" for i in (4, 12, 20, 28))
