"""Distributed BSGD parity + context-parallel attention numerics (8 devices)."""


def test_distributed_bsgd_matches_single_device(run_py):
    """Both SVM layouts reproduce the single-device BSGD step exactly."""
    run_py(r"""
import jax, jax.numpy as jnp
from repro.core import BSGDConfig, init_state, train_step
from repro.core.distributed import make_distributed_step
from repro.launch.mesh import make_mesh
from repro.data import make_blobs

cfg = BSGDConfig(budget=32, lambda_=1e-4, gamma=0.5, method="lookup-wd",
                 batch_size=16)
table = cfg.table()
x, y = make_blobs(jax.random.PRNGKey(0), 64, 8, sep=1.0)
state = init_state(cfg, 8)
for i in range(0, 32, 16):   # warm the model so maintenance fires
    state = train_step(cfg, table, state, x[i:i+16], y[i:i+16], impl="ref")
ref = train_step(cfg, table, state, x[32:48], y[32:48], impl="ref")

mesh = make_mesh((2, 4), ("data", "model"))
for layout in ("replicated", "slots"):
    step, args, in_sh, out_sh = make_distributed_step(cfg, mesh, 8, table,
                                                      layout=layout)
    with mesh:
        out = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)(
            state, table, x[32:48], y[32:48])
    assert int(out.count) == int(ref.count), layout
    err = float(jnp.max(jnp.abs(out.alpha - ref.alpha)))
    assert err < 1e-4, (layout, err)
    print("OK", layout, err)
""")


def test_seq_shard_attn_preserves_numerics(run_py):
    """Context-parallel attention (§Perf cell B) is a pure sharding change."""
    run_py(r"""
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.models import init_lm, loss_fn

cfg = get_smoke("smollm_360m")
cfg = dataclasses.replace(cfg, dtype="float32")
key = jax.random.PRNGKey(0)
params, _ = init_lm(key, cfg)
toks = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

loss_ref = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)

cfg_sp = dataclasses.replace(cfg, seq_shard_attn=("data",))
mesh = make_mesh((2, 4), ("data", "model"))
with mesh:
    loss_sp = jax.jit(lambda p, b: loss_fn(cfg_sp, p, b))(params, batch)
err = abs(float(loss_ref) - float(loss_sp))
assert err < 1e-4, err
print("OK ctxpar numerics", err)
""")
