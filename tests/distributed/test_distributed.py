"""Distributed correctness, run in subprocesses with 8 host devices.

Smoke tests must see 1 device, so every multi-device scenario is an isolated
``python -c`` child with its own ``--xla_force_host_platform_device_count=8``
(the ``run_py`` fixture in ``tests/conftest.py``).
"""


def test_dp_tp_train_step_matches_single_device(run_py):
    """The pjit'd train step on a 2x4 mesh reproduces single-device math."""
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step
from repro.launch import inputs as inp
from repro.sharding import specs as sh
from repro.models import init_lm
from repro.train.optimizer import AdamW

cfg = get_smoke("yi_9b")
key = jax.random.PRNGKey(0)
params, axes = init_lm(key, cfg)
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)
toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "mask": jnp.ones((8, 16), jnp.float32)}
step = make_train_step(cfg, opt)

# single device reference
p1, o1, loss1 = jax.jit(step)(params, opt_state, batch)

# 2x4 mesh
mesh = make_mesh((2, 4), ("data", "model"))
params_s = jax.eval_shape(lambda: params)
p_shard = sh.param_shardings(axes, params_s, mesh, "tp")
b_shard = sh.to_shardings(sh.batch_spec(mesh, jax.eval_shape(lambda: batch)), mesh)
with mesh:
    p8, o8, loss8 = jax.jit(step, in_shardings=(p_shard, None, b_shard))(
        params, opt_state, batch)
assert abs(float(loss1) - float(loss8)) < 1e-3, (float(loss1), float(loss8))
err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
          for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)))
assert err < 5e-2, err
print("OK dp+tp parity", float(loss1), err)
""")


def test_fsdp_strategy_matches_tp(run_py):
    run_py(r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step
from repro.sharding import specs as sh
from repro.models import init_lm
from repro.train.optimizer import AdamW

cfg = get_smoke("deepseek_coder_33b")
key = jax.random.PRNGKey(1)
params, axes = init_lm(key, cfg)
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)
toks = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "mask": jnp.ones((8, 16), jnp.float32)}
step = make_train_step(cfg, opt)
mesh = make_mesh((2, 4), ("data", "model"))
params_s = jax.eval_shape(lambda: params)
losses = {}
for strat in ("tp", "fsdp"):
    p_shard = sh.param_shardings(axes, params_s, mesh, strat)
    with mesh:
        _, _, loss = jax.jit(step, in_shardings=(p_shard, None, None))(
            params, opt_state, batch)
    losses[strat] = float(loss)
assert abs(losses["tp"] - losses["fsdp"]) < 1e-3, losses
print("OK fsdp parity", losses)
""")


def test_compressed_psum_within_quantization_error(run_py):
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_mesh
from repro.train.grad_compress import compressed_psum

mesh = make_mesh((8,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

@partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
         check_rep=False)
def compressed_mean(gs):
    mean, resid = compressed_psum({"g": gs}, None, "data")
    return mean["g"]

got = compressed_mean(g)[0]
want = jnp.mean(g, axis=0)
scale = float(jnp.max(jnp.abs(g)) / 127.0)
err = float(jnp.max(jnp.abs(got - want)))
assert err <= scale, (err, scale)
print("OK compressed psum", err, scale)
""")


def test_pipeline_forward_matches_sequential(run_py):
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.train.pipeline import pipeline_forward

mesh = make_mesh((4,), ("pipe",))
n_groups, d = 8, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (n_groups, d, d)) * 0.3

def body(w, x):
    return jnp.tanh(x @ w)

x_micro = jax.random.normal(jax.random.PRNGKey(1), (6, 4, d))  # 6 microbatches

# sequential reference
def seq(x):
    for i in range(n_groups):
        x = body(ws[i], x)
    return x
want = jax.vmap(seq)(x_micro)

got = pipeline_forward(body, 4, ws, x_micro, mesh, axis="pipe")
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-4, err
print("OK pipeline parity", err)
""")


def test_elastic_restart_with_fault_injection(run_py, tmp_path):
    """Child crashes at step 12 (hard exit), supervisor restarts, training
    resumes from the atomic checkpoint and completes."""
    ckdir = str(tmp_path / "ck")
    out = run_py(rf"""
import sys
from repro.launch.elastic import supervise
restarts = supervise(
    [sys.executable, "-m", "repro.launch.train", "--arch", "smollm_360m",
     "--smoke", "--steps", "24", "--ckpt-dir", r"{ckdir}",
     "--ckpt-every", "8", "--batch-size", "2", "--seq-len", "32"],
    env_extra={{"FAULT_AT_STEP": "12"}})
assert restarts == 1, restarts
print("OK elastic restart", restarts)
""", n_devices=1, timeout=900)
    assert "OK elastic restart" in out


def test_elastic_reshard_across_device_counts(run_py, tmp_path):
    """Save params sharded on 8 devices, restore on 2 (different mesh)."""
    ckdir = str(tmp_path / "ck")
    run_py(rf"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("model",))
w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh, P("model", None)))
ckpt.save(r"{ckdir}", 5, {{"w": w}})
print("saved")
""", n_devices=8)
    out = run_py(rf"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro.launch.mesh import make_mesh

mesh = make_mesh((2,), ("model",))
target = {{"w": jnp.zeros((8, 8))}}
shardings = {{"w": NamedSharding(mesh, P("model", None))}}
step, tree = ckpt.restore_latest(r"{ckdir}", target, shardings=shardings)
assert step == 5
np.testing.assert_array_equal(np.asarray(tree["w"]),
                               np.arange(64.0).reshape(8, 8))
print("OK reshard", tree["w"].sharding)
""", n_devices=2)
    assert "OK reshard" in out
