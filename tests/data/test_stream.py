"""Chunk sources + deterministic shuffle: every source yields the same rows,
the realized epoch order is a permutation, and LIBSVM round-trips in chunks."""
import os

import jax
import numpy as np
import pytest

from repro.data import (ArrayChunks, FileChunks, LibsvmChunks, dump_libsvm,
                        epoch_permutation, iter_epoch, iter_libsvm_chunks,
                        parse_libsvm, write_npz_chunks)


def _data(n=53, d=5, seed=0):
    rng = np.random.default_rng(seed)
    x = np.round(rng.normal(size=(n, d)).astype(np.float32), 3)
    x[rng.random(x.shape) < 0.2] = 0.0
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    return x, y


def _assert_source_matches(source, x, y):
    assert source.n_rows == x.shape[0]
    assert source.dim == x.shape[1]
    assert sum(source.chunk_lens) == x.shape[0]
    xs, ys = zip(*list(source))
    np.testing.assert_allclose(np.concatenate(xs), x, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate(ys), y)


def test_array_chunks_roundtrip_ragged():
    x, y = _data()
    src = ArrayChunks(x, y, 20)             # 20 + 20 + 13
    assert src.chunk_lens == [20, 20, 13]
    _assert_source_matches(src, x, y)


def test_file_chunks_roundtrip(tmp_path):
    x, y = _data()
    paths = write_npz_chunks(str(tmp_path), x, y, 16)
    src = FileChunks(paths)
    assert src.n_chunks == 4
    _assert_source_matches(src, x, y)


def test_file_chunks_npy_pairs(tmp_path):
    x, y = _data(n=24)
    pairs = []
    for i, s in enumerate(range(0, 24, 8)):
        xp = os.path.join(tmp_path, f"x{i}.npy")
        yp = os.path.join(tmp_path, f"y{i}.npy")
        np.save(xp, x[s:s + 8]); np.save(yp, y[s:s + 8])
        pairs.append((xp, yp))
    _assert_source_matches(FileChunks(pairs), x, y)


def test_libsvm_chunks_random_access(tmp_path):
    x, y = _data()
    path = os.path.join(tmp_path, "d.libsvm")
    dump_libsvm(path, x, y)
    src = LibsvmChunks(path, 20, n_features=5)
    _assert_source_matches(src, x, y)
    # chunks load independently and out of order (the shuffled-stream path)
    x2, y2 = src.load(2)
    np.testing.assert_allclose(x2, x[40:], rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(y2, y[40:])


def test_libsvm_chunks_infers_n_features(tmp_path):
    x, y = _data(d=7)
    path = os.path.join(tmp_path, "d.libsvm")
    dump_libsvm(path, x, y)
    assert LibsvmChunks(path, 10).dim == 7


def test_chunked_libsvm_roundtrip(tmp_path):
    """dump in appended chunks -> read back in chunks: never whole-resident."""
    x, y = _data(n=41, d=6, seed=3)
    path = os.path.join(tmp_path, "chunked.libsvm")
    for s in range(0, 41, 10):
        dump_libsvm(path, x[s:s + 10], y[s:s + 10], append=s > 0)
    got = list(iter_libsvm_chunks(path, 10, n_features=6))
    assert [g[0].shape[0] for g in got] == [10, 10, 10, 10, 1]
    np.testing.assert_allclose(np.concatenate([g[0] for g in got]), x,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate([g[1] for g in got]), y)
    # and the whole-file parse agrees
    x2, y2 = parse_libsvm(path, n_features=6)
    np.testing.assert_allclose(x2, x, rtol=1e-5, atol=1e-6)


def test_epoch_permutation_is_permutation_and_matches_iter():
    x, y = _data(n=47)
    src = ArrayChunks(x, y, 12)
    key = jax.random.PRNGKey(3)
    perm = epoch_permutation(src, key)
    assert sorted(perm.tolist()) == list(range(47))
    streamed = np.concatenate([xc for _, xc, _ in iter_epoch(src, key)])
    np.testing.assert_array_equal(streamed, x[perm])
    # None = natural order
    np.testing.assert_array_equal(epoch_permutation(src, None), np.arange(47))


def test_iter_epoch_start_chunk_resumes_order():
    x, y = _data(n=40)
    src = ArrayChunks(x, y, 10)
    key = jax.random.PRNGKey(9)
    full = list(iter_epoch(src, key))
    tail = list(iter_epoch(src, key, start_chunk=2))
    assert [p for p, _, _ in tail] == [2, 3]
    for (pa, xa, _), (pb, xb, _) in zip(full[2:], tail):
        assert pa == pb
        np.testing.assert_array_equal(xa, xb)


def test_source_validation():
    x, y = _data()
    with pytest.raises(ValueError):
        ArrayChunks(x, y[:-1], 10)
    with pytest.raises(ValueError):
        ArrayChunks(x, y, 0)
    with pytest.raises(ValueError):
        FileChunks([])
