"""Chunk sources + deterministic shuffle: every source yields the same rows,
the realized epoch order is a permutation, and LIBSVM round-trips in chunks."""
import os

import jax
import numpy as np
import pytest

from repro.data import (ArrayChunks, DriftChunks, FileChunks, LibsvmChunks,
                        PrefetchChunks, dump_libsvm, epoch_permutation,
                        iter_epoch, iter_libsvm_chunks, label_flip_schedule,
                        mean_shift_schedule, parse_libsvm, write_npz_chunks)


def _data(n=53, d=5, seed=0):
    rng = np.random.default_rng(seed)
    x = np.round(rng.normal(size=(n, d)).astype(np.float32), 3)
    x[rng.random(x.shape) < 0.2] = 0.0
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    return x, y


def _assert_source_matches(source, x, y):
    assert source.n_rows == x.shape[0]
    assert source.dim == x.shape[1]
    assert sum(source.chunk_lens) == x.shape[0]
    xs, ys = zip(*list(source))
    np.testing.assert_allclose(np.concatenate(xs), x, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate(ys), y)


def test_array_chunks_roundtrip_ragged():
    x, y = _data()
    src = ArrayChunks(x, y, 20)             # 20 + 20 + 13
    assert src.chunk_lens == [20, 20, 13]
    _assert_source_matches(src, x, y)


def test_file_chunks_roundtrip(tmp_path):
    x, y = _data()
    paths = write_npz_chunks(str(tmp_path), x, y, 16)
    src = FileChunks(paths)
    assert src.n_chunks == 4
    _assert_source_matches(src, x, y)


def test_file_chunks_npy_pairs(tmp_path):
    x, y = _data(n=24)
    pairs = []
    for i, s in enumerate(range(0, 24, 8)):
        xp = os.path.join(tmp_path, f"x{i}.npy")
        yp = os.path.join(tmp_path, f"y{i}.npy")
        np.save(xp, x[s:s + 8]); np.save(yp, y[s:s + 8])
        pairs.append((xp, yp))
    _assert_source_matches(FileChunks(pairs), x, y)


def test_libsvm_chunks_random_access(tmp_path):
    x, y = _data()
    path = os.path.join(tmp_path, "d.libsvm")
    dump_libsvm(path, x, y)
    src = LibsvmChunks(path, 20, n_features=5)
    _assert_source_matches(src, x, y)
    # chunks load independently and out of order (the shuffled-stream path)
    x2, y2 = src.load(2)
    np.testing.assert_allclose(x2, x[40:], rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(y2, y[40:])


def test_libsvm_chunks_infers_n_features(tmp_path):
    x, y = _data(d=7)
    path = os.path.join(tmp_path, "d.libsvm")
    dump_libsvm(path, x, y)
    assert LibsvmChunks(path, 10).dim == 7


def test_chunked_libsvm_roundtrip(tmp_path):
    """dump in appended chunks -> read back in chunks: never whole-resident."""
    x, y = _data(n=41, d=6, seed=3)
    path = os.path.join(tmp_path, "chunked.libsvm")
    for s in range(0, 41, 10):
        dump_libsvm(path, x[s:s + 10], y[s:s + 10], append=s > 0)
    got = list(iter_libsvm_chunks(path, 10, n_features=6))
    assert [g[0].shape[0] for g in got] == [10, 10, 10, 10, 1]
    np.testing.assert_allclose(np.concatenate([g[0] for g in got]), x,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.concatenate([g[1] for g in got]), y)
    # and the whole-file parse agrees
    x2, y2 = parse_libsvm(path, n_features=6)
    np.testing.assert_allclose(x2, x, rtol=1e-5, atol=1e-6)


def test_epoch_permutation_is_permutation_and_matches_iter():
    x, y = _data(n=47)
    src = ArrayChunks(x, y, 12)
    key = jax.random.PRNGKey(3)
    perm = epoch_permutation(src, key)
    assert sorted(perm.tolist()) == list(range(47))
    streamed = np.concatenate([xc for _, xc, _ in iter_epoch(src, key)])
    np.testing.assert_array_equal(streamed, x[perm])
    # None = natural order
    np.testing.assert_array_equal(epoch_permutation(src, None), np.arange(47))


def test_iter_epoch_start_chunk_resumes_order():
    x, y = _data(n=40)
    src = ArrayChunks(x, y, 10)
    key = jax.random.PRNGKey(9)
    full = list(iter_epoch(src, key))
    tail = list(iter_epoch(src, key, start_chunk=2))
    assert [p for p, _, _ in tail] == [2, 3]
    for (pa, xa, _), (pb, xb, _) in zip(full[2:], tail):
        assert pa == pb
        np.testing.assert_array_equal(xa, xb)


def test_source_validation():
    x, y = _data()
    with pytest.raises(ValueError):
        ArrayChunks(x, y[:-1], 10)
    with pytest.raises(ValueError):
        ArrayChunks(x, y, 0)
    with pytest.raises(ValueError):
        FileChunks([])
    with pytest.raises(ValueError):
        PrefetchChunks(ArrayChunks(x, y, 10), depth=0)


class _CountingSource(ArrayChunks):
    """ArrayChunks that records which thread loaded each chunk."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.load_threads: list[str] = []

    def load(self, i):
        import threading

        self.load_threads.append(threading.current_thread().name)
        return super().load(i)


def test_prefetch_chunks_bitwise_and_on_worker():
    """Planned loads come back bitwise identical AND ran on the worker."""
    x, y = _data(n=60)
    inner = _CountingSource(x, y, 12)
    pre = PrefetchChunks(inner, depth=2)
    order = [3, 0, 4, 1, 2]
    pre.plan(order)
    try:
        for cid in order:
            xd, yd = ArrayChunks(x, y, 12).load(cid)
            xp, yp = pre.load(cid)
            np.testing.assert_array_equal(xp, xd)
            np.testing.assert_array_equal(yp, yd)
    finally:
        pre.cancel()
    assert all(t.startswith("prefetch") for t in inner.load_threads), \
        inner.load_threads


def test_prefetch_chunks_off_plan_falls_back_sync():
    x, y = _data(n=40)
    pre = PrefetchChunks(ArrayChunks(x, y, 10), depth=2)
    # no plan at all: plain synchronous source
    xp, _ = pre.load(1)
    np.testing.assert_array_equal(xp, x[10:20])
    pre.plan([0, 2])
    try:
        xp, _ = pre.load(3)                  # off the declared plan
        np.testing.assert_array_equal(xp, x[30:40])
    finally:
        pre.cancel()


def test_prefetch_chunks_worker_error_surfaces_on_caller(watchdog):
    """A load() raising on the worker re-raises on the caller's thread and
    leaves no hung worker behind."""
    watchdog(120)

    class Boom(ArrayChunks):
        def load(self, i):
            if i == 1:
                raise RuntimeError("disk gone")
            return super().load(i)

    x, y = _data(n=30)
    pre = PrefetchChunks(Boom(x, y, 10), depth=2)
    pre.plan([0, 1, 2])
    try:
        pre.load(0)                          # fine
        with pytest.raises(RuntimeError, match="disk gone"):
            pre.load(1)
    finally:
        pre.cancel()


def test_iter_epoch_prefetch_bitwise_matches_sync():
    """iter_epoch(prefetch=2) yields the identical (position, x, y) stream —
    shuffled, resumed mid-epoch, and over an already-wrapped source."""
    x, y = _data(n=57)
    src = ArrayChunks(x, y, 12)
    key = jax.random.PRNGKey(11)
    for kw in ({}, {"start_chunk": 2}, {"key": None}):
        sync = list(iter_epoch(src, key, **kw)) if "key" not in kw else \
            list(iter_epoch(src, **kw))
        pre = list(iter_epoch(src, key, prefetch=2, **kw)) \
            if "key" not in kw else list(iter_epoch(src, prefetch=2, **kw))
        assert [p for p, _, _ in sync] == [p for p, _, _ in pre]
        for (_, xa, ya), (_, xb, yb) in zip(sync, pre):
            np.testing.assert_array_equal(xa, xb)
            np.testing.assert_array_equal(ya, yb)
    # an explicit PrefetchChunks source is planned, not double-wrapped
    wrapped = PrefetchChunks(src, depth=2)
    pre2 = list(iter_epoch(wrapped, key, prefetch=2))
    sync2 = list(iter_epoch(src, key))
    for (_, xa, _), (_, xb, _) in zip(sync2, pre2):
        np.testing.assert_array_equal(xa, xb)


# ---------------------------------------------------------------------------
# Prefetch teardown: no hung worker threads, ever (ISSUE 9 satellite)
# ---------------------------------------------------------------------------

def _prefetch_threads():
    import threading

    return [t.name for t in threading.enumerate()
            if t.name.startswith("prefetch")]


def test_prefetch_teardown_no_hung_threads(watchdog):
    """close() joins the worker; a consumer that raises mid-epoch and a
    dropped planned source both leave zero prefetch threads behind."""
    watchdog(120)
    import gc

    x, y = _data(n=60)
    assert _prefetch_threads() == []
    # explicit close() joins
    pre = PrefetchChunks(ArrayChunks(x, y, 12), depth=2)
    pre.plan([0, 1, 2, 3, 4])
    pre.load(0)
    pre.close()
    assert _prefetch_threads() == []
    # consumer raises mid-epoch: iter_epoch's finally must close the plan
    src = ArrayChunks(x, y, 12)
    with pytest.raises(RuntimeError, match="consumer bailed"):
        for pos, xb, yb in iter_epoch(src, jax.random.PRNGKey(0), prefetch=2):
            raise RuntimeError("consumer bailed")
    assert _prefetch_threads() == []
    # dropped mid-plan without close(): __del__ must still tear down
    pre2 = PrefetchChunks(ArrayChunks(x, y, 12), depth=2)
    pre2.plan([0, 1, 2, 3, 4])
    pre2.load(0)
    del pre2
    gc.collect()
    assert _prefetch_threads() == []
    # close() is idempotent and safe on a never-planned instance
    pre3 = PrefetchChunks(ArrayChunks(x, y, 12), depth=2)
    pre3.close()
    pre3.close()


def test_prefetch_del_safe_on_partial_init():
    """__del__ on an instance whose __init__ raised must not explode."""
    with pytest.raises(ValueError):
        PrefetchChunks(ArrayChunks(*_data(n=20), 10), depth=0)


# ---------------------------------------------------------------------------
# DriftChunks: deterministic non-stationarity (ISSUE 9 tentpole data layer)
# ---------------------------------------------------------------------------

def test_drift_chunks_label_flip_deterministic_and_localized():
    x, y = _data(n=60)
    src = ArrayChunks(x, y, 12)
    flip = label_flip_schedule(src.n_chunks, start=0.6, prob=1.0)
    drift = DriftChunks(src, flip=flip, seed=3)
    assert (drift.n_chunks, drift.n_rows, drift.dim) == \
        (src.n_chunks, src.n_rows, src.dim)
    for cid in range(src.n_chunks):
        xc, yc = src.load(cid)
        xd, yd = drift.load(cid)
        np.testing.assert_array_equal(xd, xc)       # labels-only drift
        if flip[cid] == 0.0:
            np.testing.assert_array_equal(yd, yc)   # pre-drift: clean
        else:
            np.testing.assert_array_equal(yd, -yc)  # prob=1: full negation
        assert yd.dtype == yc.dtype
        # bitwise repeatable: pure function of (seed, chunk id)
        xd2, yd2 = drift.load(cid)
        np.testing.assert_array_equal(yd2, yd)
        np.testing.assert_array_equal(xd2, xd)


def test_drift_chunks_partial_flip_seed_dependence():
    x, y = _data(n=120)
    src = ArrayChunks(x, y, 30)
    flip = label_flip_schedule(src.n_chunks, start=0.0, prob=0.5)
    _, ya = DriftChunks(src, flip=flip, seed=0).load(0)
    _, yb = DriftChunks(src, flip=flip, seed=1).load(0)
    _, ya2 = DriftChunks(src, flip=flip, seed=0).load(0)
    np.testing.assert_array_equal(ya, ya2)          # same seed: identical
    assert (ya != yb).any()                         # seeds differ
    frac = float(np.mean(ya != y[:30]))
    assert 0.2 < frac < 0.8                         # ~half flipped


def test_drift_chunks_multiclass_rotation():
    x, _ = _data(n=40)
    y = (np.arange(40) % 5).astype(np.int32)
    src = ArrayChunks(x, y, 20)
    flip = np.array([0.0, 1.0], np.float32)
    drift = DriftChunks(src, flip=flip, n_classes=5, seed=0)
    _, y0 = drift.load(0)
    _, y1 = drift.load(1)
    np.testing.assert_array_equal(y0, y[:20])
    np.testing.assert_array_equal(y1, (y[20:] + 1) % 5)  # rotate, not negate
    assert y1.dtype == y.dtype


def test_drift_chunks_mean_shift_moves_inputs_only():
    x, y = _data(n=60)
    src = ArrayChunks(x, y, 12)
    shift = mean_shift_schedule(src.n_chunks, src.dim, magnitude=2.0,
                                start=0.5, kind="step")
    drift = DriftChunks(src, shift=shift, seed=0)
    for cid in range(src.n_chunks):
        xc, yc = src.load(cid)
        xd, yd = drift.load(cid)
        np.testing.assert_array_equal(yd, yc)       # inputs-only drift
        np.testing.assert_allclose(xd, xc + shift[cid], rtol=1e-6)


def test_drift_chunks_validation():
    x, y = _data(n=40)
    src = ArrayChunks(x, y, 10)
    with pytest.raises(ValueError, match="flip.*or.*shift|at least one"):
        DriftChunks(src)
    with pytest.raises(ValueError):
        DriftChunks(src, flip=np.zeros(3, np.float32))       # wrong n_chunks
    with pytest.raises(ValueError):
        DriftChunks(src, shift=np.zeros((4, 2), np.float32))  # wrong dim
    with pytest.raises(ValueError):
        label_flip_schedule(4, prob=1.5)
    with pytest.raises(ValueError):
        mean_shift_schedule(4, 5, kind="exp")
    with pytest.raises(ValueError):
        mean_shift_schedule(4, 5, direction=np.ones(3))


def test_prefetch_cancel_then_plan_reuses_cleanly(watchdog):
    """cancel(wait=True) then plan() on the SAME wrapper: the second epoch
    streams bitwise-correct blocks and leaves no stray worker (§16 pin)."""
    watchdog(120)
    x, y = _data(n=60)
    src = ArrayChunks(x, y, 12)
    pre = PrefetchChunks(src, depth=2)
    try:
        pre.plan([0, 1, 2, 3, 4])
        pre.load(0)                      # consume partially, then abandon
        pre.cancel(wait=True)
        assert _prefetch_threads() == []
        pre.plan([4, 2, 0])              # reuse: fresh plan, fresh worker
        for cid in (4, 2, 0):
            xp, yp = pre.load(cid)
            xs, ys = src.load(cid)
            np.testing.assert_array_equal(xp, xs)
            np.testing.assert_array_equal(yp, ys)
    finally:
        pre.close()
    assert _prefetch_threads() == []
    # cancel() on a never-planned / already-cancelled wrapper is a no-op
    pre.cancel(wait=True)
    pre.cancel()


def test_prefetch_worker_death_retries_and_resumes_bitwise(watchdog):
    """A load that dies on the prefetch worker mid-epoch is retried THERE,
    and the consumer-visible stream is bitwise the clean synchronous epoch
    (worker-death -> retry -> bitwise-resume, DESIGN.md §16)."""
    watchdog(120)
    from repro.data import (FaultSchedule, FaultyChunks, ResilienceReport,
                            RetryPolicy)

    x, y = _data(n=96)
    key = jax.random.PRNGKey(3)
    clean = list(iter_epoch(ArrayChunks(x, y, 16), key))
    faulty = FaultyChunks(ArrayChunks(x, y, 16),
                          FaultSchedule(io_chunks=(1, 4), io_attempts=2))
    rep = ResilienceReport()
    pol = RetryPolicy(max_attempts=3, base_delay_s=0.0, max_delay_s=0.0)
    got = list(iter_epoch(faulty, key, prefetch=2, retry=pol, report=rep))
    assert [p for p, _, _ in got] == [p for p, _, _ in clean]
    for (_, xa, ya), (_, xb, yb) in zip(got, clean):
        np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ya, yb)
    assert sorted(rep.recovered) == [(1, 2), (4, 2)]   # recovered on worker
    assert faulty.attempts(1) == 3 and faulty.attempts(4) == 3
    assert _prefetch_threads() == []
