"""Fault injection + retry/quarantine (ISSUE 10 tentpole, ingest layer):

  * ``FaultSchedule`` decisions are pure in ``(seed, chunk_id)`` — bitwise
    repeatable under repeated, out-of-order and prefetched loads;
  * ``load_chunk_with_retry`` recovers transient IO errors, stalls and
    truncated reads with bounded backoff, quarantines on exhaustion or
    persistent corruption, and propagates genuine bugs unchanged;
  * quarantine is a SKIP: the surviving chunk sequence of an epoch with a
    quarantined chunk is bitwise the sequence of an epoch where that chunk
    never existed (``skip_chunks`` constructs the comparison run).
"""
import jax
import numpy as np
import pytest

from repro.data import (ArrayChunks, ChunkQuarantined, CorruptChunkError,
                        FaultSchedule, FaultyChunks, PrefetchChunks,
                        ResilienceReport, RetryPolicy, TrainerCrash,
                        TransientIOError, TruncatedChunkError, iter_epoch,
                        load_chunk_with_retry)

_NO_SLEEP = lambda s: None   # noqa: E731 — tests never pay real backoff


def _data(n=96, d=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    return x, y


def _fast_policy(max_attempts=3):
    return RetryPolicy(max_attempts=max_attempts, base_delay_s=0.0,
                       max_delay_s=0.0)


def test_fault_schedule_pure_in_seed_and_chunk():
    sched = FaultSchedule(seed=7, p_io=0.5, p_stall=0.3, p_truncate=0.3,
                          p_nan=0.3)
    for cid in range(20):
        a = sched.for_chunk(cid)
        b = FaultSchedule(seed=7, p_io=0.5, p_stall=0.3, p_truncate=0.3,
                          p_nan=0.3).for_chunk(cid)
        assert a == b                       # pure: no instance state involved
    plans = [sched.for_chunk(c) for c in range(64)]
    other = [FaultSchedule(seed=8, p_io=0.5, p_stall=0.3, p_truncate=0.3,
                           p_nan=0.3).for_chunk(c) for c in range(64)]
    assert plans != other                   # the seed matters
    assert any(p.any for p in plans) and not all(p.any for p in plans)


def test_fault_schedule_explicit_chunks_force_faults():
    sched = FaultSchedule(seed=0, io_chunks=(3,), io_attempts=2,
                          stall_chunks=(4,), truncate_chunks=(5,),
                          nan_chunks=(6,), fatal_chunks=(7,),
                          crash_chunks=(8,))
    assert sched.for_chunk(3).io_attempts == 2
    assert sched.for_chunk(4).stall_s > 0
    assert sched.for_chunk(5).truncate
    assert sched.for_chunk(6).nan
    assert sched.for_chunk(7).fatal
    assert sched.for_chunk(8).crash
    assert not sched.for_chunk(0).any       # all p_* are 0: clean elsewhere


def test_transient_io_recovers_bitwise():
    x, y = _data()
    clean = ArrayChunks(x, y, 32)
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(io_chunks=(1,), io_attempts=2))
    rep = ResilienceReport()
    xb, yb = load_chunk_with_retry(src, 1, _fast_policy(3), report=rep,
                                   expected_rows=32, dim=6, sleep=_NO_SLEEP)
    xa, ya = clean.load(1)
    np.testing.assert_array_equal(xb, xa)   # recovery is bitwise
    np.testing.assert_array_equal(yb, ya)
    assert rep.retries == 2
    assert rep.recovered == [(1, 2)]
    assert src.attempts(1) == 3


def test_nan_poisoning_is_deterministic():
    x, y = _data()
    sched = FaultSchedule(seed=3, nan_chunks=(2,), nan_rows=6)
    src = FaultyChunks(ArrayChunks(x, y, 32), sched)
    xa, _ = src.load(2)
    xb, _ = src.load(2)
    np.testing.assert_array_equal(xa, xb)   # pure in (seed, chunk_id)
    bad = ~np.isfinite(xa).all(axis=1)
    assert bad.sum() == 6
    assert np.isnan(xa).any() and np.isinf(xa).any()
    xc, _ = src.load(0)                     # other chunks untouched
    np.testing.assert_array_equal(xc, x[:32])


def test_truncated_read_detected_and_recovered():
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(truncate_chunks=(0,)))
    xs, ys = src.load(0)                    # raw wrapper: short first read
    assert xs.shape[0] == 16 and ys.shape[0] == 16
    src2 = FaultyChunks(ArrayChunks(x, y, 32),
                        FaultSchedule(truncate_chunks=(0,)))
    rep = ResilienceReport()
    xr, _ = load_chunk_with_retry(src2, 0, _fast_policy(3), report=rep,
                                  expected_rows=32, dim=6, sleep=_NO_SLEEP)
    np.testing.assert_array_equal(xr, x[:32])
    assert rep.retries == 1 and rep.recovered == [(0, 1)]


def test_io_plus_truncate_compose():
    """Truncation fires on the first OTHERWISE-successful read, so it still
    bites after the transient IO attempts clear."""
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(io_chunks=(0,), io_attempts=1,
                                     truncate_chunks=(0,)))
    xr, _ = load_chunk_with_retry(src, 0, _fast_policy(4), expected_rows=32,
                                  dim=6, sleep=_NO_SLEEP)
    np.testing.assert_array_equal(xr, x[:32])
    assert src.attempts(0) == 3             # io fail, short read, full read


def test_retry_exhaustion_quarantines():
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(io_chunks=(1,), io_attempts=99))
    rep = ResilienceReport()
    with pytest.raises(ChunkQuarantined) as ei:
        load_chunk_with_retry(src, 1, _fast_policy(2), report=rep,
                              sleep=_NO_SLEEP)
    assert ei.value.chunk_id == 1 and ei.value.attempts == 2
    assert isinstance(ei.value.cause, TransientIOError)
    assert rep.retries == 2                 # both attempts tallied
    assert rep.quarantined == []            # tallied by the skipping caller


def test_fatal_chunk_quarantines_immediately():
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(fatal_chunks=(2,)))
    with pytest.raises(ChunkQuarantined) as ei:
        load_chunk_with_retry(src, 2, _fast_policy(5), sleep=_NO_SLEEP)
    assert ei.value.attempts == 1           # no retry budget burned
    assert isinstance(ei.value.cause, CorruptChunkError)
    assert src.attempts(2) == 1


def test_unknown_exception_propagates_unchanged():
    class Bug(ArrayChunks):
        def load(self, i):
            raise KeyError("a genuine bug, not an IO fault")

    x, y = _data()
    with pytest.raises(KeyError):
        load_chunk_with_retry(Bug(x, y, 32), 0, _fast_policy(5),
                              sleep=_NO_SLEEP)


def test_trainer_crash_propagates_and_clears_on_restart():
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32),
                       FaultSchedule(crash_chunks=(1,)))
    with pytest.raises(TrainerCrash):
        load_chunk_with_retry(src, 1, _fast_policy(3), sleep=_NO_SLEEP)
    # the restarted trainer (same process, same wrapper) gets past it
    xr, _ = load_chunk_with_retry(src, 1, _fast_policy(3), expected_rows=32,
                                  dim=6, sleep=_NO_SLEEP)
    np.testing.assert_array_equal(xr, x[32:64])


def test_backoff_is_exponential_and_clipped():
    pol = RetryPolicy(max_attempts=6, base_delay_s=0.01, max_delay_s=0.05)
    assert [pol.delay_s(a) for a in range(5)] == \
        [0.01, 0.02, 0.04, 0.05, 0.05]
    slept = []
    src = FaultyChunks(ArrayChunks(*_data(), 32),
                       FaultSchedule(io_chunks=(0,), io_attempts=3))
    load_chunk_with_retry(src, 0, RetryPolicy(max_attempts=4,
                                              base_delay_s=0.01,
                                              max_delay_s=0.02),
                          expected_rows=32, sleep=slept.append)
    assert slept == [0.01, 0.02, 0.02]      # one backoff per failed attempt
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)


@pytest.mark.parametrize("prefetch", [0, 2])
def test_quarantine_equals_skip_chunks_bitwise(prefetch, watchdog):
    """The tentpole equivalence: an epoch that QUARANTINES chunk q yields a
    surviving (position, x, y) sequence bitwise identical to an epoch where
    q is skipped up front — with and without the prefetch worker."""
    watchdog(120)
    x, y = _data(n=160)
    key = jax.random.PRNGKey(5)
    clean = ArrayChunks(x, y, 32)
    faulty = FaultyChunks(ArrayChunks(x, y, 32),
                          FaultSchedule(fatal_chunks=(3,), io_chunks=(1,),
                                        io_attempts=1))
    rep = ResilienceReport()
    got = list(iter_epoch(faulty, key, retry=_fast_policy(3), report=rep,
                          prefetch=prefetch))
    want = list(iter_epoch(clean, key, skip_chunks=(3,)))
    assert [p for p, _, _ in got] == [p for p, _, _ in want]
    for (_, xa, ya), (_, xb, yb) in zip(got, want):
        np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ya, yb)
    assert rep.quarantined_chunks() == [3]  # counted exactly once
    assert rep.recovered == [(1, 1)]        # the io fault recovered


def test_iter_epoch_without_retry_is_the_old_path():
    """retry=None (the default): any load failure propagates — the clean
    path has no quarantine semantics bolted on."""
    x, y = _data()
    faulty = FaultyChunks(ArrayChunks(x, y, 32),
                          FaultSchedule(fatal_chunks=(0,)))
    with pytest.raises(CorruptChunkError):
        list(iter_epoch(faulty, jax.random.PRNGKey(0)))


def test_iter_epoch_retry_on_prefetch_worker(watchdog):
    """With a plan, retries run on the worker (the consumer never sees the
    transient error) and the stream is bitwise the clean sync epoch."""
    watchdog(120)
    x, y = _data(n=160)
    key = jax.random.PRNGKey(1)
    faulty = FaultyChunks(ArrayChunks(x, y, 32),
                          FaultSchedule(io_chunks=(0, 2), io_attempts=2,
                                        stall_chunks=(1,), stall_s=0.001))
    rep = ResilienceReport()
    got = list(iter_epoch(faulty, key, retry=_fast_policy(3), report=rep,
                          prefetch=2))
    want = list(iter_epoch(ArrayChunks(x, y, 32), key))
    assert [p for p, _, _ in got] == [p for p, _, _ in want]
    for (_, xa, _), (_, xb, _) in zip(got, want):
        np.testing.assert_array_equal(xa, xb)
    assert sorted(rep.recovered) == [(0, 2), (2, 2)]


def test_prefetch_wrapper_mirrors_geometry():
    x, y = _data()
    src = FaultyChunks(ArrayChunks(x, y, 32), FaultSchedule())
    assert src.chunk_lens == [32, 32, 32]
    assert src.dim == 6 and src.n_chunks == 3 and src.n_rows == 96
    pre = PrefetchChunks(src, depth=2, retry=_fast_policy(3))
    assert pre.chunk_lens == src.chunk_lens and pre.dim == src.dim
