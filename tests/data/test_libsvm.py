"""LIBSVM parser: binary sign-mapping vs raw multi-class labels, round trips."""
import os

import numpy as np

from repro.data import dump_libsvm, parse_libsvm


def test_parse_binary_maps_to_signs():
    lines = ["+1 1:0.5 3:2.0", "-1 2:1.5", "0 1:1.0"]
    x, y = parse_libsvm(lines)
    assert x.shape == (3, 3)
    np.testing.assert_array_equal(y, [1.0, -1.0, -1.0])   # 0 is "not positive"
    assert x[0, 0] == 0.5 and x[0, 2] == 2.0 and x[1, 1] == 1.5


def test_parse_raw_labels_survive():
    """binary=False keeps multi-class labels untouched (satellite fix: the
    old parser silently collapsed every label to +-1)."""
    lines = ["3 1:1.0", "0 2:1.0", "7 1:0.5 2:0.5", "1 1:2.0"]
    _, y = parse_libsvm(lines, binary=False)
    np.testing.assert_array_equal(y, [3.0, 0.0, 7.0, 1.0])


def test_multiclass_roundtrip_with_dump(tmp_path):
    rng = np.random.default_rng(0)
    x = np.round(rng.normal(size=(20, 6)).astype(np.float32), 3)
    x[rng.random(x.shape) < 0.3] = 0.0          # exercise sparse encoding
    y = rng.integers(0, 5, 20).astype(np.float32)
    path = os.path.join(tmp_path, "mc.libsvm")
    dump_libsvm(path, x, y)
    x2, y2 = parse_libsvm(path, n_features=6, binary=False)
    np.testing.assert_array_equal(y2, y)
    np.testing.assert_allclose(x2, x, rtol=1e-5, atol=1e-6)


def test_binary_roundtrip_unchanged(tmp_path):
    rng = np.random.default_rng(1)
    x = np.round(rng.normal(size=(10, 4)).astype(np.float32), 3)
    y = np.where(rng.random(10) < 0.5, 1.0, -1.0).astype(np.float32)
    path = os.path.join(tmp_path, "bin.libsvm")
    dump_libsvm(path, x, y)
    x2, y2 = parse_libsvm(path, n_features=4)
    np.testing.assert_array_equal(y2, y)
    np.testing.assert_allclose(x2, x, rtol=1e-5, atol=1e-6)
