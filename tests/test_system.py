"""End-to-end behaviour tests for the paper's system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import BSGDConfig, accuracy, decision_function, fit
from repro.data import make_two_moons, train_test_split
from repro.launch.train import train_loop


def test_bsgd_end_to_end_beats_chance_under_budget():
    """The paper's full pipeline: stream -> BSGD + lookup merging -> model
    that fits in the budget and classifies well."""
    key = jax.random.PRNGKey(0)
    x, y = make_two_moons(key, 2400, noise=0.18)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = BSGDConfig(budget=32, lambda_=1e-4, gamma=2.0, method="lookup-wd")
    st = fit(cfg, xtr, ytr, epochs=3, seed=0)
    assert int(st.count) <= 32
    assert float(accuracy(st, xte, yte, cfg.gamma)) > 0.96
    assert int(st.n_merges) > 50  # the budget did real work


def test_lookup_and_gss_train_nearly_identical_models():
    """Plug-in-replacement claim: the two solvers produce models whose
    decision functions agree on nearly all test points."""
    key = jax.random.PRNGKey(1)
    x, y = make_two_moons(key, 1600, noise=0.15)
    (xtr, ytr), (xte, _) = train_test_split(x, y)
    states = {}
    for method in ("gss", "lookup-wd"):
        cfg = BSGDConfig(budget=30, lambda_=1e-4, gamma=2.0, method=method)
        states[method] = fit(cfg, xtr, ytr, epochs=2, seed=0)
    f1 = decision_function(states["gss"], xte, 2.0)
    f2 = decision_function(states["lookup-wd"], xte, 2.0)
    agree = float(jnp.mean((jnp.sign(f1) == jnp.sign(f2)).astype(jnp.float32)))
    assert agree > 0.97, agree


def test_lm_training_learns_bigram_structure():
    """The LM substrate end-to-end: loss approaches the bigram entropy floor
    (impossible on random tokens — proves real learning)."""
    cfg = get_smoke("smollm_360m")
    cfg = dataclasses.replace(cfg, vocab_size=64, n_layers=2, d_model=64)
    metrics = train_loop(cfg, steps=60, batch_size=8, seq_len=32,
                         ckpt_dir=None, lr=5e-3, verbose=False, seed=0)
    uniform = float(np.log(cfg.vocab_size))
    last = float(np.mean(metrics["losses"][-5:]))
    assert last < uniform - 0.25, (last, uniform, metrics["bigram_floor"])


def test_checkpoint_resume_continues_not_restarts(tmp_path):
    """Kill-and-resume produces the same trajectory as an uninterrupted run
    (fault tolerance is semantically transparent)."""
    cfg = get_smoke("smollm_360m")
    cfg = dataclasses.replace(cfg, vocab_size=64, n_layers=2, d_model=64)
    d1 = str(tmp_path / "a")
    m_full = train_loop(cfg, steps=20, batch_size=4, seq_len=16, ckpt_dir=d1,
                        ckpt_every=10, verbose=False, seed=3)
    d2 = str(tmp_path / "b")
    train_loop(cfg, steps=10, batch_size=4, seq_len=16, ckpt_dir=d2,
               ckpt_every=10, verbose=False, seed=3, schedule_total=20)
    m_res = train_loop(cfg, steps=20, batch_size=4, seq_len=16, ckpt_dir=d2,
                       ckpt_every=10, verbose=False, seed=3)
    assert m_res["resumed_from"] == 10
    np.testing.assert_allclose(m_full["losses"][10:], m_res["losses"],
                               rtol=2e-3, atol=2e-3)
