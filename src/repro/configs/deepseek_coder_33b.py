"""DeepSeek-Coder-33B: llama-arch dense GQA. [arXiv:2401.14196; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_coder_33b",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab_size=32256, head_dim=128, rope_theta=100000.0,
    notes="pure full attention: long_500k skipped",
)
