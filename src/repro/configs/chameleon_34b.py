"""Chameleon-34B: early-fusion VLM backbone, qk-norm. [arXiv:2405.09818]
VQ image tokenizer is a stub: inputs are already token ids in the shared
65536 vocab (text + image codes)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon_34b",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, head_dim=128, qk_norm=True,
    notes="early-fusion: frontend stubbed to token ids; long_500k skipped",
)
