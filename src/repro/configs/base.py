"""Architecture config schema for the assigned LM-family architectures.

One frozen dataclass describes every supported family: dense GQA decoders,
encoder-only (hubert), SSM (mamba2), hybrid interleave (jamba), MLA + MoE
(deepseek v2/v3), early-fusion VLM backbones (chameleon).  ``layer_plan()``
expands the per-layer (mixer, ffn) kinds; ``scan_unit``/``prefix_layers``
derive how layers group into a ``lax.scan`` body (homogeneous repeating unit)
plus an unrolled prefix (e.g. deepseek-v3's first 3 dense layers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0              # per-expert FFN hidden size
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router: str = "softmax"        # softmax | sigmoid (v3 aux-free style)
    layer_period: int = 1          # MoE FFN on layers with i % period == offset
    layer_offset: int = 0
    first_dense: int = 0           # first N layers use the dense FFN
    routed_scale: float = 1.0      # scaling factor on routed output (deepseek)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256               # SSD chunk length (must divide seq len)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    absorb_decode: bool = False    # weight-absorbed decode path (perf option)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # mixer selection
    attn_kind: str = "gqa"         # gqa | mla
    attn_layer_period: int = 1     # hybrid: attn on i % period == offset, else mamba
    attn_layer_offset: int = 0
    pure_ssm: bool = False         # all layers mamba (attn_* ignored)
    # attention details
    causal: bool = True
    is_encoder: bool = False
    sliding_window: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # inputs
    input_kind: str = "tokens"     # tokens | frames (audio stub frontend)
    frame_dim: int = 512
    # submodules
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # output / misc
    mlp_act: str = "swiglu"        # swiglu (3-matrix) | gelu (2-matrix, hubert)
    dense_ff: Optional[int] = None  # FFN width on dense layers of MoE archs
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mtp_depth: int = 0             # deepseek-v3 multi-token prediction blocks
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: bool = False      # unroll layer groups (dry-run cost accounting:
                                   # XLA counts while-loop bodies once, so the
                                   # roofline lowers the unrolled form)
    seq_shard_attn: Optional[tuple] = None
                                   # context-parallel attention: when head
                                   # counts don't divide the model axis, shard
                                   # the QUERY sequence dim over `model`
                                   # instead of replicating attention compute.
                                   # Value = the batch (dp) mesh axes, e.g.
                                   # ("data",).  §Perf hillclimb lever.
    attn_chunk: int = 2048         # KV-chunked (online-softmax) attention above this
    notes: str = ""

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 128) * 128  # MXU/vocab-shard friendly

    def mixer_kind(self, i: int) -> str:
        if self.pure_ssm:
            return "mamba"
        if self.attn_layer_period == 1:
            return "attn"
        return "attn" if i % self.attn_layer_period == self.attn_layer_offset else "mamba"

    def ffn_kind(self, i: int) -> str:
        m = self.moe
        if m is None:
            return "dense" if self.d_ff > 0 else "none"   # mamba2: mixer-only
        if i < m.first_dense:
            return "dense"
        return "moe" if i % m.layer_period == m.layer_offset else "dense"

    def layer_plan(self) -> tuple[tuple[str, str], ...]:
        return tuple((self.mixer_kind(i), self.ffn_kind(i)) for i in range(self.n_layers))

    @property
    def prefix_layers(self) -> int:
        """Unrolled prefix (layers that break the repeating pattern)."""
        return self.moe.first_dense if self.moe is not None else 0

    @property
    def scan_unit(self) -> int:
        """Smallest repeating unit among the post-prefix layers."""
        plan = self.layer_plan()[self.prefix_layers:]
        n = len(plan)
        for unit in range(1, n + 1):
            if n % unit:
                continue
            if all(plan[i] == plan[i % unit] for i in range(n)):
                return unit
        return n

    @property
    def n_scan_groups(self) -> int:
        return (self.n_layers - self.prefix_layers) // self.scan_unit

    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline bookkeeping)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab_padded * d  # embed
        if not self.tie_embeddings and self.input_kind == "tokens":
            total += d * self.vocab_padded  # lm head
        if self.input_kind == "frames":
            total += self.frame_dim * d + d * self.vocab_padded
        for kind, ffn in self.layer_plan():
            total += 2 * d  # norms
            if kind == "attn":
                if self.attn_kind == "mla":
                    m = self.mla
                    q_in = m.q_lora_rank if m.q_lora_rank else d
                    total += (d * m.q_lora_rank if m.q_lora_rank else 0)
                    total += q_in * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
            else:
                s = self.ssm
                d_in = s.expand * d
                n_h = d_in // s.head_dim
                conv_ch = d_in + 2 * s.n_groups * s.d_state
                total += d * 2 * d_in            # z, x projections
                total += d * 2 * s.n_groups * s.d_state   # B, C projections
                total += d * n_h + 2 * n_h       # dt proj + A_log + dt_bias
                total += conv_ch * s.d_conv + conv_ch     # conv + bias
                total += n_h                      # D skip
                total += d_in                     # gate norm
                total += d_in * d                 # out proj
            if ffn == "dense":
                ff = self.d_ff if self.moe is None else (self.moe_dense_ff())
                total += (3 if self.mlp_act == "swiglu" else 2) * d * ff
            elif ffn == "moe":
                m = self.moe
                total += d * m.num_experts        # router
                total += m.num_experts * 3 * d * m.d_expert
                total += m.n_shared * 3 * d * m.d_expert
        total += d  # final norm
        return total

    def moe_dense_ff(self) -> int:
        """Dense-FFN width used on non-MoE layers of MoE archs."""
        return self.dense_ff if self.dense_ff is not None else self.d_ff

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert
        inactive = sum(1 for _, f in self.layer_plan() if f == "moe") * \
            (m.num_experts - m.top_k) * per_expert
        return self.param_count() - inactive

    def scaled_down(self, **overrides) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        defaults = dict(
            n_layers=max(2, self.scan_unit) + self.prefix_layers if self.moe else min(2, self.n_layers),
            d_model=64, n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128, vocab_size=128, head_dim=16,
            dtype="float32", attn_chunk=64,
        )
        if self.moe is not None:
            defaults["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=32,
                n_shared=min(self.moe.n_shared, 1))
        if self.dense_ff is not None:
            defaults["dense_ff"] = 128
        if self.mla is not None:
            defaults["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=32, q_lora_rank=32, qk_nope_dim=16,
                qk_rope_dim=8, v_head_dim=16)
        if self.ssm is not None:
            defaults["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.sliding_window is not None:
            defaults["sliding_window"] = 32
        defaults.update(overrides)
        return dataclasses.replace(self, **defaults)
