"""Architecture configs: one module per assigned arch + the registry."""
from .base import ArchConfig, MLACfg, MoECfg, SSMCfg
from .registry import ARCH_NAMES, SHAPES, all_cells, cell_applicable, get, get_smoke

__all__ = ["ArchConfig", "MLACfg", "MoECfg", "SSMCfg", "ARCH_NAMES", "SHAPES",
           "all_cells", "cell_applicable", "get", "get_smoke"]
