"""HuBERT-XLarge backbone: 48L encoder, d=1280, 16H, ff=5120, 504 clusters.

[arXiv:2106.07447]  Audio frontend (CNN feature extractor + k-means targets)
is a stub per the assignment: inputs are precomputed 512-d frame embeddings.
Positional information comes from RoPE instead of HuBERT's conv-pos embedding
(noted hardware adaptation: RoPE composes with the shared attention core).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert_xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, head_dim=80,
    is_encoder=True, causal=False, input_kind="frames", frame_dim=512,
    mlp_act="gelu",
    notes="encoder-only; decode shapes skipped",
)
