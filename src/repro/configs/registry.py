"""Registry of assigned architectures and their shape sets."""
from __future__ import annotations

import dataclasses
import importlib

from .base import ArchConfig

ARCH_NAMES = (
    "hubert_xlarge", "mamba2_130m", "deepseek_coder_33b", "h2o_danube3_4b",
    "yi_9b", "smollm_360m", "jamba_v01_52b", "chameleon_34b",
    "deepseek_v2_236b", "deepseek_v3_671b",
)

# Assigned input shapes: (seq_len, global_batch) per workload.
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, step="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, step="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, step="decode"),
}


def get(name: str) -> ArchConfig:
    name = name.replace("-", "_")
    if name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_smoke(name: str, **overrides) -> ArchConfig:
    return get(name).scaled_down(**overrides)


def cell_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Whether (arch x shape) is runnable, with the DESIGN.md skip reason."""
    shape = SHAPES[shape_name]
    if shape["step"] == "decode":
        if cfg.is_encoder:
            return False, "encoder-only: no decode step"
        if shape_name == "long_500k":
            has_subquadratic = (cfg.pure_ssm or cfg.attn_layer_period > 1
                                or cfg.sliding_window is not None)
            if not has_subquadratic:
                return False, "pure full attention: long_500k skipped (assignment rule)"
    return True, ""


def all_cells():
    """Yield (arch_name, shape_name, applicable, reason) for all 40 cells."""
    for a in ARCH_NAMES:
        cfg = get(a)
        for s in SHAPES:
            ok, reason = cell_applicable(cfg, s)
            yield a, s, ok, reason
