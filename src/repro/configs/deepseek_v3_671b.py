"""DeepSeek-V3 (671B total / 37B active): MLA + MoE 256e top-8 (sigmoid
router, 1 shared), MTP depth 1.  [arXiv:2412.19437; hf]"""
from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek_v3_671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab_size=129280,
    attn_kind="mla",
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
               qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(num_experts=256, top_k=8, n_shared=1, d_expert=2048,
               first_dense=3, router="sigmoid"),
    dense_ff=18432, mtp_depth=1,
    notes="MTP implemented as one extra depth-1 prediction block (simplified)",
)
