"""DeepSeek-V2 (236B total / 21B active): MLA (kv_lora=512) + MoE 160e top-6,
2 shared experts.  [arXiv:2405.04434; hf]"""
from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek_v2_236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=1536,
    vocab_size=102400,
    attn_kind="mla",
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
               qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(num_experts=160, top_k=6, n_shared=2, d_expert=1536,
               first_dense=1),
    dense_ff=12288,
    notes="MLA latent cache; long_500k skipped (full attention)",
)
