"""SmolLM-360M: small llama-arch (15 heads / 5 kv). [hf:HuggingFaceTB/SmolLM]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm_360m",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152, head_dim=64, tie_embeddings=True,
    notes="15 heads not divisible by model axis -> head dims replicated, ffn sharded",
)
