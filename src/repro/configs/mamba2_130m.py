"""Mamba2-130m: 24 SSD layers, d=768, attention-free, no FFN. [arXiv:2405.21060]"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2_130m",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab_size=50280, head_dim=64,
    pure_ssm=True, tie_embeddings=True,
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    notes="SSD state-space duality; O(1)-state decode makes long_500k native",
)
