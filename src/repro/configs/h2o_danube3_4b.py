"""H2O-Danube3-4B: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]  SWA window 4096 -> ring-buffer KV cache, so long_500k
decode is sub-quadratic (cache bounded at the window size)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o_danube3_4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120, sliding_window=4096,
    notes="SWA ring cache bounds long-context decode memory",
)
