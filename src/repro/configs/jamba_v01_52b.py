"""Jamba-v0.1 (52B total / 12B active): hybrid Mamba+attention 1:7 with MoE.
[arXiv:2403.19887; hf]  Layer unit of 8: attention at offset 4, mamba
elsewhere; MoE (16 experts, top-2) on every other layer.  The mamba mixer is
realized with the SSD (mamba-2) formulation at d_state=16 (DESIGN.md notes
this substitution; the assignment targets the hybrid structure)."""
from .base import ArchConfig, MoECfg, SSMCfg

CONFIG = ArchConfig(
    name="jamba_v01_52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    attn_layer_period=8, attn_layer_offset=4,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=128),
    moe=MoECfg(num_experts=16, top_k=2, d_expert=14336, layer_period=2,
               layer_offset=1),
    notes="hybrid: mamba layers O(1) decode; 4 attn layers carry the 500k cache",
)
