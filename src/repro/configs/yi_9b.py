"""Yi-9B: llama-arch GQA (kv=4). [arXiv:2403.04652; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi_9b",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000, head_dim=128, rope_theta=5_000_000.0,
    notes="pure full attention: long_500k skipped; kv=4 < model axis -> KV replicated",
)
