from .optimizer import AdamW, SGD, OptState, cosine_schedule, global_norm
