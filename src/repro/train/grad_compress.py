"""Gradient compression for data-parallel all-reduce: int8 + error feedback.

At 1000+ nodes the DP all-reduce of bf16 gradients dominates the step for
communication-bound configs; 8-bit quantization cuts wire bytes 2x (4x vs
fp32) at negligible quality cost when the quantization *error is fed back*
into the next step (Seide et al. / 1-bit Adam lineage).

``compressed_psum`` is the shard_map building block: quantize locally ->
psum the int32-accumulated payload -> dequantize; the residual pytree is
threaded through the training step like optimizer state.  ``wrap_grad_fn``
bolts it onto any ``value_and_grad`` for DP-only meshes; the pjit/GSPMD path
keeps XLA-chosen collectives, so this is the explicit-deployment option (and
benchmarked in EXPERIMENTS.md §Perf as a collective-term lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """Symmetric per-tensor int8: returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual):
    """Quantize grads + residual; returns (payload, new_residual).

    payload: {"q": int8 tree, "scale": scalar tree} — what goes on the wire.
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    q_and_scale = jax.tree.map(quantize_int8, corrected)
    q = jax.tree.map(lambda t: t[0], q_and_scale,
                     is_leaf=lambda x: isinstance(x, tuple))
    scale = jax.tree.map(lambda t: t[1], q_and_scale,
                         is_leaf=lambda x: isinstance(x, tuple))
    decoded = jax.tree.map(dequantize_int8, q, scale)
    new_residual = jax.tree.map(lambda c, d: c - d, corrected, decoded)
    return {"q": q, "scale": scale}, new_residual


def decompress_tree(payload):
    return jax.tree.map(dequantize_int8, payload["q"], payload["scale"])


def compressed_psum(grads, residual, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    A shared per-tensor scale (pmax of local absmax — one scalar round) makes
    the int8 sum exact to dequantize; payloads accumulate in int32 (int8
    would overflow).  Wire bytes ~= 1/2 of bf16, 1/4 of fp32.
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    scale = jax.tree.map(
        lambda c: jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(c)), 1e-12),
                               axis_name) / 127.0, corrected)
    q = jax.tree.map(
        lambda c, s: jnp.clip(jnp.round(c / s), -127, 127).astype(jnp.int8),
        corrected, scale)
    new_residual = jax.tree.map(lambda c, qq, s: c - qq.astype(jnp.float32) * s,
                                corrected, q, scale)
    n = jax.lax.psum(1, axis_name)
    summed_q = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    mean_grads = jax.tree.map(lambda sq, s: sq.astype(jnp.float32) * s / n,
                              summed_q, scale)
    return mean_grads, new_residual
