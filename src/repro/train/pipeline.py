"""GPipe-style pipeline parallelism over a mesh axis via shard_map + ppermute.

The layer-group dimension maps onto a ``pipe`` mesh axis: each device owns
``n_groups / pipe`` consecutive groups and microbatches flow through stages
with ``jax.lax.ppermute``.  The schedule below is the classic GPipe fill/
drain loop expressed as a single ``lax.scan`` over ``n_micro + n_stages - 1``
ticks — every tick each stage processes one in-flight microbatch and permutes
activations to its neighbour, so compute and the permute collective overlap
across stages.

This is the optional pod-axis deployment (``pod`` axis as ``pipe`` instead
of pure DP) — cross-pod traffic becomes point-to-point activation passing
(DCN-friendly) instead of gradient all-reduce.  Correctness is asserted
against the single-device forward in tests/distributed/.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(body_fn, n_stages: int, params_stacked, x_micro,
                     mesh, axis: str = "pipe"):
    """Run ``body_fn(unit_params, x) -> x`` over stages on ``axis``.

    params_stacked: leaves with leading dim ``n_groups`` (consecutive groups
    per stage); x_micro: (n_micro, micro_batch, ...) activations already
    embedded.  Returns final-stage activations in the same layout.
    """
    n_micro = x_micro.shape[0]

    def stage_fn(local_params, xs):
        # local_params: leading dim n_groups/n_stages (this stage's groups)
        stage = jax.lax.axis_index(axis)
        ticks = n_micro + n_stages - 1

        def apply_stage(x):
            def step(c, up):
                return body_fn(up, c), None
            out, _ = jax.lax.scan(step, x, local_params)
            return out

        def tick(carry, t):
            buf, outs = carry                     # buf: (micro_batch, ...)
            # stage s works on microbatch (t - s) when 0 <= t-s < n_micro
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests a fresh microbatch at each fill tick
            fresh = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            x_in = jnp.where((stage == 0) & active, fresh, buf)
            y = apply_stage(x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = (stage == n_stages - 1) & active
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y,
                                jax.lax.dynamic_index_in_dim(outs, done_idx, 0,
                                                             keepdims=False)),
                done_idx, axis=0)
            # pass activations downstream (ring permute; wrap is ignored)
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (y_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # finished microbatches live on the LAST stage; broadcast them so the
        # replicated out_specs sees the real results on every shard
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(stage_fn, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_micro)
