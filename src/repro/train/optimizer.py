"""Optimizers (AdamW, momentum SGD) and LR schedules — no optax dependency.

Optimizer state is a plain pytree mirroring the params (fp32 moments), so the
checkpointer and the sharding resolver treat it like any other tree: each
moment inherits its parameter's PartitionSpec (ZeRO-style when fsdp is on).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, m=new_m, v=new_v)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable | float = 1e-2
    momentum: float = 0.9

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params), v={})

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, g, m):
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat = jax.tree.map(upd, params, grads, state.m)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, m=new_m, v={})
