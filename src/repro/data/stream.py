"""Chunked host-side data pipeline for out-of-core (streaming) training.

The budgeted state is the only thing that must stay resident during BSGD
training (Zhao et al. 2012; Picard 2018) — the data itself can stream.  This
module provides the host side of that: *chunk sources* exposing a dataset as
``n_chunks`` independently-loadable ``(x, y)`` numpy blocks, and the
deterministic shuffle used by the streaming trainers in ``core.bsgd`` /
``core.multiclass``.

Chunk sources (all share the same small interface — ``n_chunks``,
``chunk_lens``, ``n_rows``, ``dim``, ``load(i) -> (x, y)``, iteration):

  * ``ArrayChunks``  — view over in-memory arrays (testing / ``--stream``
    flags on the examples; no copy until a chunk is loaded);
  * ``FileChunks``   — sharded ``.npz`` files (keys ``x``/``y``) or
    ``(x.npy, y.npy)`` path pairs, one shard per chunk; only the shard being
    trained on is ever resident (``write_npz_chunks`` is the writer);
  * ``LibsvmChunks`` — incremental ``parse_libsvm`` straight from a LIBSVM
    text file: init scans the file once recording chunk byte offsets (and the
    feature count if not given), ``load(i)`` seeks and parses one chunk.

Deterministic shuffle contract (DESIGN.md §9): an epoch's order is the
composition of a *chunk-order* permutation and one *intra-chunk* permutation
per chunk, both derived from the epoch key — ``chunk_order(key, n_chunks)``
and ``intra_perm(key, chunk_id, len)``.  Intra-chunk permutations are keyed
by chunk *id*, not stream position, so the realized global row order
(``epoch_permutation``) depends only on the key.  This is what makes streamed
training reproducible, resumable from a chunk cursor, and comparable
row-for-row against the in-memory ``train_epoch`` (the equivalence tests).
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from .libsvm import parse_libsvm


class ChunkSource:
    """Base chunk source: a dataset as independently-loadable (x, y) blocks.

    Subclasses populate ``chunk_lens`` (rows per chunk) and ``dim`` in
    ``__init__`` and implement ``load(i)``.  Iterating yields chunks in
    natural order; shuffled iteration is the trainers' job (``chunk_order`` /
    ``intra_perm``).
    """

    chunk_lens: list[int]
    dim: int

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_lens)

    @property
    def n_rows(self) -> int:
        return int(sum(self.chunk_lens))

    def load(self, i: int):
        """Return chunk ``i`` as ``(x (rows, dim) float32, y (rows,))``."""
        raise NotImplementedError

    def __iter__(self):
        for i in range(self.n_chunks):
            yield self.load(i)

    def chunk_offsets(self) -> np.ndarray:
        """Global row id of each chunk's first row; shape (n_chunks + 1,)."""
        return np.concatenate([[0], np.cumsum(self.chunk_lens)]).astype(np.int64)


class ArrayChunks(ChunkSource):
    """In-memory arrays viewed as ``ceil(n / chunk_rows)`` chunks (no copy)."""

    def __init__(self, x, y, chunk_rows: int):
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows={chunk_rows} < 1")
        self.x, self.y = np.asarray(x), np.asarray(y)
        if self.x.shape[0] != self.y.shape[0]:
            raise ValueError(f"x rows {self.x.shape[0]} != y rows "
                             f"{self.y.shape[0]}")
        n = self.x.shape[0]
        self.chunk_rows = chunk_rows
        self.chunk_lens = [min(chunk_rows, n - s)
                           for s in range(0, n, chunk_rows)]
        self.dim = int(self.x.shape[1])

    def load(self, i: int):
        s = i * self.chunk_rows
        e = s + self.chunk_lens[i]
        return self.x[s:e], self.y[s:e]


class FileChunks(ChunkSource):
    """Sharded on-disk chunks: ``.npz`` paths (keys x/y) or (x.npy, y.npy)
    pairs, one shard per chunk; only one shard is resident at a time.

    Init reads each shard's ``y`` (tiny) for the chunk lengths and each
    shard's ``x`` .npy *header* for row/dim validation — the feature blocks
    stay on disk until ``load``.
    """

    def __init__(self, paths):
        if not paths:
            raise ValueError("FileChunks needs at least one shard path")
        self.paths = list(paths)
        self.chunk_lens = []
        self.dim = None
        for p in self.paths:
            _, y = self._read(p, y_only=True)
            x_shape = self._x_shape(p)      # header only, no data read
            if x_shape[0] != y.shape[0]:
                raise ValueError(f"{p}: x rows {x_shape[0]} != y rows "
                                 f"{y.shape[0]}")
            if self.dim is None:
                self.dim = int(x_shape[1])
            elif x_shape[1] != self.dim:
                raise ValueError(f"{p}: dim {x_shape[1]} != {self.dim}")
            self.chunk_lens.append(int(y.shape[0]))

    @staticmethod
    def _npy_shape(f) -> tuple:
        """Shape from an open .npy stream's header alone (no data read)."""
        from numpy.lib import format as npfmt

        ver = npfmt.read_magic(f)
        hdr = (npfmt.read_array_header_1_0 if ver == (1, 0)
               else npfmt.read_array_header_2_0)
        return hdr(f)[0]

    @classmethod
    def _x_shape(cls, p) -> tuple:
        if isinstance(p, (tuple, list)):
            with open(p[0], "rb") as f:
                return cls._npy_shape(f)
        import zipfile

        with zipfile.ZipFile(p) as z, z.open("x.npy") as f:
            return cls._npy_shape(f)

    @staticmethod
    def _read(p, *, y_only: bool = False):
        if isinstance(p, (tuple, list)):
            xp, yp = p
            y = np.load(yp, mmap_mode="r" if y_only else None)
            if y_only:
                return None, y
            return np.asarray(np.load(xp)), np.asarray(y)
        with np.load(p) as z:
            if y_only:
                return None, z["y"]
            return z["x"], z["y"]

    def load(self, i: int):
        x, y = self._read(self.paths[i])
        return np.asarray(x), np.asarray(y)


class LibsvmChunks(ChunkSource):
    """Incremental LIBSVM parsing: chunk byte offsets scanned once at init,
    ``load(i)`` seeks and parses ``chunk_rows`` lines with O(chunk) memory.

    ``n_features`` fixes the feature dimension across chunks (a chunk that
    happens to omit the trailing features must still produce full-width
    rows); when None, the init scan infers it from the whole file.
    ``binary`` follows ``parse_libsvm``: True maps labels to {-1, +1} by
    sign, False keeps raw (multi-class) labels.
    """

    def __init__(self, path: str, chunk_rows: int, n_features: int | None = None,
                 *, binary: bool = True):
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows={chunk_rows} < 1")
        self.path, self.binary = path, binary
        self._offsets = [0]          # byte offset of each chunk's first line
        self.chunk_lens = []
        rows_in_chunk = 0
        n_rows = 0
        max_idx = 0
        pos = 0
        with open(path, "rb") as f:
            for line in f:
                pos += len(line)
                if not line.strip():
                    continue
                n_rows += 1
                rows_in_chunk += 1
                if n_features is None:
                    for tok in line.split()[1:]:
                        max_idx = max(max_idx, int(tok.split(b":")[0]))
                if rows_in_chunk == chunk_rows:
                    self.chunk_lens.append(rows_in_chunk)
                    self._offsets.append(pos)
                    rows_in_chunk = 0
        if rows_in_chunk:
            self.chunk_lens.append(rows_in_chunk)
            self._offsets.append(pos)
        if not self.chunk_lens:
            raise ValueError(f"{path}: no data rows")
        self.n_features = n_features if n_features is not None else max_idx
        self.dim = int(self.n_features)

    def load(self, i: int):
        start, end = self._offsets[i], self._offsets[i + 1]
        with open(self.path, "rb") as f:
            f.seek(start)
            blob = f.read(end - start)
        lines = blob.decode("utf-8").splitlines()
        return parse_libsvm(lines, n_features=self.n_features,
                            binary=self.binary)


class DriftChunks(ChunkSource):
    """Non-stationary view over any ``ChunkSource`` (zero-copy until load).

    Applies a drift schedule per chunk as the stream plays out — the online
    suite's data layer (DESIGN.md §15).  Two independent schedule kinds, any
    combination:

      * ``flip``  — ``(n_chunks,)`` per-chunk label-flip probabilities
        (``synthetic.label_flip_schedule``).  A flipped binary label
        negates; with ``n_classes`` set, a flipped class id rotates to
        ``(y + 1) % n_classes`` — both keep the label alphabet intact;
      * ``shift`` — ``(n_chunks, dim)`` additive input shifts
        (``synthetic.mean_shift_schedule``): covariate drift, labels
        untouched.

    Deterministic BY CONSTRUCTION: the rows flipped in chunk ``i`` are drawn
    from ``default_rng((seed, i))``, a pure function of ``(seed, chunk id)``
    — loading a chunk twice (or out of order, or under prefetch) yields
    bitwise-identical blocks, which is what makes single-pass regret
    reproducible (the determinism gate in tests/core/test_online.py).
    Chunks are visited in natural order by the prequential driver; shuffling
    a drifted stream would average the schedule away.
    """

    def __init__(self, source: ChunkSource, *, flip=None, shift=None,
                 n_classes: int | None = None, seed: int = 0):
        if flip is None and shift is None:
            raise ValueError("DriftChunks without flip or shift is the "
                             "identity — pass at least one schedule")
        self.source = source
        self.chunk_lens = source.chunk_lens
        self.dim = source.dim
        self.n_classes = n_classes
        self.seed = int(seed)
        self.flip = None if flip is None else np.asarray(flip, np.float32)
        if self.flip is not None and self.flip.shape != (source.n_chunks,):
            raise ValueError(f"flip shape {self.flip.shape} != "
                             f"({source.n_chunks},) — one prob per chunk")
        self.shift = None if shift is None else np.asarray(shift, np.float32)
        if self.shift is not None and \
                self.shift.shape != (source.n_chunks, source.dim):
            raise ValueError(f"shift shape {self.shift.shape} != "
                             f"({source.n_chunks}, {source.dim})")

    def load(self, i: int):
        x, y = self.source.load(i)
        x, y = np.asarray(x), np.asarray(y)
        if self.shift is not None and self.shift[i].any():
            x = x + self.shift[i].astype(x.dtype)
        if self.flip is not None and self.flip[i] > 0:
            rng = np.random.default_rng((self.seed, int(i)))
            m = rng.random(y.shape[0]) < self.flip[i]
            if self.n_classes is not None:
                y = np.where(m, (y + 1) % self.n_classes, y).astype(y.dtype)
            else:
                y = np.where(m, -y, y).astype(y.dtype)
        return x, y


class PrefetchChunks(ChunkSource):
    """Background-thread readahead over any ``ChunkSource``.

    Keeps up to ``depth`` chunks loaded (parsed, in host memory) ahead of the
    consumer along a declared *plan* — the iteration order, which is exactly
    what ``load`` hides for the out-of-core sources: ``FileChunks`` pays a
    disk read and ``LibsvmChunks`` a pure-Python parse per chunk, both of
    which the wrapper overlaps with whatever the consumer does with chunk
    *i* while the worker readies *i+1*.

    ``plan(order)`` declares the upcoming load order and starts the worker;
    ``load(i)`` returns the staged block when ``i`` is planned (scheduling
    more readahead) and falls back to a synchronous load otherwise, so the
    wrapper is a drop-in ``ChunkSource`` even off-plan.  A ``load()`` that
    raised on the worker re-raises on the *caller's* thread (the future
    carries it) — the worker itself never hangs or dies silently.
    ``iter_epoch(prefetch=depth)`` wraps and plans automatically; the
    streaming trainers go further and stage whole assembled minibatch blocks
    (``bsgd._stage_chunks``).

    Teardown: ``cancel()`` drops the plan without waiting (the mid-epoch
    re-plan path); ``close()`` additionally JOINS the worker, guaranteeing
    no ``prefetch-*`` thread survives the call — ``iter_epoch`` closes its
    wrapper on every exit path (exhaustion, a consumer raise, or the
    generator being dropped and finalized), and ``__del__`` backstops a
    wrapper that is GC'd while planned, so an abandoned epoch can never
    strand the worker (the no-hung-threads gate in tests/data/test_stream.py).
    """

    def __init__(self, source: ChunkSource, depth: int = 2, *, retry=None,
                 report=None):
        self._pool = None                    # first: __del__ may run on a
        if depth < 1:                        # partially-initialized instance
            raise ValueError(f"depth={depth} < 1")
        self.source = source
        self.depth = depth
        self.retry = retry                   # faults.RetryPolicy: loads (on
        self.report = report                 # the worker AND off-plan) retry
        self.chunk_lens = source.chunk_lens  # with backoff, quarantining on
        self.dim = source.dim                # exhaustion (DESIGN.md §16)
        self._futs: dict[int, object] = {}   # chunk id -> Future
        self._plan: list[int] = []           # upcoming ids, front first

    def plan(self, order) -> None:
        """Declare the upcoming load order; readahead follows it."""
        self.cancel()
        self._plan = [int(c) for c in order]
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="prefetch")
        self._fill()

    def cancel(self, wait: bool = False) -> None:
        """Drop the plan and stop the worker (idempotent); ``wait=True``
        joins the worker thread before returning."""
        self._plan = []
        self._futs.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=wait, cancel_futures=True)
            self._pool = None

    def close(self) -> None:
        """Tear down for good: cancel AND join the worker (idempotent)."""
        self.cancel(wait=True)

    def __del__(self):
        try:
            self.cancel()                    # no join inside the GC
        except Exception:                    # noqa: BLE001 — interpreter
            pass                             # shutdown half-torn state

    def _fill(self) -> None:
        while self._plan and len(self._futs) < self.depth:
            cid = self._plan.pop(0)
            self._futs[cid] = self._pool.submit(self._load_one, cid)

    def _load_one(self, cid: int):
        """One (possibly retried) source load — the worker's task body and
        the off-plan synchronous fallback share it, so retry/backoff runs on
        whichever thread performs the load."""
        if self.retry is None:
            return self.source.load(cid)
        from .faults import load_chunk_with_retry

        return load_chunk_with_retry(self.source, cid, self.retry,
                                     report=self.report,
                                     expected_rows=self.chunk_lens[cid],
                                     dim=self.dim)

    def load(self, i: int):
        fut = self._futs.pop(int(i), None)
        if fut is None:                      # off-plan: synchronous fallback
            return self._load_one(int(i))
        self._fill()                         # keep the window full
        return fut.result()                  # re-raises worker exceptions here


def write_npz_chunks(out_dir: str, x, y, chunk_rows: int, *,
                     prefix: str = "chunk") -> list[str]:
    """Shard (x, y) into ``.npz`` chunk files under ``out_dir``; returns the
    ordered shard paths (feed them to ``FileChunks``)."""
    x, y = np.asarray(x), np.asarray(y)
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for c, s in enumerate(range(0, x.shape[0], chunk_rows)):
        p = os.path.join(out_dir, f"{prefix}_{c:05d}.npz")
        np.savez(p, x=x[s:s + chunk_rows], y=y[s:s + chunk_rows])
        paths.append(p)
    return paths


def _fold_in(key, n: int):
    import jax

    return jax.random.fold_in(key, n)


def chunk_order(key, n_chunks: int) -> np.ndarray:
    """The epoch's chunk-order permutation (position -> chunk id)."""
    import jax

    return np.asarray(jax.random.permutation(_fold_in(key, 0), n_chunks))


def intra_perm(key, chunk_id: int, n: int) -> np.ndarray:
    """The intra-chunk row permutation for chunk ``chunk_id`` (keyed by id,
    not stream position — the realized order depends only on the key)."""
    import jax

    return np.asarray(jax.random.permutation(_fold_in(key, 1 + chunk_id), n))


def epoch_permutation(source: ChunkSource, key) -> np.ndarray:
    """The global row order one shuffled streamed epoch realizes.

    Feeding this to the in-memory ``train_epoch`` reproduces the streamed
    pass row-for-row — the equivalence gate in tests/core/test_stream_train.py.
    ``key=None`` is the natural (unshuffled) order.
    """
    offs = source.chunk_offsets()
    if key is None:
        return np.arange(source.n_rows, dtype=np.int64)
    order = chunk_order(key, source.n_chunks)
    parts = [offs[c] + intra_perm(key, int(c), source.chunk_lens[c])
             for c in order]
    return np.concatenate(parts).astype(np.int64)


def iter_epoch(source: ChunkSource, key=None, *, start_chunk: int = 0,
               end_chunk: int | None = None, prefetch: int = 0,
               retry=None, report=None, skip_chunks=()):
    """Yield ``(position, x, y)`` chunks for one epoch in shuffled order.

    ``key`` derives both permutations of the shuffle contract (None = natural
    order); ``start_chunk`` skips already-trained stream positions — the
    resume path (checkpoint cursor) of the streaming trainers — and
    ``end_chunk`` stops before that position (exclusive; chunks past it are
    never read from the source).  ``prefetch > 0`` reads ahead that many
    chunks on a background thread (``PrefetchChunks`` along the epoch's
    realized order) — the yielded blocks are bitwise identical to the
    synchronous path, chunk ``i+1``'s load just overlaps the consumer's work
    on chunk ``i``.  A source that is already a ``PrefetchChunks`` is planned
    directly (no double wrap).

    Resilience (DESIGN.md §16): ``retry`` (a ``faults.RetryPolicy``) retries
    transient load failures with bounded backoff — on the prefetch worker
    when one is planned, else inline — and QUARANTINES a chunk that exhausts
    its budget: the chunk is skipped (its position yields nothing), recorded
    in ``report`` (a ``faults.ResilienceReport``), and the epoch continues.
    ``skip_chunks`` (chunk *ids*) are excluded up front as if they never
    existed — the construction used to prove that quarantine leaves the
    surviving sequence bitwise identical.  With ``retry=None`` (default) the
    path is exactly the pre-resilience one: any load failure propagates.
    """
    skip = frozenset(int(c) for c in skip_chunks)
    order = (chunk_order(key, source.n_chunks) if key is not None
             else np.arange(source.n_chunks))
    end = source.n_chunks if end_chunk is None else min(end_chunk,
                                                        source.n_chunks)
    planned = None
    if prefetch and not isinstance(source, PrefetchChunks):
        source = PrefetchChunks(source, depth=prefetch, retry=retry,
                                report=report)
    if isinstance(source, PrefetchChunks):
        source.plan([c for c in order[start_chunk:end] if int(c) not in skip])
        planned = source
    # retried loads: on the planned worker (its own retry/report), or inline
    worker_retries = planned is not None and source.retry is not None
    resilient = retry is not None or worker_retries
    if resilient:
        from .faults import ChunkQuarantined, load_chunk_with_retry
    try:
        for pos in range(start_chunk, end):
            cid = int(order[pos])
            if cid in skip:
                continue
            try:
                if retry is not None and not worker_retries:
                    x, y = load_chunk_with_retry(
                        source, cid, retry, report=report,
                        expected_rows=source.chunk_lens[cid], dim=source.dim)
                else:
                    x, y = source.load(cid)
            except Exception as e:  # noqa: BLE001 — quarantine-only filter
                if not (resilient and isinstance(e, ChunkQuarantined)):
                    raise
                if report is not None:
                    report.note_quarantine(e)
                continue                 # skip: surviving sequence unchanged
            if key is not None:
                p = intra_perm(key, cid, x.shape[0])
                x, y = x[p], y[p]
            yield pos, x, y
    finally:
        if planned is not None:
            planned.close()              # abandoned epochs leave no worker:
                                         # close() joins, and generator
                                         # finalization (GC'd or consumer
                                         # raise) runs this same branch
