"""Synthetic binary-classification generators standing in for the paper's sets.

The container has no network access, so SUSY/ADULT/IJCNN/... are represented
by synthetic generators with matching dimensionality and qualitative structure
(overlapping Gaussians / nonlinear boundaries).  Benchmarks name their
workloads after the paper's datasets but record the generator used.

The *drift schedules* at the bottom make these generators non-stationary for
the online-learning suite: a schedule is a plain per-chunk numpy array (flip
probabilities, or additive mean-shift vectors) consumed by
``data.stream.DriftChunks``, which applies it deterministically while a
single-pass stream plays out (DESIGN.md §15).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_blobs(key, n: int, dim: int, *, sep: float = 2.0, noise: float = 1.0):
    """Two Gaussian blobs, labels in {-1, +1}."""
    k1, k2, k3 = jax.random.split(key, 3)
    y = jnp.where(jax.random.bernoulli(k1, 0.5, (n,)), 1.0, -1.0)
    centers = jnp.stack([jnp.full((dim,), -sep / 2), jnp.full((dim,), sep / 2)])
    mu = centers[((y + 1) // 2).astype(jnp.int32)]
    x = mu + noise * jax.random.normal(k2, (n, dim))
    perm = jax.random.permutation(k3, n)
    return x[perm], y[perm]


def make_blobs_multiclass(key, n: int, dim: int, n_classes: int = 5, *,
                          sep: float = 3.0, noise: float = 1.0):
    """C Gaussian blobs at random centers; labels are int32 in [0, C).

    Centers are drawn ``sep * N(0, I)`` — in dim >= ~4 the pairwise center
    distances concentrate around ``sep * sqrt(2 * dim)`` while the in-class
    spread is ``noise * sqrt(dim)``, so the default ``sep/noise = 3`` keeps
    classes well separated (the multi-class example trains to >= 90% in one
    pass) without being linearly trivial in every direction.
    """
    kc, ky, kx, kp = jax.random.split(key, 4)
    centers = sep * jax.random.normal(kc, (n_classes, dim))
    y = jax.random.randint(ky, (n,), 0, n_classes, dtype=jnp.int32)
    x = centers[y] + noise * jax.random.normal(kx, (n, dim))
    perm = jax.random.permutation(kp, n)
    return x[perm], y[perm]


def make_two_moons(key, n: int, *, noise: float = 0.15, dim: int = 2):
    """Classic non-linearly-separable benchmark (kernel methods shine here).

    If dim > 2, the extra dimensions are pure noise (tests robustness of
    gamma selection).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    n_half = n // 2
    t = jnp.linspace(0, jnp.pi, n_half)
    x_a = jnp.stack([jnp.cos(t), jnp.sin(t)], axis=1)
    x_b = jnp.stack([1.0 - jnp.cos(t), 0.5 - jnp.sin(t)], axis=1)
    x = jnp.concatenate([x_a, x_b]) + noise * jax.random.normal(k1, (2 * n_half, 2))
    y = jnp.concatenate([jnp.ones(n_half), -jnp.ones(n_half)])
    if dim > 2:
        x = jnp.concatenate([x, 0.5 * jax.random.normal(k2, (2 * n_half, dim - 2))], axis=1)
    perm = jax.random.permutation(k3, 2 * n_half)
    return x[perm], y[perm]


def make_susy_like(key, n: int, dim: int = 18, *, flip: float = 0.2):
    """SUSY-ish: overlapping classes (exact SVM accuracy ~80%), 18 features.

    A quadratic boundary in a random subspace plus label noise gives the
    ~20% Bayes-error feel of the physics set.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, dim))
    w = jax.random.normal(k2, (dim,))
    score = x @ w + 0.5 * jnp.sum(x[:, : dim // 2] ** 2, axis=1) - dim // 4
    y = jnp.where(score > 0, 1.0, -1.0)
    do_flip = jax.random.bernoulli(k3, flip, (n,))
    return x, jnp.where(do_flip, -y, y)


def train_test_split(x, y, *, test_frac: float = 0.2):
    n_test = int(x.shape[0] * test_frac)
    return (x[n_test:], y[n_test:]), (x[:n_test], y[:n_test])


# ---------------------------------------------------------------------------
# Drift schedules (consumed by data.stream.DriftChunks)
# ---------------------------------------------------------------------------

def label_flip_schedule(n_chunks: int, *, start: float = 0.5,
                        prob: float = 1.0) -> np.ndarray:
    """Step label drift: per-chunk flip probabilities, shape ``(n_chunks,)``.

    Chunks before position ``floor(start * n_chunks)`` are clean; from there
    on every row's label flips with probability ``prob`` (binary labels
    negate, class ids rotate — see ``DriftChunks``).  ``prob=1.0`` at
    ``start=0.5`` is the classic mid-stream concept reversal: a model that
    cannot forget its budgeted bank pays for it in cumulative mistakes.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks={n_chunks} < 1")
    if not 0.0 <= prob <= 1.0:
        raise ValueError(f"prob={prob} outside [0, 1]")
    sched = np.zeros((n_chunks,), np.float32)
    sched[int(start * n_chunks):] = prob
    return sched


def mean_shift_schedule(n_chunks: int, dim: int, *, magnitude: float = 3.0,
                        start: float = 0.5, kind: str = "step",
                        direction=None) -> np.ndarray:
    """Covariate drift: per-chunk additive shifts, shape ``(n_chunks, dim)``.

    ``kind="step"`` jumps the input mean by ``magnitude`` (along the unit
    ``direction``, default the normalized all-ones diagonal) at position
    ``floor(start * n_chunks)``; ``kind="ramp"`` interpolates linearly from
    zero at that position to the full shift at the last chunk — gradual
    drift.  Labels are untouched: the decision boundary moves under the
    model instead.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks={n_chunks} < 1")
    if kind not in ("step", "ramp"):
        raise ValueError(f"kind={kind!r} not in ('step', 'ramp')")
    d = (np.full((dim,), 1.0, np.float32) if direction is None
         else np.asarray(direction, np.float32))
    if d.shape != (dim,):
        raise ValueError(f"direction shape {d.shape} != ({dim},)")
    d = d / max(float(np.linalg.norm(d)), 1e-12)
    s0 = int(start * n_chunks)
    w = np.zeros((n_chunks,), np.float32)
    if kind == "step":
        w[s0:] = 1.0
    else:
        span = max(n_chunks - 1 - s0, 1)
        for c in range(s0, n_chunks):
            w[c] = (c - s0) / span
    return (magnitude * w)[:, None] * d[None, :]
