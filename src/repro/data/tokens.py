"""Synthetic LM data with learnable structure (random bigram chain).

Tokens follow a fixed random Markov chain, so a model that learns the
transition table beats the uniform baseline — integration tests assert the
loss drops below log(vocab) - margin, which random tokens could never do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class BigramStream:
    def __init__(self, vocab: int, *, seed: int = 0, concentration: float = 0.3):
        self.vocab = vocab
        key = jax.random.PRNGKey(seed)
        logits = jax.random.normal(key, (vocab, vocab)) / concentration
        self.trans = jax.nn.softmax(logits, axis=-1)
        self._sample = jax.jit(self._sample_impl, static_argnums=(1, 2))

    def _sample_impl(self, key, batch: int, seq: int):
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (batch,), 0, self.vocab)

        def step(tok, k):
            nxt = jax.random.categorical(k, jnp.log(self.trans[tok] + 1e-9))
            return nxt, nxt

        keys = jax.random.split(k1, seq - 1)
        _, rest = jax.lax.scan(step, first, keys)
        toks = jnp.concatenate([first[None], rest], axis=0).T  # (B, S)
        return toks

    def batch(self, key, batch: int, seq: int) -> dict:
        toks = self._sample(key, batch, seq)
        labels = jnp.roll(toks, -1, axis=1)
        mask = jnp.ones_like(toks, jnp.float32).at[:, -1].set(0.0)
        return {"tokens": toks, "labels": labels, "mask": mask}

    def bigram_entropy(self) -> float:
        """Achievable loss floor (entropy of the transition distribution)."""
        h = -jnp.sum(self.trans * jnp.log(self.trans + 1e-12), axis=-1)
        return float(jnp.mean(h))


def random_batch(key, vocab: int, batch: int, seq: int) -> dict:
    toks = jax.random.randint(key, (batch, seq), 0, vocab)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
            "mask": jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)}


def frames_batch(key, batch: int, seq: int, frame_dim: int, vocab: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"frames": jax.random.normal(k1, (batch, seq, frame_dim)),
            "labels": jax.random.randint(k2, (batch, seq), 0, vocab),
            "mask": jax.random.bernoulli(k3, 0.3, (batch, seq))}
