"""Deterministic fault injection + the ingest resilience primitives.

Production streams are not clean: loaders throw transient IO errors, chunks
arrive truncated or with non-finite rows, and whole shards go bad.  This
module makes those failures a first-class, *injectable*, reproducible input
(DESIGN.md §16):

  * ``FaultSchedule`` — a seeded per-chunk fault plan.  Like ``DriftChunks``,
    every decision is a pure function of ``(seed, chunk_id)`` (drawn from
    ``np.random.default_rng((seed, chunk_id))``), so prefetched /
    out-of-order / repeated loads reproduce bitwise and a resumed run replays
    the exact same faults;
  * ``FaultyChunks`` — a drop-in ``ChunkSource`` wrapper that executes the
    schedule: transient ``TransientIOError``s for the first N attempts,
    stalls, truncated first reads, deterministic NaN/Inf row poisoning,
    persistent ``CorruptChunkError``s (quarantine drill) and a crash-once
    ``TrainerCrash`` (supervisor drill).  Attempt counters are thread-safe —
    the prefetch worker and the consumer may both load;
  * ``RetryPolicy`` + ``load_chunk_with_retry`` — bounded exponential
    backoff with transient-vs-fatal classification and a per-chunk attempt
    budget.  A chunk that exhausts its budget (or raises a fatal-but-
    quarantinable error) raises ``ChunkQuarantined``; the streaming drivers
    catch it, SKIP the chunk, and record it — one bad shard never kills an
    epoch.  The loader also validates chunk geometry against the source's
    advertised ``chunk_lens``/``dim``, so a torn/truncated read surfaces as
    a retryable ``TruncatedChunkError`` instead of a silent short batch;
  * ``ResilienceReport`` — a thread-safe tally of retries, recoveries,
    quarantines, guard rollbacks and trainer restarts, shared across the
    ingest, training and supervisor layers of one run.

Quarantine preserves the surviving sequence bitwise: a quarantined chunk
contributes no rows and its stream position is simply skipped, so the
realized batch sequence of the surviving chunks is identical to a run where
those chunks never existed (``iter_epoch(skip_chunks=...)`` constructs that
comparison run; the equivalence gate lives in tests/data/test_faults.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from .stream import ChunkSource


class TransientIOError(IOError):
    """An injected (or genuinely flaky) IO failure that a retry may clear."""


class TruncatedChunkError(IOError):
    """A chunk came back with the wrong geometry (short rows / wrong dim).

    Raised by the retry loader's validation, not by sources themselves — a
    truncated read (e.g. a file caught mid-write) often succeeds on re-read,
    so this classifies as transient.
    """


class CorruptChunkError(ValueError):
    """Persistent, unrecoverable chunk corruption — not worth retrying.

    The retry policy classifies this as quarantinable: the chunk is skipped
    immediately (no backoff attempts burned) and reported.
    """


class TrainerCrash(RuntimeError):
    """An injected hard crash (neither transient nor quarantinable).

    Propagates through the retry layer and kills the epoch — the fault kind
    that exercises the serve supervisor's restart-from-checkpoint path.
    ``FaultyChunks`` raises it only on a chunk's FIRST in-process load
    attempt, so a restarted trainer gets past it.
    """


class ChunkQuarantined(RuntimeError):
    """A chunk exhausted its retry budget (or corrupted persistently).

    The streaming drivers catch this, skip the chunk, and record it in the
    run's ``ResilienceReport`` — quarantine is a skip, never a crash.
    """

    def __init__(self, chunk_id: int, attempts: int, cause: BaseException):
        self.chunk_id = int(chunk_id)
        self.attempts = int(attempts)
        self.cause = cause
        super().__init__(f"chunk {chunk_id} quarantined after {attempts} "
                         f"attempt(s): {cause!r}")


@dataclasses.dataclass(frozen=True)
class ChunkFaults:
    """The resolved fault plan for ONE chunk (see ``FaultSchedule.for_chunk``)."""

    io_attempts: int = 0     # first N load attempts raise TransientIOError
    stall_s: float = 0.0     # sleep injected into the first attempt
    truncate: bool = False   # first otherwise-successful read comes back short
    nan: bool = False        # deterministic NaN/Inf rows poison the data
    fatal: bool = False      # EVERY attempt raises CorruptChunkError
    crash: bool = False      # first in-process attempt raises TrainerCrash

    @property
    def any(self) -> bool:
        return bool(self.io_attempts or self.stall_s or self.truncate
                    or self.nan or self.fatal or self.crash)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded, per-chunk fault plan — pure in ``(seed, chunk_id)``.

    Probabilistic knobs (``p_*``) draw one uniform per fault kind from
    ``np.random.default_rng((seed, chunk_id))`` in a FIXED order, and the
    explicit ``*_chunks`` tuples force a fault on named chunk ids regardless
    of the draw.  Because nothing depends on load order or attempt history,
    the schedule reproduces bitwise under prefetch, out-of-order loads and
    kill-and-resume (the same determinism contract as ``DriftChunks``).

    ``fatal_chunks`` and ``crash_chunks`` are explicit-only: persistent
    corruption and hard crashes are targeted drills, not background noise.
    """

    seed: int = 0
    p_io: float = 0.0          # P(chunk's first io_attempts loads fail)
    io_attempts: int = 1       # consecutive failing attempts for an io fault
    p_stall: float = 0.0       # P(first attempt sleeps stall_s)
    stall_s: float = 0.002
    p_truncate: float = 0.0    # P(first good read returns a short chunk)
    p_nan: float = 0.0         # P(chunk data carries NaN/Inf rows)
    nan_rows: int = 4          # poisoned rows per NaN chunk
    io_chunks: tuple = ()
    stall_chunks: tuple = ()
    truncate_chunks: tuple = ()
    nan_chunks: tuple = ()
    fatal_chunks: tuple = ()   # persistent CorruptChunkError -> quarantine
    crash_chunks: tuple = ()   # crash-once TrainerCrash -> supervisor drill

    def for_chunk(self, chunk_id: int) -> ChunkFaults:
        """Resolve the plan for one chunk (pure in ``(seed, chunk_id)``)."""
        i = int(chunk_id)
        rng = np.random.default_rng((self.seed, i))
        draw = rng.random(4)                 # io, stall, truncate, nan
        return ChunkFaults(
            io_attempts=(self.io_attempts
                         if (i in self.io_chunks or draw[0] < self.p_io)
                         else 0),
            stall_s=(self.stall_s
                     if (i in self.stall_chunks or draw[1] < self.p_stall)
                     else 0.0),
            truncate=(i in self.truncate_chunks or draw[2] < self.p_truncate),
            nan=(i in self.nan_chunks or draw[3] < self.p_nan),
            fatal=i in self.fatal_chunks,
            crash=i in self.crash_chunks)

    @staticmethod
    def chaos(seed: int = 0, *, nan_chunk: int = 2,
              crash_chunk: int | None = None,
              fatal_chunk: int | None = None) -> "FaultSchedule":
        """The demo/CI chaos mix: background transient IO errors, stalls and
        truncations, one NaN chunk, and (optionally) one quarantined shard +
        one crash-once chunk for the supervisor drill."""
        return FaultSchedule(
            seed=seed, p_io=0.2, io_attempts=1, p_stall=0.1, stall_s=0.002,
            p_truncate=0.1, nan_chunks=(nan_chunk,),
            fatal_chunks=() if fatal_chunk is None else (fatal_chunk,),
            crash_chunks=() if crash_chunk is None else (crash_chunk,))


class FaultyChunks(ChunkSource):
    """Execute a ``FaultSchedule`` over any ``ChunkSource`` (drop-in wrapper).

    Data-level faults (NaN/Inf rows) are pure in ``(seed, chunk_id)`` —
    loading a poisoned chunk twice yields bitwise-identical blocks.  Attempt-
    level faults (transient IO, stalls, truncation, crash-once) consult a
    thread-safe per-chunk attempt counter, which is what makes them
    *transient*: the injected error clears after ``io_attempts`` retries.
    ``chunk_lens``/``dim`` mirror the wrapped source (truncation deliberately
    violates them — that is how the retry validator catches it).
    """

    def __init__(self, source: ChunkSource, schedule: FaultSchedule):
        self.source = source
        self.schedule = schedule
        self.chunk_lens = source.chunk_lens
        self.dim = source.dim
        self._lock = threading.Lock()
        self._attempts: dict[int, int] = {}

    def attempts(self, i: int) -> int:
        """In-process load attempts made against chunk ``i`` so far."""
        with self._lock:
            return self._attempts.get(int(i), 0)

    def load(self, i: int):
        i = int(i)
        f = self.schedule.for_chunk(i)
        with self._lock:
            attempt = self._attempts.get(i, 0)
            self._attempts[i] = attempt + 1
        if f.crash and attempt == 0:
            raise TrainerCrash(f"injected crash on chunk {i} load")
        if f.fatal:
            raise CorruptChunkError(
                f"injected persistent corruption on chunk {i}")
        if f.stall_s and attempt == 0:
            time.sleep(f.stall_s)
        if attempt < f.io_attempts:
            raise TransientIOError(
                f"injected transient IO failure on chunk {i} "
                f"(attempt {attempt + 1}/{f.io_attempts} failing)")
        x, y = self.source.load(i)
        x, y = np.asarray(x), np.asarray(y)
        if f.nan:
            x = (x.astype(np.float32) if not np.issubdtype(x.dtype, np.floating)
                 else x.copy())
            rng = np.random.default_rng((self.schedule.seed, i, 1))
            n = min(self.schedule.nan_rows, x.shape[0])
            rows = rng.choice(x.shape[0], size=n, replace=False)
            x[rows[: n // 2 + n % 2]] = np.nan
            x[rows[n // 2 + n % 2:]] = np.inf
        if f.truncate and attempt == f.io_attempts:
            # the first read that would otherwise succeed comes back short
            # (a file caught mid-write); the re-read sees the full chunk
            k = max(1, x.shape[0] // 2)
            return x[:k], y[:k]
        return x, y


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-backoff retry with transient-vs-fatal classification.

    ``transient`` exception types are retried up to ``max_attempts`` total
    loads with exponential backoff (``base_delay_s * 2^attempt``, clipped to
    ``max_delay_s``); exhausting the budget raises ``ChunkQuarantined``.
    ``quarantine`` types skip the retries and quarantine immediately
    (corruption that cannot clear).  Anything else — a genuine bug —
    propagates unchanged.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.005
    max_delay_s: float = 0.25
    transient: tuple = (OSError, TimeoutError, ConnectionError)
    quarantine: tuple = (CorruptChunkError,)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts={self.max_attempts} < 1")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt + 1`` (attempt is 0-based)."""
        return min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)

    def classify(self, exc: BaseException) -> str:
        """``'transient'`` | ``'quarantine'`` | ``'propagate'``."""
        if isinstance(exc, self.quarantine):
            return "quarantine"
        if isinstance(exc, self.transient):
            return "transient"
        return "propagate"


class ResilienceReport:
    """Thread-safe tally of one run's faults and recoveries.

    Shared across the ingest retry layer (possibly on a prefetch worker
    thread), the training guard and the serve supervisor; ``as_dict()`` is
    the JSON-able summary the benchmarks and the live serve driver record.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.retries = 0           # failed attempts that were retried
        self.recovered = []        # (chunk_id, failed_attempts_before_success)
        self.quarantined = []      # (chunk_id, attempts, repr(cause))
        self.rollbacks = []        # stream positions rolled back by the guard
        self.restarts = 0          # supervisor trainer restarts

    def note_retry(self, chunk_id: int) -> None:
        with self._lock:
            self.retries += 1

    def note_recovered(self, chunk_id: int, failed_attempts: int) -> None:
        with self._lock:
            self.recovered.append((int(chunk_id), int(failed_attempts)))

    def note_quarantine(self, q: ChunkQuarantined) -> None:
        with self._lock:
            self.quarantined.append((q.chunk_id, q.attempts, repr(q.cause)))

    def note_rollback(self, pos: int) -> None:
        with self._lock:
            self.rollbacks.append(int(pos))

    def note_restart(self) -> None:
        with self._lock:
            self.restarts += 1

    def quarantined_chunks(self) -> list[int]:
        """Chunk ids skipped by quarantine, in the order they were skipped."""
        with self._lock:
            return [cid for cid, _, _ in self.quarantined]

    def as_dict(self) -> dict:
        with self._lock:
            return {"retries": self.retries,
                    "recovered": list(self.recovered),
                    "quarantined": list(self.quarantined),
                    "rollbacks": list(self.rollbacks),
                    "restarts": self.restarts}

    def __repr__(self):
        d = self.as_dict()
        return (f"ResilienceReport(retries={d['retries']}, "
                f"recovered={len(d['recovered'])}, "
                f"quarantined={len(d['quarantined'])}, "
                f"rollbacks={len(d['rollbacks'])}, "
                f"restarts={d['restarts']})")


def load_chunk_with_retry(source: ChunkSource, chunk_id: int,
                          policy: RetryPolicy, *, report=None,
                          expected_rows: int | None = None,
                          dim: int | None = None, sleep=time.sleep):
    """Load one chunk under ``policy``; the single retry path of the stream.

    Validates the returned geometry against ``expected_rows``/``dim`` (a
    short or mis-shaped chunk raises a retryable ``TruncatedChunkError``).
    Transient failures back off and retry up to ``policy.max_attempts``
    total attempts; exhaustion or a quarantinable error raises
    ``ChunkQuarantined``; anything else propagates.  ``report`` (a
    ``ResilienceReport``) tallies retried attempts and eventual recoveries —
    quarantines are tallied by the CALLER that skips the chunk, so a
    quarantine is counted exactly once however many layers re-raise it.
    """
    cid = int(chunk_id)
    cause = None
    for attempt in range(policy.max_attempts):
        try:
            x, y = source.load(cid)
            x, y = np.asarray(x), np.asarray(y)
            if expected_rows is not None and x.shape[0] != expected_rows:
                raise TruncatedChunkError(
                    f"chunk {cid}: got {x.shape[0]} rows, source advertises "
                    f"{expected_rows} — truncated read")
            if dim is not None and x.ndim == 2 and x.shape[1] != dim:
                raise TruncatedChunkError(
                    f"chunk {cid}: got dim {x.shape[1]}, source advertises "
                    f"{dim}")
            if y.shape[0] != x.shape[0]:
                raise TruncatedChunkError(
                    f"chunk {cid}: x rows {x.shape[0]} != y rows {y.shape[0]}")
            if attempt and report is not None:
                report.note_recovered(cid, attempt)
            return x, y
        except ChunkQuarantined:
            raise                         # an inner retry layer already decided
        except Exception as e:  # noqa: BLE001 — classified below
            kind = policy.classify(e)
            if kind == "propagate":
                raise
            if kind == "quarantine":
                raise ChunkQuarantined(cid, attempt + 1, e) from e
            cause = e
            if report is not None:
                report.note_retry(cid)
            if attempt + 1 < policy.max_attempts:
                sleep(policy.delay_s(attempt))
    raise ChunkQuarantined(cid, policy.max_attempts, cause) from cause
