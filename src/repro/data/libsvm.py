"""LIBSVM text format parser (the paper's datasets ship in this format)."""
from __future__ import annotations

import numpy as np


def parse_libsvm(path_or_lines, n_features: int | None = None, *,
                 binary: bool = True):
    """Returns ``(x (n, d) float32, y (n,) float32)``.

    ``binary=True`` (the paper's setting) maps every label to {-1, +1} by
    sign; ``binary=False`` keeps the raw labels untouched so multi-class
    sets survive for ``core.multiclass``.  ``fit_multiclass`` expects
    0-based integer ids — remap first, e.g. ``y.astype(int) - 1`` for the
    common 1..C LIBSVM convention (it raises on out-of-range labels).
    """
    if isinstance(path_or_lines, str):
        with open(path_or_lines) as f:
            lines = f.readlines()
    else:
        lines = list(path_or_lines)
    rows, ys = [], []
    max_idx = 0
    for line in lines:
        parts = line.strip().split()
        if not parts:
            continue
        label = float(parts[0])
        ys.append((1.0 if label > 0 else -1.0) if binary else label)
        feats = {}
        for tok in parts[1:]:
            idx, val = tok.split(":")
            idx = int(idx)
            feats[idx] = float(val)
            max_idx = max(max_idx, idx)
        rows.append(feats)
    d = n_features or max_idx
    x = np.zeros((len(rows), d), np.float32)
    for i, feats in enumerate(rows):
        for idx, val in feats.items():
            x[i, idx - 1] = val  # libsvm is 1-indexed
    return x, np.asarray(ys, np.float32)


def dump_libsvm(path: str, x, y, *, append: bool = False) -> None:
    """Write (x, y) in LIBSVM text format (sparse: zeros are omitted).

    ``append=True`` adds rows to an existing file — the chunked writing path:
    dump a dataset chunk-by-chunk without ever materializing it whole, then
    read it back with ``iter_libsvm_chunks`` / ``repro.data.stream.LibsvmChunks``.
    """
    with open(path, "a" if append else "w") as f:
        for xi, yi in zip(x, y):
            feats = " ".join(f"{j+1}:{v:.6g}" for j, v in enumerate(xi) if v != 0)
            f.write(f"{int(yi):+d} {feats}\n")


def iter_libsvm_chunks(path: str, chunk_rows: int, n_features: int, *,
                       binary: bool = True):
    """Yield ``(x, y)`` chunks of up to ``chunk_rows`` parsed incrementally.

    One sequential pass with O(chunk) memory — the no-random-access
    counterpart of ``repro.data.stream.LibsvmChunks`` (which scans offsets
    once so chunks can be loaded in shuffled order).  ``n_features`` is
    required: a chunk cannot infer the full feature width on its own.
    """
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows={chunk_rows} < 1")
    buf = []
    with open(path) as f:
        for line in f:
            if not line.strip():
                continue
            buf.append(line)
            if len(buf) == chunk_rows:
                yield parse_libsvm(buf, n_features=n_features, binary=binary)
                buf = []
    if buf:
        yield parse_libsvm(buf, n_features=n_features, binary=binary)
