"""LIBSVM text format parser (the paper's datasets ship in this format)."""
from __future__ import annotations

import numpy as np


def parse_libsvm(path_or_lines, n_features: int | None = None):
    """Returns (x (n, d) float32, y (n,) float32 in {-1, +1})."""
    if isinstance(path_or_lines, str):
        with open(path_or_lines) as f:
            lines = f.readlines()
    else:
        lines = list(path_or_lines)
    rows, ys = [], []
    max_idx = 0
    for line in lines:
        parts = line.strip().split()
        if not parts:
            continue
        label = float(parts[0])
        ys.append(1.0 if label > 0 else -1.0)
        feats = {}
        for tok in parts[1:]:
            idx, val = tok.split(":")
            idx = int(idx)
            feats[idx] = float(val)
            max_idx = max(max_idx, idx)
        rows.append(feats)
    d = n_features or max_idx
    x = np.zeros((len(rows), d), np.float32)
    for i, feats in enumerate(rows):
        for idx, val in feats.items():
            x[i, idx - 1] = val  # libsvm is 1-indexed
    return x, np.asarray(ys, np.float32)


def dump_libsvm(path: str, x, y) -> None:
    with open(path, "w") as f:
        for xi, yi in zip(x, y):
            feats = " ".join(f"{j+1}:{v:.6g}" for j, v in enumerate(xi) if v != 0)
            f.write(f"{int(yi):+d} {feats}\n")
