"""LIBSVM text format parser (the paper's datasets ship in this format)."""
from __future__ import annotations

import numpy as np


def parse_libsvm(path_or_lines, n_features: int | None = None, *,
                 binary: bool = True):
    """Returns ``(x (n, d) float32, y (n,) float32)``.

    ``binary=True`` (the paper's setting) maps every label to {-1, +1} by
    sign; ``binary=False`` keeps the raw labels untouched so multi-class
    sets survive for ``core.multiclass``.  ``fit_multiclass`` expects
    0-based integer ids — remap first, e.g. ``y.astype(int) - 1`` for the
    common 1..C LIBSVM convention (it raises on out-of-range labels).
    """
    if isinstance(path_or_lines, str):
        with open(path_or_lines) as f:
            lines = f.readlines()
    else:
        lines = list(path_or_lines)
    rows, ys = [], []
    max_idx = 0
    for line in lines:
        parts = line.strip().split()
        if not parts:
            continue
        label = float(parts[0])
        ys.append((1.0 if label > 0 else -1.0) if binary else label)
        feats = {}
        for tok in parts[1:]:
            idx, val = tok.split(":")
            idx = int(idx)
            feats[idx] = float(val)
            max_idx = max(max_idx, idx)
        rows.append(feats)
    d = n_features or max_idx
    x = np.zeros((len(rows), d), np.float32)
    for i, feats in enumerate(rows):
        for idx, val in feats.items():
            x[i, idx - 1] = val  # libsvm is 1-indexed
    return x, np.asarray(ys, np.float32)


def dump_libsvm(path: str, x, y) -> None:
    with open(path, "w") as f:
        for xi, yi in zip(x, y):
            feats = " ".join(f"{j+1}:{v:.6g}" for j, v in enumerate(xi) if v != 0)
            f.write(f"{int(yi):+d} {feats}\n")
