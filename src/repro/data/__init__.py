"""Data pipelines: synthetic classification sets, LIBSVM parsing, chunked
streaming sources for out-of-core training, fault injection, LM tokens."""
from .faults import (ChunkQuarantined, CorruptChunkError, FaultSchedule, FaultyChunks, ResilienceReport,
                     RetryPolicy, TrainerCrash, TransientIOError, TruncatedChunkError, load_chunk_with_retry)
from .libsvm import dump_libsvm, iter_libsvm_chunks, parse_libsvm
from .stream import (ArrayChunks, ChunkSource, DriftChunks, FileChunks, LibsvmChunks, PrefetchChunks,
                     chunk_order, epoch_permutation, intra_perm, iter_epoch, write_npz_chunks)
from .synthetic import (label_flip_schedule, make_blobs, make_blobs_multiclass, make_susy_like,
                        make_two_moons, mean_shift_schedule, train_test_split)

__all__ = ["ArrayChunks", "ChunkQuarantined", "ChunkSource",
           "CorruptChunkError", "DriftChunks", "FaultSchedule",
           "FaultyChunks", "FileChunks", "LibsvmChunks", "PrefetchChunks",
           "ResilienceReport", "RetryPolicy", "TrainerCrash",
           "TransientIOError", "TruncatedChunkError",
           "chunk_order", "dump_libsvm", "epoch_permutation", "intra_perm",
           "iter_epoch", "iter_libsvm_chunks", "label_flip_schedule",
           "load_chunk_with_retry",
           "make_blobs", "make_blobs_multiclass", "make_susy_like",
           "make_two_moons", "mean_shift_schedule", "parse_libsvm",
           "train_test_split", "write_npz_chunks"]
