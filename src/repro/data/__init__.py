"""Data pipelines: synthetic classification sets, LIBSVM parsing, LM tokens."""
from .synthetic import make_blobs, make_susy_like, make_two_moons, train_test_split

__all__ = ["make_blobs", "make_susy_like", "make_two_moons", "train_test_split"]
