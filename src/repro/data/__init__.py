"""Data pipelines: synthetic classification sets, LIBSVM parsing, LM tokens."""
from .libsvm import dump_libsvm, parse_libsvm
from .synthetic import make_blobs, make_blobs_multiclass, make_susy_like, make_two_moons, train_test_split

__all__ = ["dump_libsvm", "make_blobs", "make_blobs_multiclass", "make_susy_like", "make_two_moons",
           "parse_libsvm", "train_test_split"]
