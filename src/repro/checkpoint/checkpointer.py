"""Fault-tolerant checkpointing: atomic, keep-last-k, async, mesh-elastic.

Layout: ``<dir>/step_<N>/`` holding ``arrays.npz`` (leaf-path -> numpy) and
``manifest.json``.  Writes go to ``step_<N>.tmp`` then ``os.replace`` — a
crash mid-save never corrupts the latest checkpoint, and ``latest_step``
only ever sees fully-renamed directories (the restart path after a node
failure).  Both files (and the directory entries) are fsynced before the
rename, so the atomicity holds across power loss, not just process death.

Integrity: the manifest stores a crc32 per leaf (computed over the raw
row-major bytes).  ``load`` re-hashes every leaf it reads and refuses
silently-corrupted arrays; ``verify_step`` / ``latest_verifiable_step`` let
restart paths walk back past a torn or bit-flipped newest step to the most
recent checkpoint that still verifies (DESIGN.md §16).

Checkpoints are *mesh-free*: leaves are stored as full (unsharded) numpy
arrays keyed by their tree path, so a job can restart on a different device
count / mesh shape — ``load`` takes target shardings and ``device_put``s each
leaf accordingly (elastic scaling).  At real multi-pod scale the same layout
would be written shard-wise per host; the single-process container writes the
fused array (noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _leaf_crc(arr: np.ndarray) -> int:
    """crc32 of the leaf's row-major bytes (dtype/shape live next to it in
    the manifest, so bytes alone pin the value)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directory fsync commits the
    rename/creation of its entries on POSIX)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx")
            else str(p.name) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         metadata: dict | None = None) -> str:
    """Atomic synchronous save; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                       "crc32": _leaf_crc(v)}
                   for k, v in flat.items()},
        "metadata": metadata or {},
    }
    manifest_path = os.path.join(tmp, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # fsync file contents and the tmp dir entries BEFORE the rename, then
    # the parent dir AFTER — a power cut leaves either the old state or the
    # complete new one, never a renamed-but-empty directory.
    _fsync_path(arrays_path)
    _fsync_path(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _fsync_path(ckpt_dir)
    _cleanup(ckpt_dir, keep_last)
    return final


def save_async(ckpt_dir: str, step: int, tree, **kw) -> threading.Thread:
    """Snapshot to host memory now, write in a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def _cleanup(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_metadata(ckpt_dir: str, step: int) -> dict:
    """The ``metadata`` dict passed to ``save`` for this step.

    Consumers that resume from *inside* a logical unit of work store their
    cursor here — e.g. the streaming trainers save ``{"epoch", "next_chunk"}``
    so a mid-epoch restart replays the exact remaining chunk sequence.

    Raises ``ValueError`` (never a raw traceback type) when the step has no
    manifest or the manifest is corrupt — by the atomic-rename contract a
    fully-written checkpoint always has one, so either means the directory
    is not a checkpoint this library wrote.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f).get("metadata", {})
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no manifest ({path} missing) — "
            "not a checkpoint written by repro.checkpoint") from None
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{ckpt_dir}: step {step} manifest is corrupt ({e}) — "
            "the checkpoint directory was tampered with or truncated "
            "outside the atomic-rename path") from None


def load(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional tree (matching target) of NamedSharding — leaves
    are device_put with them, enabling restore onto a different mesh than the
    one that saved (elastic restart).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    try:
        with np.load(path) as z:
            stored = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no arrays.npz — not a complete "
            "checkpoint (atomic saves always write one)") from None
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        # truncated / corrupt zip
        raise ValueError(
            f"{ckpt_dir}: step {step} arrays.npz is unreadable ({e}) — "
            "truncated or corrupt tree") from None
    keys = list(_flatten(target_tree).keys())
    missing = [k for k in keys if k not in stored]
    if missing:
        raise ValueError(
            f"{ckpt_dir}: step {step} checkpoint is missing leaves "
            f"{missing[:5]} — truncated tree or a different state layout")
    _check_crcs(ckpt_dir, step, stored)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    flat_shardings = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(leaves))
    new_leaves = []
    for key, ref, shd in zip(keys, leaves, flat_shardings):
        arr = stored[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != target {ref.shape}")
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _check_crcs(ckpt_dir: str, step: int, stored: dict[str, np.ndarray]
                ) -> None:
    """Verify stored leaves against the manifest's per-leaf crc32.

    Checkpoints written before checksums existed (no ``crc32`` key) pass
    unchecked — backward compatible.  A missing or corrupt manifest, or any
    crc mismatch, raises ``ValueError``.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(path) as f:
            leaves = json.load(f).get("leaves", {})
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no manifest ({path} missing) — "
            "not a checkpoint written by repro.checkpoint") from None
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{ckpt_dir}: step {step} manifest is corrupt ({e})") from None
    for key, arr in stored.items():
        spec = leaves.get(key)
        if spec is None or "crc32" not in spec:
            continue   # pre-checksum checkpoint, or extra leaf — skip
        got = _leaf_crc(arr)
        if got != int(spec["crc32"]):
            raise ValueError(
                f"{ckpt_dir}: step {step} leaf {key!r} fails its checksum "
                f"(crc32 {got:#010x} != manifest {int(spec['crc32']):#010x})"
                " — silent corruption, refuse to restore")


def verify_step(ckpt_dir: str, step: int) -> None:
    """Full integrity check of one step: readable manifest, readable
    arrays.npz, every manifest leaf present with the recorded shape/dtype,
    and (when recorded) a matching crc32.  Raises ``ValueError`` naming the
    first problem; returns None when the step verifies."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    path = os.path.join(step_dir, "manifest.json")
    try:
        with open(path) as f:
            leaves = json.load(f).get("leaves", {})
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no manifest — torn write") from None
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{ckpt_dir}: step {step} manifest is corrupt ({e})") from None
    arrays = os.path.join(step_dir, "arrays.npz")
    try:
        with np.load(arrays) as z:
            stored = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no arrays.npz — torn write"
        ) from None
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        raise ValueError(
            f"{ckpt_dir}: step {step} arrays.npz is unreadable ({e})"
        ) from None
    for key, spec in leaves.items():
        if key not in stored:
            raise ValueError(
                f"{ckpt_dir}: step {step} is missing leaf {key!r} — "
                "truncated tree")
        arr = stored[key]
        if list(arr.shape) != list(spec["shape"]):
            raise ValueError(
                f"{ckpt_dir}: step {step} leaf {key!r} shape "
                f"{list(arr.shape)} != manifest {spec['shape']}")
        if str(arr.dtype) != spec["dtype"]:
            raise ValueError(
                f"{ckpt_dir}: step {step} leaf {key!r} dtype {arr.dtype} "
                f"!= manifest {spec['dtype']}")
    _check_crcs(ckpt_dir, step, stored)


def latest_verifiable_step(ckpt_dir: str) -> int | None:
    """Newest step that passes ``verify_step``, walking back past torn or
    corrupt steps (a crash mid-save, or bit rot on the newest checkpoint,
    must not strand the restart path).  None when no step verifies."""
    for step in reversed(all_steps(ckpt_dir)):
        try:
            verify_step(ckpt_dir, step)
        except ValueError:
            continue
        return step
    return None


def restore_latest(ckpt_dir: str, target_tree, *, shardings=None):
    steps = all_steps(ckpt_dir)
    if not steps:
        return None, None
    step = latest_verifiable_step(ckpt_dir)
    if step is None:
        raise ValueError(
            f"{ckpt_dir}: checkpoint steps {steps} exist but none verify — "
            "refusing to restore from corrupt state")
    return step, load(ckpt_dir, step, target_tree, shardings=shardings)
