"""Fault-tolerant checkpointing: atomic, keep-last-k, async, mesh-elastic.

Layout: ``<dir>/step_<N>/`` holding ``arrays.npz`` (leaf-path -> numpy) and
``manifest.json``.  Writes go to ``step_<N>.tmp`` then ``os.replace`` — a
crash mid-save never corrupts the latest checkpoint, and ``latest_step``
only ever sees fully-renamed directories (the restart path after a node
failure).

Checkpoints are *mesh-free*: leaves are stored as full (unsharded) numpy
arrays keyed by their tree path, so a job can restart on a different device
count / mesh shape — ``load`` takes target shardings and ``device_put``s each
leaf accordingly (elastic scaling).  At real multi-pod scale the same layout
would be written shard-wise per host; the single-process container writes the
fused array (noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zipfile
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) if hasattr(p, "idx")
            else str(p.name) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         metadata: dict | None = None) -> str:
    """Atomic synchronous save; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _cleanup(ckpt_dir, keep_last)
    return final


def save_async(ckpt_dir: str, step: int, tree, **kw) -> threading.Thread:
    """Snapshot to host memory now, write in a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs=kw, daemon=True)
    t.start()
    return t


def _cleanup(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_metadata(ckpt_dir: str, step: int) -> dict:
    """The ``metadata`` dict passed to ``save`` for this step.

    Consumers that resume from *inside* a logical unit of work store their
    cursor here — e.g. the streaming trainers save ``{"epoch", "next_chunk"}``
    so a mid-epoch restart replays the exact remaining chunk sequence.

    Raises ``ValueError`` (never a raw traceback type) when the step has no
    manifest or the manifest is corrupt — by the atomic-rename contract a
    fully-written checkpoint always has one, so either means the directory
    is not a checkpoint this library wrote.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f).get("metadata", {})
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no manifest ({path} missing) — "
            "not a checkpoint written by repro.checkpoint") from None
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{ckpt_dir}: step {step} manifest is corrupt ({e}) — "
            "the checkpoint directory was tampered with or truncated "
            "outside the atomic-rename path") from None


def load(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional tree (matching target) of NamedSharding — leaves
    are device_put with them, enabling restore onto a different mesh than the
    one that saved (elastic restart).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    try:
        with np.load(path) as z:
            stored = {k: z[k] for k in z.files}
    except FileNotFoundError:
        raise ValueError(
            f"{ckpt_dir}: step {step} has no arrays.npz — not a complete "
            "checkpoint (atomic saves always write one)") from None
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        # truncated / corrupt zip
        raise ValueError(
            f"{ckpt_dir}: step {step} arrays.npz is unreadable ({e}) — "
            "truncated or corrupt tree") from None
    keys = list(_flatten(target_tree).keys())
    missing = [k for k in keys if k not in stored]
    if missing:
        raise ValueError(
            f"{ckpt_dir}: step {step} checkpoint is missing leaves "
            f"{missing[:5]} — truncated tree or a different state layout")
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    flat_shardings = (jax.tree_util.tree_flatten(shardings)[0]
                      if shardings is not None else [None] * len(leaves))
    new_leaves = []
    for key, ref, shd in zip(keys, leaves, flat_shardings):
        arr = stored[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != target {ref.shape}")
        arr = arr.astype(ref.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(ckpt_dir: str, target_tree, *, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, load(ckpt_dir, step, target_tree, shardings=shardings)
