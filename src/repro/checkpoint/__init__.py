"""Atomic keep-last-k checkpointing (see ``checkpointer`` for the layout)."""
from .checkpointer import (all_steps, latest_step, latest_verifiable_step,
                           load, load_metadata, restore_latest, save,
                           save_async, verify_step)
