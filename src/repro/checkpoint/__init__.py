from .checkpointer import all_steps, latest_step, load, restore_latest, save, save_async
