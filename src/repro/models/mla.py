"""Multi-head Latent Attention (DeepSeek-V2/V3) with decoupled RoPE.

Cache stores only the compressed latent per token: ``c_kv`` (kv_lora_rank) +
the shared rotary key ``k_rope`` (qk_rope_dim) — the memory win of MLA.

Decode uses the *absorbed* formulation by default: instead of expanding the
cached latents into per-head K/V (which would cost S x kv_lora x H x (nope+v)
matmuls per step), the query is pushed through W_kv_b once:

    q'_nope = q_nope @ W_kvb_k            (B, 1, H, kv_lora)
    scores  = q'_nope . c_kv + q_rope . k_rope
    ctx_lat = softmax(scores) @ c_kv      (B, 1, H, kv_lora)
    ctx     = ctx_lat @ W_kvb_v           (B, 1, H, v_dim)

which is O(H * S * kv_lora) per token — the form DeepSeek serves with.
Train/prefill use the expanded form (standard for sequence-parallel matmuls).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, ones_param, param, rms_norm

NEG = -1e30


def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = param(ks[0], (d, m.q_lora_rank), ("embed", "q_lora"), dtype)
        p["q_norm"] = ones_param((m.q_lora_rank,), ("q_lora",), dtype)
        p["wq_b"] = param(ks[1], (m.q_lora_rank, h, qk_dim),
                          ("q_lora", "q_heads", "head"), dtype)
    else:
        p["wq"] = param(ks[1], (d, h, qk_dim), ("embed", "q_heads", "head"), dtype)
    p["wkv_a"] = param(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim),
                       ("embed", "kv_lora"), dtype)
    p["kv_norm"] = ones_param((m.kv_lora_rank,), ("kv_lora",), dtype)
    p["wkv_b"] = param(ks[3], (m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim),
                       ("kv_lora", "q_heads", "head"), dtype)
    p["wo"] = param(ks[4], (h, m.v_head_dim, d), ("q_heads", "head", "embed"), dtype)
    return p


def _project_q(cfg, p, x):
    m = cfg.mla
    if m.q_lora_rank:
        q_lat = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhe->bshe", q_lat, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    return q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]


def mla_attention(cfg, p, x, positions, *, mode: str = "full", cache=None,
                  cache_pos=None):
    """Returns (y, new_cache).  Cache = {"ckv": (B,S,r), "krope": (B,S,rd)}."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    scale = 1.0 / float(m.qk_nope_dim + m.qk_rope_dim) ** 0.5

    q_nope, q_rope = _project_q(cfg, p, x)                 # (B,S,H,*)
    kv_a = x @ p["wkv_a"]                                  # (B,S,r+rd)
    c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:]                    # (B,S,rd) shared/heads

    if mode == "decode":
        pos = cache_pos
        abs_pos = pos + jnp.arange(s, dtype=jnp.int32)
        q_rope = apply_rope(q_rope, abs_pos, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], abs_pos, cfg.rope_theta)[:, :, 0]
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0))
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, pos, 0))
        w = ckv.shape[1]
        valid = jnp.arange(w) <= pos                       # (W,)
        bias = jnp.where(valid, 0.0, NEG)[None, None, None, :]

        wkvb_k = p["wkv_b"][..., : m.qk_nope_dim]          # (r, H, nope)
        wkvb_v = p["wkv_b"][..., m.qk_nope_dim:]           # (r, H, v)
        # absorbed decode
        q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, wkvb_k)     # (B,1,H,r)
        s_lat = jnp.einsum("bshr,bwr->bhsw", q_lat.astype(jnp.float32),
                           ckv.astype(jnp.float32))
        s_rope = jnp.einsum("bshe,bwe->bhsw", q_rope.astype(jnp.float32),
                            krope.astype(jnp.float32))
        scores = (s_lat + s_rope) * scale + bias
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhsw,bwr->bshr", probs, ckv.astype(jnp.float32))
        ctx = jnp.einsum("bshr,rhe->bshe", ctx_lat.astype(x.dtype), wkvb_v)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        from .attention import _chunked_sdpa  # shared online-softmax core

        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None, :], positions,
                              cfg.rope_theta)[:, :, 0]
        kv = jnp.einsum("bsr,rhe->bshe", c_kv, p["wkv_b"])
        k_nope = kv[..., : m.qk_nope_dim]
        v = kv[..., m.qk_nope_dim:]
        if s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
            # Fold the shared rotary key into per-head K and reuse the
            # KV-chunked core (MHA layout: hkv = H, group = 1).
            k_full = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope_r[:, :, None, :],
                                          (b, s, h, m.qk_rope_dim))], axis=-1)
            q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
            ctx = _chunked_sdpa(q_full.reshape(b, s, h, 1, -1), k_full, v,
                                positions, causal=cfg.causal and not cfg.is_encoder,
                                window=None, scale=scale,
                                chunk=cfg.attn_chunk)[:, :, :, 0, :]
        else:
            ok = jnp.ones((s, s), bool)
            if cfg.causal and not cfg.is_encoder:
                ok &= positions[None, :] <= positions[:, None]
            bias = jnp.where(ok, 0.0, NEG)[None, None]
            s_nope = jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope).astype(jnp.float32)
            s_rope = jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope_r).astype(jnp.float32)
            scores = (s_nope + s_rope) * scale + bias
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            ctx = jnp.einsum("bhqk,bkhe->bqhe", probs, v)
        new_cache = None
        if mode == "prefill":
            new_cache = {"ckv": c_kv.astype(x.dtype), "krope": k_rope_r.astype(x.dtype)}

    y = jnp.einsum("bshe,hed->bsd", ctx, p["wo"])
    return y, new_cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }
