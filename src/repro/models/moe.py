"""Mixture-of-Experts FFN: shared + routed top-k, capacity dispatch, EP-ready.

Dispatch is the FLOPs-clean scatter/gather formulation: tokens are assigned
positions inside each expert's capacity buffer via a cumulative-sum over the
routing one-hot (GShard-style), then *scattered* into an (E, C, D) buffer —
data movement, not matmul FLOPs — so ``cost_analysis`` FLOPs stay ~= the
active-parameter model FLOPs (capacity factor overhead only).  The expert
matmuls are a single grouped einsum, sharded over the ``experts`` axis (EP).

The deliberate baseline/beyond split (see EXPERIMENTS.md §Perf): this GSPMD
formulation lets XLA choose the collectives; the hillclimbed variant uses an
explicit shard_map all-to-all dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import param


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": param(ks[0], (d, m.num_experts), ("embed", None), jnp.float32),
        "w_gate": param(ks[1], (m.num_experts, d, m.d_expert),
                        ("experts", "embed", "expert_ffn"), dtype),
        "w_up": param(ks[2], (m.num_experts, d, m.d_expert),
                      ("experts", "embed", "expert_ffn"), dtype),
        "w_down": param(ks[3], (m.num_experts, m.d_expert, d),
                        ("experts", "expert_ffn", "embed"), dtype),
    }
    if m.n_shared:
        f = m.n_shared * m.d_expert
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": param(kss[0], (d, f), ("embed", "ffn"), dtype),
            "w_up": param(kss[1], (d, f), ("embed", "ffn"), dtype),
            "w_down": param(kss[2], (f, d), ("ffn", "embed"), dtype),
        }
    return p


def capacity(m, n_tokens: int) -> int:
    return max(m.min_capacity,
               int(n_tokens * m.top_k * m.capacity_factor) // m.num_experts)


def moe_ffn(cfg, p, x):
    """x: (B, S, D) -> (B, S, D).  Static shapes throughout."""
    m = cfg.moe
    bsz, s, d = x.shape
    t = bsz * s
    k = m.top_k
    e = m.num_experts
    c = capacity(m, t)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    if m.router == "sigmoid":                      # deepseek-v3 aux-free style
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(scores, k)                     # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    gate = gate * m.routed_scale

    # position of each (token, choice) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(sel.reshape(-1), e, dtype=jnp.int32)   # (T*k, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot                  # preceding count
    pos = jnp.take_along_axis(pos_all, sel.reshape(-1, 1), axis=1)[:, 0]
    keep = pos < c
    slot = jnp.where(keep, sel.reshape(-1) * c + pos, e * c)       # OOB -> drop

    # dispatch: scatter token copies into the (E*C, D) buffer
    tok_idx = jnp.arange(t * k) // k
    x_rep = jnp.take(xf, tok_idx, axis=0)                          # (T*k, D)
    buf = jnp.zeros((e * c, d), x.dtype).at[slot].set(x_rep, mode="drop")
    buf = buf.reshape(e, c, d)

    # grouped expert SwiGLU (EP: all three tensors shard over `experts`)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])           # (E, C, D)

    # combine: gather each choice's output back, weight, sum over k
    y_rep = out.reshape(e * c, d).at[jnp.where(keep, slot, 0)].get(
        mode="clip") * keep[:, None].astype(x.dtype)
    y = (y_rep.reshape(t, k, d)
         * gate.reshape(t, k, 1).astype(x.dtype)).sum(axis=1)

    if m.n_shared:
        sp = p["shared"]
        y = y + (jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])) @ sp["w_down"]
    return y.reshape(bsz, s, d)
