"""Shared model utilities: axis-annotated params, norms, RoPE, activations.

Params are plain pytrees of arrays.  At init we build a *parallel* tree of
logical-axis tuples (one name per array dim) that ``repro.sharding.specs``
resolves to ``PartitionSpec``s for a concrete mesh — flax-style logical
partitioning without the flax dependency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class Axes:
    """Leaf wrapper marking logical axes; kept OUT of jax pytrees on purpose."""

    __slots__ = ("names",)

    def __init__(self, *names):
        self.names = tuple(names)

    def __repr__(self):
        return f"Axes{self.names}"


def param(key, shape, axes: tuple, dtype, *, scale: float | None = None):
    """Truncated-normal init with fan-in scaling; returns (array, Axes)."""
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = 1.0 / np.sqrt(max(1, fan_in))
    arr = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return arr.astype(dtype), Axes(*axes)


def zeros_param(shape, axes: tuple, dtype):
    return jnp.zeros(shape, dtype), Axes(*axes)


def ones_param(shape, axes: tuple, dtype):
    return jnp.ones(shape, dtype), Axes(*axes)


def split_params_axes(tree):
    """Split a tree of (array, Axes) pairs into (params, axes) trees."""
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], Axes)
    params = jax.tree.map(lambda p: p[0], tree, is_leaf=is_pair)
    axes = jax.tree.map(lambda p: p[1], tree, is_leaf=is_pair)
    return params, axes


def rms_norm(x, gamma, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def rope_angles(positions, dim: int, theta: float):
    """positions: (...,) -> cos/sin of shape (..., dim//2)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """Rotary embedding. x: (B, S, H, D), positions: (B, S) or (S,)."""
    b, s, h, d = x.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, s))
    cos, sin = rope_angles(positions, d, theta)          # (B, S, D/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (x) -> silu(x Wg) * (x Wu) Wd."""
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def softmax_xent(logits, labels, weight=None):
    """Mean cross-entropy in fp32.  logits: (..., V), labels: (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if weight is None:
        return jnp.mean(nll)
    w = weight.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
