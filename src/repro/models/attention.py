"""GQA attention: RoPE, sliding window, bidirectional, qk-norm, KV cache.

Three entry modes:
  * ``full``   — training / encoder forward over the whole sequence.
  * ``prefill``— like full, but also returns the populated KV cache.
  * ``decode`` — one new token against the cache (ring buffer for SWA).

Long sequences use KV-chunked online-softmax attention (``lax.scan`` over key
chunks with running max/denominator) so activation memory scales with the
chunk size rather than S^2 — the pure-JAX equivalent of flash attention,
chosen over a Pallas kernel because this paper's kernels budget belongs to
the SVM merge path (see DESIGN.md); XLA fuses this form well on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, ones_param, param, rms_norm

NEG = -1e30


def init_attention(key, cfg, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": param(ks[0], (d, h, hd), ("embed", "q_heads", "head"), dtype),
        "wk": param(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head"), dtype),
        "wv": param(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head"), dtype),
        "wo": param(ks[3], (h, hd, d), ("q_heads", "head", "embed"), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_param((hd,), ("head",), dtype)
        p["k_norm"] = ones_param((hd,), ("head",), dtype)
    return p


def _sdpa(q5, k, v, bias, scale):
    """q5: (B,Sq,Hkv,G,hd); k/v: (B,Sk,Hkv,hd); bias: (B|1, 1, Sq, Sk)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k).astype(jnp.float32) * scale
    scores = scores + bias[:, :, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _chunked_sdpa(q5, k, v, positions, *, causal, window, scale, chunk):
    """Online-softmax attention, scanning key/value chunks of length ``chunk``.

    Self-attention layout: q positions == k positions == ``positions`` (S,).
    Peak activation is O(S * chunk) per head instead of O(S^2).
    """
    b, sq, hkv, g, hd = q5.shape
    sk = k.shape[1]
    hd_v = v.shape[-1]          # MLA: value head dim != qk head dim
    n_chunks = sk // chunk
    q32 = q5.astype(jnp.float32)

    k_c = k.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n_chunks, chunk, hkv, hd_v).transpose(1, 0, 2, 3, 4)
    kp_c = positions.reshape(n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, kpc = xs
        ok = jnp.ones((sq, chunk), bool)
        if causal:
            ok &= kpc[None, :] <= positions[:, None]
        if window is not None:
            ok &= kpc[None, :] > positions[:, None] - window
        bias = jnp.where(ok, 0.0, NEG)                     # (Sq, chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q32, kc.astype(jnp.float32)) * scale
        s = s + bias[None, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((b, hkv, g, sq), NEG, jnp.float32),
            jnp.zeros((b, hkv, g, sq), jnp.float32),
            jnp.zeros((b, hkv, g, sq, hd_v), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (k_c, v_c, kp_c))
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,Hkv,G,Sq,hd)
    return ctx.transpose(0, 3, 1, 2, 4).astype(q5.dtype)   # (B,Sq,Hkv,G,hd)


def attention(cfg, p, x, positions, *, mode: str = "full", cache=None,
              cache_pos=None):
    """Returns (y, new_cache).  x: (B, S, D); positions: (S,) absolute.

    decode: S == 1, ``cache`` = {"k","v","pos"} ring buffers, ``cache_pos`` =
    number of tokens already in the cache (scalar int32).
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // hkv
    scale = 1.0 / float(hd) ** 0.5
    causal = cfg.causal and not cfg.is_encoder
    window = cfg.sliding_window

    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "decode":
        pos = cache_pos
        w = cache["k"].shape[1]
        slot = pos % w                                     # ring buffer (SWA)
        abs_pos = pos + jnp.arange(s, dtype=jnp.int32)
        q = apply_rope(q, abs_pos, cfg.rope_theta)
        k = apply_rope(k, abs_pos, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        cp = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.broadcast_to(abs_pos[None, :], (b, s)), (0, slot))
        ok = (cp >= 0) & (cp <= pos)                       # (B, W)
        if window is not None:
            ok &= cp > pos - window
        bias = jnp.where(ok, 0.0, NEG)[:, None, None, :]   # (B,1,Sq=1,W)
        q5 = q.reshape(b, s, hkv, g, hd)
        ctx = _sdpa(q5, ck.astype(q.dtype), cv.astype(q.dtype), bias, scale)
        new_cache = {"k": ck, "v": cv, "pos": cp}
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        q5 = q.reshape(b, s, hkv, g, hd)
        if cfg.seq_shard_attn is not None:
            # context parallelism: queries sharded over `model` along S,
            # keys/values replicated along S — removes the 16x attention
            # replication when head counts don't divide the model axis.
            from jax.sharding import PartitionSpec as P
            dp = cfg.seq_shard_attn
            q5 = jax.lax.with_sharding_constraint(
                q5, P(dp, "model", None, None, None))
            k = jax.lax.with_sharding_constraint(k, P(dp, None, None, None))
            v = jax.lax.with_sharding_constraint(v, P(dp, None, None, None))
        if s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
            ctx = _chunked_sdpa(q5, k, v, positions, causal=causal,
                                window=window, scale=scale, chunk=cfg.attn_chunk)
            if cfg.seq_shard_attn is not None:
                from jax.sharding import PartitionSpec as P
                ctx = jax.lax.with_sharding_constraint(
                    ctx, P(cfg.seq_shard_attn, "model", None, None, None))
        else:
            ok = jnp.ones((s, s), bool)
            if causal:
                ok &= positions[None, :] <= positions[:, None]
            if window is not None:
                ok &= positions[None, :] > positions[:, None] - window
            bias = jnp.where(ok, 0.0, NEG)[None, None]     # (1,1,S,S)
            ctx = _sdpa(q5, k, v, bias, scale)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "k": k, "v": v,
                "pos": jnp.broadcast_to(positions[None, :], (b, s)).astype(jnp.int32)}

    y = jnp.einsum("bshgd,hgdo->bso", ctx, p["wo"].reshape(hkv, g, hd, d))
    return y, new_cache


def init_attn_cache(cfg, batch: int, max_len: int, dtype):
    w = max_len if cfg.sliding_window is None else min(cfg.sliding_window, max_len)
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    return {
        "k": jnp.zeros((batch, w, hkv, hd), dtype),
        "v": jnp.zeros((batch, w, hkv, hd), dtype),
        "pos": jnp.full((batch, w), -1, jnp.int32),
    }
