"""Mamba-2 (SSD — state-space duality) mixer: chunked dual form + O(1) decode.

Train/prefill use the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is split into chunks of length Q; within a chunk the computation is
an attention-like (Q x Q) masked product (MXU-friendly), across chunks a
single ``lax.scan`` carries the (H, N, P) recurrent state.  Decode is the
plain SSM recurrence on one token.

Projections are kept separate (z/x, B/C, dt) instead of one fused in_proj so
each can carry its own sharding axis (d_inner shards over the model axis;
B/C/dt are small and stay replicated) — see DESIGN.md §3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ones_param, param, rms_norm, zeros_param


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, n_heads, conv_ch


def init_mamba(key, cfg, dtype):
    s, d_in, n_heads, conv_ch = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    # dt bias initialized so softplus(dt_bias) spans ~[1e-3, 1e-1]
    dt0 = jnp.exp(jax.random.uniform(ks[6], (n_heads,), jnp.float32,
                                     jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_zx": param(ks[0], (d, 2 * d_in), ("embed", "inner"), dtype),
        "in_bc": param(ks[1], (d, 2 * s.n_groups * s.d_state), ("embed", None), dtype),
        "in_dt": param(ks[2], (d, n_heads), ("embed", None), dtype),
        # depthwise conv split into consistently-sharded segments: fusing the
        # model-sharded x channels with the replicated B/C channels into one
        # conv forced GSPMD into 24 GB/dev of halo permutes (§Perf)
        "conv_wx": param(ks[3], (s.d_conv, d_in), (None, "inner"), dtype, scale=0.5),
        "conv_bx": zeros_param((d_in,), ("inner",), dtype),
        "conv_wbc": param(ks[7], (s.d_conv, 2 * s.n_groups * s.d_state),
                          (None, None), dtype, scale=0.5),
        "conv_bbc": zeros_param((2 * s.n_groups * s.d_state,), (None,), dtype),
        "A_log": (jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
                  ones_param((n_heads,), (None,), dtype)[1]),
        "dt_bias": (dt_bias, ones_param((n_heads,), (None,), dtype)[1]),
        "D_skip": ones_param((n_heads,), (None,), dtype),
        "gate_norm": ones_param((d_in,), ("inner",), dtype),
        "out": param(ks[5], (d_in, d), ("inner", "embed"), dtype),
    }


def _causal_conv(u, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv.  u: (B,S,C); conv_w: (K,C).  Returns (y, tail).

    ``conv_state``: (B, K-1, C) carried context for decode/prefill-chaining.
    """
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)               # (B, S+K-1, C)
    # y[t] = sum_j w[j] * ext[t+j]
    y = sum(ext[:, j: j + u.shape[1], :] * conv_w[j][None, None, :]
            for j in range(k))
    tail = ext[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y + conv_b[None, None, :]), tail


def _ssd_chunked(xh, b_mat, c_mat, dt, a_h, chunk: int, state0=None):
    """Chunked SSD.  xh: (B,S,H,P); b/c: (B,S,H,N) (group-expanded);
    dt: (B,S,H) (>=0); a_h: (H,) negative.  Returns (y, final_state)."""
    bsz, s, h, p = xh.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    f32 = jnp.float32

    xc = xh.reshape(bsz, nc, chunk, h, p).astype(f32)
    bc = b_mat.reshape(bsz, nc, chunk, h, n).astype(f32)
    cc = c_mat.reshape(bsz, nc, chunk, h, n).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    da = dtc * a_h.astype(f32)[None, None, None, :]       # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(da, axis=2)                          # within-chunk cumsum

    # intra-chunk: scores[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j <= i
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bnqhc,bnkhc->bnqkh", cc, bc)         # (B,nc,Q,K,H)
    w_att = cb * decay * dtc[:, :, None, :, :]            # weight on x_k
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", w_att, xc)

    # chunk summary states: S_n = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    s_chunk = jnp.einsum("bnqh,bnqhc,bnqhp->bnhcp",
                         decay_end * dtc, bc, xc)         # (B,nc,H,N,P)
    chunk_gain = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_body(state, inp):
        s_n, gain = inp
        new = state * gain[:, :, None, None] + s_n
        return new, state                                  # emit state BEFORE chunk

    init = (jnp.zeros((bsz, h, n, p), f32) if state0 is None
            else state0.astype(f32))
    final_state, prev_states = jax.lax.scan(
        scan_body, init,
        (s_chunk.transpose(1, 0, 2, 3, 4), chunk_gain.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,N,P)

    # inter-chunk: y_i += C_i . (exp(cum_i) * state_prev)
    y_inter = jnp.einsum("bnqhc,bnhcp,bnqh->bnqhp", cc, prev_states,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final_state


def mamba2(cfg, p, x, *, mode: str = "full", cache=None):
    """Returns (y, new_cache).  cache = {"conv": (B,K-1,C), "ssm": (B,H,N,P)}."""
    s_cfg, d_in, n_heads, conv_ch = _dims(cfg)
    bsz, s, _ = x.shape
    hp = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state
    heads_per_group = n_heads // g

    zx = x @ p["in_zx"]
    z, xin = zx[..., :d_in], zx[..., d_in:]
    bc = x @ p["in_bc"]
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a_h = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) < 0

    state_x = cache["conv_x"] if cache is not None else None
    state_bc = cache["conv_bc"] if cache is not None else None

    if mode == "decode":
        xin_c, tail_x = _causal_conv(xin, p["conv_wx"], p["conv_bx"], state_x)
        y_bc, tail_bc = _causal_conv(bc, p["conv_wbc"], p["conv_bbc"], state_bc)
        b_raw = y_bc[..., : g * n]
        c_raw = y_bc[..., g * n:]
        xh = xin_c.reshape(bsz, s, n_heads, hp).astype(jnp.float32)
        b_h = jnp.repeat(b_raw.reshape(bsz, s, g, n), heads_per_group,
                         axis=2).astype(jnp.float32)
        c_h = jnp.repeat(c_raw.reshape(bsz, s, g, n), heads_per_group,
                         axis=2).astype(jnp.float32)
        # one-step recurrence (s == 1)
        da = jnp.exp(dt[:, 0] * a_h[None, :])                 # (B,H)
        state = cache["ssm"].astype(jnp.float32)
        state = (state * da[:, :, None, None]
                 + jnp.einsum("bh,bhc,bhp->bhcp", dt[:, 0], b_h[:, 0], xh[:, 0]))
        y = jnp.einsum("bhc,bhcp->bhp", c_h[:, 0], state)[:, None]  # (B,1,H,P)
        new_cache = {"conv_x": tail_x, "conv_bc": tail_bc,
                     "ssm": state.astype(cache["ssm"].dtype)}
    else:
        xin_c, tail_x = _causal_conv(xin, p["conv_wx"], p["conv_bx"], state_x)
        y_bc, tail_bc = _causal_conv(bc, p["conv_wbc"], p["conv_bbc"], state_bc)
        b_raw = y_bc[..., : g * n]
        c_raw = y_bc[..., g * n:]
        xh = xin_c.reshape(bsz, s, n_heads, hp)
        b_h = jnp.repeat(b_raw.reshape(bsz, s, g, n), heads_per_group, axis=2)
        c_h = jnp.repeat(c_raw.reshape(bsz, s, g, n), heads_per_group, axis=2)
        chunk = min(s_cfg.chunk, s)
        assert s % chunk == 0, (s, chunk)
        y, final_state = _ssd_chunked(xh, b_h, c_h, dt, a_h, chunk)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv_x": tail_x.astype(x.dtype),
                         "conv_bc": tail_bc.astype(x.dtype),
                         "ssm": final_state.astype(x.dtype)}
        y = y.reshape(bsz, s, n_heads, hp)

    y = y.astype(jnp.float32) + p["D_skip"].astype(jnp.float32)[None, None, :, None] \
        * (xin_c if mode != "decode" else xh).reshape(bsz, s, n_heads, hp).astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out"], new_cache


def init_mamba_cache(cfg, batch: int, dtype):
    s, d_in, n_heads, conv_ch = _dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, s.d_conv - 1, 2 * s.n_groups * s.d_state),
                             dtype),
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), dtype),
    }
