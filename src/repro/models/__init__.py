"""Pure-JAX model zoo for the assigned architectures."""
from . import attention, common, lm, mamba2, mla, moe
from .lm import decode_step, encode_step, forward, init_cache, init_lm, loss_fn, prefill

__all__ = ["attention", "common", "lm", "mamba2", "mla", "moe", "decode_step",
           "encode_step", "forward", "init_cache", "init_lm", "loss_fn", "prefill"]
