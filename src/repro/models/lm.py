"""Model assembly: embedding -> (prefix + scanned layer groups) -> head.

Layers are grouped into an unrolled *prefix* (e.g. deepseek-v3's first three
dense layers) and a repeating *unit* scanned with ``lax.scan`` (jamba's unit
is 8 layers: 7 mamba + 1 attention, alternating dense/MoE FFNs).  Scanning
keeps compile time flat in depth and gives remat a natural boundary.

Entry points:
  * ``init_lm``     -> (params, axes) — axes feed ``repro.sharding.specs``.
  * ``loss_fn``     -> scalar LM loss (causal shift, optional MTP head).
  * ``forward``     -> logits (+ caches for prefill).
  * ``decode_step`` -> one-token serving step against a cache.
  * ``init_cache``  -> zeroed cache pytree for (batch, max_len).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, init_attention, init_attn_cache
from .common import Axes, ones_param, param, rms_norm, softmax_xent, split_params_axes, swiglu
from .mamba2 import init_mamba, init_mamba_cache, mamba2
from .mla import init_mla, init_mla_cache, mla_attention


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_dense_ffn(key, cfg, width, dtype):
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": param(k2, (d, width), ("embed", "ffn"), dtype),
        "w_down": param(k3, (width, d), ("ffn", "embed"), dtype),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = param(k1, (d, width), ("embed", "ffn"), dtype)
    return p


def _init_layer(key, cfg, layer_idx: int, dtype):
    from .moe import init_moe  # local import to keep module graph acyclic

    kind, ffn_kind = cfg.mixer_kind(layer_idx), cfg.ffn_kind(layer_idx)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": ones_param((cfg.d_model,), ("embed",), dtype),
        "ln2": ones_param((cfg.d_model,), ("embed",), dtype),
    }
    if kind == "attn":
        p["mixer"] = (init_mla(k1, cfg, dtype) if cfg.attn_kind == "mla"
                      else init_attention(k1, cfg, dtype))
    else:
        p["mixer"] = init_mamba(k1, cfg, dtype)
    if ffn_kind == "dense":
        width = cfg.moe_dense_ff() if cfg.moe is not None else cfg.d_ff
        p["ffn"] = _init_dense_ffn(k2, cfg, width, dtype)
    elif ffn_kind == "moe":
        p["ffn"] = init_moe(k2, cfg, dtype)
    else:                      # "none": mixer-only layer (mamba2)
        del p["ln2"]
    return p


def init_lm(key, cfg):
    """Returns (params, axes): parallel pytrees of arrays / Axes."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    tree = {}
    if cfg.input_kind == "frames":
        tree["frame_proj"] = param(keys[0], (cfg.frame_dim, cfg.d_model),
                                   ("frame", "embed"), dtype)
        tree["mask_embed"] = param(keys[5], (cfg.d_model,), ("embed",), dtype,
                                   scale=0.02)
    tree["embed"] = param(keys[1], (cfg.vocab_padded, cfg.d_model),
                          ("vocab", "embed"), dtype, scale=cfg.d_model**-0.5)
    tree["final_norm"] = ones_param((cfg.d_model,), ("embed",), dtype)
    if not cfg.tie_embeddings:
        tree["lm_head"] = param(keys[2], (cfg.d_model, cfg.vocab_padded),
                                ("embed", "vocab"), dtype)

    # prefix layers (unrolled)
    pref = cfg.prefix_layers
    if pref:
        pkeys = jax.random.split(keys[3], pref)
        tree["prefix"] = [_init_layer(pkeys[i], cfg, i, dtype) for i in range(pref)]

    # scanned body: vmap the unit init over group keys, prepend "layers" axis
    unit = cfg.scan_unit
    n_groups = cfg.n_scan_groups

    def init_unit(k):
        uks = jax.random.split(k, unit)
        pairs = {f"l{j}": _init_layer(uks[j], cfg, pref + j, dtype)
                 for j in range(unit)}
        return split_params_axes(pairs)[0]

    template = {f"l{j}": _init_layer(jax.random.split(keys[4], unit)[j], cfg,
                                     pref + j, dtype) for j in range(unit)}
    _, unit_axes = split_params_axes(template)
    body = jax.vmap(init_unit)(jax.random.split(keys[4], n_groups))
    body_axes = jax.tree.map(lambda a: Axes("layers", *a.names), unit_axes,
                             is_leaf=lambda x: isinstance(x, Axes))
    if cfg.mtp_depth:
        mk1, mk2, mk3 = jax.random.split(keys[6], 3)
        tree["mtp"] = {
            "proj": param(mk1, (2 * cfg.d_model, cfg.d_model),
                          ("embed", "embed_out"), dtype),
            "block": _init_layer(mk2, cfg, cfg.n_layers - 1, dtype),
            "norm": ones_param((cfg.d_model,), ("embed",), dtype),
        }

    params, axes = split_params_axes(tree)
    params["body"] = body
    axes["body"] = body_axes
    return params, axes


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------
def _apply_layer(cfg, lp, x, positions, kind, ffn_kind, *, mode, cache,
                 cache_pos):
    from .moe import moe_ffn

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        fn = mla_attention if cfg.attn_kind == "mla" else attention
        y, new_c = fn(cfg, lp["mixer"], h, positions, mode=mode,
                      cache=None if cache is None else cache["mixer"],
                      cache_pos=cache_pos)
    else:
        y, new_c = mamba2(cfg, lp["mixer"], h, mode=mode,
                          cache=None if cache is None else cache["mixer"])
    x = x + y
    if cfg.seq_shard_attn is not None and kind == "attn" and mode == "full":
        # sequence-parallel residual (§Perf cell B iter 2): keep the stream
        # S-sharded so the FFN entry all-gather + exit reduce-scatter replace
        # the attention-exit gather + FFN all-reduce (fewer bytes, and norms
        # run on 1/16th of the tokens per shard)
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(cfg.seq_shard_attn, "model", None))
    new_cache = None if new_c is None else {"mixer": new_c}
    if ffn_kind == "none":
        return x, new_cache
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if ffn_kind == "dense":
        if cfg.mlp_act == "swiglu":
            y = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
        else:
            y = jax.nn.gelu(h @ lp["ffn"]["w_up"]) @ lp["ffn"]["w_down"]
    else:
        y = moe_ffn(cfg, lp["ffn"], h)
    return x + y, new_cache


def _embed_inputs(cfg, params, batch, mode):
    if cfg.input_kind == "frames":
        x = batch["frames"].astype(params["frame_proj"].dtype) @ params["frame_proj"]
        if "mask" in batch:  # hubert-style masked prediction: replace frames
            x = jnp.where(batch["mask"][..., None], params["mask_embed"], x)
        return x
    tok = batch["tokens"] if isinstance(batch, dict) else batch
    return jnp.take(params["embed"], tok, axis=0)


def forward(cfg, params, batch, *, mode: str = "full", cache=None,
            cache_pos=None, return_hidden: bool = False):
    """Returns (logits, new_cache[, hidden]).

    batch: {"tokens": (B, S)} or {"frames","mask"} for encoders; for decode,
    tokens is (B, 1) and cache/cache_pos must be given.
    """
    x = _embed_inputs(cfg, params, batch, mode)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)

    plan = cfg.layer_plan()
    pref = cfg.prefix_layers
    new_prefix_caches = []
    for i in range(pref):
        c = None if cache is None else cache["prefix"][i]
        x, nc = _apply_layer(cfg, params["prefix"][i], x, positions, plan[i][0],
                             plan[i][1], mode=mode, cache=c, cache_pos=cache_pos)
        new_prefix_caches.append(nc)

    unit = cfg.scan_unit

    def unit_body(x, xs):
        up, uc = xs
        new_caches = {}
        for j in range(unit):
            kind, ffn_kind = plan[pref + j]
            c = None if uc is None else uc[f"l{j}"]
            x, nc = _apply_layer(cfg, up[f"l{j}"], x, positions, kind, ffn_kind,
                                 mode=mode, cache=c, cache_pos=cache_pos)
            new_caches[f"l{j}"] = nc
        return x, (new_caches if mode != "full" else None)

    body_fn = unit_body
    if cfg.remat and mode == "full":
        body_fn = jax.checkpoint(unit_body)

    body_cache = None if cache is None else cache["body"]
    if cfg.scan_unroll:
        # Straight-line form: identical math, but every layer appears in the
        # HLO so cost_analysis / collective parsing see true totals (XLA
        # counts while-loop bodies once).  Dry-run / roofline only.
        emitted = []
        for gi in range(cfg.n_scan_groups):
            up = jax.tree.map(lambda a: a[gi], params["body"])
            uc = (None if body_cache is None
                  else jax.tree.map(lambda a: a[gi], body_cache))
            x, out = body_fn(x, (up, uc))
            emitted.append(out)
        body_caches = (None if emitted[0] is None else
                       jax.tree.map(lambda *xs: jnp.stack(xs), *emitted))
    else:
        x, body_caches = jax.lax.scan(
            body_fn, x,
            (params["body"], body_cache) if body_cache is not None
            else (params["body"], None))

    hidden = x
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"body": body_caches}
        if pref:
            new_cache["prefix"] = new_prefix_caches
    if return_hidden:
        return logits, new_cache, hidden
    return logits, new_cache


# --------------------------------------------------------------------------
# losses / steps
# --------------------------------------------------------------------------
def loss_fn(cfg, params, batch):
    """Causal-LM (or masked-encoder) cross-entropy; adds MTP loss if enabled."""
    if cfg.is_encoder:
        logits, _ = forward(cfg, params, batch, mode="full")
        return softmax_xent(logits, batch["labels"], batch.get("mask"))

    tokens = batch["tokens"]
    labels = batch["labels"]                      # next-token ids, (B, S)
    weight = batch.get("mask")
    if cfg.mtp_depth:
        logits, _, hidden = forward(cfg, params, batch, mode="full",
                                    return_hidden=True)
    else:
        logits, _ = forward(cfg, params, batch, mode="full")
    loss = softmax_xent(logits, labels, weight)

    if cfg.mtp_depth:
        # Multi-token prediction (deepseek-v3, depth 1): combine the hidden
        # state with the embedding of the *next* token and predict t+2.
        mtp = params["mtp"]
        emb_next = jnp.take(params["embed"], labels, axis=0)
        h = jnp.concatenate([rms_norm(hidden, mtp["norm"], cfg.norm_eps),
                             emb_next], axis=-1) @ mtp["proj"]
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        plan_kind = ("attn", "dense") if cfg.moe is None else ("attn", "moe")
        h, _ = _apply_layer(cfg, mtp["block"], h, positions, plan_kind[0],
                            cfg.ffn_kind(cfg.n_layers - 1), mode="full",
                            cache=None, cache_pos=None)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits2 = h @ head
        labels2 = jnp.roll(labels, -1, axis=1)
        w2 = jnp.ones_like(labels2, jnp.float32).at[:, -1].set(0.0)
        if weight is not None:
            w2 = w2 * weight
        loss = loss + 0.3 * softmax_xent(logits2, labels2, w2)
    return loss


def decode_step(cfg, params, cache, tokens, cache_pos):
    """One serving step: tokens (B, 1) -> (logits (B, V), new_cache)."""
    logits, new_cache = forward(cfg, params, {"tokens": tokens}, mode="decode",
                                cache=cache, cache_pos=cache_pos)
    return logits[:, -1, :], new_cache


def prefill(cfg, params, tokens):
    """Full-sequence prefill: returns (last-position logits, cache)."""
    logits, cache = forward(cfg, params, {"tokens": tokens}, mode="prefill")
    return logits[:, -1, :], cache


def encode_step(cfg, params, batch):
    """Encoder inference (hubert): frames -> logits over cluster vocab."""
    logits, _ = forward(cfg, params, batch, mode="full")
    return logits


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def _layer_cache(cfg, layer_idx: int, batch: int, max_len: int, dtype):
    kind = cfg.mixer_kind(layer_idx)
    if kind == "attn":
        if cfg.attn_kind == "mla":
            return {"mixer": init_mla_cache(cfg, batch, max_len, dtype)}
        return {"mixer": init_attn_cache(cfg, batch, max_len, dtype)}
    return {"mixer": init_mamba_cache(cfg, batch, dtype)}


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    pref = cfg.prefix_layers
    unit = cfg.scan_unit
    n_groups = cfg.n_scan_groups
    cache = {}
    if pref:
        cache["prefix"] = [_layer_cache(cfg, i, batch, max_len, dtype)
                           for i in range(pref)]
    unit_cache = {f"l{j}": _layer_cache(cfg, pref + j, batch, max_len, dtype)
                  for j in range(unit)}
    cache["body"] = jax.tree.map(
        lambda a: jnp.tile(a[None], (n_groups,) + (1,) * a.ndim), unit_cache)
    return cache
