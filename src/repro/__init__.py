"""repro: Budgeted SGD SVM training with precomputed golden section search,
built as a multi-pod JAX framework (see DESIGN.md)."""
__version__ = "0.1.0"
