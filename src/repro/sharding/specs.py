"""Logical-axis -> PartitionSpec resolution for params, batches and caches.

Rules map logical axis names (attached at init via ``models.common.Axes``) to
mesh axes.  Resolution is size-aware: a dim that does not divide its mesh axis
falls back to replication (e.g. smollm's 15 heads, yi's 4 KV heads), and a
mesh axis is never used twice in one spec.

Strategies:
  * tp    — tensor parallelism over ``model`` (heads/ffn/vocab/experts/inner).
  * fsdp  — adds ZeRO-3-style sharding of the ``embed`` dim over ``data``
            (params, grads and Adam state all inherit it).
Batch dims shard over ``("pod", "data")`` when the pod axis exists.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import Axes

TP_RULES = {
    "vocab": "model", "q_heads": "model", "kv_heads": "model", "ffn": "model",
    "experts": "model", "inner": "model",
    "expert_ffn": None, "embed": None, "head": None, "layers": None,
    "q_lora": None, "kv_lora": None, "frame": None, "embed_out": None,
    None: None,
}


def rules_for(strategy: str) -> dict:
    rules = dict(TP_RULES)
    if strategy == "fsdp":
        rules["embed"] = "data"
    elif strategy != "tp":
        raise ValueError(f"unknown sharding strategy {strategy!r}")
    return rules


def dp_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def resolve_spec(axes: Axes, shape, mesh, rules) -> P:
    entries, used = [], set()
    for name, dim in zip(axes.names, shape):
        ax = rules.get(name)
        if ax is not None and ax not in mesh.shape:
            ax = None  # mesh without this axis (e.g. 1-D host mesh)
        if ax is not None and ax not in used and dim % mesh.shape[ax] == 0:
            entries.append(ax)
            used.add(ax)
        else:
            entries.append(None)
    return P(*entries)


def param_specs(axes_tree, shape_tree, mesh, strategy: str = "tp"):
    """PartitionSpec tree for params (shape_tree from jax.eval_shape)."""
    rules = rules_for(strategy)
    return jax.tree.map(
        lambda a, s: resolve_spec(a, s.shape, mesh, rules),
        axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, Axes))


def param_shardings(axes_tree, shape_tree, mesh, strategy: str = "tp"):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        param_specs(axes_tree, shape_tree, mesh, strategy))


def batch_spec(mesh, batch_shape_tree):
    """Shard the leading (batch) dim of every batch leaf over (pod, data)."""
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda s: P(dp, *([None] * (len(s.shape) - 1))), batch_shape_tree)


def cache_specs(cache_shape_tree, mesh, *, policy: str = "batch"):
    """PartitionSpec tree for a decode cache.

    policy="batch"   : shard the batch dim over (pod, data); shard head-ish
                       dims over model when they divide.
    policy="sequence": batch too small to shard (long-context decode) — shard
                       the cache *sequence* dim over data instead (distributed
                       attention with softmax partial-reduction collectives).
    """
    dp = dp_axes(mesh)
    model = mesh.shape["model"]
    # base ranks of each cache leaf kind (body caches carry an extra leading
    # stacked `layers` dim, detected by ndim and spec'd None)
    base_rank = {"k": 4, "v": 4, "pos": 2, "ckv": 3, "krope": 3,
                 "conv_x": 3, "conv_bc": 3, "ssm": 4}

    def leaf_spec(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        shape = leaf.shape
        rank = base_rank.get(name)
        stacked = rank is not None and len(shape) == rank + 1
        core = shape[1:] if stacked else shape

        if name in ("k", "v"):           # (B, W, Hkv, hd)
            head = "model" if core[2] % model == 0 else None
            spec = (None, "data", head, None) if policy == "sequence" \
                else (dp, None, head, None)
        elif name == "pos":              # (B, W)
            spec = (None, "data") if policy == "sequence" else (dp, None)
        elif name in ("ckv", "krope"):   # (B, S, r)
            spec = (None, "data", None) if policy == "sequence" else (dp, None, None)
        elif name in ("conv_x", "conv_bc"):   # (B, K-1, C)
            spec = (None, None, "model" if core[2] % model == 0 else None) \
                if policy == "sequence" else (dp, None, None)
        elif name == "ssm":              # (B, H, N, P)
            hspec = "model" if core[1] % model == 0 else None
            spec = (None, hspec, None, None) if policy == "sequence" \
                else (dp, hspec, None, None)
        else:
            return P(*([None] * len(shape)))

        # divisibility guard on the batch/data entries too
        fixed = []
        for entry, dim in zip(spec, core):
            size = 1
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax is not None:
                    size *= mesh.shape[ax]
            fixed.append(entry if dim % size == 0 else None)
        if stacked:
            fixed = [None] + fixed
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape_tree)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
