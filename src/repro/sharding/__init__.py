from .specs import batch_spec, cache_specs, dp_axes, param_shardings, param_specs, resolve_spec, rules_for, to_shardings
