"""Fused multiclass train-step megakernel — one launch chain per minibatch.

A composed multiclass train step is three separately-launched phases: the
fused-rbf margin block, the vmapped shrink+insert, and the maintenance event
rounds (``merge_event``).  Each phase boundary re-streams the stacked SV bank
and ``(C, S, S)`` kernel cache through HBM.  This kernel folds all three onto
``merge_event``'s class grid and runs the WHOLE step per class block without
leaving VMEM:

  1. **margin** — the class's RBF margin rows ``k(xb, sv_c)`` from the
     resident ``(1, S, D)`` SV block (``rbf_matrix``'s matmul decomposition,
     in-kernel, MXU);
  2. **insert** — Pegasos shrink + masked insert of violating rows, with the
     margin rows reused as the new cache rows/columns — the I1-I4 cache
     invariants are maintained in VMEM with one-hot MXU scatters (no host
     round-trip, no HBM gather);
  3. **events** — up to ``rounds`` maintenance event rounds chained on the
     same resident blocks: single-pair rounds reuse
     ``merge_event._merge_event_body`` verbatim; multi-merge rounds retire up
     to P disjoint same-sign pairs per round (top-P smallest |alpha| fixed
     partners, Lookup-WD scored against the VMEM-resident tables, greedy
     disjoint choice, fused z-row writes + targeted-move compaction — the
     in-kernel restatement of ``core.budget._multi_merge_once``).

Classes at or under budget ride the event rounds as bitwise no-ops, so a
static ``rounds = batch_size`` always suffices (one minibatch bounds the
excess by ``batch_size`` and every round retires >= 1 SV per over class).
Class blocks are double-buffered through the grid by the Pallas pipeline;
outputs alias inputs so the whole stacked state updates in place.

Scatter/gather-free idioms as in ``merge_event``: scalars via one-hot
reductions, row gathers via one-hot MXU matmuls, batched scatters via masked
selects on ``broadcasted_iota`` ids, inclusive cumsum via a lower-triangular
ones matmul.  Oracle and production CPU path: ``ref.train_step_fused``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .merge_event import _first_where, _merge_event_body, _onehot_f32
from .merge_lookup import WD_INVALID, _hat_weights
from .ref import NO_PARTNER, _safe_log


def _insert_body(count, t, nins, yb, xb, kbb, alpha_in, sv_in, kmat, *,
                 lambda_: float, gamma: float, batch_size: int):
    """Margin + shrink + masked violator insert on VMEM-resident values.

    count/t/nins: () int32; yb: (B,) one-vs-rest targets; xb: (B, D)
    minibatch (rows >= batch_size are zero padding); kbb: (B, B) =
    ``k(xb, xb)``; alpha_in: (S,) storage dtype; sv_in: (S, D); kmat:
    (S, S) fp32.  Returns ``(alpha, sv, kmat, count, nins)`` with exactly
    ``bsgd.insert_from_rows`` + ``kernel_cache.insert_rows`` semantics.
    """
    alpha = alpha_in.astype(jnp.float32)
    s = alpha.shape[0]
    b = xb.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)[0]
    biota = jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)[0]
    sv_f = sv_in.astype(jnp.float32)
    xb_f = xb.astype(jnp.float32)
    yb_f = yb.astype(jnp.float32)

    # 1. margin rows k(xb, sv) — rbf_matrix's matmul decomposition, in-kernel
    xn = jnp.sum(xb_f * xb_f, axis=1, keepdims=True)          # (B, 1)
    yn = jnp.sum(sv_f * sv_f, axis=1, keepdims=True)          # (S, 1)
    prod = jax.lax.dot_general(xb_f, sv_f, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    k_b = jnp.exp(-gamma * jnp.maximum(xn + yn.T - 2.0 * prod, 0.0))

    active = iota < count
    f = jax.lax.dot_general(k_b, jnp.where(active, alpha, 0.0)[:, None],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)[:, 0]
    margin = yb_f * f

    # 2. Pegasos shrink + watermark insert of the violating rows.  Padding
    #    lanes (>= batch_size) never violate; the inclusive cumsum over the
    #    violation mask is a lower-triangular ones matmul (no jnp.cumsum on
    #    the TPU vector unit).
    eta = 1.0 / (lambda_ * t.astype(jnp.float32))
    alpha = alpha * (1.0 - eta * lambda_)
    viol = (margin < 1.0) & (biota < batch_size)
    viol_f = viol.astype(jnp.float32)
    tri = (biota[:, None] >= biota[None, :]).astype(jnp.float32)
    csum = jax.lax.dot_general(tri, viol_f[:, None], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)[:, 0]
    pos = count + csum.astype(jnp.int32) - 1
    idx_b = jnp.where(viol, pos, s)                           # (B,) OOB=drop
    sel = (iota[:, None] == idx_b[None, :]).astype(jnp.float32)   # (S, B)
    written = jnp.sum(sel, axis=1) > 0.0                      # (S,)

    sv_rows = jax.lax.dot_general(sel, xb_f, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    sv = jnp.where(written[:, None], sv_rows.astype(sv_in.dtype), sv_in)
    new_a = eta * yb_f / batch_size
    a_rows = jax.lax.dot_general(sel, new_a[:, None], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)[:, 0]
    alpha = jnp.where(written, a_rows, alpha)

    # 3. cache insert (kernel_cache.insert_rows): the margin rows ARE the new
    #    rows/columns, with the new-vs-new block patched in at the inserted
    #    slots; rows -> columns -> diagonal so column values win at
    #    intersections, exactly like the scatter form.
    repl = jax.lax.dot_general(kbb.astype(jnp.float32), sel,
                               (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)   # (B, S)
    rows_mod = jnp.where(written[None, :], repl, k_b)
    scattered = jax.lax.dot_general(sel, rows_mod, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    km = jnp.where(written[:, None], scattered, kmat)
    km = jnp.where(written[None, :], scattered.T, km)
    km = jnp.where((row_ids == col_ids) & written[:, None], 1.0, km)

    n_new = jnp.sum(viol.astype(jnp.int32))
    return (alpha.astype(alpha_in.dtype), sv, km, count + n_new,
            nins + n_new)


def _multi_merge_body(count, alpha_in, sv_in, kmat, h_tab, wd_tab, *,
                      budget: int, p: int, g: int, block_s: int):
    """One multi-merge event on VMEM-resident values (no refs).

    The in-kernel restatement of ``core.budget._multi_merge_once`` +
    ``kernel_cache.apply_multi_merge`` (oracle: ``ref.multi_merge_event``):
    up to ``p`` disjoint same-sign pairs merge in one fused pass, then the
    targeted-move compaction repairs the watermark.  P is small and static,
    so the per-pair work unrolls into masked selects and one-hot MXU
    products.  Returns ``(alpha, sv, kmat, new_count)`` — the CALLER masks
    by its ``over`` flag (unlike ``_merge_event_body`` the no-op masking is
    not internal).
    """
    alpha = alpha_in.astype(jnp.float32)
    s = alpha.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)[0]
    active = iota < count
    false = count < 0                                          # scalar False

    # 1. P fixed partners: |alpha| ascending, first index on ties (the
    #    iterative masked-min extraction matches lax.top_k's tie order).
    abs_a = jnp.where(active, jnp.abs(alpha), jnp.inf)
    rem = abs_a
    a_idx, oh_a_l, a_min_l = [], [], []
    for _ in range(p):
        mq = jnp.min(rem)
        iq = _first_where(rem == mq, iota, s)
        a_idx.append(iq)
        oh_a_l.append(_onehot_f32(iota, iq))
        a_min_l.append(jnp.sum(jnp.where(iota == iq, alpha, 0.0)))
        rem = jnp.where(iota == iq, jnp.inf, rem)
    oh_a = jnp.stack(oh_a_l)                                   # (P, S)
    a_min = jnp.stack(a_min_l)                                 # (P,)

    # 2. kappa rows straight from the resident cache (one-hot MXU gather).
    kappa_rows = jax.lax.dot_general(oh_a, kmat, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)

    # 3. Lookup-WD scoring per pair row, chunked by block_s (merge_lookup's
    #    gather-free hat-basis bilinear against the resident tables).
    wd_rows, h_rows = [], []
    for q in range(p):
        valid_q = active & (alpha * a_min[q] > 0) & (iota != a_idx[q])
        wd_parts, h_parts = [], []
        for start in range(0, s, block_s):
            al_c = alpha[start:start + block_s]
            kap_c = kappa_rows[q][start:start + block_s]
            denom = a_min[q] + al_c
            m = jnp.clip(a_min[q] / jnp.where(denom == 0.0, 1.0, denom),
                         0.0, 1.0)
            kap = jnp.clip(kap_c, 0.0, 1.0)
            w_m = _hat_weights(m, g)
            w_k = _hat_weights(kap, g)
            rows_wd = jax.lax.dot_general(
                w_m, wd_tab, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            rows_h = jax.lax.dot_general(
                w_m, h_tab, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            wd_parts.append(denom * denom * jnp.sum(rows_wd * w_k, axis=1))
            h_parts.append(jnp.sum(rows_h * w_k, axis=1))
        wd_rows.append(jnp.where(valid_q, jnp.concatenate(wd_parts),
                                 WD_INVALID))
        h_rows.append(jnp.concatenate(h_parts))

    # 4. greedy disjoint pair choice in |alpha| order (budget's loop).
    excess = count - budget
    taken = iota < 0                                           # all-False
    consumed = [false] * p
    n_exec = jnp.int32(0)
    b_idx, merged, execute = [], [], []
    for q in range(p):
        wd_q = jnp.where(taken, WD_INVALID, wd_rows[q])
        mnq = jnp.min(wd_q)
        j_q = _first_where(wd_q == mnq, iota, s)
        exec_q = ~consumed[q] & (n_exec < excess)
        merged_q = exec_q & (mnq < NO_PARTNER)
        b_idx.append(j_q)
        merged.append(merged_q)
        execute.append(exec_q)
        taken = taken | ((iota == j_q) & merged_q) | \
            ((iota == a_idx[q]) & exec_q)
        for r in range(q + 1, p):
            consumed[r] = consumed[r] | ((a_idx[r] == j_q) & merged_q)
        n_exec = n_exec + exec_q.astype(jnp.int32)

    # 5. merge math + fused cache/sv/alpha writes.  All gathers (one-hot
    #    products, where-sums) happen before any write.
    oh_b = jnp.stack([_onehot_f32(iota, j) for j in b_idx])    # (P, S)
    rows_b = jax.lax.dot_general(oh_b, kmat, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    x_ab = jax.lax.dot_general(
        jnp.concatenate([oh_a, oh_b], axis=0), sv_in.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    x_a, x_b = x_ab[:p], x_ab[p:]

    h_star, lk_ab, az, z_pts, lz_rows, write_i, hole_i = \
        [], [], [], [], [], [], []
    for q in range(p):
        sel_b = iota == b_idx[q]
        hq = jnp.sum(jnp.where(sel_b, h_rows[q], 0.0))
        k_ab = jnp.sum(jnp.where(sel_b, kappa_rows[q], 0.0))
        a_b = jnp.sum(jnp.where(sel_b, alpha, 0.0))
        kap = jnp.clip(k_ab, 0.0, 1.0)
        lkq = _safe_log(kap)
        az.append(a_min[q] * jnp.exp((1.0 - hq) ** 2 * lkq)
                  + a_b * jnp.exp(hq**2 * lkq))
        z_pts.append(hq * x_a[q] + (1.0 - hq) * x_b[q])
        # the z row's log-space combine (kernel_cache's identity)
        lz_rows.append(jnp.minimum(
            hq * _safe_log(kappa_rows[q]) + (1.0 - hq) * _safe_log(rows_b[q])
            - hq * (1.0 - hq) * lkq, 0.0))
        h_star.append(hq)
        lk_ab.append(lkq)
        write_i.append(jnp.where(merged[q], a_idx[q], s))
        hole_i.append(jnp.where(merged[q], b_idx[q],
                                jnp.where(execute[q], a_idx[q], s)))

    # (P, P) cross block k(z_i, z_j): the merge identity applied a second
    # time, to the z rows; symmetrized, diagonal pinned (I2/I3).
    cross = [[None] * p for _ in range(p)]
    for i in range(p):
        for j in range(p):
            lz_a = jnp.sum(jnp.where(iota == a_idx[j], lz_rows[i], 0.0))
            lz_b = jnp.sum(jnp.where(iota == b_idx[j], lz_rows[i], 0.0))
            cross[i][j] = jnp.exp(jnp.minimum(
                h_star[j] * lz_a + (1.0 - h_star[j]) * lz_b
                - h_star[j] * (1.0 - h_star[j]) * lk_ab[j], 0.0))

    row_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    km = kmat
    for q in range(p):                       # z rows, then columns, then the
        zrow = jnp.exp(lz_rows[q])           # cross block — scatter order
        km = jnp.where(row_ids == write_i[q], zrow[None, :], km)
    for q in range(p):
        zrow = jnp.exp(lz_rows[q])
        km = jnp.where(col_ids == write_i[q], zrow[:, None], km)
    for i in range(p):
        for j in range(p):
            c_ij = 1.0 if i == j else 0.5 * (cross[i][j] + cross[j][i])
            km = jnp.where((row_ids == write_i[i]) & (col_ids == write_i[j]),
                           c_ij, km)

    d = sv_in.shape[1]
    sv_row_ids = jax.lax.broadcasted_iota(jnp.int32, (s, d), 0)
    sv = sv_in
    al = alpha
    for q in range(p):
        sv = jnp.where(sv_row_ids == write_i[q],
                       z_pts[q][None, :].astype(sv_in.dtype), sv)
        al = jnp.where(iota == write_i[q], az[q], al)

    # 6. targeted-move compaction: the k-th hole below the new watermark
    #    takes the k-th surviving slot above it (budget's dst/src pairing,
    #    the sorts replaced by iterative masked-min extraction).
    hole_mask = iota < 0
    for q in range(p):
        hole_mask = hole_mask | (iota == hole_i[q])
    new_count = count - n_exec
    front_hole = hole_mask & (iota < new_count)
    tail_surv = active & ~hole_mask & (iota >= new_count)
    dst, src = [], []
    rem_d = jnp.where(front_hole, iota, s)
    rem_s = jnp.where(tail_surv, iota, s)
    for _ in range(p):
        dq = jnp.min(rem_d)
        sq = jnp.min(rem_s)
        dst.append(dq)
        src.append(sq)
        rem_d = jnp.where(iota == dq, s, rem_d)
        rem_s = jnp.where(iota == sq, s, rem_s)
    src_c = [jnp.minimum(sq, s - 1) for sq in src]

    oh_src = jnp.stack([_onehot_f32(iota, sq) for sq in src_c])
    mrows = jax.lax.dot_general(oh_src, km, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    msv = jax.lax.dot_general(oh_src, sv.astype(jnp.float32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    mal = [jnp.sum(jnp.where(iota == src_c[q], al, 0.0)) for q in range(p)]
    for q in range(p):
        km = jnp.where(row_ids == dst[q], mrows[q][None, :], km)
    for q in range(p):
        km = jnp.where(col_ids == dst[q], mrows[q][:, None], km)
    for i in range(p):
        for j in range(p):
            inter = jnp.sum(jnp.where(iota == src_c[j], mrows[i], 0.0))
            km = jnp.where((row_ids == dst[i]) & (col_ids == dst[j]),
                           inter, km)
    for q in range(p):
        sv = jnp.where(sv_row_ids == dst[q],
                       msv[q][None, :].astype(sv_in.dtype), sv)
        al = jnp.where(iota == dst[q], mal[q], al)
    al = jnp.where(iota < new_count, al, 0.0)
    return al.astype(alpha_in.dtype), sv, km, new_count


def _train_step_kernel(count_ref, step_ref, nins_ref, nmrg_ref, yb_ref,
                       xb_ref, kbb_ref, alpha_ref, sv_ref, kmat_ref,
                       h_tab_ref, wd_tab_ref, alpha_out, sv_out, kmat_out,
                       count_out, nins_out, nmrg_out, *, budget: int,
                       lambda_: float, gamma: float, batch_size: int,
                       rounds: int, maintenance: str, merge_batch: int,
                       g: int, block_s: int):
    cnt = count_ref[0, 0]
    t = step_ref[0, 0]
    nins = nins_ref[0, 0]
    nmrg = nmrg_ref[0, 0]
    h_tab = h_tab_ref[...]
    wd_tab = wd_tab_ref[...]

    al, sv, km, cnt, nins = _insert_body(
        cnt, t, nins, yb_ref[0, :], xb_ref[...], kbb_ref[...],
        alpha_ref[0, :], sv_ref[0], kmat_ref[0], lambda_=lambda_,
        gamma=gamma, batch_size=batch_size)

    for _ in range(rounds):
        over = cnt > budget
        if maintenance == "merge":
            al, sv, km = _merge_event_body(cnt, over, al, sv, km, h_tab,
                                           wd_tab, g=g, block_s=block_s)
            cnt = cnt - over.astype(jnp.int32)
        else:                                  # multi-merge
            al2, sv2, km2, cnt2 = _multi_merge_body(
                cnt, al, sv, km, h_tab, wd_tab, budget=budget,
                p=merge_batch, g=g, block_s=block_s)
            al = jnp.where(over, al2, al)
            sv = jnp.where(over, sv2, sv)
            km = jnp.where(over, km2, km)
            cnt = jnp.where(over, cnt2, cnt)
        nmrg = nmrg + over.astype(jnp.int32)

    alpha_out[0, :] = al
    sv_out[0] = sv
    kmat_out[0] = km
    count_out[0, 0] = cnt
    nins_out[0, 0] = nins
    nmrg_out[0, 0] = nmrg


@functools.partial(jax.jit, static_argnames=(
    "budget", "lambda_", "gamma", "batch_size", "rounds", "maintenance",
    "merge_batch", "block_s", "interpret"))
def train_step_pallas(sv_x, alpha, kmat, count, step, n_inserts, n_merges,
                      xb, yb, k_bb, h_table, wd_table, *, budget: int,
                      lambda_: float, gamma: float, batch_size: int,
                      rounds: int, maintenance: str = "merge",
                      merge_batch: int = 4, block_s: int = 256,
                      interpret: bool = False):
    """One fused train step for every class, one launch chain.

    sv_x: (C, S, D); alpha: (C, S); kmat: (C, S, S) fp32; count / step /
    n_inserts / n_merges: (C, 1) int32; xb: (B, D) minibatch shared across
    the grid (rows >= ``batch_size`` are padding); yb: (C, B) one-vs-rest
    targets; k_bb: (B, B) = k(xb, xb); tables: (G, G).  S, D and B must be
    multiples of the tile sizes (``ops.train_step`` pads).  Returns
    ``(sv_x, alpha, kmat, count, n_inserts, n_merges)`` — the caller owns
    ``step + 1``.  Outputs alias the stacked state so it updates in place;
    class blocks are double-buffered through the grid.  Oracle:
    ``ref.train_step_fused``.
    """
    c, s, d = sv_x.shape
    b = xb.shape[0]
    g = h_table.shape[0]
    bs = block_s if s % block_s == 0 else (128 if s % 128 == 0 else s)
    alpha_new, sv_new, kmat_new, count_new, nins_new, nmrg_new = pl.pallas_call(
        functools.partial(_train_step_kernel, budget=budget, lambda_=lambda_,
                          gamma=gamma, batch_size=batch_size, rounds=rounds,
                          maintenance=maintenance, merge_batch=merge_batch,
                          g=g, block_s=bs),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # count
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # step
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # n_inserts
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # n_merges
            pl.BlockSpec((1, b), lambda i: (i, 0)),        # yb
            pl.BlockSpec((b, d), lambda i: (0, 0)),        # xb: shared
            pl.BlockSpec((b, b), lambda i: (0, 0)),        # k_bb: shared
            pl.BlockSpec((1, s), lambda i: (i, 0)),        # alpha
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # sv_x
            pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),  # kmat
            pl.BlockSpec((g, g), lambda i: (0, 0)),        # tables: whole
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, s), alpha.dtype),
            jax.ShapeDtypeStruct((c, s, d), sv_x.dtype),
            jax.ShapeDtypeStruct((c, s, s), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
            jax.ShapeDtypeStruct((c, 1), jnp.int32),
        ],
        input_output_aliases={7: 0, 8: 1, 9: 2, 0: 3, 2: 4, 3: 5},
        interpret=interpret,
    )(count.astype(jnp.int32), step.astype(jnp.int32),
      n_inserts.astype(jnp.int32), n_merges.astype(jnp.int32), yb, xb,
      k_bb, alpha, sv_x, kmat.astype(jnp.float32),
      h_table.astype(jnp.float32), wd_table.astype(jnp.float32))
    return sv_new, alpha_new, kmat_new, count_new, nins_new, nmrg_new
