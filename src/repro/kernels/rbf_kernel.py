"""Tiled Gaussian (RBF) kernel-matrix Pallas kernel — the BSGD per-step hot spot.

Computes K[i, j] = exp(-gamma * ||x_i - y_j||^2) for x: (n, d), y: (m, d) via
the matmul decomposition  ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y :

  * grid (n/bn, m/bm, d/bd); the d axis is innermost and accumulates the
    squared distance into the output block (revisited across k steps — the
    standard Pallas accumulate-into-output matmul pattern).
  * the -2 x yT term runs on the MXU (jnp.dot with fp32 accumulation);
    the per-block norm terms are rank-1 VPU adds.
  * exp(-gamma * acc) is applied once, on the last k step (VPU transcendental).

VMEM footprint per step = bn*bd + bm*bd inputs + bn*bm fp32 output block;
defaults (128, 128, 512) use ~0.6 MB — far below the ~16 MB/core budget, and
every matmul dim is a multiple of the 128x128 MXU tile.

Callers use ``repro.kernels.ops.rbf_matrix``, which pads to block multiples
(TPU Pallas requires block-divisible shapes), selects interpret mode off-TPU,
and slices the result back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_block_kernel(x_ref, y_ref, gamma_ref, o_ref, *, n_k: int):
    """One (bn, bm) output block; accumulates squared distance over k steps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    y = y_ref[...].astype(jnp.float32)  # (bm, bd)
    # Partial squared distance over this feature block:
    #   ||x_blk||^2 + ||y_blk||^2 - 2 x_blk . y_blk
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # (bn, 1)   VPU
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bm)   VPU
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bn, bm) MXU
    o_ref[...] += xn + yn - 2.0 * xy

    @pl.when(k == n_k - 1)
    def _finish():
        gamma = gamma_ref[0, 0]
        d2 = jnp.maximum(o_ref[...], 0.0)
        o_ref[...] = jnp.exp(-gamma * d2)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_m", "block_d", "interpret"))
def rbf_matrix_pallas(x, y, gamma, *, block_n: int = 128, block_m: int = 128,
                      block_d: int = 512, interpret: bool = False):
    """Pallas RBF kernel matrix.  Shapes must be multiples of the block sizes
    (``ops.rbf_matrix`` handles padding)."""
    n, d = x.shape
    m, d2 = y.shape
    assert d == d2, (x.shape, y.shape)
    assert n % block_n == 0 and m % block_m == 0 and d % block_d == 0, (
        "pad inputs to block multiples (see kernels.ops.rbf_matrix)")
    n_k = d // block_d
    gamma_arr = jnp.full((1, 1), gamma, jnp.float32)
    return pl.pallas_call(
        functools.partial(_rbf_block_kernel, n_k=n_k),
        grid=(n // block_n, m // block_m, n_k),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_d), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, y, gamma_arr)
