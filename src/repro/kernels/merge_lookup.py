"""Fused budget-maintenance candidate scoring — the paper's lookup, TPU-native.

Given the fixed merge partner's coefficient ``a_min`` and, per candidate j,
its coefficient ``alpha_j`` and kernel value ``kappa_j = k(x_min, x_j)``, this
kernel computes the bilinearly-interpolated table value at
``(m_j, kappa_j) = (a_min/(a_min+alpha_j), kappa_j)`` for ALL candidates in one
VMEM pass — replacing the per-candidate golden section search (paper §3).

TPU adaptation — gather-free bilinear interpolation:
  a 2-D bilinear lookup is  f(u, v) = w(u)^T  T  w(v)  where ``w(u)`` is the
  piecewise-linear *hat* basis:  w_i(u) = max(0, 1 - |u*(G-1) - i|)  (exactly
  two nonzeros).  Instead of per-lane gathers (weakly supported on the TPU
  vector unit), we materialize the hat weights densely with ``broadcasted_iota``
  and evaluate  rowsum((W_u @ T) * W_v)  — one (bS, G) x (G, G) MXU matmul per
  block against the VMEM-resident table (400x400 fp32 = 640 KB).  This turns
  the paper's "fast lookup" into systolic-array work with zero HBM traffic per
  candidate, and removes the ~10-step sequential dependency chain GSS needs.

The same kernel interpolates either table (WD_norm for Lookup-WD scoring, or
h for Lookup-h), selected by what the caller passes as ``table``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WD_INVALID = 3.4e38  # python float: jnp constants would be captured by the kernel


def _hat_weights(coord, g: int):
    """(bS,) unit-interval coords -> (bS, G) hat-basis weights (2 nonzeros/row)."""
    u = jnp.clip(coord, 0.0, 1.0) * (g - 1)
    iota = jax.lax.broadcasted_iota(jnp.float32, (coord.shape[0], g), 1)
    return jnp.maximum(0.0, 1.0 - jnp.abs(u[:, None] - iota))


def _merge_score_kernel(alpha_ref, kappa_ref, valid_ref, amin_ref, table_ref,
                        wd_ref, interp_ref, *, g: int):
    alpha = alpha_ref[0, :].astype(jnp.float32)       # (bS,)
    kappa = kappa_ref[0, :].astype(jnp.float32)
    valid = valid_ref[0, :]
    a_min = amin_ref[0, 0]
    table = table_ref[...]                            # (G, G) resident in VMEM

    denom = a_min + alpha
    m = jnp.clip(a_min / jnp.where(denom == 0.0, 1.0, denom), 0.0, 1.0)
    kap = jnp.clip(kappa, 0.0, 1.0)

    w_m = _hat_weights(m, g)                          # (bS, G)
    w_k = _hat_weights(kap, g)                        # (bS, G)
    # Gather-free bilinear: rowsum((W_m @ T) * W_k); the matmul hits the MXU.
    rows = jax.lax.dot_general(w_m, table, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bS, G)
    interp = jnp.sum(rows * w_k, axis=1)              # (bS,)

    wd = denom * denom * interp
    wd_ref[0, :] = jnp.where(valid > 0, wd, WD_INVALID)
    interp_ref[0, :] = interp


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def merge_scores_pallas(alpha, kappa_row, valid, a_min, table, *,
                        block_s: int = 512, interpret: bool = False):
    """Score all merge candidates against a precomputed table.

    alpha, kappa_row, valid: (s,) with s % block_s == 0 (ops pads);
    a_min: scalar; table: (G, G).  Returns ``(wd, interp)`` of shape (s,)
    where invalid slots get WD = 3.4e38 (argmin-safe, finite for bf16 casts).
    """
    (s,) = alpha.shape
    assert s % block_s == 0, "pad to block multiple (see kernels.ops)"
    g = table.shape[0]
    amin_arr = jnp.full((1, 1), a_min, jnp.float32)
    wd, interp = pl.pallas_call(
        functools.partial(_merge_score_kernel, g=g),
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),     # whole table, every step
        ],
        out_specs=[
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, s), jnp.float32),
            jax.ShapeDtypeStruct((1, s), jnp.float32),
        ],
        interpret=interpret,
    )(alpha[None, :], kappa_row[None, :], valid[None, :].astype(jnp.float32),
      amin_arr, table.astype(jnp.float32))
    return wd[0], interp[0]
