"""Fused multi-merge candidate scoring — P fixed partners in one VMEM pass.

Multi-merge budget maintenance (Qaadan & Glasmachers 2018) executes the P
cheapest merges per maintenance event instead of one.  Scoring then needs a
(P, S) sweep: for each fixed partner ``a_p`` (the P smallest-|alpha| SVs) and
every candidate ``j``, the bilinearly-interpolated table values at
``(m_pj, kappa_pj) = (a_p / (a_p + alpha_j), k(x_p, x_j))`` — with the kappa
rows read from the persistent kernel cache (``core.kernel_cache``), not
recomputed.

This kernel extends ``merge_lookup`` along two axes:

  * the P fixed-partner rows are scored together — the hat-basis weight
    matrices are built for all P*bS coordinates and hit the MXU as ONE
    (P*bS, G) x (G, G) matmul per table;
  * BOTH tables (WD_norm for scoring, h for executing the winners) are
    interpolated in the same pass, so the strategy layer gets everything a
    fused multi-merge scatter needs from a single kernel launch.

Same gather-free bilinear trick as ``merge_lookup``: f(u, v) = w(u)^T T w(v)
with the piecewise-linear hat basis materialized via ``broadcasted_iota``.
Default ``block_s`` is 128 (vs 512 for the single-row kernel): the weight
matrices are (8*bS, G) here, and 8 * 128 * 400 fp32 * 4 buffers ~ 6.5 MB
keeps comfortably under the ~16 MB VMEM budget with both tables resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .merge_lookup import WD_INVALID, _hat_weights

P_PAD = 8  # fp32 sublane multiple; ops pads the pair axis to this


def _multi_merge_kernel(alpha_ref, kappa_ref, valid_ref, amin_ref,
                        h_tab_ref, wd_tab_ref, wd_ref, h_ref, *, g: int):
    alpha = alpha_ref[...].astype(jnp.float32)         # (P, bS) — per-row
    kappa = kappa_ref[...].astype(jnp.float32)         # (P, bS)
    valid = valid_ref[...]                             # (P, bS)
    a_min = amin_ref[:, 0].astype(jnp.float32)         # (P,)
    p, bs = kappa.shape

    denom = a_min[:, None] + alpha                     # (P, bS)
    m = jnp.clip(a_min[:, None] / jnp.where(denom == 0.0, 1.0, denom), 0.0, 1.0)
    kap = jnp.clip(kappa, 0.0, 1.0)

    w_m = _hat_weights(m.reshape(p * bs), g)           # (P*bS, G)
    w_k = _hat_weights(kap.reshape(p * bs), g)         # (P*bS, G)
    # One MXU matmul per table for all P rows; rowsum against w_k finishes
    # the bilinear interpolation without a single gather.
    rows_wd = jax.lax.dot_general(w_m, wd_tab_ref[...], (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    rows_h = jax.lax.dot_general(w_m, h_tab_ref[...], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    interp_wd = jnp.sum(rows_wd * w_k, axis=1).reshape(p, bs)
    interp_h = jnp.sum(rows_h * w_k, axis=1).reshape(p, bs)

    wd = denom * denom * interp_wd
    wd_ref[...] = jnp.where(valid > 0, wd, WD_INVALID)
    h_ref[...] = interp_h


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def multi_merge_scores_pallas(alpha, kappa_rows, valid, a_min, h_table,
                              wd_table, *, block_s: int = 128,
                              interpret: bool = False):
    """(wd, h) of shape (P, s) for P fixed partners against all candidates.

    alpha, kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    Each pair row carries its OWN candidate-alpha row — in the binary engine
    all P rows are the same broadcast alpha, while the class-batched layout
    folds ``(C, P)`` pairs into the row axis with per-class alphas
    (``kernels.ops.multi_merge_scores``).  P must be a multiple of ``P_PAD``
    and s of ``block_s`` (ops pads).
    Invalid slots get WD = 3.4e38 (argmin-safe, finite for bf16 casts).
    """
    p, s = kappa_rows.shape
    assert s % block_s == 0 and p % P_PAD == 0, "pad first (see kernels.ops)"
    assert alpha.shape == (p, s), "alpha must be per-row (broadcast upstream)"
    g = h_table.shape[0]
    wd, h = pl.pallas_call(
        functools.partial(_multi_merge_kernel, g=g),
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((p, block_s), lambda i: (0, i)),
            pl.BlockSpec((p, block_s), lambda i: (0, i)),
            pl.BlockSpec((p, block_s), lambda i: (0, i)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
            pl.BlockSpec((g, g), lambda i: (0, 0)),    # tables: whole, every step
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((p, block_s), lambda i: (0, i)),
            pl.BlockSpec((p, block_s), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, s), jnp.float32),
            jax.ShapeDtypeStruct((p, s), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.astype(jnp.float32), kappa_rows.astype(jnp.float32),
      valid.astype(jnp.float32), a_min[:, None].astype(jnp.float32),
      h_table.astype(jnp.float32), wd_table.astype(jnp.float32))
    return wd, h
