"""Public jit'd wrappers for the Pallas kernels, with implementation dispatch.

``impl`` semantics (every op takes it):
  * ``"auto"``             — Pallas on TPU, pure-jnp reference elsewhere (XLA
                             compiles the reference well on CPU/GPU).
  * ``"pallas"``           — compiled Pallas (TPU).
  * ``"pallas_interpret"`` — Pallas in interpret mode (CPU correctness runs;
                             this is how the kernel bodies are validated here).
  * ``"ref"``              — the pure-jnp oracle from ``kernels.ref``.

Wrappers own all shape plumbing the kernels refuse to do: padding to block
multiples, re-slicing, and scalar/1-D massaging.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import gss as gss_kernel
from . import merge_lookup as merge_lookup_kernel
from . import merge_multi as merge_multi_kernel
from . import rbf_kernel
from . import ref

IMPLS = ("auto", "pallas", "pallas_interpret", "ref")


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in IMPLS:
        raise ValueError(f"impl={impl!r} not in {IMPLS}")
    return impl


def _pad_to(x, axis: int, multiple: int, value=0.0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# --------------------------------------------------------------------------
# RBF kernel matrix / row
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_n", "block_m", "block_d"))
def rbf_matrix(x, y, gamma, *, impl: str = "auto", block_n: int = 128,
               block_m: int = 128, block_d: int = 512):
    """K[i, j] = exp(-gamma ||x_i - y_j||^2); x: (n, d), y: (m, d) -> (n, m)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_matrix(x, y, gamma)
    n, m = x.shape[0], y.shape[0]
    bd = min(block_d, max(128, x.shape[1]))
    xp = _pad_to(_pad_to(x, 0, block_n), 1, bd)
    yp = _pad_to(_pad_to(y, 0, block_m), 1, bd)
    out = rbf_kernel.rbf_matrix_pallas(
        xp, yp, gamma, block_n=block_n, block_m=block_m, block_d=bd,
        interpret=(impl == "pallas_interpret"))
    return out[:n, :m]


@partial(jax.jit, static_argnames=("impl",))
def rbf_row(sv_x, x, gamma, *, impl: str = "auto"):
    """kappa_row[j] = k(x, sv_x[j]); sv_x: (s, d), x: (d,) -> (s,)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_row(sv_x, x, gamma)
    return rbf_matrix(x[None, :], sv_x, gamma, impl=impl)[0]


# --------------------------------------------------------------------------
# Merge-candidate scoring against a precomputed table (Lookup-WD / Lookup-h)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def merge_scores(alpha, kappa_row, valid, a_min, table, *, impl: str = "auto",
                 block_s: int = 512):
    """(wd, interp) per candidate; invalid slots get a large finite WD."""
    impl = _resolve(impl)
    if impl == "ref":
        wd = ref.merge_scores(alpha, kappa_row, valid, a_min, table)
        m, kap = ref.merge_coords(a_min, alpha, kappa_row)
        interp = ref.bilinear_lookup(table, m, kap)
        return wd, interp
    s = alpha.shape[0]
    bs = min(block_s, max(128, s))
    pad = lambda a: _pad_to(a, 0, bs)
    wd, interp = merge_lookup_kernel.merge_scores_pallas(
        pad(alpha), pad(kappa_row), pad(valid.astype(jnp.float32)), a_min,
        table, block_s=bs, interpret=(impl == "pallas_interpret"))
    wd = jnp.where(jnp.arange(wd.shape[0]) < s, wd, jnp.inf)[:s]
    return wd, interp[:s]


# --------------------------------------------------------------------------
# Batched golden section search
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "n_iters"))
def gss_solve(m, kappa, *, n_iters: int, impl: str = "auto"):
    """argmax_h of the merge objective for arrays of (m, kappa); any shape."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.gss(m, kappa, n_iters)
    shape = m.shape
    flat_m = m.reshape(1, -1).astype(jnp.float32)
    flat_k = kappa.reshape(1, -1).astype(jnp.float32)
    br, bc = 1, min(512, max(128, flat_m.shape[1]))
    flat_m = _pad_to(flat_m, 1, bc)
    flat_k = _pad_to(flat_k, 1, bc, value=1.0)  # kappa=1 is a benign problem
    h = gss_kernel.gss_pallas(flat_m, flat_k, n_iters=n_iters, block=(br, bc),
                              interpret=(impl == "pallas_interpret"))
    return h[0, : math.prod(shape)].reshape(shape)


# --------------------------------------------------------------------------
# Batched multi-merge scoring (P fixed partners, both tables, one pass)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def multi_merge_scores(alpha, kappa_rows, valid, a_min, table, *,
                       impl: str = "auto", block_s: int = 128):
    """(wd, h) of shape (P, s) for P fixed merge partners at once.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,);
    table: a ``MergeLookupTable`` (both grids are interpolated in one pass).
    Invalid slots get WD = +inf (ref) / 3.4e38 (pallas) — argmin-safe either way.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.multi_merge_scores(alpha, kappa_rows, valid, a_min,
                                      table.h_table, table.wd_table)
    p, s = kappa_rows.shape
    bs = min(block_s, max(128, s))
    pad_s = lambda a: _pad_to(a, a.ndim - 1, bs)
    pad_p = lambda a: _pad_to(a, 0, merge_multi_kernel.P_PAD)
    alpha_p = pad_s(alpha)
    # Tile the pair axis: the kernel keeps all its P rows resident per grid
    # step (hat-weight matrices scale with P * block_s), so one launch per
    # P_PAD pairs keeps VMEM bounded no matter how large merge_batch is.
    wds, hs = [], []
    for start in range(0, p, merge_multi_kernel.P_PAD):
        sl = slice(start, min(start + merge_multi_kernel.P_PAD, p))
        wd_c, h_c = merge_multi_kernel.multi_merge_scores_pallas(
            alpha_p, pad_p(pad_s(kappa_rows[sl])),
            pad_p(pad_s(valid[sl].astype(jnp.float32))), pad_p(a_min[sl]),
            table.h_table, table.wd_table, block_s=bs,
            interpret=(impl == "pallas_interpret"))
        wds.append(wd_c[:sl.stop - sl.start])
        hs.append(h_c[:sl.stop - sl.start])
    return jnp.concatenate(wds)[:, :s], jnp.concatenate(hs)[:, :s]
