"""Public jit'd wrappers for the Pallas kernels, with implementation dispatch.

``impl`` semantics (every op takes it):
  * ``"auto"``             — Pallas on TPU, pure-jnp reference elsewhere (XLA
                             compiles the reference well on CPU/GPU).
  * ``"pallas"``           — compiled Pallas (TPU).
  * ``"pallas_interpret"`` — Pallas in interpret mode (CPU correctness runs;
                             this is how the kernel bodies are validated here).
  * ``"ref"``              — the pure-jnp oracle from ``kernels.ref``.

Wrappers own all shape plumbing the kernels refuse to do: padding to block
multiples, re-slicing, and scalar/1-D massaging.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import gss as gss_kernel
from . import merge_event as merge_event_kernel
from . import merge_lookup as merge_lookup_kernel
from . import merge_multi as merge_multi_kernel
from . import rbf_kernel
from . import ref

IMPLS = ("auto", "pallas", "pallas_interpret", "ref")


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in IMPLS:
        raise ValueError(f"impl={impl!r} not in {IMPLS}")
    return impl


def _pad_to(x, axis: int, multiple: int, value=0.0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# --------------------------------------------------------------------------
# RBF kernel matrix / row
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_n", "block_m", "block_d"))
def rbf_matrix(x, y, gamma, *, impl: str = "auto", block_n: int = 128,
               block_m: int = 128, block_d: int = 512):
    """K[i, j] = exp(-gamma ||x_i - y_j||^2); x: (n, d), y: (m, d) -> (n, m)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_matrix(x, y, gamma)
    n, m = x.shape[0], y.shape[0]
    bd = min(block_d, max(128, x.shape[1]))
    xp = _pad_to(_pad_to(x, 0, block_n), 1, bd)
    yp = _pad_to(_pad_to(y, 0, block_m), 1, bd)
    out = rbf_kernel.rbf_matrix_pallas(
        xp, yp, gamma, block_n=block_n, block_m=block_m, block_d=bd,
        interpret=(impl == "pallas_interpret"))
    return out[:n, :m]


@partial(jax.jit, static_argnames=("impl",))
def rbf_row(sv_x, x, gamma, *, impl: str = "auto"):
    """kappa_row[j] = k(x, sv_x[j]); sv_x: (s, d), x: (d,) -> (s,)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_row(sv_x, x, gamma)
    return rbf_matrix(x[None, :], sv_x, gamma, impl=impl)[0]


# --------------------------------------------------------------------------
# Class-batched decision scoring (the serving cell's contraction)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl",))
def class_scores(x, sv_x, alpha, gamma, *, impl: str = "auto"):
    """All-class decision scores from ONE kernel launch: (C, n).

    x: (n, d) request rows; sv_x: (C, slots, d) stacked SV bank; alpha:
    (C, slots) coefficients (inactive slots zeroed by the caller).  The
    class axis folds into the SV axis so the kernel block is a single
    (n, C * slots) ``rbf_matrix`` — one Pallas launch / one XLA matmul no
    matter how many classes — then a per-class contraction over slots with
    accumulation in ``alpha``'s dtype (fp32 in the serving path, so a
    bfloat16 bank only quantizes the kernel's *inputs*).  Oracle:
    ``ref.class_scores`` (C sequential kernel calls).
    """
    c, slots, d = sv_x.shape
    k = rbf_matrix(x, sv_x.reshape(c * slots, d), gamma, impl=impl)
    k = k.reshape(x.shape[0], c, slots)
    return jnp.einsum("ncs,cs->cn", k.astype(alpha.dtype), alpha)


# --------------------------------------------------------------------------
# Merge-candidate scoring against a precomputed table (Lookup-WD / Lookup-h)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def merge_scores(alpha, kappa_row, valid, a_min, table, *, impl: str = "auto",
                 block_s: int = 512):
    """(wd, interp) per candidate; invalid slots get a large finite WD.

    Class-batched layout: ``alpha``/``kappa_row``/``valid`` of shape (C, s)
    with ``a_min`` (C,) scores one fixed partner *per class* in one pass —
    each class row carries its own alpha, so this is exactly the row-wise
    layout of the multi-merge kernel (one launch, both lookups from the one
    ``table``).  Returns (C, s) arrays.
    """
    impl = _resolve(impl)
    if kappa_row.ndim == 2:                     # class-batched: C rows at once
        if impl == "ref":
            return ref.multi_merge_scores_rows(alpha, kappa_row, valid, a_min,
                                               table, table)
        # clamp to the multi-row kernel's VMEM-safe block: it keeps P_PAD
        # rows of hat weights resident, unlike the single-row kernel whose
        # default this function's block_s=512 was sized for
        wd, interp = _multi_merge_rows_pallas(
            alpha, kappa_row, valid, a_min, table, table,
            block_s=min(block_s, 128),
            interpret=(impl == "pallas_interpret"))
        return wd, interp
    if impl == "ref":
        wd = ref.merge_scores(alpha, kappa_row, valid, a_min, table)
        m, kap = ref.merge_coords(a_min, alpha, kappa_row)
        interp = ref.bilinear_lookup(table, m, kap)
        return wd, interp
    s = alpha.shape[0]
    bs = min(block_s, max(128, s))
    pad = lambda a: _pad_to(a, 0, bs)
    wd, interp = merge_lookup_kernel.merge_scores_pallas(
        pad(alpha), pad(kappa_row), pad(valid.astype(jnp.float32)), a_min,
        table, block_s=bs, interpret=(impl == "pallas_interpret"))
    wd = jnp.where(jnp.arange(wd.shape[0]) < s, wd, jnp.inf)[:s]
    return wd, interp[:s]


# --------------------------------------------------------------------------
# Fused maintenance event (one merge/removal per over-budget class)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def merge_event(sv_x, alpha, kmat, count, over, table, *, impl: str = "auto",
                block_s: int = 256):
    """One fused maintenance-event round over stacked classes.

    sv_x: (C, s, d); alpha: (C, s); kmat: (C, s, s) fp32 kernel cache;
    count, over: (C,) int32/bool.  Every class with ``over`` set executes one
    Lookup-WD merge event (argmin-|alpha| fixed partner, cached kappa row,
    best same-sign partner, removal fallback) exactly as
    ``core.budget._merge_once`` would on its slice; classes with ``over``
    clear return bitwise unchanged.  Returns ``(sv_x, alpha, kmat)`` — the
    caller owns ``count -= over`` and the round schedule
    (``core.budget.run_maintenance_classes``).  Oracle: ``ref.merge_event``;
    the Pallas path folds classes onto the grid axis and updates the blocks
    in place in VMEM (``merge_event.merge_event_pallas``).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.merge_event(sv_x, alpha, kmat, count, over,
                               table.h_table, table.wd_table)
    c, s, d = sv_x.shape
    sv_p = _pad_to(_pad_to(sv_x, 1, 128), 2, 128)
    al_p = _pad_to(alpha, 1, 128)
    km_p = _pad_to(_pad_to(kmat, 1, 128), 2, 128)
    sv_n, al_n, km_n = merge_event_kernel.merge_event_pallas(
        sv_p, al_p, km_p, count.reshape(c, 1).astype(jnp.int32),
        over.reshape(c, 1).astype(jnp.int32), table.h_table, table.wd_table,
        block_s=block_s, interpret=(impl == "pallas_interpret"))
    return sv_n[:, :s, :d], al_n[:, :s], km_n[:, :s, :s]


# --------------------------------------------------------------------------
# Batched golden section search
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "n_iters"))
def gss_solve(m, kappa, *, n_iters: int, impl: str = "auto"):
    """argmax_h of the merge objective for arrays of (m, kappa); any shape."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.gss(m, kappa, n_iters)
    shape = m.shape
    flat_m = m.reshape(1, -1).astype(jnp.float32)
    flat_k = kappa.reshape(1, -1).astype(jnp.float32)
    br, bc = 1, min(512, max(128, flat_m.shape[1]))
    flat_m = _pad_to(flat_m, 1, bc)
    flat_k = _pad_to(flat_k, 1, bc, value=1.0)  # kappa=1 is a benign problem
    h = gss_kernel.gss_pallas(flat_m, flat_k, n_iters=n_iters, block=(br, bc),
                              interpret=(impl == "pallas_interpret"))
    return h[0, : math.prod(shape)].reshape(shape)


# --------------------------------------------------------------------------
# Batched multi-merge scoring (P fixed partners, both tables, one pass)
# --------------------------------------------------------------------------
def _multi_merge_rows_pallas(alpha_rows, kappa_rows, valid, a_min, h_table,
                             wd_table, *, block_s: int, interpret: bool):
    """Row-wise Pallas launches: every pair row carries its own alpha.

    Tiles the row axis: the kernel keeps all its P rows resident per grid
    step (hat-weight matrices scale with P * block_s), so one launch per
    P_PAD rows keeps VMEM bounded no matter how many rows are folded in
    (merge_batch, or n_classes * merge_batch in the class-batched layout).
    """
    p, s = kappa_rows.shape
    bs = min(block_s, max(128, s))
    pad_s = lambda a: _pad_to(a, a.ndim - 1, bs)
    pad_p = lambda a: _pad_to(a, 0, merge_multi_kernel.P_PAD)
    wds, hs = [], []
    for start in range(0, p, merge_multi_kernel.P_PAD):
        sl = slice(start, min(start + merge_multi_kernel.P_PAD, p))
        wd_c, h_c = merge_multi_kernel.multi_merge_scores_pallas(
            pad_p(pad_s(alpha_rows[sl])), pad_p(pad_s(kappa_rows[sl])),
            pad_p(pad_s(valid[sl].astype(jnp.float32))), pad_p(a_min[sl]),
            h_table, wd_table, block_s=bs, interpret=interpret)
        wds.append(wd_c[:sl.stop - sl.start])
        hs.append(h_c[:sl.stop - sl.start])
    return jnp.concatenate(wds)[:, :s], jnp.concatenate(hs)[:, :s]


@partial(jax.jit, static_argnames=("impl", "block_s"))
def multi_merge_scores(alpha, kappa_rows, valid, a_min, table, *,
                       impl: str = "auto", block_s: int = 128):
    """(wd, h) of shape (P, s) for P fixed merge partners at once.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,);
    table: a ``MergeLookupTable`` (both grids are interpolated in one pass).
    Class-batched layout: ``alpha`` (C, s); ``kappa_rows``/``valid``
    (C, P, s); ``a_min`` (C, P) -> (C, P, s) outputs.  The (C, P) pair grid
    folds onto the kernel's row axis with each class's alpha repeated across
    its P rows, so all classes' maintenance candidates score in the same
    launch sequence.
    Invalid slots get WD = +inf (ref) / 3.4e38 (pallas) — argmin-safe either way.
    """
    impl = _resolve(impl)
    if kappa_rows.ndim == 3:                    # class-batched
        c, p, s = kappa_rows.shape
        if impl == "ref":
            return ref.multi_merge_scores_classes(
                alpha, kappa_rows, valid, a_min, table.h_table, table.wd_table)
        alpha_rows = jnp.broadcast_to(alpha[:, None, :], (c, p, s))
        wd, h = _multi_merge_rows_pallas(
            alpha_rows.reshape(c * p, s), kappa_rows.reshape(c * p, s),
            valid.reshape(c * p, s), a_min.reshape(c * p),
            table.h_table, table.wd_table, block_s=block_s,
            interpret=(impl == "pallas_interpret"))
        return wd.reshape(c, p, s), h.reshape(c, p, s)
    if impl == "ref":
        return ref.multi_merge_scores(alpha, kappa_rows, valid, a_min,
                                      table.h_table, table.wd_table)
    p, s = kappa_rows.shape
    alpha_rows = jnp.broadcast_to(alpha[None, :], (p, s))
    return _multi_merge_rows_pallas(alpha_rows, kappa_rows, valid, a_min,
                                    table.h_table, table.wd_table,
                                    block_s=block_s,
                                    interpret=(impl == "pallas_interpret"))
