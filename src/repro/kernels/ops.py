"""Public jit'd wrappers for the Pallas kernels, with implementation dispatch.

``impl`` semantics (every op takes it):
  * ``"auto"``             — Pallas on TPU, pure-jnp reference elsewhere (XLA
                             compiles the reference well on CPU/GPU).
  * ``"pallas"``           — compiled Pallas (TPU).
  * ``"pallas_interpret"`` — Pallas in interpret mode (CPU correctness runs;
                             this is how the kernel bodies are validated here).
  * ``"ref"``              — the pure-jnp oracle from ``kernels.ref``.

Wrappers own all shape plumbing the kernels refuse to do: padding to block
multiples, re-slicing, and scalar/1-D massaging.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import gss as gss_kernel
from . import merge_event as merge_event_kernel
from . import merge_lookup as merge_lookup_kernel
from . import merge_multi as merge_multi_kernel
from . import rbf_kernel
from . import ref
from . import train_step as train_step_kernel

IMPLS = ("auto", "pallas", "pallas_interpret", "ref")


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in IMPLS:
        raise ValueError(f"impl={impl!r} not in {IMPLS}")
    return impl


def _pad_to(x, axis: int, multiple: int, value=0.0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _pad_to_lane(x, axes, multiple=128, value=0.0):
    """Pad ``axes`` of ``x`` up to tile multiples (the shared dispatcher
    plumbing: every kernel wrapper pads with this, slices back after).

    ``axes`` is an axis or tuple of axes; ``multiple`` is one int for all of
    them or a tuple matched positionally.  Padding is appended (never
    prepended) with ``value``, so ``out[..slices of the original shape..]``
    round-trips to ``x`` exactly.
    """
    axes = (axes,) if isinstance(axes, int) else tuple(axes)
    mults = ((multiple,) * len(axes) if isinstance(multiple, int)
             else tuple(multiple))
    if len(mults) != len(axes):
        raise ValueError(f"got {len(axes)} axes but {len(mults)} multiples")
    for ax, m in zip(axes, mults):
        x = _pad_to(x, ax, m, value)
    return x


# --------------------------------------------------------------------------
# RBF kernel matrix / row
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_n", "block_m", "block_d"))
def rbf_matrix(x, y, gamma, *, impl: str = "auto", block_n: int = 128,
               block_m: int = 128, block_d: int = 512):
    """K[i, j] = exp(-gamma ||x_i - y_j||^2); x: (n, d), y: (m, d) -> (n, m)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_matrix(x, y, gamma)
    n, m = x.shape[0], y.shape[0]
    bd = min(block_d, max(128, x.shape[1]))
    xp = _pad_to_lane(x, (0, 1), (block_n, bd))
    yp = _pad_to_lane(y, (0, 1), (block_m, bd))
    out = rbf_kernel.rbf_matrix_pallas(
        xp, yp, gamma, block_n=block_n, block_m=block_m, block_d=bd,
        interpret=(impl == "pallas_interpret"))
    return out[:n, :m]


@partial(jax.jit, static_argnames=("impl",))
def rbf_row(sv_x, x, gamma, *, impl: str = "auto"):
    """kappa_row[j] = k(x, sv_x[j]); sv_x: (s, d), x: (d,) -> (s,)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.rbf_row(sv_x, x, gamma)
    return rbf_matrix(x[None, :], sv_x, gamma, impl=impl)[0]


# --------------------------------------------------------------------------
# Class-batched decision scoring (the serving cell's contraction)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl",))
def class_scores(x, sv_x, alpha, gamma, *, impl: str = "auto"):
    """All-class decision scores from ONE kernel launch: (C, n).

    x: (n, d) request rows; sv_x: (C, slots, d) stacked SV bank; alpha:
    (C, slots) coefficients (inactive slots zeroed by the caller).  The
    class axis folds into the SV axis so the kernel block is a single
    (n, C * slots) ``rbf_matrix`` — one Pallas launch / one XLA matmul no
    matter how many classes — then a per-class contraction over slots with
    accumulation in ``alpha``'s dtype (fp32 in the serving path, so a
    bfloat16 bank only quantizes the kernel's *inputs*).  Oracle:
    ``ref.class_scores`` (C sequential kernel calls).
    """
    c, slots, d = sv_x.shape
    k = rbf_matrix(x, sv_x.reshape(c * slots, d), gamma, impl=impl)
    k = k.reshape(x.shape[0], c, slots)
    return jnp.einsum("ncs,cs->cn", k.astype(alpha.dtype), alpha)


# --------------------------------------------------------------------------
# Merge-candidate scoring against a precomputed table (Lookup-WD / Lookup-h)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def merge_scores(alpha, kappa_row, valid, a_min, table, *, impl: str = "auto",
                 block_s: int = 512):
    """(wd, interp) per candidate; invalid slots get a large finite WD.

    Class-batched layout: ``alpha``/``kappa_row``/``valid`` of shape (C, s)
    with ``a_min`` (C,) scores one fixed partner *per class* in one pass —
    each class row carries its own alpha, so this is exactly the row-wise
    layout of the multi-merge kernel (one launch, both lookups from the one
    ``table``).  Returns (C, s) arrays.
    """
    impl = _resolve(impl)
    if kappa_row.ndim == 2:                     # class-batched: C rows at once
        if impl == "ref":
            return ref.multi_merge_scores_rows(alpha, kappa_row, valid, a_min,
                                               table, table)
        # clamp to the multi-row kernel's VMEM-safe block: it keeps P_PAD
        # rows of hat weights resident, unlike the single-row kernel whose
        # default this function's block_s=512 was sized for
        wd, interp = _multi_merge_rows_pallas(
            alpha, kappa_row, valid, a_min, table, table,
            block_s=min(block_s, 128),
            interpret=(impl == "pallas_interpret"))
        return wd, interp
    if impl == "ref":
        wd = ref.merge_scores(alpha, kappa_row, valid, a_min, table)
        m, kap = ref.merge_coords(a_min, alpha, kappa_row)
        interp = ref.bilinear_lookup(table, m, kap)
        return wd, interp
    s = alpha.shape[0]
    bs = min(block_s, max(128, s))
    pad = lambda a: _pad_to_lane(a, 0, bs)
    wd, interp = merge_lookup_kernel.merge_scores_pallas(
        pad(alpha), pad(kappa_row), pad(valid.astype(jnp.float32)), a_min,
        table, block_s=bs, interpret=(impl == "pallas_interpret"))
    wd = jnp.where(jnp.arange(wd.shape[0]) < s, wd, jnp.inf)[:s]
    return wd, interp[:s]


# --------------------------------------------------------------------------
# Fused maintenance event (one merge/removal per over-budget class)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "block_s"))
def merge_event(sv_x, alpha, kmat, count, over, table, *, impl: str = "auto",
                block_s: int = 256):
    """One fused maintenance-event round over stacked classes.

    sv_x: (C, s, d); alpha: (C, s); kmat: (C, s, s) fp32 kernel cache;
    count, over: (C,) int32/bool.  Every class with ``over`` set executes one
    Lookup-WD merge event (argmin-|alpha| fixed partner, cached kappa row,
    best same-sign partner, removal fallback) exactly as
    ``core.budget._merge_once`` would on its slice; classes with ``over``
    clear return bitwise unchanged.  Returns ``(sv_x, alpha, kmat)`` — the
    caller owns ``count -= over`` and the round schedule
    (``core.budget.run_maintenance_classes``).  Oracle: ``ref.merge_event``;
    the Pallas path folds classes onto the grid axis and updates the blocks
    in place in VMEM (``merge_event.merge_event_pallas``).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.merge_event(sv_x, alpha, kmat, count, over,
                               table.h_table, table.wd_table)
    c, s, d = sv_x.shape
    sv_p = _pad_to_lane(sv_x, (1, 2))
    al_p = _pad_to_lane(alpha, 1)
    km_p = _pad_to_lane(kmat, (1, 2))
    sv_n, al_n, km_n = merge_event_kernel.merge_event_pallas(
        sv_p, al_p, km_p, count.reshape(c, 1).astype(jnp.int32),
        over.reshape(c, 1).astype(jnp.int32), table.h_table, table.wd_table,
        block_s=block_s, interpret=(impl == "pallas_interpret"))
    return sv_n[:, :s, :d], al_n[:, :s], km_n[:, :s, :s]


# --------------------------------------------------------------------------
# Batched golden section search
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("impl", "n_iters"))
def gss_solve(m, kappa, *, n_iters: int, impl: str = "auto"):
    """argmax_h of the merge objective for arrays of (m, kappa); any shape."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.gss(m, kappa, n_iters)
    shape = m.shape
    flat_m = m.reshape(1, -1).astype(jnp.float32)
    flat_k = kappa.reshape(1, -1).astype(jnp.float32)
    br, bc = 1, min(512, max(128, flat_m.shape[1]))
    flat_m = _pad_to_lane(flat_m, 1, bc)
    flat_k = _pad_to_lane(flat_k, 1, bc, value=1.0)  # kappa=1: benign problem
    h = gss_kernel.gss_pallas(flat_m, flat_k, n_iters=n_iters, block=(br, bc),
                              interpret=(impl == "pallas_interpret"))
    return h[0, : math.prod(shape)].reshape(shape)


# --------------------------------------------------------------------------
# Batched multi-merge scoring (P fixed partners, both tables, one pass)
# --------------------------------------------------------------------------
def _multi_merge_rows_pallas(alpha_rows, kappa_rows, valid, a_min, h_table,
                             wd_table, *, block_s: int, interpret: bool):
    """Row-wise Pallas launches: every pair row carries its own alpha.

    Tiles the row axis: the kernel keeps all its P rows resident per grid
    step (hat-weight matrices scale with P * block_s), so one launch per
    P_PAD rows keeps VMEM bounded no matter how many rows are folded in
    (merge_batch, or n_classes * merge_batch in the class-batched layout).
    """
    p, s = kappa_rows.shape
    bs = min(block_s, max(128, s))
    pad_s = lambda a: _pad_to_lane(a, a.ndim - 1, bs)
    pad_p = lambda a: _pad_to_lane(a, 0, merge_multi_kernel.P_PAD)
    wds, hs = [], []
    for start in range(0, p, merge_multi_kernel.P_PAD):
        sl = slice(start, min(start + merge_multi_kernel.P_PAD, p))
        wd_c, h_c = merge_multi_kernel.multi_merge_scores_pallas(
            pad_p(pad_s(alpha_rows[sl])), pad_p(pad_s(kappa_rows[sl])),
            pad_p(pad_s(valid[sl].astype(jnp.float32))), pad_p(a_min[sl]),
            h_table, wd_table, block_s=bs, interpret=interpret)
        wds.append(wd_c[:sl.stop - sl.start])
        hs.append(h_c[:sl.stop - sl.start])
    return jnp.concatenate(wds)[:, :s], jnp.concatenate(hs)[:, :s]


@partial(jax.jit, static_argnames=("impl", "block_s"))
def multi_merge_scores(alpha, kappa_rows, valid, a_min, table, *,
                       impl: str = "auto", block_s: int = 128):
    """(wd, h) of shape (P, s) for P fixed merge partners at once.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,);
    table: a ``MergeLookupTable`` (both grids are interpolated in one pass).
    Class-batched layout: ``alpha`` (C, s); ``kappa_rows``/``valid``
    (C, P, s); ``a_min`` (C, P) -> (C, P, s) outputs.  The (C, P) pair grid
    folds onto the kernel's row axis with each class's alpha repeated across
    its P rows, so all classes' maintenance candidates score in the same
    launch sequence.
    Invalid slots get WD = +inf (ref) / 3.4e38 (pallas) — argmin-safe either way.
    """
    impl = _resolve(impl)
    if kappa_rows.ndim == 3:                    # class-batched
        c, p, s = kappa_rows.shape
        if impl == "ref":
            return ref.multi_merge_scores_classes(
                alpha, kappa_rows, valid, a_min, table.h_table, table.wd_table)
        alpha_rows = jnp.broadcast_to(alpha[:, None, :], (c, p, s))
        wd, h = _multi_merge_rows_pallas(
            alpha_rows.reshape(c * p, s), kappa_rows.reshape(c * p, s),
            valid.reshape(c * p, s), a_min.reshape(c * p),
            table.h_table, table.wd_table, block_s=block_s,
            interpret=(impl == "pallas_interpret"))
        return wd.reshape(c, p, s), h.reshape(c, p, s)
    if impl == "ref":
        return ref.multi_merge_scores(alpha, kappa_rows, valid, a_min,
                                      table.h_table, table.wd_table)
    p, s = kappa_rows.shape
    alpha_rows = jnp.broadcast_to(alpha[None, :], (p, s))
    return _multi_merge_rows_pallas(alpha_rows, kappa_rows, valid, a_min,
                                    table.h_table, table.wd_table,
                                    block_s=block_s,
                                    interpret=(impl == "pallas_interpret"))


# --------------------------------------------------------------------------
# Fused train step (margin + insert + event rounds, one launch chain)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("budget", "lambda_", "gamma", "batch_size",
                                   "maintenance", "merge_batch", "unroll",
                                   "impl", "block_s"))
def train_step(sv_x, alpha, kmat, count, step, n_inserts, n_merges, xb, yb,
               k_bb, table, *, budget: int, lambda_: float, gamma: float,
               batch_size: int, maintenance: str = "merge",
               merge_batch: int = 4, unroll: int = 0, impl: str = "auto",
               block_s: int = 256):
    """One WHOLE multiclass train step in one launch chain: margin rows +
    Pegasos shrink/insert + maintenance event rounds (DESIGN.md §12).

    sv_x: (C, slots, d); alpha: (C, slots); kmat: (C, slots, slots) fp32
    kernel cache (REQUIRED — the fused step maintains it in VMEM); count /
    step / n_inserts / n_merges: (C,) int32; xb: (batch, d); yb: (C, batch)
    one-vs-rest targets; k_bb: (batch, batch) = k(xb, xb); ``table`` a
    ``MergeLookupTable``.  ``maintenance`` is ``"merge"`` or
    ``"multi-merge"`` (P = ``merge_batch`` disjoint pairs per round).
    ``unroll`` only affects the reference path's round loop (the Pallas
    kernel always inlines ``batch_size`` masked rounds — one minibatch
    bounds the excess by ``batch_size``).  Returns the updated ``(sv_x,
    alpha, kmat, count, step, n_inserts, n_merges)``.  Oracle and CPU
    production path: ``ref.train_step_fused``.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.train_step_fused(
            sv_x, alpha, kmat, count, step, n_inserts, n_merges, xb, yb,
            k_bb, table.h_table, table.wd_table, budget=budget,
            lambda_=lambda_, gamma=gamma, batch_size=batch_size,
            maintenance=maintenance, merge_batch=merge_batch, unroll=unroll)
    c, s, d = sv_x.shape
    sv_p = _pad_to_lane(sv_x, (1, 2))
    al_p = _pad_to_lane(alpha, 1)
    km_p = _pad_to_lane(kmat, (1, 2))
    xb_p = _pad_to_lane(xb, (0, 1))
    kbb_p = _pad_to_lane(k_bb, (0, 1))
    yb_p = _pad_to_lane(yb, 1)
    as_col = lambda a: a.reshape(c, 1).astype(jnp.int32)
    sv_n, al_n, km_n, cnt_n, nins_n, nmrg_n = \
        train_step_kernel.train_step_pallas(
            sv_p, al_p, km_p, as_col(count), as_col(step),
            as_col(n_inserts), as_col(n_merges), xb_p, yb_p, kbb_p,
            table.h_table, table.wd_table, budget=budget, lambda_=lambda_,
            gamma=gamma, batch_size=batch_size, rounds=batch_size,
            maintenance=maintenance, merge_batch=merge_batch,
            block_s=block_s, interpret=(impl == "pallas_interpret"))
    return (sv_n[:, :s, :d], al_n[:, :s], km_n[:, :s, :s],
            cnt_n.reshape(c), step + 1, nins_n.reshape(c),
            nmrg_n.reshape(c))
