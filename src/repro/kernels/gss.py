"""Batched golden-section-search Pallas kernel (baseline solver + table builder).

Runs ALL candidate searches in lockstep: the bracket state (a, b) lives in
vector registers, every iteration evaluates the merge objective at the two
golden probes for the whole block, and `jnp.where` selects the surviving
bracket per lane.  Iteration count is static (ceil(log eps / log (1/phi))),
so the loop unrolls into a fixed-depth chain — this IS the cost the paper's
lookup removes: ~10 (eps=.01) / ~48 (eps=1e-10) sequential VPU steps, each
with two exp() transcendentals, vs. one MXU matmul for the lookup kernel.

Used both as the runtime baseline ("GSS", "GSS-precise") and to precompute
the lookup tables at high precision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVPHI = (5.0**0.5 - 1.0) / 2.0


def _gss_kernel(m_ref, kappa_ref, h_ref, *, n_iters: int):
    m = m_ref[...].astype(jnp.float32)
    kappa = jnp.clip(kappa_ref[...].astype(jnp.float32), 1e-30, 1.0)
    lk = jnp.log(kappa)

    def s(h):
        # s_{m,kappa}(h) = m kappa^{(1-h)^2} + (1-m) kappa^{h^2}
        return m * jnp.exp((1.0 - h) ** 2 * lk) + (1.0 - m) * jnp.exp(h**2 * lk)

    def body(_, ab):
        a, b = ab
        span = b - a
        c = b - span * INVPHI
        d = a + span * INVPHI
        go_left = s(c) > s(d)
        return jnp.where(go_left, a, c), jnp.where(go_left, d, b)

    a, b = jax.lax.fori_loop(0, n_iters,
                             body, (jnp.zeros_like(m), jnp.ones_like(m)))
    h_ref[...] = 0.5 * (a + b)


@functools.partial(jax.jit, static_argnames=("n_iters", "block", "interpret"))
def gss_pallas(m, kappa, *, n_iters: int, block: tuple[int, int] = (8, 512),
               interpret: bool = False):
    """Golden section search for 2-D arrays of (m, kappa) problems.

    m, kappa: (r, c) with r % block[0] == 0 and c % block[1] == 0 (ops pads).
    """
    r, c = m.shape
    br, bc = block
    assert r % br == 0 and c % bc == 0, "pad to block multiples (see kernels.ops)"
    return pl.pallas_call(
        functools.partial(_gss_kernel, n_iters=n_iters),
        grid=(r // br, c // bc),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))] * 2,
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=interpret,
    )(m, kappa)
