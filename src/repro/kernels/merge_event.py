"""Fused budget-maintenance event — one launch per round, all classes.

The class-axis engine's maintenance hot spot (ROADMAP: "Batched maintenance
under vmap at scale") is NOT the merge math — it is the memory traffic around
it: under ``vmap`` the per-event two-row/two-column scatters on the stacked
``(C, slots, slots)`` kernel cache defeat XLA's in-place buffer aliasing, so
every event degenerates to full-matrix copies.  This kernel folds the classes
onto the grid axis (like ``merge_multi``) and executes ONE whole maintenance
event per class per launch:

  * argmin-|alpha| fixed-partner selection over the active watermark;
  * the kappa row read straight from the class's VMEM-resident cache block
    (``kmat`` is symmetric: row ``i_min`` IS ``k(x_min, .)``);
  * Lookup-WD candidate scoring with the same gather-free hat-basis bilinear
    trick as ``merge_lookup`` (one ``(bS, G) x (G, G)`` MXU matmul per table
    per chunk against the VMEM-resident tables);
  * the merged point's cache row derived IN the kernel from the two parent
    rows (the log-space combine of ``core.kernel_cache`` — the z-row never
    round-trips through HBM);
  * the merge / removal-fallback two-row + two-column update applied as
    masked selects on the VMEM blocks — no scatter, no full-matrix HBM copy
    (outputs alias inputs, so XLA updates the stacked state in place).

Classes whose ``over`` flag is clear are no-op rows: their blocks are written
back bitwise unchanged, which is what makes the sorted-excess schedule in
``core.budget.run_maintenance_classes`` correct — the engine runs exactly
``max_c(count_c - budget)`` rounds and finished classes ride along for free.

Scalar gathers (``alpha[i_min]`` etc.) are one-hot reductions and row gathers
are one-hot matmuls — the TPU vector unit has no efficient per-lane gather,
and the ``(3, S) x (S, S)`` one-hot products are trivial MXU work.  VMEM
budget: the class's ``(S, S)`` cache + ``(S, D)`` SV blocks dominate (4 MB
each at S = D = 1024); scoring is chunked by ``block_s`` so the hat-weight
matrices stay small.  Keep ``slots`` and ``dim`` at multiples of 128 in
production configs to avoid the pad/slice copy in ``ops.merge_event``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .merge_lookup import WD_INVALID, _hat_weights
from .ref import NO_PARTNER, _safe_log


def _first_where(pred, iota, s):
    """Smallest index with ``pred`` true (== jnp.argmin tie-breaking)."""
    return jnp.min(jnp.where(pred, iota, s)).astype(jnp.int32)


def _onehot_f32(iota, i):
    return (iota == i).astype(jnp.float32)


def _merge_event_body(count, over, alpha_in, sv_in, kmat, h_tab, wd_tab,
                      *, g: int, block_s: int):
    """One whole merge event on VMEM-resident values (no refs).

    count: () int32; over: () bool; alpha_in: (S,) storage dtype; sv_in:
    (S, D) storage dtype; kmat: (S, S) fp32; tables: (G, G) fp32 arrays.
    Returns ``(alpha, sv, kmat)`` — bitwise unchanged when ``over`` is
    clear.  Shared by ``_merge_event_kernel`` (one event per launch) and
    the fused train-step megakernel (``kernels.train_step``), which chains
    these bodies as its maintenance rounds without leaving VMEM.
    """
    alpha = alpha_in.astype(jnp.float32)                 # (S,)
    s = alpha.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)[0]
    active = iota < count

    # 1. fixed partner: active argmin |alpha| (first occurrence on ties).
    abs_a = jnp.where(active, jnp.abs(alpha), jnp.inf)
    mn = jnp.min(abs_a)
    i_min = _first_where(abs_a == mn, iota, s)
    oh_i = _onehot_f32(iota, i_min)
    a_min = jnp.sum(jnp.where(iota == i_min, alpha, 0.0))

    # 2. kappa row = cache row i_min (one-hot MXU product, no gather).
    kappa_row = jax.lax.dot_general(
        oh_i[None, :], kmat, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]           # (S,)

    # 3. Lookup-WD scoring in block_s chunks (hat-basis bilinear, both
    #    tables interpolated per chunk — merge_lookup's trick).
    wd_parts, h_parts = [], []
    for start in range(0, s, block_s):
        al_c = alpha[start:start + block_s]
        kap_c = kappa_row[start:start + block_s]
        denom = a_min + al_c
        m = jnp.clip(a_min / jnp.where(denom == 0.0, 1.0, denom), 0.0, 1.0)
        kap = jnp.clip(kap_c, 0.0, 1.0)
        w_m = _hat_weights(m, g)                         # (bS, G)
        w_k = _hat_weights(kap, g)
        rows_wd = jax.lax.dot_general(
            w_m, wd_tab, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        rows_h = jax.lax.dot_general(
            w_m, h_tab, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        wd_parts.append(denom * denom * jnp.sum(rows_wd * w_k, axis=1))
        h_parts.append(jnp.sum(rows_h * w_k, axis=1))
    wd = jnp.concatenate(wd_parts)
    h = jnp.concatenate(h_parts)
    valid = active & (alpha * a_min > 0) & (iota != i_min)
    wd = jnp.where(valid, wd, WD_INVALID)

    # best partner; removal fallback when every candidate is invalid
    wd_mn = jnp.min(wd)
    j_star = _first_where(wd == wd_mn, iota, s)
    has_partner = wd_mn < NO_PARTNER
    last = count - 1

    # 4. merge math on the chosen pair (scalars via one-hot reductions,
    #    parent rows via one (2, S) one-hot MXU product).
    sel_j = iota == j_star
    sel_last = iota == last
    h_m = jnp.sum(jnp.where(sel_j, h, 0.0))
    k_ij = jnp.sum(jnp.where(sel_j, kappa_row, 0.0))
    kap_m = jnp.clip(k_ij, 0.0, 1.0)
    a_j = jnp.sum(jnp.where(sel_j, alpha, 0.0))
    a_last = jnp.sum(jnp.where(sel_last, alpha, 0.0))
    lk = _safe_log(kap_m)
    a_z = (a_min * jnp.exp((1.0 - h_m) ** 2 * lk)
           + a_j * jnp.exp(h_m**2 * lk))
    oh_jl = jnp.stack([_onehot_f32(iota, j_star),
                       _onehot_f32(iota, last)])         # (2, S)
    rows_jl = jax.lax.dot_general(oh_jl, kmat, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    row_j, row_last = rows_jl[0], rows_jl[1]
    sv_rows = jax.lax.dot_general(
        jnp.stack([oh_i, oh_jl[0], oh_jl[1]]), sv_in.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    x_i, x_j, v_last = sv_rows[0], sv_rows[1], sv_rows[2]
    z = h_m * x_i + (1.0 - h_m) * x_j                    # (D,)

    # z's cache row from the parent rows (kernel_cache's log-space combine)
    lz = (h_m * _safe_log(kappa_row) + (1.0 - h_m) * _safe_log(row_j)
          - h_m * (1.0 - h_m) * _safe_log(k_ij))
    z_row = jnp.exp(jnp.minimum(lz, 0.0))

    # 5. the branch-free two-row + two-column update as masked selects on
    #    the VMEM blocks (budget._merge_once's fused form): t1 <- z (or the
    #    old ``last`` on removal), t2 <- the old ``last``; t2 = S on removal
    #    so its masks are empty, and a cleared ``over`` empties them all.
    lo = jnp.minimum(i_min, j_star)
    hi = jnp.maximum(i_min, j_star)
    z_row_l = jnp.sum(jnp.where(sel_last, z_row, 0.0))
    r_merge = jnp.where(iota == hi, z_row_l, z_row)
    r_merge = jnp.where(iota == lo, 1.0, r_merge)
    r_move = jnp.where(iota == hi, 1.0, row_last)
    r_move = jnp.where(iota == lo, z_row_l, r_move)
    r_remove = jnp.where(iota == i_min, 1.0, row_last)
    t1 = jnp.where(over, jnp.where(has_partner, lo, i_min), s)
    t2 = jnp.where(over & has_partner, hi, s)
    r1 = jnp.where(has_partner, r_merge, r_remove)

    row_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    km = jnp.where(row_ids == t1, r1[None, :], kmat)
    km = jnp.where(row_ids == t2, r_move[None, :], km)
    km = jnp.where(col_ids == t1, r1[:, None], km)
    km = jnp.where(col_ids == t2, r_move[:, None], km)

    d = sv_in.shape[1]
    sv_row_ids = jax.lax.broadcasted_iota(jnp.int32, (s, d), 0)
    sv1 = jnp.where(has_partner, z, v_last)
    sv = jnp.where(sv_row_ids == t1, sv1[None, :].astype(sv_in.dtype), sv_in)
    sv = jnp.where(sv_row_ids == t2, v_last[None, :].astype(sv_in.dtype), sv)

    a1 = jnp.where(has_partner, a_z, a_last)
    al = jnp.where(iota == t1, a1, alpha)
    al = jnp.where(iota == t2, a_last, al)
    al = jnp.where((iota == last) & over, 0.0, al)
    al_out = jnp.where(over, al.astype(alpha_in.dtype), alpha_in)
    return al_out, sv, km


def _merge_event_kernel(count_ref, over_ref, alpha_ref, sv_ref, kmat_ref,
                        h_tab_ref, wd_tab_ref, alpha_out, sv_out, kmat_out,
                        *, g: int, block_s: int):
    al, sv, km = _merge_event_body(
        count_ref[0, 0], over_ref[0, 0] > 0, alpha_ref[0, :], sv_ref[0],
        kmat_ref[0], h_tab_ref[...], wd_tab_ref[...], g=g, block_s=block_s)
    alpha_out[0, :] = al
    sv_out[0] = sv
    kmat_out[0] = km


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def merge_event_pallas(sv_x, alpha, kmat, count, over, h_table, wd_table, *,
                       block_s: int = 256, interpret: bool = False):
    """One maintenance event per over-budget class, one launch for them all.

    sv_x: (C, S, D); alpha: (C, S); kmat: (C, S, S) fp32; count, over:
    (C, 1) int32; tables: (G, G).  S and D must be multiples of the tile
    sizes (``ops.merge_event`` pads).  Returns ``(sv_x, alpha, kmat)`` with
    classes where ``over == 0`` bitwise unchanged; outputs alias the inputs
    so the whole stacked state updates in place.  Oracle: ``ref.merge_event``.
    """
    c, s, d = sv_x.shape
    g = h_table.shape[0]
    # scoring chunk must divide the (padded) slot count; ops pads s to a
    # multiple of 128, so 128 always works when block_s does not divide s
    bs = block_s if s % block_s == 0 else (128 if s % 128 == 0 else s)
    alpha_new, sv_new, kmat_new = pl.pallas_call(
        functools.partial(_merge_event_kernel, g=g, block_s=bs),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),      # count
            pl.BlockSpec((1, 1), lambda i: (i, 0)),      # over
            pl.BlockSpec((1, s), lambda i: (i, 0)),      # alpha
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # sv_x
            pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),  # kmat
            pl.BlockSpec((g, g), lambda i: (0, 0)),      # tables: whole
            pl.BlockSpec((g, g), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, s), alpha.dtype),
            jax.ShapeDtypeStruct((c, s, d), sv_x.dtype),
            jax.ShapeDtypeStruct((c, s, s), kmat.dtype),
        ],
        input_output_aliases={2: 0, 3: 1, 4: 2},
        interpret=interpret,
    )(count.astype(jnp.int32), over.astype(jnp.int32), alpha, sv_x,
      kmat.astype(jnp.float32), h_table.astype(jnp.float32),
      wd_table.astype(jnp.float32))
    return sv_new, alpha_new, kmat_new
