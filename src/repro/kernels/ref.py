"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes and
asserts allclose against the function here.  They are also the production CPU
fallback (XLA compiles them well); the Pallas kernels target TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_matrix(x, y, gamma):
    """Gaussian kernel matrix K[i, j] = exp(-gamma ||x_i - y_j||^2).

    x: (n, d), y: (m, d)  ->  (n, m), computed via the matmul decomposition
    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y  (clamped at 0 for fp safety).
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xn + yn - 2.0 * x @ y.T
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def rbf_row(sv_x, x, gamma):
    """kappa_row[j] = k(x, sv_x[j]).  sv_x: (s, d), x: (d,)  ->  (s,)."""
    d2 = jnp.sum((sv_x - x[None, :]) ** 2, axis=-1)
    return jnp.exp(-gamma * d2)


def bilinear_lookup(table, u, v):
    """Bilinear interpolation of ``table`` at unit-square coords (u, v).

    Identical semantics to ``repro.core.lookup.bilinear_lookup``; duplicated
    here (3 lines of gather math) so the kernels package stays import-clean.
    """
    g0, g1 = table.shape
    uu = jnp.clip(u, 0.0, 1.0) * (g0 - 1)
    vv = jnp.clip(v, 0.0, 1.0) * (g1 - 1)
    i0 = jnp.clip(jnp.floor(uu).astype(jnp.int32), 0, g0 - 2)
    j0 = jnp.clip(jnp.floor(vv).astype(jnp.int32), 0, g1 - 2)
    du = uu - i0
    dv = vv - j0
    top = table[i0, j0] * (1 - dv) + table[i0, j0 + 1] * dv
    bot = table[i0 + 1, j0] * (1 - dv) + table[i0 + 1, j0 + 1] * dv
    return top * (1 - du) + bot * du


def merge_coords(a_min, alpha, kappa):
    """Table coordinates ``(m, kappa)`` of the merge problem, clipped to the
    unit square.

    ``m = a_min / (a_min + alpha)``; same-sign pairs land strictly inside
    (0, 1), and the clip keeps masked-out entries finite so they cannot
    poison an argmin with NaNs.  Broadcasts: ``a_min`` may be a scalar or a
    ``(P, 1)`` column against ``(s,)`` / ``(P, s)`` candidate arrays.  This
    is the single definition shared by the core strategy layer
    (``budget.candidate_scores``) and the kernel oracles/wrappers.
    """
    denom = a_min + alpha
    m = jnp.clip(a_min / jnp.where(denom == 0, 1.0, denom), 0.0, 1.0)
    return m, jnp.clip(kappa, 0.0, 1.0)


def merge_scores(alpha, kappa_row, valid, a_min, wd_table):
    """Lookup-WD candidate scoring (paper Alg. 1 with the lookup solver).

    alpha, kappa_row, valid: (s,); a_min: scalar; wd_table: (G, G).
    Returns WD per candidate with +inf at invalid slots.
    """
    m, kap = merge_coords(a_min, alpha, kappa_row)
    wd = (a_min + alpha) ** 2 * bilinear_lookup(wd_table, m, kap)
    return jnp.where(valid, wd, jnp.inf)


def multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min, h_table,
                            wd_table):
    """Row-wise Lookup-WD scoring: every fixed partner brings its OWN
    candidate-alpha row.

    alpha_rows, kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    This is the layout the class-batched engine folds into: ``(C, P)`` pairs
    flatten onto the row axis with each class's alpha repeated across its P
    rows (``kernels.ops.multi_merge_scores``).  Returns ``(wd, h)`` of shape
    (P, s) with +inf WD at invalid slots.
    """
    m, kap = merge_coords(a_min[:, None], alpha_rows, kappa_rows)
    wd = (a_min[:, None] + alpha_rows) ** 2 * bilinear_lookup(wd_table, m, kap)
    h = bilinear_lookup(h_table, m, kap)
    return jnp.where(valid, wd, jnp.inf), h


def multi_merge_scores(alpha, kappa_rows, valid, a_min, h_table, wd_table):
    """Batched Lookup-WD scoring for P fixed partners sharing one alpha.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    Returns ``(wd, h)`` of shape (P, s): per-pair weight degradation (+inf at
    invalid slots) and the merge coefficient from the h table.
    """
    alpha_rows = jnp.broadcast_to(alpha[None, :], kappa_rows.shape)
    return multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min,
                                   h_table, wd_table)


def class_scores(x, sv_x, alpha, gamma):
    """Per-class decision scores, scored class-by-class (the serving oracle).

    x: (n, d); sv_x: (C, slots, d); alpha: (C, slots) with inactive slots
    already zeroed -> (C, n).  C sequential kernel calls — the semantics
    the fused ``ops.class_scores`` fold is tested against.
    """
    return jnp.stack([
        rbf_matrix(x, sv_x[c], gamma).astype(alpha.dtype) @ alpha[c]
        for c in range(sv_x.shape[0])])


def multi_merge_scores_classes(alpha, kappa_rows, valid, a_min, h_table,
                               wd_table):
    """Class-batched oracle: alpha (C, s); kappa_rows, valid (C, P, s);
    a_min (C, P) -> (wd, h) of shape (C, P, s)."""
    return jax.vmap(multi_merge_scores, in_axes=(0, 0, 0, 0, None, None))(
        alpha, kappa_rows, valid, a_min, h_table, wd_table)


def gss(m, kappa, n_iters: int):
    """Vectorized golden section search maximizing the merge objective.

    Mirrors ``repro.core.merge_math.golden_section_search`` but parameterized
    by iteration count (the kernel's static parameter).
    """
    invphi = (5.0**0.5 - 1.0) / 2.0
    m = jnp.asarray(m, jnp.float32)
    kappa = jnp.clip(jnp.asarray(kappa, jnp.float32), 1e-30, 1.0)
    lk = jnp.log(kappa)

    def s(h):
        return m * jnp.exp((1.0 - h) ** 2 * lk) + (1.0 - m) * jnp.exp(h**2 * lk)

    a = jnp.zeros_like(m)
    b = jnp.ones_like(m)

    def body(_, ab):
        a, b = ab
        span = b - a
        c = b - span * invphi
        d = a + span * invphi
        go_left = s(c) > s(d)
        return jnp.where(go_left, a, c), jnp.where(go_left, d, b)

    a, b = jax.lax.fori_loop(0, n_iters, body, (a, b))
    return 0.5 * (a + b)
