"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes and
asserts allclose against the function here.  They are also the production CPU
fallback (XLA compiles them well); the Pallas kernels target TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_matrix(x, y, gamma):
    """Gaussian kernel matrix K[i, j] = exp(-gamma ||x_i - y_j||^2).

    x: (n, d), y: (m, d)  ->  (n, m), computed via the matmul decomposition
    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y  (clamped at 0 for fp safety).
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xn + yn - 2.0 * x @ y.T
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def rbf_row(sv_x, x, gamma):
    """kappa_row[j] = k(x, sv_x[j]).  sv_x: (s, d), x: (d,)  ->  (s,)."""
    d2 = jnp.sum((sv_x - x[None, :]) ** 2, axis=-1)
    return jnp.exp(-gamma * d2)


def bilinear_lookup(table, u, v):
    """Bilinear interpolation of ``table`` at unit-square coords (u, v).

    Identical semantics to ``repro.core.lookup.bilinear_lookup``; duplicated
    here (3 lines of gather math) so the kernels package stays import-clean.
    """
    g0, g1 = table.shape
    uu = jnp.clip(u, 0.0, 1.0) * (g0 - 1)
    vv = jnp.clip(v, 0.0, 1.0) * (g1 - 1)
    i0 = jnp.clip(jnp.floor(uu).astype(jnp.int32), 0, g0 - 2)
    j0 = jnp.clip(jnp.floor(vv).astype(jnp.int32), 0, g1 - 2)
    du = uu - i0
    dv = vv - j0
    top = table[i0, j0] * (1 - dv) + table[i0, j0 + 1] * dv
    bot = table[i0 + 1, j0] * (1 - dv) + table[i0 + 1, j0 + 1] * dv
    return top * (1 - du) + bot * du


def merge_coords(a_min, alpha, kappa):
    """Table coordinates ``(m, kappa)`` of the merge problem, clipped to the
    unit square.

    ``m = a_min / (a_min + alpha)``; same-sign pairs land strictly inside
    (0, 1), and the clip keeps masked-out entries finite so they cannot
    poison an argmin with NaNs.  Broadcasts: ``a_min`` may be a scalar or a
    ``(P, 1)`` column against ``(s,)`` / ``(P, s)`` candidate arrays.  This
    is the single definition shared by the core strategy layer
    (``budget.candidate_scores``) and the kernel oracles/wrappers.
    """
    denom = a_min + alpha
    m = jnp.clip(a_min / jnp.where(denom == 0, 1.0, denom), 0.0, 1.0)
    return m, jnp.clip(kappa, 0.0, 1.0)


def merge_scores(alpha, kappa_row, valid, a_min, wd_table):
    """Lookup-WD candidate scoring (paper Alg. 1 with the lookup solver).

    alpha, kappa_row, valid: (s,); a_min: scalar; wd_table: (G, G).
    Returns WD per candidate with +inf at invalid slots.
    """
    m, kap = merge_coords(a_min, alpha, kappa_row)
    wd = (a_min + alpha) ** 2 * bilinear_lookup(wd_table, m, kap)
    return jnp.where(valid, wd, jnp.inf)


def multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min, h_table,
                            wd_table):
    """Row-wise Lookup-WD scoring: every fixed partner brings its OWN
    candidate-alpha row.

    alpha_rows, kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    This is the layout the class-batched engine folds into: ``(C, P)`` pairs
    flatten onto the row axis with each class's alpha repeated across its P
    rows (``kernels.ops.multi_merge_scores``).  Returns ``(wd, h)`` of shape
    (P, s) with +inf WD at invalid slots.
    """
    m, kap = merge_coords(a_min[:, None], alpha_rows, kappa_rows)
    wd = (a_min[:, None] + alpha_rows) ** 2 * bilinear_lookup(wd_table, m, kap)
    h = bilinear_lookup(h_table, m, kap)
    return jnp.where(valid, wd, jnp.inf), h


def multi_merge_scores(alpha, kappa_rows, valid, a_min, h_table, wd_table):
    """Batched Lookup-WD scoring for P fixed partners sharing one alpha.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    Returns ``(wd, h)`` of shape (P, s): per-pair weight degradation (+inf at
    invalid slots) and the merge coefficient from the h table.
    """
    alpha_rows = jnp.broadcast_to(alpha[None, :], kappa_rows.shape)
    return multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min,
                                   h_table, wd_table)


def class_scores(x, sv_x, alpha, gamma):
    """Per-class decision scores, scored class-by-class (the serving oracle).

    x: (n, d); sv_x: (C, slots, d); alpha: (C, slots) with inactive slots
    already zeroed -> (C, n).  C sequential kernel calls — the semantics
    the fused ``ops.class_scores`` fold is tested against.
    """
    return jnp.stack([
        rbf_matrix(x, sv_x[c], gamma).astype(alpha.dtype) @ alpha[c]
        for c in range(sv_x.shape[0])])


def multi_merge_scores_classes(alpha, kappa_rows, valid, a_min, h_table,
                               wd_table):
    """Class-batched oracle: alpha (C, s); kappa_rows, valid (C, P, s);
    a_min (C, P) -> (wd, h) of shape (C, P, s)."""
    return jax.vmap(multi_merge_scores, in_axes=(0, 0, 0, 0, None, None))(
        alpha, kappa_rows, valid, a_min, h_table, wd_table)


# Scores at/above this mean "no valid partner" (shared with core.budget;
# the Pallas scorers use a finite 3.4e38, real WDs are << 1e30 — both lose
# every argmin and both compare < NO_PARTNER identically).
NO_PARTNER = 1e30
# kappa values are clipped away from 0 before log (core.merge_math.KAPPA_MIN;
# duplicated so the kernels package stays import-clean).
_KAPPA_MIN = 1e-30


def _safe_log(k):
    return jnp.log(jnp.clip(k.astype(jnp.float32), _KAPPA_MIN, 1.0))


def _kappa_pow(kappa, expo):
    """kappa**expo as exp(expo log kappa) — core.merge_math.kappa_pow."""
    return jnp.exp(expo * _safe_log(kappa))


def merge_event(sv_x, alpha, kmat, count, over, h_table, wd_table):
    """One fused maintenance-event round over stacked classes (the oracle for
    ``merge_event.merge_event_pallas`` and the production CPU path).

    Per class ``c`` with ``over[c]`` set, executes exactly the paper's Alg. 1
    event — the same decisions and fp formulas as one cached
    ``core.budget._merge_once`` call on that class's slice:

      1. fixed partner ``i_min`` = active argmin |alpha|;
      2. kappa row read from the class's kernel cache (never recomputed);
      3. all candidates scored by the Lookup-WD tables (bilinear lookup);
      4. the merge (or the removal fallback when no same-sign partner
         exists) applied as the shared masked two-row + two-column update,
         with the merged point's cache row derived in closed form from the
         two parent rows (the log-space combine of ``core.kernel_cache``);
      5. the freed slot compacted by moving the old ``last`` row in.

    sv_x: (C, s, d); alpha: (C, s); kmat: (C, s, s) fp32 cache; count, over:
    (C,).  Classes with ``over`` False are returned BITWISE untouched (all
    their scatters are redirected out of bounds and dropped).  Returns
    ``(sv_x, alpha, kmat)``; the caller owns ``count -= over``.
    """
    c, s = alpha.shape
    idx = jnp.arange(s)
    carange = jnp.arange(c)
    active = idx[None, :] < count[:, None]                        # (C, s)

    # 1. fixed partners: per-class active min-|alpha| slot.
    abs_a = jnp.where(active, jnp.abs(alpha), jnp.inf)
    i_min = jnp.argmin(abs_a, axis=1)                             # (C,)
    a_min = jnp.take_along_axis(alpha, i_min[:, None], 1)[:, 0]   # (C,)

    # 2. kappa rows from the cache — the engine never touches sv_x for them.
    kappa_row = jnp.take_along_axis(
        kmat, i_min[:, None, None], 1)[:, 0, :].astype(alpha.dtype)  # (C, s)

    # 3. Lookup-WD scoring, identical formulas to budget.candidate_scores.
    same_sign = alpha * a_min[:, None] > 0
    valid = active & same_sign & (idx[None, :] != i_min[:, None])
    m, kap = merge_coords(a_min[:, None], alpha, kappa_row)
    wd = (a_min[:, None] + alpha) ** 2 * bilinear_lookup(wd_table, m, kap)
    h = bilinear_lookup(h_table, m, kap)
    wd = jnp.where(valid, wd, jnp.inf)
    j_star = jnp.argmin(wd, axis=1)                               # (C,)
    has_partner = jnp.take_along_axis(wd, j_star[:, None], 1)[:, 0] < NO_PARTNER

    # 4. merge math on the chosen pairs (per-class scalars).
    last = count - 1
    lo = jnp.minimum(i_min, j_star)
    hi = jnp.maximum(i_min, j_star)
    h_m = jnp.take_along_axis(h, j_star[:, None], 1)[:, 0]
    k_ij = jnp.take_along_axis(kappa_row, j_star[:, None], 1)[:, 0]
    kap_m = jnp.clip(k_ij, 0.0, 1.0)
    a_j = jnp.take_along_axis(alpha, j_star[:, None], 1)[:, 0]
    a_last = jnp.take_along_axis(alpha, last[:, None] % s, 1)[:, 0]
    a_z = (a_min * _kappa_pow(kap_m, (1.0 - h_m) ** 2)
           + a_j * _kappa_pow(kap_m, h_m**2)).astype(alpha.dtype)
    gather_row = lambda a, i: jnp.take_along_axis(
        a, (i % s)[:, None, None], 1)[:, 0]
    x_i = gather_row(sv_x, i_min)
    x_j = gather_row(sv_x, j_star)
    v_last = gather_row(sv_x, last)
    z = h_m[:, None] * x_i.astype(jnp.float32) \
        + (1.0 - h_m[:, None]) * x_j.astype(jnp.float32)

    # Merged point's cache row from the two parent rows (kernel_cache's
    # log-space combine — the z-row derivation lives inside the event).
    row_j = gather_row(kmat, j_star)
    row_last = gather_row(kmat, last)
    lz = (h_m[:, None] * _safe_log(kappa_row)
          + (1.0 - h_m[:, None]) * _safe_log(row_j)
          - (h_m * (1.0 - h_m))[:, None] * _safe_log(k_ij)[:, None])
    z_row = jnp.exp(jnp.minimum(lz, 0.0)).astype(kmat.dtype)

    # 5. masked two-row + two-column update (budget._merge_once's fused
    # branch-free form, batched over classes): slot t1 <- z row (or, on the
    # removal fallback, the old ``last``); slot t2 <- the old ``last``;
    # non-executing classes scatter out of bounds and drop.
    col = idx[None, :]
    z_row_l = jnp.take_along_axis(z_row, (last % s)[:, None], 1)[:, 0]
    r_merge = jnp.where(col == hi[:, None], z_row_l[:, None], z_row)
    r_merge = jnp.where(col == lo[:, None], 1.0, r_merge)
    r_move = jnp.where(col == hi[:, None], 1.0, row_last)
    r_move = jnp.where(col == lo[:, None], z_row_l[:, None], r_move)
    r_remove = jnp.where(col == i_min[:, None], 1.0, row_last)
    t1 = jnp.where(has_partner, lo, i_min)
    t2 = jnp.where(has_partner, hi, s)          # OOB on removal -> dropped
    t1 = jnp.where(over, t1, s)                 # OOB when not over -> no-op
    t2 = jnp.where(over, t2, s)
    tt = jnp.stack([t1, t2], axis=1)                              # (C, 2)
    rows = jnp.stack([jnp.where(has_partner[:, None], r_merge, r_remove),
                      r_move], axis=1).astype(kmat.dtype)         # (C, 2, s)
    kmat = kmat.at[carange[:, None], tt, :].set(rows, mode="drop")
    kmat = kmat.at[carange[:, None], :, tt].set(rows, mode="drop")

    sv1 = jnp.where(has_partner[:, None], z.astype(sv_x.dtype), v_last)
    sv_x = sv_x.at[carange[:, None], tt, :].set(
        jnp.stack([sv1, v_last], axis=1), mode="drop")
    a1 = jnp.where(has_partner, a_z, a_last)
    alpha = alpha.at[carange[:, None], tt].set(
        jnp.stack([a1, a_last], axis=1).astype(alpha.dtype), mode="drop")
    last_t = jnp.where(over, last, s)
    alpha = alpha.at[carange, last_t].set(0.0, mode="drop")
    return sv_x, alpha, kmat


def _multi_merge_event_one(sv_x, alpha, kmat, count, h_table, wd_table, *,
                           budget: int, merge_batch: int):
    """One single-class multi-merge event off the kernel cache (the oracle's
    standalone re-statement of ``core.budget._multi_merge_once`` +
    ``core.kernel_cache.apply_multi_merge`` — the kernels package cannot
    import core, so the formulas are restated here and the engine tests pin
    the two paths against each other).

    sv_x: (s, d); alpha: (s,); kmat: (s, s) fp32 cache (REQUIRED — kappa
    rows are read, never recomputed); count: () int32.  Up to ``merge_batch``
    disjoint same-sign pairs merge in one fused scatter (greedy in |alpha|
    order, Lookup-WD scored, removal fallback per pair), then targeted-move
    compaction.  Returns ``(sv_x, alpha, kmat, new_count)``.
    """
    slots = alpha.shape[0]
    p = merge_batch
    idx = jnp.arange(slots)
    active = idx < count

    # 1. fixed partners: the P smallest-|alpha| active SVs, cheapest first.
    abs_a = jnp.where(active, jnp.abs(alpha), jnp.inf)
    _, a_idx = jax.lax.top_k(-abs_a, p)                    # (P,) |alpha| asc
    a_min = alpha[a_idx]

    # 2. kappa rows straight from the cache.
    kappa_rows = kmat[a_idx].astype(alpha.dtype)

    # 3. Lookup-WD scoring; a pair may merge with another pair's fixed slot,
    #    only its own slot is excluded.
    same_sign = a_min[:, None] * alpha[None, :] > 0
    self_mask = jnp.zeros((p, slots), bool).at[jnp.arange(p), a_idx].set(True)
    valid = active[None, :] & same_sign & ~self_mask
    wd, h = multi_merge_scores(alpha, kappa_rows, valid, a_min,
                               h_table, wd_table)

    # 4. greedy disjoint pair choice in |alpha| order (static unroll).
    excess = count - budget
    taken = jnp.zeros((slots,), bool)
    consumed = jnp.zeros((p,), bool)
    n_exec = jnp.int32(0)
    b_list, merged_list, exec_list = [], [], []
    for q in range(p):
        wd_q = jnp.where(taken, jnp.inf, wd[q])
        j_q = jnp.argmin(wd_q)
        exec_q = ~consumed[q] & (n_exec < excess)
        merged_q = exec_q & (wd_q[j_q] < NO_PARTNER)
        b_list.append(j_q)
        merged_list.append(merged_q)
        exec_list.append(exec_q)
        taken = taken | ((idx == j_q) & merged_q) | ((idx == a_idx[q]) & exec_q)
        consumed = consumed | ((a_idx == j_q) & merged_q)
        n_exec = n_exec + exec_q.astype(jnp.int32)
    b_idx = jnp.stack(b_list)
    merged = jnp.stack(merged_list)
    execute = jnp.stack(exec_list)

    # 5. merge math + one fused scatter (z_q overwrites a_q; b_q — or a_q on
    #    the removal fallback — becomes a hole).
    h_star = h[jnp.arange(p), b_idx]
    kap = jnp.clip(kappa_rows[jnp.arange(p), b_idx], 0.0, 1.0)
    a_z = (a_min * _kappa_pow(kap, (1.0 - h_star) ** 2)
           + alpha[b_idx] * _kappa_pow(kap, h_star**2))
    z = h_star[:, None] * sv_x[a_idx] + (1.0 - h_star[:, None]) * sv_x[b_idx]
    write_idx = jnp.where(merged, a_idx, slots)            # OOB -> dropped
    hole_idx = jnp.where(merged, b_idx,
                         jnp.where(execute, a_idx, slots))

    # cache update (kernel_cache.apply_multi_merge's formulas): the P new z
    # rows/columns in log space plus the (P, P) cross block, symmetrized.
    lk = _safe_log(kmat[jnp.concatenate([a_idx, b_idx])])
    lk_a, lk_b = lk[:p], lk[p:]
    lk_ab = lk_a[jnp.arange(p), b_idx]
    hc = h_star[:, None]
    lz = jnp.minimum(hc * lk_a + (1.0 - hc) * lk_b
                     - (h_star * (1.0 - h_star))[:, None] * lk_ab[:, None],
                     0.0)
    z_rows = jnp.exp(lz).astype(kmat.dtype)
    hr = h_star[None, :]
    cross = jnp.exp(jnp.minimum(
        hr * lz[:, a_idx] + (1.0 - hr) * lz[:, b_idx]
        - (h_star * (1.0 - h_star))[None, :] * lk_ab[None, :], 0.0))
    cross = 0.5 * (cross + cross.T)
    cross = jnp.where(jnp.eye(p, dtype=bool), 1.0, cross).astype(kmat.dtype)
    kmat = kmat.at[write_idx, :].set(z_rows, mode="drop")
    kmat = kmat.at[:, write_idx].set(z_rows.T, mode="drop")
    kmat = kmat.at[write_idx[:, None], write_idx[None, :]].set(cross,
                                                              mode="drop")
    sv_x = sv_x.at[write_idx].set(z.astype(sv_x.dtype), mode="drop")
    alpha = alpha.at[write_idx].set(a_z.astype(alpha.dtype), mode="drop")

    # 6. targeted-move compaction: k-th hole below the new watermark takes
    #    the k-th surviving slot above it.
    hole_mask = jnp.zeros((slots,), bool).at[hole_idx].set(True, mode="drop")
    new_count = count - n_exec
    front_hole = hole_mask & (idx < new_count)
    tail_surv = active & ~hole_mask & (idx >= new_count)
    dst = jnp.sort(jnp.where(front_hole, idx, slots))[:p]     # OOB-padded
    src = jnp.sort(jnp.where(tail_surv, idx, slots))[:p]
    src_c = jnp.minimum(src, slots - 1)
    rows = kmat[src_c]
    kmat = kmat.at[dst, :].set(rows, mode="drop")
    kmat = kmat.at[:, dst].set(rows.T, mode="drop")
    kmat = kmat.at[dst[:, None], dst[None, :]].set(rows[:, src_c],
                                                   mode="drop")
    sv_x = sv_x.at[dst].set(sv_x[src_c], mode="drop")
    alpha = alpha.at[dst].set(alpha[src_c], mode="drop")
    alpha = jnp.where(idx < new_count, alpha, 0.0)
    return sv_x, alpha, kmat, new_count


def multi_merge_event(sv_x, alpha, kmat, count, over, h_table, wd_table, *,
                      budget: int, merge_batch: int):
    """One fused multi-merge maintenance round over stacked classes.

    The multi-merge counterpart of ``merge_event``: per class with ``over``
    set, up to ``merge_batch`` disjoint same-sign pairs retire in one event
    (greedy in |alpha| order, Lookup-WD scored off the resident cache);
    classes with ``over`` clear return bitwise untouched.  Returns
    ``(sv_x, alpha, kmat, count)`` — unlike ``merge_event`` the new count is
    returned (an event retires a data-dependent number of pairs).
    """
    new = jax.vmap(lambda sv, al, km, c: _multi_merge_event_one(
        sv, al, km, c, h_table, wd_table, budget=budget,
        merge_batch=merge_batch))(sv_x, alpha, kmat, count)
    ov = over.astype(bool)

    def mask(n, o):
        return jnp.where(ov.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)

    return (mask(new[0], sv_x), mask(new[1], alpha), mask(new[2], kmat),
            jnp.where(ov, new[3], count))


def train_step_fused(sv_x, alpha, kmat, count, step, n_inserts, n_merges,
                     xb, yb, k_bb, h_table, wd_table, *, budget: int,
                     lambda_: float, gamma: float, batch_size: int,
                     maintenance: str = "merge", merge_batch: int = 4,
                     unroll: int = 0):
    """Whole fused multiclass train step: margin + insert + event rounds
    (the oracle for ``train_step.train_step_pallas`` AND the production CPU
    path behind ``ops.train_step``).

    Executes, for every class at once, exactly what the composed engine does
    in three phase launches:

      1. the RBF margin rows ``k(xb, sv_c)`` from ONE flattened kernel call
         (identical fp path to ``core.multiclass.class_kernel_rows``);
      2. the Pegasos shrink + masked violator insert, reusing the margin
         rows as the new cache rows/columns (``bsgd.insert_from_rows`` +
         ``kernel_cache.insert_rows`` semantics, vmapped);
      3. masked maintenance event rounds until no class is over budget —
         ``merge_event`` rounds for ``maintenance="merge"``,
         ``multi_merge_event`` rounds for ``"multi-merge"`` (``unroll > 0``
         inlines that many masked rounds instead of the while loop, same
         contract as ``core.budget.run_maintenance``).

    sv_x: (C, s, d); alpha: (C, s); kmat: (C, s, s) fp32 (REQUIRED); count /
    step / n_inserts / n_merges: (C,) int32; xb: (batch, d); yb: (C, batch)
    one-vs-rest targets in {-1, +1}; k_bb: (batch, batch) = k(xb, xb).
    Returns the updated ``(sv_x, alpha, kmat, count, step, n_inserts,
    n_merges)``.
    """
    c, s, d = sv_x.shape
    slots = s
    k = rbf_matrix(xb, sv_x.reshape(c * s, d), gamma)
    k_b = jnp.moveaxis(k.reshape(xb.shape[0], c, s), 1, 0)    # (C, batch, s)

    def insert_one(sv, al, km, cnt, t, nin, yc, kb):
        eta = 1.0 / (lambda_ * t)
        active = jnp.arange(slots) < cnt
        f = kb.astype(al.dtype) @ jnp.where(active, al, 0.0)
        margin = yc * f
        al = al * (1.0 - eta * lambda_)
        viol = margin < 1.0
        pos = cnt + jnp.cumsum(viol.astype(jnp.int32)) - 1
        tgt = jnp.where(viol, pos, slots)                 # OOB -> dropped
        sv = sv.at[tgt].set(xb.astype(sv.dtype), mode="drop")
        new_alpha = (eta * yc / batch_size).astype(al.dtype)
        al = al.at[tgt].set(new_alpha, mode="drop")
        n_new = jnp.sum(viol).astype(jnp.int32)
        # cache insert: the margin rows double as the new rows/columns, with
        # the new-vs-new block patched in at the inserted slots
        rows = kb.astype(km.dtype).at[:, tgt].set(k_bb.astype(km.dtype),
                                                  mode="drop")
        km = km.at[tgt, :].set(rows, mode="drop")
        km = km.at[:, tgt].set(rows.T, mode="drop")
        km = km.at[tgt, tgt].set(1.0, mode="drop")
        return sv, al, km, cnt + n_new, t + 1, nin + n_new

    sv_x, alpha, kmat, count, step, n_inserts = jax.vmap(insert_one)(
        sv_x, alpha, kmat, count, step, n_inserts, yb, k_b)

    if maintenance == "merge":
        def round_(carry):
            sv, al, km, cnt, n = carry
            ov = cnt > budget
            sv, al, km = merge_event(sv, al, km, cnt, ov, h_table, wd_table)
            return (sv, al, km, cnt - ov.astype(cnt.dtype),
                    n + ov.astype(n.dtype))
    else:
        def round_(carry):
            sv, al, km, cnt, n = carry
            ov = cnt > budget
            sv, al, km, cnt = multi_merge_event(
                sv, al, km, cnt, ov, h_table, wd_table, budget=budget,
                merge_batch=merge_batch)
            return sv, al, km, cnt, n + ov.astype(n.dtype)

    carry = (sv_x, alpha, kmat, count, n_merges)
    if unroll:
        for _ in range(unroll):
            carry = round_(carry)
    else:
        carry = jax.lax.while_loop(lambda cr: jnp.any(cr[3] > budget),
                                   round_, carry)
    sv_x, alpha, kmat, count, n_merges = carry
    return sv_x, alpha, kmat, count, step, n_inserts, n_merges


def gss(m, kappa, n_iters: int):
    """Vectorized golden section search maximizing the merge objective.

    Mirrors ``repro.core.merge_math.golden_section_search`` but parameterized
    by iteration count (the kernel's static parameter).
    """
    invphi = (5.0**0.5 - 1.0) / 2.0
    m = jnp.asarray(m, jnp.float32)
    kappa = jnp.clip(jnp.asarray(kappa, jnp.float32), 1e-30, 1.0)
    lk = jnp.log(kappa)

    def s(h):
        return m * jnp.exp((1.0 - h) ** 2 * lk) + (1.0 - m) * jnp.exp(h**2 * lk)

    a = jnp.zeros_like(m)
    b = jnp.ones_like(m)

    def body(_, ab):
        a, b = ab
        span = b - a
        c = b - span * invphi
        d = a + span * invphi
        go_left = s(c) > s(d)
        return jnp.where(go_left, a, c), jnp.where(go_left, d, b)

    a, b = jax.lax.fori_loop(0, n_iters, body, (a, b))
    return 0.5 * (a + b)
