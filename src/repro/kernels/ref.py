"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes and
asserts allclose against the function here.  They are also the production CPU
fallback (XLA compiles them well); the Pallas kernels target TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_matrix(x, y, gamma):
    """Gaussian kernel matrix K[i, j] = exp(-gamma ||x_i - y_j||^2).

    x: (n, d), y: (m, d)  ->  (n, m), computed via the matmul decomposition
    ||x - y||^2 = ||x||^2 + ||y||^2 - 2 x.y  (clamped at 0 for fp safety).
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xn + yn - 2.0 * x @ y.T
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def rbf_row(sv_x, x, gamma):
    """kappa_row[j] = k(x, sv_x[j]).  sv_x: (s, d), x: (d,)  ->  (s,)."""
    d2 = jnp.sum((sv_x - x[None, :]) ** 2, axis=-1)
    return jnp.exp(-gamma * d2)


def bilinear_lookup(table, u, v):
    """Bilinear interpolation of ``table`` at unit-square coords (u, v).

    Identical semantics to ``repro.core.lookup.bilinear_lookup``; duplicated
    here (3 lines of gather math) so the kernels package stays import-clean.
    """
    g0, g1 = table.shape
    uu = jnp.clip(u, 0.0, 1.0) * (g0 - 1)
    vv = jnp.clip(v, 0.0, 1.0) * (g1 - 1)
    i0 = jnp.clip(jnp.floor(uu).astype(jnp.int32), 0, g0 - 2)
    j0 = jnp.clip(jnp.floor(vv).astype(jnp.int32), 0, g1 - 2)
    du = uu - i0
    dv = vv - j0
    top = table[i0, j0] * (1 - dv) + table[i0, j0 + 1] * dv
    bot = table[i0 + 1, j0] * (1 - dv) + table[i0 + 1, j0 + 1] * dv
    return top * (1 - du) + bot * du


def merge_coords(a_min, alpha, kappa):
    """Table coordinates ``(m, kappa)`` of the merge problem, clipped to the
    unit square.

    ``m = a_min / (a_min + alpha)``; same-sign pairs land strictly inside
    (0, 1), and the clip keeps masked-out entries finite so they cannot
    poison an argmin with NaNs.  Broadcasts: ``a_min`` may be a scalar or a
    ``(P, 1)`` column against ``(s,)`` / ``(P, s)`` candidate arrays.  This
    is the single definition shared by the core strategy layer
    (``budget.candidate_scores``) and the kernel oracles/wrappers.
    """
    denom = a_min + alpha
    m = jnp.clip(a_min / jnp.where(denom == 0, 1.0, denom), 0.0, 1.0)
    return m, jnp.clip(kappa, 0.0, 1.0)


def merge_scores(alpha, kappa_row, valid, a_min, wd_table):
    """Lookup-WD candidate scoring (paper Alg. 1 with the lookup solver).

    alpha, kappa_row, valid: (s,); a_min: scalar; wd_table: (G, G).
    Returns WD per candidate with +inf at invalid slots.
    """
    m, kap = merge_coords(a_min, alpha, kappa_row)
    wd = (a_min + alpha) ** 2 * bilinear_lookup(wd_table, m, kap)
    return jnp.where(valid, wd, jnp.inf)


def multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min, h_table,
                            wd_table):
    """Row-wise Lookup-WD scoring: every fixed partner brings its OWN
    candidate-alpha row.

    alpha_rows, kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    This is the layout the class-batched engine folds into: ``(C, P)`` pairs
    flatten onto the row axis with each class's alpha repeated across its P
    rows (``kernels.ops.multi_merge_scores``).  Returns ``(wd, h)`` of shape
    (P, s) with +inf WD at invalid slots.
    """
    m, kap = merge_coords(a_min[:, None], alpha_rows, kappa_rows)
    wd = (a_min[:, None] + alpha_rows) ** 2 * bilinear_lookup(wd_table, m, kap)
    h = bilinear_lookup(h_table, m, kap)
    return jnp.where(valid, wd, jnp.inf), h


def multi_merge_scores(alpha, kappa_rows, valid, a_min, h_table, wd_table):
    """Batched Lookup-WD scoring for P fixed partners sharing one alpha.

    alpha: (s,); kappa_rows, valid: (P, s); a_min: (P,); tables: (G, G).
    Returns ``(wd, h)`` of shape (P, s): per-pair weight degradation (+inf at
    invalid slots) and the merge coefficient from the h table.
    """
    alpha_rows = jnp.broadcast_to(alpha[None, :], kappa_rows.shape)
    return multi_merge_scores_rows(alpha_rows, kappa_rows, valid, a_min,
                                   h_table, wd_table)


def class_scores(x, sv_x, alpha, gamma):
    """Per-class decision scores, scored class-by-class (the serving oracle).

    x: (n, d); sv_x: (C, slots, d); alpha: (C, slots) with inactive slots
    already zeroed -> (C, n).  C sequential kernel calls — the semantics
    the fused ``ops.class_scores`` fold is tested against.
    """
    return jnp.stack([
        rbf_matrix(x, sv_x[c], gamma).astype(alpha.dtype) @ alpha[c]
        for c in range(sv_x.shape[0])])


def multi_merge_scores_classes(alpha, kappa_rows, valid, a_min, h_table,
                               wd_table):
    """Class-batched oracle: alpha (C, s); kappa_rows, valid (C, P, s);
    a_min (C, P) -> (wd, h) of shape (C, P, s)."""
    return jax.vmap(multi_merge_scores, in_axes=(0, 0, 0, 0, None, None))(
        alpha, kappa_rows, valid, a_min, h_table, wd_table)


# Scores at/above this mean "no valid partner" (shared with core.budget;
# the Pallas scorers use a finite 3.4e38, real WDs are << 1e30 — both lose
# every argmin and both compare < NO_PARTNER identically).
NO_PARTNER = 1e30
# kappa values are clipped away from 0 before log (core.merge_math.KAPPA_MIN;
# duplicated so the kernels package stays import-clean).
_KAPPA_MIN = 1e-30


def _safe_log(k):
    return jnp.log(jnp.clip(k.astype(jnp.float32), _KAPPA_MIN, 1.0))


def _kappa_pow(kappa, expo):
    """kappa**expo as exp(expo log kappa) — core.merge_math.kappa_pow."""
    return jnp.exp(expo * _safe_log(kappa))


def merge_event(sv_x, alpha, kmat, count, over, h_table, wd_table):
    """One fused maintenance-event round over stacked classes (the oracle for
    ``merge_event.merge_event_pallas`` and the production CPU path).

    Per class ``c`` with ``over[c]`` set, executes exactly the paper's Alg. 1
    event — the same decisions and fp formulas as one cached
    ``core.budget._merge_once`` call on that class's slice:

      1. fixed partner ``i_min`` = active argmin |alpha|;
      2. kappa row read from the class's kernel cache (never recomputed);
      3. all candidates scored by the Lookup-WD tables (bilinear lookup);
      4. the merge (or the removal fallback when no same-sign partner
         exists) applied as the shared masked two-row + two-column update,
         with the merged point's cache row derived in closed form from the
         two parent rows (the log-space combine of ``core.kernel_cache``);
      5. the freed slot compacted by moving the old ``last`` row in.

    sv_x: (C, s, d); alpha: (C, s); kmat: (C, s, s) fp32 cache; count, over:
    (C,).  Classes with ``over`` False are returned BITWISE untouched (all
    their scatters are redirected out of bounds and dropped).  Returns
    ``(sv_x, alpha, kmat)``; the caller owns ``count -= over``.
    """
    c, s = alpha.shape
    idx = jnp.arange(s)
    carange = jnp.arange(c)
    active = idx[None, :] < count[:, None]                        # (C, s)

    # 1. fixed partners: per-class active min-|alpha| slot.
    abs_a = jnp.where(active, jnp.abs(alpha), jnp.inf)
    i_min = jnp.argmin(abs_a, axis=1)                             # (C,)
    a_min = jnp.take_along_axis(alpha, i_min[:, None], 1)[:, 0]   # (C,)

    # 2. kappa rows from the cache — the engine never touches sv_x for them.
    kappa_row = jnp.take_along_axis(
        kmat, i_min[:, None, None], 1)[:, 0, :].astype(alpha.dtype)  # (C, s)

    # 3. Lookup-WD scoring, identical formulas to budget.candidate_scores.
    same_sign = alpha * a_min[:, None] > 0
    valid = active & same_sign & (idx[None, :] != i_min[:, None])
    m, kap = merge_coords(a_min[:, None], alpha, kappa_row)
    wd = (a_min[:, None] + alpha) ** 2 * bilinear_lookup(wd_table, m, kap)
    h = bilinear_lookup(h_table, m, kap)
    wd = jnp.where(valid, wd, jnp.inf)
    j_star = jnp.argmin(wd, axis=1)                               # (C,)
    has_partner = jnp.take_along_axis(wd, j_star[:, None], 1)[:, 0] < NO_PARTNER

    # 4. merge math on the chosen pairs (per-class scalars).
    last = count - 1
    lo = jnp.minimum(i_min, j_star)
    hi = jnp.maximum(i_min, j_star)
    h_m = jnp.take_along_axis(h, j_star[:, None], 1)[:, 0]
    k_ij = jnp.take_along_axis(kappa_row, j_star[:, None], 1)[:, 0]
    kap_m = jnp.clip(k_ij, 0.0, 1.0)
    a_j = jnp.take_along_axis(alpha, j_star[:, None], 1)[:, 0]
    a_last = jnp.take_along_axis(alpha, last[:, None] % s, 1)[:, 0]
    a_z = (a_min * _kappa_pow(kap_m, (1.0 - h_m) ** 2)
           + a_j * _kappa_pow(kap_m, h_m**2)).astype(alpha.dtype)
    gather_row = lambda a, i: jnp.take_along_axis(
        a, (i % s)[:, None, None], 1)[:, 0]
    x_i = gather_row(sv_x, i_min)
    x_j = gather_row(sv_x, j_star)
    v_last = gather_row(sv_x, last)
    z = h_m[:, None] * x_i.astype(jnp.float32) \
        + (1.0 - h_m[:, None]) * x_j.astype(jnp.float32)

    # Merged point's cache row from the two parent rows (kernel_cache's
    # log-space combine — the z-row derivation lives inside the event).
    row_j = gather_row(kmat, j_star)
    row_last = gather_row(kmat, last)
    lz = (h_m[:, None] * _safe_log(kappa_row)
          + (1.0 - h_m[:, None]) * _safe_log(row_j)
          - (h_m * (1.0 - h_m))[:, None] * _safe_log(k_ij)[:, None])
    z_row = jnp.exp(jnp.minimum(lz, 0.0)).astype(kmat.dtype)

    # 5. masked two-row + two-column update (budget._merge_once's fused
    # branch-free form, batched over classes): slot t1 <- z row (or, on the
    # removal fallback, the old ``last``); slot t2 <- the old ``last``;
    # non-executing classes scatter out of bounds and drop.
    col = idx[None, :]
    z_row_l = jnp.take_along_axis(z_row, (last % s)[:, None], 1)[:, 0]
    r_merge = jnp.where(col == hi[:, None], z_row_l[:, None], z_row)
    r_merge = jnp.where(col == lo[:, None], 1.0, r_merge)
    r_move = jnp.where(col == hi[:, None], 1.0, row_last)
    r_move = jnp.where(col == lo[:, None], z_row_l[:, None], r_move)
    r_remove = jnp.where(col == i_min[:, None], 1.0, row_last)
    t1 = jnp.where(has_partner, lo, i_min)
    t2 = jnp.where(has_partner, hi, s)          # OOB on removal -> dropped
    t1 = jnp.where(over, t1, s)                 # OOB when not over -> no-op
    t2 = jnp.where(over, t2, s)
    tt = jnp.stack([t1, t2], axis=1)                              # (C, 2)
    rows = jnp.stack([jnp.where(has_partner[:, None], r_merge, r_remove),
                      r_move], axis=1).astype(kmat.dtype)         # (C, 2, s)
    kmat = kmat.at[carange[:, None], tt, :].set(rows, mode="drop")
    kmat = kmat.at[carange[:, None], :, tt].set(rows, mode="drop")

    sv1 = jnp.where(has_partner[:, None], z.astype(sv_x.dtype), v_last)
    sv_x = sv_x.at[carange[:, None], tt, :].set(
        jnp.stack([sv1, v_last], axis=1), mode="drop")
    a1 = jnp.where(has_partner, a_z, a_last)
    alpha = alpha.at[carange[:, None], tt].set(
        jnp.stack([a1, a_last], axis=1).astype(alpha.dtype), mode="drop")
    last_t = jnp.where(over, last, s)
    alpha = alpha.at[carange, last_t].set(0.0, mode="drop")
    return sv_x, alpha, kmat


def gss(m, kappa, n_iters: int):
    """Vectorized golden section search maximizing the merge objective.

    Mirrors ``repro.core.merge_math.golden_section_search`` but parameterized
    by iteration count (the kernel's static parameter).
    """
    invphi = (5.0**0.5 - 1.0) / 2.0
    m = jnp.asarray(m, jnp.float32)
    kappa = jnp.clip(jnp.asarray(kappa, jnp.float32), 1e-30, 1.0)
    lk = jnp.log(kappa)

    def s(h):
        return m * jnp.exp((1.0 - h) ** 2 * lk) + (1.0 - m) * jnp.exp(h**2 * lk)

    a = jnp.zeros_like(m)
    b = jnp.ones_like(m)

    def body(_, ab):
        a, b = ab
        span = b - a
        c = b - span * invphi
        d = a + span * invphi
        go_left = s(c) > s(d)
        return jnp.where(go_left, a, c), jnp.where(go_left, d, b)

    a, b = jax.lax.fori_loop(0, n_iters, body, (a, b))
    return 0.5 * (a + b)
