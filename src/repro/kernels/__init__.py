"""Pallas TPU kernels for the BSGD hot spots, each with a pure-jnp oracle.

Layout: ``<name>.py`` holds the Pallas kernel, ``ref.py`` the semantics of
record (and CPU/GPU fallback), ``ops.py`` the public jit'd wrappers with
``impl`` dispatch (``auto | pallas | pallas_interpret | ref``).  Kernels:
``rbf_kernel`` (tiled Gaussian kernel matrix), ``gss`` (batched golden
section search), ``merge_lookup`` (fused single-partner candidate scoring),
``merge_multi`` (P-partner multi-merge scoring), ``merge_event`` (one whole
maintenance event per over-budget class — selection, cached-kappa Lookup-WD
scoring, and the in-VMEM two-row/two-column cache update in one launch).
"""
