"""Restart supervisor: run the training driver, re-admit on failure.

Simulates the cluster-level control loop: a child training process that dies
(node failure, injected fault, straggler exit code 75) is restarted and
resumes from the newest atomic checkpoint.  Combined with the mesh-free
checkpoint layout this also covers *elastic scaling*: the restart may use a
different device count (``--devices``) and the state reshard happens at load.

Usage:
  python -m repro.launch.elastic --arch smollm_360m --steps 60 \
      --ckpt-dir /tmp/ck --fault-at 30
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def supervise(cmd: list[str], *, max_restarts: int = 5, env_extra=None,
              verbose: bool = True) -> int:
    """Run ``cmd``; restart on any nonzero exit, up to ``max_restarts``."""
    restarts = 0
    while True:
        env = dict(os.environ)
        if env_extra:
            env.update(env_extra)
            env_extra = None  # fault injections fire only on the first run
        t0 = time.time()
        proc = subprocess.run(cmd, env=env)
        if proc.returncode == 0:
            if verbose:
                print(f"[elastic] child finished OK after {restarts} restarts")
            return restarts
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError(f"child kept failing ({restarts} restarts)")
        if verbose:
            print(f"[elastic] child exited rc={proc.returncode} "
                  f"after {time.time()-t0:.1f}s; restart {restarts}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="restart with this many host devices (elastic)")
    ap.add_argument("--max-restarts", type=int, default=5)
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", args.arch,
           "--smoke", "--steps", str(args.steps), "--ckpt-dir", args.ckpt_dir,
           "--ckpt-every", str(args.ckpt_every)]
    env_extra = {}
    if args.fault_at is not None:
        env_extra["FAULT_AT_STEP"] = str(args.fault_at)
    if args.devices:
        env_extra["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    supervise(cmd, max_restarts=args.max_restarts, env_extra=env_extra or None)


if __name__ == "__main__":
    main()
