"""End-to-end training driver: sharded init, checkpoint-restart, watchdog.

Runs real steps on whatever devices exist (CPU smoke configs, TPU pods with
the production mesh).  Fault-tolerance contract:

  * checkpoints are atomic + keep-last-k (``repro.checkpoint``); on start the
    driver resumes from the newest complete checkpoint automatically, so a
    SIGKILL'd / OOM'd / preempted job loses at most ``ckpt_every`` steps
    (exercised by ``launch/elastic.py`` and tests/test_fault_tolerance.py).
  * a per-step deadline watchdog flags stragglers; after ``max_strikes``
    consecutive overruns the driver exits with code 75 (EX_TEMPFAIL) so the
    supervisor re-admits it elsewhere — on a real cluster this is the
    slow-host escape hatch.
  * ``FAULT_AT_STEP`` env var injects a hard crash at a given step (fault
    drills in tests).

Usage: python -m repro.launch.train --arch smollm_360m --smoke --steps 100
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import checkpoint as ckpt
from ..configs import get, get_smoke
from ..data.tokens import BigramStream, frames_batch
from ..models import init_lm
from ..sharding import specs as sh
from ..train.optimizer import AdamW, cosine_schedule
from .inputs import abstract_params
from .mesh import make_host_mesh
from .steps import make_train_step

EX_TEMPFAIL = 75


def train_loop(cfg, *, steps: int = 100, batch_size: int = 8, seq_len: int = 128,
               ckpt_dir: str | None = None, ckpt_every: int = 25,
               mesh=None, strategy: str = "tp", lr: float = 3e-3,
               step_deadline_s: float | None = None, max_strikes: int = 3,
               log_every: int = 10, seed: int = 0, verbose: bool = True,
               schedule_total: int | None = None):
    """Returns dict of metrics (losses, resumed_from, straggler_strikes).

    ``schedule_total``: the LR schedule's horizon — pass the TARGET total when
    running a partial leg of a longer job, so interrupted + resumed runs see
    the identical schedule (restart transparency)."""
    mesh = mesh or make_host_mesh()
    total = schedule_total or steps
    opt = AdamW(lr=cosine_schedule(lr, warmup=min(20, total // 10 + 1),
                                   total=total))
    params_s, axes = abstract_params(cfg)
    p_shard = sh.param_shardings(axes, params_s, mesh, strategy)

    with mesh:
        params = jax.jit(lambda k: init_lm(k, cfg)[0],
                         out_shardings=p_shard)(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt.init)(params)

    start_step = 0
    resumed_from = None
    if ckpt_dir:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            state = ckpt.load(ckpt_dir, latest,
                              {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            resumed_from = latest
            if verbose:
                print(f"[train] resumed from step {latest}")

    step_fn = make_train_step(cfg, opt)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    stream = BigramStream(cfg.vocab_size, seed=seed)
    fault_at = int(os.environ.get("FAULT_AT_STEP", -1))
    losses, strikes = [], 0
    base_key = jax.random.PRNGKey(seed + 1)
    deadline = step_deadline_s

    for step in range(start_step, steps):
        # stateless per-step key: a resumed run sees the exact same batches
        # as an uninterrupted one (restart must be semantically transparent)
        sub = jax.random.fold_in(base_key, step)
        if cfg.input_kind == "frames":
            batch = frames_batch(sub, batch_size, seq_len, cfg.frame_dim,
                                 cfg.vocab_size)
        else:
            batch = stream.batch(sub, batch_size, seq_len)
        t0 = time.time()
        with mesh:
            params, opt_state, loss = jit_step(params, opt_state, batch)
        loss = float(loss)
        dt = time.time() - t0
        losses.append(loss)
        if step == fault_at:
            print(f"[train] FAULT INJECTION at step {step}", flush=True)
            os._exit(137)
        if deadline is not None and step > start_step:  # first step compiles
            if dt > deadline:
                strikes += 1
                print(f"[train] STRAGGLER step {step}: {dt:.2f}s > {deadline}s "
                      f"({strikes}/{max_strikes})", flush=True)
                if strikes >= max_strikes:
                    if ckpt_dir:
                        ckpt.save(ckpt_dir, step + 1,
                                  {"params": params, "opt": opt_state})
                    raise SystemExit(EX_TEMPFAIL)
            else:
                strikes = 0
        if verbose and step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)",
                  flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state})

    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return {"losses": losses, "resumed_from": resumed_from,
            "final_loss": losses[-1] if losses else None,
            "bigram_floor": stream.bigram_entropy()
            if cfg.input_kind == "tokens" else None,
            "params": params}


def svm_stream_loop(source, *, layout: str = "replicated", n_classes: int = 8,
                    budget: int = 128, batch_size: int = 8,
                    method: str = "lookup-wd", gamma: float = 0.5,
                    lambda_: float = 1e-4, epochs: int = 1, seed: int = 0,
                    mesh=None, ckpt_dir: str | None = None,
                    ckpt_every: int = 0, max_chunks: int | None = None,
                    prefetch: int = 0, verbose: bool = True, retry=None,
                    guard_finite: bool = False, report=None,
                    skip_chunks=()):
    """Streamed SVM training on the production mesh: the distributed path
    consuming the same chunk stream as the single-device trainers.

    ``source`` is any ``repro.data.stream.ChunkSource``.  Each resident chunk
    runs as ONE pjit'd donated-state program
    (``core.distributed.make_distributed_chunk_step``) with the chunk's batch
    axis sharded over the data axes and the SV state laid out per ``layout``
    — ``replicated`` / ``slots`` for binary, ``class`` for one-vs-rest
    multi-class (classes over ``model``, ``n_classes`` problems).  Epoch
    shuffling, remainder carry, every-K-chunks checkpointing and mid-epoch
    resume are exactly the ``fit_stream`` contract (the drivers are shared).
    ``prefetch > 0`` parses/shuffles/assembles the next chunk on a background
    stager while the current pjit program runs (host-side overlap only here —
    device placement stays with pjit's ``in_shardings``, since the chunk
    batch axis is sharded across the mesh, not single-device).
    ``retry``/``guard_finite``/``report``/``skip_chunks`` are the §16
    resilience knobs, forwarded verbatim to the shared streaming driver.

    Returns ``(state, cfg)``.
    """
    from ..core.bsgd import BSGDConfig, fit_stream, init_state
    from ..core.distributed import make_distributed_chunk_step
    from ..core.multiclass import (MulticlassSVMConfig, check_labels,
                                   fit_multiclass_stream,
                                   init_multiclass_state)
    from .mesh import make_mesh

    if mesh is None:
        mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    bcfg = BSGDConfig(budget=budget, lambda_=lambda_, gamma=gamma,
                      method=method, batch_size=batch_size)
    is_class = layout == "class"
    cfg = (MulticlassSVMConfig(n_classes=n_classes, binary=bcfg) if is_class
           else bcfg)
    table = cfg.table()

    compiled = {}   # chunk_steps -> pjit'd donated-state chunk program

    def chunk_fn(state, xc, yc):
        if is_class:
            check_labels(yc, n_classes)
        steps = xc.shape[0]
        if steps not in compiled:
            fn, _, in_sh, out_sh = make_distributed_chunk_step(
                cfg, mesh, source.dim, steps, table, layout=layout)
            with mesh:
                compiled[steps] = jax.jit(fn, in_shardings=in_sh,
                                          out_shardings=out_sh,
                                          donate_argnums=(0,))
        with mesh:
            return compiled[steps](state, table, xc, yc)

    if is_class:
        state = init_multiclass_state(cfg, source.dim)
        state = fit_multiclass_stream(cfg, source, epochs=epochs, seed=seed,
                                      state=state, ckpt_dir=ckpt_dir,
                                      ckpt_every=ckpt_every,
                                      max_chunks=max_chunks,
                                      chunk_fn=chunk_fn, prefetch=prefetch,
                                      retry=retry, guard_finite=guard_finite,
                                      report=report, skip_chunks=skip_chunks)
    else:
        state = init_state(cfg, source.dim)
        state = fit_stream(cfg, source, epochs=epochs, seed=seed, state=state,
                           ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                           max_chunks=max_chunks, chunk_fn=chunk_fn,
                           prefetch=prefetch, retry=retry,
                           guard_finite=guard_finite, report=report,
                           skip_chunks=skip_chunks)
    if verbose:
        counts = np.asarray(state.count).tolist()
        print(f"[train] svm stream done: layout={layout} "
              f"chunks={source.n_chunks} rows={source.n_rows} "
              f"sv_count={counts}", flush=True)
    return state, cfg


def _open_stream(path: str, *, chunk_rows: int, n_features: int | None,
                 binary: bool):
    """CLI helper: a shard directory (*.npz) or a LIBSVM text file."""
    import glob

    from ..data.stream import FileChunks, LibsvmChunks

    if os.path.isdir(path):
        shards = sorted(glob.glob(os.path.join(path, "*.npz")))
        if not shards:
            raise SystemExit(f"{path}: no .npz shards")
        return FileChunks(shards)
    return LibsvmChunks(path, chunk_rows, n_features, binary=binary)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--deadline", type=float, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="svm_bsgd only: chunk source — a directory of .npz "
                         "shards or a LIBSVM text file")
    ap.add_argument("--svm-layout", default="replicated",
                    choices=("replicated", "slots", "class"))
    ap.add_argument("--svm-classes", type=int, default=8)
    ap.add_argument("--svm-budget", type=int, default=128)
    ap.add_argument("--chunk-rows", type=int, default=4096,
                    help="rows per chunk for LIBSVM streams")
    ap.add_argument("--n-features", type=int, default=None)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--prefetch", type=int, default=0, metavar="DEPTH",
                    help="svm_bsgd only: stage the next DEPTH chunks "
                         "(parse/shuffle/assemble) on a background thread "
                         "while the device runs the current chunk")
    ap.add_argument("--retry", type=int, default=0, metavar="ATTEMPTS",
                    help="svm_bsgd only: retry transient chunk-load "
                         "failures up to ATTEMPTS times (bounded backoff); "
                         "chunks that exhaust retries are quarantined and "
                         "skipped, not fatal (DESIGN.md §16)")
    ap.add_argument("--guard-finite", action="store_true",
                    help="svm_bsgd only: per-chunk non-finite sentinel — "
                         "roll back to the last good state and skip the "
                         "offending chunk instead of training on NaN/Inf")
    args = ap.parse_args()
    if args.arch == "svm_bsgd":
        if not args.stream:
            raise SystemExit("--arch svm_bsgd needs --stream PATH")
        source = _open_stream(args.stream, chunk_rows=args.chunk_rows,
                              n_features=args.n_features,
                              binary=args.svm_layout != "class")
        retry = None
        report = None
        if args.retry or args.guard_finite:
            from ..data import ResilienceReport, RetryPolicy
            report = ResilienceReport()
            if args.retry:
                retry = RetryPolicy(max_attempts=args.retry)
        svm_stream_loop(source, layout=args.svm_layout,
                        n_classes=args.svm_classes, budget=args.svm_budget,
                        batch_size=args.batch_size, epochs=args.epochs,
                        seed=args.seed, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, prefetch=args.prefetch,
                        retry=retry, guard_finite=args.guard_finite,
                        report=report)
        if report is not None:
            print(f"[train] resilience: {report!r}")
        return
    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    metrics = train_loop(cfg, steps=args.steps, batch_size=args.batch_size,
                         seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         step_deadline_s=args.deadline, lr=args.lr,
                         seed=args.seed)
    print(f"[train] done: final loss {metrics['final_loss']:.4f} "
          f"(bigram floor {metrics['bigram_floor']})")


if __name__ == "__main__":
    main()
