"""Launch layer: meshes, input specs, step plans, dry-run, drivers.

NOTE: do NOT import ``repro.launch.dryrun`` from here — it must own its
process (it forces 512 host devices before any jax import).
"""
from . import mesh, roofline
from .mesh import make_host_mesh, make_mesh, make_production_mesh
