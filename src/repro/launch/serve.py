"""Serving driver: prefill + batched decode against any arch config.

CPU-runnable with smoke configs; the same step functions are what the
dry-run lowers for the production mesh.  Supports the exact cache (ring
buffer for SWA archs) and the --budgeted-kv option (the paper-technique
transfer: merge-based cache maintenance, core/budgeted_kv.py).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get, get_smoke
from ..models import decode_step, init_cache, init_lm, prefill
from .mesh import make_host_mesh


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 32,
          seed: int = 0, greedy: bool = True, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    params, _ = init_lm(key, cfg)
    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    jit_prefill = jax.jit(lambda p, t: prefill(cfg, p, t))
    logits, pf_cache = jit_prefill(params, toks)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # decode cache sized for prompt + generation; copy prefill K/V in
    cache = init_cache(cfg, batch, prompt_len + gen + 1)

    # structural copy: prefill caches have seq dim = prompt_len; place at 0
    def place(dst, src):
        if src.shape == dst.shape:
            return src
        # pad the sequence dim (axis 1 for k/v/pos/ckv/krope)
        if src.ndim == dst.ndim and src.shape[0] == dst.shape[0]:
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                (0,) * dst.ndim)
        return dst
    cache = jax.tree.map(place, cache, jax.tree.map(lambda x: x, pf_cache)) \
        if _cache_compatible(cache, pf_cache) else cache

    jit_decode = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) if greedy else toks[:, -1:]
    out_tokens = [cur]
    t0 = time.time()
    pos = prompt_len if _cache_compatible(cache, pf_cache) else 0
    for i in range(gen):
        logits_i, cache = jit_decode(params, cache, cur,
                                     jnp.int32(pos + i))
        cur = jnp.argmax(logits_i, -1)[:, None].astype(jnp.int32)
        out_tokens.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.time() - t0
    toks_out = jnp.concatenate(out_tokens, axis=1)
    if verbose:
        print(f"[serve] prefill {batch}x{prompt_len}: {t_prefill*1e3:.1f} ms; "
              f"decode {gen} steps: {t_decode*1e3:.1f} ms "
              f"({t_decode/gen*1e3:.2f} ms/tok incl. dispatch)")
    return toks_out


def _cache_compatible(cache, pf_cache) -> bool:
    try:
        return (pf_cache is not None and
                jax.tree.structure(cache) == jax.tree.structure(pf_cache))
    except Exception:  # noqa: BLE001
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    with make_host_mesh():
        serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
