"""Serving driver: LM prefill/decode, and the budgeted-SVM request server.

CPU-runnable with smoke configs; the same step functions are what the
dry-run lowers for the production mesh.  Two arms:

  * LM archs — prefill + batched decode with the exact cache (ring buffer
    for SWA archs); see ``serve``.
  * ``--arch svm_bsgd`` — the trained budgeted model as a scoring service
    (``serve_svm``): a ``core.predict.BatchQueue`` assembles request rows
    into bucket-padded microbatches and each microbatch runs the fused
    multiclass predict cell (one ``rbf_matrix`` launch against the exported
    bank, argmax on device).  ``--model`` points at a ``fit_stream`` /
    ``fit_multiclass_stream`` checkpoint directory (mid-epoch checkpoints
    serve fine); without it a small in-process model is trained first (the
    smoke/demo path).  ``--bank-dtype bfloat16`` serves the quantized bank.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --smoke \
        --batch 4 --prompt-len 32 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch svm_bsgd --smoke
    PYTHONPATH=src python -m repro.launch.serve --arch svm_bsgd \
        --model ckpts/run1 --gamma 0.5 --bank-dtype bfloat16
    PYTHONPATH=src python -m repro.launch.serve --arch svm_bsgd --smoke --live

    ``--live`` is the train-while-serve arm (``serve_svm_live``): a
    background ``fit_multiclass_stream`` publishes versioned snapshots into
    a ``core.predict.ModelBank`` while an ``AsyncBatchQueue`` serves a
    ragged trace over the bank, hot-swapping models mid-trace.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get, get_smoke
from ..models import decode_step, init_cache, init_lm, prefill
from .mesh import make_host_mesh


def serve(cfg, *, batch: int = 4, prompt_len: int = 32, gen: int = 32,
          seed: int = 0, greedy: bool = True, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    params, _ = init_lm(key, cfg)
    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    jit_prefill = jax.jit(lambda p, t: prefill(cfg, p, t))
    logits, pf_cache = jit_prefill(params, toks)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # decode cache sized for prompt + generation; copy prefill K/V in
    cache = init_cache(cfg, batch, prompt_len + gen + 1)

    # structural copy: prefill caches have seq dim = prompt_len; place at 0
    def place(dst, src):
        if src.shape == dst.shape:
            return src
        # pad the sequence dim (axis 1 for k/v/pos/ckv/krope)
        if src.ndim == dst.ndim and src.shape[0] == dst.shape[0]:
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                (0,) * dst.ndim)
        return dst
    cache = jax.tree.map(place, cache, jax.tree.map(lambda x: x, pf_cache)) \
        if _cache_compatible(cache, pf_cache) else cache

    jit_decode = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i))
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) if greedy else toks[:, -1:]
    out_tokens = [cur]
    t0 = time.time()
    pos = prompt_len if _cache_compatible(cache, pf_cache) else 0
    for i in range(gen):
        logits_i, cache = jit_decode(params, cache, cur,
                                     jnp.int32(pos + i))
        cur = jnp.argmax(logits_i, -1)[:, None].astype(jnp.int32)
        out_tokens.append(cur)
    jax.block_until_ready(cur)
    t_decode = time.time() - t0
    toks_out = jnp.concatenate(out_tokens, axis=1)
    if verbose:
        print(f"[serve] prefill {batch}x{prompt_len}: {t_prefill*1e3:.1f} ms; "
              f"decode {gen} steps: {t_decode*1e3:.1f} ms "
              f"({t_decode/gen*1e3:.2f} ms/tok incl. dispatch)")
    return toks_out


def serve_svm(*, model_dir: str | None = None, gamma: float = 0.5,
              bank_dtype: str | None = None, n_classes: int = 8,
              budget: int = 64, dim: int = 16, train_rows: int = 2048,
              rows: int = 4096, max_batch: int = 256, min_bucket: int = 8,
              top_k: int | None = None, seed: int = 0,
              verbose: bool = True) -> dict:
    """Serve a budgeted SVM: batched request queue over the fused predict cell.

    Loads ``model_dir`` (any ``repro.checkpoint`` dir holding an ``SVMState``
    — what the streaming trainers write) or, without one, trains a small
    ``n_classes``-blob model in-process.  A deterministic request trace of
    ``rows`` total rows with ragged request sizes is pushed through a
    ``BatchQueue`` (``max_batch`` microbatches, power-of-two pad buckets) and
    the labels are checked bitwise against one direct ``predict_labels``
    call — the parity gate runs on every invocation, not just in tests.
    ``top_k`` additionally serves the k-best class ids + calibrated softmax
    probabilities for a sample of the trace (``core.predict.top_k_labels`` /
    ``predict_proba``) and re-asserts that rank 1 is bitwise the argmax
    labels.  Returns the stats dict (rows/sec, p50/p99 microbatch latency,
    bucket histogram, top-k sample when requested).
    """
    from ..core import (MulticlassSVMConfig, drive_trace, export_model,
                        fit_multiclass, load_serve_model, predict_labels,
                        predict_proba, ragged_trace_sizes, top_k_labels)
    from ..data import make_blobs_multiclass

    if model_dir:
        model = load_serve_model(model_dir, gamma, bank_dtype=bank_dtype)
        if verbose:
            print(f"[serve] loaded {model_dir}: C={model.n_classes} "
                  f"slots={model.sv_x.shape[1]} dim={model.sv_x.shape[2]} "
                  f"bank={model.sv_x.dtype} "
                  f"sv_count={np.asarray(model.count).tolist()}")
    else:
        cfg = MulticlassSVMConfig.create(
            n_classes, budget=budget, lambda_=1e-3, gamma=gamma, batch_size=8)
        x, y = make_blobs_multiclass(jax.random.PRNGKey(seed), train_rows,
                                     dim, n_classes=n_classes, sep=2.5)
        state = fit_multiclass(cfg, x, y, epochs=1, seed=seed)
        model = export_model(state, gamma, bank_dtype=bank_dtype)
        if verbose:
            print(f"[serve] trained in-process: C={n_classes} budget={budget} "
                  f"dim={dim} bank={model.sv_x.dtype}")

    dim = model.sv_x.shape[2]
    rng = np.random.default_rng(seed)
    req_x = rng.standard_normal((rows, dim)).astype(np.float32)
    result = drive_trace(model, req_x, ragged_trace_sizes(rows, max_batch, rng),
                         max_batch=max_batch, min_bucket=min_bucket)
    result.update(dim=dim, n_classes=model.n_classes)
    if top_k:
        n_sample = min(64, rows)
        ids, vals = top_k_labels(model, req_x[:n_sample], k=top_k)
        probs = predict_proba(model, req_x[:n_sample])
        direct = predict_labels(model, req_x[:n_sample])
        assert (np.asarray(ids[:, 0]) == np.asarray(direct)).all(), \
            "top-1 of top_k_labels diverged from predict_labels"
        p_np = np.asarray(probs)
        assert np.allclose(p_np.sum(axis=1), 1.0, atol=1e-5)
        result.update(top_k=int(top_k),
                      top1_prob_mean=round(float(p_np.max(axis=1).mean()), 4))
        if verbose:
            head = [(np.asarray(ids[i]).tolist(),
                     np.round(np.asarray(vals[i]), 3).tolist(),
                     round(float(p_np[i].max()), 3))
                    for i in range(min(3, n_sample))]
            print(f"[serve] top-{top_k} sample (ids, scores, p_top1): {head}; "
                  f"mean top-1 prob {result['top1_prob_mean']}; "
                  f"rank 1 == argmax labels (bitwise)")
    if verbose:
        print(f"[serve] {result['rows']} rows in {result['requests']} "
              f"requests -> "
              f"{result['microbatches']} microbatches "
              f"(buckets {result['bucket_counts']}, "
              f"{result['padded_rows']} pad rows)")
        print(f"[serve] {result['rows_per_s']} rows/s; batch latency "
              f"p50={result['p50_ms']} ms p99={result['p99_ms']} ms; "
              f"queue == direct predict (bitwise)")
    return result


def serve_svm_live(*, gamma: float = 0.5, bank_dtype: str | None = None,
                   n_classes: int = 4, budget: int = 32, dim: int = 16,
                   train_rows: int = 4096, chunk_rows: int = 512,
                   epochs: int = 2, publish_every: int = 2,
                   rows: int = 4096, max_batch: int = 64,
                   min_bucket: int = 8, seed: int = 0,
                   verbose: bool = True, faults=None, retry=None,
                   ckpt_dir: str | None = None, ckpt_every: int = 0,
                   max_restarts: int = 2, report=None) -> dict:
    """Train-while-serve: a background trainer hot-swaps the model mid-trace.

    The ``--live`` arm — the pipeline PR's end-to-end artifact as one driver:
    ``fit_multiclass_stream(bank=..., publish_every=...)`` runs on a
    background thread (prefetched chunk staging on its own worker),
    publishing an immutable ``ServeModel`` snapshot into a ``ModelBank``
    every K chunks, while the foreground replays a ragged request trace
    through an ``AsyncBatchQueue`` built over the bank — every published
    version is picked up at the next microbatch launch, no drain, no pause.
    Returns the serve stats dict plus the version histogram
    (``versions: {version: microbatches}``) proving the hot-swap happened
    mid-trace, and re-runs the trace against the FINAL snapshot for the
    usual bitwise parity gate.

    Resilience (DESIGN.md §16): ``faults`` (a ``data.FaultSchedule``) wraps
    the chunk source in ``FaultyChunks`` and arms the full recovery stack —
    retries (``retry`` defaults to ``RetryPolicy()``), the non-finite
    publish guard, and checkpointing (``ckpt_dir`` defaults to a tempdir,
    ``ckpt_every`` to ``publish_every``).  A SUPERVISOR wraps the trainer:
    a crash leaves serving up on the last published bank version and
    restarts the trainer (up to ``max_restarts``), which resumes from the
    latest *verifiable* checkpoint.  The final snapshot is asserted finite.
    The result dict then also carries ``restarts``/``retries``/
    ``quarantined``/``rollbacks`` from the shared ``ResilienceReport``.
    """
    import tempfile
    import threading

    from ..core import (MulticlassSVMConfig, ModelBank, drive_trace,
                        ragged_trace_sizes)
    from ..data import (ArrayChunks, FaultyChunks, ResilienceReport,
                        RetryPolicy, make_blobs_multiclass)

    cfg = MulticlassSVMConfig.create(
        n_classes, budget=budget, lambda_=1e-3, gamma=gamma,
        batch_size=min(64, chunk_rows))
    x, y = make_blobs_multiclass(jax.random.PRNGKey(seed), train_rows, dim,
                                 n_classes=n_classes, sep=2.5)
    source = ArrayChunks(np.asarray(x, np.float32),
                         np.asarray(y, np.int32), chunk_rows=chunk_rows)
    report = report if report is not None else ResilienceReport()
    tmp_ckpt = None
    if faults is not None:
        source = FaultyChunks(source, faults)
        retry = retry if retry is not None else RetryPolicy()
        if ckpt_dir is None:
            tmp_ckpt = tempfile.TemporaryDirectory(prefix="serve_live_ckpt_")
            ckpt_dir = tmp_ckpt.name
        if not ckpt_every:
            ckpt_every = publish_every
    bank = ModelBank()
    fail: list[BaseException] = []

    def trainer() -> None:
        from ..core import fit_multiclass_stream
        attempts = 0
        while True:
            try:
                fit_multiclass_stream(cfg, source, epochs=epochs, seed=seed,
                                      prefetch=2, bank=bank,
                                      publish_every=publish_every,
                                      publish_dtype=bank_dtype,
                                      ckpt_dir=ckpt_dir,
                                      ckpt_every=ckpt_every, retry=retry,
                                      report=report,
                                      guard_finite=faults is not None)
                return
            except BaseException as e:  # noqa: BLE001 — supervised
                attempts += 1
                if attempts > max_restarts:
                    fail.append(e)   # re-raised on the main thread
                    return
                # serving stays up on the last published version; the next
                # attempt resumes from the latest verifiable checkpoint
                report.note_restart()
                if verbose:
                    print(f"[serve --live] trainer crashed ({e!r}); "
                          f"restart {attempts}/{max_restarts} from "
                          f"checkpoint")

    t = threading.Thread(target=trainer, daemon=True, name="live-trainer")
    t.start()
    try:
        bank.wait(1, timeout=120.0)           # first snapshot before serving
        rng = np.random.default_rng(seed)
        req_x = rng.standard_normal((rows, dim)).astype(np.float32)
        result = drive_trace(bank, req_x,
                             ragged_trace_sizes(rows, max_batch, rng),
                             max_batch=max_batch, min_bucket=min_bucket,
                             queue="async")
        t.join(timeout=300.0)
        if fail:
            raise RuntimeError("background trainer failed past "
                               f"{max_restarts} restarts") from fail[0]
        _, final_model = bank.current()
        for name in ("sv_x", "alpha"):
            leaf = jnp.asarray(getattr(final_model, name), jnp.float32)
            if not bool(jnp.all(jnp.isfinite(leaf))):
                raise AssertionError(
                    f"published ServeModel.{name} contains non-finite "
                    "values — the publish guard failed")
    finally:
        if tmp_ckpt is not None:
            tmp_ckpt.cleanup()
    result.update(dim=dim, n_classes=n_classes,
                  final_version=bank.version,
                  restarts=report.restarts,
                  retries=report.retries,
                  quarantined=report.quarantined_chunks(),
                  rollbacks=len(report.rollbacks))
    if verbose:
        print(f"[serve --live] {result['rows']} rows while training "
              f"({result['microbatches']} microbatches); versions served: "
              f"{result['versions']} (final v{bank.version})")
        print(f"[serve --live] {result['rows_per_s']} rows/s; "
              f"p50={result['p50_ms']} ms p99={result['p99_ms']} ms; "
              f"pad waste {result['pad_waste_frac']}")
        if faults is not None:
            print(f"[serve --live] resilience: {report!r}; final snapshot "
                  "finite (guarded publish)")
    return result


def _cache_compatible(cache, pf_cache) -> bool:
    try:
        return (pf_cache is not None and
                jax.tree.structure(cache) == jax.tree.structure(pf_cache))
    except Exception:  # noqa: BLE001
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    # svm_bsgd arm
    ap.add_argument("--model", default=None, metavar="CKPT_DIR",
                    help="svm_bsgd: checkpoint directory to serve "
                         "(fit_stream / fit_multiclass_stream layout)")
    ap.add_argument("--gamma", type=float, default=0.5,
                    help="svm_bsgd: RBF width the model was trained with")
    ap.add_argument("--bank-dtype", default=None,
                    choices=(None, "float32", "bfloat16"),
                    help="svm_bsgd: quantize the served SV bank")
    ap.add_argument("--rows", type=int, default=4096,
                    help="svm_bsgd: total request rows in the trace")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="svm_bsgd: microbatch rows per fused predict call")
    ap.add_argument("--top-k", type=int, default=None, metavar="K",
                    help="svm_bsgd: also serve the K best class ids + "
                         "calibrated softmax probabilities (sampled; rank 1 "
                         "re-asserted bitwise against the argmax labels)")
    ap.add_argument("--live", action="store_true",
                    help="svm_bsgd: train-while-serve — a background "
                         "fit_multiclass_stream publishes snapshots into a "
                         "ModelBank every K chunks while an AsyncBatchQueue "
                         "serves the trace, hot-swapping mid-flight")
    ap.add_argument("--publish-every", type=int, default=2, metavar="K",
                    help="svm_bsgd --live: chunks between snapshots")
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="svm_bsgd --live: chaos drill — inject the seeded "
                         "FaultSchedule.chaos(SEED) (transient IO errors, "
                         "stalls, a NaN chunk, a fatal chunk, a trainer "
                         "crash) and run the full recovery stack: retries, "
                         "quarantine, guarded publish, checkpointed "
                         "supervisor restart")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arch == "svm_bsgd" and args.live:
        faults = None
        if args.faults is not None:
            from ..data import FaultSchedule
            faults = FaultSchedule.chaos(args.faults, nan_chunk=2,
                                         crash_chunk=3, fatal_chunk=5)
        kw = dict(rows=1024, train_rows=2048, chunk_rows=256,
                  epochs=1) if args.smoke else {}
        serve_svm_live(gamma=args.gamma, bank_dtype=args.bank_dtype,
                       publish_every=args.publish_every, seed=args.seed,
                       faults=faults, **kw)
        return
    if args.arch == "svm_bsgd":
        kw = {}
        if args.smoke:
            # default the top-k drive only for the in-process 4-class model;
            # --model may point at a binary (or 2-class) checkpoint where an
            # unasked-for top_k=3 would be an error
            kw = dict(rows=1024, max_batch=64, budget=32, train_rows=1024,
                      n_classes=4, bank_dtype=args.bank_dtype or "bfloat16",
                      top_k=args.top_k or (None if args.model else 3))
        serve_svm(model_dir=args.model, gamma=args.gamma, seed=args.seed,
                  **(kw if args.smoke else
                     dict(rows=args.rows, max_batch=args.max_batch,
                          bank_dtype=args.bank_dtype, top_k=args.top_k)))
        return
    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    with make_host_mesh():
        serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
