import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

MUST be executed as its own process (``python -m repro.launch.dryrun ...``):
the first two lines force 512 host-platform devices BEFORE any jax import so
``jax.make_mesh((2,16,16))`` can build the production mesh.  Never import
this module from tests/benchmarks — they must see 1 device.

Per cell it prints/records:
  * ``compiled.memory_analysis()``  — bytes per device (does it fit HBM)
  * ``compiled.cost_analysis()``    — FLOPs / bytes for §Roofline
  * parsed collective-bytes breakdown + the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch deepseek_v3_671b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--strategy fsdp]
  python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import SHAPES, all_cells, get, registry
from . import roofline as rl
from .mesh import make_production_mesh
from .steps import lower_cell


def choose_strategy(cfg, shape_name: str, strategy: str) -> str:
    if strategy != "auto":
        return strategy
    n = cfg.param_count()
    if SHAPES[shape_name]["step"] == "train":
        # ZeRO-3/FSDP once params+grads+Adam can't fit under pure TP
        return "fsdp" if n > 8e9 else "tp"
    # inference: 16-way TP leaves 2N/16 bytes of weights per device; beyond
    # ~60B params that alone blows the 16 GiB HBM -> 2-D (256-way) sharding
    return "fsdp" if n > 60e9 else "tp"


def run_svm_cell(*, multi_pod: bool, method: str = "lookup-wd",
                 out_dir: str | None = None, budget: int = 16384,
                 dim: int = 1024, batch: int = 8192, verbose=True,
                 layout: str = "replicated", n_classes: int = 8,
                 stream_steps: int = 0, step: str = "train",
                 maintenance_engine: str = "xla",
                 step_engine: str = "composed",
                 solver: str = "bsgd", maintenance: str = "merge") -> dict:
    """The paper-technique cell: distributed minibatch BSGD on the mesh.

    ``stream_steps > 0`` lowers the streaming-epoch chunk program (one
    resident chunk = a ``stream_steps``-minibatch donated-state scan) instead
    of the single-step cell.  ``step="predict"`` lowers the serving cell
    (fused scoring on the exported bank, ``layout="serve"`` sharding).
    ``maintenance_engine="pallas"`` lowers the fused maintenance-event
    engine (sorted-excess schedule over the class-sharded state).
    ``step_engine="pallas"`` lowers the fused train-step megakernel
    (margin + insert + event rounds in one launch chain per class block).
    ``solver="bdca"`` lowers the dual coordinate-ascent step (``core.bdca``)
    through the same layouts (implies the kernel cache).  ``maintenance``
    selects the drain strategy (``removal-project``/``quantized`` imply the
    cache; invalid engine combinations are rejected by config validation)."""
    from ..core.distributed import lower_svm_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, cfg = lower_svm_cell(mesh, budget=budget, dim=dim, batch=batch,
                                  method=method, layout=layout,
                                  n_classes=n_classes,
                                  stream_steps=stream_steps, step=step,
                                  maintenance_engine=maintenance_engine,
                                  step_engine=step_engine, solver=solver,
                                  maintenance=maintenance)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    # model flops: the useful work is the (batch x slots x dim) kernel matrix
    # — times n_classes for the fused all-class contraction (layout="class")
    model_flops = 2.0 * batch * (budget + batch) * dim
    if layout == "class":
        model_flops *= n_classes
    if stream_steps > 0:
        model_flops *= stream_steps
    rec = rl.analyze(compiled, arch=f"svm_bsgd_{method}", shape=f"b{budget}",
                     mesh=mesh,
                     strategy="serve" if step == "predict" else layout,
                     model_flops_global=model_flops)
    result = rec.to_json()
    result.update(lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                  multi_pod=multi_pod)
    if verbose:
        print(f"[dryrun] svm_bsgd({method}) budget={budget} dim={dim} "
              f"batch={batch} mesh={rec.mesh}")
        print(f"  mem: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB/dev")
        print(f"  roofline: compute={rec.compute_s*1e3:.2f}ms "
              f"memory={rec.memory_s*1e3:.2f}ms "
              f"collective={rec.collective_s*1e3:.2f}ms dominant={rec.dominant} "
              f"useful={rec.useful_ratio:.2f} frac={rec.roofline_frac:.3f}")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"svm_bsgd_{method}.b{budget}.{'pod2' if multi_pod else 'pod1'}.{layout}"
        if stream_steps > 0:
            tag += f".stream{stream_steps}"
        if step == "predict":
            tag += ".predict"
        if maintenance != "merge":
            tag += f".{maintenance}"
        if maintenance_engine != "xla":
            tag += f".{maintenance_engine}"
        if step_engine != "composed":
            tag += ".fusedstep"
        if solver != "bsgd":
            tag += f".{solver}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return result


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, strategy: str,
             out_dir: str | None = None, verbose: bool = True,
             cfg_overrides: dict | None = None, tag_suffix: str = "") -> dict:
    import dataclasses
    cfg = get(arch)
    # Single-pod runs unroll layer groups so cost_analysis counts every layer
    # (XLA counts while bodies once — see lm.forward).  The multi-pod pass
    # proves the pod-axis sharding compiles and keeps the scan (fast compile).
    overrides = {"scan_unroll": not multi_pod}
    overrides.update(cfg_overrides or {})
    cfg = dataclasses.replace(cfg, **overrides)
    strat = choose_strategy(cfg, shape_name, strategy)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, plan = lower_cell(cfg, shape_name, mesh, strategy=strat)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    rec = rl.analyze(compiled, arch=arch, shape=shape_name, mesh=mesh,
                     strategy=strat,
                     model_flops_global=rl.model_flops(cfg, shape_name, SHAPES),
                     act_bytes=rl.act_bytes_estimate(
                         cfg, shape_name, SHAPES, mesh.shape["data"]))
    result = rec.to_json()
    result.update(lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                  multi_pod=multi_pod)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={rec.mesh} strat={strat}")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB per device "
              f"(fits 16GiB HBM: {rec.fits_hbm})")
        print(f"  cost_analysis: flops/dev={rec.flops_per_dev:.3e} "
              f"bytes/dev={rec.bytes_per_dev:.3e}")
        print(f"  collectives/dev: {rec.coll_breakdown}")
        print(f"  roofline: compute={rec.compute_s*1e3:.2f}ms "
              f"memory={rec.memory_s*1e3:.2f}ms "
              f"collective={rec.collective_s*1e3:.2f}ms "
              f"dominant={rec.dominant} useful={rec.useful_ratio:.2f} "
              f"frac={rec.roofline_frac:.3f}")
        print(f"  lower={t_lower:.1f}s compile={t_compile:.1f}s")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}.{shape_name}.{'pod2' if multi_pod else 'pod1'}.{strat}{tag_suffix}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "tp", "fsdp"])
    ap.add_argument("--svm-method", default="lookup-wd",
                    help="solver for the svm_bsgd cell")
    ap.add_argument("--svm-layout", default="replicated",
                    choices=["replicated", "slots", "class"])
    ap.add_argument("--svm-classes", type=int, default=8,
                    help="n_classes for --svm-layout=class")
    ap.add_argument("--svm-stream-steps", type=int, default=0,
                    help="> 0: lower the streaming chunk program (a "
                         "stream-steps-minibatch donated-state scan)")
    ap.add_argument("--svm-step", default="train",
                    choices=["train", "predict"],
                    help="predict: lower the serving cell (fused scoring on "
                         "the exported bank, layout='serve' sharding)")
    ap.add_argument("--svm-engine", default="xla",
                    choices=["xla", "pallas"],
                    help="pallas: lower the fused maintenance-event engine "
                         "(kernel cache + sorted-excess event rounds)")
    ap.add_argument("--svm-step-engine", default="composed",
                    choices=["composed", "pallas"],
                    help="pallas: lower the fused train-step megakernel "
                         "(margin + insert + event rounds, one launch chain)")
    ap.add_argument("--svm-solver", default="bsgd",
                    choices=["bsgd", "bdca"],
                    help="bdca: lower the dual coordinate-ascent step "
                         "(core.bdca; implies the kernel cache)")
    ap.add_argument("--svm-maintenance", default="merge",
                    choices=["merge", "multi-merge", "removal",
                             "removal-project", "quantized"],
                    help="drain strategy for the svm_bsgd cell "
                         "(removal-project/quantized imply the kernel "
                         "cache; engine mismatches are config errors)")
    ap.add_argument("--seq-shard-attn", action="store_true",
                    help="context-parallel attention (hillclimb variant)")
    ap.add_argument("--keep-scan", action="store_true",
                    help="lower the scanned form even single-pod (fast "
                         "compile proof; cost_analysis undercounts scan "
                         "bodies — roofline flops derived analytically)")
    ap.add_argument("--tag-suffix", default="",
                    help="suffix for the output json tag")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    overrides = {}
    if args.seq_shard_attn:
        overrides["seq_shard_attn"] = ("pod", "data") if args.multi_pod else ("data",)
    if args.keep_scan:
        overrides["scan_unroll"] = False

    assert len(jax.devices()) == 512, "dryrun must own the 512-device env"

    if args.arch == "svm_bsgd":
        run_svm_cell(multi_pod=args.multi_pod, method=args.svm_method,
                     out_dir=args.out, layout=args.svm_layout,
                     n_classes=args.svm_classes,
                     stream_steps=args.svm_stream_steps, step=args.svm_step,
                     maintenance_engine=args.svm_engine,
                     step_engine=args.svm_step_engine,
                     solver=args.svm_solver,
                     maintenance=args.svm_maintenance)
        return

    failures = []
    if args.all:
        for arch, shape, ok, reason in all_cells():
            if args.arch and arch != args.arch:
                continue
            if not ok:
                print(f"[dryrun] SKIP {arch} x {shape}: {reason}")
                continue
            try:
                run_cell(arch, shape, multi_pod=args.multi_pod,
                         strategy=args.strategy, out_dir=args.out)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                traceback.print_exc()
                failures.append((arch, shape, str(e)))
        if failures:
            print(f"[dryrun] {len(failures)} FAILURES: {failures}")
            raise SystemExit(1)
        print("[dryrun] all cells compiled OK")
    else:
        cfg_ok, reason = registry.cell_applicable(get(args.arch), args.shape)
        if not cfg_ok:
            print(f"[dryrun] cell not applicable: {reason}")
            return
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 strategy=args.strategy, out_dir=args.out,
                 cfg_overrides=overrides, tag_suffix=args.tag_suffix)


if __name__ == "__main__":
    main()
