"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation ever happens here: shapes/dtypes only, weak-type-correct
and shardable.  ``abstract_state`` builds the params / optimizer / cache
abstract trees the dry-run lowers against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import SHAPES
from ..models import init_cache, init_lm
from ..train.optimizer import AdamW


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg, shape_name: str):
    """Abstract training/serving batch for one shape cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    step = sh["step"]
    if step == "decode":
        return {"tokens": sds((b, 1), jnp.int32)}
    if cfg.input_kind == "frames":
        spec = {"frames": sds((b, s, cfg.frame_dim), jnp.dtype(cfg.dtype))}
        if step == "train":
            spec["labels"] = sds((b, s), jnp.int32)
            spec["mask"] = sds((b, s), jnp.bool_)
        return spec
    spec = {"tokens": sds((b, s), jnp.int32)}
    if step == "train":
        spec["labels"] = sds((b, s), jnp.int32)
        spec["mask"] = sds((b, s), jnp.float32)
    return spec


def svm_chunk_specs(dim: int, chunk_steps: int, batch_size: int, *,
                    n_classes: int | None = None, x_dtype="float32",
                    y_dtype="float32"):
    """Abstract streamed chunk for the SVM cells: ``(steps, batch, ...)``.

    The streaming engine feeds ONE chunk-sized program per resident chunk
    (``core.distributed.make_distributed_chunk_step``); this is its abstract
    input — x as ``(chunk_steps, batch, dim)`` in the SV storage dtype
    (``cfg.sv_dtype or cfg.dtype``), y as ``(chunk_steps, batch)`` (float ±1
    targets in ``cfg.dtype`` for binary, int32 class ids when ``n_classes``
    is set).  The launch stream test pins this against the chunk program's
    real abstract arguments.
    """
    return {
        "xc": sds((chunk_steps, batch_size, dim), jnp.dtype(x_dtype)),
        "yc": sds((chunk_steps, batch_size),
                  jnp.int32 if n_classes else jnp.dtype(y_dtype)),
    }


def svm_serve_specs(dim: int, batch: int, slots: int, *,
                    n_classes: int | None = None, bank_dtype="bfloat16"):
    """Abstract serving inputs for the SVM predict cell.

    The serve cell scores a ``(batch, dim)`` float32 request block against an
    exported ``(C, slots, dim)`` bank in ``bank_dtype`` with fp32 alphas
    (``core.predict.ServeModel``); ``n_classes=None`` is the binary C = 1
    bank.  The launch serve test pins this against
    ``make_distributed_predict``'s real abstract arguments.
    """
    c = 1 if n_classes is None else n_classes
    return {
        "sv_x": sds((c, slots, dim), jnp.dtype(bank_dtype)),
        "alpha": sds((c, slots), jnp.float32),
        "count": sds((c,), jnp.int32),
        "gamma": sds((), jnp.float32),
        "x": sds((batch, dim), jnp.float32),
    }


def abstract_params(cfg):
    """(params, axes) with ShapeDtypeStruct leaves (axes tree is concrete —
    ``Axes`` markers are static objects created during tracing)."""
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg)[0], key)
    return shapes, _axes_only(cfg)


def _axes_only(cfg):
    holder = {}

    def grab(k):
        params, axes = init_lm(k, cfg)
        holder["axes"] = axes
        return params

    jax.eval_shape(grab, jax.random.PRNGKey(0))
    return holder["axes"]


def abstract_opt_state(cfg, params_shapes):
    opt = AdamW()
    return jax.eval_shape(opt.init, params_shapes)


def abstract_cache(cfg, shape_name: str):
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    return jax.eval_shape(lambda: init_cache(cfg, b, s))
