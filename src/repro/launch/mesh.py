"""Production meshes.  Functions, not module constants, so importing this
module never touches jax device state (smoke tests must keep seeing 1 CPU)."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    # older jax (< 0.5) has no AxisType; plain meshes are Auto already
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading pure-DP pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now, as a 1-D data mesh (CPU tests)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
