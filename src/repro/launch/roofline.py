"""Three-term roofline analysis from the compiled dry-run artifact.

TPU v5e constants (the target, not the runtime):
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI               : ~50 GB/s per link

Terms (per assignment):
    compute_s    = HLO_FLOPs / peak            (cost_analysis is per-device
                                                for an SPMD executable)
    memory_s     = HLO_bytes / HBM_bw
    collective_s = collective_bytes / link_bw  (parsed from the partitioned
                                                HLO text — per-device shapes)

``MODEL_FLOPS`` bookkeeping uses 6*N*D (dense) / 6*N_active*D (MoE) for train
and 2*N*D for inference, so the ``useful-flops ratio`` exposes remat /
dispatch-overhead waste in the compiled module.
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link
HBM_BYTES = 16 * 1024**3   # v5e HBM per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"%([\w.-]+) = \(?(\w+)\[([0-9,]*)\]")
_OPND_RE = re.compile(r"%([\w.-]+)")
# ops whose operands/results actually stream HBM on TPU (elementwise chains
# fuse into these); used for the fusion-aware memory proxy.
_HBM_OPS = (" dot(", " convolution(", " gather(", " scatter(", " sort(",
            " dynamic-update-slice(", " reduce(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective instruction, by op kind.

    In the SPMD-partitioned module shapes are per-device; async pairs are
    counted once (the ``-start`` op).  For all-reduce / all-to-all /
    collective-permute the result size equals the operand size; for
    all-gather it is the post-gather size and for reduce-scatter the
    pre-reduce size is result * group — we report result bytes (the wire
    traffic of ring algorithms is within 2x of this; constants noted in
    EXPERIMENTS.md).
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLLECTIVES:
            # match `op(`, `op-start(` but not `-done(`
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                lhs = stripped.split(f" {op}", 1)[0]
                total = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(lhs))
                out[op] = out.get(op, 0) + total
                break
    return out


def fused_bytes(hlo_text: str, arg_bytes: float, out_bytes: float) -> float:
    """Fusion-aware HBM-traffic proxy for the TPU target.

    The CPU backend's ``bytes accessed`` counts every unfused intermediate
    (20-30x what a TPU module would stream).  TPU fuses elementwise chains
    into their matmul/reduce producers, so we approximate HBM traffic as
    (operands + result) of dot/conv/gather/scatter/sort/reduce instructions
    plus one read of the entry arguments and one write of the outputs.
    Reported next to the raw value; the raw value is the upper bound.
    """
    shape_of: dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shape_of[m.group(1)] = _shape_bytes(m.group(2), m.group(3))
    total = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not any(op in s for op in _HBM_OPS):
            continue
        md = _DEF_RE.search(s)
        if md:
            total += _shape_bytes(md.group(2), md.group(3))   # result
        # operand reads (names resolved via the def map)
        args = s.split("(", 2)
        if len(args) >= 2:
            for om in _OPND_RE.finditer(args[-1].split(")", 1)[0]):
                total += shape_of.get(om.group(1), 0)
    return float(total) + arg_bytes + out_bytes


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    strategy: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float           # fusion-aware proxy (see fused_bytes)
    bytes_per_dev_raw: float       # CPU-backend 'bytes accessed' (upper bound)
    coll_bytes_per_dev: float
    coll_breakdown: dict
    peak_mem_per_dev: float        # CPU buffer-assignment temp (pessimistic:
                                   # CPU liveness != TPU; see EXPERIMENTS.md)
    arg_bytes_per_dev: float
    act_bytes_est: float = 0.0     # analytic activation estimate (TPU model)
    model_flops_global: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    fits_hbm: bool = True
    step_s: float = 0.0
    roofline_frac: float = 0.0

    def finalize(self):
        self.compute_s = self.flops_per_dev / PEAK_FLOPS
        self.memory_s = self.bytes_per_dev / HBM_BW
        self.collective_s = self.coll_bytes_per_dev / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        hlo_global = self.flops_per_dev * self.n_devices
        self.useful_ratio = (self.model_flops_global / hlo_global
                             if hlo_global else 0.0)
        # fit decided on args + analytic activations: CPU temp is an artifact
        # of CPU buffer liveness, reported but not used for the verdict.
        self.fits_hbm = (self.act_bytes_est + self.arg_bytes_per_dev) <= HBM_BYTES
        # overlap model: compute overlaps with memory AND collectives at best
        self.step_s = max(terms.values())
        ideal_s = self.model_flops_global / (self.n_devices * PEAK_FLOPS)
        self.roofline_frac = ideal_s / self.step_s if self.step_s else 0.0
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def act_bytes_estimate(cfg, shape_name: str, shapes: dict, n_data_shards: int) -> float:
    """Per-device activation memory under the TPU deployment model:
    bf16 remat residual stash (one checkpoint per layer) for train, an
    8x-residual transient for prefill, negligible for decode."""
    sh = shapes[shape_name]
    tokens_dev = sh["global_batch"] * sh["seq_len"] / n_data_shards
    resid = tokens_dev * cfg.d_model * 2
    if sh["step"] == "train":
        return float(cfg.n_layers * resid + 8 * resid)
    if sh["step"] == "prefill":
        return float(8 * resid)
    return float(2 * cfg.d_model * sh["global_batch"] * 8)


def analyze(compiled, *, arch: str, shape: str, mesh, strategy: str,
            model_flops_global: float, hlo_text: str | None = None,
            act_bytes: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):       # newer jax: one dict per program
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    arg_b = float(mem.argument_size_in_bytes)
    out_b = float(mem.output_size_in_bytes)
    r = Roofline(
        arch=arch, shape=shape, mesh="x".join(map(str, mesh.shape.values())),
        strategy=strategy, n_devices=n_dev,
        flops_per_dev=float(cost.get("flops", 0.0)),
        bytes_per_dev=fused_bytes(text, arg_b, out_b),
        bytes_per_dev_raw=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(sum(coll.values())),
        coll_breakdown=coll,
        peak_mem_per_dev=float(mem.temp_size_in_bytes + mem.output_size_in_bytes),
        arg_bytes_per_dev=arg_b,
        act_bytes_est=act_bytes,
        model_flops_global=model_flops_global,
    )
    return r.finalize()


def model_flops(cfg, shape_name: str, shapes: dict) -> float:
    """6*N_active*tokens for train, 2*N_active*tokens for inference."""
    sh = shapes[shape_name]
    n = cfg.active_param_count()
    if sh["step"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n * tokens
    if sh["step"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n * tokens
    tokens = sh["global_batch"]  # one new token per sequence
    return 2.0 * n * tokens


def save_record(rec: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(rec.to_json(), f, indent=2)
