"""Jittable step functions + their sharding plans for train / prefill / decode.

``plan_cell`` is the single source of truth the dry-run, the trainer and the
server all use: given (cfg, shape, mesh, strategy) it returns the step
callable, abstract arguments, and in/out shardings — so what we dry-run is
exactly what would launch on hardware.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES
from ..models import decode_step, encode_step, loss_fn, prefill
from ..sharding import specs as sh
from ..train.optimizer import AdamW
from . import inputs as inp


@dataclasses.dataclass
class CellPlan:
    step_fn: Callable
    args: tuple                 # abstract args (ShapeDtypeStructs)
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    kind: str = "train"


def make_train_step(cfg, optimizer=None):
    optimizer = optimizer or AdamW()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return train_step


def make_decode_fn(cfg):
    def serve_step(params, cache, tokens, cache_pos):
        return decode_step(cfg, params, cache, tokens, cache_pos)
    return serve_step


def make_prefill_fn(cfg):
    if cfg.is_encoder:
        def encode(params, batch):
            return encode_step(cfg, params, batch)
        return encode

    def prefill_fn(params, batch):
        return prefill(cfg, params, batch["tokens"])
    return prefill_fn


def plan_cell(cfg, shape_name: str, mesh, *, strategy: str = "tp",
              optimizer=None) -> CellPlan:
    step = SHAPES[shape_name]["step"]
    params_s, axes = inp.abstract_params(cfg)
    p_shard = sh.param_shardings(axes, params_s, mesh, strategy)
    batch_s = inp.batch_specs(cfg, shape_name)
    b_shard = sh.to_shardings(sh.batch_spec(mesh, batch_s), mesh)
    repl = NamedSharding(mesh, P())

    if step == "train":
        optimizer = optimizer or AdamW()
        opt_s = inp.abstract_opt_state(cfg, params_s)
        # moments mirror param shardings; step counter replicated
        opt_shard = type(opt_s)(
            step=repl,
            m=jax.tree.map(lambda _, s: s, opt_s.m, p_shard),
            v=jax.tree.map(lambda _, s: s, opt_s.v, p_shard))
        fn = make_train_step(cfg, optimizer)
        return CellPlan(
            step_fn=fn, args=(params_s, opt_s, batch_s),
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, repl),
            donate_argnums=(0, 1), kind="train")

    if step == "prefill":
        fn = make_prefill_fn(cfg)
        if cfg.is_encoder:
            # encoder output: logits (B, S, V) batch-sharded
            out = NamedSharding(mesh, P(sh.dp_axes(mesh), None, None))
        else:
            cache_s = jax.eval_shape(
                lambda p, b: fn(p, b)[1], params_s, batch_s)
            cache_shard = sh.to_shardings(
                sh.cache_specs(cache_s, mesh, policy="batch"), mesh)
            logits_shard = NamedSharding(mesh, P(sh.dp_axes(mesh), None))
            out = (logits_shard, cache_shard)
        return CellPlan(step_fn=fn, args=(params_s, batch_s),
                        in_shardings=(p_shard, b_shard),
                        out_shardings=out, kind="prefill")

    # decode: batch=1 long-context shards the cache over sequence instead
    policy = "sequence" if SHAPES[shape_name]["global_batch"] < mesh.shape["data"] \
        else "batch"
    cache_s = inp.abstract_cache(cfg, shape_name)
    cache_shard = sh.to_shardings(sh.cache_specs(cache_s, mesh, policy=policy),
                                  mesh)
    tok_shard = (NamedSharding(mesh, P(sh.dp_axes(mesh), None))
                 if policy == "batch" else repl)
    fn = make_decode_fn(cfg)
    logits_shard = tok_shard
    return CellPlan(
        step_fn=fn,
        args=(params_s, cache_s, inp.batch_specs(cfg, shape_name)["tokens"],
              jax.ShapeDtypeStruct((), jax.numpy.int32)),
        in_shardings=(p_shard, cache_shard, tok_shard, repl),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(1,), kind="decode")


def lower_cell(cfg, shape_name: str, mesh, *, strategy: str = "tp"):
    """AOT-lower one cell on ``mesh``; returns (lowered, plan)."""
    plan = plan_cell(cfg, shape_name, mesh, strategy=strategy)
    with mesh:
        jitted = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                         out_shardings=plan.out_shardings,
                         donate_argnums=plan.donate_argnums)
        lowered = jitted.lower(*plan.args)
    return lowered, plan
