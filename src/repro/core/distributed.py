"""Distributed minibatch BSGD: the paper's solver as a pjit'd program.

Parallel structure (DESIGN.md §3.5):
  * the minibatch is sharded over the data axes (pod, data) — each shard
    computes margins for its examples against the full SV set;
  * the SV set is sharded over the *model* axis along the budget dimension:
    the (batch, slots) kernel matrix contraction over features happens per
    shard, and the margin sum over SVs psums across model;
  * maintenance decisions (argmin over |alpha|, candidate scoring against
    the lookup table, the merge scatter) operate on the replicated-alpha
    view — cheap *because* the lookup made them cheap; with runtime GSS the
    sequential solver chain would serialize every replica (the paper's cost,
    amplified by scale).

Multi-class (``layout="class"``, DESIGN.md §8): the stacked one-vs-rest
state's leading ``(C,)`` axis shards over ``model`` — every device owns
whole classes, so per-class maintenance needs NO collective at all; the
minibatch shards over the data axes and all-gathers once into the fused
(batch, C * slots) kernel contraction.

``make_distributed_step`` returns (step_fn, in_shardings, out_shardings,
abstract args) — consumed by both the real trainer and the dry-run, so the
SVM cell is exercised on the production mesh exactly like the LM cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .bsgd import BSGDConfig, SVMState, train_step
from .lookup import MergeLookupTable
from .multiclass import MulticlassSVMConfig, train_step_multiclass
from .predict import ServeModel, predict_labels


def sv_shardings(cfg: BSGDConfig, mesh, dim: int, *, layout: str = "replicated"):
    """Shardings for SVMState + batch on the production mesh.

    layout="slots":       SV arrays sharded over `model` along the budget dim,
                          batch over (pod, data).  First/naive plan — GSPMD
                          reshards the SV state around the insert scatter and
                          maintenance argmin (all-gather heavy, see §Perf).
    layout="replicated":  SV state replicated (100 MB — trivially fits), batch
                          sharded over EVERY mesh axis (256/512-way).  The
                          kernel matrix needs no communication at all; the
                          only collective left is gathering the minibatch's
                          violator rows for the (replicated) insert.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if layout == "replicated":
        batch_axes = dp + ("model",)
        slot_axis = None
    else:
        batch_axes = dp
        slot_axis = "model" if cfg.slots % mesh.shape["model"] == 0 else None
    return SVMState(
        sv_x=NamedSharding(mesh, P(slot_axis, None)),
        alpha=NamedSharding(mesh, P(slot_axis)),
        count=NamedSharding(mesh, P()),
        step=NamedSharding(mesh, P()),
        n_inserts=NamedSharding(mesh, P()),
        n_merges=NamedSharding(mesh, P()),
        # The kernel cache rides the SV layout: rows sharded with the slots
        # axis (each shard owns its SVs' kappa rows), columns replicated.
        kmat=(NamedSharding(mesh, P(slot_axis, None))
              if cfg.use_kernel_cache else None),
    ), NamedSharding(mesh, P(batch_axes, None)), NamedSharding(mesh, P(batch_axes))


def multiclass_shardings(cfg: MulticlassSVMConfig, mesh):
    """``layout="class"`` shardings: classes over ``model``, batch over the
    data axes.  Requires ``n_classes`` divisible by the model-axis size
    (falls back to replicated classes otherwise)."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    cls = "model" if cfg.n_classes % mesh.shape["model"] == 0 else None
    state_sh = SVMState(
        sv_x=NamedSharding(mesh, P(cls, None, None)),
        alpha=NamedSharding(mesh, P(cls, None)),
        count=NamedSharding(mesh, P(cls)),
        step=NamedSharding(mesh, P(cls)),
        n_inserts=NamedSharding(mesh, P(cls)),
        n_merges=NamedSharding(mesh, P(cls)),
        kmat=(NamedSharding(mesh, P(cls, None, None))
              if cfg.binary.use_kernel_cache else None),
    )
    return (state_sh, NamedSharding(mesh, P(dp, None)),
            NamedSharding(mesh, P(dp)))


def _make_multiclass_step(cfg: MulticlassSVMConfig, mesh, dim: int,
                          table: MergeLookupTable | None):
    b = cfg.binary
    state_sh, x_sh, y_sh = multiclass_shardings(cfg, mesh)
    repl = NamedSharding(mesh, P())
    table_sh = (MergeLookupTable(h_table=repl, wd_table=repl)
                if table is not None else None)

    def step(state: SVMState, table, xb, yb):
        return train_step_multiclass(cfg, table, state, xb, yb, impl="ref")

    c = cfg.n_classes
    args = (
        SVMState(
            sv_x=jax.ShapeDtypeStruct((c, b.slots, dim),
                                      jnp.dtype(b.sv_dtype or b.dtype)),
            alpha=jax.ShapeDtypeStruct((c, b.slots), jnp.dtype(b.dtype)),
            count=jax.ShapeDtypeStruct((c,), jnp.int32),
            step=jax.ShapeDtypeStruct((c,), jnp.int32),
            n_inserts=jax.ShapeDtypeStruct((c,), jnp.int32),
            n_merges=jax.ShapeDtypeStruct((c,), jnp.int32),
            kmat=(jax.ShapeDtypeStruct((c, b.slots, b.slots), jnp.float32)
                  if b.use_kernel_cache else None)),
        (jax.eval_shape(lambda: table) if table is not None else None),
        jax.ShapeDtypeStruct((b.batch_size, dim),
                             jnp.dtype(b.sv_dtype or b.dtype)),
        jax.ShapeDtypeStruct((b.batch_size,), jnp.int32),
    )
    in_sh = (state_sh, table_sh, x_sh, y_sh)
    return step, args, in_sh, state_sh


def serve_shardings(mesh, *, binary: bool = False):
    """``layout="serve"``: the exported bank replicated per device, the
    request batch sharded over EVERY mesh axis.

    The serving contract (DESIGN.md §10): each device scores its request
    shard against its own full copy of the (C, slots, dim) bank, the
    per-class contraction and the argmax stay local, and the output labels
    inherit the batch sharding — ZERO collectives in the whole cell.  The
    bank is small by construction (the budget exists so it is), so
    replication is the right trade at serving batch sizes.
    Returns ``(model_shardings, x_sharding, labels_sharding)``.
    """
    repl = NamedSharding(mesh, P())
    batch_axes = mesh.axis_names          # e.g. ("data", "model")
    model_sh = ServeModel(sv_x=repl, alpha=repl, count=repl, gamma=repl,
                          binary=binary)
    return (model_sh, NamedSharding(mesh, P(batch_axes, None)),
            NamedSharding(mesh, P(batch_axes)))


def make_distributed_predict(mesh, *, dim: int, batch: int, slots: int,
                             n_classes: int | None = None,
                             bank_dtype="bfloat16"):
    """The fused serve cell on the production mesh.

    ``n_classes=None`` builds the binary cell (C = 1 bank, ±1 sign labels);
    otherwise the multiclass argmax cell.  Returns ``(predict_fn,
    args_abstract, in_shardings, out_sharding)`` with ``predict_fn(model, x)
    -> labels``; jit it with the shardings and hand it to ``BatchQueue`` as
    ``predict_fn`` (wrapped to close over the resident model) — the queue's
    bucket set then bounds the pjit cache exactly as on one device.
    """
    binary = n_classes is None
    c = 1 if binary else n_classes
    model_sh, x_sh, y_sh = serve_shardings(mesh, binary=binary)

    def predict_fn(model: ServeModel, x):
        return predict_labels(model, x, impl="ref")

    args = (
        ServeModel(
            sv_x=jax.ShapeDtypeStruct((c, slots, dim), jnp.dtype(bank_dtype)),
            alpha=jax.ShapeDtypeStruct((c, slots), jnp.float32),
            count=jax.ShapeDtypeStruct((c,), jnp.int32),
            gamma=jax.ShapeDtypeStruct((), jnp.float32),
            binary=binary),
        jax.ShapeDtypeStruct((batch, dim), jnp.float32),
    )
    return predict_fn, args, (model_sh, x_sh), y_sh


def make_distributed_step(cfg, mesh, dim: int,
                          table: MergeLookupTable | None = None,
                          layout: str = "replicated"):
    """(step_fn, args_abstract, in_shardings, out_shardings).

    ``cfg`` is a ``BSGDConfig`` for the binary layouts (``replicated`` /
    ``slots``) or a ``MulticlassSVMConfig`` for ``layout="class"``.
    """
    if layout == "class":
        if not isinstance(cfg, MulticlassSVMConfig):
            raise TypeError("layout='class' needs a MulticlassSVMConfig, got "
                            f"{type(cfg).__name__}")
        if table is None and cfg.binary.method.startswith("lookup"):
            table = cfg.table()
        return _make_multiclass_step(cfg, mesh, dim, table)
    if table is None and cfg.method.startswith("lookup"):
        table = cfg.table()
    state_sh, x_sh, y_sh = sv_shardings(cfg, mesh, dim, layout=layout)
    repl = NamedSharding(mesh, P())
    table_sh = (MergeLookupTable(h_table=repl, wd_table=repl)
                if table is not None else None)

    def step(state: SVMState, table, xb, yb):
        return train_step(cfg, table, state, xb, yb, impl="ref")

    args = (
        SVMState(
            sv_x=jax.ShapeDtypeStruct((cfg.slots, dim),
                                      jnp.dtype(cfg.sv_dtype or cfg.dtype)),
            alpha=jax.ShapeDtypeStruct((cfg.slots,), jnp.dtype(cfg.dtype)),
            count=jax.ShapeDtypeStruct((), jnp.int32),
            step=jax.ShapeDtypeStruct((), jnp.int32),
            n_inserts=jax.ShapeDtypeStruct((), jnp.int32),
            n_merges=jax.ShapeDtypeStruct((), jnp.int32),
            kmat=(jax.ShapeDtypeStruct((cfg.slots, cfg.slots), jnp.float32)
                  if cfg.use_kernel_cache else None)),
        (jax.eval_shape(lambda: table) if table is not None else None),
        jax.ShapeDtypeStruct((cfg.batch_size, dim),
                             jnp.dtype(cfg.sv_dtype or cfg.dtype)),
        jax.ShapeDtypeStruct((cfg.batch_size,), jnp.dtype(cfg.dtype)),
    )
    in_sh = (state_sh, table_sh, x_sh, y_sh)
    out_sh = state_sh
    return step, args, in_sh, out_sh


def make_distributed_chunk_step(cfg, mesh, dim: int, chunk_steps: int,
                                table: MergeLookupTable | None = None,
                                layout: str = "replicated"):
    """Per-chunk program for the streaming path on the production mesh.

    The streaming trainers (``core.bsgd.fit_stream`` /
    ``core.multiclass.fit_multiclass_stream``) run one jitted program per
    resident chunk; this builds that program's distributed form — a
    ``chunk_steps``-long scan of the same sharded ``train_step`` the per-batch
    cell uses, with the chunk arrays sharded like the per-step minibatch along
    their batch axis (``(steps, batch, dim)`` with batch over the data axes —
    or every axis for ``layout="replicated"`` — and the SV state sharded per
    ``layout``: ``replicated`` / ``slots`` / ``class``).  Returns
    ``(chunk_fn, args_abstract, in_shardings, out_shardings)`` with
    ``chunk_fn(state, table, xc, yc) -> state``; jit with
    ``donate_argnums=(0,)`` so the budgeted state updates in place while
    chunks stream through (``launch.train.svm_stream_loop`` is the driver).
    """
    step, args, in_sh, out_sh = make_distributed_step(cfg, mesh, dim, table,
                                                      layout=layout)
    state_abs, table_abs, xb_abs, yb_abs = args
    state_sh, table_sh, x_sh, y_sh = in_sh

    def chunk_fn(state, table, xc, yc):
        def body(st, xy):
            return step(st, table, xy[0], xy[1]), ()

        state, _ = jax.lax.scan(body, state, (xc, yc))
        return state

    cargs = (state_abs, table_abs,
             jax.ShapeDtypeStruct((chunk_steps,) + xb_abs.shape, xb_abs.dtype),
             jax.ShapeDtypeStruct((chunk_steps,) + yb_abs.shape, yb_abs.dtype))
    cin_sh = (state_sh, table_sh,
              NamedSharding(mesh, P(None, *x_sh.spec)),
              NamedSharding(mesh, P(None, *y_sh.spec)))
    return chunk_fn, cargs, cin_sh, out_sh


def lower_svm_cell(mesh, *, budget: int = 16384, dim: int = 1024,
                   batch: int = 8192, method: str = "lookup-wd",
                   layout: str = "replicated", n_classes: int = 8,
                   stream_steps: int = 0, step: str = "train",
                   maintenance_engine: str = "xla",
                   step_engine: str = "composed", solver: str = "bsgd",
                   maintenance: str = "merge"):
    """AOT-lower the production-scale BSGD cell (the paper-technique cell).

    Production sizing: budget 16k SVs, 1k features, 8k-example global
    minibatch — the regime where the kernel matrix (batch x slots) is real
    MXU work and merging fires every step.  ``layout="class"`` lowers the
    one-vs-rest multi-class cell instead (``n_classes`` stacked problems,
    classes sharded over ``model``).  ``stream_steps > 0`` lowers the
    streaming-epoch chunk program instead — the ``stream_steps``-minibatch
    scan one resident chunk runs as (``make_distributed_chunk_step``).
    ``step="predict"`` lowers the SERVING cell instead of a training step:
    the fused multiclass scoring program on the exported bfloat16 bank,
    bank replicated and the request batch sharded over every axis
    (``layout="serve"``; ``layout="class"`` here selects the multiclass
    bank, anything else the binary one) — the dryrun roofline for
    ``launch.serve --arch svm_bsgd``.  ``maintenance_engine="pallas"``
    lowers the fused maintenance-event engine instead of the vmapped
    per-class while loop (implies the kernel cache; the event rounds stay
    collective-free under ``layout="class"`` because every array they touch
    is sharded along the class axis).  ``step_engine="pallas"`` lowers the
    fused train-step megakernel (DESIGN.md §12) — the whole step is one
    launch chain per class block; under ``layout="class"`` every array the
    fused step touches (bank, alpha, cache, counters) stays sharded along
    the class axis and the cell adds NO collectives over the §11
    event-engine cell (identical collective breakdown in the dryrun — the
    shared all-gathers belong to the kernel-cache-carrying step, not the
    fusion).  ``solver="bdca"`` lowers the dual coordinate-ascent step
    (``core.bdca``) through the SAME layouts — it implies the kernel cache
    (the ascent reads cached Gram rows) and composes with
    ``maintenance_engine`` but not with ``step_engine="pallas"``.
    ``maintenance`` selects the strategy the cell drains through (any
    ``core.budget.STRATEGIES`` entry; ``removal-project``/``quantized``
    imply the kernel cache — their coefficients are cache reads — and only
    compose with the xla engines, which ``BSGDConfig`` validation enforces
    with a clear error rather than silently lowering the wrong program).
    """
    cfg = BSGDConfig(budget=budget, lambda_=1e-6, gamma=2.0**-7, method=method,
                     batch_size=batch, dtype="float32", sv_dtype="bfloat16",
                     use_kernel_cache=(solver == "bdca"
                                       or maintenance_engine == "pallas"
                                       or step_engine == "pallas"
                                       or maintenance in ("removal-project",
                                                          "quantized")),
                     maintenance=maintenance,
                     maintenance_engine=maintenance_engine,
                     step_engine=step_engine, solver=solver)
    if layout == "class":
        cfg = MulticlassSVMConfig(n_classes=n_classes, binary=cfg)
    if step == "predict":
        b = cfg.binary if layout == "class" else cfg
        fn, args, in_sh, out_sh = make_distributed_predict(
            mesh, dim=dim, batch=batch, slots=b.slots,
            n_classes=n_classes if layout == "class" else None,
            bank_dtype=b.sv_dtype or b.dtype)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
        return lowered, cfg
    table = cfg.table()
    if stream_steps > 0:
        step, args, in_sh, out_sh = make_distributed_chunk_step(
            cfg, mesh, dim, stream_steps, table, layout=layout)
    else:
        step, args, in_sh, out_sh = make_distributed_step(cfg, mesh, dim,
                                                          table, layout=layout)
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=(0,)).lower(*args)
    return lowered, cfg
