"""Single-pass online evaluation: prequential (test-then-train) streaming.

The workload the paper's budget maintenance was designed for, made a
first-class driver: one pass over a chunk stream in which every chunk is
first SCORED by the current model (those predictions are the online
record — the model has never seen the rows) and then TRAINED on.  The
cumulative mistake count over the pass is the standard prequential error
(the regret readout of the online-learning literature: mistakes of the
online learner on an adversarially-revealed sequence); the per-chunk
accuracy trace localizes *where* a model loses it — e.g. right after a
drift point injected by ``data.stream.DriftChunks``.

Two deliberate contract points, pinned by tests/core/test_online.py:

  * chunks are visited in NATURAL order by default (``key=None``) — a
    shuffled pass would average any drift schedule away; pass a key only
    for stationary streams;
  * the whole pass is deterministic given the chunk source and seed: the
    driver introduces no randomness of its own (scoring is pure, training
    consumes batches in stream order), so two runs agree bitwise.

Each chunk trains through the donated per-chunk program
(``bsgd.train_chunk`` / ``multiclass.train_chunk_multiclass``) on its
batch-aligned prefix; the up-to-``batch_size - 1`` remainder rows of a
chunk are scored but not trained (chunk-grained prequential — benchmarks
size chunks as multiples of the batch so nothing is dropped).  A cold
binary model scores ``sign(0) = 0`` (the repo's ``predict`` convention)
and pays a full mistake on every first-chunk row — the same handicap for
every strategy, so matched comparisons are unaffected.
"""
from __future__ import annotations

import jax
import numpy as np

from .bsgd import BSGDConfig, init_state, predict, train_chunk
from .multiclass import (MulticlassSVMConfig, init_multiclass_state,
                         predict_multiclass, train_chunk_multiclass)
from ..data.stream import iter_epoch


def prequential_stream(cfg, source, *, key=None, impl: str = "auto",
                       state=None, prefetch: int = 0, retry=None,
                       report=None, skip_chunks=()) -> dict:
    """One prequential pass: score each chunk, then train on it.

    ``cfg`` is a binary ``BSGDConfig`` (labels in {-1, +1}) or a
    ``MulticlassSVMConfig`` (integer class ids).  ``state`` continues from
    an existing model (e.g. a ``seed_codebook``-warm-started bank);  None
    starts cold.  ``retry``/``report``/``skip_chunks`` are the §16
    resilience knobs forwarded to ``iter_epoch`` (quarantined chunks are
    neither scored nor trained on).  Returns the final state plus the
    online record::

        {"state", "n_rows", "mistakes", "mistake_rate",   # cumulative
         "chunk_acc",                                     # per-chunk trace
         "chunk_mistakes"}
    """
    multi = isinstance(cfg, MulticlassSVMConfig)
    binary = cfg.binary if multi else cfg
    if not isinstance(binary, BSGDConfig):
        raise TypeError(f"cfg must be BSGDConfig or MulticlassSVMConfig, "
                        f"got {type(cfg).__name__}")
    table = binary.table()
    if state is None:
        state = (init_multiclass_state(cfg, source.dim) if multi
                 else init_state(binary, source.dim))
    score = predict_multiclass if multi else predict
    train = jax.jit(train_chunk_multiclass if multi else train_chunk,
                    static_argnames=("cfg", "impl"), donate_argnums=(2,))
    bsz = binary.batch_size
    mistakes = 0
    n_rows = 0
    chunk_acc, chunk_mist = [], []
    for _, x, y in iter_epoch(source, key, prefetch=prefetch, retry=retry,
                              report=report, skip_chunks=skip_chunks):
        x = np.asarray(x, np.float32)
        y = np.asarray(y)
        # test ...
        pred = np.asarray(score(state, x, binary.gamma, impl=impl))
        wrong = int(np.sum(pred != y))
        mistakes += wrong
        n_rows += x.shape[0]
        chunk_mist.append(wrong)
        chunk_acc.append(round(1.0 - wrong / x.shape[0], 4))
        # ... then train on the batch-aligned prefix
        steps = x.shape[0] // bsz
        if steps:
            xc = x[:steps * bsz].reshape(steps, bsz, -1)
            yc = y[:steps * bsz].reshape(steps, bsz)
            if not multi:
                yc = yc.astype(np.float32)
            state = train(cfg, table, state, xc, yc, impl=impl)
    state = jax.block_until_ready(state)
    return {"state": state, "n_rows": n_rows, "mistakes": mistakes,
            "mistake_rate": round(mistakes / max(n_rows, 1), 4),
            "chunk_acc": chunk_acc, "chunk_mistakes": chunk_mist}
