"""Budget maintenance as a pluggable strategy engine over a kernel cache.

Two orthogonal axes (DESIGN.md §5):

  * **solver** (``method``) — how a candidate pair is scored (paper §4):
      - ``gss``         — golden section search at runtime precision eps = 0.01
      - ``gss-precise`` — golden section search at eps = 1e-10 (reference)
      - ``lookup-h``    — bilinear table lookup of h(m, kappa), WD exact
      - ``lookup-wd``   — bilinear table lookup of WD_norm(m, kappa); h looked
                          up only for winning pairs (fewest flops)
  * **strategy** (``strategy``) — what one maintenance event does:
      - ``merge``       — the paper's Alg. 1: merge the min-|alpha| SV with its
                          best same-sign partner; count -= 1 per event
      - ``multi-merge`` — Qaadan & Glasmachers 2018: the P smallest-|alpha| SVs
                          each merge with their best partner (disjoint pairs,
                          greedy in |alpha| order) in ONE fused scatter;
                          count -= P per event
      - ``removal``     — drop the ``count - budget`` smallest-|alpha| SVs in
                          one permutation (cheapest, largest degradation)
      - ``removal-project`` — BOGD-style removal (Zhao et al., arXiv
                          1206.4633): drop the same SVs but first project
                          each dropped SV's mass onto the survivors via its
                          cached kernel row — closed form, zero new kernel
                          evaluations, requires the cache
      - ``quantized``   — fixed-centroid codebook (arXiv 1701.00167): the
                          first ``budget`` slots are a centroid codebook
                          (first-come, or k-means via ``seed_codebook``);
                          each over-budget violator is snapped to its
                          nearest centroid and its alpha mass accumulates
                          there via the cached kernel row — the budget
                          never drains through merge events, requires the
                          cache

Every strategy reads its kappa rows ``k(x_fixed, .)`` from the persistent
SV-SV kernel cache (``core.kernel_cache``) when one is passed, and keeps it
incrementally consistent through merges/removals/compaction; with
``kmat=None`` the rows are recomputed per event (the seed behavior).

The SV set lives in fixed-size arrays (``slots = budget + batch``) with a
``count`` watermark; inactive slots are masked.  All steps are jit-safe
(masked argmin / top-k, scatter-with-drop, stable-argsort compaction — no
dynamic shapes).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import kernel_cache, merge_math
from .lookup import MergeLookupTable
from ..kernels import ops as kops
from ..kernels import ref as kref

METHODS = ("gss", "gss-precise", "lookup-h", "lookup-wd")
STRATEGIES = ("merge", "multi-merge", "removal", "removal-project",
              "quantized")
_BIG = jnp.inf
# Scores above this mean "no valid partner" (the Pallas scorer marks invalid
# slots with a finite 3.4e38 so bf16 casts stay argmin-safe; real WDs are
# bounded by (2 max|alpha|)^2 << 1e30).  Single-sourced from the kernels
# package so the xla and fused-event engines cannot desynchronize their
# merge-vs-removal threshold.
_NO_PARTNER = kref.NO_PARTNER


class MaintenanceInfo(NamedTuple):
    """Diagnostics for tests / paper Table 3 statistics."""

    i_min: jax.Array      # slot of the fixed (min-|alpha|) partner
    j_star: jax.Array     # slot of the chosen merge partner
    h_star: jax.Array     # merge coefficient used
    wd_star: jax.Array    # weight degradation of the executed merge
    merged: jax.Array     # bool: True = merged, False = removal fallback


def candidate_scores(alpha, kappa_row, i_min, valid, method: str,
                     table: MergeLookupTable | None):
    """Per-candidate (WD, h) for merging slot(s) ``i_min`` with each slot j.

    ``kappa_row[j] = k(x_{i_min}, x_j)``.  Invalid candidates get WD = +inf.
    ``method`` is static, so exactly one solver is traced.  Batched form:
    ``i_min`` of shape (P,) with ``kappa_row``/``valid`` of shape (P, s)
    scores P fixed partners at once.
    """
    a_min = alpha[i_min]
    if jnp.ndim(a_min) == 1:          # batched fixed partners -> broadcast
        a_min = a_min[:, None]
    m, kap = kref.merge_coords(a_min, alpha, kappa_row)

    if method == "lookup-wd":
        wd = (a_min + alpha) ** 2 * table.lookup_wd_norm(m, kap)
        h = table.lookup_h(m, kap)
    elif method == "lookup-h":
        h = table.lookup_h(m, kap)
        a_z = merge_math.merge_alpha_z(a_min, alpha, kap, h)
        wd = merge_math.weight_degradation(a_min, alpha, kap, a_z)
    elif method in ("gss", "gss-precise"):
        eps = merge_math.EPS_STANDARD if method == "gss" else merge_math.EPS_PRECISE
        h = merge_math.golden_section_search(m, kap, eps=eps)
        a_z = merge_math.merge_alpha_z(a_min, alpha, kap, h)
        wd = merge_math.weight_degradation(a_min, alpha, kap, a_z)
    else:  # pragma: no cover - guarded by METHODS
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")

    wd = jnp.where(valid, wd, _BIG)
    return wd, h


# --------------------------------------------------------------------------
# Strategy: merge (paper Alg. 1) — one pair per event
# --------------------------------------------------------------------------
def _merge_once(sv_x, alpha, kmat, count, gamma, method, table,
                kappa_row=None):
    """One merge event; ``kmat`` may be None (then kappa is recomputed unless
    ``kappa_row`` is supplied).  Returns (sv_x, alpha, kmat, count-1, info)."""
    slots = alpha.shape[0]
    idx = jnp.arange(slots)
    active = idx < count

    # 1. fixed partner: active SV with minimal |alpha| (paper Alg. 1 line 2).
    abs_a = jnp.where(active, jnp.abs(alpha), _BIG)
    i_min = jnp.argmin(abs_a)
    a_min = alpha[i_min]

    # 2. kappa row k(x_{i_min}, x_j): cache read when available — the rbf
    #    recompute below is the seed's per-event hot spot.
    if kappa_row is None:
        if kmat is not None:
            kappa_row = kmat[i_min].astype(alpha.dtype)
        else:
            kappa_row = kops.rbf_row(sv_x, sv_x[i_min], gamma)

    same_sign = alpha * a_min > 0
    valid = active & same_sign & (idx != i_min)
    wd, h = candidate_scores(alpha, kappa_row, i_min, valid, method, table)

    j_star = jnp.argmin(wd)
    has_partner = wd[j_star] < _NO_PARTNER

    last = count - 1

    if kmat is not None:
        # Branch-free fused update: a lax.cond over the (slots, slots) cache
        # defeats XLA's in-place buffer aliasing inside the maintenance
        # while_loop (full-matrix copies per event, O(slots^2)); instead the
        # merge and the removal fallback share one masked two-row scatter.
        # All gathers happen before any write.
        slots_i = jnp.int32(alpha.shape[0])
        lo = jnp.minimum(i_min, j_star)   # lo <= count-2, safe to overwrite
        hi = jnp.maximum(i_min, j_star)
        h_m = h[j_star]
        kap = jnp.clip(kappa_row[j_star], 0.0, 1.0)
        z = merge_math.merge_point(h_m, sv_x[i_min], sv_x[j_star])
        a_z = merge_math.merge_alpha_z(a_min, alpha[j_star], kap, h_m)
        # one batched gather for everything the update reads (each separate
        # gather/scatter on the loop-carried cache risks a full-matrix copy
        # on backends that cannot prove in-place aliasing)
        block = kmat[jnp.stack([j_star, last])]
        row_last = block[1]
        z_row = kernel_cache.z_row_from_rows(
            kappa_row.astype(jnp.float32), block[0], kappa_row[j_star],
            h_m).astype(kmat.dtype)
        # Fix intersections so row and column scatters agree: slot t1 holds z
        # (or, on removal, the old ``last``), slot t2 holds the old ``last``;
        # diagonals are pinned to 1 inside the rows themselves.
        r_merge = z_row.at[hi].set(z_row[last]).at[lo].set(1.0)
        r_move = row_last.at[hi].set(1.0).at[lo].set(z_row[last])
        r_remove = row_last.at[i_min].set(1.0)
        t1 = jnp.where(has_partner, lo, i_min)
        t2 = jnp.where(has_partner, hi, slots_i)      # OOB on removal -> drop
        tt = jnp.stack([t1, t2])
        rows = jnp.stack([jnp.where(has_partner, r_merge, r_remove), r_move])
        kmat = kmat.at[tt, :].set(rows, mode="drop")
        kmat = kmat.at[:, tt].set(rows.T, mode="drop")
        v_last, a_last = sv_x[last], alpha[last]
        sv1 = jnp.where(has_partner, z.astype(sv_x.dtype), v_last)
        a1 = jnp.where(has_partner, a_z, a_last)
        sv_x = sv_x.at[tt].set(jnp.stack([sv1, v_last]), mode="drop")
        alpha = alpha.at[tt].set(jnp.stack([a1, a_last]), mode="drop")
        alpha = alpha.at[last].set(0.0)
        h_star = jnp.where(has_partner, h_m, jnp.asarray(1.0, alpha.dtype))
        wd_star = jnp.where(has_partner, wd[j_star], a_min**2)
    else:
        def do_merge(args):
            sv_x, alpha = args
            h_star = h[j_star]
            kap = jnp.clip(kappa_row[j_star], 0.0, 1.0)
            z = merge_math.merge_point(h_star, sv_x[i_min], sv_x[j_star])
            a_z = merge_math.merge_alpha_z(a_min, alpha[j_star], kap, h_star)
            lo = jnp.minimum(i_min, j_star)   # lo <= count-2, safe to overwrite
            hi = jnp.maximum(i_min, j_star)
            sv_x = sv_x.at[lo].set(z.astype(sv_x.dtype))
            sv_x = sv_x.at[hi].set(sv_x[last])    # compact: move last into hole
            alpha = alpha.at[lo].set(a_z)
            alpha = alpha.at[hi].set(alpha[last])
            alpha = alpha.at[last].set(0.0)
            return sv_x, alpha, h_star, wd[j_star]

        def do_remove(args):
            # No same-sign partner: fall back to removing the min-|alpha| SV.
            sv_x, alpha = args
            sv_x = sv_x.at[i_min].set(sv_x[last])
            alpha = alpha.at[i_min].set(alpha[last])
            alpha = alpha.at[last].set(0.0)
            return sv_x, alpha, jnp.asarray(1.0, alpha.dtype), a_min**2

        sv_x, alpha, h_star, wd_star = jax.lax.cond(
            has_partner, do_merge, do_remove, (sv_x, alpha))

    info = MaintenanceInfo(i_min=i_min, j_star=j_star, h_star=h_star,
                           wd_star=wd_star, merged=has_partner)
    return sv_x, alpha, kmat, count - 1, info


@partial(jax.jit, static_argnames=("method",))
def maintenance_step(sv_x, alpha, count, gamma, method: str = "lookup-wd",
                     table: MergeLookupTable | None = None, kappa_row=None):
    """One budget-maintenance event: merge two SVs (or remove one), count -= 1.

    Back-compatible single-merge entry point; pass ``kappa_row`` to skip the
    rbf recompute (e.g. a row read from the kernel cache).
    Returns ``(sv_x, alpha, count, MaintenanceInfo)``.
    """
    sv_x, alpha, _, count, info = _merge_once(
        sv_x, alpha, None, count, gamma, method, table, kappa_row=kappa_row)
    return sv_x, alpha, count, info


# --------------------------------------------------------------------------
# Strategy: multi-merge — P disjoint pairs in one fused scatter
# --------------------------------------------------------------------------
def _compaction_perm(hole_mask):
    """Stable permutation pushing hole slots behind every survivor.

    Sort key: survivors keep their slot index (order preserved), inactive
    slots stay in [count, slots), holes move past ``slots``.  With n holes
    among the active slots, positions [0, count - n) are exactly the
    surviving SVs in their original order.
    """
    slots = hole_mask.shape[0]
    idx = jnp.arange(slots)
    return jnp.argsort(jnp.where(hole_mask, slots + idx, idx), stable=True)


def _multi_merge_once(sv_x, alpha, kmat, count, gamma, method, table,
                      budget: int, merge_batch: int, impl: str):
    """One fused multi-merge event: up to P = merge_batch disjoint same-sign
    pairs merge at once; count -= the number of executed pairs (>= 1, <=
    min(P, count - budget))."""
    slots = alpha.shape[0]
    p = merge_batch
    idx = jnp.arange(slots)
    active = idx < count

    # 1. fixed partners: the P smallest-|alpha| active SVs, cheapest first
    #    (requires budget >= P, so count > budget implies all P are active).
    abs_a = jnp.where(active, jnp.abs(alpha), _BIG)
    _, a_idx = jax.lax.top_k(-abs_a, p)                    # (P,) |alpha| asc
    a_min = alpha[a_idx]

    # 2. kappa rows from the cache, or one (P, slots) rbf block per event.
    if kmat is not None:
        kappa_rows = kmat[a_idx].astype(alpha.dtype)
    else:
        kappa_rows = kops.rbf_matrix(sv_x[a_idx], sv_x, gamma, impl=impl)

    # a pair may merge with another pair's fixed slot (the lowest-|alpha| SVs
    # are often each other's best partners); only its own slot is excluded
    same_sign = a_min[:, None] * alpha[None, :] > 0        # (P, slots)
    self_mask = jnp.zeros((p, slots), bool).at[jnp.arange(p), a_idx].set(True)
    valid = active[None, :] & same_sign & ~self_mask

    # 3. score all P x slots pairs in one pass (fused Pallas kernel for the
    #    lookup solvers; candidate_scores broadcasts for the GSS solvers).
    if method == "lookup-wd" and table is not None:
        wd, h = kops.multi_merge_scores(alpha, kappa_rows, valid, a_min,
                                        table, impl=impl)
    else:
        wd, h = candidate_scores(alpha, kappa_rows, a_idx, valid, method,
                                 table)

    # 4. greedy disjoint pair choice in |alpha| order (P is small/static: the
    #    loop unrolls).  Executing a pair consumes both slots; a pair whose
    #    fixed slot was consumed as an earlier partner is skipped, and no
    #    pair executes once the budget excess is covered.  Pair 0 always
    #    executes, so every event lowers count.
    excess = count - budget
    taken = jnp.zeros((slots,), bool)
    consumed = jnp.zeros((p,), bool)
    n_exec = jnp.int32(0)
    b_list, merged_list, exec_list = [], [], []
    for q in range(p):
        wd_q = jnp.where(taken, _BIG, wd[q])
        j_q = jnp.argmin(wd_q)
        exec_q = ~consumed[q] & (n_exec < excess)
        merged_q = exec_q & (wd_q[j_q] < _NO_PARTNER)
        b_list.append(j_q)
        merged_list.append(merged_q)
        exec_list.append(exec_q)
        taken = taken | ((idx == j_q) & merged_q) | ((idx == a_idx[q]) & exec_q)
        consumed = consumed | ((a_idx == j_q) & merged_q)
        n_exec = n_exec + exec_q.astype(jnp.int32)
    b_idx = jnp.stack(b_list)                              # (P,)
    merged = jnp.stack(merged_list)                        # (P,) bool
    execute = jnp.stack(exec_list)                         # (P,) bool

    # 5. one fused scatter: z_q overwrites a_q; b_q (or a_q on removal
    #    fallback) becomes a hole.  Non-executing pairs scatter out of bounds.
    h_star = h[jnp.arange(p), b_idx]
    kap = jnp.clip(kappa_rows[jnp.arange(p), b_idx], 0.0, 1.0)
    a_z = merge_math.merge_alpha_z(a_min, alpha[b_idx], kap, h_star)
    z = merge_math.merge_point(h_star[:, None], sv_x[a_idx], sv_x[b_idx])
    write_idx = jnp.where(merged, a_idx, slots)            # OOB -> dropped
    hole_idx = jnp.where(merged, b_idx,
                         jnp.where(execute, a_idx, slots))

    if kmat is not None:
        kmat = kernel_cache.apply_multi_merge(kmat, a_idx, b_idx, h_star,
                                              write_idx)
    sv_x = sv_x.at[write_idx].set(z.astype(sv_x.dtype), mode="drop")
    alpha = alpha.at[write_idx].set(a_z.astype(alpha.dtype), mode="drop")

    # 6. compaction by targeted moves: pair the k-th hole below the new
    #    watermark with the k-th surviving slot above it — O(P * slots)
    #    scatters instead of an O(slots^2) permutation gather of the cache
    #    (survivor order is not an invariant; only the watermark is).
    hole_mask = jnp.zeros((slots,), bool).at[hole_idx].set(True, mode="drop")
    new_count = count - n_exec              # one hole per executed pair
    front_hole = hole_mask & (idx < new_count)
    tail_surv = active & ~hole_mask & (idx >= new_count)
    # both sets have the same size (the tail has n_exec slots, n_exec - |front|
    # of which are holes); sort pushes the `slots` padding behind real entries
    dst = jnp.sort(jnp.where(front_hole, idx, slots))[:p]     # OOB-padded
    src = jnp.sort(jnp.where(tail_surv, idx, slots))[:p]
    src_c = jnp.minimum(src, slots - 1)                       # clamp gathers
    if kmat is not None:
        rows = kmat[src_c]                                    # (P, slots)
        kmat = kmat.at[dst, :].set(rows, mode="drop")
        kmat = kmat.at[:, dst].set(rows.T, mode="drop")
        # moved-row intersections: slot dst_l now holds old src_l
        kmat = kmat.at[dst[:, None], dst[None, :]].set(rows[:, src_c],
                                                       mode="drop")
    sv_x = sv_x.at[dst].set(sv_x[src_c], mode="drop")
    alpha = alpha.at[dst].set(alpha[src_c], mode="drop")
    alpha = jnp.where(idx < new_count, alpha, 0.0)
    return sv_x, alpha, kmat, new_count


# --------------------------------------------------------------------------
# Strategy: removal — drop the excess smallest-|alpha| SVs in one shot
# --------------------------------------------------------------------------
def _removal_all(sv_x, alpha, kmat, count, budget: int):
    """Remove the ``count - budget`` smallest-|alpha| SVs in one permutation."""
    slots = alpha.shape[0]
    idx = jnp.arange(slots)
    active = idx < count
    excess = jnp.maximum(count - budget, 0)
    abs_a = jnp.where(active, jnp.abs(alpha), _BIG)
    order = jnp.argsort(abs_a, stable=True)        # smallest |alpha| first
    rank = jnp.zeros((slots,), jnp.int32).at[order].set(idx.astype(jnp.int32))
    hole_mask = active & (rank < excess)
    perm = _compaction_perm(hole_mask)
    new_count = count - excess
    sv_x = sv_x[perm]
    alpha = jnp.where(idx < new_count, alpha[perm], 0.0)
    if kmat is not None:
        kmat = kernel_cache.permute(kmat, perm)
    return sv_x, alpha, kmat, new_count


def _removal_project_all(sv_x, alpha, kmat, count, budget: int):
    """BOGD-style removal+projection (arXiv 1206.4633, closed form).

    Same holes as ``_removal_all`` (the ``count - budget`` smallest-|alpha|
    active SVs), but before compaction each dropped SV's coefficient mass is
    projected onto the survivors: survivor ``j`` gains

        sum_i  alpha_i * k(x_i, x_j) / sum_j' k(x_i, x_j')

    over dropped SVs ``i`` — every ``k`` read straight from the cached
    kernel rows, so the projection costs one masked matmul and no kernel
    evaluations.  Degrades the weight vector less than plain removal while
    staying a pure cache read (no new kernels, no solver).
    """
    slots = alpha.shape[0]
    idx = jnp.arange(slots)
    active = idx < count
    excess = jnp.maximum(count - budget, 0)
    abs_a = jnp.where(active, jnp.abs(alpha), _BIG)
    order = jnp.argsort(abs_a, stable=True)        # smallest |alpha| first
    rank = jnp.zeros((slots,), jnp.int32).at[order].set(idx.astype(jnp.int32))
    hole_mask = active & (rank < excess)
    surv = active & ~hole_mask
    # dropped-row x survivor-column slice of the cache, everything else 0
    k_hs = jnp.where(hole_mask[:, None] & surv[None, :],
                     kmat.astype(jnp.float32), 0.0)
    denom = jnp.maximum(jnp.sum(k_hs, axis=1), 1e-12)
    w = jnp.where(hole_mask, alpha.astype(jnp.float32), 0.0) / denom
    gain = w @ k_hs                                # (slots,) survivor gains
    alpha = jnp.where(surv, alpha + gain.astype(alpha.dtype), alpha)
    perm = _compaction_perm(hole_mask)
    new_count = count - excess
    sv_x = sv_x[perm]
    alpha = jnp.where(idx < new_count, alpha[perm], 0.0)
    kmat = kernel_cache.permute(kmat, perm)
    return sv_x, alpha, kmat, new_count


# --------------------------------------------------------------------------
# Strategy: quantized — fixed-centroid codebook absorbs arriving violators
# --------------------------------------------------------------------------
def _quantized_all(sv_x, alpha, kmat, count, budget: int):
    """Fixed-centroid absorption (arXiv 1701.00167, RKHS projection form).

    The first ``budget`` slots ARE the model: a centroid codebook filled
    first-come by the opening inserts (or k-means-seeded via
    ``seed_codebook``) and never moved again.  When inserts push ``count``
    past the budget, slots [budget, count) hold this batch's fresh
    violators; each is snapped to its nearest centroid — for the RBF kernel
    the nearest-by-distance centroid is exactly the argmax of the violator's
    cached kernel row over the codebook — and its coefficient mass is
    projected onto that centroid's basis function.  The least-squares
    coefficient of ``alpha_i k(x_i, .)`` on ``k(c_j, .)`` in the RKHS is
    ``alpha_i k(x_i, c_j) / k(c_j, c_j) = alpha_i k(x_i, c_j)`` (unit
    diagonal), read straight from the cache — zero kernel evaluations.

    Centroid rows of ``sv_x`` and the codebook block of ``kmat`` are never
    written, so cache invariants I1-I3 hold trivially; the absorbed
    violators' rows fall past the watermark (I4 territory).  One event
    absorbs the whole batch and pins ``count`` back to ``budget``.
    """
    slots = alpha.shape[0]
    idx = jnp.arange(slots)
    fresh = (idx >= budget) & (idx < count)        # this batch's violators
    cent = idx < budget                            # the fixed codebook
    # nearest centroid per fresh row, off-codebook columns masked out
    k_fc = jnp.where(fresh[:, None] & cent[None, :],
                     kmat.astype(jnp.float32), -1.0)
    nearest = jnp.argmax(k_fc, axis=1)             # (slots,), junk off-fresh
    w = jnp.where(fresh, alpha.astype(jnp.float32) * k_fc[idx, nearest], 0.0)
    gain = jnp.zeros((slots,), jnp.float32).at[
        jnp.where(fresh, nearest, slots)].add(w, mode="drop")
    alpha = jnp.where(cent, alpha + gain.astype(alpha.dtype), 0.0)
    return sv_x, alpha, kmat, jnp.minimum(count, budget)


def kmeans_codebook(key, x, k: int, *, iters: int = 10):
    """Lloyd's k-means over ``x`` (n, dim): a (k, dim) float32 codebook for
    warm-starting the quantized strategy (``seed_codebook``).

    Plain jit-safe Lloyd iterations from a random-row init; a cluster that
    goes empty keeps its previous centroid (no NaN means).  This is the
    offline "or k-means-warm-started" variant of the codebook — the online
    default is first-come (the opening ``budget`` inserts).
    """
    n = x.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"kmeans_codebook needs 1 <= k={k} <= n={n}")
    x = jnp.asarray(x, jnp.float32)
    init = x[jax.random.choice(key, n, (k,), replace=False)]

    def lloyd(cent, _):
        d2 = jnp.sum((x[:, None, :] - cent[None, :, :]) ** 2, axis=-1)
        assign = jnp.argmin(d2, axis=1)
        one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
        sums = one_hot.T @ x                       # (k, dim)
        counts = jnp.sum(one_hot, axis=0)          # (k,)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where((counts > 0)[:, None], new, cent), ()

    cent, _ = jax.lax.scan(lloyd, init, None, length=iters)
    return cent


def seed_codebook(state, centroids, gamma):
    """Seed a FRESH state's bank with a fixed centroid codebook.

    Writes ``centroids`` (k, dim) into the first k slots, fills the cache's
    codebook Gram block exactly, and sets the watermark to k with zero
    coefficients — the quantized strategy then only ever accumulates mass
    onto these slots.  Requires the kernel cache (the strategy reads
    absorption coefficients from it); ``k`` must not exceed the budget slice
    of the bank.  Works on any ``SVMState``-shaped NamedTuple.
    """
    if state.kmat is None:
        raise ValueError("seed_codebook requires the kernel cache "
                         "(use_kernel_cache=True): quantized absorption "
                         "reads cached kernel rows")
    c = jnp.asarray(centroids)
    k = c.shape[0]
    if k > state.alpha.shape[0]:
        raise ValueError(f"codebook k={k} exceeds the bank's "
                         f"{state.alpha.shape[0]} slots")
    sv_x = state.sv_x.at[:k].set(c.astype(state.sv_x.dtype))
    block = kops.rbf_matrix(sv_x[:k], sv_x[:k], gamma).astype(jnp.float32)
    block = (block + block.T) / 2                  # exact symmetry (I2)
    block = jnp.fill_diagonal(block, 1.0, inplace=False)
    kmat = state.kmat.at[:k, :k].set(block)
    return state._replace(sv_x=sv_x, kmat=kmat,
                          count=jnp.asarray(k, state.count.dtype))


# --------------------------------------------------------------------------
# Engine entry point: loop a strategy until count <= budget
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("budget", "strategy", "method",
                                   "merge_batch", "impl", "unroll"))
def run_maintenance(sv_x, alpha, kmat, count, n_events, gamma, table, *,
                    budget: int, strategy: str = "merge",
                    method: str = "lookup-wd", merge_batch: int = 4,
                    impl: str = "auto", unroll: int = 0):
    """Run budget maintenance until ``count <= budget``.

    ``kmat`` is the SV-SV kernel cache (or None to recompute kappa rows per
    event); it is kept consistent across merges and compaction.  Returns
    ``(sv_x, alpha, kmat, count, n_events)`` with ``n_events`` incremented
    once per maintenance event (a fused multi-merge counts as one event).

    ``unroll > 0`` replaces the ``lax.while_loop`` with exactly ``unroll``
    statically-inlined events, each masked to a no-op once ``count <=
    budget``.  The caller must guarantee the budget excess never exceeds
    ``unroll`` (one insert minibatch gives excess <= batch_size, and every
    event lowers count by >= 1, so ``unroll = batch_size`` always suffices).
    The payoff is exact cross-batching numerics: XLA compiles a while-loop
    body with batch-width-dependent FMA contraction, so ``vmap`` over a class
    axis drifts from the per-class loop by ~1 ULP per event — inlined bodies
    do not (the loop-parity property in tests/core/test_multiclass.py).
    """
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; expected one of {STRATEGIES}")

    if strategy in ("removal", "removal-project", "quantized"):
        if strategy != "removal" and kmat is None:
            raise ValueError(
                f"strategy={strategy!r} reads cached kernel rows "
                "(projection / absorption coefficients) and needs the "
                "kernel cache (use_kernel_cache=True)")
        fn = {"removal": _removal_all,
              "removal-project": _removal_project_all,
              "quantized": _quantized_all}[strategy]
        over = count > budget
        sv_x, alpha, kmat, count = jax.lax.cond(
            over,
            lambda args: fn(*args, budget),
            lambda args: args,
            (sv_x, alpha, kmat, count))
        return sv_x, alpha, kmat, count, n_events + over.astype(n_events.dtype)

    if strategy == "merge":
        def body(carry):
            sv_x, alpha, kmat, c, n = carry
            sv_x, alpha, kmat, c, _ = _merge_once(sv_x, alpha, kmat, c, gamma,
                                                  method, table)
            return sv_x, alpha, kmat, c, n + 1
    else:  # multi-merge
        def body(carry):
            sv_x, alpha, kmat, c, n = carry
            sv_x, alpha, kmat, c = _multi_merge_once(
                sv_x, alpha, kmat, c, gamma, method, table, budget,
                merge_batch, impl)
            return sv_x, alpha, kmat, c, n + 1

    carry = (sv_x, alpha, kmat, count, n_events)
    if unroll:
        for _ in range(unroll):
            over = carry[3] > budget
            carry = jax.tree.map(lambda new, old: jnp.where(over, new, old),
                                 body(carry), carry)
        return carry

    return jax.lax.while_loop(lambda c: c[3] > budget, body, carry)


# --------------------------------------------------------------------------
# Maintenance-event engine: fused all-class rounds (sorted-excess schedule)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("budget", "impl", "unroll"))
def run_maintenance_classes(sv_x, alpha, kmat, count, n_events, table, *,
                            budget: int, impl: str = "auto", unroll: int = 0):
    """Budget maintenance for a stacked class axis as fused event rounds.

    The vmapped per-class engine (``vmap(run_maintenance)``) pays two taxes
    at scale: every class runs the while body whenever ANY class is over
    budget, and the vmapped two-row scatters on the ``(C, slots, slots)``
    cache defeat XLA's in-place aliasing (full-matrix copies per event).
    This engine replaces it with the *sorted-excess schedule*: the per-class
    excess ``count - budget`` is known up front, every round executes ONE
    fused ``kernels.ops.merge_event`` launch in which classes still over
    budget run a whole merge event and finished classes are bitwise no-op
    rows, and the loop runs exactly ``max_c(count_c - budget)`` rounds —
    total work proportional to the worst class, not ``C x worst``.  With no
    class over budget the loop body never runs and the state is returned
    bitwise unchanged (the early exit the engine tests pin).

    Arguments carry a leading ``(C,)`` class axis (``C = 1`` lifts the
    binary engine); ``kmat`` is REQUIRED — the event reads its kappa rows
    from the cache (``BSGDConfig`` validation enforces
    ``use_kernel_cache=True`` for ``maintenance_engine="pallas"``).  Scoring
    is Lookup-WD against ``table``.  ``unroll > 0`` inlines that many masked
    rounds instead of the while loop (same contract as ``run_maintenance``:
    one insert minibatch bounds the excess by ``batch_size``).  Returns
    ``(sv_x, alpha, kmat, count, n_events)`` with ``n_events`` incremented
    per class per executed event.
    """
    if kmat is None:
        raise ValueError("run_maintenance_classes needs the kernel cache "
                         "(use_kernel_cache=True): the fused event reads "
                         "its kappa rows from kmat")
    if table is None:
        raise ValueError("run_maintenance_classes scores with Lookup-WD and "
                         "needs the precomputed table")

    if sv_x.shape[0] == 1:
        # One class: a fused round IS a single-class merge event, and the
        # single-class engine's batched-gather body is cheaper than the
        # class-batched forms with nothing to amortize them over (decisions
        # are bitwise identical — the merge_event oracle is pinned against
        # _merge_once).  gamma is never read: the cache supplies every row.
        out = run_maintenance(sv_x[0], alpha[0], kmat[0], count[0],
                              n_events[0], jnp.float32(0.0), table,
                              budget=budget, strategy="merge",
                              method="lookup-wd", impl=impl, unroll=unroll)
        return tuple(a[None] for a in out)

    def round_(carry):
        sv_x, alpha, kmat, count, n = carry
        over = count > budget
        sv_x, alpha, kmat = kops.merge_event(sv_x, alpha, kmat, count, over,
                                             table, impl=impl)
        return (sv_x, alpha, kmat, count - over.astype(count.dtype),
                n + over.astype(n.dtype))

    carry = (sv_x, alpha, kmat, count, n_events)
    if unroll:
        for _ in range(unroll):
            carry = round_(carry)
        return carry
    return jax.lax.while_loop(lambda c: jnp.any(c[3] > budget), round_, carry)
