"""Budget maintenance by merging (paper Alg. 1), with four selectable solvers.

Methods (paper §4):
  * ``gss``         — golden section search at runtime precision eps = 0.01
  * ``gss-precise`` — golden section search at eps = 1e-10 (reference)
  * ``lookup-h``    — bilinear table lookup of h(m, kappa), WD computed exactly
  * ``lookup-wd``   — bilinear table lookup of WD_norm(m, kappa) for scoring;
                      h looked up only for the winning pair (fewest flops)

The SV set lives in fixed-size arrays (``slots = budget + batch``) with an
``count`` watermark; inactive slots are masked.  One maintenance event:

  1. fix x_a := the active SV with minimal |alpha|  (paper's O(B) heuristic)
  2. score every active same-sign candidate x_b via the selected solver
  3. merge the winning pair into z = h x_a + (1-h) x_b, compact the slots

All steps are jit-safe (masked argmin / scatter, no dynamic shapes).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import merge_math
from .lookup import MergeLookupTable
from ..kernels import ops as kops

METHODS = ("gss", "gss-precise", "lookup-h", "lookup-wd")
_BIG = jnp.inf


class MaintenanceInfo(NamedTuple):
    """Diagnostics for tests / paper Table 3 statistics."""

    i_min: jax.Array      # slot of the fixed (min-|alpha|) partner
    j_star: jax.Array     # slot of the chosen merge partner
    h_star: jax.Array     # merge coefficient used
    wd_star: jax.Array    # weight degradation of the executed merge
    merged: jax.Array     # bool: True = merged, False = removal fallback


def candidate_scores(alpha, kappa_row, i_min, valid, method: str,
                     table: MergeLookupTable | None):
    """Per-candidate (WD, h) for merging slot ``i_min`` with each slot j.

    ``kappa_row[j] = k(x_{i_min}, x_j)``.  Invalid candidates get WD = +inf.
    ``method`` is static, so exactly one solver is traced.
    """
    a_min = alpha[i_min]
    denom = a_min + alpha
    # Same-sign pairs have m strictly inside (0, 1); clip keeps masked-out
    # entries finite so they cannot poison the argmin with NaNs.
    m = jnp.clip(a_min / jnp.where(denom == 0, 1.0, denom), 0.0, 1.0)
    kap = jnp.clip(kappa_row, 0.0, 1.0)

    if method == "lookup-wd":
        wd = (a_min + alpha) ** 2 * table.lookup_wd_norm(m, kap)
        h = table.lookup_h(m, kap)
    elif method == "lookup-h":
        h = table.lookup_h(m, kap)
        a_z = merge_math.merge_alpha_z(a_min, alpha, kap, h)
        wd = merge_math.weight_degradation(a_min, alpha, kap, a_z)
    elif method in ("gss", "gss-precise"):
        eps = merge_math.EPS_STANDARD if method == "gss" else merge_math.EPS_PRECISE
        h = merge_math.golden_section_search(m, kap, eps=eps)
        a_z = merge_math.merge_alpha_z(a_min, alpha, kap, h)
        wd = merge_math.weight_degradation(a_min, alpha, kap, a_z)
    else:  # pragma: no cover - guarded by METHODS
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")

    wd = jnp.where(valid, wd, _BIG)
    return wd, h


@partial(jax.jit, static_argnames=("method",))
def maintenance_step(sv_x, alpha, count, gamma, method: str = "lookup-wd",
                     table: MergeLookupTable | None = None):
    """One budget-maintenance event: merge two SVs (or remove one), count -= 1.

    Returns ``(sv_x, alpha, count, MaintenanceInfo)``.
    """
    slots = alpha.shape[0]
    idx = jnp.arange(slots)
    active = idx < count

    # 1. fixed partner: active SV with minimal |alpha| (paper Alg. 1 line 2).
    abs_a = jnp.where(active, jnp.abs(alpha), _BIG)
    i_min = jnp.argmin(abs_a)
    a_min = alpha[i_min]

    # 2. kappa row k(x_{i_min}, x_j) — the rbf kernel hot spot.
    kappa_row = kops.rbf_row(sv_x, sv_x[i_min], gamma)

    same_sign = alpha * a_min > 0
    valid = active & same_sign & (idx != i_min)
    wd, h = candidate_scores(alpha, kappa_row, i_min, valid, method, table)

    j_star = jnp.argmin(wd)
    has_partner = jnp.isfinite(wd[j_star])

    last = count - 1

    def do_merge(args):
        sv_x, alpha = args
        h_star = h[j_star]
        kap = jnp.clip(kappa_row[j_star], 0.0, 1.0)
        z = merge_math.merge_point(h_star, sv_x[i_min], sv_x[j_star])
        a_z = merge_math.merge_alpha_z(a_min, alpha[j_star], kap, h_star)
        lo = jnp.minimum(i_min, j_star)   # lo <= count-2, safe to overwrite
        hi = jnp.maximum(i_min, j_star)
        sv_x = sv_x.at[lo].set(z)
        sv_x = sv_x.at[hi].set(sv_x[last])        # compact: move last into hole
        alpha = alpha.at[lo].set(a_z)
        alpha = alpha.at[hi].set(alpha[last])
        alpha = alpha.at[last].set(0.0)
        return sv_x, alpha, h_star, wd[j_star]

    def do_remove(args):
        # No same-sign partner exists: fall back to removing the min-|alpha| SV.
        sv_x, alpha = args
        sv_x = sv_x.at[i_min].set(sv_x[last])
        alpha = alpha.at[i_min].set(alpha[last])
        alpha = alpha.at[last].set(0.0)
        return sv_x, alpha, jnp.asarray(1.0, alpha.dtype), a_min**2

    sv_x, alpha, h_star, wd_star = jax.lax.cond(has_partner, do_merge, do_remove,
                                                (sv_x, alpha))
    info = MaintenanceInfo(i_min=i_min, j_star=j_star, h_star=h_star,
                           wd_star=wd_star, merged=has_partner)
    return sv_x, alpha, count - 1, info
