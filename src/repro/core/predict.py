"""Serving path: fused multiclass scoring + batched request queue.

The paper's end product is a model that is cheap to *evaluate* — merging
exists precisely so the SV bank stays small enough for fast prediction
(Picard 2018 builds budgeted SV banks expressly for high-throughput batched
scoring).  This module is the inference half of that bargain:

  * ``ServeModel`` — the exported, inference-only view of a trained
    ``SVMState``: the (C, slots, dim) SV bank (optionally quantized to
    bfloat16 — halves the bank's HBM and gather traffic), fp32 alphas with
    the active-count mask FOLDED IN at export time (inactive slots zeroed
    once, so the hot scoring path carries no masking), and the kernel width.
    Binary models export as C = 1 with ``binary=True`` (labels are ±1 signs
    instead of argmax ids).
  * ``predict_labels`` — ONE fused scoring program per microbatch: a single
    ``rbf_matrix`` launch against the flattened (C * slots, dim) bank
    (``kernels.ops.class_scores``, the same fold ``class_kernel_rows`` uses
    for training margins), fp32 alpha accumulation, argmax on device.
  * ``BatchQueue`` — microbatch assembly for a request stream: rows from
    submitted requests are packed into full ``max_batch`` microbatches in
    arrival order (a request may span microbatches; a microbatch may span
    requests), and the ragged tail pads up to a power-of-two *bucket* so the
    jit/pjit cache holds at most ``len(buckets)`` compiled shapes.  Because
    each row's scores depend only on that row and the bank, queue labels are
    bitwise the labels of one direct ``predict_labels`` call on the same
    rows — any arrival pattern, any bucket geometry (pinned by
    ``tests/core/test_serve_predict.py``).
  * ``load_serve_model`` — reads a ``fit_stream`` / ``fit_multiclass_stream``
    checkpoint (``repro.checkpoint`` layout) straight into a ``ServeModel``:
    the state template is reconstructed from the manifest's recorded leaf
    shapes/dtypes, so serving needs no training config object.

The distributed form (bank replicated per device, requests sharded over
every mesh axis — zero-collective scoring) is ``core.distributed``'s
``layout="serve"``; ``launch.serve --arch svm_bsgd`` is the driver and
``benchmarks/bench_serve.py`` the throughput/latency artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bsgd import SVMState
from ..kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class ServeModel:
    """Inference-only view of a trained budgeted SVM.

    Attributes:
      sv_x: (C, slots, dim) SV bank in the serving dtype (``bank_dtype`` at
        export; bfloat16 halves bank HBM).  Binary models are C = 1.
      alpha: (C, slots) float32 coefficients with inactive slots already
        zeroed — scoring never masks.
      count: (C,) int32 active-SV watermarks (reporting only).
      gamma: () float32 RBF width.
      binary: static — True when the model was a binary ``SVMState``; labels
        are then ±1 signs (``bsgd.predict`` convention) instead of argmax
        class ids.
    """

    sv_x: jax.Array
    alpha: jax.Array
    count: jax.Array
    gamma: jax.Array
    binary: bool = False

    @property
    def n_classes(self) -> int:
        return self.sv_x.shape[0]

    @property
    def label_dtype(self):
        return np.float32 if self.binary else np.int32


jax.tree_util.register_dataclass(
    ServeModel, ["sv_x", "alpha", "count", "gamma"], ["binary"])


def export_model(state: SVMState, gamma, *, bank_dtype=None) -> ServeModel:
    """Trained ``SVMState`` (binary or stacked multiclass) -> ``ServeModel``.

    ``bank_dtype`` quantizes the SV bank (e.g. ``"bfloat16"``); alphas are
    always carried in float32 and accumulation in scoring stays fp32, so
    quantization touches only the kernel's inputs.  The active-count mask is
    folded into alpha here — exactly the ``where(active, alpha, 0)`` the
    training-side decision functions apply per call.
    """
    binary = state.sv_x.ndim == 2
    sv_x, alpha, count = state.sv_x, state.alpha, state.count
    if binary:
        sv_x, alpha, count = sv_x[None], alpha[None], count[None]
    active = jnp.arange(alpha.shape[-1])[None, :] < count[:, None]
    alpha = jnp.where(active, alpha, 0.0).astype(jnp.float32)
    if bank_dtype is not None:
        sv_x = sv_x.astype(jnp.dtype(bank_dtype))
    return ServeModel(sv_x=sv_x, alpha=alpha,
                      count=count.astype(jnp.int32),
                      gamma=jnp.asarray(gamma, jnp.float32), binary=binary)


def serve_scores(model: ServeModel, x, *, impl: str = "auto"):
    """Per-class decision scores for a request batch: (n, d) -> (C, n).

    One fused kernel launch against the flattened (C * slots, dim) bank with
    fp32 accumulation (``kernels.ops.class_scores``).
    """
    return kops.class_scores(x, model.sv_x, model.alpha, model.gamma,
                             impl=impl)


@partial(jax.jit, static_argnames=("impl",))
def predict_labels(model: ServeModel, x, *, impl: str = "auto"):
    """The fused serve cell: labels for a request batch, argmax on device.

    Multiclass models return (n,) int32 class ids; binary models return the
    (n,) float32 ±1 signs of ``bsgd.predict``.
    """
    scores = serve_scores(model, x, impl=impl)
    if model.binary:
        return jnp.sign(scores[0]).astype(jnp.float32)
    return jnp.argmax(scores, axis=0).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "impl"))
def top_k_labels(model: ServeModel, x, *, k: int = 1, impl: str = "auto"):
    """Top-k class ids + decision scores per request row.

    x: (n, d) -> ``(ids, scores)`` of shape (n, k): per row, the k classes
    with the highest one-vs-rest decision scores, best first (ties broken by
    the lower class id, exactly like the argmax in ``predict_labels`` — so
    ``ids[:, 0]`` is bitwise ``predict_labels``).  One fused scoring launch;
    only the final ``lax.top_k`` is new work.  Multiclass models only: a
    binary model has one score, rank it yourself from ``serve_scores``.
    """
    if model.binary:
        raise ValueError("top_k_labels needs a multiclass model; binary "
                         "models have a single ±1 decision (predict_labels)")
    if not 1 <= k <= model.n_classes:
        raise ValueError(f"k={k} not in [1, n_classes={model.n_classes}]")
    scores = serve_scores(model, x, impl=impl)            # (C, n)
    vals, ids = jax.lax.top_k(scores.T, k)                # (n, k) each
    return ids.astype(jnp.int32), vals


@partial(jax.jit, static_argnames=("temperature", "impl"))
def predict_proba(model: ServeModel, x, *, temperature: float = 1.0,
                  impl: str = "auto"):
    """Calibrated softmax probabilities over the C class scores: (n, C).

    ``softmax(scores / temperature)`` per row — temperature scaling is the
    standard post-hoc calibration knob (T = 1 is the raw softmax; fit T on a
    held-out split to calibrate confidence).  Rows sum to 1 and the argmax
    is bitwise ``predict_labels`` for any positive temperature.  Multiclass
    models only.  ``temperature`` is static (one compile per distinct value
    — it is a per-deployment calibration constant, not per-request data).
    """
    if model.binary:
        raise ValueError("predict_proba needs a multiclass model")
    # T = 0 would be a silent NaN factory and T < 0 reverses the ranking
    # the docstring promises
    if temperature <= 0:
        raise ValueError(f"temperature={temperature} must be > 0")
    scores = serve_scores(model, x, impl=impl)            # (C, n)
    return jax.nn.softmax(scores.T / temperature, axis=-1)


# ---------------------------------------------------------------------------
# Batched request queue
# ---------------------------------------------------------------------------

def default_buckets(max_batch: int, min_bucket: int = 8) -> tuple[int, ...]:
    """Power-of-two pad targets up to (and always including) ``max_batch``."""
    if min_bucket < 1:
        raise ValueError(f"min_bucket={min_bucket} < 1")
    buckets = []
    b = min_bucket
    while b < max_batch:
        buckets.append(b)
        b *= 2
    buckets.append(max_batch)
    return tuple(buckets)


class BatchQueue:
    """Microbatch assembly over a request stream, one fused cell per batch.

    Requests (``(n_i, dim)`` row blocks) are packed into ``max_batch``-row
    microbatches in arrival order; a full microbatch runs immediately at
    ``submit`` (host memory stays O(max_batch), not O(stream)), and
    ``drain`` flushes the ragged remainder padded up to the smallest bucket
    that fits — so the set of compiled shapes is exactly ``buckets``, never
    one-per-request-size.  Pad rows are zeros and their labels are dropped;
    every real row's label is bitwise what one direct ``predict_labels``
    call on the concatenated stream would produce.

    ``predict_fn`` overrides the compute (the distributed serve path passes
    a pjit'd cell over the mesh — ``make_distributed_predict``); it must map
    a (b, dim) device/host array to (b,) labels.  Per-microbatch wall times
    (including dispatch + host sync) land in ``latencies_s`` for the bench.
    """

    def __init__(self, model: ServeModel, *, max_batch: int = 256,
                 min_bucket: int = 8, impl: str = "auto", predict_fn=None):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        self.model = model
        self.max_batch = max_batch
        self.buckets = default_buckets(max_batch, min_bucket)
        self._predict = (predict_fn if predict_fn is not None
                         else partial(predict_labels, model, impl=impl))
        self._pending: deque = deque()   # (ticket, rows ndarray, row_offset)
        self._pending_rows = 0
        self._need: dict[int, int] = {}          # ticket -> total rows
        self._parts: dict[int, list] = {}        # ticket -> [(offset, labels)]
        self._done: dict[int, np.ndarray] = {}
        self._next_ticket = 0
        self.latencies_s: list[float] = []
        self.stats = {"rows": 0, "microbatches": 0, "padded_rows": 0,
                      "bucket_counts": {}}

    def warmup(self, dtype=np.float32) -> None:
        """Pay every bucket shape's compile up front (honest tail latencies).

        Runs the queue's OWN ``predict_fn`` — a warm call through any other
        route can still miss the jit cache (a static arg passed explicitly
        and the same value as a default key separate entries).
        """
        dim = self.model.sv_x.shape[-1]
        for b in self.buckets:
            jax.block_until_ready(self._predict(np.zeros((b, dim), dtype)))

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def submit(self, x) -> int:
        """Enqueue one request of rows; returns its ticket."""
        x = np.asarray(x)
        if x.ndim != 2:
            raise ValueError(f"request must be (n, dim), got {x.shape}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._need[ticket] = x.shape[0]
        self._parts[ticket] = []
        if x.shape[0] == 0:
            self._finish(ticket)
        else:
            self._pending.append((ticket, x, 0))
            self._pending_rows += x.shape[0]
        while self._pending_rows >= self.max_batch:
            self._run_microbatch(self.max_batch)
        return ticket

    def drain(self) -> None:
        """Flush the ragged tail (padded to its bucket); all tickets resolve."""
        while self._pending_rows >= self.max_batch:
            self._run_microbatch(self.max_batch)
        if self._pending_rows:
            self._run_microbatch(self._pending_rows)

    def take(self, ticket: int) -> np.ndarray:
        """Labels for a resolved ticket (``drain`` first for partial tails)."""
        if ticket not in self._done:
            raise KeyError(f"ticket {ticket} not resolved — drain() first")
        return self._done.pop(ticket)

    def _finish(self, ticket: int) -> None:
        parts = sorted(self._parts.pop(ticket), key=lambda p: p[0])
        got = np.concatenate([p[1] for p in parts]) if parts else \
            np.zeros((0,), self.model.label_dtype)
        assert got.shape[0] == self._need.pop(ticket)
        self._done[ticket] = got

    def _run_microbatch(self, n_real: int) -> None:
        pad_to = self._bucket_for(n_real)
        slices, rows = [], []
        need = n_real
        while need:
            ticket, x, off = self._pending.popleft()
            take = min(need, x.shape[0])
            rows.append(x[:take])
            slices.append((ticket, off, take))
            if take < x.shape[0]:
                self._pending.appendleft((ticket, x[take:], off + take))
            need -= take
        self._pending_rows -= n_real
        xb = np.concatenate(rows) if len(rows) > 1 else rows[0]
        if pad_to > n_real:
            xb = np.concatenate(
                [xb, np.zeros((pad_to - n_real, xb.shape[1]), xb.dtype)])
        t0 = time.perf_counter()
        labels = self._predict(xb)
        labels = np.asarray(jax.block_until_ready(labels))
        self.latencies_s.append(time.perf_counter() - t0)
        self.stats["rows"] += n_real
        self.stats["microbatches"] += 1
        self.stats["padded_rows"] += pad_to - n_real
        self.stats["bucket_counts"][pad_to] = \
            self.stats["bucket_counts"].get(pad_to, 0) + 1
        pos = 0
        for ticket, off, take in slices:
            self._parts[ticket].append((off, labels[pos:pos + take]))
            pos += take
            done = sum(p[1].shape[0] for p in self._parts[ticket])
            if done == self._need[ticket]:
                self._finish(ticket)


def serve_requests(model: ServeModel, requests, **queue_kw) -> list[np.ndarray]:
    """Convenience wrapper: run a whole request list through a fresh
    ``BatchQueue``; returns per-request label arrays in submission order."""
    q = BatchQueue(model, **queue_kw)
    tickets = [q.submit(r) for r in requests]
    q.drain()
    return [q.take(t) for t in tickets]


def ragged_trace_sizes(total_rows: int, max_batch: int, rng) -> list[int]:
    """A deterministic ragged request-size trace summing to ``total_rows``
    (sizes drawn in [1, max_batch] from the caller's ``rng``)."""
    sizes, left = [], total_rows
    while left:
        s = int(min(left, rng.integers(1, max_batch + 1)))
        sizes.append(s)
        left -= s
    return sizes


def drive_trace(model: ServeModel, req_x, sizes, *, max_batch: int = 256,
                min_bucket: int = 8, impl: str = "auto",
                predict_fn=None) -> dict:
    """Push one request trace through a fresh warmed queue and measure it.

    The shared serve-loop used by ``launch.serve_svm`` and
    ``benchmarks.bench_serve``: submits ``sizes``-shaped requests from
    ``req_x`` in order, drains, ASSERTS the labels are bitwise one direct
    ``predict_labels`` call (the parity gate runs on every invocation), and
    returns rows/sec + p50/p99 microbatch latency + queue stats.
    """
    queue = BatchQueue(model, max_batch=max_batch, min_bucket=min_bucket,
                       impl=impl, predict_fn=predict_fn)
    queue.warmup()
    t0 = time.perf_counter()
    tickets, off = [], 0
    for s in sizes:
        tickets.append(queue.submit(req_x[off:off + s]))
        off += s
    queue.drain()
    labels = np.concatenate([queue.take(t) for t in tickets])
    wall = time.perf_counter() - t0
    direct = np.asarray(predict_labels(model, req_x[:off], impl=impl))
    assert (labels == direct).all(), "queue/direct parity violated"
    lat = np.asarray(queue.latencies_s)
    return {
        "rows": off, "requests": len(sizes),
        "bank_dtype": str(model.sv_x.dtype),
        "rows_per_s": round(off / wall, 1),
        "microbatches": queue.stats["microbatches"],
        "padded_rows": queue.stats["padded_rows"],
        "bucket_counts": queue.stats["bucket_counts"],
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
    }


# ---------------------------------------------------------------------------
# Checkpoint -> ServeModel
# ---------------------------------------------------------------------------

def load_serve_model(ckpt_dir: str, gamma, *, step: int | None = None,
                     bank_dtype=None) -> ServeModel:
    """Export a ``ServeModel`` straight from a training checkpoint.

    Works on any ``repro.checkpoint`` directory whose tree carries an
    ``SVMState`` under the ``state`` key — which is exactly what
    ``fit_stream`` / ``fit_multiclass_stream`` write (mid-epoch checkpoints
    included: serving ignores the epoch cursor/carry leaves).  The state
    template is rebuilt from the manifest's recorded leaf shapes/dtypes, so
    no training config is needed; binary vs multiclass is inferred from the
    bank's rank.  ``gamma`` is a hyperparameter, not a checkpointed array —
    pass the training value.
    """
    from .. import checkpoint as ckpt

    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise ValueError(f"{ckpt_dir}: no complete checkpoint found")
    manifest = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(manifest) as f:
            leaves = json.load(f).get("leaves")
    except FileNotFoundError:
        raise ValueError(f"{ckpt_dir}: step {step} has no manifest — not a "
                         "complete checkpoint") from None
    except json.JSONDecodeError as e:
        raise ValueError(f"{ckpt_dir}: step {step} manifest is corrupt "
                         f"({e})") from None
    if not isinstance(leaves, dict):
        raise ValueError(f"{ckpt_dir}: step {step} manifest records no "
                         "leaves — not a checkpoint this library wrote")
    needed = ("state/sv_x", "state/alpha", "state/count", "state/step",
              "state/n_inserts", "state/n_merges")
    missing = [k for k in needed if k not in leaves]
    if missing:
        raise ValueError(
            f"{ckpt_dir}: step {step} is not an SVM training checkpoint "
            f"(missing leaves {missing})")

    def sds(key):
        spec = leaves[key]
        return jax.ShapeDtypeStruct(tuple(spec["shape"]),
                                    jnp.dtype(spec["dtype"]))

    template = SVMState(
        sv_x=sds("state/sv_x"), alpha=sds("state/alpha"),
        count=sds("state/count"), step=sds("state/step"),
        n_inserts=sds("state/n_inserts"), n_merges=sds("state/n_merges"),
        kmat=sds("state/kmat") if "state/kmat" in leaves else None)
    state = ckpt.load(ckpt_dir, step, {"state": template})["state"]
    return export_model(state, gamma, bank_dtype=bank_dtype)
