"""Serving path: fused multiclass scoring + batched request queue.

The paper's end product is a model that is cheap to *evaluate* — merging
exists precisely so the SV bank stays small enough for fast prediction
(Picard 2018 builds budgeted SV banks expressly for high-throughput batched
scoring).  This module is the inference half of that bargain:

  * ``ServeModel`` — the exported, inference-only view of a trained
    ``SVMState``: the (C, slots, dim) SV bank (optionally quantized to
    bfloat16 — halves the bank's HBM and gather traffic), fp32 alphas with
    the active-count mask FOLDED IN at export time (inactive slots zeroed
    once, so the hot scoring path carries no masking), and the kernel width.
    Binary models export as C = 1 with ``binary=True`` (labels are ±1 signs
    instead of argmax ids).
  * ``predict_labels`` — ONE fused scoring program per microbatch: a single
    ``rbf_matrix`` launch against the flattened (C * slots, dim) bank
    (``kernels.ops.class_scores``, the same fold ``class_kernel_rows`` uses
    for training margins), fp32 alpha accumulation, argmax on device.
  * ``BatchQueue`` — microbatch assembly for a request stream: rows from
    submitted requests are packed into full ``max_batch`` microbatches in
    arrival order (a request may span microbatches; a microbatch may span
    requests), and the ragged tail pads up to a power-of-two *bucket* so the
    jit/pjit cache holds at most ``len(buckets)`` compiled shapes.  Because
    each row's scores depend only on that row and the bank, queue labels are
    bitwise the labels of one direct ``predict_labels`` call on the same
    rows — any arrival pattern, any bucket geometry (pinned by
    ``tests/core/test_serve_predict.py``).
  * ``load_serve_model`` — reads a ``fit_stream`` / ``fit_multiclass_stream``
    checkpoint (``repro.checkpoint`` layout) straight into a ``ServeModel``:
    the state template is reconstructed from the manifest's recorded leaf
    shapes/dtypes, so serving needs no training config object.

The distributed form (bank replicated per device, requests sharded over
every mesh axis — zero-collective scoring) is ``core.distributed``'s
``layout="serve"``; ``launch.serve --arch svm_bsgd`` is the driver and
``benchmarks/bench_serve.py`` the throughput/latency artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bsgd import SVMState
from ..kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class ServeModel:
    """Inference-only view of a trained budgeted SVM.

    Attributes:
      sv_x: (C, slots, dim) SV bank in the serving dtype (``bank_dtype`` at
        export; bfloat16 halves bank HBM).  Binary models are C = 1.
      alpha: (C, slots) float32 coefficients with inactive slots already
        zeroed — scoring never masks.
      count: (C,) int32 active-SV watermarks (reporting only).
      gamma: () float32 RBF width.
      binary: static — True when the model was a binary ``SVMState``; labels
        are then ±1 signs (``bsgd.predict`` convention) instead of argmax
        class ids.
    """

    sv_x: jax.Array
    alpha: jax.Array
    count: jax.Array
    gamma: jax.Array
    binary: bool = False

    @property
    def n_classes(self) -> int:
        return self.sv_x.shape[0]

    @property
    def label_dtype(self):
        return np.float32 if self.binary else np.int32


jax.tree_util.register_dataclass(
    ServeModel, ["sv_x", "alpha", "count", "gamma"], ["binary"])


def export_model(state: SVMState, gamma, *, bank_dtype=None) -> ServeModel:
    """Trained ``SVMState`` (binary or stacked multiclass) -> ``ServeModel``.

    ``bank_dtype`` quantizes the SV bank (e.g. ``"bfloat16"``); alphas are
    always carried in float32 and accumulation in scoring stays fp32, so
    quantization touches only the kernel's inputs.  The active-count mask is
    folded into alpha here — exactly the ``where(active, alpha, 0)`` the
    training-side decision functions apply per call.
    """
    binary = state.sv_x.ndim == 2
    sv_x, alpha, count = state.sv_x, state.alpha, state.count
    if binary:
        sv_x, alpha, count = sv_x[None], alpha[None], count[None]
    active = jnp.arange(alpha.shape[-1])[None, :] < count[:, None]
    alpha = jnp.where(active, alpha, 0.0).astype(jnp.float32)
    if bank_dtype is not None:
        sv_x = sv_x.astype(jnp.dtype(bank_dtype))
    return ServeModel(sv_x=sv_x, alpha=alpha,
                      count=count.astype(jnp.int32),
                      gamma=jnp.asarray(gamma, jnp.float32), binary=binary)


def serve_scores(model: ServeModel, x, *, impl: str = "auto"):
    """Per-class decision scores for a request batch: (n, d) -> (C, n).

    One fused kernel launch against the flattened (C * slots, dim) bank with
    fp32 accumulation (``kernels.ops.class_scores``).
    """
    return kops.class_scores(x, model.sv_x, model.alpha, model.gamma,
                             impl=impl)


@partial(jax.jit, static_argnames=("impl",))
def predict_labels(model: ServeModel, x, *, impl: str = "auto"):
    """The fused serve cell: labels for a request batch, argmax on device.

    Multiclass models return (n,) int32 class ids; binary models return the
    (n,) float32 ±1 signs of ``bsgd.predict``.
    """
    scores = serve_scores(model, x, impl=impl)
    if model.binary:
        return jnp.sign(scores[0]).astype(jnp.float32)
    return jnp.argmax(scores, axis=0).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "impl"))
def top_k_labels(model: ServeModel, x, *, k: int = 1, impl: str = "auto"):
    """Top-k class ids + decision scores per request row.

    x: (n, d) -> ``(ids, scores)`` of shape (n, k): per row, the k classes
    with the highest one-vs-rest decision scores, best first (ties broken by
    the lower class id, exactly like the argmax in ``predict_labels`` — so
    ``ids[:, 0]`` is bitwise ``predict_labels``).  One fused scoring launch;
    only the final ``lax.top_k`` is new work.  Multiclass models only: a
    binary model has one score, rank it yourself from ``serve_scores``.
    """
    if model.binary:
        raise ValueError("top_k_labels needs a multiclass model; binary "
                         "models have a single ±1 decision (predict_labels)")
    if not 1 <= k <= model.n_classes:
        raise ValueError(f"k={k} not in [1, n_classes={model.n_classes}]")
    scores = serve_scores(model, x, impl=impl)            # (C, n)
    vals, ids = jax.lax.top_k(scores.T, k)                # (n, k) each
    return ids.astype(jnp.int32), vals


@partial(jax.jit, static_argnames=("temperature", "impl"))
def predict_proba(model: ServeModel, x, *, temperature: float = 1.0,
                  impl: str = "auto"):
    """Calibrated softmax probabilities over the C class scores: (n, C).

    ``softmax(scores / temperature)`` per row — temperature scaling is the
    standard post-hoc calibration knob (T = 1 is the raw softmax; fit T on a
    held-out split to calibrate confidence).  Rows sum to 1 and the argmax
    is bitwise ``predict_labels`` for any positive temperature.  Multiclass
    models only.  ``temperature`` is static (one compile per distinct value
    — it is a per-deployment calibration constant, not per-request data).
    """
    if model.binary:
        raise ValueError("predict_proba needs a multiclass model")
    # T = 0 would be a silent NaN factory and T < 0 reverses the ranking
    # the docstring promises
    if temperature <= 0:
        raise ValueError(f"temperature={temperature} must be > 0")
    scores = serve_scores(model, x, impl=impl)            # (C, n)
    return jax.nn.softmax(scores.T / temperature, axis=-1)


# ---------------------------------------------------------------------------
# Batched request queue
# ---------------------------------------------------------------------------

class ServeTimeout(TimeoutError):
    """``take``/``drain`` timed out waiting for resolution.  The message
    names the ticket and the queue's in-flight depth (DESIGN.md §16);
    subclassing ``TimeoutError`` keeps pre-§16 handlers working."""


class ServeDeadline(TimeoutError):
    """A request's own ``deadline_s`` expired before its rows were
    dispatched — the queue shed it instead of serving stale results."""


class QueueFull(RuntimeError):
    """``submit`` refused because ``max_pending`` rows are already queued —
    bounded-pending load shedding instead of unbounded buffering."""


def _validate_request(x: np.ndarray, dim: int | None) -> None:
    """Shared ``submit`` validation (BatchQueue + AsyncBatchQueue): clear
    ``ValueError``s for malformed rows instead of a shape blowup (or a
    silent poisoned score) deep inside a fused microbatch."""
    if x.ndim != 2:
        raise ValueError(f"request must be (n, dim), got shape {x.shape}")
    if x.dtype == np.bool_ or not np.issubdtype(x.dtype, np.number):
        raise ValueError(
            f"request rows must be a numeric dtype, got {x.dtype}")
    if dim is not None and x.shape[1] != dim:
        raise ValueError(
            f"request dim {x.shape[1]} != model dim {dim}")
    if x.size and not np.isfinite(x).all():
        raise ValueError(
            "request rows contain non-finite values — refused at submit so "
            "a poisoned request can never surface as a non-finite score")


def default_buckets(max_batch: int, min_bucket: int = 8) -> tuple[int, ...]:
    """Power-of-two pad targets up to (and always including) ``max_batch``."""
    if min_bucket < 1:
        raise ValueError(f"min_bucket={min_bucket} < 1")
    buckets = []
    b = min_bucket
    while b < max_batch:
        buckets.append(b)
        b *= 2
    buckets.append(max_batch)
    return tuple(buckets)


def pad_bucket(n: int, buckets) -> int:
    """The smallest bucket that fits ``n`` rows (ascending ``buckets``; the
    largest bucket is the fallback for ``n > max``).  THE pad-target rule —
    shared by ``BatchQueue``, ``AsyncBatchQueue`` and ``drive_trace`` so the
    compiled-shape set can never silently diverge between them."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class BatchQueue:
    """Microbatch assembly over a request stream, one fused cell per batch.

    Requests (``(n_i, dim)`` row blocks) are packed into ``max_batch``-row
    microbatches in arrival order; a full microbatch runs immediately at
    ``submit`` (host memory stays O(max_batch), not O(stream)), and
    ``drain`` flushes the ragged remainder padded up to the smallest bucket
    that fits — so the set of compiled shapes is exactly ``buckets``, never
    one-per-request-size.  Pad rows are zeros and their labels are dropped;
    every real row's label is bitwise what one direct ``predict_labels``
    call on the concatenated stream would produce.

    ``predict_fn`` overrides the compute (the distributed serve path passes
    a pjit'd cell over the mesh — ``make_distributed_predict``); it must map
    a (b, dim) device/host array to (b,) labels.  Per-microbatch wall times
    (including dispatch + host sync) land in ``latencies_s`` for the bench.
    """

    def __init__(self, model: ServeModel, *, max_batch: int = 256,
                 min_bucket: int = 8, impl: str = "auto", predict_fn=None):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        self.model = model
        self.max_batch = max_batch
        self.buckets = default_buckets(max_batch, min_bucket)
        self._predict = (predict_fn if predict_fn is not None
                         else partial(predict_labels, model, impl=impl))
        self._pending: deque = deque()   # (ticket, rows ndarray, row_offset)
        self._pending_rows = 0
        self._need: dict[int, int] = {}          # ticket -> total rows
        self._parts: dict[int, list] = {}        # ticket -> [(offset, labels)]
        self._done: dict[int, np.ndarray] = {}
        self._next_ticket = 0
        self.latencies_s: list[float] = []
        self.stats = {"rows": 0, "microbatches": 0, "padded_rows": 0,
                      "bucket_counts": {}, "bucket_real_rows": {}}

    def warmup(self, dtype=np.float32) -> None:
        """Pay every bucket shape's compile up front (honest tail latencies).

        Runs the queue's OWN ``predict_fn`` — a warm call through any other
        route can still miss the jit cache (a static arg passed explicitly
        and the same value as a default key separate entries).
        """
        dim = self.model.sv_x.shape[-1]
        for b in self.buckets:
            jax.block_until_ready(self._predict(np.zeros((b, dim), dtype)))

    def _bucket_for(self, n: int) -> int:
        return pad_bucket(n, self.buckets)

    def submit(self, x) -> int:
        """Enqueue one request of rows; returns its ticket."""
        x = np.asarray(x)
        _validate_request(x, self.model.sv_x.shape[-1])
        ticket = self._next_ticket
        self._next_ticket += 1
        self._need[ticket] = x.shape[0]
        self._parts[ticket] = []
        if x.shape[0] == 0:
            self._finish(ticket)
        else:
            self._pending.append((ticket, x, 0))
            self._pending_rows += x.shape[0]
        while self._pending_rows >= self.max_batch:
            self._run_microbatch(self.max_batch)
        return ticket

    def drain(self) -> None:
        """Flush the ragged tail (padded to its bucket); all tickets resolve."""
        while self._pending_rows >= self.max_batch:
            self._run_microbatch(self.max_batch)
        if self._pending_rows:
            self._run_microbatch(self._pending_rows)

    def take(self, ticket: int) -> np.ndarray:
        """Labels for a resolved ticket (``drain`` first for partial tails)."""
        if ticket not in self._done:
            raise KeyError(f"ticket {ticket} not resolved — drain() first")
        return self._done.pop(ticket)

    def _finish(self, ticket: int) -> None:
        parts = sorted(self._parts.pop(ticket), key=lambda p: p[0])
        got = np.concatenate([p[1] for p in parts]) if parts else \
            np.zeros((0,), self.model.label_dtype)
        assert got.shape[0] == self._need.pop(ticket)
        self._done[ticket] = got

    def _run_microbatch(self, n_real: int) -> None:
        pad_to = self._bucket_for(n_real)
        slices, rows = [], []
        need = n_real
        while need:
            ticket, x, off = self._pending.popleft()
            take = min(need, x.shape[0])
            rows.append(x[:take])
            slices.append((ticket, off, take))
            if take < x.shape[0]:
                self._pending.appendleft((ticket, x[take:], off + take))
            need -= take
        self._pending_rows -= n_real
        xb = np.concatenate(rows) if len(rows) > 1 else rows[0]
        if pad_to > n_real:
            xb = np.concatenate(
                [xb, np.zeros((pad_to - n_real, xb.shape[1]), xb.dtype)])
        t0 = time.perf_counter()
        labels = self._predict(xb)
        labels = np.asarray(jax.block_until_ready(labels))
        self.latencies_s.append(time.perf_counter() - t0)
        self.stats["rows"] += n_real
        self.stats["microbatches"] += 1
        self.stats["padded_rows"] += pad_to - n_real
        self.stats["bucket_counts"][pad_to] = \
            self.stats["bucket_counts"].get(pad_to, 0) + 1
        self.stats["bucket_real_rows"][pad_to] = \
            self.stats["bucket_real_rows"].get(pad_to, 0) + n_real
        pos = 0
        for ticket, off, take in slices:
            self._parts[ticket].append((off, labels[pos:pos + take]))
            pos += take
            done = sum(p[1].shape[0] for p in self._parts[ticket])
            if done == self._need[ticket]:
                self._finish(ticket)


def serve_requests(model: ServeModel, requests, **queue_kw) -> list[np.ndarray]:
    """Convenience wrapper: run a whole request list through a fresh
    ``BatchQueue``; returns per-request label arrays in submission order."""
    q = BatchQueue(model, **queue_kw)
    tickets = [q.submit(r) for r in requests]
    q.drain()
    return [q.take(t) for t in tickets]


# ---------------------------------------------------------------------------
# Versioned model bank + continuous-batching async queue
# ---------------------------------------------------------------------------

class ModelBank:
    """A versioned, atomically hot-swappable ``ServeModel`` slot.

    The seam between a streaming trainer and a live serve queue:
    ``fit_stream(bank=..., publish_every=K)`` publishes an immutable snapshot
    every K chunks, and an ``AsyncBatchQueue`` built over the bank picks up
    the newest version per microbatch WITHOUT draining — hot-swap mid-trace.

    The slot is one ``(version, model)`` tuple swapped by a single reference
    assignment, so readers always see a consistent pair (never version *n*
    with model *n+1*); versions are strictly monotone.  ``ServeModel``s are
    immutable (frozen dataclass over immutable jax arrays), so a published
    snapshot can never change under a reader — the publisher's job is to
    hand over arrays nobody mutates or donates afterwards (the trainers copy
    out of their donated buffers first; see ``bsgd._make_publish``).
    """

    def __init__(self, model: ServeModel | None = None):
        self._slot = (1 if model is not None else 0, model)
        self._cv = threading.Condition()

    @property
    def version(self) -> int:
        """Version of the current model (0 = empty bank)."""
        return self._slot[0]

    def publish(self, model: ServeModel) -> int:
        """Swap in ``model`` as the new current version; returns it."""
        with self._cv:
            version = self._slot[0] + 1
            self._slot = (version, model)       # one atomic reference swap
            self._cv.notify_all()
        return version

    def current(self) -> tuple[int, ServeModel]:
        """The live ``(version, model)`` pair (lock-free hot path)."""
        slot = self._slot
        if slot[1] is None:
            raise LookupError("ModelBank is empty — publish() a model first")
        return slot

    def wait(self, version: int = 1,
             timeout: float | None = None) -> tuple[int, ServeModel]:
        """Block until the bank holds at least ``version``; returns the pair
        (raises TimeoutError on ``timeout``)."""
        with self._cv:
            if not self._cv.wait_for(lambda: self._slot[0] >= version,
                                     timeout):
                raise TimeoutError(
                    f"ModelBank still at version {self._slot[0]} < {version} "
                    f"after {timeout}s")
            return self._slot


class AsyncBatchQueue:
    """Continuous batching: a dispatcher thread owns the device, submitters
    never compute.

    ``submit`` is thread-safe and returns a ticket immediately — rows land
    in a pending ring and the dispatcher assembles microbatches out of
    WHATEVER is pending whenever the device frees up (up to ``max_batch``
    rows per launch, ragged tails coalesced across requests before padding,
    arrival order preserved).  Two launches are kept in flight: while
    microbatch *i* executes, the dispatcher assembles AND dispatches *i+1*,
    then resolves *i* — host assembly, the host↔device sync, and the label
    scatter all overlap device compute instead of serializing with it (the
    ``BatchQueue`` gap this class exists to close).  Dispatch is
    WAITER-GATED: a microbatch launches only when a full ``max_batch`` is
    pending, or someone is blocked in ``take``/``drain``, or the queue is
    closing.  Submit-ahead traces therefore coalesce into full launches
    instead of trickling out as many small ones (the dispatcher never does
    MORE launches than a sync ``BatchQueue`` would for the same trace),
    while a live caller blocking on its ticket still gets its rows
    dispatched immediately — no artificial batching delay where latency
    matters.

    Each row's scores depend only on that row and the bank, so labels are
    BITWISE one direct ``predict_labels`` call on the same rows for any
    arrival pattern/interleaving (same guarantee, and same pad-bucket rule
    — ``pad_bucket`` — as ``BatchQueue``).

    ``model`` may be a ``ServeModel`` (fixed) or a ``ModelBank``: with a
    bank, the dispatcher re-reads ``bank.current()`` per microbatch, so a
    version published mid-trace is picked up at the next launch without
    draining — every row of one microbatch is scored by exactly one version
    (recorded in ``stats["versions"]``).  The single-model predict path is
    AOT-compiled per bucket shape (``predict_labels.lower(...).compile()``)
    — hot-swapped snapshots share the executables because shapes/dtypes
    don't change across versions.  ``predict_fn`` overrides compute exactly
    as in ``BatchQueue`` (fixed model only — the distributed serve path).

    ``take``/``drain`` block until resolution (optional ``timeout``); a
    dispatcher failure re-raises on the caller's thread, never hangs.  Use
    as a context manager or call ``close()`` — pending work is flushed, the
    thread joins.

    Overload protection (DESIGN.md §16): ``max_pending`` bounds the pending
    row buffer — ``submit`` beyond it raises ``QueueFull`` immediately
    (load shedding) instead of buffering without bound.  A per-request
    ``submit(..., deadline_s=...)`` sheds the request if its rows are still
    undispatched when the deadline passes: ``take`` then raises
    ``ServeDeadline``.  ``take``/``drain`` timeouts raise ``ServeTimeout``
    naming the ticket and the in-flight depth.  All three are typed results,
    never hangs — a supervisor can catch and retry/degrade.
    """

    def __init__(self, model: ServeModel | ModelBank, *, max_batch: int = 256,
                 min_bucket: int = 8, impl: str = "auto", predict_fn=None,
                 max_pending: int | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        if max_pending is not None and max_pending < max_batch:
            raise ValueError(f"max_pending={max_pending} < "
                             f"max_batch={max_batch} could never fill "
                             "a full microbatch")
        self._bank = model if isinstance(model, ModelBank) else None
        self.model = None if self._bank is not None else model
        if self._bank is not None and predict_fn is not None:
            raise ValueError("predict_fn requires a fixed ServeModel — a "
                             "ModelBank swaps models per microbatch")
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.buckets = default_buckets(max_batch, min_bucket)
        self._impl = impl
        self._predict_fn = predict_fn
        self._compiled: dict = {}     # (bucket, bank signature) -> executable
        self._cv = threading.Condition()
        self._pending: deque = deque()  # (ticket, rows, row_offset, deadline)
        self._pending_rows = 0
        self._need: dict[int, int] = {}
        self._parts: dict[int, list] = {}
        self._done: dict[int, np.ndarray] = {}
        self._dead: dict[int, str] = {}   # ticket -> shed reason
        self._next_ticket = 0
        self._unresolved = 0
        self._waiters = 0
        self._error: BaseException | None = None
        self._stop = False
        self.latencies_s: list[float] = []
        self.stats = {"rows": 0, "microbatches": 0, "padded_rows": 0,
                      "bucket_counts": {}, "bucket_real_rows": {},
                      "versions": {}}
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        daemon=True, name="serve-dispatch")
        self._thread.start()

    # -- submitter side ------------------------------------------------------

    def submit(self, x, *, deadline_s: float | None = None) -> int:
        """Enqueue one request of rows; returns its ticket immediately.

        ``deadline_s``: optional per-request budget (seconds from now).  If
        the rows are still undispatched when it expires, the request is shed
        and ``take`` raises ``ServeDeadline`` instead of returning stale
        labels.  Raises ``QueueFull`` when ``max_pending`` rows are already
        buffered (bounded-pending load shedding).
        """
        x = np.asarray(x)
        try:
            dim = self._current()[1].sv_x.shape[-1]
        except LookupError:
            dim = None                     # empty bank — no dim to pin yet
        _validate_request(x, dim)
        dl = (None if deadline_s is None
              else time.monotonic() + float(deadline_s))
        with self._cv:
            self._check_error()
            if self._stop:
                raise RuntimeError("AsyncBatchQueue is closed")
            if (self.max_pending is not None and x.shape[0]
                    and self._pending_rows + x.shape[0] > self.max_pending):
                raise QueueFull(
                    f"{self._pending_rows} rows pending + {x.shape[0]} new "
                    f"> max_pending={self.max_pending} — request shed")
            ticket = self._next_ticket
            self._next_ticket += 1
            self._need[ticket] = x.shape[0]
            self._parts[ticket] = []
            if x.shape[0] == 0:
                self._done[ticket] = np.zeros((0,), self._label_dtype())
                self._need.pop(ticket)
                self._parts.pop(ticket)
            else:
                self._unresolved += 1
                self._pending.append((ticket, x, 0, dl))
                self._pending_rows += x.shape[0]
                # only wake the dispatcher when the gate is actually open
                # (full batch, or a waiter already blocked) — an
                # unconditional notify would bounce it awake on every
                # sub-batch submit just to re-check and sleep
                if self._pending_rows >= self.max_batch or self._waiters:
                    self._cv.notify_all()
            return ticket

    def take(self, ticket: int, timeout: float | None = None) -> np.ndarray:
        """Labels for a ticket; blocks until its last microbatch resolves.

        Raises ``ServeDeadline`` if the ticket was shed (its ``deadline_s``
        expired undispatched), ``ServeTimeout`` on ``timeout``.
        """
        def ready():
            return ticket in self._done or ticket in self._dead

        def timed_out():
            raise ServeTimeout(
                f"ticket {ticket} unresolved after {timeout}s "
                f"({self._unresolved} requests in flight, "
                f"{self._pending_rows} rows pending)")

        self._await(ready, timeout, timed_out)
        with self._cv:
            if ticket in self._dead:
                raise ServeDeadline(
                    f"ticket {ticket} shed: {self._dead.pop(ticket)}")
            return self._done.pop(ticket)

    def drain(self, timeout: float | None = None) -> None:
        """Block until every submitted row is scored, resolved or shed."""
        def ready():
            return self._unresolved == 0

        def timed_out():
            raise ServeTimeout(
                f"{self._unresolved} requests unresolved after {timeout}s "
                f"({self._pending_rows} rows pending)")

        self._await(ready, timeout, timed_out)

    def _await(self, ready, timeout, timed_out) -> None:
        """Wait (as a gate-opening waiter) until ``ready()`` under the lock,
        re-checking at request deadlines so shed tickets surface without a
        dispatcher wakeup; calls ``timed_out()`` past ``timeout``."""
        deadline_t = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            self._waiters += 1          # un-gate dispatch of partial batches
            self._cv.notify_all()
            try:
                while True:
                    self._purge_expired_locked()
                    self._check_error()
                    if ready():
                        return
                    now = time.monotonic()
                    if deadline_t is not None and now >= deadline_t:
                        timed_out()
                    bounds = [t for t in (deadline_t,
                                          self._earliest_deadline_locked())
                              if t is not None]
                    self._cv.wait(max(min(bounds) - now, 0.0) + 1e-3
                                  if bounds else None)
            finally:
                self._waiters -= 1

    def close(self, timeout: float | None = 30.0) -> None:
        """Flush pending work, stop and join the dispatcher (idempotent)."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def warmup(self, dtype=np.float32) -> None:
        """Pay every bucket shape's compile up front (honest tail latencies).

        Compiles through the queue's OWN per-bucket path (the AOT executable
        cache, or the caller's ``predict_fn``) — see ``BatchQueue.warmup``
        for the jit-cache-key footgun this sidesteps.
        """
        version, model = self._current()
        dim = model.sv_x.shape[-1]
        for b in self.buckets:
            jax.block_until_ready(
                self._score(model, np.zeros((b, dim), dtype), b))

    # -- dispatcher side -----------------------------------------------------

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError("AsyncBatchQueue dispatcher failed") \
                from self._error

    def _label_dtype(self):
        try:
            return self._current()[1].label_dtype
        except LookupError:
            return np.int32

    def _current(self) -> tuple:
        if self._bank is not None:
            return self._bank.current()
        return None, self.model

    def _score(self, model: ServeModel, xb: np.ndarray, bucket: int):
        """One microbatch launch (async dispatch — no host sync here)."""
        if self._predict_fn is not None:
            return self._predict_fn(xb)
        sig = (bucket, str(xb.dtype), model.sv_x.shape,
               str(model.sv_x.dtype), model.binary)
        fn = self._compiled.get(sig)
        if fn is None:
            fn = predict_labels.lower(model, xb, impl=self._impl).compile()
            self._compiled[sig] = fn
        return fn(model, xb)

    def _earliest_deadline_locked(self) -> float | None:
        dls = [e[3] for e in self._pending if e[3] is not None]
        return min(dls) if dls else None

    def _purge_expired_locked(self) -> None:
        """Shed pending requests whose deadline passed (caller holds the
        lock): the ticket is marked dead, its undispatched rows dropped, and
        ``take`` raises ``ServeDeadline`` for it.  In-flight slices of a
        shed ticket resolve into the void (``_resolve`` skips dead)."""
        if self._earliest_deadline_locked() is None:
            return
        now = time.monotonic()
        kept: deque = deque()
        shed = False
        for ticket, x, off, dl in self._pending:
            if dl is None or now < dl:
                kept.append((ticket, x, off, dl))
                continue
            shed = True
            self._pending_rows -= x.shape[0]
            self._dead[ticket] = (
                f"deadline expired with {x.shape[0]} rows undispatched")
            self._need.pop(ticket, None)
            self._parts.pop(ticket, None)
            self._unresolved -= 1
        if shed:
            self._pending = kept
            self._cv.notify_all()

    def _pop_rows_locked(self):
        """Take up to ``max_batch`` live pending rows (caller holds the
        lock); expired requests are shed first, never launched."""
        self._purge_expired_locked()
        n_real = min(self._pending_rows, self.max_batch)
        rows, slices, need = [], [], n_real
        while need:
            ticket, x, off, dl = self._pending.popleft()
            take = min(need, x.shape[0])
            rows.append(x[:take])
            slices.append((ticket, off, take))
            if take < x.shape[0]:
                self._pending.appendleft((ticket, x[take:], off + take, dl))
            need -= take
        self._pending_rows -= n_real
        return rows, slices, n_real

    def _launch(self, rows, slices, n_real):
        """Assemble + dispatch one microbatch (outside the lock)."""
        pad_to = pad_bucket(n_real, self.buckets)
        xb = np.zeros((pad_to, rows[0].shape[1]), rows[0].dtype)
        pos = 0
        for r in rows:
            xb[pos:pos + r.shape[0]] = r
            pos += r.shape[0]
        # a fixed model needs no bank read in the hot loop
        version, model = ((None, self.model) if self._bank is None
                          else self._bank.current())
        t0 = time.perf_counter()
        labels = self._score(model, xb, pad_to)
        return labels, slices, n_real, pad_to, version, t0

    def _resolve(self, inflight) -> None:
        """Sync one launch, scatter its labels, resolve finished tickets."""
        labels, slices, n_real, pad_to, version, t0 = inflight
        labels = np.asarray(labels)               # blocks until scored
        lat = time.perf_counter() - t0
        parts_by_slice = []                       # slice outside the lock
        pos = 0
        for ticket, off, take in slices:
            parts_by_slice.append(labels[pos:pos + take])
            pos += take
        with self._cv:
            self.latencies_s.append(lat)
            st = self.stats
            st["rows"] += n_real
            st["microbatches"] += 1
            st["padded_rows"] += pad_to - n_real
            st["bucket_counts"][pad_to] = \
                st["bucket_counts"].get(pad_to, 0) + 1
            st["bucket_real_rows"][pad_to] = \
                st["bucket_real_rows"].get(pad_to, 0) + n_real
            if version is not None:
                st["versions"][version] = st["versions"].get(version, 0) + 1
            for (ticket, off, take), part in zip(slices, parts_by_slice):
                if ticket in self._dead:
                    continue   # shed mid-flight — drop its labels
                need = self._need[ticket]
                if off == 0 and take == need:     # single-part fast path
                    self._done[ticket] = part
                    self._need.pop(ticket)
                    self._parts.pop(ticket)
                    self._unresolved -= 1
                    continue
                parts = self._parts[ticket]
                parts.append((off, part))
                if sum(p[1].shape[0] for p in parts) == need:
                    parts.sort(key=lambda p: p[0])
                    self._done[ticket] = np.concatenate([p[1] for p in parts])
                    self._need.pop(ticket)
                    self._parts.pop(ticket)
                    self._unresolved -= 1
            self._cv.notify_all()

    def _dispatch_loop(self) -> None:
        inflight = None
        try:
            while True:
                batch = None
                with self._cv:
                    # dispatchable = a full batch pends, or someone is
                    # blocked on the result (take/drain/close) — partial
                    # batches otherwise keep coalescing
                    def dispatchable():
                        return self._pending_rows and (
                            self._pending_rows >= self.max_batch
                            or self._waiters or self._stop)
                    while (not dispatchable() and not self._stop
                           and inflight is None):
                        self._cv.wait()
                    if (self._stop and not self._pending_rows
                            and inflight is None):
                        return
                    if dispatchable():
                        batch = self._pop_rows_locked()
                # dispatch the NEXT microbatch before syncing the previous:
                # the device is never idle while the host scatters labels
                # (a purge can shed every pending row — then there is
                # nothing to launch)
                launched = (self._launch(*batch)
                            if batch is not None and batch[2] else None)
                if inflight is not None:
                    self._resolve(inflight)
                inflight = launched
        except BaseException as e:  # noqa: BLE001 — surfaced to callers
            with self._cv:
                self._error = e
                self._cv.notify_all()


def ragged_trace_sizes(total_rows: int, max_batch: int, rng) -> list[int]:
    """A deterministic ragged request-size trace summing to ``total_rows``
    (sizes drawn in [1, max_batch] from the caller's ``rng``)."""
    sizes, left = [], total_rows
    while left:
        s = int(min(left, rng.integers(1, max_batch + 1)))
        sizes.append(s)
        left -= s
    return sizes


def drive_trace(model: ServeModel, req_x, sizes, *, max_batch: int = 256,
                min_bucket: int = 8, impl: str = "auto", predict_fn=None,
                queue: str = "sync") -> dict:
    """Push one request trace through a fresh warmed queue and measure it.

    The shared serve-loop used by ``launch.serve_svm`` and
    ``benchmarks.bench_serve``: submits ``sizes``-shaped requests from
    ``req_x`` in order, drains, ASSERTS the labels are bitwise one direct
    ``predict_labels`` call (the parity gate runs on every invocation), and
    returns rows/sec + p50/p99 microbatch latency + queue stats —
    including ``pad_waste_frac`` (fraction of scored rows that were
    padding) and per-bucket ``bucket_occupancy`` (real rows / bucket
    capacity), which make tail padding at non-power-of-two traces visible.

    ``queue="async"`` drives the same trace through an ``AsyncBatchQueue``
    (continuous batching; same parity gate) — with a ``ModelBank`` in
    ``model``, its CURRENT snapshot anchors the parity call even if the
    bank keeps moving mid-trace (per-row labels are version-consistent,
    so parity is asserted only on a fixed model).
    """
    bank = model if isinstance(model, ModelBank) else None
    fixed = bank is None
    if queue == "async":
        q = AsyncBatchQueue(model, max_batch=max_batch,
                            min_bucket=min_bucket, impl=impl,
                            predict_fn=predict_fn)
    elif queue == "sync":
        if bank is not None:
            raise ValueError("queue='sync' needs a fixed ServeModel")
        q = BatchQueue(model, max_batch=max_batch, min_bucket=min_bucket,
                       impl=impl, predict_fn=predict_fn)
    else:
        raise ValueError(f"queue={queue!r}: expected 'sync' or 'async'")
    q.warmup()
    t0 = time.perf_counter()
    tickets, off = [], 0
    for s in sizes:
        tickets.append(q.submit(req_x[off:off + s]))
        off += s
    q.drain()
    labels = np.concatenate([q.take(t) for t in tickets])
    wall = time.perf_counter() - t0
    if queue == "async":
        q.close()
    if fixed:
        direct = np.asarray(predict_labels(model, req_x[:off], impl=impl))
        assert (labels == direct).all(), "queue/direct parity violated"
    lat = np.asarray(q.latencies_s)
    padded = q.stats["padded_rows"]
    occupancy = {
        b: round(q.stats["bucket_real_rows"].get(b, 0) / (n * b), 4)
        for b, n in sorted(q.stats["bucket_counts"].items())
    }
    out = {
        "rows": off, "requests": len(sizes), "queue": queue,
        "bank_dtype": str((bank.current()[1] if bank is not None
                           else model).sv_x.dtype),
        "rows_per_s": round(off / wall, 1),
        "microbatches": q.stats["microbatches"],
        "padded_rows": padded,
        "pad_waste_frac": round(padded / (off + padded), 4) if off else 0.0,
        "bucket_counts": q.stats["bucket_counts"],
        "bucket_occupancy": occupancy,
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
    }
    if queue == "async" and q.stats["versions"]:
        out["versions"] = {int(k): v for k, v in q.stats["versions"].items()}
    return out


# ---------------------------------------------------------------------------
# Checkpoint -> ServeModel
# ---------------------------------------------------------------------------

def load_serve_model(ckpt_dir: str, gamma, *, step: int | None = None,
                     bank_dtype=None) -> ServeModel:
    """Export a ``ServeModel`` straight from a training checkpoint.

    Works on any ``repro.checkpoint`` directory whose tree carries an
    ``SVMState`` under the ``state`` key — which is exactly what
    ``fit_stream`` / ``fit_multiclass_stream`` write (mid-epoch checkpoints
    included: serving ignores the epoch cursor/carry leaves).  The state
    template is rebuilt from the manifest's recorded leaf shapes/dtypes, so
    no training config is needed; binary vs multiclass is inferred from the
    bank's rank.  ``gamma`` is a hyperparameter, not a checkpointed array —
    pass the training value.
    """
    from .. import checkpoint as ckpt

    if step is None:
        step = ckpt.latest_step(ckpt_dir)
        if step is None:
            raise ValueError(f"{ckpt_dir}: no complete checkpoint found")
    manifest = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    try:
        with open(manifest) as f:
            leaves = json.load(f).get("leaves")
    except FileNotFoundError:
        raise ValueError(f"{ckpt_dir}: step {step} has no manifest — not a "
                         "complete checkpoint") from None
    except json.JSONDecodeError as e:
        raise ValueError(f"{ckpt_dir}: step {step} manifest is corrupt "
                         f"({e})") from None
    if not isinstance(leaves, dict):
        raise ValueError(f"{ckpt_dir}: step {step} manifest records no "
                         "leaves — not a checkpoint this library wrote")
    needed = ("state/sv_x", "state/alpha", "state/count", "state/step",
              "state/n_inserts", "state/n_merges")
    missing = [k for k in needed if k not in leaves]
    if missing:
        raise ValueError(
            f"{ckpt_dir}: step {step} is not an SVM training checkpoint "
            f"(missing leaves {missing})")

    def sds(key):
        spec = leaves[key]
        return jax.ShapeDtypeStruct(tuple(spec["shape"]),
                                    jnp.dtype(spec["dtype"]))

    template = SVMState(
        sv_x=sds("state/sv_x"), alpha=sds("state/alpha"),
        count=sds("state/count"), step=sds("state/step"),
        n_inserts=sds("state/n_inserts"), n_merges=sds("state/n_merges"),
        kmat=sds("state/kmat") if "state/kmat" in leaves else None)
    state = ckpt.load(ckpt_dir, step, {"state": template})["state"]
    return export_model(state, gamma, bank_dtype=bank_dtype)
