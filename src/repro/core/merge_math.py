"""Closed-form math of the support-vector merging problem (paper §2-3).

Merging two support vectors ``(alpha_a, x_a)`` and ``(alpha_b, x_b)`` under a
Gaussian kernel ``k(x, x') = exp(-gamma * ||x - x'||^2)`` reduces to a 1-D
problem on the segment ``z = h * x_a + (1 - h) * x_b``.  With

    m     = alpha_a / (alpha_a + alpha_b)        (relative coefficient mass)
    kappa = k(x_a, x_b)                          (cosine of the RKHS angle)

the objective (paper Alg. 1, line 7) is

    h*(m, kappa) = argmax_{h in [0,1]}  s_{m,kappa}(h)
    s_{m,kappa}(h) = m * kappa^{(1-h)^2} + (1-m) * kappa^{h^2}

and the optimal merged coefficient / weight degradation follow in closed form:

    alpha_z = alpha_a * kappa^{(1-h)^2} + alpha_b * kappa^{h^2}
    WD      = alpha_a^2 + alpha_b^2 + 2*alpha_a*alpha_b*kappa - alpha_z^2

``WD`` normalized by ``(alpha_a + alpha_b)^2`` depends only on ``(m, kappa)``:

    WD_norm(m, kappa) = m^2 + (1-m)^2 + 2*m*(1-m)*kappa - s_{m,kappa}(h*)^2

Everything here is pure jnp and differentiable; the golden section search is a
fixed-iteration ``lax.fori_loop`` (iteration count derived from the target
precision), so it jits, vmaps and lowers to TPU without dynamic shapes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# Golden ratio constants.
INVPHI = (math.sqrt(5.0) - 1.0) / 2.0  # 1/phi ~ 0.618034
# kappa = exp(-gamma d^2) is clipped away from 0 so log(kappa) stays finite.
KAPPA_MIN = 1e-30
# Paper Lemma 1: s_{m,kappa} is unimodal iff kappa > e^{-2}.
KAPPA_UNIMODAL = math.exp(-2.0)

# Paper precisions: runtime GSS eps=0.01, table-build GSS eps=1e-10.
EPS_STANDARD = 1e-2
EPS_PRECISE = 1e-10


def gss_num_iters(eps: float) -> int:
    """Iterations for the bracket [0,1] to shrink below ``eps`` (width *= 1/phi)."""
    return int(math.ceil(math.log(eps) / math.log(INVPHI)))


def kappa_pow(kappa, expo):
    """kappa**expo computed as exp(expo * log kappa), safe at kappa -> 0."""
    kappa = jnp.clip(kappa, KAPPA_MIN, 1.0)
    return jnp.exp(expo * jnp.log(kappa))


def s_objective(h, m, kappa):
    """s_{m,kappa}(h) = m kappa^{(1-h)^2} + (1-m) kappa^{h^2} (to maximize)."""
    return m * kappa_pow(kappa, (1.0 - h) ** 2) + (1.0 - m) * kappa_pow(kappa, h**2)


@partial(jax.jit, static_argnames=("eps",))
def golden_section_search(m, kappa, eps: float = EPS_STANDARD):
    """Maximize ``s_{m,kappa}`` over [0, 1] by golden section search.

    Fully vectorized over the (broadcasted) shapes of ``m`` and ``kappa``; the
    iteration count is static (derived from ``eps``) so the loop unrolls into a
    fixed-depth dependency chain, exactly like the reference solver's cost
    model (~10 sequential evaluations for eps=0.01, ~48 for eps=1e-10).
    """
    m, kappa = jnp.broadcast_arrays(jnp.asarray(m, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32),
                                    jnp.asarray(kappa))
    n_iters = gss_num_iters(eps)
    a = jnp.zeros_like(m)
    b = jnp.ones_like(m)

    def body(_, ab):
        a, b = ab
        span = b - a
        c = b - span * INVPHI
        d = a + span * INVPHI
        fc = s_objective(c, m, kappa)
        fd = s_objective(d, m, kappa)
        go_left = fc > fd  # keep [a, d] if the left probe wins, else [c, b]
        return jnp.where(go_left, a, c), jnp.where(go_left, d, b)

    a, b = jax.lax.fori_loop(0, n_iters, body, (a, b))
    return 0.5 * (a + b)


def wd_norm_at(h, m, kappa):
    """Normalized weight degradation at merge coefficient ``h``.

    WD / (alpha_a + alpha_b)^2 = m^2 + (1-m)^2 + 2 m (1-m) kappa - s(h)^2.
    """
    s = s_objective(h, m, kappa)
    return m**2 + (1.0 - m) ** 2 + 2.0 * m * (1.0 - m) * kappa - s**2


@partial(jax.jit, static_argnames=("eps",))
def solve_merge(m, kappa, eps: float = EPS_STANDARD):
    """(h*, WD_norm(m, kappa)) via golden section search."""
    h = golden_section_search(m, kappa, eps=eps)
    return h, wd_norm_at(h, m, kappa)


def merge_alpha_z(alpha_a, alpha_b, kappa, h):
    """Optimal merged coefficient for z = h x_a + (1-h) x_b (paper Alg.1 l.8)."""
    return alpha_a * kappa_pow(kappa, (1.0 - h) ** 2) + alpha_b * kappa_pow(kappa, h**2)


def weight_degradation(alpha_a, alpha_b, kappa, alpha_z):
    """||Delta||^2 = alpha_a^2 + alpha_b^2 + 2 alpha_a alpha_b kappa - alpha_z^2."""
    return alpha_a**2 + alpha_b**2 + 2.0 * alpha_a * alpha_b * kappa - alpha_z**2


def merge_point(h, x_a, x_b):
    """z = h * x_a + (1 - h) * x_b."""
    return h * x_a + (1.0 - h) * x_b


def gss_numpy(m, kappa, eps: float = EPS_PRECISE):
    """float64 numpy golden section search (vectorized), for table precompute.

    fp32 GSS saturates at ~sqrt(eps_f32) ~ 3e-4 argmax precision near a smooth
    maximum (function-value comparisons drown in rounding noise), so the
    paper's eps=1e-10 table build runs in doubles — exactly like the reference
    C++ implementation.  One-time offline cost, not a runtime path.
    """
    import numpy as np

    m = np.asarray(m, np.float64)
    kappa = np.clip(np.asarray(kappa, np.float64), KAPPA_MIN, 1.0)
    lk = np.log(kappa)

    def s(h):
        return m * np.exp((1.0 - h) ** 2 * lk) + (1.0 - m) * np.exp(h**2 * lk)

    a = np.zeros_like(m)
    b = np.ones_like(m)
    for _ in range(gss_num_iters(eps)):
        span = b - a
        c = b - span * INVPHI
        d = a + span * INVPHI
        go_left = s(c) > s(d)
        a = np.where(go_left, a, c)
        b = np.where(go_left, d, b)
    return 0.5 * (a + b)


def brute_force_h(m, kappa, n_grid: int = 200_001):
    """Dense-grid argmax oracle for tests (not jitted on purpose: fp64 numpy)."""
    import numpy as np

    hs = np.linspace(0.0, 1.0, n_grid)
    kk = max(float(kappa), KAPPA_MIN)
    vals = float(m) * kk ** ((1.0 - hs) ** 2) + (1.0 - float(m)) * kk ** (hs**2)
    return float(hs[int(np.argmax(vals))])


def s_second_derivative_at_half(kappa):
    """d^2/dh^2 s_{1/2,kappa}(h) at h = 1/2 (sign flips at kappa = e^{-2}).

    For m = 1/2:  s(h) = (kappa^{(1-h)^2} + kappa^{h^2}) / 2, and
    s''(1/2) = kappa^{1/4} * log(kappa) * (2 + log(kappa))  (paper Lemma 1:
    s''_{1/2,kappa}(1/2) > 0  <=>  kappa < e^{-2}).
    """
    lk = jnp.log(jnp.clip(kappa, KAPPA_MIN, 1.0))
    return kappa_pow(kappa, 0.25) * lk * (2.0 + lk)
