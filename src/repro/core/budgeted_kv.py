"""Beyond-paper transfer: budgeted KV cache with merge-based maintenance.

The analogy to the paper (DESIGN.md §4): a decode-time KV cache is a kernel
expansion — keys are support vectors, values are (vector-valued)
coefficients, and the attention kernel exp(q.k) is locally Gaussian in k.
Evicting cache entries = BSGD's "removal"; the paper showed *merging* is
strictly better, and that the merge coefficient can be a precomputed lookup.

Maintenance of a full cache mirrors paper Alg. 1:
  1. fix the entry with minimal importance (||v||, the alpha analogue),
  2. kappa_j = exp(-gamma ||k_min - k_j||^2) via the same rbf kernels,
  3. m = |v_min| / (|v_min| + |v_j|); h from the SAME MergeLookupTable,
  4. merged entry: k_z = h k_min + (1-h) k_j,
     v_z = v_min kappa^{(1-h)^2} + v_j kappa^{h^2}   (alpha_z, per channel).

This gives O(budget) decode attention for arbitrarily long generations —
the sub-quadratic-memory option noted for the full-attention archs.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from . import merge_math
from .lookup import MergeLookupTable


class KVBudgetState(NamedTuple):
    k: jax.Array      # (B, W, H, hd)
    v: jax.Array      # (B, W, H, hd)
    count: jax.Array  # () int32 — filled slots (same across batch/heads)


def init_kv_state(batch: int, budget: int, n_heads: int, head_dim: int, dtype):
    return KVBudgetState(k=jnp.zeros((batch, budget, n_heads, head_dim), dtype),
                         v=jnp.zeros((batch, budget, n_heads, head_dim), dtype),
                         count=jnp.zeros((), jnp.int32))


def _merge_one(k_bh, v_bh, count, gamma, table: MergeLookupTable):
    """Merge the least-important pair for one (batch, head): k/v (W, hd)."""
    w = k_bh.shape[0]
    idx = jnp.arange(w)
    active = idx < count
    imp = jnp.where(active, jnp.linalg.norm(v_bh, axis=-1), jnp.inf)
    i_min = jnp.argmin(imp)
    a_min = imp[i_min]

    kappa = kops.rbf_row(k_bh, k_bh[i_min], gamma, impl="ref")
    a_j = jnp.where(active, jnp.linalg.norm(v_bh, axis=-1), 0.0)
    m = jnp.clip(a_min / jnp.where(a_min + a_j == 0, 1.0, a_min + a_j), 0, 1)
    kap = jnp.clip(kappa, 0.0, 1.0)
    wd = (a_min + a_j) ** 2 * table.lookup_wd_norm(m, kap)
    wd = jnp.where(active & (idx != i_min), wd, jnp.inf)
    j = jnp.argmin(wd)

    h = table.lookup_h(m[j], kap[j])
    k_z = merge_math.merge_point(h, k_bh[i_min], k_bh[j])
    # Value combination — a documented ADAPTATION of the paper's alpha_z:
    # alpha_z's kappa^h^2 decay is exact for LINEAR kernel fields (the SVM
    # case) but systematically loses value mass under softmax-normalized
    # attention; the importance-weighted convex mean preserves it and is
    # what beats eviction empirically (see examples/budgeted_kv_serve.py).
    v_z = (a_min * v_bh[i_min] + a_j[j] * v_bh[j]) / (a_min + a_j[j] + 1e-9)

    last = count - 1
    lo = jnp.minimum(i_min, j)
    hi = jnp.maximum(i_min, j)
    k_bh = k_bh.at[lo].set(k_z).at[hi].set(k_bh[last])
    v_bh = v_bh.at[lo].set(v_z).at[hi].set(v_bh[last])
    v_bh = v_bh.at[last].set(0.0)
    return k_bh, v_bh


def _evict_one(k_bh, v_bh, count):
    """Removal baseline (what the paper shows merging beats): drop min-||v||."""
    w = k_bh.shape[0]
    imp = jnp.where(jnp.arange(w) < count, jnp.linalg.norm(v_bh, axis=-1),
                    jnp.inf)
    i_min = jnp.argmin(imp)
    last = count - 1
    k_bh = k_bh.at[i_min].set(k_bh[last])
    v_bh = v_bh.at[i_min].set(v_bh[last]).at[last].set(0.0)
    return k_bh, v_bh


@partial(jax.jit, static_argnames=("policy",))
def kv_append(state: KVBudgetState, k_new, v_new, gamma, table: MergeLookupTable,
              *, policy: str = "merge"):
    """Append one token's K/V; merge (or evict) per (batch, head) at budget.

    k_new/v_new: (B, 1, H, hd).  Returns the new state (count <= budget).
    """
    budget = state.k.shape[1]

    def do_maintain(st):
        if policy == "merge":
            fn = lambda kk, vv: _merge_one(kk, vv, st.count, gamma, table)
        else:
            fn = lambda kk, vv: _evict_one(kk, vv, st.count)
        maintain = jax.vmap(jax.vmap(fn, in_axes=(1, 1), out_axes=(1, 1)),
                            in_axes=(0, 0), out_axes=(0, 0))
        k2, v2 = maintain(st.k, st.v)
        return KVBudgetState(k=k2, v=v2, count=st.count - 1)

    state = jax.lax.cond(state.count >= budget, do_maintain, lambda s: s, state)
    slot = state.count
    k = jax.lax.dynamic_update_slice(state.k, k_new.astype(state.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(state.v, v_new.astype(state.v.dtype),
                                     (0, slot, 0, 0))
    return KVBudgetState(k=k, v=v, count=state.count + 1)


def kv_attend(state: KVBudgetState, q, scale: float):
    """q: (B, 1, H, hd) against the budgeted cache -> (B, 1, H, hd)."""
    valid = jnp.arange(state.k.shape[1]) < state.count
    bias = jnp.where(valid, 0.0, -1e30)[None, None, None, :]
    scores = jnp.einsum("bqhd,bwhd->bhqw", q.astype(jnp.float32),
                        state.k.astype(jnp.float32)) * scale + bias
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqw,bwhd->bqhd", probs,
                      state.v.astype(jnp.float32)).astype(q.dtype)
