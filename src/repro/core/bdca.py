"""BDCA: budgeted dual coordinate ascent on the cached working-set Gram matrix.

The dual subspace ascent solver of "Dual SVM Training on a Budget" (Qaadan,
Schüler & Glasmachers, arXiv 1806.10182 — same group as the source paper),
implemented as a *second optimizer* behind ``BSGDConfig.solver`` sharing
every other layer of this repo unchanged (the §14 solver contract in
DESIGN.md):

  * the **working set** is the budgeted SV bank itself — the fixed-slot
    ``SVMState`` with its ``count`` watermark (DESIGN.md §2);
  * the **Gram matrix** of the working set is exactly ``SVMState.kmat``, the
    persistent SV-SV kernel cache maintenance already keeps incrementally
    consistent (I1-I4, DESIGN.md §4) — the ascent never recomputes a kernel
    value, so ``solver="bdca"`` requires ``use_kernel_cache=True``;
  * **violator insertion** reuses the fused ``rbf_matrix`` margin rows the
    step already computed: a point enters iff its margin violates
    ``y f(x) < 1`` (the same criterion as BSGD — it is exactly "the optimal
    dual coordinate step from 0 is nonzero"), with its coefficient set to
    that optimal step ``clip(1 - y f(x), 0, C)``;
  * **budget maintenance** is the untouched strategy layer: over-budget
    counts drain through ``budget.run_maintenance`` /
    ``run_maintenance_classes`` (merge / multi-merge / removal /
    removal-project, ``maintenance_engine="xla"|"pallas"``).

Math.  The hinge-loss SVM dual over the working set is the box-constrained
concave quadratic

    D(a) = sum_i a_i - 1/2 sum_ij a_i a_j y_i y_j K_ij,    0 <= a_i <= C.

``SVMState.alpha`` stores the *signed* coefficients ``b_i = y_i a_i`` (the
BSGD convention), so ``y_i = sign(b_i)`` and the box reads ``|b_i| <= C``.
One Gauss-Seidel coordinate step maximizes the 1-D restriction exactly
(``K_ii = 1`` for the RBF kernel):

    g_i   = 1 - y_i f(x_i),    f(x_i) = sum_j b_j K_ij   (a cache row read)
    a_i  <- clip(a_i + g_i, 0, C)

and the margin vector ``f`` is updated incrementally from the coordinate's
cached kernel row — ``O(slots)`` per coordinate, ``O(slots^2)`` per sweep,
zero kernel evaluations.  Each exact 1-D maximization makes the dual
objective monotone non-decreasing and keeps the box invariant — the
properties ``tests/core/test_bdca.py`` pins.

Two deliberate deviations from the sequential-reference algorithm, both
shared with the BSGD step and documented so the invariant harness can hold
them fixed:

  * batch inserts are Jacobi-style (each new point's step uses the
    pre-insert margins; ``batch_size=1`` is the exact sequential setting);
  * a coordinate driven to ``a_i = 0`` loses its label sign and FREEZES
    (merged SVs carry synthetic signed coefficients, so the sign *is* the
    label information) — frozen slots contribute nothing to ``f``, are
    excluded from the KKT residual, and are the first candidates removal
    strategies drop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import kernel_cache
from .bsgd import BSGDConfig, SVMState, drain_budget


def box_from_lambda(n: int, lambda_: float, *, cap: float = 4.0) -> float:
    """Dual box ``C`` for a primal regularizer ``lambda_`` at sample size n.

    The textbook Pegasos correspondence is ``C = 1 / (n * lambda_)``, but it
    is derived for the EXACT dual and breaks down under budget maintenance:
    at the paper's table hyperparameters (``lambda_ = 1e-5``, n in the
    thousands) it blows the box up to ~1e2, and merged SVs — whose synthetic
    signed coefficients approximate *sums* of true duals — then take exact
    1-D ascent steps of that magnitude against a Gram matrix they are no
    longer consistent with, measurably hurting held-out accuracy.  Clamping
    the box to ``cap`` keeps the small-lambda regime at the moderate box the
    budgeted dual is stable under (the invariant harness pins solver parity
    at C <= 4) while preserving the textbook mapping whenever it is already
    moderate (large lambda / small n).
    """
    if n < 1:
        raise ValueError(f"n={n} < 1")
    if lambda_ <= 0.0:
        raise ValueError(f"lambda_={lambda_} must be > 0")
    return min(float(cap), 1.0 / (n * lambda_))


def _masked(alpha, count):
    """Signed coefficients with stale (>= count) slots zeroed."""
    return jnp.where(jnp.arange(alpha.shape[0]) < count, alpha, 0.0)


def dual_objective(alpha, kmat, count):
    """D(a) = sum_i |b_i| - 1/2 b^T K b over the active working set.

    Stale cache entries never contribute: the masked coefficient vector is
    zero outside the watermark on both sides of the quadratic form.
    """
    b = _masked(alpha, count).astype(jnp.float32)
    k = kmat.astype(jnp.float32)
    return jnp.sum(jnp.abs(b)) - 0.5 * (b @ (k @ b))


def kkt_residual(alpha, kmat, count, C):
    """Max |projected dual gradient| over live (non-frozen) coordinates.

    Interior coordinates contribute ``|g_i|``; coordinates at the upper box
    bound contribute only the infeasible-direction part ``max(-g_i, 0)``
    (ascent there is blocked, so a positive gradient is KKT-consistent);
    frozen ``a_i = 0`` slots are excluded (their sign — the label — is
    gone, so no feasible direction is defined).  Zero iff the live working
    set is dual-optimal at this box.
    """
    b = _masked(alpha, count).astype(jnp.float32)
    f = kmat.astype(jnp.float32) @ b
    a = jnp.abs(b)
    g = 1.0 - jnp.sign(b) * f
    live = (jnp.arange(alpha.shape[0]) < count) & (a > 0)
    pg = jnp.where(a >= C, jnp.maximum(-g, 0.0), jnp.abs(g))
    return jnp.max(jnp.where(live, pg, 0.0))


def ascent_rounds(alpha, kmat, count, C, rounds: int):
    """``rounds`` Gauss-Seidel sweeps of exact 1-D dual maximization.

    Sequential over slots within a sweep (lax.fori_loop), the margin vector
    ``f = K b`` carried incrementally — the update for coordinate ``i``
    reads one cached kernel row, so a sweep is one O(slots^2) pass over
    ``kmat`` with no kernel evaluations.  Inactive and frozen slots are
    bitwise no-ops.  Returns the updated signed coefficients (stale slots
    zeroed, as ``init_state`` guarantees on entry).
    """
    slots = alpha.shape[0]
    k = kmat.astype(alpha.dtype)
    b0 = _masked(alpha, count)
    f0 = k @ b0                      # stale rows only feed frozen/inactive i

    def coord(i, bf):
        beta, f = bf
        b_i = beta[i]
        y_i = jnp.sign(b_i)
        live = (i < count) & (b_i != 0)
        a_new = jnp.clip(jnp.abs(b_i) + 1.0 - y_i * f[i], 0.0, C)
        b_new = jnp.where(live, y_i * a_new, b_i)
        f = f + (b_new - b_i) * k[i]
        return beta.at[i].set(b_new), f

    def sweep(carry, _):
        return jax.lax.fori_loop(0, slots, coord, carry), ()

    (beta, _), _ = jax.lax.scan(sweep, (b0, f0), None, length=rounds)
    return beta


def insert_from_rows(cfg: BSGDConfig, state: SVMState, xb, yb, k_b,
                     k_bb=None) -> SVMState:
    """The BDCA solver half of a step: dual violator insert + ascent sweeps.

    The §14 contract's counterpart of ``bsgd.insert_from_rows`` (same
    signature, same post-condition: ``count`` may exceed the budget by up
    to ``batch_size`` and the maintenance engine drains it).  ``k_b = k(xb,
    sv_x)`` are the margin rows ONE fused ``rbf_matrix`` call produced;
    ``k_bb = k(xb, xb)`` completes the cache block for the inserted points.
    No Pegasos shrink: dual coefficients are bounded by the box, not by a
    decaying step size.
    """
    slots = cfg.slots
    active = jnp.arange(state.alpha.shape[0]) < state.count
    f = k_b.astype(state.alpha.dtype) @ jnp.where(active, state.alpha, 0.0)
    margin = yb * f

    # optimal dual coordinate step from a = 0 (K_ii = 1): nonzero iff the
    # margin violates — the identical criterion BSGD inserts on, so the two
    # solvers share the violator definition the harness pins
    viol = margin < 1.0
    a_new = jnp.clip(1.0 - margin, 0.0, cfg.bdca_C)
    pos = state.count + jnp.cumsum(viol.astype(jnp.int32)) - 1
    idx = jnp.where(viol, pos, slots)                 # slots == OOB -> dropped
    sv_x = state.sv_x.at[idx].set(xb.astype(state.sv_x.dtype), mode="drop")
    alpha = state.alpha.at[idx].set((yb * a_new).astype(state.alpha.dtype),
                                    mode="drop")
    n_new = jnp.sum(viol).astype(jnp.int32)
    kmat = kernel_cache.insert_rows(state.kmat, idx, k_b, k_bb)
    count = state.count + n_new

    # coordinate ascent over the whole working set (bank + fresh inserts),
    # every kappa read a cache row
    alpha = ascent_rounds(alpha, kmat, count, cfg.bdca_C, cfg.bdca_rounds)

    return SVMState(sv_x=sv_x, alpha=alpha, count=count, step=state.step + 1,
                    n_inserts=state.n_inserts + n_new,
                    n_merges=state.n_merges, kmat=kmat)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step_from_rows(cfg: BSGDConfig, table, state: SVMState, xb, yb,
                         k_b, k_bb=None, *, impl: str = "auto") -> SVMState:
    """One BDCA minibatch step from precomputed kernel rows: dual insert +
    ascent sweeps, then the SAME maintenance drain as the BSGD step
    (``bsgd.drain_budget`` — strategy layer and engines untouched)."""
    state = insert_from_rows(cfg, state, xb, yb, k_b, k_bb)
    return drain_budget(cfg, table, state, impl=impl)
