"""Precomputed merge tables with bilinear interpolation (the paper's contribution).

``h(m, kappa)`` and ``WD_norm(m, kappa)`` are precomputed once on a regular
``G x G`` grid over the unit square with high-precision golden section search
(eps = 1e-10, paper §3), then evaluated at runtime by a bilinearly-interpolated
lookup — a plug-in replacement for the per-candidate iterative search.

The table is tiny (400x400 fp32 = 640 KB per function) and lives comfortably in
TPU VMEM; see ``repro.kernels.merge_lookup`` for the fused Pallas kernel that
scores all budget-maintenance candidates against the table in one pass.

``build_lookup_table`` is generic over the solved function so the pattern
"replace an inner iterative solver with an interpolated table" is reusable
beyond the SVM merge problem (e.g. ``core.budgeted_kv``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import merge_math

DEFAULT_GRID = 400  # paper: "in our experiments we use a grid of size 400x400"


def build_merge_tables(grid_size: int = DEFAULT_GRID, eps: float = merge_math.EPS_PRECISE):
    """Precompute h(m, kappa) and WD_norm(m, kappa) on a grid.

    Returns ``(h_table, wd_table)`` of shape ``(grid_size, grid_size)`` indexed
    ``[i_m, j_kappa]`` with grid points ``linspace(0, 1, grid_size)`` on both
    axes.  One-time *offline* cost (exactly as in the paper): grid_size^2
    golden section searches at eps=1e-10, run vectorized in float64 numpy —
    fp32 GSS cannot localize a smooth argmax beyond ~3e-4 (see
    ``merge_math.gss_numpy``), and the paper's table build used C++ doubles.
    """
    g = np.linspace(0.0, 1.0, grid_size)
    mm, kk = np.meshgrid(g, g, indexing="ij")
    h = merge_math.gss_numpy(mm, kk, eps=eps)
    kk_safe = np.clip(kk, merge_math.KAPPA_MIN, 1.0)
    s = mm * kk_safe ** ((1.0 - h) ** 2) + (1.0 - mm) * kk_safe ** (h**2)
    wd = mm**2 + (1.0 - mm) ** 2 + 2.0 * mm * (1.0 - mm) * kk - s**2
    # Analytic boundary columns where the objective degenerates:
    #  kappa = 1 (coincident points): s(h) == 1 for all h, GSS sees a flat
    #  function; the kappa -> 1 limit is h = m with zero degradation.
    h[:, -1] = g
    wd[:, -1] = 0.0
    #  kappa = 0 (infinitely distant points): the optimum is removal of the
    #  smaller-coefficient point: h -> {0, 1}, WD_norm -> min(m, 1-m)^2.
    h[:, 0] = np.where(g >= 0.5, 1.0, 0.0)
    wd[:, 0] = np.minimum(g, 1.0 - g) ** 2
    return jnp.asarray(h), jnp.asarray(wd)


def bilinear_lookup(table, u, v):
    """Bilinearly interpolate ``table`` at unit-square coordinates ``(u, v)``.

    ``table[i, j]`` holds the function value at ``(i/(G-1), j/(G-1))``.
    Vectorized over the broadcasted shape of ``u`` and ``v``.
    """
    g = table.shape[0]
    u = jnp.clip(u, 0.0, 1.0) * (g - 1)
    v = jnp.clip(v, 0.0, 1.0) * (table.shape[1] - 1)
    i0 = jnp.clip(jnp.floor(u).astype(jnp.int32), 0, g - 2)
    j0 = jnp.clip(jnp.floor(v).astype(jnp.int32), 0, table.shape[1] - 2)
    du = u - i0
    dv = v - j0
    t00 = table[i0, j0]
    t01 = table[i0, j0 + 1]
    t10 = table[i0 + 1, j0]
    t11 = table[i0 + 1, j0 + 1]
    top = t00 * (1.0 - dv) + t01 * dv
    bot = t10 * (1.0 - dv) + t11 * dv
    return top * (1.0 - du) + bot * du


def build_lookup_table(fn, grid_size: int = DEFAULT_GRID):
    """Generic 2-D tabulation of ``fn(u, v)`` over the unit square.

    ``fn`` must accept broadcasted arrays.  Returns a ``(G, G)`` table usable
    with :func:`bilinear_lookup` — the reusable "precompute the inner solver"
    pattern.
    """
    g = jnp.linspace(0.0, 1.0, grid_size)
    uu, vv = jnp.meshgrid(g, g, indexing="ij")
    return fn(uu, vv)


@jax.tree_util.register_pytree_node_class
@dataclass
class MergeLookupTable:
    """Precomputed h / WD_norm tables (paper's Lookup-h / Lookup-WD)."""

    h_table: jax.Array
    wd_table: jax.Array

    @classmethod
    def create(cls, grid_size: int = DEFAULT_GRID, eps: float = merge_math.EPS_PRECISE,
               dtype=jnp.float32) -> "MergeLookupTable":
        h, wd = build_merge_tables(grid_size=grid_size, eps=eps)
        return cls(h_table=h.astype(dtype), wd_table=wd.astype(dtype))

    def lookup_h(self, m, kappa):
        return bilinear_lookup(self.h_table, m, kappa)

    def lookup_wd_norm(self, m, kappa):
        return bilinear_lookup(self.wd_table, m, kappa)

    def lookup_wd(self, alpha_a, alpha_b, m, kappa):
        """Denormalized weight degradation (alpha_a + alpha_b)^2 * WD_norm."""
        return (alpha_a + alpha_b) ** 2 * self.lookup_wd_norm(m, kappa)

    # --- persistence -----------------------------------------------------
    def save(self, path: str) -> None:
        tmp = path + ".tmp.npz"  # .npz suffix stops np.savez appending another
        np.savez(tmp, h_table=np.asarray(self.h_table), wd_table=np.asarray(self.wd_table))
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "MergeLookupTable":
        with np.load(path) as z:
            return cls(h_table=jnp.asarray(z["h_table"]), wd_table=jnp.asarray(z["wd_table"]))

    # --- pytree protocol (so the table threads through jit/pjit as data) --
    def tree_flatten(self):
        return (self.h_table, self.wd_table), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


_TABLE_CACHE: dict[tuple, MergeLookupTable] = {}


def default_table(grid_size: int = DEFAULT_GRID,
                  eps: float = merge_math.EPS_PRECISE,
                  dtype=jnp.float32) -> MergeLookupTable:
    """Process-wide cached tables (each built once, ~160k GSS solves, <1s).

    Keyed by every build parameter — a call with a different ``eps`` or
    ``dtype`` must not be handed a table built with someone else's settings.
    """
    key = (int(grid_size), float(eps), jnp.dtype(dtype).name)
    table = _TABLE_CACHE.get(key)
    if table is None:
        table = _TABLE_CACHE[key] = MergeLookupTable.create(
            grid_size=grid_size, eps=eps, dtype=dtype)
    return table
