"""Core paper contribution: budgeted SGD SVM with precomputed merge lookup."""
from . import budget, kernel_cache, merge_math
from .bsgd import BSGDConfig, SVMState, accuracy, decision_function, fit, init_state, predict, train_epoch, train_step
from .budget import METHODS, STRATEGIES, MaintenanceInfo, maintenance_step, run_maintenance
from .lookup import MergeLookupTable, bilinear_lookup, build_lookup_table, build_merge_tables, default_table
from .merge_math import (EPS_PRECISE, EPS_STANDARD, KAPPA_UNIMODAL, golden_section_search, gss_num_iters,
                         merge_alpha_z, merge_point, s_objective, solve_merge, wd_norm_at, weight_degradation)

__all__ = [
    "BSGDConfig", "SVMState", "MaintenanceInfo", "MergeLookupTable", "METHODS",
    "STRATEGIES", "accuracy", "bilinear_lookup", "budget", "build_lookup_table",
    "build_merge_tables", "decision_function", "default_table", "fit",
    "golden_section_search", "gss_num_iters", "init_state", "kernel_cache",
    "maintenance_step", "merge_alpha_z", "merge_math", "merge_point", "predict",
    "run_maintenance", "s_objective", "solve_merge", "train_epoch",
    "train_step", "wd_norm_at", "weight_degradation", "EPS_PRECISE",
    "EPS_STANDARD", "KAPPA_UNIMODAL",
]
