"""Core paper contribution: budgeted SGD SVM with precomputed merge lookup."""
from . import budget, kernel_cache, merge_math
# the serving module imports first: its submodule import binds the package
# attribute ``predict`` to the module, and the ``from .bsgd import`` below
# then restores ``repro.core.predict`` to the binary predict *function*
# (the public API since PR 0) — import serving symbols from ``repro.core``
# directly, never via ``repro.core.predict.<name>``
from .predict import (AsyncBatchQueue, BatchQueue, ModelBank, QueueFull, ServeDeadline, ServeModel,
                      ServeTimeout, default_buckets, drive_trace, export_model, load_serve_model,
                      pad_bucket, predict_labels, predict_proba, ragged_trace_sizes, serve_requests,
                      serve_scores, top_k_labels)
from .bsgd import (BSGDConfig, SVMState, accuracy, decision_function, drain_budget, fit, fit_stream,
                   init_state, insert_from_rows, predict, train_chunk, train_epoch, train_epoch_stream,
                   train_step, train_step_from_rows)
from . import bdca
from .budget import (METHODS, STRATEGIES, MaintenanceInfo, kmeans_codebook, maintenance_step,
                     run_maintenance, run_maintenance_classes, seed_codebook)
from .online import prequential_stream
from .lookup import MergeLookupTable, bilinear_lookup, build_lookup_table, build_merge_tables, default_table
from .multiclass import (MulticlassSVMConfig, accuracy_multiclass, check_labels, class_kernel_rows,
                         decision_function_multiclass, fit_multiclass, fit_multiclass_loop, fit_multiclass_stream,
                         init_multiclass_state, ovr_targets, predict_multiclass, train_chunk_multiclass,
                         train_epoch_multiclass, train_epoch_multiclass_stream, train_step_multiclass)
from .merge_math import (EPS_PRECISE, EPS_STANDARD, KAPPA_UNIMODAL, golden_section_search, gss_num_iters,
                         merge_alpha_z, merge_point, s_objective, solve_merge, wd_norm_at, weight_degradation)

__all__ = [
    "AsyncBatchQueue", "BSGDConfig", "BatchQueue", "SVMState", "MaintenanceInfo", "MergeLookupTable", "METHODS",
    "ModelBank", "MulticlassSVMConfig", "QueueFull", "STRATEGIES",
    "ServeDeadline", "ServeModel", "ServeTimeout", "accuracy", "accuracy_multiclass",
    "bdca", "bilinear_lookup", "budget", "build_lookup_table",
    "build_merge_tables", "check_labels", "class_kernel_rows", "decision_function",
    "decision_function_multiclass", "default_buckets", "default_table",
    "drain_budget", "drive_trace", "export_model", "fit", "fit_multiclass",
    "fit_multiclass_loop", "fit_multiclass_stream", "fit_stream",
    "golden_section_search", "gss_num_iters",
    "init_multiclass_state", "init_state", "insert_from_rows", "kernel_cache",
    "kmeans_codebook", "load_serve_model", "maintenance_step", "merge_alpha_z",
    "merge_math", "merge_point", "ovr_targets", "pad_bucket", "predict",
    "predict_labels", "predict_multiclass", "predict_proba",
    "prequential_stream", "ragged_trace_sizes", "seed_codebook",
    "run_maintenance", "run_maintenance_classes", "s_objective",
    "serve_requests", "serve_scores",
    "solve_merge", "top_k_labels", "train_chunk",
    "train_chunk_multiclass", "train_epoch",
    "train_epoch_multiclass", "train_epoch_multiclass_stream",
    "train_epoch_stream", "train_step", "train_step_from_rows",
    "train_step_multiclass", "wd_norm_at", "weight_degradation",
    "EPS_PRECISE", "EPS_STANDARD", "KAPPA_UNIMODAL",
]
