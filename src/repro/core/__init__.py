"""Core paper contribution: budgeted SGD SVM with precomputed merge lookup."""
from . import budget, kernel_cache, merge_math
from .bsgd import (BSGDConfig, SVMState, accuracy, decision_function, fit, fit_stream, init_state, predict,
                   train_chunk, train_epoch, train_epoch_stream, train_step, train_step_from_rows)
from .budget import METHODS, STRATEGIES, MaintenanceInfo, maintenance_step, run_maintenance
from .lookup import MergeLookupTable, bilinear_lookup, build_lookup_table, build_merge_tables, default_table
from .multiclass import (MulticlassSVMConfig, accuracy_multiclass, check_labels, class_kernel_rows,
                         decision_function_multiclass, fit_multiclass, fit_multiclass_loop, fit_multiclass_stream,
                         init_multiclass_state, ovr_targets, predict_multiclass, train_chunk_multiclass,
                         train_epoch_multiclass, train_epoch_multiclass_stream, train_step_multiclass)
from .merge_math import (EPS_PRECISE, EPS_STANDARD, KAPPA_UNIMODAL, golden_section_search, gss_num_iters,
                         merge_alpha_z, merge_point, s_objective, solve_merge, wd_norm_at, weight_degradation)

__all__ = [
    "BSGDConfig", "SVMState", "MaintenanceInfo", "MergeLookupTable", "METHODS",
    "MulticlassSVMConfig", "STRATEGIES", "accuracy", "accuracy_multiclass",
    "bilinear_lookup", "budget", "build_lookup_table",
    "build_merge_tables", "check_labels", "class_kernel_rows", "decision_function",
    "decision_function_multiclass", "default_table", "fit", "fit_multiclass",
    "fit_multiclass_loop", "fit_multiclass_stream", "fit_stream",
    "golden_section_search", "gss_num_iters",
    "init_multiclass_state", "init_state", "kernel_cache",
    "maintenance_step", "merge_alpha_z", "merge_math", "merge_point",
    "ovr_targets", "predict", "predict_multiclass",
    "run_maintenance", "s_objective", "solve_merge", "train_chunk",
    "train_chunk_multiclass", "train_epoch",
    "train_epoch_multiclass", "train_epoch_multiclass_stream",
    "train_epoch_stream", "train_step", "train_step_from_rows",
    "train_step_multiclass", "wd_norm_at", "weight_degradation",
    "EPS_PRECISE", "EPS_STANDARD", "KAPPA_UNIMODAL",
]
