"""One-vs-rest multi-class BSGD: the class axis as a leading state dimension.

The paper's lookup-based merge makes budget maintenance cheap enough to run
*per class per step* — exactly what one-vs-rest multi-class kernel SVMs need
(Picard 2018 shows budgeted kernel SVMs paying off in large multi-class
regimes).  This module stacks C independent binary BSGD problems into one
``SVMState`` whose every array carries a leading ``(C,)`` axis and trains
them in lockstep:

  * margins for ALL classes come from a single fused kernel contraction —
    ONE ``rbf_matrix`` call against the flattened ``(C * slots, dim)`` SV
    bank, reshaped to ``(C, batch, slots)`` — not C sequential kernel calls
    (``class_kernel_rows``);
  * the Pegasos update + budget maintenance is ``jax.vmap`` of
    ``bsgd.train_step_from_rows`` over the class axis — the step is
    vmap-clean, and with ``unroll_maintenance=True`` it is *bitwise*
    loop-parity (property test in ``tests/core/test_multiclass.py``);
  * ONE ``MergeLookupTable`` is shared by every class (closed over the vmap,
    never stacked — 640 KB total regardless of C).

Prediction is argmax over the C per-class decision functions, again from one
fused kernel call.  The loop-over-classes baseline (`fit_multiclass_loop`)
is kept as the benchmark reference point (`bench_table2_accuracy
--multiclass` reports batched vs loop wall-clock).

Sharding: ``core.distributed`` maps this layout onto the production mesh
with ``layout="class"`` — classes over the ``model`` axis, the minibatch
over the data axes (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from . import budget as budget_mod
from .bsgd import (BSGDConfig, SVMState, _device_stage, _fit_stream,
                   _make_guard, _make_publish, _stream_epoch, init_state,
                   insert_from_rows, train_step_from_rows)
from ..kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class MulticlassSVMConfig:
    """C one-vs-rest copies of a binary ``BSGDConfig``.

    Attributes:
      n_classes: number of one-vs-rest problems (stacked along the leading
        state axis; labels are integer ids in [0, n_classes)).
      binary: the per-class ``BSGDConfig`` — every binary knob (budget,
        solver, kernel cache, maintenance strategy, dtypes) applies to each
        class unchanged; ONE lookup table is shared by all classes.
    """

    n_classes: int
    binary: BSGDConfig

    def __post_init__(self):
        if self.n_classes < 2:
            raise ValueError(f"n_classes={self.n_classes} < 2")

    @property
    def slots(self) -> int:
        return self.binary.slots

    def table(self):
        return self.binary.table()

    @staticmethod
    def create(n_classes: int, **kw) -> "MulticlassSVMConfig":
        """Build from binary hyperparameters: ``create(5, budget=100, ...)``."""
        return MulticlassSVMConfig(n_classes=n_classes, binary=BSGDConfig(**kw))


def ovr_targets(y, n_classes: int, dtype=jnp.float32):
    """Integer class labels (n,) -> one-vs-rest targets (C, n) in {-1, +1}.

    Labels must be 0-based: an out-of-range id would silently train as "not
    any class" (all-(-1) targets) and could never be predicted.  The fit
    drivers validate concrete labels up front (``check_labels``).
    """
    y = y.astype(jnp.int32)
    onehot = jnp.arange(n_classes, dtype=jnp.int32)[:, None] == y[None, :]
    return jnp.where(onehot, 1.0, -1.0).astype(dtype)


def check_labels(y, n_classes: int) -> None:
    """Raise on concrete labels outside [0, n_classes); no-op on tracers."""
    try:
        y_min, y_max = int(jnp.min(y)), int(jnp.max(y))
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return
    if y_min < 0 or y_max >= n_classes:
        raise ValueError(
            f"class labels must be integers in [0, {n_classes}); got range "
            f"[{y_min}, {y_max}] — remap 1-based labels (e.g. y - 1) first")


def init_multiclass_state(cfg: MulticlassSVMConfig, dim: int) -> SVMState:
    """Stacked ``SVMState``: every leaf gains a leading ``(C,)`` axis."""
    st = init_state(cfg.binary, dim)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_classes,) + a.shape), st)


def class_kernel_rows(sv_x, x, gamma, *, impl: str = "auto"):
    """``k(x, sv_c)`` for every class from ONE kernel call.

    sv_x: (C, slots, dim) stacked SV bank; x: (n, dim).
    Returns (C, n, slots) — the batched all-class kernel contraction: the
    class axis is flattened into the SV axis so the whole thing is a single
    ``(n, C * slots)`` rbf block (one Pallas launch / one XLA matmul), then
    reshaped back.
    """
    c, slots, dim = sv_x.shape
    k = kops.rbf_matrix(x, sv_x.reshape(c * slots, dim), gamma, impl=impl)
    return jnp.moveaxis(k.reshape(x.shape[0], c, slots), 1, 0)


def decision_function_multiclass(state: SVMState, x, gamma, *,
                                 impl: str = "auto"):
    """Per-class scores f_c(x); x: (n, d) -> (C, n).

    Same fused fold as the serving cell (``kernels.ops.class_scores``): one
    kernel launch against the flattened (C * slots, dim) bank.
    """
    active = jnp.arange(state.alpha.shape[-1])[None, :] < state.count[:, None]
    alpha = jnp.where(active, state.alpha, 0.0)                   # (C, slots)
    return kops.class_scores(x, state.sv_x, alpha, gamma, impl=impl)


def predict_multiclass(state: SVMState, x, gamma, **kw):
    """argmax over the C one-vs-rest decision functions; returns (n,) int32."""
    scores = decision_function_multiclass(state, x, gamma, **kw)
    return jnp.argmax(scores, axis=0).astype(jnp.int32)


def accuracy_multiclass(state: SVMState, x, y, gamma, **kw) -> jax.Array:
    pred = predict_multiclass(state, x, gamma, **kw)
    return jnp.mean((pred == y.astype(jnp.int32)).astype(jnp.float32))


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step_multiclass(cfg: MulticlassSVMConfig, table, state: SVMState,
                          xb, yb, *, impl: str = "auto") -> SVMState:
    """One lockstep solver step for all C one-vs-rest problems.

    xb: (batch, dim); yb: (batch,) integer class ids in [0, C).
    ``cfg.binary.solver`` picks the per-class update (Pegasos primal SGD or
    BDCA dual ascent — ``core.bdca``); both plug into the identical class
    vmap / fused-maintenance structure below.
    One fused rbf call produces every class's margin rows; the per-class
    update (insert + budget maintenance) is vmapped over the class axis with
    the lookup table and minibatch closed over (shared, not stacked).

    With ``maintenance_engine="pallas"`` only the shrink + insert half is
    vmapped; maintenance then runs ONCE on the whole stacked state through
    the fused merge-event engine (``budget.run_maintenance_classes``) —
    classes fold onto the kernel grid and the sorted-excess schedule bounds
    the rounds by the worst class's excess instead of C x worst
    (DESIGN.md §11).

    With ``step_engine="pallas"`` the WHOLE step — margin rows, shrink +
    insert, event rounds — is one ``kernels.ops.train_step`` launch chain:
    classes fold onto the kernel grid and the cache stays VMEM-resident
    across all three phases (DESIGN.md §12).
    """
    b = cfg.binary
    if b.step_engine == "pallas":
        k_bb = kops.rbf_matrix(xb, xb, b.gamma, impl=impl)
        y_ovr = ovr_targets(yb, cfg.n_classes, dtype=jnp.dtype(b.dtype))
        sv, al, km, cnt, st_, nin, nmg = kops.train_step(
            state.sv_x, state.alpha, state.kmat, state.count, state.step,
            state.n_inserts, state.n_merges, xb, y_ovr, k_bb, table,
            budget=b.budget, lambda_=b.lambda_, gamma=b.gamma,
            batch_size=b.batch_size, maintenance=b.maintenance,
            merge_batch=b.merge_batch,
            unroll=b.batch_size if b.unroll_maintenance else 0, impl=impl)
        return SVMState(sv_x=sv, alpha=al, count=cnt, step=st_,
                        n_inserts=nin, n_merges=nmg, kmat=km)
    k_b = class_kernel_rows(state.sv_x, xb, b.gamma, impl=impl)   # (C, batch, slots)
    k_bb = (kops.rbf_matrix(xb, xb, b.gamma, impl=impl)
            if b.use_kernel_cache else None)
    y_ovr = ovr_targets(yb, cfg.n_classes, dtype=jnp.dtype(b.dtype))

    # the §14 solver contract: a solver is an (insert+update, full-step) pair
    # with bsgd's row-consuming signatures; everything downstream — the class
    # vmap, the fused maintenance engine, streaming, serving — is shared
    if b.solver == "bdca":
        from . import bdca
        insert_fn, row_step_fn = bdca.insert_from_rows, bdca.train_step_from_rows
    else:
        insert_fn, row_step_fn = insert_from_rows, train_step_from_rows

    if b.maintenance_engine == "pallas":
        def one_insert(st, yc, kc):
            return insert_fn(b, st, xb, yc, kc, k_bb)

        mid = jax.vmap(one_insert)(state, y_ovr, k_b)
        sv_x, alpha, kmat, count, n_merges = \
            budget_mod.run_maintenance_classes(
                mid.sv_x, mid.alpha, mid.kmat, mid.count, mid.n_merges,
                table, budget=b.budget, impl=impl,
                unroll=b.batch_size if b.unroll_maintenance else 0)
        return mid._replace(sv_x=sv_x, alpha=alpha, count=count,
                            n_merges=n_merges, kmat=kmat)

    def one_class(st, yc, kc):
        return row_step_fn(b, table, st, xb, yc, kc, k_bb, impl=impl)

    return jax.vmap(one_class)(state, y_ovr, k_b)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_epoch_multiclass(cfg: MulticlassSVMConfig, table, state: SVMState,
                           x, y, perm, *, impl: str = "auto") -> SVMState:
    """One pass over resident (x, integer y) as a single jitted lax.scan —
    the class-axis counterpart of ``train_epoch`` (same perm/truncation
    contract; streamed form: ``train_epoch_multiclass_stream``)."""
    bs = cfg.binary.batch_size
    steps = perm.shape[0] // bs
    order = perm[: steps * bs].reshape(steps, bs)

    def scan_body(st, batch_idx):
        xb = jnp.take(x, batch_idx, axis=0)
        yb = jnp.take(y, batch_idx, axis=0)
        return train_step_multiclass(cfg, table, st, xb, yb, impl=impl), ()

    state, _ = jax.lax.scan(scan_body, state, order)
    return state


def fit_multiclass(cfg: MulticlassSVMConfig, x, y, *, epochs: int = 1,
                   seed: int = 0, impl: str = "auto",
                   state: SVMState | None = None) -> SVMState:
    """Train C one-vs-rest problems in lockstep on in-memory data.

    Mirrors ``bsgd.fit``: shuffled epochs (permutation per epoch from
    ``seed``) over ``x: (n, dim)`` with integer labels ``y: (n,)`` in
    [0, n_classes) — validated up front when concrete.  ``state`` resumes an
    existing stacked model.  Out-of-core counterpart:
    ``fit_multiclass_stream``.
    """
    check_labels(y, cfg.n_classes)
    table = cfg.table()
    if state is None:
        state = init_multiclass_state(cfg, x.shape[1])
    key = jax.random.PRNGKey(seed)
    for _ in range(epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, x.shape[0])
        state = train_epoch_multiclass(cfg, table, state, x, y, perm,
                                       impl=impl)
    return state


@partial(jax.jit, static_argnames=("cfg", "impl"), donate_argnums=(2,))
def train_chunk_multiclass(cfg: MulticlassSVMConfig, table, state: SVMState,
                           xc, yc, *, impl: str = "auto") -> SVMState:
    """One resident chunk of the one-vs-rest engine as a single donated-state
    program; ``xc: (steps, batch, dim)``, ``yc: (steps, batch)`` class ids
    (cf. ``bsgd.train_chunk``)."""
    def body(st, xy):
        xb, yb = xy
        return train_step_multiclass(cfg, table, st, xb,
                                     yb.astype(jnp.int32), impl=impl), ()

    state, _ = jax.lax.scan(body, state, (xc, yc))
    return state


def train_epoch_multiclass_stream(cfg: MulticlassSVMConfig, table,
                                  state: SVMState, source, *, key=None,
                                  impl: str = "auto", start_chunk: int = 0,
                                  carry=None, on_chunk=None,
                                  max_chunks: int | None = None,
                                  chunk_fn=None, prefetch: int = 0,
                                  retry=None, report=None, skip_chunks=()):
    """One streamed pass of the one-vs-rest engine over a chunk source.

    The multi-class counterpart of ``bsgd.train_epoch_stream`` — identical
    chunk-carry contract (deterministic shuffle, donated per-chunk program —
    the caller's input state buffers are consumed —, remainder carry,
    ``prefetch`` background staging, ``(state, next_chunk, carry)`` return);
    labels are integer class ids in [0, C).
    """
    stage = _device_stage if chunk_fn is None else None
    if chunk_fn is None:
        def chunk_fn(st, xc, yc):
            return train_chunk_multiclass(cfg, table, st, xc, yc, impl=impl)
    state, next_chunk, carry, _ = _stream_epoch(
        chunk_fn, state, source, batch_size=cfg.binary.batch_size, key=key,
        start_chunk=start_chunk, carry=carry, on_chunk=on_chunk,
        max_chunks=max_chunks, prefetch=prefetch, stage=stage, retry=retry,
        report=report, skip_chunks=skip_chunks)
    if next_chunk == source.n_chunks:
        jax.block_until_ready(state.alpha)
    return state, next_chunk, carry


def fit_multiclass_stream(cfg: MulticlassSVMConfig, source, *,
                          epochs: int = 1, seed: int = 0, impl: str = "auto",
                          state: SVMState | None = None,
                          ckpt_dir: str | None = None, ckpt_every: int = 0,
                          max_chunks: int | None = None, keep_last: int = 3,
                          chunk_fn=None, prefetch: int = 0, bank=None,
                          publish_every: int = 0,
                          publish_dtype=None, retry=None,
                          guard_finite: bool = False,
                          debug_invariants: bool = False, report=None,
                          skip_chunks=()) -> SVMState:
    """Out-of-core ``fit_multiclass``: streamed shuffled epochs over a chunk
    source of integer-labelled rows (contract as in ``bsgd.fit_stream`` —
    same checkpointing, cursor, bitwise-resume, copied-caller-state,
    ``prefetch`` background staging, ``bank``/``publish_every`` snapshot
    semantics, and ``retry``/``guard_finite``/``debug_invariants``/
    ``report``/``skip_chunks`` resilience knobs).  Labels are validated per
    concrete chunk."""
    table = cfg.table()
    if state is None:
        state = init_multiclass_state(cfg, source.dim)
    else:
        state = jax.tree.map(jnp.array, state)   # donation would delete it
    stage = _device_stage if chunk_fn is None else None
    if chunk_fn is None:
        def chunk_fn(st, xc, yc):
            check_labels(yc, cfg.n_classes)
            return train_chunk_multiclass(cfg, table, st, xc, yc, impl=impl)
    return _fit_stream(cfg.binary.batch_size, source, chunk_fn, state,
                       epochs=epochs, seed=seed, ckpt_dir=ckpt_dir,
                       ckpt_every=ckpt_every, max_chunks=max_chunks,
                       keep_last=keep_last, prefetch=prefetch, stage=stage,
                       publish=_make_publish(bank, cfg.binary.gamma,
                                             publish_dtype),
                       publish_every=publish_every, retry=retry,
                       report=report, skip_chunks=skip_chunks,
                       guard=_make_guard(guard_finite, debug_invariants,
                                         cfg.binary, report))


def fit_multiclass_loop(cfg: MulticlassSVMConfig, x, y, *, epochs: int = 1,
                        seed: int = 0, impl: str = "auto") -> SVMState:
    """Loop-over-classes baseline: C sequential binary fits on OVR labels.

    Identical epoch permutations (same seed) mean this trains the same model
    as ``fit_multiclass`` — it just pays C sequential kernel calls per step
    plus C scans per epoch.  Kept as the reference point the batched engine
    is benchmarked against (``bench_table2_accuracy --multiclass``).
    """
    from .bsgd import fit

    check_labels(y, cfg.n_classes)
    y_ovr = ovr_targets(y, cfg.n_classes, dtype=jnp.dtype(cfg.binary.dtype))
    states = [fit(cfg.binary, x, y_ovr[c], epochs=epochs, seed=seed, impl=impl)
              for c in range(cfg.n_classes)]
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *states)
