"""Persistent SV-SV kernel cache: incremental kappa rows for budget maintenance.

The budget-maintenance hot spot is the kappa row ``k(x_min, .)`` against every
SV (``O(slots * dim)`` distances + exp per event, recomputed from scratch).
This module maintains a ``(slots, slots)`` symmetric kernel matrix ``kmat``
inside ``SVMState`` so maintenance *reads* its kappa row instead:

  * **insert** — reuses the ``k(xb, sv)`` rows ``train_step`` already computed
    for the margins (zero extra kernel evaluations against the SV set; only
    the tiny ``(batch, batch)`` block among the inserted points is new);
  * **merge**  — the merged point ``z = h x_a + (1-h) x_b`` gets its row in
    closed form from cached values.  For the Gaussian kernel,

        ||z - c||^2 = h ||x_a - c||^2 + (1-h) ||x_b - c||^2
                      - h (1-h) ||x_a - x_b||^2

    so ``log k(z, c) = h log k(x_a, c) + (1-h) log k(x_b, c)
    - h (1-h) log k(x_a, x_b)`` — an ``O(slots)`` log/exp combine of two
    cached rows, **independent of dim** (vs ``O(slots * dim)`` for a direct
    recompute);
  * **removal / compaction** — pure row/column moves, no kernel math at all.

Invariants (see DESIGN.md §4):

  I1. for all ``i, j < count``:  ``kmat[i, j] == k(sv_x[i], sv_x[j])`` up to
      fp tolerance (inserts come from the matmul-decomposition ``rbf_matrix``,
      merge rows from the log-space combine; both agree to ~1e-6 in fp32);
  I2. ``kmat`` is exactly symmetric (every update writes row and column from
      the same values);
  I3. ``kmat[i, i] == 1`` for ``i < count`` (set explicitly, never derived);
  I4. entries with ``i >= count`` or ``j >= count`` are arbitrary stale
      values — every consumer masks by ``count``, exactly like ``sv_x``.

The cache is always fp32 regardless of ``sv_dtype`` (it is ``slots^2 * 4``
bytes — 4 MB at a 1k budget, ~1 GiB per class at 16k, so size it into the
HBM plan at production budgets — and fp32 keeps merge decisions stable when
SV rows are stored in bf16).

The fused maintenance-event engine (``kernels/merge_event.py``, DESIGN.md
§11) inlines the merge rule: the z-row log-space combine below is derived
*inside* the kernel from the two parent rows resident in VMEM, so the
per-event cache update never round-trips through this module on that path —
``z_row_from_rows`` stays the shared reference form (used by the xla engine
and the kernel's oracle, which is pinned bitwise against it).
"""
from __future__ import annotations

import jax.numpy as jnp

from .merge_math import KAPPA_MIN


def init_cache(slots: int, dtype=jnp.float32):
    """Fresh all-stale cache (``count = 0`` masks every entry)."""
    return jnp.zeros((slots, slots), dtype)


def exact_cache(sv_x, gamma, dtype=jnp.float32):
    """Ground-truth cache recomputed from the SV set (tests / benchmarks /
    cache (re)builds after checkpoint restore)."""
    from ..kernels import ref

    x = sv_x.astype(jnp.float32)
    k = ref.rbf_matrix(x, x, gamma).astype(dtype)
    # I3: rbf_matrix yields exp(-gamma * eps) on the diagonal, not exactly 1
    return jnp.where(jnp.eye(k.shape[0], dtype=bool), 1.0, k)


def _safe_log(k):
    return jnp.log(jnp.clip(k.astype(jnp.float32), KAPPA_MIN, 1.0))


def _combine_rows(lk_a, lk_b, lk_ab, h):
    """Log-space kernel row of ``z = h x_a + (1-h) x_b`` (module docstring).

    The clamp at 0 enforces ``k <= 1``; without it, fp noise in the
    ``-h(1-h) log k_ab`` term could push near-duplicate entries above 1.
    """
    lz = h * lk_a + (1.0 - h) * lk_b - h * (1.0 - h) * lk_ab
    return jnp.minimum(lz, 0.0)


def z_row_from_rows(row_i, row_j, k_ij, h):
    """``k(z, .)`` from the two parents' kernel rows and their pair kernel
    (rows the caller already gathered — lets hot paths batch their gathers)."""
    lz = _combine_rows(_safe_log(row_i), _safe_log(row_j), _safe_log(k_ij), h)
    return jnp.exp(lz)


def merge_z_row(kmat, i, j, h):
    """``k(z, sv[q])`` for all slots ``q``, from cached rows only.

    ``z = h sv[i] + (1-h) sv[j]``; exact for the RBF kernel up to the
    ``KAPPA_MIN`` clip (entries that small are numerically zero anyway).
    """
    return z_row_from_rows(kmat[i], kmat[j], kmat[i, j], h).astype(kmat.dtype)


# --------------------------------------------------------------------------
# Incremental updates, mirroring the SV-array edits in ``core.budget``
# --------------------------------------------------------------------------
def insert_rows(kmat, idx, k_new_old, k_new_new):
    """Cache update for a minibatch insert at slots ``idx``.

    idx:       (batch,) target slots; entries ``== slots`` are dropped
               (non-violators), matching the sv_x scatter in ``train_step``.
    k_new_old: (batch, slots) ``k(xb, sv_old)`` — the rows the margin
               computation already produced (reused, not recomputed).
    k_new_new: (batch, batch) ``k(xb, xb)`` — kernel among the new points.
    """
    # Columns of the new rows at the inserted slots hold new-vs-new values
    # (k_new_old there is stale: it was computed against pre-insert sv_x).
    rows = k_new_old.astype(kmat.dtype).at[:, idx].set(
        k_new_new.astype(kmat.dtype), mode="drop")
    kmat = kmat.at[idx, :].set(rows, mode="drop")
    kmat = kmat.at[:, idx].set(rows.T, mode="drop")
    # I3: the diagonal of the inserted block is exactly 1 (rbf_matrix gives
    # exp(-gamma * eps) on the diagonal, not exactly 1).
    kmat = kmat.at[idx, idx].set(1.0, mode="drop")
    return kmat


def apply_merge(kmat, i_min, j_star, last, h):
    """Cache update for one merge, mirroring ``budget``'s compaction exactly:
    slot ``lo`` <- z, slot ``hi`` <- old slot ``last``, ``last`` retired.
    """
    z_row = merge_z_row(kmat, i_min, j_star, h)
    lo = jnp.minimum(i_min, j_star)
    hi = jnp.maximum(i_min, j_star)
    row_last = kmat[last]
    kmat = kmat.at[hi, :].set(row_last)
    kmat = kmat.at[:, hi].set(row_last)
    kmat = kmat.at[hi, hi].set(1.0)
    # z_row was computed against the pre-move layout; slot hi now holds the
    # old ``last`` vector, and the diagonal entry is k(z, z) = 1.
    z_row = z_row.at[hi].set(z_row[last]).at[lo].set(1.0)
    kmat = kmat.at[lo, :].set(z_row)
    kmat = kmat.at[:, lo].set(z_row)
    return kmat


def apply_removal(kmat, i_min, last):
    """Cache update for the removal fallback: slot ``i_min`` <- old ``last``."""
    row_last = kmat[last]
    kmat = kmat.at[i_min, :].set(row_last)
    kmat = kmat.at[:, i_min].set(row_last)
    kmat = kmat.at[i_min, i_min].set(1.0)
    return kmat


def apply_multi_merge(kmat, a_idx, b_idx, h, write_idx):
    """Batched cache update for P fused merges (pairs ``(a_p, b_p)``).

    a_idx, b_idx: (P,) slot indices of the pairs (disjoint across pairs).
    h:            (P,) merge coefficients.
    write_idx:    (P,) slot receiving ``z_p`` (``a_p``), or ``slots`` for
                  pairs that did not execute / fell back to removal (those
                  scatters drop).

    Writes the P new ``z`` rows/columns plus the (P, P) cross block
    ``k(z_p, z_q)`` — itself derived by applying the merge identity a second
    time, to the z rows.  Compaction is a separate permutation (``permute``).
    """
    p = a_idx.shape[0]
    lk = _safe_log(kmat[jnp.concatenate([a_idx, b_idx])])   # one (2P,) gather
    lk_a, lk_b = lk[:p], lk[p:]                    # (P, slots) each
    lk_ab = lk_a[jnp.arange(p), b_idx]             # (P,) log k(a_p, b_p)
    lz = _combine_rows(lk_a, lk_b, lk_ab[:, None], h[:, None])   # (P, slots)
    z_rows = jnp.exp(lz).astype(kmat.dtype)
    # Cross block: z_q = h_q a_q + (1-h_q) b_q, so k(z_p, z_q) combines the
    # z_p row's entries at a_q and b_q with the (a_q, b_q) pair kernel.
    cross = jnp.exp(_combine_rows(lz[:, a_idx], lz[:, b_idx],
                                  lk_ab[None, :], h[None, :]))
    # k(z_p, z_q) and k(z_q, z_p) take different fp paths; average to keep
    # the cache exactly symmetric (I2), and pin the diagonal (I3).
    cross = 0.5 * (cross + cross.T)
    cross = jnp.where(jnp.eye(p, dtype=bool), 1.0, cross).astype(kmat.dtype)
    kmat = kmat.at[write_idx, :].set(z_rows, mode="drop")
    kmat = kmat.at[:, write_idx].set(z_rows.T, mode="drop")
    kmat = kmat.at[write_idx[:, None], write_idx[None, :]].set(cross,
                                                              mode="drop")
    return kmat


def permute(kmat, perm):
    """Apply a slot permutation to both axes (multi-merge compaction)."""
    return kmat[perm][:, perm]


class CacheInvariantError(AssertionError):
    """A runtime I1-I3 violation detected by ``check_invariants``."""


def check_invariants(kmat, sv_x, count, gamma, *, tol: float = 5e-5,
                     context: str = "") -> None:
    """Debug-mode runtime check of cache invariants I1-I3 (DESIGN.md §4).

    Host-side and O(count^2 * dim) — strictly a debug tool, wired into the
    streaming drivers behind ``debug_invariants=True`` (DESIGN.md §16).
    Verifies, masked by the active watermark:

      I1. ``kmat[:c, :c]`` equals a from-scratch Gram rebuild within ``tol``;
      I2. the active block is exactly symmetric;
      I3. the active diagonal is exactly 1.

    Stacked multiclass arrays (3-D ``sv_x``) are checked per class.  Raises
    ``CacheInvariantError`` naming the violated invariant and the worst
    entry; I4 (stale entries past the watermark) is by definition
    uncheckable — consumers mask by ``count``.
    """
    import numpy as np

    sv = np.asarray(sv_x)
    if sv.ndim == 3:
        for q in range(sv.shape[0]):
            check_invariants(np.asarray(kmat)[q], sv[q],
                             np.asarray(count)[q], gamma, tol=tol,
                             context=f"{context}[class {q}]")
        return
    c = int(count)
    if c == 0:
        return
    got = np.asarray(kmat, np.float32)[:c, :c]
    want = np.asarray(exact_cache(jnp.asarray(sv[:c], jnp.float32), gamma))
    where = f"{context}: " if context else ""
    if not np.array_equal(got, got.T):
        i, j = np.unravel_index(np.argmax(np.abs(got - got.T)), got.shape)
        raise CacheInvariantError(
            f"{where}I2 violated: kmat[{i},{j}]={got[i, j]!r} != "
            f"kmat[{j},{i}]={got[j, i]!r}")
    diag = np.diag(got)
    if not np.array_equal(diag, np.ones(c, got.dtype)):
        i = int(np.argmax(np.abs(diag - 1.0)))
        raise CacheInvariantError(
            f"{where}I3 violated: kmat[{i},{i}]={diag[i]!r} != 1")
    err = np.abs(got - want)
    if not np.all(err <= tol):
        i, j = np.unravel_index(np.argmax(err), err.shape)
        raise CacheInvariantError(
            f"{where}I1 violated: |kmat[{i},{j}] - k(sv_{i}, sv_{j})| = "
            f"{err[i, j]:.3e} > tol {tol:g} (cached {got[i, j]!r}, "
            f"exact {want[i, j]!r})")
