"""Budgeted Stochastic Gradient Descent kernel SVM (Pegasos + merge budget).

Faithful JAX port of the paper's training loop (Wang et al. 2012 BSGD with the
paper's four budget-maintenance solvers), adapted to fixed shapes:

  * SV storage has ``slots = budget + batch_size`` rows; ``count`` is the
    active watermark.  Insert = scatter at the watermark; merge = masked
    argmin + compaction (see ``core.budget``).
  * Pegasos step t:  eta_t = 1/(lambda t);  alpha *= (1 - eta_t lambda);
    every margin violator in the minibatch is inserted with
    alpha = eta_t y / batch_size;  maintenance runs until count <= budget
    via the pluggable engine in ``core.budget`` (merge / multi-merge /
    removal strategies, optionally backed by the persistent SV-SV kernel
    cache in ``core.kernel_cache`` — DESIGN.md §4-5).
  * ``batch_size = 1`` reproduces the paper's setting exactly; larger
    minibatches are the TPU-friendly configuration (see DESIGN.md §3).

Everything jits; ``train_epoch`` wraps the step in ``lax.scan`` so a whole
pass over the data is one XLA program.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import budget as budget_mod
from . import kernel_cache
from .lookup import MergeLookupTable, default_table
from ..kernels import ops as kops


class SVMState(NamedTuple):
    sv_x: jax.Array    # (slots, dim)
    alpha: jax.Array   # (slots,)
    count: jax.Array   # () int32 — active SVs
    step: jax.Array    # () int32 — Pegasos t (starts at 1)
    n_inserts: jax.Array  # () int32 — margin violations so far
    n_merges: jax.Array   # () int32 — budget-maintenance events so far
    kmat: jax.Array | None = None  # (slots, slots) SV-SV kernel cache (fp32),
                                   # or None when cfg.use_kernel_cache is off;
                                   # invariants in core.kernel_cache / DESIGN.md


@dataclasses.dataclass(frozen=True)
class BSGDConfig:
    """Hyperparameters. C-parameterization: lambda = 1 / (n * C) (paper §4)."""

    budget: int = 100
    lambda_: float = 1e-4
    gamma: float = 1.0
    method: str = "lookup-wd"          # gss | gss-precise | lookup-h | lookup-wd
    batch_size: int = 1
    grid_size: int = 400
    dtype: str = "float32"             # alpha / margin arithmetic dtype
    sv_dtype: str | None = None        # SV row storage (bf16 halves HBM + gather
                                       # traffic at scale; kappa error ~1e-3)
    use_kernel_cache: bool = False     # persistent SV-SV kernel matrix: kappa
                                       # rows are read, not recomputed
    maintenance: str = "merge"         # merge | multi-merge | removal
    merge_batch: int = 4               # P pairs per fused multi-merge event
    unroll_maintenance: bool = False   # inline batch_size masked events instead
                                       # of a while_loop: bitwise loop-parity
                                       # under vmap (core.budget docstring);
                                       # compile size grows with batch_size

    def __post_init__(self):
        if self.maintenance not in budget_mod.STRATEGIES:
            raise ValueError(f"maintenance={self.maintenance!r} not in "
                             f"{budget_mod.STRATEGIES}")
        if self.maintenance == "multi-merge" and not (
                1 <= self.merge_batch <= self.budget):
            raise ValueError("multi-merge needs 1 <= merge_batch <= budget")

    @property
    def slots(self) -> int:
        return self.budget + self.batch_size

    def table(self) -> MergeLookupTable | None:
        if self.method.startswith("lookup"):
            return default_table(self.grid_size)
        return None

    @staticmethod
    def from_C(n: int, C: float, **kw) -> "BSGDConfig":
        return BSGDConfig(lambda_=1.0 / (n * C), **kw)


def init_state(cfg: BSGDConfig, dim: int) -> SVMState:
    dt = jnp.dtype(cfg.dtype)
    z = jnp.zeros((), jnp.int32)
    return SVMState(
        sv_x=jnp.zeros((cfg.slots, dim), jnp.dtype(cfg.sv_dtype or cfg.dtype)),
        alpha=jnp.zeros((cfg.slots,), dt),
        count=z, step=jnp.ones((), jnp.int32), n_inserts=z, n_merges=z,
        kmat=kernel_cache.init_cache(cfg.slots) if cfg.use_kernel_cache
        else None)


def decision_function(state: SVMState, x, gamma, *, impl: str = "auto"):
    """f(x) = sum_j alpha_j k(sv_j, x);  x: (n, d) -> (n,)."""
    k = kops.rbf_matrix(x, state.sv_x, gamma, impl=impl)          # (n, slots)
    active = jnp.arange(state.alpha.shape[0]) < state.count
    return k @ jnp.where(active, state.alpha, 0.0)


def predict(state: SVMState, x, gamma, **kw):
    return jnp.sign(decision_function(state, x, gamma, **kw))


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step_from_rows(cfg: BSGDConfig, table, state: SVMState, xb, yb,
                         k_b, k_bb=None, *, impl: str = "auto") -> SVMState:
    """Pegasos minibatch step + maintenance from precomputed kernel rows.

    ``k_b = k(xb, sv_x)`` of shape (batch, slots) and — only when the kernel
    cache is on — ``k_bb = k(xb, xb)`` of shape (batch, batch).  This is the
    seam the one-vs-rest engine (``core.multiclass``) vmaps over the class
    axis: all classes' rows come from ONE fused ``rbf_matrix`` call against
    the flattened (C * slots, dim) SV bank, then each class runs this
    row-consuming step.  Everything below is vmap-clean (masked argmin/top-k,
    scatter-with-drop — no per-example control flow).
    """
    slots = cfg.slots
    t = state.step
    eta = 1.0 / (cfg.lambda_ * t)

    # margins under the current model; the kernel rows k(xb, sv) are kept —
    # they double as the cache update on insert (zero extra kernel evals)
    # mask by the state's own width: callers may replay a step under a
    # one-larger budget on the same arrays (see bench_table3 decision_stats)
    active = jnp.arange(state.alpha.shape[0]) < state.count
    f = k_b.astype(state.alpha.dtype) @ jnp.where(active, state.alpha, 0.0)
    margin = yb * f

    # Pegasos shrink: w <- (1 - eta lambda) w  == alpha *= (1 - 1/t)
    alpha = state.alpha * (1.0 - eta * cfg.lambda_)

    # insert violators at the watermark (scatter with drop for non-violators)
    viol = margin < 1.0
    pos = state.count + jnp.cumsum(viol.astype(jnp.int32)) - 1
    idx = jnp.where(viol, pos, slots)                 # slots == OOB -> dropped
    sv_x = state.sv_x.at[idx].set(xb.astype(state.sv_x.dtype), mode="drop")
    new_alpha = (eta * yb / cfg.batch_size).astype(alpha.dtype)
    alpha = alpha.at[idx].set(new_alpha, mode="drop")
    n_new = jnp.sum(viol).astype(jnp.int32)
    count = state.count + n_new

    kmat = state.kmat
    if cfg.use_kernel_cache:
        kmat = kernel_cache.insert_rows(kmat, idx, k_b, k_bb)

    # budget maintenance until count <= budget (strategy layer: core.budget)
    sv_x, alpha, kmat, count, n_merges = budget_mod.run_maintenance(
        sv_x, alpha, kmat, count, state.n_merges, cfg.gamma, table,
        budget=cfg.budget, strategy=cfg.maintenance, method=cfg.method,
        merge_batch=cfg.merge_batch, impl=impl,
        unroll=cfg.batch_size if cfg.unroll_maintenance else 0)

    return SVMState(sv_x=sv_x, alpha=alpha, count=count, step=t + 1,
                    n_inserts=state.n_inserts + n_new, n_merges=n_merges,
                    kmat=kmat)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step(cfg: BSGDConfig, table, state: SVMState, xb, yb, *,
               impl: str = "auto") -> SVMState:
    """One Pegasos minibatch step + budget maintenance.

    xb: (batch, dim), yb: (batch,) in {-1, +1}.
    """
    k_b = kops.rbf_matrix(xb, state.sv_x, cfg.gamma, impl=impl)   # (batch, slots)
    k_bb = (kops.rbf_matrix(xb, xb, cfg.gamma, impl=impl)         # (batch, batch)
            if cfg.use_kernel_cache else None)
    return train_step_from_rows(cfg, table, state, xb, yb, k_b, k_bb,
                                impl=impl)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_epoch(cfg: BSGDConfig, table, state: SVMState, x, y, perm, *,
                impl: str = "auto") -> SVMState:
    """One pass over the data as a single lax.scan.

    x: (n, d), y: (n,), perm: (n,) shuffled indices; n must be a multiple of
    cfg.batch_size (callers truncate).
    """
    n = perm.shape[0]
    steps = n // cfg.batch_size
    order = perm[: steps * cfg.batch_size].reshape(steps, cfg.batch_size)

    def scan_body(st, batch_idx):
        xb = jnp.take(x, batch_idx, axis=0)
        yb = jnp.take(y, batch_idx, axis=0)
        return train_step(cfg, table, st, xb, yb, impl=impl), ()

    state, _ = jax.lax.scan(scan_body, state, order)
    return state


def fit(cfg: BSGDConfig, x, y, *, epochs: int = 1, seed: int = 0,
        impl: str = "auto", state: SVMState | None = None) -> SVMState:
    """Convenience driver: shuffled epochs over (x, y)."""
    table = cfg.table()
    if state is None:
        state = init_state(cfg, x.shape[1])
    key = jax.random.PRNGKey(seed)
    for _ in range(epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, x.shape[0])
        state = train_epoch(cfg, table, state, x, y, perm, impl=impl)
    return state


def accuracy(state: SVMState, x, y, gamma, **kw) -> jax.Array:
    pred = predict(state, x, gamma, **kw)
    return jnp.mean((pred == y).astype(jnp.float32))
