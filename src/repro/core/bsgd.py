"""Budgeted Stochastic Gradient Descent kernel SVM (Pegasos + merge budget).

Faithful JAX port of the paper's training loop (Wang et al. 2012 BSGD with the
paper's four budget-maintenance solvers), adapted to fixed shapes:

  * SV storage has ``slots = budget + batch_size`` rows; ``count`` is the
    active watermark.  Insert = scatter at the watermark; merge = masked
    argmin + compaction (see ``core.budget``).
  * Pegasos step t:  eta_t = 1/(lambda t);  alpha *= (1 - eta_t lambda);
    every margin violator in the minibatch is inserted with
    alpha = eta_t y / batch_size;  maintenance runs until count <= budget
    via the pluggable engine in ``core.budget`` (merge / multi-merge /
    removal strategies, optionally backed by the persistent SV-SV kernel
    cache in ``core.kernel_cache`` — DESIGN.md §4-5).
  * ``batch_size = 1`` reproduces the paper's setting exactly; larger
    minibatches are the TPU-friendly configuration (see DESIGN.md §3).

Everything jits; ``train_epoch`` wraps the step in ``lax.scan`` so a whole
pass over the data is one XLA program.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import budget as budget_mod
from . import kernel_cache
from .lookup import MergeLookupTable, default_table
from ..kernels import ops as kops


class SVMState(NamedTuple):
    sv_x: jax.Array    # (slots, dim)
    alpha: jax.Array   # (slots,)
    count: jax.Array   # () int32 — active SVs
    step: jax.Array    # () int32 — Pegasos t (starts at 1)
    n_inserts: jax.Array  # () int32 — margin violations so far
    n_merges: jax.Array   # () int32 — budget-maintenance events so far
    kmat: jax.Array | None = None  # (slots, slots) SV-SV kernel cache (fp32),
                                   # or None when cfg.use_kernel_cache is off;
                                   # invariants in core.kernel_cache / DESIGN.md


@dataclasses.dataclass(frozen=True)
class BSGDConfig:
    """Budgeted-SGD hyperparameters (one binary problem).

    Attributes:
      budget: maximum active support vectors; maintenance runs whenever the
        post-insert count exceeds it (storage is ``slots = budget +
        batch_size`` rows, DESIGN.md §2).
      lambda_: Pegasos regularization; the paper's C-parameterization is
        ``lambda = 1 / (n * C)`` (``BSGDConfig.from_C``).
      gamma: RBF kernel width, k(a, b) = exp(-gamma ||a - b||^2).
      method: how merge candidates are scored — ``gss`` (runtime golden
        section search, eps 0.01), ``gss-precise`` (eps 1e-10, reference),
        ``lookup-h`` / ``lookup-wd`` (the paper's precomputed bilinear
        tables; ``lookup-wd`` needs the fewest flops).
      batch_size: minibatch rows per Pegasos step; 1 reproduces the paper,
        larger is the TPU-friendly configuration.
      grid_size: resolution of the precomputed lookup tables.
      dtype: alpha / margin arithmetic dtype.
      sv_dtype: SV row storage dtype (``"bfloat16"`` halves HBM + gather
        traffic at scale; kappa error ~1e-3); None = ``dtype``.
      use_kernel_cache: maintain the persistent (slots, slots) SV-SV kernel
        matrix so maintenance reads kappa rows instead of recomputing them
        (DESIGN.md §4).
      maintenance: what one maintenance event does — ``merge`` (paper
        Alg. 1), ``multi-merge`` (P fused pairs/event), ``removal``
        (drop smallest-|alpha|; no kernel evals), ``removal-project``
        (BOGD: drop + project mass onto survivors via cached rows) or
        ``quantized`` (fixed-centroid codebook absorbs arriving violators
        via cached rows, arXiv 1701.00167 — the online-learning strategy;
        requires the cache, xla engines only).
      merge_batch: P, pairs per fused multi-merge event.
      unroll_maintenance: inline ``batch_size`` masked events instead of the
        while_loop — bitwise loop-parity under vmap (DESIGN.md §5);
        compile size grows with ``batch_size``.
      maintenance_engine: how maintenance events execute — ``"xla"`` (the
        per-class engine in ``core.budget``; vmapped over the class axis by
        the multi-class step) or ``"pallas"`` (the fused maintenance-event
        engine: one ``kernels.ops.merge_event`` round per event, classes
        folded onto the kernel grid, sorted-excess schedule — DESIGN.md
        §11).  ``"pallas"`` requires ``use_kernel_cache=True``,
        ``maintenance="merge"`` and ``method="lookup-wd"``.
      step_engine: how a WHOLE train step executes — ``"composed"`` (margin
        rbf -> shrink/insert -> maintenance engine, three phase launches) or
        ``"pallas"`` (the fused train-step megakernel
        ``kernels/train_step.py``: margin + insert + event rounds chained in
        one launch per class block, the kernel cache maintained in VMEM
        across phases — DESIGN.md §12).  ``"pallas"`` requires
        ``use_kernel_cache=True``, ``method="lookup-wd"`` and
        ``maintenance`` in ``("merge", "multi-merge")``; on non-TPU backends
        it dispatches to the fused reference path ``ref.train_step_fused``
        (one XLA program instead of three phase launches).
      solver: which optimizer drives the working set — ``"bsgd"`` (primal
        Pegasos SGD, the source paper) or ``"bdca"`` (dual coordinate
        ascent over the budgeted bank, ``core.bdca`` / arXiv 1806.10182).
        Both share violator insertion, the kernel cache, the maintenance
        strategy layer, streaming and serving (the §14 solver contract in
        DESIGN.md).  ``"bdca"`` ascends on the cached Gram matrix, so it
        requires ``use_kernel_cache=True``; the fused train-step megakernel
        implements the Pegasos update, so ``step_engine="pallas"`` is
        incompatible (``maintenance_engine="pallas"`` composes fine).
      bdca_rounds: Gauss-Seidel coordinate-ascent sweeps over the working
        set per minibatch step (``solver="bdca"`` only).  Each sweep is one
        O(slots^2) pass over the cached Gram matrix; 2 is the
        speed/optimality sweet spot at bench sizes.
      bdca_C: the dual box constraint ``0 <= alpha_i <= C``
        (``solver="bdca"`` only).  The same C-parameterization as
        ``from_C`` — pass ``bdca_C=C`` alongside ``lambda_ = 1/(nC)`` for a
        like-for-like solver comparison.
    """

    budget: int = 100
    lambda_: float = 1e-4
    gamma: float = 1.0
    method: str = "lookup-wd"          # gss | gss-precise | lookup-h | lookup-wd
    batch_size: int = 1
    grid_size: int = 400
    dtype: str = "float32"             # alpha / margin arithmetic dtype
    sv_dtype: str | None = None        # SV row storage (bf16 halves HBM + gather
                                       # traffic at scale; kappa error ~1e-3)
    use_kernel_cache: bool = False     # persistent SV-SV kernel matrix: kappa
                                       # rows are read, not recomputed
    maintenance: str = "merge"         # merge | multi-merge | removal |
                                       # removal-project | quantized
    merge_batch: int = 4               # P pairs per fused multi-merge event
    unroll_maintenance: bool = False   # inline batch_size masked events instead
                                       # of a while_loop: bitwise loop-parity
                                       # under vmap (core.budget docstring);
                                       # compile size grows with batch_size
    maintenance_engine: str = "xla"    # xla | pallas — pallas runs the fused
                                       # all-class merge-event kernel on the
                                       # sorted-excess schedule (DESIGN.md §11)
    step_engine: str = "composed"      # composed | pallas — pallas fuses the
                                       # whole step (margin + insert + event
                                       # rounds) into one launch chain per
                                       # class block (DESIGN.md §12)
    solver: str = "bsgd"               # bsgd | bdca — primal Pegasos SGD or
                                       # dual coordinate ascent (core.bdca);
                                       # the §14 solver contract
    bdca_rounds: int = 2               # ascent sweeps per step (bdca only)
    bdca_C: float = 1.0                # dual box 0 <= alpha <= C (bdca only)

    def __post_init__(self):
        if self.maintenance not in budget_mod.STRATEGIES:
            raise ValueError(f"maintenance={self.maintenance!r} not in "
                             f"{budget_mod.STRATEGIES}")
        if self.maintenance == "multi-merge" and not (
                1 <= self.merge_batch <= self.budget):
            raise ValueError("multi-merge needs 1 <= merge_batch <= budget")
        if self.maintenance_engine not in ("xla", "pallas"):
            raise ValueError(f"maintenance_engine={self.maintenance_engine!r}"
                             " not in ('xla', 'pallas')")
        if self.maintenance_engine == "pallas" and not (
                self.use_kernel_cache and self.maintenance == "merge"
                and self.method == "lookup-wd"):
            raise ValueError(
                "maintenance_engine='pallas' runs the fused Lookup-WD merge "
                "event off the kernel cache: it requires "
                "use_kernel_cache=True, maintenance='merge' and "
                "method='lookup-wd'")
        if self.maintenance in ("removal-project", "quantized") \
                and not self.use_kernel_cache:
            raise ValueError(
                f"maintenance={self.maintenance!r} reads projection/"
                "absorption coefficients from cached kernel rows: it "
                "requires use_kernel_cache=True")
        if self.step_engine not in ("composed", "pallas"):
            raise ValueError(f"step_engine={self.step_engine!r} not in "
                             "('composed', 'pallas')")
        if self.step_engine == "pallas" and not (
                self.use_kernel_cache and self.method == "lookup-wd"
                and self.maintenance in ("merge", "multi-merge")):
            raise ValueError(
                "step_engine='pallas' runs the fused train-step megakernel "
                "off the kernel cache: it requires use_kernel_cache=True, "
                "method='lookup-wd' and maintenance in "
                "('merge', 'multi-merge')")
        if self.solver not in ("bsgd", "bdca"):
            raise ValueError(f"solver={self.solver!r} not in "
                             "('bsgd', 'bdca')")
        if self.solver == "bdca":
            if not self.use_kernel_cache:
                raise ValueError(
                    "solver='bdca' ascends on the cached working-set Gram "
                    "matrix (SVMState.kmat): it requires "
                    "use_kernel_cache=True")
            if self.step_engine == "pallas":
                raise ValueError(
                    "step_engine='pallas' fuses the Pegasos primal update; "
                    "solver='bdca' needs step_engine='composed' "
                    "(maintenance_engine='pallas' composes fine)")
            if self.bdca_rounds < 1:
                raise ValueError("solver='bdca' needs bdca_rounds >= 1")
            if not self.bdca_C > 0:
                raise ValueError("solver='bdca' needs bdca_C > 0")

    @property
    def slots(self) -> int:
        return self.budget + self.batch_size

    def table(self) -> MergeLookupTable | None:
        if self.method.startswith("lookup"):
            return default_table(self.grid_size)
        return None

    @staticmethod
    def from_C(n: int, C: float, **kw) -> "BSGDConfig":
        return BSGDConfig(lambda_=1.0 / (n * C), **kw)


def init_state(cfg: BSGDConfig, dim: int) -> SVMState:
    dt = jnp.dtype(cfg.dtype)
    # distinct zero buffers per counter: the streaming path donates the whole
    # state, and XLA rejects the same buffer donated twice
    z = lambda: jnp.zeros((), jnp.int32)
    return SVMState(
        sv_x=jnp.zeros((cfg.slots, dim), jnp.dtype(cfg.sv_dtype or cfg.dtype)),
        alpha=jnp.zeros((cfg.slots,), dt),
        count=z(), step=jnp.ones((), jnp.int32), n_inserts=z(), n_merges=z(),
        kmat=kernel_cache.init_cache(cfg.slots) if cfg.use_kernel_cache
        else None)


def decision_function(state: SVMState, x, gamma, *, impl: str = "auto"):
    """f(x) = sum_j alpha_j k(sv_j, x);  x: (n, d) -> (n,)."""
    k = kops.rbf_matrix(x, state.sv_x, gamma, impl=impl)          # (n, slots)
    active = jnp.arange(state.alpha.shape[0]) < state.count
    return k @ jnp.where(active, state.alpha, 0.0)


def predict(state: SVMState, x, gamma, **kw):
    return jnp.sign(decision_function(state, x, gamma, **kw))


def insert_from_rows(cfg: BSGDConfig, state: SVMState, xb, yb, k_b,
                     k_bb=None) -> SVMState:
    """The Pegasos shrink + violator insert half of a step (no maintenance).

    Returns the post-insert state: ``count`` may exceed the budget by up to
    ``batch_size`` — the maintenance engine drains it back.  Split out of
    ``train_step_from_rows`` so the fused maintenance-event engine can vmap
    ONLY this part over the class axis and run maintenance once, outside the
    vmap, on the whole stacked state (``core.multiclass``).
    """
    slots = cfg.slots
    t = state.step
    eta = 1.0 / (cfg.lambda_ * t)

    # margins under the current model; the kernel rows k(xb, sv) are kept —
    # they double as the cache update on insert (zero extra kernel evals)
    # mask by the state's own width: callers may replay a step under a
    # one-larger budget on the same arrays (see bench_table3 decision_stats)
    active = jnp.arange(state.alpha.shape[0]) < state.count
    f = k_b.astype(state.alpha.dtype) @ jnp.where(active, state.alpha, 0.0)
    margin = yb * f

    # Pegasos shrink: w <- (1 - eta lambda) w  == alpha *= (1 - 1/t)
    alpha = state.alpha * (1.0 - eta * cfg.lambda_)

    # insert violators at the watermark (scatter with drop for non-violators)
    viol = margin < 1.0
    pos = state.count + jnp.cumsum(viol.astype(jnp.int32)) - 1
    idx = jnp.where(viol, pos, slots)                 # slots == OOB -> dropped
    sv_x = state.sv_x.at[idx].set(xb.astype(state.sv_x.dtype), mode="drop")
    new_alpha = (eta * yb / cfg.batch_size).astype(alpha.dtype)
    alpha = alpha.at[idx].set(new_alpha, mode="drop")
    n_new = jnp.sum(viol).astype(jnp.int32)

    kmat = state.kmat
    if cfg.use_kernel_cache:
        kmat = kernel_cache.insert_rows(kmat, idx, k_b, k_bb)

    return SVMState(sv_x=sv_x, alpha=alpha, count=state.count + n_new,
                    step=t + 1, n_inserts=state.n_inserts + n_new,
                    n_merges=state.n_merges, kmat=kmat)


def drain_budget(cfg: BSGDConfig, table, state: SVMState, *,
                 impl: str = "auto") -> SVMState:
    """The maintenance half of a train step, shared by every solver.

    Drains an over-budget post-insert ``count`` back to ``cfg.budget``
    through the configured strategy/engine (the §14 solver contract:
    a solver produces the insert/update half, this drain is common).
    """
    unroll = cfg.batch_size if cfg.unroll_maintenance else 0

    if cfg.maintenance_engine == "pallas":
        # the fused event engine is class-batched; the binary step lifts to
        # C = 1 (same decisions and schedule, one no-op-free grid row)
        sv_x, alpha, kmat, count, n_merges = jax.tree.map(
            lambda a: a[0],
            budget_mod.run_maintenance_classes(
                state.sv_x[None], state.alpha[None], state.kmat[None],
                state.count[None], state.n_merges[None], table,
                budget=cfg.budget, impl=impl, unroll=unroll))
    else:
        # budget maintenance until count <= budget (strategy: core.budget)
        sv_x, alpha, kmat, count, n_merges = budget_mod.run_maintenance(
            state.sv_x, state.alpha, state.kmat, state.count, state.n_merges,
            cfg.gamma, table, budget=cfg.budget, strategy=cfg.maintenance,
            method=cfg.method, merge_batch=cfg.merge_batch, impl=impl,
            unroll=unroll)

    return state._replace(sv_x=sv_x, alpha=alpha, count=count,
                          n_merges=n_merges, kmat=kmat)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step_from_rows(cfg: BSGDConfig, table, state: SVMState, xb, yb,
                         k_b, k_bb=None, *, impl: str = "auto") -> SVMState:
    """Pegasos minibatch step + maintenance from precomputed kernel rows.

    ``k_b = k(xb, sv_x)`` of shape (batch, slots) and — only when the kernel
    cache is on — ``k_bb = k(xb, xb)`` of shape (batch, batch).  This is the
    seam the one-vs-rest engine (``core.multiclass``) vmaps over the class
    axis: all classes' rows come from ONE fused ``rbf_matrix`` call against
    the flattened (C * slots, dim) SV bank, then each class runs this
    row-consuming step.  Everything below is vmap-clean (masked argmin/top-k,
    scatter-with-drop — no per-example control flow).
    """
    state = insert_from_rows(cfg, state, xb, yb, k_b, k_bb)
    return drain_budget(cfg, table, state, impl=impl)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_step(cfg: BSGDConfig, table, state: SVMState, xb, yb, *,
               impl: str = "auto") -> SVMState:
    """One minibatch step + budget maintenance (``cfg.solver`` dispatch).

    xb: (batch, dim), yb: (batch,) in {-1, +1}.
    """
    if cfg.solver == "bdca":
        # dual coordinate ascent (core.bdca) — same fused margin rows, same
        # maintenance drain; only the insert/update half differs
        from . import bdca
        k_b = kops.rbf_matrix(xb, state.sv_x, cfg.gamma, impl=impl)
        k_bb = kops.rbf_matrix(xb, xb, cfg.gamma, impl=impl)
        return bdca.train_step_from_rows(cfg, table, state, xb, yb, k_b,
                                         k_bb, impl=impl)
    if cfg.step_engine == "pallas":
        # the fused megakernel is class-batched; the binary step lifts to
        # C = 1 (margin + insert + event rounds in one launch chain)
        k_bb = kops.rbf_matrix(xb, xb, cfg.gamma, impl=impl)
        sv, al, km, cnt, st_, nin, nmg = (a[0] for a in kops.train_step(
            state.sv_x[None], state.alpha[None], state.kmat[None],
            state.count[None], state.step[None], state.n_inserts[None],
            state.n_merges[None], xb, yb[None], k_bb, table,
            budget=cfg.budget, lambda_=cfg.lambda_, gamma=cfg.gamma,
            batch_size=cfg.batch_size, maintenance=cfg.maintenance,
            merge_batch=cfg.merge_batch,
            unroll=cfg.batch_size if cfg.unroll_maintenance else 0,
            impl=impl))
        return SVMState(sv_x=sv, alpha=al, count=cnt, step=st_,
                        n_inserts=nin, n_merges=nmg, kmat=km)
    k_b = kops.rbf_matrix(xb, state.sv_x, cfg.gamma, impl=impl)   # (batch, slots)
    k_bb = (kops.rbf_matrix(xb, xb, cfg.gamma, impl=impl)         # (batch, batch)
            if cfg.use_kernel_cache else None)
    return train_step_from_rows(cfg, table, state, xb, yb, k_b, k_bb,
                                impl=impl)


@partial(jax.jit, static_argnames=("cfg", "impl"))
def train_epoch(cfg: BSGDConfig, table, state: SVMState, x, y, perm, *,
                impl: str = "auto") -> SVMState:
    """One pass over resident data as a single jitted ``lax.scan``.

    Args:
      table: the precomputed ``MergeLookupTable`` (``cfg.table()``), or None
        for the gss methods.
      x: (n, d) rows; y: (n,) labels in {-1, +1}; perm: (n,) row order for
        this epoch (rows past the last full ``batch_size`` multiple are
        dropped).
    Returns the updated ``SVMState``.  The streamed counterpart over a chunk
    source is ``train_epoch_stream``.
    """
    n = perm.shape[0]
    steps = n // cfg.batch_size
    order = perm[: steps * cfg.batch_size].reshape(steps, cfg.batch_size)

    def scan_body(st, batch_idx):
        xb = jnp.take(x, batch_idx, axis=0)
        yb = jnp.take(y, batch_idx, axis=0)
        return train_step(cfg, table, st, xb, yb, impl=impl), ()

    state, _ = jax.lax.scan(scan_body, state, order)
    return state


def fit(cfg: BSGDConfig, x, y, *, epochs: int = 1, seed: int = 0,
        impl: str = "auto", state: SVMState | None = None) -> SVMState:
    """Train a budgeted SVM on in-memory data: shuffled epochs over (x, y).

    Args:
      cfg: hyperparameters (``BSGDConfig``); ``cfg.table()`` supplies the
        precomputed merge lookup when the method needs one.
      x: (n, dim) training rows; y: (n,) labels in {-1, +1}.
      epochs: passes over the data; each uses a fresh permutation derived
        from ``seed``.
      impl: kernel implementation dispatch (``auto | pallas |
        pallas_interpret | ref`` — see ``kernels.ops``).
      state: resume from an existing ``SVMState`` instead of a fresh model
        (its ``slots``/dtypes must match ``cfg``).

    Returns the final ``SVMState``.  For data larger than device memory use
    ``fit_stream`` (same model, chunked host pipeline).
    """
    table = cfg.table()
    if state is None:
        state = init_state(cfg, x.shape[1])
    key = jax.random.PRNGKey(seed)
    for _ in range(epochs):
        key, sub = jax.random.split(key)
        perm = jax.random.permutation(sub, x.shape[0])
        state = train_epoch(cfg, table, state, x, y, perm, impl=impl)
    return state


# ---------------------------------------------------------------------------
# Streaming epochs: chunked host pipeline -> one donated-state program/chunk
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "impl"), donate_argnums=(2,))
def train_chunk(cfg: BSGDConfig, table, state: SVMState, xc, yc, *,
                impl: str = "auto") -> SVMState:
    """One resident chunk as a single donated-state XLA program.

    ``xc: (steps, batch, dim)``, ``yc: (steps, batch)`` — the chunk already
    shuffled and reshaped into minibatches on the host.  The scan body is the
    same traced ``train_step`` as the in-memory ``train_epoch``, so the hot
    path is identical; donating ``state`` lets XLA update the budgeted model
    in place while chunks stream through.
    """
    def body(st, xy):
        xb, yb = xy
        return train_step(cfg, table, st, xb, yb, impl=impl), ()

    state, _ = jax.lax.scan(body, state, (xc, yc))
    return state


def _assemble_chunks(source, key, *, batch_size: int, start_chunk: int,
                     end: int, carry, stage=None, retry=None, report=None,
                     skip_chunks=()):
    """Host-side assembly of one epoch: yield ``(pos, xc, yc, carry)``.

    The single definition of the chunk -> minibatch-block transform shared by
    the synchronous and prefetched streaming paths (bitwise-identity between
    them is BY CONSTRUCTION: the async path runs this very generator on a
    worker thread).  Per chunk: prepend the previous chunk's remainder rows,
    reshape the batch-aligned part to ``(steps, batch, dim)`` (``xc/yc`` are
    None for a chunk that yields no full batch), and copy the new remainder
    out of the chunk buffer (O(chunk) residency promise).  ``stage`` maps the
    assembled blocks (the ``jax.device_put`` hook of the prefetched path).
    ``retry``/``report``/``skip_chunks`` pass straight to ``iter_epoch`` —
    a quarantined (or skipped) chunk contributes no rows, so the carry flows
    across it and the surviving batch sequence is bitwise the one of a run
    where the chunk never existed (DESIGN.md §16).
    """
    from ..data import stream as stream_mod

    cx, cy = carry if carry is not None else (None, None)
    for pos, x, y in stream_mod.iter_epoch(source, key,
                                           start_chunk=start_chunk,
                                           end_chunk=end, retry=retry,
                                           report=report,
                                           skip_chunks=skip_chunks):
        x, y = np.asarray(x), np.asarray(y)
        if cx is not None and cx.size:
            x = np.concatenate([cx.astype(x.dtype, copy=False), x])
            y = np.concatenate([cy.astype(y.dtype, copy=False), y])
        steps = x.shape[0] // batch_size
        used = steps * batch_size
        # copy the (< batch_size rows) remainder: a view would keep the whole
        # chunk buffer alive through the next chunk's load (O(chunk) promise)
        cx, cy = x[used:].copy(), y[used:].copy()
        xc = yc = None
        if steps:
            xc = x[:used].reshape(steps, batch_size, x.shape[1])
            yc = y[:used].reshape(steps, batch_size)
            if stage is not None:
                xc, yc = stage(xc, yc)
        yield pos, xc, yc, (cx, cy)


def _stage_chunks(gen, depth: int):
    """Run an assembly generator ``depth`` items ahead on a worker thread.

    The prefetched streaming pipeline: the worker parses/shuffles/assembles
    (and, via the generator's ``stage`` hook, ``jax.device_put``s) chunk
    ``i+1``..``i+depth`` while the consumer's donated-state scan of chunk
    ``i`` runs.  A bounded queue applies backpressure; a worker exception is
    re-raised on the CONSUMER's thread at the point the failing chunk would
    have been yielded, and abandoning the generator (early close, consumer
    exception) stops the worker promptly — no hung thread either way.
    """
    import queue as queue_mod
    import threading

    q = queue_mod.Queue(maxsize=depth)
    stop = threading.Event()
    _DONE, _FAIL = object(), object()

    def _put(item) -> bool:
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue_mod.Full:
                continue
        return False

    def work():
        try:
            for item in gen:
                if not _put((None, item)):
                    return
            _put((_DONE, None))
        except BaseException as e:  # noqa: BLE001 — re-raised on consumer
            _put((_FAIL, e))

    t = threading.Thread(target=work, daemon=True, name="chunk-stager")
    t.start()
    try:
        while True:
            tag, item = q.get()
            if tag is _DONE:
                return
            if tag is _FAIL:
                raise item
            yield item
    finally:
        stop.set()
        t.join(timeout=5.0)


@jax.jit
def _tree_all_finite(tree):
    """One fused all-finite reduction over the inexact leaves of a pytree —
    the O(1)-sync non-finite sentinel of the streaming guard (int counters
    are always finite and are skipped)."""
    leaves = [leaf for leaf in jax.tree.leaves(tree)
              if jnp.issubdtype(leaf.dtype, jnp.inexact)]
    if not leaves:
        return jnp.bool_(True)
    return jnp.all(jnp.stack([jnp.all(jnp.isfinite(leaf)) for leaf in leaves]))


@dataclasses.dataclass
class _StreamGuard:
    """Per-chunk training guards for the streaming drivers (DESIGN.md §16).

    ``finite=True`` snapshots the state before each chunk program and, after
    it, runs ONE fused ``isfinite`` all-reduce over the float leaves (a
    single scalar sync).  On trip the chunk is rolled back and skipped —
    a poisoned state is never kept, never checkpointed, never published.
    ``check`` (optional, debug mode) runs a host-side validator — the cache
    invariant checker — on every accepted state.
    """

    finite: bool = True
    report: object = None       # faults.ResilienceReport (rollback tally)
    check: object = None        # callable(state) -> None, raises on violation


def _make_guard(guard_finite: bool, debug_invariants: bool, binary_cfg,
                report):
    """Resolve the ``guard_finite``/``debug_invariants`` fit-driver knobs to
    a ``_StreamGuard`` (or None — the exact pre-resilience chunk loop)."""
    if not (guard_finite or debug_invariants):
        return None
    check = None
    if debug_invariants and binary_cfg.use_kernel_cache:
        def check(state):
            kernel_cache.check_invariants(state.kmat, state.sv_x, state.count,
                                          binary_cfg.gamma)
    return _StreamGuard(finite=guard_finite, report=report, check=check)


def _stream_epoch(chunk_fn, state, source, *, batch_size: int, key,
                  start_chunk: int = 0, carry=None, on_chunk=None,
                  max_chunks: int | None = None, prefetch: int = 0,
                  stage=None, retry=None, report=None, skip_chunks=(),
                  guard=None):
    """Generic one-epoch streaming driver shared by binary and multi-class.

    ``chunk_fn(state, xc, yc) -> state`` runs one jitted chunk program.
    Rows left over when a chunk is not a multiple of ``batch_size`` *carry*
    into the next chunk (so the realized batch sequence equals the in-memory
    one on the concatenated order); the final sub-batch rows of the epoch are
    dropped, matching ``train_epoch``'s truncation.  Chunks are staged in the
    source's own dtypes (no forced cast — streamed and in-memory training see
    the same arrays); checkpointed carry rows are stored as float32 and cast
    back on resume.  ``on_chunk(state, pos, carry)`` fires after each chunk
    program — the checkpoint hook.

    ``prefetch > 0`` moves the whole host pipeline (chunk load, shuffle,
    carry splice, minibatch reshape, and — for the default single-device
    programs — the ``jax.device_put`` transfer) onto a background worker
    running up to ``prefetch`` chunks ahead of the device, double-buffered
    against the donated-state scan of the current chunk.  The worker runs the
    same ``_assemble_chunks`` generator as the sync path, so the realized
    batch sequence (and therefore training) is bitwise identical.  ``stage``
    overrides the staging transform (``None`` with a custom distributed
    ``chunk_fn`` keeps host arrays — pjit places them per its in_shardings).

    Resilience (all default-off — the zero-fault path is the exact pre-PR
    loop): ``retry``/``report``/``skip_chunks`` flow into the ingest layer
    (``iter_epoch`` — transient-failure retries, quarantine-as-skip);
    ``guard`` (a ``_StreamGuard``) snapshots the state per chunk and rolls
    back any chunk whose resulting state has a non-finite float leaf, so a
    NaN/Inf row (or a diverged update) can never persist into checkpoints or
    published ``ServeModel`` snapshots — the rollback fires BEFORE
    ``on_chunk``.

    Returns ``(state, next_chunk, carry, chunks_run)``; ``next_chunk <
    source.n_chunks`` means the epoch was cut short by ``max_chunks``.
    """
    # resolve the budget to an exclusive end position up front so chunks past
    # it are never read from the source
    end = (source.n_chunks if max_chunks is None
           else min(source.n_chunks, start_chunk + max_chunks))
    gen = _assemble_chunks(source, key, batch_size=batch_size,
                           start_chunk=start_chunk, end=end, carry=carry,
                           stage=stage if prefetch else None, retry=retry,
                           report=report, skip_chunks=skip_chunks)
    items = _stage_chunks(gen, prefetch) if prefetch else gen
    out_carry = carry
    try:
        for pos, xc, yc, out_carry in items:
            if xc is not None:
                if guard is not None and guard.finite:
                    # the chunk program donates its input state, so the
                    # last-good snapshot must be copied out BEFORE the launch
                    snap = jax.tree.map(jnp.copy, state)
                    new_state = chunk_fn(state, xc, yc)
                    if bool(_tree_all_finite(new_state)):
                        state = new_state
                    else:
                        state = snap       # roll back + skip the poisoned
                        if guard.report is not None:      # chunk wholesale
                            guard.report.note_rollback(pos)
                else:
                    state = chunk_fn(state, xc, yc)
                if guard is not None and guard.check is not None:
                    guard.check(state)
            if on_chunk is not None:
                on_chunk(state, pos, out_carry)
    finally:
        if prefetch:
            items.close()                 # stop the stager on any exit
    if out_carry is None:
        out_carry = (np.zeros((0, source.dim), np.float32),
                     np.zeros((0,), np.float32))
    return state, end, out_carry, end - start_chunk


def _ckpt_template(state: SVMState, batch_size: int, dim: int):
    """Target tree for the streaming checkpoint: model state + epoch RNG key
    + the (padded, fixed-shape) inter-chunk carry rows."""
    return {
        "state": state,
        "epoch_key": jax.random.PRNGKey(0),
        "carry_x": jnp.zeros((batch_size - 1, dim), jnp.float32),
        "carry_y": jnp.zeros((batch_size - 1,), jnp.float32),
        "carry_n": jnp.zeros((), jnp.int32),
    }


def _pad_carry(carry, batch_size: int, dim: int):
    cx, cy = carry
    n = cx.shape[0]
    px = np.zeros((batch_size - 1, dim), np.float32)
    py = np.zeros((batch_size - 1,), np.float32)
    px[:n], py[:n] = cx, cy
    return px, py, np.int32(n)


def _device_stage(xc, yc):
    """Default staging for the prefetched single-device path: start the
    host->device transfer of an assembled block from the worker thread, so
    the copy (and not just the parse) overlaps the previous chunk's scan."""
    return jax.device_put(xc), jax.device_put(yc)


def _fit_stream(batch_size: int, source, chunk_fn, state, *,
                epochs: int, seed: int, ckpt_dir, ckpt_every: int,
                max_chunks, keep_last: int, prefetch: int = 0, stage=None,
                publish=None, publish_every: int = 0, retry=None,
                report=None, skip_chunks=(), guard=None):
    """Shared multi-epoch streaming driver (see ``fit_stream`` for the
    contract).  ``publish(state)`` fires every ``publish_every`` chunks (and
    once at the very end) — the ``ModelBank`` snapshot hook.  Resume walks
    back past torn/corrupt checkpoint steps to the newest verifiable one
    (``checkpoint.latest_verifiable_step``); ``retry``/``report``/
    ``skip_chunks``/``guard`` are the §16 resilience hooks threaded into
    every epoch."""
    from .. import checkpoint as ckpt

    dim = source.dim
    n_chunks = source.n_chunks
    start_epoch, start_chunk = 0, 0
    carry, resume_key = None, None
    if ckpt_dir:
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            # a torn/bit-flipped newest step (crash mid-save outside the
            # atomic-rename path, disk corruption) must not kill the restart:
            # fall back to the newest step whose checksums verify
            verified = ckpt.latest_verifiable_step(ckpt_dir)
            if verified is None:
                raise ValueError(
                    f"{ckpt_dir}: checkpoint steps {ckpt.all_steps(ckpt_dir)}"
                    " exist but none verify (manifest/arrays corrupt) — "
                    "refusing to silently restart from scratch")
            latest = verified
        if latest is not None:
            meta = ckpt.load_metadata(ckpt_dir, latest)
            if meta.get("kind") != "stream-epoch":
                raise ValueError(f"{ckpt_dir}: step {latest} is not a "
                                 "streaming checkpoint")
            # the cursor is only meaningful against the same shuffle and the
            # same chunking — a silent mismatch would train some rows twice
            # and others never, so refuse instead
            if meta["seed"] != seed:
                raise ValueError(
                    f"{ckpt_dir}: checkpoint was written with seed="
                    f"{meta['seed']}, resume called with seed={seed}")
            if meta["n_chunks"] != n_chunks:
                raise ValueError(
                    f"{ckpt_dir}: checkpoint cursor is against "
                    f"{meta['n_chunks']} chunks, source now has {n_chunks} — "
                    "re-chunked data cannot resume mid-epoch")
            tree = ckpt.load(ckpt_dir, latest,
                             _ckpt_template(state, batch_size, dim))
            state = tree["state"]
            start_epoch, start_chunk = meta["epoch"], meta["next_chunk"]
            resume_key = tree["epoch_key"]    # the interrupted epoch's key
            cn = int(tree["carry_n"])
            carry = (np.asarray(tree["carry_x"])[:cn],
                     np.asarray(tree["carry_y"])[:cn])
            if start_chunk >= n_chunks:       # checkpoint at an epoch boundary
                start_epoch, start_chunk, carry = start_epoch + 1, 0, None
                resume_key = None

    budget_left = max_chunks
    base_key = jax.random.PRNGKey(seed)
    for epoch in range(start_epoch, epochs):
        # the resumed epoch continues under its checkpointed RNG key (equal,
        # by the seed guard above, to the rederived one); later epochs fold
        epoch_key = (resume_key if epoch == start_epoch and
                     resume_key is not None
                     else jax.random.fold_in(base_key, epoch))

        def save(st, pos, cr, *, _epoch=epoch, _key=epoch_key):
            done = pos + 1
            if (publish is not None and publish_every
                    and done % publish_every == 0):
                publish(st)
            if not (ckpt_dir and ckpt_every and done % ckpt_every == 0):
                return
            px, py, cn = _pad_carry(cr, batch_size, dim)
            ckpt.save(ckpt_dir, _epoch * n_chunks + done,
                      {"state": st, "epoch_key": _key, "carry_x": px,
                       "carry_y": py, "carry_n": cn},
                      keep_last=keep_last,
                      metadata={"kind": "stream-epoch", "epoch": _epoch,
                                "next_chunk": done, "n_chunks": n_chunks,
                                "seed": seed})

        state, next_chunk, carry, ran = _stream_epoch(
            chunk_fn, state, source, batch_size=batch_size, key=epoch_key,
            start_chunk=start_chunk, carry=carry, on_chunk=save,
            max_chunks=budget_left, prefetch=prefetch, stage=stage,
            retry=retry, report=report, skip_chunks=skip_chunks, guard=guard)
        if budget_left is not None:
            budget_left -= ran
        if next_chunk < n_chunks:             # cut short by max_chunks
            if publish is not None:
                publish(state)
            return state
        jax.block_until_ready(state.alpha)    # sync only at epoch end
        start_chunk, carry = 0, None          # sub-batch remainder dropped
    if publish is not None:
        publish(state)                        # the final model always lands
    return state


def _make_publish(bank, gamma, bank_dtype):
    """Build the ``ModelBank`` snapshot hook for a streaming trainer.

    The chunk programs DONATE the state, so the next chunk invalidates the
    buffers a naive export would alias — the hook copies the state out first
    and publishes a genuinely immutable ``ServeModel`` snapshot.
    """
    if bank is None:
        return None
    from .predict import export_model   # lazy: predict imports this module

    def publish(state):
        snap = jax.tree.map(jnp.copy, state)
        bank.publish(export_model(snap, gamma, bank_dtype=bank_dtype))

    return publish


def train_epoch_stream(cfg: BSGDConfig, table, state: SVMState, source, *,
                       key=None, impl: str = "auto", start_chunk: int = 0,
                       carry=None, on_chunk=None, max_chunks: int | None = None,
                       chunk_fn=None, prefetch: int = 0, retry=None,
                       report=None, skip_chunks=()):
    """One streamed pass over a ``repro.data.stream`` chunk source.

    The chunked counterpart of ``train_epoch``: chunks are loaded on the
    host in the deterministic shuffled order derived from ``key`` (chunk
    order permuted, then rows within each chunk — ``None`` streams in natural
    order), and each becomes ONE donated-state jitted program
    (``train_chunk``); only the budgeted ``SVMState`` stays on device between
    chunks.  Remainder rows of a ragged chunk carry into the next chunk, so
    the realized minibatch sequence equals ``train_epoch`` on
    ``epoch_permutation(source, key)`` — the equivalence the stream tests pin.

    ``start_chunk``/``carry`` resume mid-epoch (see ``fit_stream`` for the
    checkpointed version); ``on_chunk(state, pos, carry)`` fires after each
    chunk; ``max_chunks`` bounds how many chunk programs run (fault drills).
    ``chunk_fn(state, xc, yc)`` overrides the jitted per-chunk program — the
    distributed path passes a pjit'd one (``launch.train.svm_stream_loop``).
    ``prefetch > 0`` assembles (and, for the default chunk program, device-
    transfers) up to that many chunks ahead on a background thread — bitwise
    the same training, the host pipeline just overlaps the device scan
    (DESIGN.md §13).

    Returns ``(state, next_chunk, carry)``; ``next_chunk == source.n_chunks``
    means the epoch completed.  The chunk programs DONATE ``state``: the
    caller's input buffers are consumed — keep using the returned state (or
    use ``fit_stream``, which copies a provided state up front).
    """
    stage = _device_stage if chunk_fn is None else None
    if chunk_fn is None:
        def chunk_fn(st, xc, yc):
            return train_chunk(cfg, table, st, xc, yc, impl=impl)
    state, next_chunk, carry, _ = _stream_epoch(
        chunk_fn, state, source, batch_size=cfg.batch_size, key=key,
        start_chunk=start_chunk, carry=carry, on_chunk=on_chunk,
        max_chunks=max_chunks, prefetch=prefetch, stage=stage, retry=retry,
        report=report, skip_chunks=skip_chunks)
    if next_chunk == source.n_chunks:
        jax.block_until_ready(state.alpha)
    return state, next_chunk, carry


def fit_stream(cfg: BSGDConfig, source, *, epochs: int = 1, seed: int = 0,
               impl: str = "auto", state: SVMState | None = None,
               ckpt_dir: str | None = None, ckpt_every: int = 0,
               max_chunks: int | None = None, keep_last: int = 3,
               chunk_fn=None, prefetch: int = 0, bank=None,
               publish_every: int = 0, publish_dtype=None, retry=None,
               guard_finite: bool = False, debug_invariants: bool = False,
               report=None, skip_chunks=()) -> SVMState:
    """Out-of-core ``fit``: shuffled streamed epochs over a chunk source.

    Args:
      source: a ``repro.data.stream.ChunkSource`` (in-memory ``ArrayChunks``,
        sharded ``FileChunks``, incremental ``LibsvmChunks``); only one chunk
        is host-resident at a time and only the budgeted state lives on
        device across chunks.
      epochs / seed: as in ``fit``; the per-epoch shuffle is the
        deterministic chunk-order + intra-chunk composition (DESIGN.md §9).
      ckpt_dir / ckpt_every: write a resumable checkpoint every
        ``ckpt_every`` chunks through ``repro.checkpoint`` (0 = off).  The
        checkpoint stores the model, the epoch RNG key, the inter-chunk carry
        rows and the ``(epoch, next_chunk)`` cursor; calling ``fit_stream``
        again with the same ``ckpt_dir`` resumes mid-epoch and reproduces the
        uninterrupted run bit-for-bit (the resume test pins this).
      max_chunks: stop after this many chunk programs without writing a final
        checkpoint — simulates a hard kill in tests/fault drills.
      chunk_fn: override the per-chunk program (distributed path).
      prefetch: assemble (and device-transfer, for the default chunk program)
        up to this many chunks ahead on a background thread — bitwise the
        same run as ``prefetch=0`` including checkpoints and resume, the host
        pipeline just overlaps the device scan (DESIGN.md §13).
      bank / publish_every / publish_dtype: publish an immutable, versioned
        ``ServeModel`` snapshot into ``bank`` (a ``core.predict.ModelBank``)
        every ``publish_every`` chunks and once at the end — the
        train-while-serve hot-swap feed.  ``publish_dtype`` quantizes the
        published bank (e.g. ``"bfloat16"``).
      retry / report / skip_chunks: the §16 ingest-resilience hooks — a
        ``data.faults.RetryPolicy`` retries transient chunk-load failures
        with bounded backoff and quarantines (skips + records in ``report``,
        a ``data.faults.ResilienceReport``) chunks that exhaust it;
        ``skip_chunks`` excludes chunk ids up front as if they never existed.
      guard_finite: snapshot the state before each chunk program and run one
        fused ``isfinite`` all-reduce over its float leaves after — a chunk
        producing any non-finite value is rolled back and skipped (recorded
        in ``report``), so NaN/Inf rows can never poison checkpoints or
        published snapshots.  Costs one state copy + one scalar sync per
        chunk; off (default) the chunk loop is exactly the pre-resilience
        program.
      debug_invariants: additionally verify the kernel-cache invariants
        I1-I3 on every accepted state (host-side, O(count^2 * dim) — debug
        only; no-op without ``use_kernel_cache``).

    Returns the final ``SVMState``.  The chunk programs run with donated
    state; a caller-provided ``state`` is copied once up front so the
    caller's arrays stay valid (same non-destructive contract as ``fit``).
    """
    table = cfg.table()
    if state is None:
        state = init_state(cfg, source.dim)
    else:
        state = jax.tree.map(jnp.array, state)   # donation would delete it
    stage = _device_stage if chunk_fn is None else None
    if chunk_fn is None:
        def chunk_fn(st, xc, yc):
            return train_chunk(cfg, table, st, xc, yc, impl=impl)
    return _fit_stream(cfg.batch_size, source, chunk_fn, state,
                       epochs=epochs, seed=seed, ckpt_dir=ckpt_dir,
                       ckpt_every=ckpt_every, max_chunks=max_chunks,
                       keep_last=keep_last, prefetch=prefetch, stage=stage,
                       publish=_make_publish(bank, cfg.gamma, publish_dtype),
                       publish_every=publish_every, retry=retry,
                       report=report, skip_chunks=skip_chunks,
                       guard=_make_guard(guard_finite, debug_invariants,
                                         cfg, report))


def accuracy(state: SVMState, x, y, gamma, **kw) -> jax.Array:
    pred = predict(state, x, gamma, **kw)
    return jnp.mean((pred == y).astype(jnp.float32))
