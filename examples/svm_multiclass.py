"""One-vs-rest multi-class BSGD demo: C budgeted binary problems trained in
lockstep — one fused kernel contraction for all classes' margins per step,
per-class budget maintenance through the shared lookup table.

    PYTHONPATH=src python examples/svm_multiclass.py [--classes 10] [--n 6000]
"""
import argparse
import time

import jax
import numpy as np

from repro.core import (MulticlassSVMConfig, accuracy_multiclass,
                        fit_multiclass, fit_multiclass_loop,
                        fit_multiclass_stream)
from repro.data import ArrayChunks, make_blobs_multiclass, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6000)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--dim", type=int, default=20)
    ap.add_argument("--budget", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=1)
    ap.add_argument("--skip-loop-baseline", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="train through the chunked streaming engine "
                         "(out-of-core path) instead of the resident arrays")
    ap.add_argument("--chunk-rows", type=int, default=1024)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x, y = make_blobs_multiclass(key, args.n, args.dim, args.classes, sep=1.0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = MulticlassSVMConfig.create(
        args.classes, budget=args.budget, lambda_=1e-4, gamma=0.1,
        method="lookup-wd", batch_size=args.batch_size)
    print(f"blobs: n={xtr.shape[0]} d={args.dim} classes={args.classes} "
          f"budget={args.budget}/class (single pass, one-vs-rest"
          f"{', streamed' if args.stream else ''})")
    if args.stream:
        source = ArrayChunks(np.asarray(xtr), np.asarray(ytr),
                             args.chunk_rows)

    def timed(fit_fn):
        """Best-of-3 after a compile warmup (single-shot wall-clock on a
        small shared machine swings 2x either way)."""
        fit_fn(cfg, xtr, ytr, epochs=1, seed=0)
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            st = fit_fn(cfg, xtr, ytr, epochs=1, seed=0)
            jax.block_until_ready(st.alpha)
            times.append(time.perf_counter() - t0)
        return min(times), st

    def fit_streamed(cfg, xtr, ytr, *, epochs, seed):
        return fit_multiclass_stream(cfg, source, epochs=epochs, seed=seed)

    t_batched, st = timed(fit_streamed if args.stream else fit_multiclass)

    acc = float(accuracy_multiclass(st, xte, yte, cfg.binary.gamma))
    merges = np.asarray(st.n_merges)
    print(f"  batched OVR: time={t_batched:6.2f}s  test_acc={acc:.4f}")
    print(f"  per-class merges: {merges.tolist()}  (total {int(merges.sum())})")
    print(f"  per-class SV counts: {np.asarray(st.count).tolist()}")
    assert acc >= 0.9, f"expected >= 90% one-pass accuracy, got {acc:.4f}"

    if not args.skip_loop_baseline:
        t_loop, _ = timed(fit_multiclass_loop)
        print(f"  loop-over-classes baseline: time={t_loop:6.2f}s "
              f"(batched is {t_loop / t_batched:.2f}x)")


if __name__ == "__main__":
    main()
