"""Streaming-epoch BSGD demo: train over a chunked ON-DISK dataset that never
sits in memory whole, match the in-memory model, and survive a mid-epoch kill.

    PYTHONPATH=src python examples/svm_stream.py [--n 8192] [--chunk-rows 1024]

What it shows (DESIGN.md §9):
  1. the dataset is sharded into on-disk ``.npz`` chunks, at least
     ``--min-ratio`` (default 4) times larger than any single resident chunk;
  2. one streamed pass (``fit_stream`` over ``FileChunks``) reproduces the
     in-memory ``train_epoch`` on the SAME realized row order — allclose
     state, equal accuracy;
  3. a run killed mid-epoch (``max_chunks``) resumes from its every-2-chunks
     checkpoint and finishes BITWISE identical to the uninterrupted run;
  4. streamed rows/sec (the number ``benchmarks/bench_stream.py`` records to
     ``BENCH_stream.json``, together with peak RSS).
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import (BSGDConfig, accuracy, fit, fit_stream, init_state,
                        train_epoch)
from repro.data import (FileChunks, epoch_permutation, make_susy_like,
                        train_test_split, write_npz_chunks)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--chunk-rows", type=int, default=1024)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--min-ratio", type=int, default=4,
                    help="dataset must be >= this many resident chunks")
    args = ap.parse_args()

    x, y = make_susy_like(jax.random.PRNGKey(1), args.n)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    xtr, ytr = np.asarray(xtr), np.asarray(ytr)
    cfg = BSGDConfig(budget=args.budget, lambda_=2e-5, gamma=2.0**-7,
                     batch_size=args.batch_size)

    with tempfile.TemporaryDirectory() as tmp:
        paths = write_npz_chunks(os.path.join(tmp, "shards"), xtr, ytr,
                                 args.chunk_rows)
        source = FileChunks(paths)
        ratio = source.n_rows / max(source.chunk_lens)
        print(f"SUSY-like on disk: {source.n_rows} rows in {source.n_chunks} "
              f"chunks of <= {max(source.chunk_lens)} "
              f"({ratio:.1f}x larger than any resident chunk)")
        assert ratio >= args.min_ratio, \
            f"dataset only {ratio:.1f}x a chunk (need >= {args.min_ratio})"

        # -- 1. streamed single pass ------------------------------------
        t0 = time.perf_counter()
        st_stream = fit_stream(cfg, source, epochs=1, seed=0)
        dt = time.perf_counter() - t0
        acc_stream = float(accuracy(st_stream, xte, yte, cfg.gamma))
        print(f"  streamed:  time={dt:6.2f}s rows/sec={source.n_rows/dt:,.0f} "
              f"acc={acc_stream:.4f} SVs={int(st_stream.count)}")

        # -- 2. in-memory reference on the SAME realized order ----------
        ekey = jax.random.fold_in(jax.random.PRNGKey(0), 0)
        perm = epoch_permutation(source, ekey)
        t0 = time.perf_counter()
        st_mem = train_epoch(cfg, cfg.table(), init_state(cfg, source.dim),
                             xtr, ytr, perm)
        jax.block_until_ready(st_mem.alpha)
        dt_mem = time.perf_counter() - t0
        acc_mem = float(accuracy(st_mem, xte, yte, cfg.gamma))
        print(f"  in-memory: time={dt_mem:6.2f}s "
              f"rows/sec={source.n_rows/dt_mem:,.0f} acc={acc_mem:.4f}")
        # the states are allclose (below), not bitwise — chunked scans are
        # different XLA programs — so allow the drift to flip a few test
        # points sitting exactly on the decision boundary
        assert abs(acc_stream - acc_mem) <= 2.0 / len(yte), (acc_stream, acc_mem)
        for name, a, b in zip(st_mem._fields, st_mem, st_stream):
            if a is not None:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-6, err_msg=name)
        print("  state allclose to in-memory train_epoch on the same order")

        # -- 3. kill mid-epoch, resume from checkpoint ------------------
        ck = os.path.join(tmp, "ckpt")
        kill_after = source.n_chunks // 2 + 1
        fit_stream(cfg, source, epochs=1, seed=0, ckpt_dir=ck, ckpt_every=2,
                   max_chunks=kill_after)        # "SIGKILL" after N chunks
        st_resumed = fit_stream(cfg, source, epochs=1, seed=0, ckpt_dir=ck,
                                ckpt_every=2)    # picks up the cursor
        for name, a, b in zip(st_stream._fields, st_stream, st_resumed):
            if a is not None:
                assert np.array_equal(np.asarray(a), np.asarray(b)), name
        print(f"  killed after {kill_after}/{source.n_chunks} chunks, resumed "
              "mid-epoch: final state BITWISE identical")


if __name__ == "__main__":
    main()
