"""Paper reproduction driver: Lookup-WD vs GSS training-time comparison on a
large synthetic stream (the SUSY-like setting, single pass — paper §4).

    PYTHONPATH=src python examples/svm_speedup.py [--n 40000] [--budget 100]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core import BSGDConfig, accuracy, fit
from repro.data import make_susy_like, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=40_000)
    ap.add_argument("--budget", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=1)
    args = ap.parse_args()

    key = jax.random.PRNGKey(1)
    x, y = make_susy_like(key, args.n)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    print(f"SUSY-like stream: n={xtr.shape[0]} d={x.shape[1]} "
          f"budget={args.budget} (single pass)")

    results = {}
    for method in ("gss", "lookup-wd"):
        cfg = BSGDConfig(budget=args.budget, lambda_=2e-5, gamma=2.0**-7,
                         method=method, batch_size=args.batch_size)
        t0 = time.time()
        st = fit(cfg, xtr, ytr, epochs=1, seed=0)
        dt = time.time() - t0
        acc = float(accuracy(st, xte, yte, cfg.gamma))
        freq = int(st.n_merges) / max(int(st.step) - 1, 1)
        results[method] = dt
        print(f"  {method:10s} time={dt:7.2f}s acc={acc:.4f} "
              f"merge_freq={freq:.1%} merges={int(st.n_merges)}")
    imp = 100 * (results["gss"] - results["lookup-wd"]) / results["gss"]
    print(f"total-training-time improvement (Lookup-WD vs GSS): {imp:.1f}% "
          f"(paper: up to 44% on SUSY)")


if __name__ == "__main__":
    main()
