"""Paper reproduction driver: Lookup-WD vs GSS training-time comparison on a
large synthetic stream (the SUSY-like setting, single pass — paper §4).

    PYTHONPATH=src python examples/svm_speedup.py [--n 40000] [--budget 100]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSGDConfig, accuracy, fit, fit_stream, run_maintenance
from repro.data import ArrayChunks, make_susy_like, train_test_split


def merge_seconds_per_event(cfg, table, st, events: int = 64):
    """Seconds per budget-maintenance event, measured as ``events`` merges
    scanned inside one XLA program on SV rows from the trained model — the
    same in-program regime as training, so per-call dispatch overhead (which
    dwarfs a single table lookup) does not pollute the estimate."""
    slots = cfg.budget + events
    reps = -(-slots // cfg.budget)                       # ceil division
    sv = jnp.tile(st.sv_x[: cfg.budget], (reps, 1))[:slots]
    # strictly positive alphas: every event is a genuine same-sign merge
    alpha = jnp.tile(jnp.abs(st.alpha[: cfg.budget]) + 1e-3, (reps,))[:slots]
    tbl = table if cfg.method.startswith("lookup") else None

    def go():
        return run_maintenance(sv, alpha, None, jnp.int32(slots),
                               jnp.int32(0), cfg.gamma, tbl,
                               budget=cfg.budget, method=cfg.method)[1]

    jax.block_until_ready(go())                          # compile warmup
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(go())
        times.append(time.perf_counter() - t0)
    per_event = sorted(times)[len(times) // 2] / events
    # event cost is ~linear in the array width (the rbf_row recompute and the
    # candidate sweep are both O(slots)); rescale from this program's
    # budget+events rows to the budget+batch rows training actually carries
    return per_event * cfg.slots / slots


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=40_000)
    ap.add_argument("--budget", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=1)
    ap.add_argument("--stream", action="store_true",
                    help="train through the chunked streaming engine "
                         "(out-of-core path) instead of the resident arrays")
    ap.add_argument("--chunk-rows", type=int, default=8192)
    args = ap.parse_args()

    key = jax.random.PRNGKey(1)
    x, y = make_susy_like(key, args.n)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    print(f"SUSY-like stream: n={xtr.shape[0]} d={x.shape[1]} "
          f"budget={args.budget} (single pass"
          f"{f', streamed in {args.chunk_rows}-row chunks' if args.stream else ''})")
    source = (ArrayChunks(np.asarray(xtr), np.asarray(ytr), args.chunk_rows)
              if args.stream else None)

    results = {}
    for method in ("gss", "lookup-wd"):
        cfg = BSGDConfig(budget=args.budget, lambda_=2e-5, gamma=2.0**-7,
                         method=method, batch_size=args.batch_size)
        table = cfg.table()
        t0 = time.time()
        if args.stream:
            st = fit_stream(cfg, source, epochs=1, seed=0)
        else:
            st = fit(cfg, xtr, ytr, epochs=1, seed=0)
        dt = time.time() - t0
        acc = float(accuracy(st, xte, yte, cfg.gamma))
        freq = int(st.n_merges) / max(int(st.step) - 1, 1)
        # paper Fig. 3: share of training time spent on budget maintenance,
        # estimated as (events x per-event cost on the trained SV set) / total
        merge_s = int(st.n_merges) * merge_seconds_per_event(cfg, table, st)
        results[method] = dt
        print(f"  {method:10s} time={dt:7.2f}s acc={acc:.4f} "
              f"merge_freq={freq:.1%} merges={int(st.n_merges)} "
              f"merge_time={100 * merge_s / dt:.0f}% of total (est)")
    imp = 100 * (results["gss"] - results["lookup-wd"]) / results["gss"]
    print(f"total-training-time improvement (Lookup-WD vs GSS): {imp:.1f}% "
          f"(paper: up to 44% on SUSY)")


if __name__ == "__main__":
    main()
