"""Quickstart: train a budgeted kernel SVM with the precomputed-lookup merge.

    PYTHONPATH=src python examples/quickstart.py

Builds the 400x400 lookup tables (one-time, <1s), trains BSGD on a
non-linearly-separable problem under a budget of 40 support vectors, and
compares the paper's four budget-maintenance solvers.
"""
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core import BSGDConfig, METHODS, accuracy, default_table, fit
from repro.data import make_two_moons, train_test_split


def main():
    key = jax.random.PRNGKey(0)
    x, y = make_two_moons(key, 3000, noise=0.15)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    print(f"two-moons: {xtr.shape[0]} train / {xte.shape[0]} test")

    t0 = time.time()
    default_table()   # precompute h(m,kappa) / WD(m,kappa) once
    print(f"lookup tables built in {time.time() - t0:.2f}s "
          f"(400x400, GSS eps=1e-10)")

    for method in METHODS:
        cfg = BSGDConfig(budget=40, lambda_=1e-4, gamma=2.0, method=method)
        t0 = time.time()
        st = fit(cfg, xtr, ytr, epochs=3, seed=0)
        acc = float(accuracy(st, xte, yte, cfg.gamma))
        print(f"  {method:12s} acc={acc:.4f}  SVs={int(st.count)}/{cfg.budget} "
              f"merges={int(st.n_merges)}  time={time.time() - t0:.2f}s")


if __name__ == "__main__":
    main()
