"""Beyond-paper demo: serve batched requests with a merge-budgeted KV cache.

The paper's precomputed-merge idea applied to decode-time attention
(core/budgeted_kv.py): when the cache hits its budget, the two least-costly
entries are MERGED with a lookup of the SAME h(m, kappa) table — instead of
evicted.  The paper's core claim (merging beats removal, and the merge
coefficient is a table lookup) transfers: we compare the attention-output
error of the merge policy vs the eviction baseline against an exact full
cache, across a batch of concurrent requests.

    PYTHONPATH=src python examples/budgeted_kv_serve.py [--budget 64]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.budgeted_kv import init_kv_state, kv_append, kv_attend
from repro.core.lookup import default_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--steps", type=int, default=192)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--head-dim", type=int, default=32)
    args = ap.parse_args()

    table = default_table()
    gamma = 1.0 / (2.0 * args.head_dim)        # RBF width matched to q.k scale
    scale = 1.0 / args.head_dim**0.5
    key = jax.random.PRNGKey(0)
    shape = (args.batch, 1, args.heads, args.head_dim)

    states = {p: init_kv_state(args.batch, args.budget, args.heads,
                               args.head_dim, jnp.float32)
              for p in ("merge", "evict")}
    full_k, full_v = [], []
    errs = {"merge": [], "evict": []}
    t0 = time.time()
    for t in range(args.steps):
        key, k1, k2, k3 = jax.random.split(key, 4)
        # a drifting key distribution (nearby keys merge gracefully)
        center = jnp.sin(jnp.arange(args.head_dim) * 0.1 + t * 0.02)
        k_new = center + 0.3 * jax.random.normal(k1, shape)
        v_new = jax.random.normal(k2, shape)
        for policy in states:
            states[policy] = kv_append(states[policy], k_new, v_new, gamma,
                                       table, policy=policy)
        full_k.append(k_new)
        full_v.append(v_new)

        if (t + 1) % 64 == 0:
            q = jax.random.normal(k3, shape)
            fk = jnp.concatenate(full_k, axis=1)
            fv = jnp.concatenate(full_v, axis=1)
            scores = jnp.einsum("bqhd,bwhd->bhqw", q, fk) * scale
            out_f = jnp.einsum("bhqw,bwhd->bqhd", jax.nn.softmax(scores, -1), fv)
            line = f"  t={t+1:4d} cache={int(states['merge'].count):3d}/{args.budget}"
            for policy in ("merge", "evict"):
                out_b = kv_attend(states[policy], q, scale)
                rel = float(jnp.linalg.norm(out_b - out_f)
                            / jnp.maximum(jnp.linalg.norm(out_f), 1e-9))
                errs[policy].append(rel)
                line += f"  {policy}_err={rel:.4f}"
            print(line)

    mem_ratio = args.budget / args.steps
    print(f"done in {time.time()-t0:.1f}s; cache memory = {mem_ratio:.1%} of "
          f"full at t={args.steps}")
    m, e = errs["merge"][-1], errs["evict"][-1]
    print(f"final rel err: merge={m:.4f} evict={e:.4f} "
          f"(merge better by {100*(e-m)/max(e,1e-9):.1f}%)")
    assert m <= e + 1e-6, "merging should not lose to eviction (paper claim)"


if __name__ == "__main__":
    main()
