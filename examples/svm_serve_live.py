"""Train-while-serve demo: a background trainer streams chunks with
``prefetch`` and publishes versioned model snapshots into a ``ModelBank``
while an ``AsyncBatchQueue`` serves ragged requests over it the whole time.

    PYTHONPATH=src python examples/svm_serve_live.py [--n 4096] [--classes 4]

What it shows (DESIGN.md §13):
  1. ``fit_multiclass_stream(bank=, publish_every=K, prefetch=2)`` publishes
     an immutable snapshot every K chunks plus the final model — the serve
     side never waits for training to finish;
  2. the continuous-batching queue hot-swaps to each new version at the
     next microbatch, with no drain and no pause — the served-version
     histogram spans the run;
  3. once the trainer exits, a final pass through the SAME live queue is
     bitwise one direct ``predict_labels`` call on the bank's last version.

``--faults SEED`` turns the run into a chaos drill (DESIGN.md §16): the
chunk source is wrapped in ``FaultyChunks`` with a seeded chaos schedule
(transient IO errors, stalls, one NaN chunk, one fatal chunk) and training
runs with retries, quarantine, and the non-finite publish guard — the same
bitwise-parity and finite-snapshot assertions must still hold.
"""
import argparse
import threading
import time

import jax
import numpy as np

from repro.core import (AsyncBatchQueue, ModelBank, MulticlassSVMConfig,
                        fit_multiclass_stream, predict_labels)
from repro.data import (ArrayChunks, FaultSchedule, FaultyChunks,
                        ResilienceReport, RetryPolicy, make_blobs_multiclass,
                        train_test_split)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--chunk-rows", type=int, default=256)
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--publish-every", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--faults", type=int, default=None, metavar="SEED",
                    help="inject FaultSchedule.chaos(SEED) and train with "
                         "the full recovery stack")
    args = ap.parse_args()

    x, y = make_blobs_multiclass(jax.random.PRNGKey(0), args.n, 16,
                                 n_classes=args.classes, sep=2.5)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    xtr, ytr = np.asarray(xtr, np.float32), np.asarray(ytr, np.int32)
    xte = np.asarray(xte, np.float32)
    cfg = MulticlassSVMConfig.create(args.classes, budget=args.budget,
                                     lambda_=1e-3, gamma=0.5, batch_size=64)
    source = ArrayChunks(xtr, ytr, args.chunk_rows)
    report = ResilienceReport()
    retry = None
    if args.faults is not None:
        source = FaultyChunks(
            source, FaultSchedule.chaos(args.faults, nan_chunk=2,
                                        fatal_chunk=5))
        retry = RetryPolicy()
    print(f"blobs: {source.n_rows} train rows in {source.n_chunks} chunks, "
          f"C={args.classes}, publish every {args.publish_every} chunks"
          + (f", chaos faults seed={args.faults}"
             if args.faults is not None else ""))

    # -- 1. trainer publishes into the bank from a background thread -----
    bank = ModelBank()
    fail: list[BaseException] = []

    def trainer():
        try:
            fit_multiclass_stream(cfg, source, epochs=args.epochs, seed=0,
                                  prefetch=2, bank=bank,
                                  publish_every=args.publish_every,
                                  retry=retry, report=report,
                                  guard_finite=args.faults is not None)
        except BaseException as e:            # surface on the main thread
            fail.append(e)

    t = threading.Thread(target=trainer, name="live-trainer", daemon=True)
    t.start()
    bank.wait(1, timeout=300.0)               # first snapshot is up

    # -- 2. serve ragged requests the whole time the trainer runs --------
    rng = np.random.default_rng(7)
    served = 0
    t0 = time.perf_counter()
    with AsyncBatchQueue(bank, max_batch=args.max_batch) as q:
        q.warmup()
        passes = 0
        while t.is_alive() or passes == 0:    # at least one pass, even if the
            sizes = [int(s) for s in           # trainer wins the race
                     rng.integers(1, args.max_batch, size=8)]
            tickets, off = [], 0
            for s in sizes:
                tickets.append(q.submit(xte[off:off + s]))
                off += s
            for tk in tickets:
                q.take(tk, timeout=120.0)
            served += off
            passes += 1
        t.join()
        if fail:
            raise fail[0]
        dt = time.perf_counter() - t0
        versions = dict(q.stats["versions"])
        print(f"  served {served} rows in {dt:.2f}s "
              f"({served / dt:,.0f} rows/s) while training")
        print(f"  versions served: {versions}")
        assert versions, "the queue never read a bank version"
        assert bank.version >= 2, "trainer never published a mid-run snapshot"

        # -- 3. final pass through the SAME queue: bitwise the last model
        final_v, final_model = bank.current()
        tk = q.submit(xte)
        live = q.take(tk, timeout=120.0)
    direct = np.asarray(predict_labels(final_model, xte))
    assert (live == direct).all()
    acc = float(np.mean(direct == np.asarray(yte)))
    print(f"  final version v{final_v}: queue == direct predict (bitwise), "
          f"test acc={acc:.4f}")
    if args.faults is not None:
        for name in ("sv_x", "alpha"):
            leaf = np.asarray(getattr(final_model, name), np.float32)
            assert np.isfinite(leaf).all(), \
                f"published ServeModel.{name} went non-finite under faults"
        print(f"  chaos drill survived: {report!r}; "
              "published snapshots stayed finite")


if __name__ == "__main__":
    main()
