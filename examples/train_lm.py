"""End-to-end LM training driver on the repro substrate.

Default (CPU-feasible here): a ~27M-param llama-family model, 300 steps on
the synthetic bigram stream — loss must approach the stream's bigram
entropy floor.  ``--full`` trains the real smollm_360m config (TPU-scale;
the step function is identical, only the config changes).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = get("smollm_360m")
    if not args.full:
        # ~27M params: same family, CPU-trainable in minutes
        cfg = dataclasses.replace(
            cfg, n_layers=6, d_model=384, n_heads=6, n_kv_heads=2, d_ff=1024,
            head_dim=64, vocab_size=2048, dtype="float32", attn_chunk=4096)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch_size} x {args.seq_len}")

    metrics = train_loop(cfg, steps=args.steps, batch_size=args.batch_size,
                         seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                         ckpt_every=100, lr=3e-3, log_every=20)
    first = sum(metrics["losses"][:10]) / 10
    last = sum(metrics["losses"][-10:]) / 10
    print(f"loss: first10={first:.4f} last10={last:.4f} "
          f"bigram floor={metrics['bigram_floor']:.4f}")
    assert last < first - 0.5, "loss did not drop"
    print("OK: loss dropped toward the bigram floor")


if __name__ == "__main__":
    main()
