"""Online-learning scenario bench: single-pass prequential regret under drift.

The workload the paper's GSS-precomputed merging was built for, finally
measured: every maintenance strategy — merge / multi-merge / removal /
removal-project / quantized — rides ONE pass over a non-stationary stream
(``data.stream.DriftChunks`` over the synthetic generators) at a matched
budget, scored test-then-train by ``core.online.prequential_stream``.
Two model shapes per scenario: binary bsgd and C=16 one-vs-rest.

Readouts per (scenario, strategy) cell:

  * ``mistake_rate``   — cumulative prequential error over the whole pass
    (the online regret readout; lower is better);
  * ``acc_pre`` / ``acc_post`` — mean per-chunk streaming accuracy before
    and after the drift point (how hard the model falls, how fast it
    recovers);
  * ``chunk_acc``      — the full per-chunk trace (drift localization);
  * ``t_s``            — wall-clock for the pass (compile included; the
    strategies share sizes, so relative time is meaningful).

``--smoke`` is the CI sizing and writes ``BENCH_online.json`` (wired into
``benchmarks.run --smoke`` and uploaded as a CI artifact): the label-flip
step schedule for both model shapes, plus a mean-shift ramp for the binary
model.  No strategy is skipped at any sizing — a strategy that cannot run
a cell is a hard error, not a silent gap.

    PYTHONPATH=src python -m benchmarks.bench_online --smoke --out BENCH_online.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import BSGDConfig, MulticlassSVMConfig, STRATEGIES, prequential_stream
from repro.data import (ArrayChunks, DriftChunks, label_flip_schedule,
                        make_blobs, make_blobs_multiclass, mean_shift_schedule)

from .common import csv_row


def _run_cells(source, n_chunks: int, drift_start: float, make_cfg,
               verbose: bool) -> dict:
    """One scenario: every maintenance strategy over the same drifted
    stream at a matched budget; returns {strategy: metrics}."""
    split = int(drift_start * n_chunks)
    out = {}
    for strat in STRATEGIES:
        cfg = make_cfg(strat)
        t0 = time.perf_counter()
        r = prequential_stream(cfg, source)
        t = time.perf_counter() - t0
        acc = r["chunk_acc"]
        out[strat] = {
            "mistake_rate": r["mistake_rate"],
            "mistakes": r["mistakes"],
            "acc_pre": round(float(np.mean(acc[:split])), 4),
            "acc_post": round(float(np.mean(acc[split:])), 4),
            "chunk_acc": acc,
            "t_s": round(t, 3),
        }
    if verbose:
        for strat, m in out.items():
            print(csv_row(strat, m["mistake_rate"], m["acc_pre"],
                          m["acc_post"], m["t_s"]), flush=True)
    return out


def run_online(n: int = 4096, dim: int = 8, budget: int = 64,
               batch_size: int = 32, chunk_rows: int = 512,
               n_classes: int = 16, mc_dim: int = 16, mc_budget: int = 32,
               drift_start: float = 0.5, seed: int = 0,
               verbose: bool = True) -> dict:
    """The full suite: binary label-flip + binary mean-shift + C-class
    label-rotation scenarios, all five strategies each."""
    lam = 1e-3
    gamma = 0.5

    def binary_cfg(strat):
        return BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                          method="lookup-wd", batch_size=batch_size,
                          use_kernel_cache=True, maintenance=strat)

    def mc_cfg(strat):
        return MulticlassSVMConfig.create(
            n_classes, budget=mc_budget, lambda_=lam, gamma=gamma,
            method="lookup-wd", batch_size=batch_size,
            use_kernel_cache=True, maintenance=strat)

    x, y = make_blobs(jax.random.PRNGKey(seed), n, dim, sep=1.6)
    src = ArrayChunks(np.asarray(x, np.float32), np.asarray(y, np.float32),
                      chunk_rows)
    n_chunks = src.n_chunks
    flip = label_flip_schedule(n_chunks, start=drift_start, prob=1.0)
    shift = mean_shift_schedule(n_chunks, dim, magnitude=3.0,
                                start=drift_start, kind="ramp")

    result = {
        "n": n, "dim": dim, "budget": budget, "batch_size": batch_size,
        "chunk_rows": chunk_rows, "n_chunks": n_chunks,
        "drift_start": drift_start, "lambda": lam, "gamma": gamma,
        "schedules": {
            "label-flip": {"kind": "step", "start": drift_start, "prob": 1.0},
            "mean-shift": {"kind": "ramp", "start": drift_start,
                           "magnitude": 3.0},
        },
    }
    if verbose:
        print(csv_row("strategy", "mistake_rate", "acc_pre", "acc_post",
                      "t_s"))
        print(f"# binary / label-flip (n={n}, budget={budget})")
    result["binary_label_flip"] = _run_cells(
        DriftChunks(src, flip=flip, seed=seed), n_chunks, drift_start,
        binary_cfg, verbose)
    if verbose:
        print(f"# binary / mean-shift ramp")
    result["binary_mean_shift"] = _run_cells(
        DriftChunks(src, shift=shift, seed=seed), n_chunks, drift_start,
        binary_cfg, verbose)

    xm, ym = make_blobs_multiclass(jax.random.PRNGKey(seed + 1), n, mc_dim,
                                   n_classes, sep=2.0)
    msrc = ArrayChunks(np.asarray(xm, np.float32), np.asarray(ym), chunk_rows)
    mflip = label_flip_schedule(msrc.n_chunks, start=drift_start, prob=1.0)
    result["ovr_label_rotate"] = {"n_classes": n_classes, "dim": mc_dim,
                                  "budget_per_class": mc_budget}
    if verbose:
        print(f"# ovr C={n_classes} / label-rotate (budget/class={mc_budget})")
    result["ovr_label_rotate"]["rows"] = _run_cells(
        DriftChunks(msrc, flip=mflip, n_classes=n_classes, seed=seed),
        msrc.n_chunks, drift_start, mc_cfg, verbose)

    # the acceptance-level readout: quantized must be competitive post-drift
    for scen in ("binary_label_flip", "ovr_label_rotate"):
        rows = result[scen].get("rows", result[scen])
        best = min(r["mistake_rate"] for k, r in rows.items()
                   if isinstance(r, dict) and "mistake_rate" in r)
        q = rows["quantized"]["mistake_rate"]
        if verbose:
            print(f"# {scen}: best mistake_rate {best:.4f}, "
                  f"quantized {q:.4f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing, JSON artifact to --out")
    ap.add_argument("--out", default="BENCH_online.json")
    args = ap.parse_args()
    if args.smoke:
        result = run_online(n=4096)
        result["smoke"] = True
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {args.out}")
        return
    run_online(n=args.n, budget=128, mc_budget=64)


if __name__ == "__main__":
    main()