"""Benchmark runner: one function per paper table/figure.

``python -m benchmarks.run``          — quick pass over every benchmark
``python -m benchmarks.run --full``   — paper-scale settings (slow on CPU)
``python -m benchmarks.run --smoke``  — the CI entry point: every smoke
    bench in one invocation, JSON artifacts (``BENCH_*.json``) at the repo
    root so the perf trajectory accumulates run over run.

Prints ``name,us_per_call,derived`` CSV summary lines per benchmark plus the
benchmark's own CSV.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time

# the CI smoke set: (module, artifact) — each runs as its own child process
# (bench_stream measures child-process RSS; isolation also keeps one bench's
# jit cache from warming another's timings)
SMOKE_BENCHES = (
    ("benchmarks.bench_table2_accuracy", "BENCH_table2_accuracy.json"),
    ("benchmarks.bench_maintenance", "BENCH_maintenance.json"),
    ("benchmarks.bench_train_step", "BENCH_train_step.json"),
    ("benchmarks.bench_stream", "BENCH_stream.json"),
    ("benchmarks.bench_serve", "BENCH_serve.json"),
    ("benchmarks.bench_pipeline", "BENCH_pipeline.json"),
    ("benchmarks.bench_online", "BENCH_online.json"),
    ("benchmarks.bench_faults", "BENCH_faults.json"),
)


def run_smoke() -> None:
    """Run every smoke bench; artifacts land in the current directory."""
    for mod, out in SMOKE_BENCHES:
        print(f"== {mod} --smoke -> {out} ==", flush=True)
        subprocess.run([sys.executable, "-m", mod, "--smoke", "--out", out],
                       check=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="the consolidated CI smoke set -> BENCH_*.json")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
        return
    quick = not args.full

    from . import (bench_fig3_breakdown, bench_roofline, bench_table2_accuracy,
                   bench_table3_speedup)

    summary = []

    print("== Table 2: accuracy parity (GSS vs lookups) ==", flush=True)
    t0 = time.perf_counter()
    rows = (bench_table2_accuracy.run(n=1200, budgets=(50,), epochs=1,
                                      seeds=(0,), datasets=["SUSY", "IJCNN"])
            if quick else bench_table2_accuracy.run())
    accs = [r[3] for r in rows]
    summary.append(("table2_accuracy", (time.perf_counter() - t0) * 1e6,
                    f"min_acc={min(accs):.3f}"))

    print("\n== Table 3: training-time speedup + decision stats ==", flush=True)
    t0 = time.perf_counter()
    rows = (bench_table3_speedup.run(n=1500, budgets=(50,), epochs=1,
                                     datasets=["SUSY", "ADULT"],
                                     stats_steps=400)
            if quick else bench_table3_speedup.run())
    imps = [r[-1] for r in rows if isinstance(r[-1], (int, float))]
    summary.append(("table3_speedup", (time.perf_counter() - t0) * 1e6,
                    f"improv_wd_pct={imps}"))

    print("\n== Fig 3: merge-time breakdown ==", flush=True)
    t0 = time.perf_counter()
    rows = bench_fig3_breakdown.run(budget=100 if quick else 500)
    lookup_us = [r[1] for r in rows if r[0] == "lookup-wd"][0]
    gss_us = [r[1] for r in rows if r[0] == "gss"][0]
    summary.append(("fig3_breakdown", lookup_us,
                    f"solverA_gss/lookup={gss_us / max(lookup_us, 1e-9):.2f}x"))

    print("\n== Roofline table (from dry-run artifacts) ==", flush=True)
    t0 = time.perf_counter()
    recs = bench_roofline.run()
    summary.append(("roofline_cells", (time.perf_counter() - t0) * 1e6,
                    f"n_cells={len(recs)}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
