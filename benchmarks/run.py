"""Benchmark runner: one function per paper table/figure.

``python -m benchmarks.run``          — quick pass over every benchmark
``python -m benchmarks.run --full``   — paper-scale settings (slow on CPU)

Prints ``name,us_per_call,derived`` CSV summary lines per benchmark plus the
benchmark's own CSV.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_fig3_breakdown, bench_roofline, bench_table2_accuracy,
                   bench_table3_speedup)

    summary = []

    print("== Table 2: accuracy parity (GSS vs lookups) ==", flush=True)
    t0 = time.perf_counter()
    rows = (bench_table2_accuracy.run(n=1200, budgets=(50,), epochs=1,
                                      seeds=(0,), datasets=["SUSY", "IJCNN"])
            if quick else bench_table2_accuracy.run())
    accs = [r[3] for r in rows]
    summary.append(("table2_accuracy", (time.perf_counter() - t0) * 1e6,
                    f"min_acc={min(accs):.3f}"))

    print("\n== Table 3: training-time speedup + decision stats ==", flush=True)
    t0 = time.perf_counter()
    rows = (bench_table3_speedup.run(n=1500, budgets=(50,), epochs=1,
                                     datasets=["SUSY", "ADULT"],
                                     stats_steps=400)
            if quick else bench_table3_speedup.run())
    imps = [r[6] for r in rows if isinstance(r[6], (int, float))]
    summary.append(("table3_speedup", (time.perf_counter() - t0) * 1e6,
                    f"improv_wd_pct={imps}"))

    print("\n== Fig 3: merge-time breakdown ==", flush=True)
    t0 = time.perf_counter()
    rows = bench_fig3_breakdown.run(budget=100 if quick else 500)
    lookup_us = [r[1] for r in rows if r[0] == "lookup-wd"][0]
    gss_us = [r[1] for r in rows if r[0] == "gss"][0]
    summary.append(("fig3_breakdown", lookup_us,
                    f"solverA_gss/lookup={gss_us / max(lookup_us, 1e-9):.2f}x"))

    print("\n== Roofline table (from dry-run artifacts) ==", flush=True)
    t0 = time.perf_counter()
    recs = bench_roofline.run()
    summary.append(("roofline_cells", (time.perf_counter() - t0) * 1e6,
                    f"n_cells={len(recs)}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
