"""Async-pipeline benchmark: prefetched streaming, continuous batching, and
train-while-serve hot-swap — the PR 7 artifact.

Three cells, one JSON (``BENCH_pipeline.json``):

  * ``stream`` — ``fit_stream`` over a LIBSVM text file (pure-Python parse,
    the host work worth hiding) sync vs ``prefetch=2``; the final states
    must be BITWISE equal (prefetch changes wall-clock only, never math).
  * ``queue`` — one replayed ragged request trace through the synchronous
    ``BatchQueue`` vs the ``AsyncBatchQueue`` (continuous batching +
    per-bucket AOT executables + double-buffered dispatch); both runs carry
    ``drive_trace``'s bitwise parity gate against direct ``predict_labels``.
  * ``live`` — the same async trace while a background
    ``fit_multiclass_stream(bank=..., publish_every=K)`` hot-swaps versioned
    snapshots into a ``ModelBank`` mid-trace, vs the idle-trainer baseline;
    records the served-version histogram and the p99 inflation.

Thread overlap needs cores: the JSON records ``cpus`` and per-bar pass
booleans (prefetch >= 1.3x, async queue >= 1.5x, live p99 <= 2x idle).  On
a single-core machine overlap is physically impossible, and on shared CI
runners the live-p99 bar is co-tenancy roulette — so by default the bars
are REPORTED (loud PASS/FAIL lines) but only enforced as hard failures
when ``BENCH_PIPELINE_STRICT=1`` (a dedicated multi-core perf machine).
The bitwise-parity gates, by contrast, are always fatal.

    PYTHONPATH=src python -m benchmarks.bench_pipeline --smoke --out BENCH_pipeline.json

CI runs the smoke sizing and uploads ``BENCH_pipeline.json`` next to the
serve/stream benches.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np

PREFETCH_BAR = 1.3      # prefetched fit_stream vs sync, rows/sec
ASYNC_BAR = 1.5         # AsyncBatchQueue vs BatchQueue, rows/sec
LIVE_P99_BAR = 2.0      # hot-swap p99 vs idle-trainer p99


def _cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:       # non-linux
        return os.cpu_count() or 1


def _bitwise(a, b) -> bool:
    import jax

    return all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def cell_stream(args) -> dict:
    """Sync vs prefetched ``fit_stream`` over a LIBSVM text stream."""
    import jax

    from repro.core import BSGDConfig, fit_stream
    from repro.data import LibsvmChunks, dump_libsvm, make_susy_like

    x, y = make_susy_like(jax.random.PRNGKey(args.seed), args.stream_rows,
                          args.dim)
    x, y = np.asarray(x, np.float32), np.asarray(y, np.float32)
    cfg = BSGDConfig(budget=args.budget, lambda_=2e-5, gamma=2.0**-7,
                     batch_size=args.batch_size)

    def run(prefetch: int):
        source = LibsvmChunks(path, args.chunk_rows, args.dim, binary=True)
        state = fit_stream(cfg, source, epochs=1, seed=0, prefetch=prefetch)
        t0 = time.perf_counter()           # warm pass: compiles already paid
        state = fit_stream(cfg, source, epochs=1, seed=1, state=state,
                           prefetch=prefetch)
        jax.block_until_ready(state.alpha)
        return state, time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "train.libsvm")
        dump_libsvm(path, x, y)
        s_sync, t_sync = run(0)
        s_pre, t_pre = run(args.prefetch)

    assert _bitwise(s_sync, s_pre), \
        "prefetched fit_stream diverged from sync (bitwise)"
    return {
        "rows": int(x.shape[0]), "chunk_rows": args.chunk_rows,
        "prefetch_depth": args.prefetch,
        "sync_rows_per_s": round(x.shape[0] / t_sync, 1),
        "prefetch_rows_per_s": round(x.shape[0] / t_pre, 1),
        "prefetch_vs_sync": round(t_sync / t_pre, 3),
        "bitwise_parity": True,
    }


def _trace(args, rng):
    from repro.core import ragged_trace_sizes

    req_x = rng.standard_normal(
        (args.trace_rows, args.dim)).astype(np.float32)
    sizes = ragged_trace_sizes(args.trace_rows, args.max_batch, rng)
    return req_x, sizes


def cell_queue(args, model, req_x, sizes) -> dict:
    """One ragged trace: synchronous ``BatchQueue`` vs ``AsyncBatchQueue``."""
    from repro.core import drive_trace

    sync = drive_trace(model, req_x, sizes, max_batch=args.max_batch)
    asyn = drive_trace(model, req_x, sizes, max_batch=args.max_batch,
                       queue="async")
    return {
        "trace_rows": int(sum(sizes)),
        "requests": len(sizes), "max_batch": args.max_batch,
        "sync": sync, "async": asyn,
        "async_vs_sync": round(asyn["rows_per_s"] / sync["rows_per_s"], 3),
        "bitwise_parity": True,      # drive_trace asserts it per run
    }


def cell_live(args, idle_p99_ms: float, req_x, sizes) -> dict:
    """Replay the trace continuously while a background trainer hot-swaps
    versioned snapshots into the bank — sustained serving under training.

    The trace loops until the trainer exits, so the served-version histogram
    spans every snapshot published mid-flight (a single pass lasts
    milliseconds — far shorter than a publish interval)."""
    import jax

    from repro.core import (AsyncBatchQueue, ModelBank, MulticlassSVMConfig,
                            fit_multiclass_stream)
    from repro.data import ArrayChunks, make_blobs_multiclass

    cfg = MulticlassSVMConfig.create(
        args.n_classes, budget=args.budget, lambda_=1e-3, gamma=args.gamma,
        batch_size=args.batch_size)
    x, y = make_blobs_multiclass(jax.random.PRNGKey(args.seed),
                                 args.train_rows, args.dim,
                                 n_classes=args.n_classes, sep=2.5)
    source = ArrayChunks(np.asarray(x, np.float32), np.asarray(y, np.int32),
                         chunk_rows=args.chunk_rows)
    bank = ModelBank()
    fail: list[BaseException] = []

    def trainer() -> None:
        try:
            fit_multiclass_stream(cfg, source, epochs=args.live_epochs,
                                  seed=args.seed, prefetch=2, bank=bank,
                                  publish_every=args.publish_every)
        except BaseException as e:  # noqa: BLE001 — re-raised below
            fail.append(e)

    t = threading.Thread(target=trainer, daemon=True, name="bench-trainer")
    t.start()
    bank.wait(1, timeout=300.0)
    rows, passes = 0, 0
    with AsyncBatchQueue(bank, max_batch=args.max_batch) as q:
        q.warmup()
        t0 = time.perf_counter()
        while t.is_alive() or passes == 0:    # at least one full pass
            tickets, off = [], 0
            for s in sizes:
                tickets.append(q.submit(req_x[off:off + s]))
                off += s
            q.drain(timeout=600.0)
            for tk in tickets:
                q.take(tk)
            rows += off
            passes += 1
        wall = time.perf_counter() - t0
        lat = np.asarray(q.latencies_s)
        versions = dict(q.stats["versions"])
    t.join(timeout=600.0)
    if fail:
        raise RuntimeError("background trainer failed") from fail[0]
    p99 = round(float(np.percentile(lat, 99)) * 1e3, 3)
    return {
        "publish_every": args.publish_every,
        "final_version": bank.version,
        "versions_served": versions,
        "trace_passes": passes,
        "rows_per_s": round(rows / wall, 1),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": p99,
        "idle_p99_ms": idle_p99_ms,
        "live_vs_idle_p99": round(p99 / idle_p99_ms, 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--n-classes", type=int, default=8)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--stream-rows", type=int, default=65536)
    ap.add_argument("--chunk-rows", type=int, default=4096)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--train-rows", type=int, default=32768)
    ap.add_argument("--trace-rows", type=int, default=32768)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--live-epochs", type=int, default=4)
    ap.add_argument("--publish-every", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (16k stream rows, 8k trace rows)")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()
    if args.smoke:
        args.stream_rows, args.chunk_rows = 16384, 2048
        args.train_rows, args.trace_rows = 8192, 8192
        args.live_epochs = 8

    import jax

    from repro.core import export_model, fit_multiclass, MulticlassSVMConfig
    from repro.data import make_blobs_multiclass

    cpus = _cpus()
    print(f"== stream: sync vs prefetch={args.prefetch} "
          f"({args.stream_rows} LIBSVM rows) ==", flush=True)
    stream = cell_stream(args)
    print(json.dumps(stream), flush=True)

    # one model + one trace shared by the queue and live cells
    cfg = MulticlassSVMConfig.create(
        args.n_classes, budget=args.budget, lambda_=1e-3, gamma=args.gamma,
        batch_size=args.batch_size)
    x, y = make_blobs_multiclass(jax.random.PRNGKey(args.seed + 1),
                                 args.train_rows, args.dim,
                                 n_classes=args.n_classes, sep=2.5)
    model = export_model(fit_multiclass(cfg, x, y, epochs=1, seed=args.seed),
                         args.gamma)
    rng = np.random.default_rng(args.seed)
    req_x, sizes = _trace(args, rng)

    print(f"== queue: BatchQueue vs AsyncBatchQueue "
          f"({args.trace_rows} trace rows) ==", flush=True)
    queue = cell_queue(args, model, req_x, sizes)
    print(json.dumps({k: queue[k] for k in
                      ("async_vs_sync", "trace_rows")}), flush=True)

    print("== live: hot-swap trace vs idle trainer ==", flush=True)
    live = cell_live(args, queue["async"]["p99_ms"], req_x, sizes)
    print(json.dumps(live), flush=True)

    strict = os.environ.get("BENCH_PIPELINE_STRICT") == "1"
    bars = {
        f"prefetch>={PREFETCH_BAR}x":
            stream["prefetch_vs_sync"] >= PREFETCH_BAR,
        f"async_queue>={ASYNC_BAR}x": queue["async_vs_sync"] >= ASYNC_BAR,
        f"live_p99<={LIVE_P99_BAR}x_idle":
            live["live_vs_idle_p99"] <= LIVE_P99_BAR,
    }
    result = {
        "cpus": cpus,
        "bars_met": bars,
        "bars_enforced": strict,
        "workload": {"dim": args.dim, "n_classes": args.n_classes,
                     "budget": args.budget, "batch_size": args.batch_size,
                     "stream_rows": args.stream_rows,
                     "trace_rows": args.trace_rows,
                     "max_batch": args.max_batch},
        "stream": stream, "queue": queue, "live": live,
    }
    for cell in ("sync", "async"):
        for field in ("bucket_counts", "bucket_occupancy"):
            queue[cell][field] = {str(k): v
                                  for k, v in queue[cell][field].items()}
    live["versions_served"] = {str(k): v
                               for k, v in live["versions_served"].items()}

    for name, ok in bars.items():
        print(f"# bar {name}: {'PASS' if ok else 'FAIL'}", flush=True)
    if cpus == 1:
        print(f"# single-cpu machine ({cpus}): thread overlap is physically "
              f"impossible here — bars recorded for the multi-core CI run",
              flush=True)
    if strict:
        assert all(bars.values()), f"perf bars failed: {bars}"

    print(json.dumps(result, indent=2))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
