"""Paper Table 3: relative training-time improvement of the lookups vs GSS,
merging frequency, decision agreement, and WD precision factors — plus the
maintenance-engine variants (kernel cache, fused multi-merge) this repo adds
on top of the paper.

Timing compares jit'd whole-epoch training (identical streams, identical
model updates modulo solver choice).  Decision/precision statistics run the
solvers side-by-side on the same pre-maintenance states, exactly like the
paper's paired run.  ``maintenance_bench`` isolates the budget-maintenance
path itself: a fixed number of merge events scanned inside one XLA program,
with the kappa row recomputed per event (seed) vs read from the kernel cache.
"""
from __future__ import annotations

import argparse
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BSGDConfig, default_table, fit, init_state,
                        kernel_cache, maintenance_step, run_maintenance,
                        train_step)
from repro.data.synthetic import train_test_split

from .common import DATASETS, csv_row, time_fn

# engine variants timed alongside the paper's three solvers; each maps to
# BSGDConfig knobs layered on the lookup-wd solver
ENGINE_VARIANTS = {
    "lookup-wd+cache": dict(method="lookup-wd", use_kernel_cache=True),
    "lookup-wd+mm4": dict(method="lookup-wd", use_kernel_cache=True,
                          maintenance="multi-merge", merge_batch=4),
    "lookup-wd+evt": dict(method="lookup-wd", use_kernel_cache=True,
                          maintenance_engine="pallas"),
    "fused-step": dict(method="lookup-wd", use_kernel_cache=True,
                       step_engine="pallas"),
}


def timed_fit(cfg, xtr, ytr, epochs):
    def run():
        return fit(cfg, xtr, ytr, epochs=epochs, seed=0).alpha
    return time_fn(run, warmup=1, repeats=3)[0]


def decision_stats(name, dim, gen, gamma, lam, *, budget=60, steps=1500):
    """Run BSGD; at every maintenance event compare GSS vs Lookup-WD vs
    GSS-precise on the SAME state (paper's paired methodology)."""
    key = jax.random.PRNGKey(0)
    x, y = gen(key, steps + budget + 10)
    cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma, method="lookup-wd")
    table = default_table()
    state = init_state(cfg, x.shape[1])
    stats = dict(events=0, equal=0, factor_gss=[], factor_lookup=[], steps=0)

    for i in range(steps):
        xb, yb = x[i:i+1], y[i:i+1]
        new_state = train_step(cfg, table, state, xb, yb)
        stats["steps"] += 1
        if int(new_state.n_merges) > int(state.n_merges):
            # recreate the pre-maintenance SV set: replay insert w/o budget
            big = BSGDConfig(budget=cfg.budget + 1, lambda_=lam, gamma=gamma,
                             method="lookup-wd")
            over = train_step(big, table, state, xb, yb)
            args = (over.sv_x, over.alpha, over.count, gamma)
            _, _, _, i_g = maintenance_step(*args, method="gss")
            _, _, _, i_l = maintenance_step(*args, method="lookup-wd", table=table)
            _, _, _, i_p = maintenance_step(*args, method="gss-precise")
            stats["events"] += 1
            stats["equal"] += int(int(i_g.j_star) == int(i_l.j_star))
            wd_p = float(i_p.wd_star)
            # the paper's factor metric is meaningless when the optimal WD is
            # ~0 (near-duplicate SVs: any solver is near-exact; fp noise
            # dominates the ratio) — exclude degenerate events
            if wd_p > 1e-9:
                stats["factor_gss"].append(float(i_g.wd_star) / wd_p)
                stats["factor_lookup"].append(float(i_l.wd_star) / wd_p)
        state = new_state
    return stats


def maintenance_bench(budget: int = 256, dim: int = 512, events: int = 64,
                      gamma: float = 0.5, seed: int = 0, verbose=True):
    """Isolated maintenance timing: ``events`` merge events in one XLA scan.

    Compares the seed path (kappa row recomputed by ``rbf_row`` per event)
    against the kernel-cache engine variants on identical over-budget states.
    Returns {variant: seconds_per_event}.
    """
    slots = budget + events
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    sv = jax.random.normal(k1, (slots, dim))
    # all same sign -> every event is a genuine merge, never the fallback
    alpha = 0.1 * jnp.abs(jax.random.normal(k2, (slots,))) + 0.01
    table = default_table()

    def timed(use_cache, strategy, merge_batch=4):
        kmat = kernel_cache.exact_cache(sv, gamma) if use_cache else None

        def go():
            out = run_maintenance(sv, alpha, kmat, jnp.int32(slots),
                                  jnp.int32(0), gamma, table, budget=budget,
                                  strategy=strategy, method="lookup-wd",
                                  merge_batch=merge_batch, impl="auto")
            return out[1]
        return time_fn(go)[0] / events

    res = {
        "lookup-wd (recompute, seed)": timed(False, "merge"),
        "lookup-wd + kernel cache": timed(True, "merge"),
        "lookup-wd + cache + mm4": timed(True, "multi-merge"),
        "lookup-wd + mm4 (no cache)": timed(False, "multi-merge"),
        "removal (batched)": timed(False, "removal"),
    }
    if verbose:
        base = res["lookup-wd (recompute, seed)"]
        print(f"# maintenance_bench budget={budget} dim={dim} events={events}")
        for k, v in res.items():
            print(f"#   {k:30s} {v * 1e6:9.1f} us/event "
                  f"(x{base / v:.2f} vs seed)", flush=True)
    return res


def run(n: int = 4000, budgets=(50, 150), epochs: int = 2, datasets=None,
        stats_steps: int = 1200, verbose=True):
    rows = []
    names = datasets or list(DATASETS)
    if verbose:
        print(csv_row("dataset", "budget", "t_gss_s", "t_lookup_h_s",
                      "t_lookup_wd_s", "t_lwd_cache_s", "t_lwd_mm4_s",
                      "t_lwd_evt_s", "t_fused_step_s", "improv_h_%",
                      "improv_wd_%"))
    for name in names:
        dim, gen, gamma, lam = DATASETS[name]
        # stable digest, not hash(): str hashing is salted per process
        x, y = gen(jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31), n)
        (xtr, ytr), _ = train_test_split(x, y)
        for budget in budgets:
            times = {}
            for method in ("gss", "lookup-h", "lookup-wd"):
                cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                                 method=method)
                times[method] = timed_fit(cfg, xtr, ytr, epochs)
            for variant, knobs in ENGINE_VARIANTS.items():
                cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                                 **knobs)
                times[variant] = timed_fit(cfg, xtr, ytr, epochs)
            imp_h = 100 * (times["gss"] - times["lookup-h"]) / times["gss"]
            imp_wd = 100 * (times["gss"] - times["lookup-wd"]) / times["gss"]
            row = (name, budget, round(times["gss"], 3),
                   round(times["lookup-h"], 3), round(times["lookup-wd"], 3),
                   round(times["lookup-wd+cache"], 3),
                   round(times["lookup-wd+mm4"], 3),
                   round(times["lookup-wd+evt"], 3),
                   round(times["fused-step"], 3),
                   round(imp_h, 2), round(imp_wd, 2))
            rows.append(row)
            if verbose:
                print(csv_row(*row), flush=True)
        st = decision_stats(name, dim, gen, gamma, lam, steps=stats_steps)
        freq = st["events"] / max(st["steps"], 1)
        eq = st["equal"] / max(st["events"], 1)
        fg = float(np.mean(st["factor_gss"])) if st["factor_gss"] else float("nan")
        fl = float(np.mean(st["factor_lookup"])) if st["factor_lookup"] else float("nan")
        if verbose:
            print(f"# {name}: merge_freq={freq:.2%} equal_decisions={eq:.2%} "
                  f"factor_gss={fg:.5f} factor_lookupwd={fl:.5f}", flush=True)
        rows.append((name, "stats", freq, eq, fg, fl, ""))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--maintenance-only", action="store_true",
                    help="only the isolated maintenance-path microbench")
    args = ap.parse_args()
    if args.maintenance_only:
        maintenance_bench()
        return
    if args.quick:
        maintenance_bench()
        run(n=1500, budgets=(50,), epochs=1, datasets=["SUSY", "ADULT"],
            stats_steps=400)
    else:
        maintenance_bench()
        run()


if __name__ == "__main__":
    main()
