"""Paper Table 3: relative training-time improvement of the lookups vs GSS,
merging frequency, decision agreement, and WD precision factors.

Timing compares jit'd whole-epoch training (identical streams, identical
model updates modulo solver choice).  Decision/precision statistics run the
solvers side-by-side on the same pre-maintenance states, exactly like the
paper's paired run.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BSGDConfig, default_table, fit, init_state,
                        maintenance_step, train_step)
from repro.data.synthetic import train_test_split

from .common import DATASETS, csv_row, time_fn


def timed_fit(cfg, xtr, ytr, epochs):
    def run():
        return fit(cfg, xtr, ytr, epochs=epochs, seed=0).alpha
    return time_fn(run, warmup=1, repeats=3)[0]


def decision_stats(name, dim, gen, gamma, lam, *, budget=60, steps=1500):
    """Run BSGD; at every maintenance event compare GSS vs Lookup-WD vs
    GSS-precise on the SAME state (paper's paired methodology)."""
    key = jax.random.PRNGKey(0)
    x, y = gen(key, steps + budget + 10)
    cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma, method="lookup-wd")
    table = default_table()
    state = init_state(cfg, x.shape[1])
    stats = dict(events=0, equal=0, factor_gss=[], factor_lookup=[], steps=0)

    for i in range(steps):
        xb, yb = x[i:i+1], y[i:i+1]
        new_state = train_step(cfg, table, state, xb, yb)
        stats["steps"] += 1
        if int(new_state.n_merges) > int(state.n_merges):
            # recreate the pre-maintenance SV set: replay insert w/o budget
            big = BSGDConfig(budget=cfg.budget + 1, lambda_=lam, gamma=gamma,
                             method="lookup-wd")
            over = train_step(big, table, state, xb, yb)
            args = (over.sv_x, over.alpha, over.count, gamma)
            _, _, _, i_g = maintenance_step(*args, method="gss")
            _, _, _, i_l = maintenance_step(*args, method="lookup-wd", table=table)
            _, _, _, i_p = maintenance_step(*args, method="gss-precise")
            stats["events"] += 1
            stats["equal"] += int(int(i_g.j_star) == int(i_l.j_star))
            wd_p = float(i_p.wd_star)
            # the paper's factor metric is meaningless when the optimal WD is
            # ~0 (near-duplicate SVs: any solver is near-exact; fp noise
            # dominates the ratio) — exclude degenerate events
            if wd_p > 1e-9:
                stats["factor_gss"].append(float(i_g.wd_star) / wd_p)
                stats["factor_lookup"].append(float(i_l.wd_star) / wd_p)
        state = new_state
    return stats


def run(n: int = 4000, budgets=(50, 150), epochs: int = 2, datasets=None,
        stats_steps: int = 1200, verbose=True):
    rows = []
    names = datasets or list(DATASETS)
    if verbose:
        print(csv_row("dataset", "budget", "t_gss_s", "t_lookup_h_s",
                      "t_lookup_wd_s", "improv_h_%", "improv_wd_%"))
    for name in names:
        dim, gen, gamma, lam = DATASETS[name]
        x, y = gen(jax.random.PRNGKey(hash(name) % 2**31), n)
        (xtr, ytr), _ = train_test_split(x, y)
        for budget in budgets:
            times = {}
            for method in ("gss", "lookup-h", "lookup-wd"):
                cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                                 method=method)
                times[method] = timed_fit(cfg, xtr, ytr, epochs)
            imp_h = 100 * (times["gss"] - times["lookup-h"]) / times["gss"]
            imp_wd = 100 * (times["gss"] - times["lookup-wd"]) / times["gss"]
            row = (name, budget, round(times["gss"], 3),
                   round(times["lookup-h"], 3), round(times["lookup-wd"], 3),
                   round(imp_h, 2), round(imp_wd, 2))
            rows.append(row)
            if verbose:
                print(csv_row(*row), flush=True)
        st = decision_stats(name, dim, gen, gamma, lam, steps=stats_steps)
        freq = st["events"] / max(st["steps"], 1)
        eq = st["equal"] / max(st["events"], 1)
        fg = float(np.mean(st["factor_gss"])) if st["factor_gss"] else float("nan")
        fl = float(np.mean(st["factor_lookup"])) if st["factor_lookup"] else float("nan")
        if verbose:
            print(f"# {name}: merge_freq={freq:.2%} equal_decisions={eq:.2%} "
                  f"factor_gss={fg:.5f} factor_lookupwd={fl:.5f}", flush=True)
        rows.append((name, "stats", freq, eq, fg, fl, ""))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(n=1500, budgets=(50,), epochs=1, datasets=["SUSY", "ADULT"],
            stats_steps=400)
    else:
        run()


if __name__ == "__main__":
    main()
