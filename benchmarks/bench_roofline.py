"""§Roofline harness: aggregate the dry-run JSONs into the roofline table.

Reads experiments/dryrun/*.json (produced by ``repro.launch.dryrun``; see
scripts/dryrun_sweep.sh) and prints the per-(arch x shape x mesh) three-term
roofline with dominant-term and useful-flops columns — the source of
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .common import csv_row


def load_records(out_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(out_dir: str = "experiments/dryrun", verbose=True):
    recs = load_records(out_dir)
    if verbose:
        print(csv_row("arch", "shape", "mesh", "strategy", "compute_ms",
                      "memory_ms", "collective_ms", "dominant", "useful_ratio",
                      "roofline_frac", "fits_hbm", "args_GiB"))
        for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            print(csv_row(
                r["arch"], r["shape"], r["mesh"], r["strategy"],
                round(r["compute_s"] * 1e3, 2), round(r["memory_s"] * 1e3, 2),
                round(r["collective_s"] * 1e3, 2), r["dominant"],
                round(r["useful_ratio"], 3), round(r["roofline_frac"], 4),
                r["fits_hbm"], round(r["arg_bytes_per_dev"] / 2**30, 2)))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--quick", action="store_true")  # same either way
    args = ap.parse_args()
    run(args.out_dir)


if __name__ == "__main__":
    main()
