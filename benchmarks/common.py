"""Shared benchmark utilities: timing, dataset stand-ins, CSV output.

The paper's datasets (SUSY, SKIN, IJCNN, ADULT, WEB, PHISHING) are not
downloadable in this offline container; each is represented by a synthetic
generator with the same feature dimensionality and qualitatively similar
class structure.  Sizes are scaled to CPU-feasible n (recorded per row) —
relative timings between solvers are the quantity of interest, matching the
paper's methodology of comparing methods on identical streams.
"""
from __future__ import annotations

import time

import jax

from repro.data.synthetic import make_blobs, make_susy_like, make_two_moons

# name -> (n_features, generator, gamma, C-style lambda)
DATASETS = {
    # dims follow paper Table 1
    "SUSY": (18, lambda k, n: make_susy_like(k, n, 18), 2.0**-7, 1e-5),
    "SKIN": (3, lambda k, n: make_blobs(k, n, 3, sep=3.0, noise=0.8), 2.0**-7, 1e-5),
    "IJCNN": (22, lambda k, n: make_two_moons(k, n, noise=0.2, dim=22), 2.0**1, 1e-5),
    "ADULT": (123, lambda k, n: make_blobs(k, n, 123, sep=0.6, noise=1.3), 2.0**-7, 1e-5),
    "WEB": (300, lambda k, n: make_blobs(k, n, 300, sep=2.0, noise=1.0), 2.0**-5, 1e-4),
    "PHISHING": (68, lambda k, n: make_blobs(k, n, 68, sep=1.5, noise=1.0), 2.0**3, 1e-4),
}


def time_fn(fn, *args, warmup: int = 1, repeats: int = 3):
    """Median wall-clock seconds of fn(*args) (block_until_ready-aware)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
