"""Paper Table 2: test accuracy of GSS-precise / GSS / Lookup-h / Lookup-WD
across datasets and budget sizes — the "no accuracy loss" claim."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import BSGDConfig, METHODS, accuracy, fit
from repro.data.synthetic import train_test_split

from .common import DATASETS, csv_row

ORDER = ("gss-precise", "gss", "lookup-h", "lookup-wd")


def run(n: int = 3000, budgets=(50, 150), epochs: int = 2, seeds=(0, 1, 2),
        datasets=None, verbose=True):
    rows = []
    names = datasets or list(DATASETS)
    if verbose:
        print(csv_row("dataset", "budget", "method", "acc_mean", "acc_std"))
    for name in names:
        dim, gen, gamma, lam = DATASETS[name]
        x, y = gen(jax.random.PRNGKey(hash(name) % 2**31), n)
        (xtr, ytr), (xte, yte) = train_test_split(x, y)
        for budget in budgets:
            for method in ORDER:
                accs = []
                for seed in seeds:
                    cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                                     method=method, batch_size=1)
                    st = fit(cfg, xtr, ytr, epochs=epochs, seed=seed)
                    accs.append(float(accuracy(st, xte, yte, gamma)))
                row = (name, budget, method, round(float(np.mean(accs)), 4),
                       round(float(np.std(accs)), 4))
                rows.append(row)
                if verbose:
                    print(csv_row(*row), flush=True)
    # the paper's claim: spread between methods within noise
    by_cell = {}
    for name, budget, method, mean, std in rows:
        by_cell.setdefault((name, budget), {})[method] = (mean, std)
    for cell, accs in by_cell.items():
        spread = max(a for a, _ in accs.values()) - min(a for a, _ in accs.values())
        max_std = max(s for _, s in accs.values())
        if verbose:
            print(f"# {cell}: method spread {spread:.4f} "
                  f"(max run std {max_std:.4f})")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(n=1200, budgets=(50,), epochs=1, seeds=(0,),
            datasets=["SUSY", "IJCNN"])
    else:
        run(n=args.n)


if __name__ == "__main__":
    main()
