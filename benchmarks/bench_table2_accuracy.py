"""Paper Table 2: test accuracy of GSS-precise / GSS / Lookup-h / Lookup-WD
across datasets and budget sizes — the "no accuracy loss" claim.

``--multiclass`` adds the one-vs-rest mode this repo grows on top of the
paper: per-class merge counts plus wall-clock of the batched lockstep engine
(one fused all-class kernel contraction per step) vs the loop-over-classes
baseline.  ``--solver`` runs the bsgd-vs-bdca head-to-head (time-to-accuracy
on identical streams, binary + OVR).  ``--smoke`` runs a CI-sized subset of
all three and writes the results as JSON (the ``BENCH_*.json``
perf-trajectory artifact).
"""
from __future__ import annotations

import argparse
import json
import zlib

import jax
import numpy as np

from repro.core import (BSGDConfig, METHODS, MulticlassSVMConfig, accuracy,
                        accuracy_multiclass, fit, fit_multiclass,
                        fit_multiclass_loop)
from repro.core.bdca import box_from_lambda
from repro.data.synthetic import make_blobs_multiclass, train_test_split

from .common import DATASETS, csv_row, time_fn

ORDER = ("gss-precise", "gss", "lookup-h", "lookup-wd")


def run(n: int = 3000, budgets=(50, 150), epochs: int = 2, seeds=(0, 1, 2),
        datasets=None, verbose=True):
    rows = []
    names = datasets or list(DATASETS)
    if verbose:
        print(csv_row("dataset", "budget", "method", "acc_mean", "acc_std"))
    for name in names:
        dim, gen, gamma, lam = DATASETS[name]
        # stable digest, not hash(): str hashing is salted per process, and
        # the --smoke artifact must benchmark the SAME dataset every CI run
        x, y = gen(jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31), n)
        (xtr, ytr), (xte, yte) = train_test_split(x, y)
        for budget in budgets:
            for method in ORDER:
                accs = []
                for seed in seeds:
                    cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                                     method=method, batch_size=1)
                    st = fit(cfg, xtr, ytr, epochs=epochs, seed=seed)
                    accs.append(float(accuracy(st, xte, yte, gamma)))
                row = (name, budget, method, round(float(np.mean(accs)), 4),
                       round(float(np.std(accs)), 4))
                rows.append(row)
                if verbose:
                    print(csv_row(*row), flush=True)
    # the paper's claim: spread between methods within noise
    by_cell = {}
    for name, budget, method, mean, std in rows:
        by_cell.setdefault((name, budget), {})[method] = (mean, std)
    for cell, accs in by_cell.items():
        spread = max(a for a, _ in accs.values()) - min(a for a, _ in accs.values())
        max_std = max(s for _, s in accs.values())
        if verbose:
            print(f"# {cell}: method spread {spread:.4f} "
                  f"(max run std {max_std:.4f})")
    return rows


def run_multiclass(n: int = 6000, n_classes: int = 16, dim: int = 20,
                   budget: int = 50, batch_size: int = 1, verbose=True):
    """One-vs-rest mode: accuracy, per-class merge counts, and wall-clock of
    the batched lockstep engine vs the loop-over-classes baseline (identical
    models — same seed means same permutations)."""
    x, y = make_blobs_multiclass(jax.random.PRNGKey(0), n, dim, n_classes,
                                 sep=1.0)
    (xtr, ytr), (xte, yte) = train_test_split(x, y)
    cfg = MulticlassSVMConfig.create(n_classes, budget=budget, lambda_=1e-4,
                                     gamma=0.1, method="lookup-wd",
                                     batch_size=batch_size)

    def timed(fit_fn):
        t, st = time_fn(lambda: fit_fn(cfg, xtr, ytr, epochs=1, seed=0))
        return t, st

    t_batched, st = timed(fit_multiclass)
    t_loop, st_loop = timed(fit_multiclass_loop)
    g = cfg.binary.gamma
    result = {
        "n_train": int(xtr.shape[0]), "dim": dim, "n_classes": n_classes,
        "budget": budget, "batch_size": batch_size,
        "acc_batched": round(float(accuracy_multiclass(st, xte, yte, g)), 4),
        "acc_loop": round(float(accuracy_multiclass(st_loop, xte, yte, g)), 4),
        "t_batched_s": round(t_batched, 3),
        "t_loop_s": round(t_loop, 3),
        "speedup_batched_vs_loop": round(t_loop / t_batched, 3),
        "merges_per_class": np.asarray(st.n_merges).tolist(),
        "sv_count_per_class": np.asarray(st.count).tolist(),
    }
    if verbose:
        print(csv_row("mode", "classes", "budget", "acc", "t_batched_s",
                      "t_loop_s", "speedup"))
        print(csv_row("ovr-batched", n_classes, budget, result["acc_batched"],
                      result["t_batched_s"], result["t_loop_s"],
                      result["speedup_batched_vs_loop"]), flush=True)
        print(f"# per-class merges: {result['merges_per_class']}")
    return result


def run_solvers(n: int = 3000, budget: int = 50, epochs: int = 2,
                batch_size: int = 8, datasets=None, n_classes: int = 5,
                verbose=True):
    """Head-to-head time-to-accuracy: the primal Pegasos solver (bsgd) vs the
    dual coordinate-ascent solver (bdca) on identical streams — same budget,
    same lookup-wd maintenance, same kernel cache, same batches.  bdca's box
    comes from ``core.bdca.box_from_lambda`` at each dataset's own paper
    lambda and train size — the clamped Pegasos correspondence, so the dual
    runs at the table's hyperparameters instead of a hand-tuned constant.
    Binary rows per dataset plus one OVR multiclass row per solver."""
    names = datasets or list(DATASETS)
    rows = []
    if verbose:
        print(csv_row("dataset", "mode", "solver", "acc", "t_fit_s"))
    for name in names:
        dim, gen, gamma, lam = DATASETS[name]
        x, y = gen(jax.random.PRNGKey(zlib.crc32(name.encode()) % 2**31), n)
        (xtr, ytr), (xte, yte) = train_test_split(x, y)
        for solver in ("bsgd", "bdca"):
            cfg = BSGDConfig(budget=budget, lambda_=lam, gamma=gamma,
                             method="lookup-wd", batch_size=batch_size,
                             use_kernel_cache=True, solver=solver,
                             bdca_C=box_from_lambda(xtr.shape[0], lam))
            t, st = time_fn(
                lambda c=cfg: fit(c, xtr, ytr, epochs=epochs, seed=0),
                warmup=1, repeats=1)
            row = {"dataset": name, "mode": "binary", "solver": solver,
                   "acc": round(float(accuracy(st, xte, yte, gamma)), 4),
                   "t_fit_s": round(t, 3)}
            rows.append(row)
            if verbose:
                print(csv_row(*row.values()), flush=True)
    xm, ym = make_blobs_multiclass(jax.random.PRNGKey(7), n, 20, n_classes,
                                   sep=1.0)
    (xtr, ytr), (xte, yte) = train_test_split(xm, ym)
    for solver in ("bsgd", "bdca"):
        cfg = MulticlassSVMConfig.create(
            n_classes, budget=budget, lambda_=1e-4, gamma=0.1,
            method="lookup-wd", batch_size=batch_size,
            use_kernel_cache=True, solver=solver,
            bdca_C=box_from_lambda(xtr.shape[0], 1e-4))
        t, st = time_fn(
            lambda c=cfg: fit_multiclass(c, xtr, ytr, epochs=epochs, seed=0),
            warmup=1, repeats=1)
        row = {"dataset": f"blobs-{n_classes}c", "mode": "ovr",
               "solver": solver,
               "acc": round(float(accuracy_multiclass(st, xte, yte, 0.1)), 4),
               "t_fit_s": round(t, 3)}
        rows.append(row)
        if verbose:
            print(csv_row(*row.values()), flush=True)
    # the acceptance-level readout: per-cell accuracy gap between solvers
    for i in range(0, len(rows), 2):
        a, b = rows[i], rows[i + 1]
        if verbose:
            print(f"# {a['dataset']}/{a['mode']}: bsgd {a['acc']} vs "
                  f"bdca {b['acc']} (gap {abs(a['acc'] - b['acc']):.4f})")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--multiclass", action="store_true",
                    help="one-vs-rest mode: batched engine vs class loop")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized binary + multiclass run, JSON to --out")
    ap.add_argument("--solver", action="store_true",
                    help="head-to-head: bsgd vs bdca time-to-accuracy")
    ap.add_argument("--out", default="BENCH_table2_accuracy.json",
                    help="JSON output path for --smoke")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n=1200, budgets=(50,), epochs=1, seeds=(0,),
                   datasets=["SUSY", "IJCNN"])
        mc = run_multiclass(n=2500, n_classes=5, budget=30)
        solver_rows = run_solvers(n=1600, budget=40, epochs=2,
                                  datasets=["SKIN", "WEB"])
        with open(args.out, "w") as f:
            json.dump({"binary_rows": rows, "multiclass": mc,
                       "solver_head_to_head": solver_rows}, f, indent=2)
        print(f"# wrote {args.out}")
        return
    if args.solver:
        run_solvers(n=args.n)
        return
    if args.multiclass:
        run_multiclass(n=args.n * 2)
        return
    if args.quick:
        run(n=1200, budgets=(50,), epochs=1, seeds=(0,),
            datasets=["SUSY", "IJCNN"])
    else:
        run(n=args.n)


if __name__ == "__main__":
    main()
