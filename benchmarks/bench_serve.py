"""Serving benchmark: rows/sec + microbatch latency, fp32 vs bfloat16 bank.

The serving engine's promise is that a budgeted bank scores request streams
fast (the budget exists so prediction stays cheap) and that quantizing the
bank to bfloat16 is free accuracy-wise on separated data while halving bank
bytes.  This pushes an identical ragged request trace through a
``core.predict.BatchQueue`` for both banks and records, per bank: rows/sec,
p50/p99 per-microbatch latency (post-warmup, including dispatch + host
sync), the bucket histogram, and bench-split accuracy.  The run fails if
the bf16 bank is less accurate than fp32 on the bench split, or if either
queue's labels diverge from one direct fused predict call (bitwise).

    PYTHONPATH=src python -m benchmarks.bench_serve --smoke --out BENCH_serve.json

CI runs the smoke sizing and uploads ``BENCH_serve.json`` next to the
stream/accuracy benches.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-classes", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--train-rows", type=int, default=8192)
    ap.add_argument("--bench-rows", type=int, default=8192)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (4 classes, 2k train / 2k bench rows)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        args.n_classes, args.train_rows, args.bench_rows = 4, 2048, 2048
        args.budget, args.max_batch = 32, 64

    import jax

    from repro.core import (MulticlassSVMConfig, drive_trace, export_model,
                            fit_multiclass, predict_labels,
                            ragged_trace_sizes)
    from repro.data import make_blobs_multiclass, train_test_split

    cfg = MulticlassSVMConfig.create(args.n_classes, budget=args.budget,
                                     lambda_=1e-3, gamma=args.gamma,
                                     batch_size=8)
    x, y = make_blobs_multiclass(jax.random.PRNGKey(args.seed),
                                 args.train_rows + args.bench_rows, args.dim,
                                 n_classes=args.n_classes, sep=2.5)
    (xtr, ytr), (xbe, ybe) = train_test_split(
        x, y, test_frac=args.bench_rows / (args.train_rows + args.bench_rows))
    state = fit_multiclass(cfg, xtr, ytr, epochs=1, seed=args.seed)

    # one ragged request trace, shared by both banks
    xbe_np = np.asarray(xbe)
    ybe_np = np.asarray(ybe)
    rng = np.random.default_rng(args.seed)
    sizes = ragged_trace_sizes(xbe_np.shape[0], args.max_batch, rng)

    banks, accs = {}, {}
    for tag, bank_dtype in (("fp32", None), ("bf16", "bfloat16")):
        model = export_model(state, args.gamma, bank_dtype=bank_dtype)
        direct = np.asarray(predict_labels(model, xbe_np))
        accs[tag] = round(float((direct == ybe_np.astype(np.int32)).mean()), 4)
        banks[tag] = drive_trace(model, xbe_np, sizes,
                                 max_batch=args.max_batch)
        for field in ("bucket_counts", "bucket_occupancy"):
            banks[tag][field] = {str(k): v
                                 for k, v in banks[tag][field].items()}
        banks[tag]["bench_accuracy"] = accs[tag]

    assert accs["bf16"] >= accs["fp32"], (
        f"bf16 bank lost accuracy on the bench split: {accs}")

    result = {
        "workload": {"n_classes": args.n_classes, "dim": args.dim,
                     "budget": args.budget, "train_rows": int(xtr.shape[0]),
                     "bench_rows": int(xbe_np.shape[0]),
                     "requests": len(sizes), "max_batch": args.max_batch},
        "fp32": banks["fp32"], "bf16": banks["bf16"],
        "bf16_vs_fp32_rows_per_s": round(
            banks["bf16"]["rows_per_s"] / banks["fp32"]["rows_per_s"], 3),
    }
    print(json.dumps(result, indent=2))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
