"""Maintenance-engine microbench: µs/event for every engine, one JSON row
per (dim, budget, C) cell — the perf artifact behind DESIGN.md §11.

    PYTHONPATH=src python -m benchmarks.bench_maintenance --smoke \
        --out BENCH_maintenance.json

Each cell builds a stacked over-budget state (C classes, ``events`` excess
SVs per class, all same-sign alphas so every event is a genuine merge, exact
kernel caches) and drains it to the budget through four engines:

  * ``class-loop``  — C sequential jitted ``run_maintenance`` calls, one per
                      class slice (the non-vmapped reference the ROADMAP's
                      3x regression was measured against);
  * ``xla-loop``    — ``vmap(run_maintenance)`` with the while-loop body
                      (PR 2's lockstep engine — the regression under test);
  * ``xla-unroll``  — the same vmap with statically inlined masked events;
  * ``pallas``      — the fused merge-event engine on the sorted-excess
                      schedule (``run_maintenance_classes``; Pallas kernel
                      on TPU, its jnp oracle elsewhere — ``impl="auto"``).

µs/event divides wall-clock by C x events — the engines execute identical
event sequences (the parity property in tests/core/test_event_engine.py), so
rows are directly comparable.  ``ratio_vs_class_loop`` is recorded per cell;
the acceptance target for this PR is pallas <= 1.25x class-loop at dim=512,
slots >= 256, and pallas at C=1/budget=256/dim=512 no worse than PR 1's
cached single-merge (~63 µs/event on the 2-core CI container).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import (default_table, kernel_cache, run_maintenance,
                        run_maintenance_classes)

from .common import time_fn

ENGINES = ("class-loop", "xla-loop", "xla-unroll", "pallas")


def build_state(c: int, budget: int, events: int, dim: int, seed: int = 0,
                gamma: float = 0.5):
    """Stacked over-budget state: count = budget + events per class, all
    same-sign alphas (every event merges, never the removal fallback)."""
    slots = budget + events
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    sv = jax.random.normal(k1, (c, slots, dim))
    alpha = 0.1 * jnp.abs(jax.random.normal(k2, (c, slots))) + 0.01
    kmat = jax.vmap(lambda s: kernel_cache.exact_cache(s, gamma))(sv)
    count = jnp.full((c,), slots, jnp.int32)
    return sv, alpha, kmat, count


def bench_cell(c: int, budget: int, events: int, dim: int, *,
               gamma: float = 0.5, repeats: int = 3) -> dict:
    """µs/event for every engine on one (dim, budget, C) cell."""
    sv, alpha, kmat, count = build_state(c, budget, events, dim, gamma=gamma)
    table = default_table()
    n0 = jnp.zeros((c,), jnp.int32)

    def per_class(q):
        return run_maintenance(
            sv[q], alpha[q], kmat[q], count[q], n0[q], gamma, table,
            budget=budget, strategy="merge", method="lookup-wd", impl="auto")

    def class_loop():
        return [per_class(q)[1] for q in range(c)]

    def vmapped(unroll):
        fn = jax.vmap(lambda s, a, k, ct, n: run_maintenance(
            s, a, k, ct, n, gamma, table, budget=budget, strategy="merge",
            method="lookup-wd", impl="auto", unroll=unroll))
        return lambda: fn(sv, alpha, kmat, count, n0)[1]

    def fused():
        return run_maintenance_classes(sv, alpha, kmat, count, n0, table,
                                       budget=budget, impl="auto")[1]

    timers = {"class-loop": class_loop, "xla-loop": vmapped(0),
              "xla-unroll": vmapped(events), "pallas": fused}
    n_events = c * events
    out = {}
    for name in ENGINES:
        secs, _ = time_fn(timers[name], warmup=1, repeats=repeats)
        out[name] = secs / n_events * 1e6
    return out


def run(*, dims=(64, 512, 1024), budgets=(256, 1024), classes=(1, 16),
        events: int = 32, repeats: int = 3, verbose: bool = True) -> list[dict]:
    rows = []
    for dim in dims:
        for budget in budgets:
            for c in classes:
                us = bench_cell(c, budget, events, dim, repeats=repeats)
                row = {"dim": dim, "budget": budget, "slots": budget + events,
                       "C": c, "events_per_class": events,
                       "us_per_event": {k: round(v, 2) for k, v in us.items()},
                       "ratio_vs_class_loop": {
                           k: round(us[k] / us["class-loop"], 3)
                           for k in ENGINES if k != "class-loop"}}
                rows.append(row)
                if verbose:
                    per = "  ".join(f"{k}={us[k]:8.1f}" for k in ENGINES)
                    print(f"dim={dim:5d} budget={budget:5d} C={c:3d}  "
                          f"us/event: {per}  "
                          f"(pallas {row['ratio_vs_class_loop']['pallas']:.2f}x"
                          " class-loop)", flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (drops the 1024-dim/-budget cells)")
    ap.add_argument("--events", type=int, default=32,
                    help="excess SVs (= merge events) per class")
    ap.add_argument("--out", default="BENCH_maintenance.json")
    args = ap.parse_args()
    if args.smoke:
        rows = run(dims=(64, 512), budgets=(256,), classes=(1, 16),
                   events=min(args.events, 16), repeats=3)
    else:
        rows = run(events=args.events)
    payload = {"benchmark": "maintenance_engines", "smoke": bool(args.smoke),
               "engines": list(ENGINES),
               "note": "class-loop at C=1 is exactly PR 1's cached "
                       "single-merge engine (run_maintenance, merge+cache) — "
                       "the same-run baseline for the pallas column",
               "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
