"""Streaming-epoch benchmark: rows/sec and peak RSS, streamed vs in-memory.

The streaming engine's promise is that throughput stays close to the
in-memory trainer while host memory stays O(chunk), not O(dataset).  Both
measurements run in CHILD processes so each reports its own honest peak RSS
(``ru_maxrss`` would otherwise remember the larger of the two phases):

    PYTHONPATH=src python -m benchmarks.bench_stream --smoke --out BENCH_stream.json

Rows/sec is a warm second pass (the first pass pays the per-chunk-shape
compiles); the JSON artifact records both passes, the chunk geometry and the
RSS split — CI uploads ``BENCH_stream.json`` next to the accuracy bench.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import tempfile
import time


def _peak_rss_mb() -> float:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return round(ru / 1024.0, 1)      # linux reports KiB


def _cfg(args):
    from repro.core import BSGDConfig

    return BSGDConfig(budget=args.budget, lambda_=2e-5, gamma=2.0**-7,
                      batch_size=args.batch_size)


def child_stream(args) -> dict:
    import glob

    import jax

    from repro.core import fit_stream
    from repro.data import FileChunks

    source = FileChunks(sorted(glob.glob(os.path.join(args.data, "*.npz"))))
    cfg = _cfg(args)
    t0 = time.perf_counter()
    state = fit_stream(cfg, source, epochs=1, seed=0)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    state = fit_stream(cfg, source, epochs=1, seed=1, state=state)
    jax.block_until_ready(state.alpha)
    warm = time.perf_counter() - t0
    return {"mode": "stream", "n_rows": source.n_rows, "dim": source.dim,
            "n_chunks": source.n_chunks,
            "chunk_rows": max(source.chunk_lens),
            "rows_per_s_cold": round(source.n_rows / cold, 1),
            "rows_per_s": round(source.n_rows / warm, 1),
            "peak_rss_mb": _peak_rss_mb()}


def child_inmem(args) -> dict:
    import glob

    import jax
    import numpy as np

    from repro.core import fit
    from repro.data import FileChunks

    source = FileChunks(sorted(glob.glob(os.path.join(args.data, "*.npz"))))
    xs, ys = zip(*[source.load(i) for i in range(source.n_chunks)])
    x, y = np.concatenate(xs), np.concatenate(ys)   # the resident baseline
    cfg = _cfg(args)
    t0 = time.perf_counter()
    state = fit(cfg, x, y, epochs=1, seed=0)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    state = fit(cfg, x, y, epochs=1, seed=1, state=state)
    jax.block_until_ready(state.alpha)
    warm = time.perf_counter() - t0
    return {"mode": "inmem", "n_rows": int(x.shape[0]),
            "rows_per_s_cold": round(x.shape[0] / cold, 1),
            "rows_per_s": round(x.shape[0] / warm, 1),
            "peak_rss_mb": _peak_rss_mb()}


def _spawn(mode: str, data_dir: str, args) -> dict:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")   # never probe TPU from children
    cmd = [sys.executable, "-m", "benchmarks.bench_stream", "--child", mode,
           "--data", data_dir, "--budget", str(args.budget),
           "--batch-size", str(args.batch_size)]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"{mode} child failed:\n{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--dim", type=int, default=18)
    ap.add_argument("--chunk-rows", type=int, default=8192)
    ap.add_argument("--budget", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing (16k rows, 2k chunks)")
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--data", default=None,
                    help="existing shard dir (skips generation)")
    ap.add_argument("--child", default=None, choices=("stream", "inmem"),
                    help=argparse.SUPPRESS)   # internal: one measurement
    args = ap.parse_args()

    if args.child:
        print(json.dumps(child_stream(args) if args.child == "stream"
                         else child_inmem(args)))
        return

    if args.smoke:
        args.n, args.chunk_rows, args.budget = 16384, 2048, 64

    import jax
    import numpy as np

    from repro.data import make_susy_like, write_npz_chunks

    with tempfile.TemporaryDirectory() as tmp:
        data_dir = args.data
        if data_dir is None:
            x, y = make_susy_like(jax.random.PRNGKey(1), args.n, args.dim)
            data_dir = os.path.join(tmp, "shards")
            write_npz_chunks(data_dir, np.asarray(x), np.asarray(y),
                             args.chunk_rows)
        stream = _spawn("stream", data_dir, args)
        inmem = _spawn("inmem", data_dir, args)

    result = {
        # geometry from the measured source, not the CLI (--data may supply
        # pre-existing shards with different sizing)
        "workload": {"n": stream["n_rows"], "dim": stream["dim"],
                     "chunk_rows": stream["chunk_rows"],
                     "budget": args.budget,
                     "batch_size": args.batch_size,
                     "dataset_over_chunk": round(
                         stream["n_rows"] / stream["chunk_rows"], 1)},
        "stream": stream, "inmem": inmem,
        "stream_vs_inmem_rows_per_s": round(
            stream["rows_per_s"] / inmem["rows_per_s"], 3),
        "stream_vs_inmem_peak_rss": round(
            stream["peak_rss_mb"] / inmem["peak_rss_mb"], 3),
    }
    print(json.dumps(result, indent=2))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
