"""Train-step engine microbench: µs/step composed vs fused, one JSON row per
(dim, budget, C) cell — the perf artifact behind DESIGN.md §12.

    PYTHONPATH=src python -m benchmarks.bench_train_step --smoke \
        --out BENCH_train_step.json

Each cell builds a steady-state model (bank full at exactly ``budget``, all
same-sign alphas, exact kernel caches — every violator insert forces a
maintenance event, the regime the paper trains in after warmup) and times
ONE full train step on a fixed minibatch through both engines:

  * ``composed``  — the three-phase step (``step_engine="composed"``):
                    margin launch, insert launch, then the maintenance
                    engine's event loop;
  * ``fused``     — the fused train-step megakernel path
                    (``step_engine="pallas"``: margin + insert + masked
                    event rounds in one launch chain; the Pallas kernel on
                    TPU, its jnp oracle ``ref.train_step_fused`` elsewhere —
                    ``impl="auto"``).

Both engines make bitwise-identical step decisions at every cell here
(pinned by tests/core/test_step_engine.py::test_fused_step_parity_at_bench_cells),
so µs/step rows compare like for like.  ``ratio_vs_composed`` is recorded
per cell; the acceptance target for this PR is fused <= 0.8x composed at
dim=512 / budget=256 / C=16 on the CPU CI container (methodology matches
BENCH_maintenance.json: median of 3 timed calls after 1 warmup).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import BSGDConfig, MulticlassSVMConfig, kernel_cache
from repro.core.bsgd import init_state, train_step
from repro.core.multiclass import init_multiclass_state, train_step_multiclass

from .common import time_fn

ENGINES = ("composed", "fused")
BATCH = 8
GAMMA = 2.0**-7
LAMBDA = 1e-3


def _cfg(budget: int, step_engine: str) -> BSGDConfig:
    return BSGDConfig(budget=budget, lambda_=LAMBDA, gamma=GAMMA,
                      batch_size=BATCH, method="lookup-wd",
                      use_kernel_cache=True, maintenance="merge",
                      step_engine=step_engine)


def _steady_state(state, c: int, budget: int, dim: int, seed: int = 0):
    """Bank full at exactly budget, same-sign alphas, exact caches: every
    violator insert this step pushes the class over budget -> event."""
    lead = () if c == 1 else (c,)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    slots = state.alpha.shape[-1]
    sv = jax.random.normal(k1, lead + (slots, dim))
    alpha = 0.1 * jnp.abs(jax.random.normal(k2, lead + (slots,))) + 0.01
    alpha = jnp.where(jnp.arange(slots) < budget, alpha, 0.0)
    cache = (kernel_cache.exact_cache if c == 1 else
             jax.vmap(lambda s: kernel_cache.exact_cache(s, GAMMA)))
    kmat = cache(sv, GAMMA) if c == 1 else cache(sv)
    return state._replace(
        sv_x=sv.astype(state.sv_x.dtype), alpha=alpha, kmat=kmat,
        count=jnp.full(lead, budget, jnp.int32),
        step=jnp.full(lead, 3, jnp.int32))


def bench_cell(c: int, budget: int, dim: int, *, repeats: int = 3) -> dict:
    """µs/step for both engines on one (dim, budget, C) cell."""
    key = jax.random.PRNGKey(c * 7 + budget + dim)
    xb = jax.random.normal(key, (BATCH, dim))
    out = {}
    for name, engine in (("composed", "composed"), ("fused", "pallas")):
        if c == 1:
            cfg = _cfg(budget, engine)
            state = _steady_state(init_state(cfg, dim), 1, budget, dim)
            yb = jnp.where(jax.random.uniform(key, (BATCH,)) < 0.5,
                           -1.0, 1.0)
            table = cfg.table()
            fn = lambda: train_step(cfg, table, state, xb, yb, impl="auto")
        else:
            cfg = MulticlassSVMConfig(n_classes=c, binary=_cfg(budget,
                                                               engine))
            state = _steady_state(init_multiclass_state(cfg, dim), c,
                                  budget, dim)
            yb = jax.random.randint(key, (BATCH,), 0, c)
            table = cfg.table()
            fn = lambda: train_step_multiclass(cfg, table, state, xb, yb,
                                               impl="auto")
        secs, _ = time_fn(fn, warmup=1, repeats=repeats)
        out[name] = secs * 1e6
    return out


def run(*, dims=(64, 512), budgets=(256, 1024), classes=(1, 16),
        repeats: int = 3, verbose: bool = True) -> list[dict]:
    rows = []
    for dim in dims:
        for budget in budgets:
            for c in classes:
                us = bench_cell(c, budget, dim, repeats=repeats)
                row = {"dim": dim, "budget": budget,
                       "slots": budget + BATCH, "C": c, "batch": BATCH,
                       "us_per_step": {k: round(v, 1) for k, v in us.items()},
                       "ratio_vs_composed": round(
                           us["fused"] / us["composed"], 3)}
                rows.append(row)
                if verbose:
                    print(f"dim={dim:5d} budget={budget:5d} C={c:3d}  "
                          f"us/step: composed={us['composed']:10.1f}  "
                          f"fused={us['fused']:10.1f}  "
                          f"({row['ratio_vs_composed']:.2f}x composed)",
                          flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI grid: dim {64,512} x budget {256,1024} x "
                         "C {1,16} (includes the acceptance cell "
                         "dim=512/budget=256/C=16)")
    ap.add_argument("--out", default="BENCH_train_step.json")
    args = ap.parse_args()
    if args.smoke:
        rows = run(repeats=3)
    else:
        rows = run(dims=(64, 512, 1024), repeats=5)
    payload = {"benchmark": "train_step_engines", "smoke": bool(args.smoke),
               "engines": list(ENGINES),
               "note": "one full steady-state train step (bank at budget, "
                       "batch=8 -> every violator insert forces a "
                       "maintenance event); engines are decision-bitwise "
                       "identical at every cell "
                       "(tests/core/test_step_engine.py), so rows compare "
                       "like for like",
               "rows": rows}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
