"""Paper Fig. 3: breakdown of budget-maintenance time into
  section A — solving for h / WD (GSS iterations vs table lookup), and
  section B — everything else (kappa row, argmin, executing the merge).

On TPU the equivalent split is [solver kernel] vs [rbf_row + argmin + merge
scatter]; here we measure the jit'd solver paths in isolation on
representative candidate sets, then a full maintenance event, per method.
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import default_table, maintenance_step, merge_math
from repro.core.budget import candidate_scores

from .common import csv_row, time_fn


def _mk_state(key, count, dim):
    k1, k2 = jax.random.split(key)
    sv_x = jax.random.normal(k1, (count, dim))
    alpha = jnp.abs(0.1 * jax.random.normal(k2, (count,))) + 0.01
    return sv_x, alpha


@partial(jax.jit, static_argnames=("method",))
def _solver_only(alpha, kappa, valid, method, table):
    return candidate_scores(alpha, kappa, 0, valid, method, table)[0]


def run(budget: int = 500, dim: int = 20, verbose=True):
    key = jax.random.PRNGKey(0)
    sv_x, alpha = _mk_state(key, budget + 1, dim)
    kappa = jax.random.uniform(key, (budget + 1,), minval=0.05, maxval=0.99)
    valid = jnp.ones((budget + 1,), bool).at[0].set(False)
    table = default_table()
    rows = []
    if verbose:
        print(csv_row("method", "sectionA_us", "full_event_us", "sectionB_us"))
    for method in ("gss-precise", "gss", "lookup-h", "lookup-wd"):
        tbl = table if method.startswith("lookup") else None
        t_a, _ = time_fn(lambda: _solver_only(alpha, kappa, valid, method, tbl),
                         warmup=2, repeats=5)
        t_full, _ = time_fn(
            lambda: maintenance_step(sv_x, alpha, jnp.int32(budget + 1), 0.5,
                                     method=method, table=tbl),
            warmup=2, repeats=5)
        row = (method, round(t_a * 1e6, 1), round(t_full * 1e6, 1),
               round(max(t_full - t_a, 0.0) * 1e6, 1))
        rows.append(row)
        if verbose:
            print(csv_row(*row), flush=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=500)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(budget=100 if args.quick else args.budget)


if __name__ == "__main__":
    main()
